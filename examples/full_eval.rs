//! End-to-end evaluation driver — the run recorded in EXPERIMENTS.md.
//!
//! Exercises the full three-layer stack on the paper's evaluation set:
//!
//! 1. loads the AOT-compiled JAX/Pallas compression model (PJRT) and
//!    verifies it against the native substrate on this run's data;
//! 2. simulates every eval-set workload under the five headline designs
//!    (Fig. 8/9) plus the three CABA algorithm variants (Fig. 12/13);
//! 3. prints the paper-format tables with GMean/Mean summaries and the
//!    headline-claim comparison.
//!
//! Run: `make artifacts && cargo run --release --example full_eval`
//! (set CABA_SCALE to trade fidelity for speed; default 0.1)

use caba::compress::oracle::{CompressionOracle, MemoOracle, NativeOracle};
use caba::compress::Algo;
use caba::energy::EnergyModel;
use caba::report::{figure_matrix, Series};
use caba::runtime::{artifacts_available, PjrtOracle};
use caba::sim::designs::{Design, Mechanism};
use caba::sim::Simulator;
use caba::stats::SimStats;
use caba::sweep::{resolve_jobs, SweepEngine, SweepJob};
use caba::util::geomean;
use caba::workload::apps;
use caba::SimConfig;
use std::time::Instant;

fn main() {
    let scale: f64 = std::env::var("CABA_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1);
    let jobs: usize = std::env::var("CABA_JOBS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0);
    eprintln!("[full_eval] scale {scale}, {} sweep worker(s)", resolve_jobs(jobs));
    let t0 = Instant::now();

    // ---- Layer contract check: PJRT artifact vs native substrate ----
    if artifacts_available() {
        let mut pjrt = PjrtOracle::from_default_dir().expect("load artifacts");
        let mut native = NativeOracle;
        let lines: Vec<_> = (0..512)
            .map(|i| {
                caba::workload::datagen::line_data(
                    &caba::workload::datagen::DataPattern::LowDynRange {
                        value_bytes: 8,
                        delta_bytes: 1,
                    },
                    7,
                    i,
                    0,
                )
            })
            .collect();
        for algo in Algo::CONCRETE {
            assert_eq!(
                pjrt.analyze(algo, &lines),
                native.analyze(algo, &lines),
                "PJRT artifact disagrees with native {algo:?}"
            );
        }
        println!("[ok] PJRT artifacts bit-identical to native substrate (3 algos x 512 lines)");

        // Run one full simulation with the PJRT oracle on the hot path to
        // prove the three layers compose end-to-end.
        let app = apps::find("PVC").unwrap();
        let oracle = MemoOracle::new(PjrtOracle::from_default_dir().unwrap());
        let mut sim = Simulator::with_oracle(
            SimConfig::default(),
            Design::caba(Algo::Bdi),
            app,
            (scale * 0.2).max(0.01),
            Box::new(oracle),
        );
        let s = sim.run();
        println!(
            "[ok] end-to-end sim on PJRT oracle: PVC/CABA-BDI IPC={:.3} ratio={:.2}x\n",
            s.ipc(),
            s.dram.compression_ratio()
        );
    } else {
        println!("[warn] artifacts/ missing — run `make artifacts` for the PJRT path\n");
    }

    // ---- Figs. 8/9/10/11: five headline designs ----
    let set = apps::eval_set();
    let names: Vec<&str> = set.iter().map(|a| a.name).collect();
    let designs = Design::headline();
    let em = EnergyModel::default();

    // One deduplicated parallel pass over the whole (app × design) matrix.
    let engine = SweepEngine::shared(jobs);
    let matrix: Vec<SweepJob> = set
        .iter()
        .flat_map(|&app| {
            designs
                .iter()
                .map(move |d| SweepJob::new(app, *d, SimConfig::default(), scale))
        })
        .collect();
    let flat = engine.run(&matrix).expect("eval matrix failed");
    let all: Vec<Vec<SimStats>> = flat
        .chunks(designs.len())
        .map(|row| row.to_vec())
        .collect();

    let metric = |f: &dyn Fn(&SimStats, &Design) -> f64| -> Vec<Series> {
        designs
            .iter()
            .enumerate()
            .map(|(di, d)| Series {
                label: d.name.to_string(),
                values: all.iter().map(|row| f(&row[di], d)).collect(),
            })
            .collect()
    };

    let base_ipc: Vec<f64> = all.iter().map(|r| r[0].ipc()).collect();
    let mut perf = metric(&|s, _| s.ipc());
    for s in perf.iter_mut() {
        for (i, v) in s.values.iter_mut().enumerate() {
            *v /= base_ipc[i];
        }
    }
    println!("# Fig. 8 — normalized performance (paper: CABA-BDI +41.7%)\n{}",
        figure_matrix(&names, &perf, 3));

    let n_mcs = SimConfig::default().n_mcs;
    let bw = metric(&|s, _| s.dram.bandwidth_utilization(s.cycles, n_mcs) * 100.0);
    println!("# Fig. 9 — bandwidth utilization % (paper: 53.6% -> 35.6%)\n{}",
        figure_matrix(&names, &bw, 1));

    let energy = |s: &SimStats, d: &Design| {
        em.evaluate(s, d.mechanism == Mechanism::Caba, d.mechanism == Mechanism::Hardware)
            .total_mj()
    };
    let base_e: Vec<f64> = all.iter().map(|r| energy(&r[0], &designs[0])).collect();
    let mut en = metric(&energy);
    for s in en.iter_mut() {
        for (i, v) in s.values.iter_mut().enumerate() {
            *v /= base_e[i];
        }
    }
    println!("# Fig. 10 — normalized energy (paper: CABA-BDI -22.2%)\n{}",
        figure_matrix(&names, &en, 3));

    // ---- Fig. 12/13: algorithm variants ----
    let algo_designs = [
        Design::caba(Algo::Fpc),
        Design::caba(Algo::Bdi),
        Design::caba(Algo::CPack),
        Design::caba(Algo::BestOfAll),
    ];
    let algo_matrix: Vec<SweepJob> = algo_designs
        .iter()
        .flat_map(|d| {
            set.iter()
                .map(move |&app| SweepJob::new(app, *d, SimConfig::default(), scale))
        })
        .collect();
    let algo_flat = engine.run(&algo_matrix).expect("algorithm matrix failed");
    let mut speed = Vec::new();
    let mut ratio = Vec::new();
    for (di, d) in algo_designs.iter().enumerate() {
        let row = &algo_flat[di * set.len()..(di + 1) * set.len()];
        speed.push(Series {
            label: d.name.to_string(),
            values: row.iter().enumerate().map(|(i, s)| s.ipc() / base_ipc[i]).collect(),
        });
        ratio.push(Series {
            label: d.name.to_string(),
            values: row.iter().map(|s| s.dram.compression_ratio()).collect(),
        });
    }
    println!("# Fig. 12 — speedup per algorithm (paper: FPC +20.7% BDI +41.7% C-Pack +35.2%)\n{}",
        figure_matrix(&names, &speed, 3));
    println!("# Fig. 13 — compression ratio (paper avg: BDI 2.1x)\n{}",
        figure_matrix(&names, &ratio, 2));

    // ---- Headline claims ----
    let gm = |di: usize| geomean(&perf[di].values);
    println!("# Headline comparison (geomean over {} apps)", names.len());
    println!("  CABA-BDI speedup:      {:+.1}%   (paper +41.7%)", (gm(3) - 1.0) * 100.0);
    println!("  vs Ideal-BDI:          {:+.1}%   (paper -2.8%)", (gm(3) / gm(4) - 1.0) * 100.0);
    println!("  vs HW-BDI-Mem:         {:+.1}%   (paper +9.9%)", (gm(3) / gm(1) - 1.0) * 100.0);
    println!("  vs HW-BDI:             {:+.1}%   (paper -1.6%)", (gm(3) / gm(2) - 1.0) * 100.0);
    let ratio_bdi = geomean(&ratio[1].values);
    println!("  BDI compression ratio: {:.2}x   (paper 2.1x)", ratio_bdi);
    let e_gm = geomean(&en[3].values);
    println!("  CABA-BDI energy:       {:+.1}%   (paper -22.2%)", (e_gm - 1.0) * 100.0);
    println!("\ncompleted in {:.1}s at scale {scale}", t0.elapsed().as_secs_f64());
}
