//! Quickstart: simulate one bandwidth-bound workload (PVC, the paper's
//! Fig. 6 example app) under the baseline and under CABA-BDI, and print
//! the headline comparison.
//!
//! Run: `cargo run --release --example quickstart`

use caba::compress::Algo;
use caba::energy::EnergyModel;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn main() {
    let app = apps::find("PVC").expect("PVC profile");
    let cfg = SimConfig::default();
    let scale = 0.1;

    println!("== CABA quickstart: {} (Mars suite, memory-bound) ==\n", app.name);
    println!("{}\n", cfg.table1());

    let base = Simulator::new(cfg.clone(), Design::base(), app, scale).run();
    let caba = Simulator::new(cfg.clone(), Design::caba(Algo::Bdi), app, scale).run();

    let em = EnergyModel::default();
    let e_base = em.evaluate(&base, false, false);
    let e_caba = em.evaluate(&caba, true, false);

    println!("metric                      Base        CABA-BDI");
    println!("cycles               {:>11} {:>14}", base.cycles, caba.cycles);
    println!("IPC                  {:>11.3} {:>14.3}", base.ipc(), caba.ipc());
    println!(
        "speedup              {:>11} {:>13.1}%",
        "-",
        (caba.ipc() / base.ipc() - 1.0) * 100.0
    );
    println!(
        "DRAM bursts          {:>11} {:>14}",
        base.dram.bursts, caba.dram.bursts
    );
    println!(
        "compression ratio    {:>11.2} {:>14.2}",
        base.dram.compression_ratio(),
        caba.dram.compression_ratio()
    );
    println!(
        "bandwidth util       {:>10.1}% {:>13.1}%",
        base.dram.bandwidth_utilization(base.cycles, cfg.n_mcs) * 100.0,
        caba.dram.bandwidth_utilization(caba.cycles, cfg.n_mcs) * 100.0
    );
    println!(
        "energy (mJ)          {:>11.2} {:>14.2}",
        e_base.total_mj(),
        e_caba.total_mj()
    );
    println!(
        "assist warps         {:>11} {:>14}",
        0,
        caba.caba.decompress_warps + caba.caba.compress_warps
    );
    println!(
        "\npaper (avg over eval set): +41.7% IPC, 2.1x ratio, -22.2% energy"
    );
}
