//! Bandwidth sensitivity sweep (the paper's Fig. 14 experiment, finer-
//! grained): run Base and CABA-BDI at several peak-bandwidth points and
//! show where compression stops mattering.
//!
//! Run: `cargo run --release --example bandwidth_sweep [-- <app>]`

use caba::compress::Algo;
use caba::report::Table;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn main() {
    let app_name = std::env::args().nth(1).unwrap_or_else(|| "PVC".into());
    let app = apps::find(&app_name).unwrap_or_else(|| {
        eprintln!("unknown app {app_name:?}; see `caba list`");
        std::process::exit(1);
    });
    let scale = 0.05;

    println!("# Bandwidth sweep: {} (Base vs CABA-BDI, normalized to Base@1x)\n", app.name);
    let mut base1 = None;
    let mut t = Table::new(["bw", "Base IPC", "CABA IPC", "CABA speedup", "Base bw-util", "CABA ratio"]);
    for bw in [0.25, 0.5, 1.0, 2.0, 4.0] {
        let mut cfg = SimConfig::default();
        cfg.bw_scale = bw;
        let b = Simulator::new(cfg.clone(), Design::base(), app, scale).run();
        let c = Simulator::new(cfg.clone(), Design::caba(Algo::Bdi), app, scale).run();
        if bw == 1.0 {
            base1 = Some(b.ipc());
        }
        t.row([
            format!("{bw}x"),
            format!("{:.3}", b.ipc()),
            format!("{:.3}", c.ipc()),
            format!("{:+.1}%", (c.ipc() / b.ipc() - 1.0) * 100.0),
            format!("{:.1}%", b.dram.bandwidth_utilization(b.cycles, cfg.n_mcs) * 100.0),
            format!("{:.2}x", c.dram.compression_ratio()),
        ]);
    }
    println!("{}", t.render());
    if let Some(b1) = base1 {
        let mut cfg = SimConfig::default();
        cfg.bw_scale = 2.0;
        let b2 = Simulator::new(cfg.clone(), Design::base(), app, scale).run();
        cfg.bw_scale = 1.0;
        let c1 = Simulator::new(cfg, Design::caba(Algo::Bdi), app, scale).run();
        println!(
            "paper claim check: CABA@1x = {:.2}x Base@1x; doubling BW = {:.2}x \
             (\"performance improvement of CABA is often equivalent to doubling the bandwidth\")",
            c1.ipc() / b1,
            b2.ipc() / b1
        );
    }
}
