//! Compression explorer: push data-distribution classes (and, optionally,
//! a real file) through BDI / FPC / C-Pack with both oracle backends and
//! print per-pattern compression ratios — a standalone tour of the
//! substrate the assist warps execute.
//!
//! Run: `cargo run --release --example compression_explorer [-- <file>]`

use caba::compress::oracle::{CompressionOracle, NativeOracle};
use caba::compress::{Algo, Line, LINE_BYTES, LINE_BURSTS};
use caba::report::Table;
use caba::runtime::{artifacts_available, PjrtOracle};
use caba::workload::datagen::{line_data, DataPattern};

fn ratio(oracle: &mut dyn CompressionOracle, algo: Algo, lines: &[Line]) -> f64 {
    let verdicts = oracle.analyze(algo, lines);
    let bursts: u64 = verdicts.iter().map(|v| v.bursts as u64).sum();
    (lines.len() as u64 * LINE_BURSTS as u64) as f64 / bursts as f64
}

fn main() {
    let n = 2048;
    let patterns: Vec<(&str, DataPattern)> = vec![
        ("zeros-heavy", DataPattern::ZeroHeavy { p_zero: 0.6 }),
        ("pointers-8B (PVC)", DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 }),
        ("narrow-int (SLA)", DataPattern::NarrowInt { max: 120 }),
        ("dict-pointers (graph)", DataPattern::PointerLike { n_bases: 4 }),
        ("repeated-bytes (JPEG)", DataPattern::RepBytes),
        ("sparse-narrow (LPS)", DataPattern::SparseNarrow { p_nonzero: 0.3 }),
        ("float-grid (RAY)", DataPattern::FloatGrid { exp: 120 }),
        ("random (SCP)", DataPattern::Random),
    ];

    let mut native = NativeOracle;
    let mut pjrt = if artifacts_available() {
        Some(PjrtOracle::from_default_dir().expect("artifact load"))
    } else {
        eprintln!("(artifacts missing — native backend only; run `make artifacts`)");
        None
    };

    let mut t = Table::new(["pattern", "BDI", "FPC", "C-Pack", "Best", "backend-check"]);
    for (name, p) in &patterns {
        let lines: Vec<Line> = (0..n).map(|i| line_data(p, 42, i as u64, 0)).collect();
        let r: Vec<f64> = [Algo::Bdi, Algo::Fpc, Algo::CPack, Algo::BestOfAll]
            .iter()
            .map(|&a| ratio(&mut native, a, &lines))
            .collect();
        let check = match &mut pjrt {
            Some(px) => {
                let agree = Algo::CONCRETE.iter().all(|&a| {
                    px.analyze(a, &lines[..256]) == native.analyze(a, &lines[..256])
                });
                if agree { "pjrt==native" } else { "MISMATCH!" }
            }
            None => "native-only",
        };
        t.row([
            name.to_string(),
            format!("{:.2}x", r[0]),
            format!("{:.2}x", r[1]),
            format!("{:.2}x", r[2]),
            format!("{:.2}x", r[3]),
            check.to_string(),
        ]);
    }
    println!("# Compression ratios by data-distribution class ({n} lines each)\n");
    println!("{}", t.render());

    // Optional: analyze a real file's bytes.
    if let Some(path) = std::env::args().nth(1) {
        match std::fs::read(&path) {
            Ok(bytes) => {
                let lines: Vec<Line> = bytes
                    .chunks_exact(LINE_BYTES)
                    .take(1 << 16)
                    .map(|c| {
                        let mut l = [0u8; LINE_BYTES];
                        l.copy_from_slice(c);
                        l
                    })
                    .collect();
                if lines.is_empty() {
                    eprintln!("{path}: too small ({} bytes)", bytes.len());
                    return;
                }
                println!("\n# {path} ({} lines)", lines.len());
                for algo in [Algo::Bdi, Algo::Fpc, Algo::CPack, Algo::BestOfAll] {
                    println!(
                        "  {:<10} {:.3}x",
                        algo.name(),
                        ratio(&mut native, algo, &lines)
                    );
                }
            }
            Err(e) => eprintln!("cannot read {path}: {e}"),
        }
    }
}
