//! Minimal, offline, API-compatible subset of the `anyhow` crate.
//!
//! The build image has no crates.io access, so the repo vendors the small
//! slice of anyhow it actually uses:
//!
//! * [`Error`] — an opaque error value carrying a message and a cause
//!   chain; `{e}` prints the outermost message, `{e:#}` the full chain.
//! * [`Result`] — `Result<T, Error>` with a defaultable error type.
//! * [`Context`] — `.context(msg)` / `.with_context(|| msg)` on both
//!   `Result` and `Option`.
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction.
//!
//! Like the real crate, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what makes the blanket
//! `From<E: std::error::Error>` impl coherent.

use std::fmt;

/// An opaque error: an outermost message plus the `Display` renderings of
/// the source chain it was built from (or wrapped around via `context`).
pub struct Error {
    /// Outermost message first, root cause last.
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { chain: vec![m.to_string()] }
    }

    /// Wrap with an outer context message (the new outermost error).
    pub fn context<C: fmt::Display>(mut self, c: C) -> Error {
        self.chain.insert(0, c.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for c in &self.chain[1..] {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error carried by a `Result` or to a `None`.
pub trait Context<T> {
    /// Wrap any error with `context` as the new outermost message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(::std::format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_u32(s: &str) -> Result<u32> {
        let v = s.parse::<u32>().with_context(|| format!("bad value: {s:?}"))?;
        Ok(v)
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let err = parse_u32("zonk").unwrap_err();
        assert_eq!(format!("{err}"), "bad value: \"zonk\"");
        let full = format!("{err:#}");
        assert!(full.starts_with("bad value: \"zonk\": "), "{full}");
        assert!(full.contains("invalid digit"), "{full}");
    }

    #[test]
    fn option_context_and_macros() {
        let none: Option<u32> = None;
        let err = none.context("missing thing").unwrap_err();
        assert_eq!(format!("{err}"), "missing thing");

        fn fails() -> Result<()> {
            bail!("code {}", 7);
        }
        let err = fails().unwrap_err();
        assert_eq!(format!("{err}"), "code 7");
        let e2 = anyhow!("x={}", 1);
        assert_eq!(format!("{e2:#}"), "x=1");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<u32> {
            let v: u32 = "12".parse()?;
            Ok(v)
        }
        assert_eq!(inner().unwrap(), 12);
    }

    #[test]
    fn debug_renders_cause_chain() {
        let err = parse_u32("x").unwrap_err();
        let dbg = format!("{err:?}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }
}
