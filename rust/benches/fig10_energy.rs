//! Fig. 10: normalized energy of the five designs.
fn main() {
    caba::report::benchutil::run_bench("fig10", caba::report::figures::fig10_energy);
}
