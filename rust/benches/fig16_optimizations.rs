//! Fig. 16: Uncompressed-L2 and Direct-Load optimizations.
fn main() {
    caba::report::benchutil::run_bench("fig16", caba::report::figures::fig16_optimizations);
}
