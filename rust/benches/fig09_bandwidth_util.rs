//! Fig. 9: memory bandwidth utilization of the five designs.
fn main() {
    caba::report::benchutil::run_bench("fig09", caba::report::figures::fig09_bandwidth_utilization);
}
