//! Fig. 15: L1/L2 cache-capacity compression (2x/4x tags).
fn main() {
    caba::report::benchutil::run_bench("fig15", caba::report::figures::fig15_cache_compression);
}
