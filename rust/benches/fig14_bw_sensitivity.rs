//! Fig. 14: sensitivity to 0.5x/1x/2x peak memory bandwidth.
fn main() {
    caba::report::benchutil::run_bench("fig14", caba::report::figures::fig14_bw_sensitivity);
}
