//! Hot-path microbenchmarks — the measurement harness for EXPERIMENTS.md
//! §Perf (L3 simulator throughput, compression substrate throughput, oracle
//! memoization, PJRT batch latency).

use caba::compress::oracle::{CompressionOracle, MemoOracle, NativeOracle};
use caba::compress::{compress, Algo, Line, LINE_BYTES};
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::workload::datagen::{line_data, DataPattern};
use caba::SimConfig;
use std::time::Instant;

fn lines(n: usize, p: DataPattern) -> Vec<Line> {
    (0..n).map(|i| line_data(&p, 3, i as u64, 0)).collect()
}

fn main() {
    println!("# Hot-path microbenchmarks\n");

    // --- Compression substrate throughput ---
    let mixed: Vec<Line> = lines(4096, DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 })
        .into_iter()
        .chain(lines(4096, DataPattern::Random))
        .chain(lines(4096, DataPattern::SparseNarrow { p_nonzero: 0.3 }))
        .collect();
    for algo in Algo::CONCRETE {
        let t0 = Instant::now();
        let mut total = 0usize;
        for line in &mixed {
            total += compress(algo, line).size_bytes();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "compress {:<7} {:>8.1} Mlines/s  ({:>6.1} MB/s input, checksum {total})",
            algo.name(),
            mixed.len() as f64 / dt / 1e6,
            mixed.len() as f64 * LINE_BYTES as f64 / dt / 1e6
        );
    }

    // --- Oracle memoization ---
    let mut memo = MemoOracle::new(NativeOracle);
    let t0 = Instant::now();
    memo.analyze(Algo::Bdi, &mixed);
    let cold = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    memo.analyze(Algo::Bdi, &mixed);
    let warm = t0.elapsed().as_secs_f64();
    println!(
        "\noracle memo: cold {:>8.1} Mlines/s, warm {:>8.1} Mlines/s ({:.0}x)",
        mixed.len() as f64 / cold / 1e6,
        mixed.len() as f64 / warm / 1e6,
        cold / warm
    );

    // --- PJRT batch path ---
    if caba::runtime::artifacts_available() {
        let mut pjrt = caba::runtime::PjrtOracle::from_default_dir().expect("artifacts");
        pjrt.analyze(Algo::Bdi, &mixed[..256]); // compile+warm
        let t0 = Instant::now();
        let reps = 8;
        for _ in 0..reps {
            pjrt.analyze(Algo::Bdi, &mixed[..2048]);
        }
        let dt = t0.elapsed().as_secs_f64() / reps as f64;
        println!(
            "pjrt oracle (BDI, 2048-line call): {:.2} ms/call, {:>6.2} Mlines/s",
            dt * 1e3,
            2048.0 / dt / 1e6
        );
    } else {
        println!("pjrt oracle: SKIPPED (run `make artifacts`)");
    }

    // --- Simulator throughput (the L3 hot loop) ---
    println!();
    for (name, design) in [("Base", Design::base()), ("CABA-BDI", Design::caba(Algo::Bdi))] {
        let app = apps::find("PVC").unwrap();
        let t0 = Instant::now();
        let stats = Simulator::new(SimConfig::default(), design, app, 0.1).run();
        let dt = t0.elapsed().as_secs_f64();
        println!(
            "sim PVC/{name:<9} {:>7.2} Mcycles/s  {:>7.2} Minsts/s  (cycles {}, host {:.2}s)",
            stats.cycles as f64 / dt / 1e6,
            stats.warp_insts as f64 / dt / 1e6,
            stats.cycles,
            dt
        );
    }

    // --- Trace replay throughput (lines/sec through the trace subsystem) ---
    // Record a CABA-BDI run, then measure how fast the replayer feeds the
    // same access stream back through the full pipeline.
    {
        use caba::trace::replay::TraceData;
        use std::sync::Arc;
        let app = apps::find("PVC").unwrap();
        let design = Design::caba(Algo::Bdi);
        let path = std::env::temp_dir()
            .join(format!("caba_perf_replay_{}.cabatrace", std::process::id()));
        let path_s = path.to_str().unwrap();
        let t0 = Instant::now();
        let mut rec_sim = Simulator::new(SimConfig::default(), design, app, 0.05);
        rec_sim.record_to(path_s).expect("attach recorder");
        let rec_stats = rec_sim.run();
        let rec_dt = t0.elapsed().as_secs_f64();
        let trace = TraceData::load(path_s).expect("load trace");
        let t0 = Instant::now();
        let rep_stats = Simulator::from_trace(SimConfig::default(), design, Arc::clone(&trace))
            .expect("build replay")
            .run();
        let rep_dt = t0.elapsed().as_secs_f64();
        assert_eq!(
            rep_stats.memory_signature(),
            rec_stats.memory_signature(),
            "replay diverged from recording"
        );
        println!(
            "\ntrace record PVC/CABA-BDI  {:>7.2} Mlines/s captured  ({} accesses, host {:.2}s)",
            trace.total_lines as f64 / rec_dt / 1e6,
            trace.n_access_records,
            rec_dt
        );
        println!(
            "trace replay PVC/CABA-BDI  {:>7.2} Mlines/s replayed  ({:.2} Mcycles/s, host {:.2}s)",
            trace.replayed_lines() as f64 / rep_dt / 1e6,
            rep_stats.cycles as f64 / rep_dt / 1e6,
            rep_dt
        );
        std::fs::remove_file(&path).ok();
    }

    // --- Sweep-engine scaling (the EXPERIMENTS.md wall-clock table) ---
    // The Fig. 8 matrix (eval set × five headline designs) at a small
    // scale, executed with 1/2/4/... workers on *private* caches so every
    // run re-simulates. Results are asserted bit-identical across worker
    // counts while we're at it.
    use caba::sweep::{resolve_jobs, SweepEngine, SweepJob};
    println!();
    let set = apps::eval_set();
    let jobs: Vec<SweepJob> = set
        .iter()
        .flat_map(|&app| {
            Design::headline()
                .into_iter()
                .map(move |d| SweepJob::new(app, d, SimConfig::default(), 0.02))
        })
        .collect();
    let mut serial_dt = None;
    let mut reference = None;
    let max_workers = resolve_jobs(0);
    let mut w = 1;
    while w <= max_workers {
        let engine = SweepEngine::new(w);
        let t0 = Instant::now();
        let out = engine.run(&jobs).expect("bench sweep failed");
        let dt = t0.elapsed().as_secs_f64();
        match reference.take() {
            None => reference = Some(out),
            Some(r) => {
                assert_eq!(r, out, "sweep results diverge at {w} workers");
                reference = Some(r);
            }
        }
        let speedup = serial_dt.get_or_insert(dt);
        println!(
            "sweep fig8-matrix ({} jobs) --jobs {:<3} {:>6.2}s  ({:.2}x vs serial)",
            jobs.len(),
            w,
            dt,
            *speedup / dt
        );
        if w == max_workers {
            break;
        }
        w = (w * 2).min(max_workers);
    }
}
