//! §8 extension experiments: the paper's other CABA use cases, implemented
//! as first-class framework features — memoization (§8.1) on compute-bound
//! SFU-heavy apps, and stride-prefetching (§8.2) on latency-sensitive apps.
//! (The paper leaves their evaluation to future work; these benches are the
//! "future work" experiments.)

use caba::report::Table;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::SimConfig;

fn main() {
    let scale = caba::report::benchutil::bench_scale();

    // --- §8.1 Memoization: the compute-bound suite + the paper pool's
    // SFU-heavy members. Hit/alias/evict rates are *measured* through the
    // per-SM LUT model (see `caba fig memo` for the full figure).
    let mut t = Table::new([
        "app", "Base IPC", "CABA-Memo IPC", "speedup", "LUT hit", "alias", "evict/install",
    ]);
    for app in apps::memo_suite() {
        let base = Simulator::new(SimConfig::default(), Design::base(), app, scale).run();
        let memo = Simulator::new(SimConfig::default(), Design::caba_memo(), app, scale).run();
        let c = memo.caba;
        let pct = |n: u64, d: u64| {
            if d == 0 { "n/a".to_string() } else { format!("{:.0}%", n as f64 / d as f64 * 100.0) }
        };
        t.row([
            app.name.to_string(),
            format!("{:.3}", base.ipc()),
            format!("{:.3}", memo.ipc()),
            format!("{:+.1}%", (memo.ipc() / base.ipc() - 1.0) * 100.0),
            pct(c.memo_hits, c.memo_lookups),
            pct(c.memo_alias_hits, c.memo_lookups),
            pct(c.memo_evictions, c.memo_installs),
        ]);
    }
    println!("# §8.1 — CABA memoization on compute-bound apps\n{}", t.render());

    // --- §8.2 Prefetching: latency-bound streaming apps ---
    let mut t = Table::new(["app", "Base IPC", "CABA-Prefetch IPC", "speedup", "prefetches", "L1 hit Δ"]);
    for name in ["hs", "CONS", "MM", "RAY", "bh"] {
        let app = apps::find(name).unwrap();
        let base = Simulator::new(SimConfig::default(), Design::base(), app, scale).run();
        let pf = Simulator::new(SimConfig::default(), Design::caba_prefetch(), app, scale).run();
        t.row([
            name.to_string(),
            format!("{:.3}", base.ipc()),
            format!("{:.3}", pf.ipc()),
            format!("{:+.1}%", (pf.ipc() / base.ipc() - 1.0) * 100.0),
            pf.caba.prefetches_issued.to_string(),
            format!(
                "{:+.1}pp",
                (pf.l1.hit_rate() - base.l1.hit_rate()) * 100.0
            ),
        ]);
    }
    println!("# §8.2 — CABA stride-prefetching on latency-sensitive apps\n{}", t.render());
}
