//! Table 1: the simulated system configuration.
fn main() {
    caba::report::benchutil::run_bench("table1", |_| {
        format!("# Table 1 — major parameters of the simulated system\n{}", caba::SimConfig::default().table1())
    });
}
