//! Fig. 12: speedup with FPC / BDI / C-Pack / BestOfAll under CABA.
fn main() {
    caba::report::benchutil::run_bench("fig12", caba::report::figures::fig12_algorithms);
}
