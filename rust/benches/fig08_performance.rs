//! Fig. 8: normalized performance of the five designs.
fn main() {
    caba::report::benchutil::run_bench("fig08", caba::report::figures::fig08_performance);
}
