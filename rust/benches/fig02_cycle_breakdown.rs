//! Fig. 2: issue-cycle breakdown, 27 apps × {0.5x, 1x, 2x} bandwidth.
fn main() {
    caba::report::benchutil::run_bench("fig02", caba::report::figures::fig02_cycle_breakdown);
}
