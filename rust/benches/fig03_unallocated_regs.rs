//! Fig. 3: fraction of statically unallocated registers.
fn main() {
    caba::report::benchutil::run_bench("fig03", caba::report::figures::fig03_unallocated_regs);
}
