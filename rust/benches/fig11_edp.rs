//! Fig. 11: normalized energy-delay product.
fn main() {
    caba::report::benchutil::run_bench("fig11", caba::report::figures::fig11_edp);
}
