//! §5.3.2: MD-cache hit rate across the eval set (paper: 85% average).
fn main() {
    caba::report::benchutil::run_bench("md_cache", caba::report::figures::md_cache_hitrate);
}
