//! Ablation studies for the design choices DESIGN.md §6 calls out:
//! AWC throttling, MD-cache size, AWT capacity, and the FPC segment-size
//! simplicity/compressibility trade-off (§5.1.4).

use caba::compress::fpc::Fpc;
use caba::compress::{Algo, Compressor, LINE_BURSTS};
use caba::report::Table;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::workload::apps;
use caba::workload::datagen::{line_data, DataPattern};
use caba::SimConfig;

fn ipc(app: &'static caba::workload::apps::AppSpec, cfg: SimConfig, scale: f64) -> f64 {
    Simulator::new(cfg, Design::caba(Algo::Bdi), app, scale).run().ipc()
}

fn main() {
    let scale = caba::report::benchutil::bench_scale();
    let app = apps::find("PVC").unwrap();

    // --- Throttling on/off (§4.4 dynamic feedback) ---
    let mut t = Table::new(["throttle", "IPC", "compress skipped", "throttled deploys"]);
    for on in [true, false] {
        let mut cfg = SimConfig::default();
        cfg.caba_throttle = on;
        let s = Simulator::new(cfg, Design::caba(Algo::Bdi), app, scale).run();
        t.row([
            on.to_string(),
            format!("{:.3}", s.ipc()),
            s.caba.compress_skipped.to_string(),
            s.caba.throttled_deploys.to_string(),
        ]);
    }
    println!("# Ablation: AWC utilization-feedback throttle (PVC, CABA-BDI)\n{}", t.render());

    // --- MD cache size (§5.3.2) ---
    let mut t = Table::new(["md cache", "IPC", "MD hit rate", "extra DRAM accesses"]);
    for kb in [1usize, 4, 8, 32, 128] {
        let mut cfg = SimConfig::default();
        cfg.md_cache_bytes = kb * 1024;
        let s = Simulator::new(cfg, Design::caba(Algo::Bdi), app, scale).run();
        t.row([
            format!("{kb}KB"),
            format!("{:.3}", s.ipc()),
            format!("{:.1}%", s.md.hit_rate() * 100.0),
            s.dram.md_accesses.to_string(),
        ]);
    }
    println!("# Ablation: MD-cache size (paper: 8KB 4-way, 85% avg hit rate)\n{}", t.render());

    // --- AWT capacity ---
    let mut t = Table::new(["AWT entries", "IPC", "compress skipped"]);
    for entries in [4usize, 16, 32, 128] {
        let mut cfg = SimConfig::default();
        cfg.awt_entries = entries;
        let s = Simulator::new(cfg, Design::caba(Algo::Bdi), app, scale).run();
        t.row([
            entries.to_string(),
            format!("{:.3}", s.ipc()),
            s.caba.compress_skipped.to_string(),
        ]);
    }
    println!("# Ablation: Assist Warp Table capacity\n{}", t.render());

    // --- FPC segment size (ratio only; §5.1.4 trade-off) ---
    let mut t = Table::new(["segment words", "ratio (sparse)", "ratio (narrow)"]);
    for seg in [4usize, 8, 16] {
        let f = Fpc { segment_words: seg };
        let mut ratios = Vec::new();
        for p in [
            DataPattern::SparseNarrow { p_nonzero: 0.3 },
            DataPattern::NarrowInt { max: 120 },
        ] {
            let mut bursts = 0u64;
            let n = 2000;
            for i in 0..n {
                bursts += f.compress(&line_data(&p, 5, i, 0)).bursts() as u64;
            }
            ratios.push(n as f64 * LINE_BURSTS as f64 / bursts as f64);
        }
        t.row([
            seg.to_string(),
            format!("{:.2}x", ratios[0]),
            format!("{:.2}x", ratios[1]),
        ]);
    }
    println!("# Ablation: FPC segment size (parallelism vs compressibility)\n{}", t.render());

    // --- Assist-warp register provisioning (occupancy cost, §4.2.2) ---
    let mut t = Table::new(["app", "CTAs base", "CTAs +2regs", "unallocated base"]);
    let cfg = SimConfig::default();
    for name in ["PVC", "CONS", "RAY", "MM"] {
        let a = apps::find(name).unwrap();
        let o0 = caba::workload::occupancy(a, &cfg, 0);
        let o2 = caba::workload::occupancy(a, &cfg, caba::sim::CABA_EXTRA_REGS);
        t.row([
            name.to_string(),
            o0.ctas_per_sm.to_string(),
            o2.ctas_per_sm.to_string(),
            format!("{:.1}%", o0.unallocated_reg_frac * 100.0),
        ]);
    }
    println!("# Ablation: assist-warp register provisioning\n{}", t.render());
    let _ = ipc; // helper retained for future ablations
}
