//! Fig. 13: compression ratio of the algorithms under CABA.
fn main() {
    caba::report::benchutil::run_bench("fig13", caba::report::figures::fig13_compression_ratio);
}
