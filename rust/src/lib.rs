//! # CABA — Core-Assisted Bottleneck Acceleration
//!
//! A full reproduction of *"A Framework for Accelerating Bottlenecks in GPU
//! Execution with Assist Warps"* (Vijaykumar et al., 2016) as a
//! production-quality Rust + JAX + Pallas stack.
//!
//! The crate contains:
//!
//! * a **cycle-level GPU simulator** ([`core`], [`mem`], [`sim`]) modelling
//!   the paper's baseline (Table 1): 15 SMs, GTO warp scheduling, L1/L2
//!   caches, a crossbar interconnect and GDDR5 memory controllers;
//! * the **CABA microarchitecture** ([`caba`]): Assist Warp Store,
//!   Controller, Table and Buffer, with trigger/deploy/kill, priorities
//!   and dynamic throttling;
//! * byte-exact **compression substrates** ([`compress`]): BDI, FPC and
//!   C-Pack, used both as "hardware" compressors and as assist-warp
//!   subroutines;
//! * a **PJRT runtime** ([`runtime`]) that loads the AOT-compiled JAX/Pallas
//!   compression model (`artifacts/*.hlo.txt`) and serves it as a batched
//!   compression oracle from the Rust hot path — Python is never on the
//!   request path;
//! * an **energy model** ([`energy`]), the paper's 27 **workloads**
//!   ([`workload`]) and the full **evaluation harness** ([`report`],
//!   `rust/benches/`) regenerating every table and figure;
//! * a deterministic **parallel sweep engine** ([`sweep`]) that executes
//!   the `(app × design × bw_scale)` evaluation matrices on a scoped
//!   `std::thread` worker pool — `caba fig 8 --jobs N` is bit-identical
//!   to `--jobs 1`, just faster;
//! * a **trace capture & replay subsystem** ([`trace`]): `caba trace
//!   record` streams a run's warp-level memory accesses and line payloads
//!   into a compact versioned binary format, `caba trace replay` drives
//!   the full pipeline from such a file (bit-identical memory-side
//!   statistics), and `caba trace import` converts accelsim-style text
//!   dumps — trace-driven jobs participate in sweeps, cache-keyed on the
//!   trace's content digest;
//! * a calibrated **perf harness** ([`bench`]): `caba bench` measures the
//!   hot paths (word-wise compressors, open-addressed oracle memo,
//!   end-to-end simulator throughput), writes a machine-readable
//!   `BENCH_*.json`, and gates CI against committed regression floors;
//! * a **value-based memoization subsystem** ([`memo`], §8.1): per-SM
//!   set-associative LUTs carved from unutilized shared memory, probed
//!   with hashes of real operand values ([`workload::values`]) at the SFU
//!   issue path — hit rates emerge from the data (capacity, eviction and
//!   tag aliasing all modeled) instead of being drawn from a table, and a
//!   compute-bound workload suite (`workload::apps::MEMO_APPS`) exercises
//!   the paper's second bottleneck axis (`caba fig memo`);
//! * a deterministic **flight recorder** ([`telemetry`]): fixed-cadence
//!   windowed timelines of IPC / stalls / bandwidth / cache and AWT
//!   occupancy plus bounded assist-warp span logs, bit-identical across
//!   all tick modes and provably observation-only — rendered as ASCII
//!   sparklines and a per-SM stall heatmap (`caba run --timeline`,
//!   [`report::timeline`]) or exported as Perfetto-loadable Chrome
//!   trace-event JSON (`caba prof`);
//! * a **crash-safe on-disk run store** ([`store`]): content-addressed by
//!   the sweep `JobKey`, written atomically (temp + fsync + rename) with
//!   per-entry checksums and version headers, quarantining anything
//!   corrupt instead of trusting or aborting — plus a deterministic
//!   fault-injection harness ([`store::fault`]);
//! * a **fault-tolerant sweep service** ([`serve`]): `caba serve` answers
//!   JSON sweep requests over a unix socket, deduping in-flight identical
//!   requests, serving warm hits from the store, and running cold misses
//!   on panic-isolated workers behind a bounded queue with load shedding,
//!   per-request deadlines and graceful SIGTERM drain;
//! * a **service observability layer** ([`obs`]): lock-cheap atomic
//!   counters/gauges and log2-bucketed latency histograms (p50/p95/p99
//!   from buckets, allocation-free hot path), per-request trace spans
//!   with ids echoed in every serve response, a hand-rolled Prometheus
//!   text exposition behind the `metrics` verb, and `caba prof --serve`
//!   rendering server request spans as Perfetto-loadable Chrome trace
//!   JSON — all observation-only, pinned bit-identical on/off by test;
//! * a **bounded-resource resilience layer** ([`client`], plus capacity
//!   management in [`store`] and brownout in [`serve`]): the store runs
//!   under a byte budget (`--store-max-bytes`) with LRU eviction,
//!   incremental compaction and quarantine GC; disk faults (ENOSPC, read
//!   EIO, slow fsync, dropped connections) degrade to
//!   compute-without-caching instead of failing; the daemon sheds new
//!   cold work when queue-wait p95 crosses `--brownout-p95-ms` while
//!   still serving warm hits; and `caba client` retries shed/deadline/
//!   connection failures with capped, deterministically-jittered backoff,
//!   asserting bit-identical `stats_digest`s across retries.
//!
//! See `DESIGN.md` (repo root) for the system inventory and
//! `EXPERIMENTS.md` for paper-vs-measured results and the sweep-engine
//! wall-clock methodology. `README.md` has the quickstart and the full
//! CLI reference.

pub mod bench;
pub mod caba;
pub mod client;
pub mod compress;
pub mod config;
pub mod core;
pub mod energy;
pub mod isa;
pub mod mem;
pub mod memo;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod stats;
pub mod store;
pub mod sweep;
pub mod telemetry;
pub mod trace;
pub mod util;
pub mod workload;

pub use config::SimConfig;
pub use sim::designs::Design;
pub use sim::Simulator;
