//! # Retrying serve client
//!
//! [`crate::serve::client_request`] is a deliberately dumb one-shot:
//! connect, one line out, one line back. Real clients of a service that
//! *sheds under load by design* (queue-full, brownout), expires
//! deadlines, and may drop a connection mid-flight (see the
//! `drop_conn_at` chaos key) need a retry loop — and a retry loop that
//! is honest about what is retryable:
//!
//! * **retryable** — `"status":"shed"` (queue full or brownout),
//!   `"status":"deadline"` (the job keeps running and will be warm on
//!   retry), and any transport failure (connect error, mid-flight EOF,
//!   unparsable response). These hold no server resources; backing off
//!   and retrying is exactly what the daemon's shed message asks for.
//! * **terminal** — `"status":"ok"` (done), `"status":"error"` (a typed
//!   [`crate::sweep::JobError`] or a bad request: retrying would
//!   recompute the same failure), and `"status":"draining"` (this
//!   daemon is going away; the caller decides where to go next).
//!
//! ## Backoff: capped exponential, deterministic jitter
//!
//! Delays follow full jitter over `[0, min(cap, base * 2^attempt)]`,
//! but the "randomness" is a seeded xorshift over
//! `(seed, request, attempt)` — two runs with the same seed produce the
//! same delays, so the chaos soak (`tests/chaos_soak.rs`) is replayable,
//! while different requests still decorrelate their retry storms.
//!
//! ## Idempotent retry, asserted
//!
//! Every `ok` response carries `stats_digest` (FNV-1a64 of the stats'
//! canonical encoding). [`Conn`] remembers the first digest it saw per
//! request line and **asserts bit-identity** on every later `ok` for the
//! same line — across retries and across repeats. A mismatch is not a
//! retryable blip, it is the one thing the whole stack promises can
//! never happen, so it surfaces as a hard error.

use crate::serve::json::{self, Json};
use crate::store::fnv1a64;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::{Path, PathBuf};
use std::time::Duration;

/// Capped exponential backoff with deterministic (seeded) full jitter.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Retries after the first attempt (0 = one-shot).
    pub max_retries: u32,
    /// Backoff base, milliseconds: attempt `k` draws from
    /// `[0, min(cap_ms, base_ms * 2^k)]`.
    pub base_ms: u64,
    /// Backoff ceiling, milliseconds.
    pub cap_ms: u64,
    /// Jitter seed. Same seed, same request, same attempt → same delay.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_retries: 4, base_ms: 10, cap_ms: 2_000, seed: 0xcaba_5eed }
    }
}

impl RetryPolicy {
    /// The delay before retry `attempt` (0-based) of the request whose
    /// identity hash is `salt`. Pure: the chaos soak replays byte-equal
    /// schedules from the seed alone.
    pub fn backoff_ms(&self, attempt: u32, salt: u64) -> u64 {
        let ceiling = self
            .base_ms
            .saturating_mul(1u64 << attempt.min(20))
            .min(self.cap_ms);
        if ceiling == 0 {
            return 0;
        }
        // xorshift64* over the (seed, request, attempt) tuple.
        let stride = (u64::from(attempt) + 1).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut x = self.seed ^ salt.rotate_left(17) ^ stride;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d) % (ceiling + 1)
    }
}

/// What a converged request ended as. Both variants carry the verbatim
/// response line (`raw`) — the CLI prints it unchanged, so scripts see
/// exactly what the daemon said.
#[derive(Clone, Debug)]
pub enum Response {
    /// `"status":"ok"`.
    Ok {
        raw: String,
        /// `stats_digest` if the response carried one (sweep answers do,
        /// ping/stats answers don't).
        digest: Option<String>,
        /// `source` field (`warm`/`cold`/`dedup`) if present.
        source: Option<String>,
    },
    /// A terminal non-ok: typed job/request error or a draining daemon.
    Terminal { raw: String, status: String, message: String },
}

impl Response {
    /// The verbatim response line.
    pub fn raw(&self) -> &str {
        match self {
            Response::Ok { raw, .. } | Response::Terminal { raw, .. } => raw,
        }
    }

    pub fn is_ok(&self) -> bool {
        matches!(self, Response::Ok { .. })
    }
}

/// Client-side tallies, mostly for tests and the CLI's `--log`-style
/// stderr note. Plain fields: [`Conn`] is `&mut self` throughout.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ClientCounters {
    /// Request attempts sent (first tries + retries).
    pub attempts: u64,
    /// Retries performed (attempts beyond each request's first).
    pub retries: u64,
    /// `shed` answers seen (queue full or brownout).
    pub sheds_seen: u64,
    /// `deadline` answers seen.
    pub deadlines_seen: u64,
    /// Transport failures (connect/EOF/unparsable response).
    pub conn_errors: u64,
    /// `ok` answers whose digest was checked against a remembered one.
    pub digest_rechecks: u64,
}

/// A persistent connection to a serve daemon with retry, reconnect and
/// digest bit-identity built in. One line of protocol per call: hand a
/// request line to [`Conn::request`], get a terminal [`Response`] or an
/// error after the retry budget is spent.
pub struct Conn {
    socket: PathBuf,
    policy: RetryPolicy,
    reader: Option<BufReader<UnixStream>>,
    /// First `stats_digest` seen per request-line hash; later `ok`s for
    /// the same line must match bit-for-bit.
    digests: HashMap<u64, String>,
    counters: ClientCounters,
}

impl Conn {
    /// Lazily-connecting client for `socket`. No I/O happens until the
    /// first [`Conn::request`].
    pub fn new(socket: impl Into<PathBuf>, policy: RetryPolicy) -> Conn {
        Conn {
            socket: socket.into(),
            policy,
            reader: None,
            digests: HashMap::new(),
            counters: ClientCounters::default(),
        }
    }

    pub fn counters(&self) -> ClientCounters {
        self.counters
    }

    /// One write+read over the persistent stream, (re)connecting as
    /// needed. Any failure tears the stream down so the next attempt
    /// reconnects from scratch.
    fn roundtrip(&mut self, line: &str) -> Result<String> {
        if self.reader.is_none() {
            let stream = UnixStream::connect(&self.socket)
                .with_context(|| format!("connect {}", self.socket.display()))?;
            self.reader = Some(BufReader::new(stream));
        }
        let reader = self.reader.as_mut().expect("just connected");
        let io = (|| -> Result<String> {
            let mut w = reader.get_ref();
            w.write_all(line.as_bytes()).context("send request")?;
            w.write_all(b"\n").context("send request")?;
            w.flush().context("send request")?;
            let mut resp = String::new();
            reader.read_line(&mut resp).context("read response")?;
            if resp.is_empty() {
                bail!("server closed the connection without a response");
            }
            Ok(resp.trim_end().to_string())
        })();
        if io.is_err() {
            self.reader = None;
        }
        io
    }

    /// Drive `line` to a terminal answer: retry shed/deadline/transport
    /// failures under the backoff policy, return `ok` and typed
    /// error/draining answers as-is, and fail hard on either an
    /// exhausted retry budget or — the one unforgivable case — an `ok`
    /// whose `stats_digest` differs from an earlier answer to the same
    /// request.
    pub fn request(&mut self, line: &str) -> Result<Response> {
        let line = line.trim();
        let salt = fnv1a64(line.as_bytes());
        let mut attempt = 0u32;
        loop {
            self.counters.attempts += 1;
            let retryable_because = match self.roundtrip(line) {
                Ok(raw) => match classify(&raw) {
                    Classified::Ok { digest, source } => {
                        if let Some(d) = &digest {
                            match self.digests.get(&salt) {
                                None => {
                                    self.digests.insert(salt, d.clone());
                                }
                                Some(first) => {
                                    self.counters.digest_rechecks += 1;
                                    if first != d {
                                        bail!(
                                            "stats_digest mismatch for retried request: \
                                             first answer {first}, now {d} — the store/serve \
                                             bit-identity contract is broken (request: {line})"
                                        );
                                    }
                                }
                            }
                        }
                        return Ok(Response::Ok { raw, digest, source });
                    }
                    Classified::Terminal { status, message } => {
                        return Ok(Response::Terminal { raw, status, message });
                    }
                    Classified::RetryShed => {
                        self.counters.sheds_seen += 1;
                        "shed"
                    }
                    Classified::RetryDeadline => {
                        self.counters.deadlines_seen += 1;
                        "deadline"
                    }
                    Classified::RetryGarbled => {
                        self.counters.conn_errors += 1;
                        self.reader = None; // desynced framing: reconnect
                        "garbled response"
                    }
                },
                Err(_) => {
                    self.counters.conn_errors += 1;
                    "connection failure"
                }
            };
            if attempt >= self.policy.max_retries {
                bail!(
                    "request did not converge after {} attempt(s); last failure: {} \
                     (request: {line})",
                    attempt + 1,
                    retryable_because
                );
            }
            let delay = self.policy.backoff_ms(attempt, salt);
            if delay > 0 {
                std::thread::sleep(Duration::from_millis(delay));
            }
            attempt += 1;
            self.counters.retries += 1;
        }
    }
}

enum Classified {
    Ok { digest: Option<String>, source: Option<String> },
    Terminal { status: String, message: String },
    RetryShed,
    RetryDeadline,
    RetryGarbled,
}

fn classify(raw: &str) -> Classified {
    let Ok(v) = json::parse(raw) else {
        return Classified::RetryGarbled;
    };
    let status = v.get("status").and_then(Json::as_str).unwrap_or("");
    let field = |k: &str| v.get(k).and_then(Json::as_str).map(str::to_string);
    match status {
        "ok" => Classified::Ok { digest: field("stats_digest"), source: field("source") },
        "shed" => Classified::RetryShed,
        "deadline" => Classified::RetryDeadline,
        "" => Classified::RetryGarbled,
        other => Classified::Terminal {
            status: other.to_string(),
            message: field("message").unwrap_or_default(),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_capped_and_request_decorrelated() {
        let p = RetryPolicy { max_retries: 8, base_ms: 10, cap_ms: 400, seed: 7 };
        for attempt in 0..8 {
            for salt in [1u64, 2, 0xdead_beef] {
                let a = p.backoff_ms(attempt, salt);
                let b = p.backoff_ms(attempt, salt);
                assert_eq!(a, b, "same (seed, request, attempt) must draw the same delay");
                let ceiling = 10u64.saturating_mul(1 << attempt).min(400);
                assert!(a <= ceiling, "attempt {attempt}: {a} > ceiling {ceiling}");
            }
        }
        // Different seeds / requests decorrelate (not a hard guarantee of
        // xorshift, but these particular tuples must not all collide).
        let spread: std::collections::HashSet<u64> =
            (0..16u64).map(|s| p.backoff_ms(4, s)).collect();
        assert!(spread.len() > 4, "jitter must actually spread: {spread:?}");
        // Zero-base policy never sleeps.
        let z = RetryPolicy { base_ms: 0, ..p };
        assert_eq!(z.backoff_ms(3, 1), 0);
    }

    #[test]
    fn classify_is_honest_about_retryable_vs_terminal() {
        assert!(matches!(
            classify(r#"{"status":"ok","stats_digest":"00ff","source":"warm"}"#),
            Classified::Ok { digest: Some(d), source: Some(s) } if d == "00ff" && s == "warm"
        ));
        assert!(matches!(
            classify(r#"{"status":"shed","message":"queue full"}"#),
            Classified::RetryShed
        ));
        assert!(matches!(
            classify(r#"{"status":"deadline","message":"no result"}"#),
            Classified::RetryDeadline
        ));
        // Typed job errors and draining are terminal: retrying recomputes
        // the same failure / hits the same dying daemon.
        assert!(matches!(
            classify(r#"{"status":"error","message":"worker panic"}"#),
            Classified::Terminal { status, .. } if status == "error"
        ));
        assert!(matches!(
            classify(r#"{"status":"draining"}"#),
            Classified::Terminal { status, .. } if status == "draining"
        ));
        // Garbage and statusless lines are transport-class: retry.
        assert!(matches!(classify("not json at all"), Classified::RetryGarbled));
        assert!(matches!(classify(r#"{"pong":true}"#), Classified::RetryGarbled));
    }
}
