//! A minimal JSON value + recursive-descent parser for the serve wire
//! protocol. The container has no serde, so the daemon speaks JSON
//! through this ~200-line module: enough for flat request objects and
//! the string/number/bool/object shapes the protocol uses, with a depth
//! limit so a hostile request cannot blow the stack.

use anyhow::{bail, Result};

/// Maximum nesting depth accepted by the parser. Protocol messages are
/// at most 2 levels deep (`{"set": {...}}`); 32 leaves headroom without
/// letting `[[[[…]]]]` recurse unboundedly.
const MAX_DEPTH: usize = 32;

/// A parsed JSON value. Objects preserve insertion order (the protocol
/// never needs hashing, and ordered output is stable for tests).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Object member lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric member as an exact non-negative integer.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn members(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Array elements (`caba prof --serve` walks the `trace` verb's
    /// spans array with this).
    pub fn elements(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

impl std::fmt::Display for Json {
    /// Serialize back to compact wire JSON — the inverse of [`parse`].
    /// Numbers print via Rust's shortest-round-trip `f64` formatting, so
    /// `parse(v.to_string()) == v` for every parseable value (pinned by
    /// `prop_json_display_parse_roundtrip`). Non-finite numbers cannot
    /// come out of [`parse`]; a hand-built one serializes as `null`
    /// rather than emitting invalid JSON.
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) if n.is_finite() => write!(f, "{n}"),
            Json::Num(_) => f.write_str("null"),
            Json::Str(s) => write!(f, "\"{}\"", escape(s)),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(members) => {
                f.write_str("{")?;
                for (i, (k, v)) in members.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "\"{}\":{v}", escape(k))?;
                }
                f.write_str("}")
            }
        }
    }
}

/// Parse one complete JSON value; trailing non-whitespace is an error.
pub fn parse(input: &str) -> Result<Json> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing bytes after JSON value at offset {}", p.pos);
    }
    Ok(v)
}

/// Escape a string for embedding in JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!("expected {:?} at offset {}", b as char, self.pos)
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json> {
        if depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH}");
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => bail!("unexpected {:?} at offset {}", c as char, self.pos),
            None => bail!("unexpected end of input"),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            bail!("bad literal at offset {}", self.pos)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        let n: f64 =
            text.parse().map_err(|_| anyhow::anyhow!("bad number {text:?} at offset {start}"))?;
        if !n.is_finite() {
            bail!("non-finite number {text:?}");
        }
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(c) = self.peek() else { bail!("unterminated string") };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(esc) = self.peek() else { bail!("unterminated escape") };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => out.push(self.unicode_escape()?),
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // Re-decode from the byte position to keep multi-byte
                    // UTF-8 sequences intact.
                    self.pos -= 1;
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| anyhow::anyhow!("invalid UTF-8 in string"))?;
                    let ch = rest.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.bytes.len() - self.pos < 4 {
            bail!("truncated \\u escape");
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| anyhow::anyhow!("bad \\u escape"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| anyhow::anyhow!("bad \\u escape {s:?}"))?;
        self.pos += 4;
        Ok(v)
    }

    fn unicode_escape(&mut self) -> Result<char> {
        let hi = self.hex4()?;
        if (0xd800..0xdc00).contains(&hi) {
            // Surrogate pair: a second \uXXXX must follow.
            if self.bytes[self.pos..].starts_with(b"\\u") {
                self.pos += 2;
                let lo = self.hex4()?;
                if !(0xdc00..0xe000).contains(&lo) {
                    bail!("invalid low surrogate {lo:04x}");
                }
                let cp = 0x10000 + ((hi - 0xd800) << 10) + (lo - 0xdc00);
                return char::from_u32(cp).ok_or_else(|| anyhow::anyhow!("bad surrogate pair"));
            }
            bail!("lone high surrogate {hi:04x}");
        }
        char::from_u32(hi).ok_or_else(|| anyhow::anyhow!("bad codepoint {hi:04x}"))
    }

    fn object(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'{')?;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(members));
                }
                _ => bail!("expected ',' or '}}' at offset {}", self.pos),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at offset {}", self.pos),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_protocol_shapes() {
        let v = parse(
            r#"{"verb":"sweep","app":"SLA","design":"CABA-BDI","scale":0.01,
               "set":{"n_sms":"2","max_cycles":"150000"},"deadline_ms":500}"#,
        )
        .unwrap();
        assert_eq!(v.get("verb").and_then(Json::as_str), Some("sweep"));
        assert_eq!(v.get("scale").and_then(Json::as_f64), Some(0.01));
        assert_eq!(v.get("deadline_ms").and_then(Json::as_u64), Some(500));
        let set = v.get("set").unwrap();
        assert_eq!(set.get("n_sms").and_then(Json::as_str), Some("2"));
        assert_eq!(set.members().unwrap().len(), 2);
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn scalars_and_arrays() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#"[1, "two", false]"#).unwrap(),
            Json::Arr(vec![Json::Num(1.0), Json::Str("two".into()), Json::Bool(false)])
        );
    }

    #[test]
    fn string_escapes() {
        assert_eq!(parse(r#""a\"b\\c\nd\u0041""#).unwrap(), Json::Str("a\"b\\c\ndA".into()));
        // Surrogate pair (clef symbol) and raw multi-byte UTF-8.
        assert_eq!(parse(r#""\ud834\udd1e""#).unwrap(), Json::Str("𝄞".into()));
        assert_eq!(parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
        assert!(parse(r#""\ud834""#).is_err(), "lone surrogate must not parse");
    }

    #[test]
    fn malformed_rejected() {
        for bad in ["", "{", "{\"a\":}", "[1,]", "{\"a\" 1}", "1 2", "nul", "\"open", "{]"] {
            assert!(parse(bad).is_err(), "{bad:?} must not parse");
        }
        // Depth bomb: rejected, not a stack overflow.
        let bomb = "[".repeat(200) + &"]".repeat(200);
        assert!(parse(&bomb).is_err());
    }

    #[test]
    fn display_is_the_inverse_of_parse() {
        for wire in [
            r#"{"status":"ok","source":"warm","stats_digest":"00ff","n":3}"#,
            r#"[0,-1.5,1e300,"a\nb",null,true,{"k":[]}]"#,
            "null",
            r#"{"set":{"n_sms":"2"},"deadline_ms":500}"#,
        ] {
            let v = parse(wire).unwrap();
            let out = v.to_string();
            assert_eq!(parse(&out).unwrap(), v, "{wire} -> {out}");
        }
        // Member order and duplicate keys survive verbatim.
        let v = parse(r#"{"b":1,"a":2,"b":3}"#).unwrap();
        assert_eq!(v.to_string(), r#"{"b":1,"a":2,"b":3}"#);
        // Hand-built non-finite numbers degrade to null, not invalid JSON.
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
    }

    #[test]
    fn escape_roundtrips_through_parse() {
        let nasty = "line1\nline2\t\"quoted\" \\slash\u{0007}é";
        let wire = format!("\"{}\"", escape(nasty));
        assert_eq!(parse(&wire).unwrap(), Json::Str(nasty.to_string()));
    }
}
