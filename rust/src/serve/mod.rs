//! # `caba serve` — the fault-tolerant sweep service
//!
//! A long-lived daemon over a unix socket, turning the sweep engine into
//! the ROADMAP's "sweep-as-a-service": many concurrent clients request
//! simulation points as newline-delimited JSON and get stats back, with
//! the crash-safe [`crate::store::RunStore`] making every answered point
//! persistent across restarts.
//!
//! ## Request lifecycle
//!
//! ```text
//! request ── parse ──► key = SweepJob::key()
//!    │                     │
//!    │              cache/store hit? ──► "warm" response
//!    │                     │ miss
//!    │              in-flight for key? ──► wait on it ──► "dedup" response
//!    │                     │ no
//!    │              queue full? ──► "shed" response (429-style, retryable)
//!    │                     │ no
//!    │              enqueue ──► worker runs it ──► "cold" response
//!    │                     │
//!    └── deadline expires while waiting ──► "deadline" response
//!        (the job keeps running and warms the store for the retry)
//! ```
//!
//! ## Fault model
//!
//! Every failure mode has a typed, non-fatal answer:
//!
//! * a panicking job (or an injected [`FaultPlan`] fault) is caught by
//!   the engine and returned as `"status":"error"` — workers never die,
//!   failed keys are never cached, and a retry recomputes;
//! * a corrupt store entry quarantines on read and the request
//!   recomputes — never wrong data;
//! * an overloaded queue sheds new work *before* admitting it (a shed
//!   request holds no resources and can simply be retried);
//! * malformed JSON gets `"status":"error"` on that line and the
//!   connection stays usable;
//! * under sustained overload the daemon **browns out** (PR 10): when
//!   the windowed queue-wait p95 crosses `--brownout-p95-ms`, *new cold*
//!   admissions are shed (`"status":"shed"`, message names brownout)
//!   while warm hits and dedup followers keep being answered — graceful
//!   degradation, with entry/exit transitions counted
//!   (`caba_serve_brownout_*`), gauged, and logged under `--log`. The
//!   controller exits on a calm window (hysteresis at threshold/2) or
//!   when the queue fully drains;
//! * a byte-budgeted store (`--store-max-bytes`) evicts
//!   least-recently-used entries instead of filling the disk; an
//!   injected ENOSPC/EIO (chaos keys in [`FaultPlan`]) degrades to
//!   compute-without-caching / recompute-and-heal — see
//!   `tests/chaos_soak.rs` for the whole menagerie at once;
//! * `SIGTERM`/`SIGINT` (or the `shutdown` verb) drains gracefully:
//!   accepting stops, queued jobs finish, waiting clients get their
//!   answers, then the socket is removed and the process exits 0.
//!
//! Every `ok` response carries `stats_digest` — the FNV-1a64 of the
//! stats' canonical encoding — so clients (and the fault-injection
//! harness in `tests/serve_faults.rs` and `caba bench`) can assert
//! bit-identity without shipping the full struct.
//!
//! ## Observability
//!
//! The daemon owns a [`crate::obs::ServiceMetrics`] registry (atomic
//! counters/gauges, log2 latency histograms, a bounded request-span
//! ring). Every request line gets a **request id**, echoed as
//! `"request_id"` in every response — ok, error, shed, deadline — so a
//! client retrying across shed/deadline answers can correlate them; with
//! `--log` the daemon also writes one structured line per request to
//! stderr. Three read-out surfaces:
//!
//! * the `metrics` verb — Prometheus text exposition (hand-rolled, like
//!   this module's JSON) carried as one escaped `"metrics"` string field
//!   to keep the one-line-per-response wire protocol;
//! * the enriched `stats` verb — queue depth + high-water mark, the
//!   warm/cold/dedup/shed/deadline split, request-latency percentiles,
//!   and the full [`StoreCounters`] (quarantines, put errors, swept
//!   temps — previously counted but invisible to clients);
//! * the `trace` verb — recent request spans (accept → parse → queue →
//!   execute → respond timestamps), which `caba prof --serve` renders as
//!   Perfetto-loadable Chrome trace JSON via
//!   [`crate::telemetry::export::server_trace_json`].
//!
//! All of it is observation-only: metrics are recorded strictly around
//! engine/store calls, nothing is fingerprinted, and
//! `tests/serve_obs.rs` pins SimStats bit-identity with metrics on/off.

pub mod json;

use crate::config::SimConfig;
use crate::obs::{PromWriter, RequestTrace, ServiceMetrics, UNSET};
use crate::sim::designs::Design;
use crate::stats::SimStats;
use crate::store::{stats_digest, FaultPlan, RunStore, StoreCounters};
use crate::sweep::{resolve_jobs, JobError, JobKey, RunCache, SweepEngine, SweepJob};
use crate::workload::apps;
use anyhow::{Context, Result};
use json::Json;
use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// Process-wide flag set by the SIGTERM/SIGINT handler; the accept loop
/// polls it. Kept separate from the per-server stop flag so in-process
/// test servers are not affected by signals aimed at the CLI daemon.
static SIGNAL_SHUTDOWN: AtomicBool = AtomicBool::new(false);

extern "C" fn on_shutdown_signal(_sig: i32) {
    SIGNAL_SHUTDOWN.store(true, Ordering::SeqCst);
}

// Raw libc `signal(2)` — the container is std-only, and std never
// exposes signal installation. Typed fn-pointer parameter, so no cast.
extern "C" {
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

const SIGINT: i32 = 2;
const SIGTERM: i32 = 15;

/// Install the graceful-drain handler for SIGTERM and SIGINT. Called by
/// the `caba serve` CLI path only (tests stop servers via
/// [`ServerHandle::stop`] or the `shutdown` verb).
pub fn install_signal_handlers() {
    unsafe {
        signal(SIGTERM, on_shutdown_signal);
        signal(SIGINT, on_shutdown_signal);
    }
}

/// Daemon configuration.
#[derive(Clone)]
pub struct ServeOpts {
    /// Unix socket path (created on bind, removed on drain).
    pub socket: PathBuf,
    /// Worker threads; 0 = one per available core.
    pub jobs: usize,
    /// Cold-miss queue capacity; admissions beyond this are shed.
    pub queue_cap: usize,
    /// Deadline applied to requests that don't carry their own.
    pub default_deadline_ms: u64,
    /// Back the cache with a persistent store at this directory.
    pub store_dir: Option<PathBuf>,
    /// Byte budget for the persistent store (`--store-max-bytes`);
    /// 0 = unbounded. LRU entries are evicted to stay under it.
    pub store_max_bytes: u64,
    /// Brownout threshold (`--brownout-p95-ms`): when the windowed
    /// queue-wait p95 exceeds this, new cold admissions are shed while
    /// warm hits and dedup followers are still served. 0 = disabled —
    /// production jobs legitimately queue for seconds; tests, bench and
    /// CI opt in explicitly.
    pub brownout_p95_ms: u64,
    /// Minimum queue-wait samples a brownout window needs before the
    /// controller acts on its p95 (guards against one slow job flipping
    /// the mode).
    pub brownout_min_samples: u64,
    /// Fault-injection plan (tests, `caba bench`, `--fault`).
    pub fault: Option<Arc<FaultPlan>>,
    /// Write one structured line per request to stderr (`--log`).
    pub log: bool,
}

impl ServeOpts {
    pub fn new(socket: impl Into<PathBuf>) -> ServeOpts {
        ServeOpts {
            socket: socket.into(),
            jobs: 0,
            queue_cap: 64,
            default_deadline_ms: 30_000,
            store_dir: None,
            store_max_bytes: 0,
            brownout_p95_ms: 0,
            brownout_min_samples: 8,
            fault: None,
            log: false,
        }
    }
}

/// Monotonic request counters, snapshot via [`ServerHandle::counters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServeCounters {
    pub connections: u64,
    pub requests: u64,
    /// Answered straight from the cache/store.
    pub warm: u64,
    /// Simulated by a worker for this request.
    pub cold: u64,
    /// Waited on an identical in-flight request.
    pub dedup: u64,
    /// Rejected at admission because the queue was full.
    pub shed: u64,
    /// Waiting client gave up at its deadline (job kept running).
    pub deadline_expired: u64,
    /// Jobs that failed with a typed error.
    pub job_errors: u64,
    /// Lines that didn't parse into a valid request.
    pub bad_requests: u64,
    /// Cold admissions shed *because* brownout was active (a subset of
    /// `shed`).
    pub brownout_shed: u64,
    /// Times the brownout controller engaged.
    pub brownout_entered: u64,
    /// Times the brownout controller disengaged.
    pub brownout_exited: u64,
}

/// End-of-run report returned by [`Server::run`].
#[derive(Clone, Copy, Debug)]
pub struct ServeSummary {
    pub counters: ServeCounters,
    pub store: Option<StoreCounters>,
    pub cache_entries: u64,
    /// Deepest the cold-miss queue ever got.
    pub queue_depth_hwm: u64,
    /// End-to-end request latency percentiles, microseconds (log2-bucket
    /// upper bounds — see `crate::obs::hist`).
    pub request_p50_us: u64,
    pub request_p95_us: u64,
    pub request_p99_us: u64,
}

#[derive(Default)]
struct Pending {
    result: Mutex<Option<Result<SimStats, JobError>>>,
    cv: Condvar,
    /// Filled by the worker before it notifies: how long the job queued
    /// and how long it executed. Dedup followers read the leader's
    /// values — the span they observed *is* the shared job's.
    queue_wait_us: AtomicU64,
    exec_us: AtomicU64,
}

struct QueueItem {
    job: SweepJob,
    key: JobKey,
    pending: Arc<Pending>,
    /// Admission time, for the queue-wait histogram.
    enqueued: Instant,
}

/// Adaptive overload controller (DESIGN.md §5e). Watches the queue-wait
/// histogram as a sequence of *windows* (snapshot deltas — the lifetime
/// histogram is sticky, so a past overload would otherwise poison the
/// p95 forever) and trips a shed-new-cold-work mode when a window's p95
/// crosses the threshold. Exit has hysteresis (half the threshold) plus
/// an idle path: brownout blocks the very admissions that would produce
/// new samples, so a drained queue with an empty window also disengages.
struct Brownout {
    /// Entry threshold, microseconds; 0 = disabled.
    threshold_us: u64,
    /// Exit threshold (hysteresis): threshold / 2.
    exit_us: u64,
    /// Minimum samples in a window before its p95 is acted on.
    min_samples: u64,
    /// Start of the current window (the last consumed snapshot).
    window_start: Mutex<crate::obs::HistSnapshot>,
}

struct Inner {
    engine: SweepEngine,
    queue_cap: usize,
    default_deadline_ms: u64,
    inflight: Mutex<HashMap<JobKey, Arc<Pending>>>,
    queue: Mutex<VecDeque<QueueItem>>,
    queue_cv: Condvar,
    stop: AtomicBool,
    active_conns: AtomicU64,
    /// The observability registry (DESIGN.md §5d): request/outcome
    /// counters, queue gauges, latency histograms, the span ring. The
    /// engine shares its `jobs` slice via `SweepEngine::with_metrics`.
    metrics: Arc<ServiceMetrics>,
    brownout: Brownout,
    /// Fault plan shared with the store/engine, consulted here for the
    /// `drop_conn_at` chaos key (close the Nth response's connection
    /// without answering).
    fault: Option<Arc<FaultPlan>>,
    /// Structured per-request stderr logging (`--log`).
    log: bool,
}

impl Inner {
    fn counters(&self) -> ServeCounters {
        let m = &self.metrics;
        ServeCounters {
            connections: m.connections.load(Ordering::Relaxed),
            requests: m.requests.load(Ordering::Relaxed),
            warm: m.warm.load(Ordering::Relaxed),
            cold: m.cold.load(Ordering::Relaxed),
            dedup: m.dedup.load(Ordering::Relaxed),
            shed: m.shed.load(Ordering::Relaxed),
            deadline_expired: m.deadline_expired.load(Ordering::Relaxed),
            job_errors: m.job_errors.load(Ordering::Relaxed),
            bad_requests: m.bad_requests.load(Ordering::Relaxed),
            brownout_shed: m.brownout_shed.load(Ordering::Relaxed),
            brownout_entered: m.brownout_entered.load(Ordering::Relaxed),
            brownout_exited: m.brownout_exited.load(Ordering::Relaxed),
        }
    }

    fn brownout_active(&self) -> bool {
        self.metrics.brownout_active.load(Ordering::Relaxed) == 1
    }

    /// Evaluate the brownout state machine against the latest queue-wait
    /// window. Called from cold-admission attempts and from workers after
    /// each pop — cheap (one snapshot + one small mutex), never on the
    /// warm path's critical section.
    fn brownout_evaluate(&self) {
        let b = &self.brownout;
        if b.threshold_us == 0 {
            return;
        }
        let m = &self.metrics;
        let snap = m.jobs.queue_wait_us.snapshot();
        let mut start = b.window_start.lock().unwrap_or_else(PoisonError::into_inner);
        let win = snap.delta_since(&start);
        let active = self.brownout_active();
        if win.count >= b.min_samples {
            let p95 = win.percentile(0.95);
            *start = snap;
            if !active && p95 > b.threshold_us {
                m.brownout_active.store(1, Ordering::Relaxed);
                m.brownout_entered.fetch_add(1, Ordering::Relaxed);
                if self.log {
                    eprintln!(
                        "[serve] brownout enter: queue-wait p95 {p95} us > {} us \
                         (window n={}) — shedding new cold work",
                        b.threshold_us, win.count
                    );
                }
            } else if active && p95 <= b.exit_us {
                m.brownout_active.store(0, Ordering::Relaxed);
                m.brownout_exited.fetch_add(1, Ordering::Relaxed);
                if self.log {
                    eprintln!(
                        "[serve] brownout exit: queue-wait p95 {p95} us <= {} us (window n={})",
                        b.exit_us, win.count
                    );
                }
            }
        } else if active && win.count == 0 && m.queue_depth.load(Ordering::Relaxed) == 0 {
            // Idle drain: nothing queued and no pops since the window
            // started. Brownout itself suppresses the cold admissions
            // that would produce samples, so waiting for min_samples
            // here would latch the mode on forever.
            *start = snap;
            m.brownout_active.store(0, Ordering::Relaxed);
            m.brownout_exited.fetch_add(1, Ordering::Relaxed);
            if self.log {
                eprintln!("[serve] brownout exit: queue drained, window empty");
            }
        }
    }

    fn summary(&self) -> ServeSummary {
        let req = self.metrics.request_us.snapshot();
        ServeSummary {
            counters: self.counters(),
            store: self.engine.cache().store_counters(),
            cache_entries: self.engine.cache_entries() as u64,
            queue_depth_hwm: self.metrics.queue_depth_hwm.load(Ordering::Relaxed),
            request_p50_us: req.p50(),
            request_p95_us: req.p95(),
            request_p99_us: req.p99(),
        }
    }
}

/// A bound, not-yet-running server. [`Server::run`] blocks until drain;
/// grab a [`ServerHandle`] first to stop/inspect it from other threads
/// (in-process tests, the bench load generator).
pub struct Server {
    inner: Arc<Inner>,
    listener: UnixListener,
    socket: PathBuf,
    workers: usize,
}

/// A cheap clone-around handle to a running (or drained) server.
#[derive(Clone)]
pub struct ServerHandle {
    inner: Arc<Inner>,
}

impl ServerHandle {
    /// Begin graceful drain (idempotent).
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
    }

    pub fn counters(&self) -> ServeCounters {
        self.inner.counters()
    }

    pub fn summary(&self) -> ServeSummary {
        self.inner.summary()
    }

    /// The live metrics registry (in-process tests and the bench load
    /// generator read histograms/gauges without a socket round-trip).
    pub fn metrics(&self) -> &Arc<ServiceMetrics> {
        &self.inner.metrics
    }
}

impl Server {
    /// Bind the socket and build the engine/store. Removes a stale
    /// socket file from a previous (crashed) daemon first.
    pub fn bind(opts: ServeOpts) -> Result<Server> {
        let cache = match &opts.store_dir {
            Some(dir) => {
                let policy = crate::store::CapacityPolicy {
                    max_bytes: opts.store_max_bytes,
                    ..Default::default()
                };
                let mut store = RunStore::open_with(dir, policy)?;
                if let Some(f) = &opts.fault {
                    store = store.with_fault(Arc::clone(f));
                }
                RunCache::with_store(Arc::new(store))
            }
            None => RunCache::new(),
        };
        let metrics = Arc::new(ServiceMetrics::new());
        let mut engine = SweepEngine::with_cache(opts.jobs, Arc::new(cache))
            .with_metrics(Arc::clone(&metrics.jobs));
        if let Some(f) = &opts.fault {
            engine = engine.with_fault(Arc::clone(f));
        }

        let _ = std::fs::remove_file(&opts.socket);
        if let Some(parent) = opts.socket.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("serve: create {}", parent.display()))?;
            }
        }
        let listener = UnixListener::bind(&opts.socket)
            .with_context(|| format!("serve: bind {}", opts.socket.display()))?;
        listener.set_nonblocking(true).context("serve: set socket nonblocking")?;

        Ok(Server {
            inner: Arc::new(Inner {
                engine,
                queue_cap: opts.queue_cap,
                default_deadline_ms: opts.default_deadline_ms,
                inflight: Mutex::new(HashMap::new()),
                queue: Mutex::new(VecDeque::new()),
                queue_cv: Condvar::new(),
                stop: AtomicBool::new(false),
                active_conns: AtomicU64::new(0),
                metrics,
                brownout: Brownout {
                    threshold_us: opts.brownout_p95_ms.saturating_mul(1000),
                    exit_us: opts.brownout_p95_ms.saturating_mul(1000) / 2,
                    min_samples: opts.brownout_min_samples.max(1),
                    window_start: Mutex::new(crate::obs::HistSnapshot::empty()),
                },
                fault: opts.fault.clone(),
                log: opts.log,
            }),
            listener,
            socket: opts.socket,
            workers: resolve_jobs(opts.jobs),
        })
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { inner: Arc::clone(&self.inner) }
    }

    /// Accept and serve until a stop is requested ([`ServerHandle::stop`],
    /// the `shutdown` verb, or — for the CLI daemon — SIGTERM/SIGINT),
    /// then drain: queued jobs finish, waiting clients get answers, the
    /// socket file is removed. Blocks the calling thread.
    pub fn run(self) -> Result<ServeSummary> {
        let worker_handles: Vec<_> = (0..self.workers)
            .map(|_| {
                let inner = Arc::clone(&self.inner);
                std::thread::spawn(move || worker_loop(&inner))
            })
            .collect();

        loop {
            if self.inner.stop.load(Ordering::SeqCst) || SIGNAL_SHUTDOWN.load(Ordering::SeqCst) {
                break;
            }
            match self.listener.accept() {
                Ok((stream, _)) => {
                    self.inner.metrics.connections.fetch_add(1, Ordering::Relaxed);
                    self.inner.active_conns.fetch_add(1, Ordering::SeqCst);
                    let inner = Arc::clone(&self.inner);
                    std::thread::spawn(move || {
                        handle_connection(&inner, stream);
                        inner.active_conns.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(25));
                }
                Err(e) => return Err(e).context("serve: accept"),
            }
        }

        // Drain: stop admissions, let workers empty the queue, let every
        // open connection finish (their waits are deadline-bounded).
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.queue_cv.notify_all();
        for h in worker_handles {
            let _ = h.join();
        }
        while self.inner.active_conns.load(Ordering::SeqCst) > 0 {
            std::thread::sleep(Duration::from_millis(10));
        }
        let _ = std::fs::remove_file(&self.socket);
        Ok(self.inner.summary())
    }
}

/// Worker: pop cold misses off the queue, execute panic-isolated, fill
/// the pending slot *before* removing the in-flight entry (so a deduping
/// waiter that found the entry is always woken with a result). Exits
/// when stop is set **and** the queue is empty — queued work always
/// completes, which both answers its waiters and warms the store.
fn worker_loop(inner: &Inner) {
    loop {
        let item = {
            let mut q = inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
            loop {
                if let Some(item) = q.pop_front() {
                    break Some(item);
                }
                if inner.stop.load(Ordering::SeqCst) {
                    break None;
                }
                let (guard, _) = inner
                    .queue_cv
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap_or_else(PoisonError::into_inner);
                q = guard;
            }
        };
        let Some(QueueItem { job, key, pending, enqueued }) = item else { return };
        let m = &inner.metrics;
        m.queue_popped();
        let queue_wait = enqueued.elapsed();
        m.jobs.queue_wait_us.record_duration(queue_wait);
        // Each pop lands a fresh queue-wait sample — the brownout
        // controller's signal.
        inner.brownout_evaluate();
        pending
            .queue_wait_us
            .store(queue_wait.as_micros().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        let t0 = Instant::now();
        let result = inner.engine.try_run_one(&job);
        pending
            .exec_us
            .store(t0.elapsed().as_micros().min(u64::MAX as u128) as u64, Ordering::Relaxed);
        if result.is_err() {
            m.job_errors.fetch_add(1, Ordering::Relaxed);
        }
        *pending.result.lock().unwrap_or_else(PoisonError::into_inner) = Some(result);
        pending.cv.notify_all();
        inner.inflight.lock().unwrap_or_else(PoisonError::into_inner).remove(&key);
    }
}

/// Serve one connection: newline-delimited JSON requests, one response
/// line each. A short read timeout keeps idle connections from blocking
/// drain forever.
fn handle_connection(inner: &Inner, stream: UnixStream) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let mut writer = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let mut reader = BufReader::new(stream);
    let mut line = String::new();
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => {
                let response = handle_line(inner, line.trim());
                line.clear();
                if let Some(resp) = response {
                    if inner.fault.as_deref().is_some_and(FaultPlan::on_respond) {
                        // Injected connection drop: the answer is
                        // computed (and, for cold work, already in the
                        // store) but the peer sees EOF — a retryable
                        // mid-flight network failure.
                        return;
                    }
                    if writer.write_all(resp.as_bytes()).is_err()
                        || writer.write_all(b"\n").is_err()
                        || writer.flush().is_err()
                    {
                        return;
                    }
                }
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // Idle: partially-read bytes stay in `line`; hang up once
                // the server is draining.
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Dispatch one request line. `None` = blank line, no response owed.
/// Everything else — including unparsable garbage — gets a response
/// carrying a fresh `request_id`, a completed span in the trace ring,
/// and (under `--log`) one structured stderr line.
fn handle_line(inner: &Inner, line: &str) -> Option<String> {
    if line.is_empty() {
        return None;
    }
    let m = &inner.metrics;
    m.requests.fetch_add(1, Ordering::Relaxed);
    let id = m.next_request_id();
    let mut span = RequestTrace {
        id,
        verb: "?".to_string(),
        detail: String::new(),
        outcome: String::new(),
        t_accept: m.now_us(),
        t_parsed: UNSET,
        t_queued: UNSET,
        t_done: 0,
        queue_wait_us: 0,
        exec_us: 0,
    };
    let response = dispatch(inner, line, id, &mut span);
    span.t_done = m.now_us();
    m.request_us.record(span.t_done.saturating_sub(span.t_accept));
    if inner.log {
        eprintln!(
            "[serve] req={} verb={} detail={} outcome={} total_us={} queue_us={} exec_us={}",
            span.id,
            span.verb,
            if span.detail.is_empty() { "-" } else { &span.detail },
            span.outcome,
            span.t_done.saturating_sub(span.t_accept),
            span.queue_wait_us,
            span.exec_us,
        );
    }
    m.trace.push(span);
    Some(response)
}

/// Verb dispatch, filling the request span as stages complete.
fn dispatch(inner: &Inner, line: &str, id: u64, span: &mut RequestTrace) -> String {
    let m = &inner.metrics;
    let req = match json::parse(line) {
        Ok(v) => v,
        Err(e) => {
            m.bad_requests.fetch_add(1, Ordering::Relaxed);
            span.outcome = "bad_request".to_string();
            return error_json("error", id, &format!("bad JSON: {e:#}"));
        }
    };
    span.t_parsed = m.now_us();
    let verb = req.get("verb").and_then(Json::as_str);
    if let Some(v) = verb {
        span.verb = v.to_string();
    }
    match verb {
        Some("ping") => {
            span.outcome = "ok".to_string();
            format!("{{\"status\":\"ok\",\"request_id\":{id},\"pong\":true}}")
        }
        Some("stats") => {
            span.outcome = "ok".to_string();
            stats_json(inner, id)
        }
        Some("metrics") => {
            // The wire protocol is one JSON line per response, so the
            // multi-line Prometheus exposition ships as one escaped
            // string field (`caba metrics` decodes and prints it raw).
            span.outcome = "ok".to_string();
            format!(
                "{{\"status\":\"ok\",\"request_id\":{id},\"metrics\":\"{}\"}}",
                json::escape(&render_prometheus(inner))
            )
        }
        Some("trace") => {
            span.outcome = "ok".to_string();
            trace_json(inner, id)
        }
        Some("shutdown") => {
            inner.stop.store(true, Ordering::SeqCst);
            inner.queue_cv.notify_all();
            span.outcome = "draining".to_string();
            format!("{{\"status\":\"ok\",\"request_id\":{id},\"draining\":true}}")
        }
        Some("sweep") => handle_sweep(inner, &req, id, span),
        Some(other) => {
            m.bad_requests.fetch_add(1, Ordering::Relaxed);
            span.outcome = "bad_request".to_string();
            error_json("error", id, &format!("unknown verb {other:?}"))
        }
        None => {
            m.bad_requests.fetch_add(1, Ordering::Relaxed);
            span.outcome = "bad_request".to_string();
            error_json("error", id, "missing \"verb\"")
        }
    }
}

/// Build the `SweepJob` a sweep request describes. The `SweepJob::new`
/// constructor strips run-control knobs (trace_record, telemetry), so
/// served keys can never fragment the cache/store.
fn sweep_job_from(req: &Json) -> Result<SweepJob, String> {
    let app_name =
        req.get("app").and_then(Json::as_str).ok_or("missing \"app\" (string)")?;
    let app = apps::find(app_name).ok_or_else(|| format!("unknown app {app_name:?}"))?;
    let design_name = req.get("design").and_then(Json::as_str).unwrap_or("CABA-BDI");
    let design =
        Design::by_name(design_name).ok_or_else(|| format!("unknown design {design_name:?}"))?;
    let scale = match req.get("scale") {
        None => 0.25,
        Some(v) => match v.as_f64() {
            Some(s) if s.is_finite() && s > 0.0 => s,
            _ => return Err("\"scale\" must be a positive finite number".to_string()),
        },
    };
    let mut cfg = SimConfig::default();
    if let Some(set) = req.get("set") {
        let members = set.members().ok_or("\"set\" must be an object")?;
        for (k, v) in members {
            let val = match v {
                Json::Str(s) => s.clone(),
                Json::Num(n) if n.fract() == 0.0 => format!("{}", *n as i64),
                Json::Num(n) => format!("{n}"),
                _ => return Err(format!("set.{k}: value must be a string or number")),
            };
            cfg.set(k, &val).map_err(|e| format!("set.{k}: {e:#}"))?;
        }
    }
    Ok(SweepJob::new(app, design, cfg, scale))
}

fn handle_sweep(inner: &Inner, req: &Json, id: u64, span: &mut RequestTrace) -> String {
    let m = &inner.metrics;
    let job = match sweep_job_from(req) {
        Ok(j) => j,
        Err(msg) => {
            m.bad_requests.fetch_add(1, Ordering::Relaxed);
            span.outcome = "bad_request".to_string();
            return error_json("error", id, &msg);
        }
    };
    span.detail = format!("{}/{}", job.app.name, job.design.name);
    let key = job.key();
    let deadline_ms = req
        .get("deadline_ms")
        .and_then(Json::as_u64)
        .unwrap_or(inner.default_deadline_ms)
        .max(1);

    // Warm path: cache (and, through it, the validated store).
    if let Some(stats) = inner.engine.cache().get(&key) {
        m.warm.fetch_add(1, Ordering::Relaxed);
        span.outcome = "warm".to_string();
        return ok_json(&job, "warm", id, &stats);
    }

    // Admission. Lock order: inflight, then queue; both released before
    // waiting. Brownout is evaluated before any lock: a cold attempt is
    // exactly the event that should notice a saturated queue window.
    inner.brownout_evaluate();
    let (pending, source) = {
        let mut inflight = inner.inflight.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(p) = inflight.get(&key) {
            (Arc::clone(p), "dedup")
        } else {
            if inner.stop.load(Ordering::SeqCst) {
                span.outcome = "draining".to_string();
                return error_json("draining", id, "server is draining; retry elsewhere");
            }
            // Brownout sheds *new cold* work only: warm hits returned
            // above, dedup followers joined above — both keep flowing
            // while the daemon digests its backlog.
            if inner.brownout_active() {
                m.shed.fetch_add(1, Ordering::Relaxed);
                m.brownout_shed.fetch_add(1, Ordering::Relaxed);
                span.outcome = "brownout_shed".to_string();
                return error_json(
                    "shed",
                    id,
                    "brownout: queue-wait p95 over threshold; retry with backoff",
                );
            }
            let mut q = inner.queue.lock().unwrap_or_else(PoisonError::into_inner);
            if q.len() >= inner.queue_cap {
                m.shed.fetch_add(1, Ordering::Relaxed);
                span.outcome = "shed".to_string();
                return error_json("shed", id, "queue full; retry with backoff");
            }
            let p = Arc::new(Pending::default());
            inflight.insert(key, Arc::clone(&p));
            q.push_back(QueueItem {
                job: job.clone(),
                key,
                pending: Arc::clone(&p),
                enqueued: Instant::now(),
            });
            m.queue_pushed();
            span.t_queued = m.now_us();
            inner.queue_cv.notify_one();
            (p, "cold")
        }
    };

    // Wait for the worker, bounded by the deadline.
    let guard = pending.result.lock().unwrap_or_else(PoisonError::into_inner);
    let (guard, _) = pending
        .cv
        .wait_timeout_while(guard, Duration::from_millis(deadline_ms), |r| r.is_none())
        .unwrap_or_else(PoisonError::into_inner);
    // Worker-side timings (the leader's, for dedup followers — the span
    // they observed *is* the shared job's). Unfilled on deadline: the
    // job is still running.
    span.queue_wait_us = pending.queue_wait_us.load(Ordering::Relaxed);
    span.exec_us = pending.exec_us.load(Ordering::Relaxed);
    match guard.as_ref() {
        None => {
            m.deadline_expired.fetch_add(1, Ordering::Relaxed);
            span.outcome = "deadline".to_string();
            error_json(
                "deadline",
                id,
                &format!("no result within {deadline_ms} ms; the job continues and will be warm"),
            )
        }
        Some(Ok(stats)) => {
            match source {
                "dedup" => m.dedup.fetch_add(1, Ordering::Relaxed),
                _ => m.cold.fetch_add(1, Ordering::Relaxed),
            };
            span.outcome = source.to_string();
            ok_json(&job, source, id, stats)
        }
        Some(Err(e)) => {
            span.outcome = "error".to_string();
            error_json("error", id, &e.to_string())
        }
    }
}

fn ok_json(job: &SweepJob, source: &str, id: u64, stats: &SimStats) -> String {
    format!(
        "{{\"status\":\"ok\",\"request_id\":{id},\"source\":\"{source}\",\"app\":\"{}\",\
         \"design\":\"{}\",\"cycles\":{},\"warp_insts\":{},\"finished\":{},\
         \"stats_digest\":\"{:016x}\"}}",
        json::escape(job.app.name),
        json::escape(job.design.name),
        stats.cycles,
        stats.warp_insts,
        stats.finished,
        stats_digest(stats),
    )
}

fn error_json(status: &str, id: u64, message: &str) -> String {
    format!(
        "{{\"status\":\"{status}\",\"request_id\":{id},\"message\":\"{}\"}}",
        json::escape(message)
    )
}

fn stats_json(inner: &Inner, id: u64) -> String {
    let c = inner.counters();
    let m = &inner.metrics;
    let req_us = m.request_us.snapshot();
    let mut out = format!(
        "{{\"status\":\"ok\",\"request_id\":{id},\"connections\":{},\"requests\":{},\
         \"warm\":{},\"cold\":{},\"dedup\":{},\"shed\":{},\"deadline_expired\":{},\
         \"job_errors\":{},\"bad_requests\":{},\"cache_entries\":{},\"queue_depth\":{},\
         \"queue_depth_hwm\":{},\"request_p50_us\":{},\"request_p95_us\":{},\
         \"request_p99_us\":{}",
        c.connections,
        c.requests,
        c.warm,
        c.cold,
        c.dedup,
        c.shed,
        c.deadline_expired,
        c.job_errors,
        c.bad_requests,
        inner.engine.cache_entries(),
        m.queue_depth.load(Ordering::Relaxed),
        m.queue_depth_hwm.load(Ordering::Relaxed),
        req_us.p50(),
        req_us.p95(),
        req_us.p99(),
    );
    out.push_str(&format!(
        ",\"brownout_active\":{},\"brownout_entered\":{},\"brownout_exited\":{},\
         \"brownout_shed\":{}",
        m.brownout_active.load(Ordering::Relaxed),
        m.brownout_entered.load(Ordering::Relaxed),
        m.brownout_exited.load(Ordering::Relaxed),
        m.brownout_shed.load(Ordering::Relaxed),
    ));
    if let Some(store) = inner.engine.cache().store() {
        let s = store.counters();
        out.push_str(&format!(
            ",\"store_puts\":{},\"store_warm_hits\":{},\"store_misses\":{},\
             \"store_quarantined\":{},\"store_temp_cleaned\":{},\"store_put_errors\":{},\
             \"store_evicted\":{},\"store_evicted_bytes\":{},\"store_quarantine_gced\":{},\
             \"store_put_uncached\":{},\"store_read_faults\":{},\"store_disk_bytes\":{},\
             \"store_max_bytes\":{}",
            s.puts,
            s.warm_hits,
            s.misses,
            s.quarantined,
            s.temp_cleaned,
            s.put_errors,
            s.evicted,
            s.evicted_bytes,
            s.quarantine_gced,
            s.put_uncached,
            s.read_faults,
            store.disk_bytes(),
            store.policy().max_bytes,
        ));
    }
    out.push('}');
    out
}

/// The Prometheus text exposition behind the `metrics` verb: every serve
/// counter/gauge, the three latency histograms, and — when store-backed —
/// the full [`StoreCounters`] including the previously invisible
/// quarantine/put-error/temp-sweep counts.
fn render_prometheus(inner: &Inner) -> String {
    let m = &inner.metrics;
    let ld = |a: &AtomicU64| a.load(Ordering::Relaxed);
    let mut w = PromWriter::new();
    w.counter("caba_serve_connections_total", "Client connections accepted.", ld(&m.connections));
    w.counter("caba_serve_requests_total", "Request lines received.", ld(&m.requests));
    w.counter("caba_serve_warm_total", "Requests answered from the cache/store.", ld(&m.warm));
    w.counter("caba_serve_cold_total", "Requests computed by a worker.", ld(&m.cold));
    w.counter(
        "caba_serve_dedup_total",
        "Requests that joined an identical in-flight job.",
        ld(&m.dedup),
    );
    w.counter("caba_serve_shed_total", "Requests shed at admission (queue full).", ld(&m.shed));
    w.counter(
        "caba_serve_deadline_total",
        "Requests whose client gave up at its deadline.",
        ld(&m.deadline_expired),
    );
    w.counter(
        "caba_serve_job_errors_total",
        "Jobs that failed with a typed JobError.",
        ld(&m.job_errors),
    );
    w.counter(
        "caba_serve_bad_requests_total",
        "Lines that did not parse into a valid request.",
        ld(&m.bad_requests),
    );
    w.counter(
        "caba_serve_trace_dropped_total",
        "Request spans evicted from the bounded trace ring.",
        m.trace.dropped(),
    );
    w.counter(
        "caba_serve_brownout_entered_total",
        "Times the brownout controller engaged (queue-wait p95 over threshold).",
        ld(&m.brownout_entered),
    );
    w.counter(
        "caba_serve_brownout_exited_total",
        "Times the brownout controller disengaged.",
        ld(&m.brownout_exited),
    );
    w.counter(
        "caba_serve_brownout_shed_total",
        "Cold admissions shed because brownout was active.",
        ld(&m.brownout_shed),
    );
    w.gauge(
        "caba_serve_brownout_active",
        "1 while the daemon is shedding new cold work, else 0.",
        ld(&m.brownout_active),
    );
    w.gauge("caba_serve_queue_depth", "Cold-miss jobs currently queued.", ld(&m.queue_depth));
    w.gauge(
        "caba_serve_queue_depth_hwm",
        "Queue depth high-water mark.",
        ld(&m.queue_depth_hwm),
    );
    w.gauge(
        "caba_serve_cache_entries",
        "In-memory run-cache entries.",
        inner.engine.cache_entries() as u64,
    );
    w.counter("caba_jobs_ok_total", "Engine jobs that returned stats.", ld(&m.jobs.jobs_ok));
    w.counter(
        "caba_jobs_failed_total",
        "Engine jobs that returned a typed JobError.",
        ld(&m.jobs.jobs_failed),
    );
    w.histogram(
        "caba_serve_request_us",
        "End-to-end request latency, microseconds.",
        &m.request_us.snapshot(),
    );
    w.histogram(
        "caba_serve_queue_wait_us",
        "Queue wait before a worker claimed the job, microseconds.",
        &m.jobs.queue_wait_us.snapshot(),
    );
    w.histogram(
        "caba_job_wall_us",
        "SweepJob::execute wall time, microseconds.",
        &m.jobs.job_wall_us.snapshot(),
    );
    if let Some(store) = inner.engine.cache().store() {
        let s = store.counters();
        w.counter("caba_store_puts_total", "Store entries written.", s.puts);
        w.counter("caba_store_warm_hits_total", "Store reads that validated.", s.warm_hits);
        w.counter("caba_store_misses_total", "Store reads that found no entry.", s.misses);
        w.counter(
            "caba_store_quarantined_total",
            "Corrupt entries quarantined on read.",
            s.quarantined,
        );
        w.counter(
            "caba_store_temp_cleaned_total",
            "Stale temp files swept at open.",
            s.temp_cleaned,
        );
        w.counter("caba_store_put_errors_total", "Store writes that failed.", s.put_errors);
        w.counter(
            "caba_store_evicted_total",
            "Entries evicted (LRU) to stay under the byte budget.",
            s.evicted,
        );
        w.counter(
            "caba_store_evicted_bytes_total",
            "Bytes reclaimed by LRU eviction.",
            s.evicted_bytes,
        );
        w.counter(
            "caba_store_quarantine_gced_total",
            "Quarantined files aged out (keep-newest-K).",
            s.quarantine_gced,
        );
        w.counter(
            "caba_store_put_uncached_total",
            "Writes skipped because one entry exceeds the whole budget.",
            s.put_uncached,
        );
        w.counter(
            "caba_store_read_faults_total",
            "Reads that failed with an I/O error (recompute-and-heal).",
            s.read_faults,
        );
        w.counter(
            "caba_store_compact_steps_total",
            "Incremental compaction steps executed.",
            s.compact_steps,
        );
        w.gauge(
            "caba_store_disk_bytes",
            "Committed entry bytes accounted by the LRU index.",
            store.disk_bytes(),
        );
        w.gauge(
            "caba_store_max_bytes",
            "Configured byte budget (0 = unbounded).",
            store.policy().max_bytes,
        );
    }
    w.into_string()
}

/// The `trace` verb: recent request spans, oldest first, as one JSON
/// line. Unreached stages ([`UNSET`]) encode as `null`.
fn trace_json(inner: &Inner, id: u64) -> String {
    let spans = inner.metrics.trace.snapshot();
    let mut out = format!(
        "{{\"status\":\"ok\",\"request_id\":{id},\"dropped\":{},\"spans\":[",
        inner.metrics.trace.dropped()
    );
    for (i, s) in spans.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&span_json(s));
    }
    out.push_str("]}");
    out
}

fn span_json(s: &RequestTrace) -> String {
    fn opt(v: u64) -> String {
        if v == UNSET {
            "null".to_string()
        } else {
            v.to_string()
        }
    }
    format!(
        "{{\"id\":{},\"verb\":\"{}\",\"detail\":\"{}\",\"outcome\":\"{}\",\"t_accept\":{},\
         \"t_parsed\":{},\"t_queued\":{},\"t_done\":{},\"queue_wait_us\":{},\"exec_us\":{}}}",
        s.id,
        json::escape(&s.verb),
        json::escape(&s.detail),
        json::escape(&s.outcome),
        s.t_accept,
        opt(s.t_parsed),
        opt(s.t_queued),
        s.t_done,
        s.queue_wait_us,
        s.exec_us,
    )
}

/// Decode one span object of a `trace` response back into a
/// [`RequestTrace`] (`caba prof --serve` feeds these to
/// [`crate::telemetry::export::server_trace_json`]). `null` timestamps
/// map back to [`UNSET`]. Returns `None` on a malformed object.
pub fn span_from_json(v: &Json) -> Option<RequestTrace> {
    let num = |k: &str| v.get(k).and_then(Json::as_u64);
    let opt = |k: &str| match v.get(k) {
        None | Some(Json::Null) => Some(UNSET),
        Some(x) => x.as_u64(),
    };
    let s = |k: &str| v.get(k).and_then(Json::as_str).unwrap_or("").to_string();
    Some(RequestTrace {
        id: num("id")?,
        verb: s("verb"),
        detail: s("detail"),
        outcome: s("outcome"),
        t_accept: num("t_accept")?,
        t_parsed: opt("t_parsed")?,
        t_queued: opt("t_queued")?,
        t_done: num("t_done")?,
        queue_wait_us: num("queue_wait_us").unwrap_or(0),
        exec_us: num("exec_us").unwrap_or(0),
    })
}

/// One-shot client: send a single request line, return the response
/// line. Used by `caba client` and the CI smoke test.
pub fn client_request(socket: &Path, line: &str) -> Result<String> {
    let mut stream = UnixStream::connect(socket)
        .with_context(|| format!("connect {}", socket.display()))?;
    stream.write_all(line.trim().as_bytes()).context("send request")?;
    stream.write_all(b"\n").context("send request")?;
    stream.flush().context("send request")?;
    let mut reader = BufReader::new(stream);
    let mut resp = String::new();
    reader.read_line(&mut resp).context("read response")?;
    if resp.is_empty() {
        anyhow::bail!("server closed the connection without a response");
    }
    Ok(resp.trim_end().to_string())
}

/// Human-readable drain report for the CLI.
pub fn render_summary(s: &ServeSummary) -> String {
    let c = &s.counters;
    let mut out = format!(
        "serve: drained cleanly\n\
         connections {}  requests {}\n\
         warm {}  cold {}  dedup {}  shed {}  deadline {}\n\
         job_errors {}  bad_requests {}  cache_entries {}",
        c.connections,
        c.requests,
        c.warm,
        c.cold,
        c.dedup,
        c.shed,
        c.deadline_expired,
        c.job_errors,
        c.bad_requests,
        s.cache_entries,
    );
    out.push_str(&format!(
        "\nlatency: request p50 {} us  p95 {} us  p99 {} us  queue_hwm {}",
        s.request_p50_us, s.request_p95_us, s.request_p99_us, s.queue_depth_hwm
    ));
    if c.brownout_entered > 0 || c.brownout_shed > 0 {
        out.push_str(&format!(
            "\nbrownout: entered {}  exited {}  shed {}",
            c.brownout_entered, c.brownout_exited, c.brownout_shed
        ));
    }
    if let Some(st) = &s.store {
        out.push_str(&format!(
            "\nstore: puts {}  warm_hits {}  misses {}  quarantined {}  temp_cleaned {}  \
             put_errors {}",
            st.puts, st.warm_hits, st.misses, st.quarantined, st.temp_cleaned, st.put_errors
        ));
        if st.evicted > 0 || st.quarantine_gced > 0 || st.put_uncached > 0 || st.read_faults > 0
        {
            out.push_str(&format!(
                "\nstore capacity: evicted {} ({} bytes)  quarantine_gced {}  \
                 put_uncached {}  read_faults {}",
                st.evicted, st.evicted_bytes, st.quarantine_gced, st.put_uncached,
                st.read_faults
            ));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(line: &str) -> Json {
        json::parse(line).unwrap()
    }

    #[test]
    fn sweep_job_parsing_strips_knobs_and_validates() {
        let j = sweep_job_from(&req(
            r#"{"verb":"sweep","app":"SLA","design":"caba-bdi","scale":0.01,
               "set":{"n_sms":2,"max_cycles":"150000","telemetry_window":512}}"#,
        ))
        .unwrap();
        assert_eq!(j.app.name, "SLA");
        assert_eq!(j.design.name, "CABA-BDI");
        assert_eq!(j.cfg.n_sms, 2);
        assert_eq!(j.cfg.max_cycles, 150_000);
        // Run-control knobs are stripped by the SweepJob constructor: a
        // telemetry-carrying request hits the same key as a plain one.
        assert_eq!(j.cfg.telemetry_window, 0);
        let plain = sweep_job_from(&req(
            r#"{"verb":"sweep","app":"SLA","design":"CABA-BDI","scale":0.01,
               "set":{"n_sms":2,"max_cycles":150000}}"#,
        ))
        .unwrap();
        assert_eq!(j.key(), plain.key());

        for bad in [
            r#"{"verb":"sweep"}"#,
            r#"{"verb":"sweep","app":"NOPE"}"#,
            r#"{"verb":"sweep","app":"SLA","design":"NOPE"}"#,
            r#"{"verb":"sweep","app":"SLA","scale":-1}"#,
            r#"{"verb":"sweep","app":"SLA","set":{"no_such_key":"1"}}"#,
            r#"{"verb":"sweep","app":"SLA","set":[1]}"#,
        ] {
            assert!(sweep_job_from(&req(bad)).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn responses_are_valid_json() {
        let s = SimStats::default();
        let job = sweep_job_from(&req(r#"{"verb":"sweep","app":"SLA"}"#)).unwrap();
        let ok = ok_json(&job, "warm", 7, &s);
        let v = json::parse(&ok).unwrap();
        assert_eq!(v.get("status").and_then(Json::as_str), Some("ok"));
        assert_eq!(v.get("source").and_then(Json::as_str), Some("warm"));
        assert_eq!(v.get("request_id").and_then(Json::as_u64), Some(7));
        assert_eq!(v.get("stats_digest").and_then(Json::as_str).map(str::len), Some(16));

        let err = error_json("shed", 8, "queue full; retry \"later\"");
        let v = json::parse(&err).unwrap();
        assert_eq!(v.get("status").and_then(Json::as_str), Some("shed"));
        assert_eq!(v.get("request_id").and_then(Json::as_u64), Some(8));
    }

    #[test]
    fn span_json_round_trips_including_null_stages() {
        let span = RequestTrace {
            id: 42,
            verb: "sweep".to_string(),
            detail: "SLA/Base".to_string(),
            outcome: "warm".to_string(),
            t_accept: 10,
            t_parsed: 12,
            t_queued: UNSET, // warm hit: never queued → null on the wire
            t_done: 99,
            queue_wait_us: 0,
            exec_us: 0,
        };
        let wire = span_json(&span);
        let v = json::parse(&wire).unwrap();
        assert_eq!(v.get("t_queued"), Some(&Json::Null));
        assert_eq!(span_from_json(&v), Some(span));
    }
}
