//! Simulation statistics: the issue-cycle taxonomy of Fig. 2, cache /
//! interconnect / DRAM counters, compression effectiveness, CABA activity,
//! and the energy event counts consumed by [`crate::energy`].

/// Why a scheduler slot failed to issue this cycle (Fig. 2's taxonomy).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StallKind {
    /// ALU-pipeline structural stall (backed-up compute pipelines).
    Compute,
    /// Memory-pipeline structural stall (LSU/MSHR/queues full).
    Memory,
    /// All warps blocked on operands of in-flight long-latency ops.
    DataDependence,
    /// No warp had a decodable instruction (empty IB / drained / barrier).
    Idle,
}

/// Per-scheduler-slot issue-cycle breakdown.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IssueBreakdown {
    pub active: u64,
    pub compute_stall: u64,
    pub memory_stall: u64,
    pub data_stall: u64,
    pub idle: u64,
}

impl IssueBreakdown {
    pub fn total(&self) -> u64 {
        self.active + self.compute_stall + self.memory_stall + self.data_stall + self.idle
    }

    pub fn record_stall(&mut self, kind: StallKind) {
        self.bulk_charge(kind, 1);
    }

    /// Charge `n` scheduler slots to `kind` at once — the event-driven
    /// tick's bulk equivalent of `n` calls to [`Self::record_stall`]
    /// (integer counters, so bulk and per-cycle charging are exactly
    /// interchangeable).
    pub fn bulk_charge(&mut self, kind: StallKind, n: u64) {
        match kind {
            StallKind::Compute => self.compute_stall += n,
            StallKind::Memory => self.memory_stall += n,
            StallKind::DataDependence => self.data_stall += n,
            StallKind::Idle => self.idle += n,
        }
    }

    /// Fractions in paper order: (compute, memory, data, idle, active).
    pub fn fractions(&self) -> (f64, f64, f64, f64, f64) {
        let t = self.total().max(1) as f64;
        (
            self.compute_stall as f64 / t,
            self.memory_stall as f64 / t,
            self.data_stall as f64 / t,
            self.idle as f64 / t,
            self.active as f64 / t,
        )
    }
}

/// Cache counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CacheStats {
    pub accesses: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub writebacks: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            0.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// DRAM counters (per run, aggregated over MCs).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct DramStats {
    pub reads: u64,
    pub writes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    /// 32B bursts actually transferred (compressed traffic shrinks this).
    pub bursts: u64,
    /// Bursts an uncompressed system would have moved for the same accesses.
    pub bursts_uncompressed: u64,
    /// Core-cycles the data bus was busy (summed over MCs).
    pub bus_busy_cycles: f64,
    /// Extra DRAM accesses for compression metadata (MD-cache misses).
    pub md_accesses: u64,
}

impl DramStats {
    /// Paper metric: fraction of DRAM cycles the data bus is busy.
    /// Clamped to 1.0 — short windows can book more bus-busy cycles than
    /// wall-clock cycles × MCs (queued bursts charged on dispatch). The
    /// flight recorder counts such windows (`bus_overcommit_windows` on
    /// [`crate::telemetry::TelemetryRun`]) via the raw value below.
    pub fn bandwidth_utilization(&self, cycles: u64, n_mcs: usize) -> f64 {
        self.bandwidth_utilization_raw(cycles, n_mcs).min(1.0)
    }

    /// [`Self::bandwidth_utilization`] without the `.min(1.0)` clamp: may
    /// exceed 1.0 when the bus is overcommitted within the measured span.
    pub fn bandwidth_utilization_raw(&self, cycles: u64, n_mcs: usize) -> f64 {
        if cycles == 0 {
            0.0
        } else {
            self.bus_busy_cycles / (cycles as f64 * n_mcs as f64)
        }
    }

    /// Paper metric: bursts uncompressed / bursts compressed.
    pub fn compression_ratio(&self) -> f64 {
        if self.bursts == 0 {
            1.0
        } else {
            self.bursts_uncompressed as f64 / self.bursts as f64
        }
    }
}

/// Interconnect counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct IcntStats {
    pub packets_fwd: u64,
    pub packets_back: u64,
    /// 32B flits in each direction (compression shrinks the data flits).
    pub flits_fwd: u64,
    pub flits_back: u64,
}

/// CABA framework activity.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CabaStats {
    pub decompress_warps: u64,
    pub compress_warps: u64,
    pub assist_insts_issued: u64,
    /// Assist instructions issued into otherwise-idle issue slots.
    pub assist_insts_idle_slots: u64,
    /// Compression skipped (AWT full / throttled) → line sent uncompressed.
    pub compress_skipped: u64,
    /// Deployments deferred by the utilization-feedback throttle.
    pub throttled_deploys: u64,
    /// Assist warps killed (e.g., line arrived uncompressed).
    pub killed: u64,
    /// §8.2 prefetching: lines prefetched by assist warps.
    pub prefetches_issued: u64,
    /// §8.1 memoization (`crate::memo`): LUT probes by lookup assist warps.
    pub memo_lookups: u64,
    /// Probes whose stored tag matched a resident entry (alias hits
    /// included — the modeled hardware serves them either way).
    pub memo_hits: u64,
    /// Of the hits, probes that matched a *different* tuple's entry — the
    /// aliasing the truncated tag width (`memo_tag_bits`) allows.
    pub memo_alias_hits: u64,
    /// Results installed into the LUT by retired install assist warps.
    pub memo_installs: u64,
    /// Valid LUT entries evicted (LRU) to make room for an install.
    pub memo_evictions: u64,
    /// SFU ops that bypassed the LUT because the AWT had no free row for
    /// the lookup assist warp.
    pub memo_lookups_skipped: u64,
}

impl CabaStats {
    /// Measured LUT hit rate (alias hits included — they return a result
    /// in the modeled hardware, right or wrong). `None` when the design
    /// never probed.
    pub fn memo_hit_rate(&self) -> Option<f64> {
        if self.memo_lookups == 0 {
            None
        } else {
            Some(self.memo_hits as f64 / self.memo_lookups as f64)
        }
    }
}

/// MD cache (per-MC compression metadata cache, §5.3.2).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MdCacheStats {
    pub accesses: u64,
    pub hits: u64,
}

impl MdCacheStats {
    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            1.0
        } else {
            self.hits as f64 / self.accesses as f64
        }
    }
}

/// Energy-relevant event counts (consumed by [`crate::energy::EnergyModel`]).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct EnergyEvents {
    /// Parent-warp instructions issued (each ≈ fetch+decode+RF+ALU).
    pub core_insts: u64,
    /// Assist-warp instructions issued.
    pub assist_insts: u64,
    pub l1_accesses: u64,
    pub l2_accesses: u64,
    pub icnt_flits: u64,
    pub dram_bursts: u64,
    pub dram_activates: u64,
    pub md_cache_accesses: u64,
    /// Dedicated-logic (de)compression operations (HW designs only).
    pub hw_compressor_ops: u64,
}

/// Trace-capture activity (see `crate::trace`). Only *recording* counters
/// live here: they are a deterministic function of the run. Replay-side
/// counters (cache hits, generator fallbacks) are cumulative per loaded
/// trace and deliberately stay on `trace::replay::TraceData`, so cached
/// sweep results remain a pure function of the simulation inputs.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TraceStats {
    /// Deduplicated warp-level access records captured.
    pub accesses_recorded: u64,
    /// Deduplicated (line, epoch) payload entries captured.
    pub payloads_recorded: u64,
}

/// Everything a single simulation run produces.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SimStats {
    pub cycles: u64,
    /// Issued warp-instructions (parent warps only).
    pub warp_insts: u64,
    /// Issued thread-instructions (warp_insts × active lanes).
    pub thread_insts: u64,
    pub issue: IssueBreakdown,
    pub l1: CacheStats,
    pub l2: CacheStats,
    pub dram: DramStats,
    pub icnt: IcntStats,
    pub caba: CabaStats,
    pub md: MdCacheStats,
    pub energy_events: EnergyEvents,
    pub trace: TraceStats,
    /// CTAs launched (initial dispatch + refills). On a drained run every
    /// launched CTA also retired, and [`crate::sim::Simulator::run`]
    /// asserts this equals the workload's `total_ctas`.
    pub ctas_launched: u64,
    /// All launched warps finished their program.
    pub finished: bool,
}

impl SimStats {
    /// Paper headline metric: warp-instructions per cycle across the chip.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.warp_insts as f64 / self.cycles as f64
        }
    }

    /// The memory-side counters a trace replay must reproduce
    /// **bit-identically** (the `trace record` → `trace replay` regression
    /// contract): caches, DRAM, interconnect, MD cache and CABA activity.
    /// Excludes [`SimStats::trace`] (a record run counts captures, a
    /// replay run doesn't) — everything else here must match exactly.
    pub fn memory_signature(
        &self,
    ) -> (CacheStats, CacheStats, DramStats, IcntStats, MdCacheStats, CabaStats) {
        (self.l1, self.l2, self.dram, self.icnt, self.md, self.caba)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_fractions_sum_to_one() {
        let mut b = IssueBreakdown::default();
        b.active = 50;
        b.record_stall(StallKind::Compute);
        b.record_stall(StallKind::Memory);
        b.record_stall(StallKind::DataDependence);
        b.record_stall(StallKind::Idle);
        for _ in 0..46 {
            b.record_stall(StallKind::Memory);
        }
        assert_eq!(b.total(), 100);
        let (c, m, d, i, a) = b.fractions();
        assert!((c + m + d + i + a - 1.0).abs() < 1e-12);
        assert!((a - 0.5).abs() < 1e-12);
        assert!((m - 0.47).abs() < 1e-12);
    }

    #[test]
    fn compression_ratio_identity_when_uncompressed() {
        let d = DramStats {
            bursts: 100,
            bursts_uncompressed: 100,
            ..Default::default()
        };
        assert_eq!(d.compression_ratio(), 1.0);
    }

    #[test]
    fn bw_utilization_bounds() {
        let d = DramStats {
            bus_busy_cycles: 600.0,
            ..Default::default()
        };
        let u = d.bandwidth_utilization(100, 6);
        assert!((u - 1.0).abs() < 1e-12);
        assert_eq!(d.bandwidth_utilization(0, 6), 0.0);
    }

    #[test]
    fn bw_utilization_clamp_boundary() {
        // Exactly at capacity: raw == clamped == 1.0 (not an overcommit).
        let d = DramStats {
            bus_busy_cycles: 600.0,
            ..Default::default()
        };
        assert_eq!(d.bandwidth_utilization_raw(100, 6), 1.0);
        assert_eq!(d.bandwidth_utilization(100, 6), 1.0);
        // One busy cycle over capacity: raw exceeds 1.0, public metric clamps.
        let over = DramStats {
            bus_busy_cycles: 601.0,
            ..Default::default()
        };
        assert!(over.bandwidth_utilization_raw(100, 6) > 1.0);
        assert_eq!(over.bandwidth_utilization(100, 6), 1.0);
        // Under capacity: clamp is a no-op.
        let under = DramStats {
            bus_busy_cycles: 599.0,
            ..Default::default()
        };
        assert_eq!(
            under.bandwidth_utilization(100, 6),
            under.bandwidth_utilization_raw(100, 6)
        );
        // Zero-cycle guard holds for both.
        assert_eq!(over.bandwidth_utilization_raw(0, 6), 0.0);
    }

    #[test]
    fn ipc_zero_cycles() {
        assert_eq!(SimStats::default().ipc(), 0.0);
    }

    #[test]
    fn md_hit_rate_empty_is_one() {
        assert_eq!(MdCacheStats::default().hit_rate(), 1.0);
    }

    #[test]
    fn memory_signature_ignores_trace_counters_only() {
        let mut a = SimStats::default();
        let mut b = a.clone();
        b.trace.accesses_recorded = 99; // a record run vs its replay
        assert_ne!(a, b);
        assert_eq!(a.memory_signature(), b.memory_signature());
        a.dram.bursts = 1; // any memory-side divergence must show
        assert_ne!(a.memory_signature(), b.memory_signature());
    }
}
