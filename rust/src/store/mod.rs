//! # The crash-safe on-disk run store
//!
//! The sweep engine's [`crate::sweep::RunCache`] is sharded but
//! in-process: it dies with the run, so every `caba fig` invocation and
//! every serve-daemon restart re-simulates from scratch. This module
//! promotes it to a **persistent content-addressed store**: one file per
//! completed run, keyed by the existing [`crate::sweep::JobKey`]
//! (app, design, full-config fingerprint, scale bits, trace digest).
//! Because the key already digests *every* simulated parameter — and the
//! sweep constructors strip run-control knobs like `trace_record` and the
//! telemetry settings — a store entry is valid forever: same key, same
//! bit-identical [`SimStats`], across processes and PRs.
//!
//! ## Durability contract
//!
//! Writes are **atomic or invisible**:
//!
//! 1. encode the full entry (header + payload + checksum) in memory;
//! 2. write it to `<name>.tmp.<pid>.<seq>` in the store directory;
//! 3. `fsync` the temp file;
//! 4. atomically `rename` onto the final `<name>.run` path;
//! 5. `fsync` the directory so the rename itself is durable.
//!
//! A `kill -9` at any point leaves either the old state or a stale
//! `*.tmp.*` file, which [`RunStore::open`] deletes (counted as
//! `temp_cleaned`) — never a half-written entry under the final name.
//!
//! ## Read-side skepticism
//!
//! The store trusts nothing it reads. Every entry carries a magic tag, a
//! format version, the full key it was written under, and an FNV-1a64
//! checksum over everything that precedes it. Any mismatch — truncation,
//! bit rot, a stale format version, a file renamed onto the wrong key —
//! **quarantines** the entry: it is renamed aside
//! (`<name>.quarantined.<pid>.<seq>`), counted, and reported as a miss so
//! the caller recomputes. Corruption can cost a re-simulation; it can
//! never produce wrong stats, and it is never fatal.
//!
//! ## Bounded capacity (PR 10)
//!
//! The store is a cache over recomputation, so *any* entry may be
//! discarded at any moment without correctness loss — the same
//! reclaimable-donation property CABA demands of assist warps and
//! Morpheus of its victim cache. [`CapacityPolicy`] makes that bound
//! explicit:
//!
//! - **Byte budget** (`max_bytes`, `--store-max-bytes`): committed
//!   `.run` bytes never exceed the budget. [`RunStore::open_with`] runs a
//!   manifest scan that seeds an in-memory LRU index from file mtimes;
//!   every warm hit bumps the entry's stamp (and best-effort re-stamps
//!   the file so recency survives restarts); every put evicts
//!   least-recently-used entries until the total fits. An entry larger
//!   than the whole budget is not written at all (`put_uncached`).
//! - **Quarantine GC**: `.quarantined.*` files used to accumulate
//!   forever; now only the newest `quarantine_keep` are retained, the
//!   rest are deleted on open and whenever a new quarantine happens
//!   (`quarantine_gced`).
//! - **Incremental compaction**: every `compact_every` puts, one
//!   background-free [`RunStore::compact_step`] revalidates a couple of
//!   entries (proactively quarantining bit rot before a reader trips on
//!   it) and reconciles the index with disk truth (externally deleted or
//!   resized files). No rewrite pass is needed: a valid entry is already
//!   canonical (exact-length, checksummed), so "compaction" is
//!   validate + quarantine + reconcile, and any replacement write goes
//!   through the same temp+fsync+rename discipline as a normal put.
//!
//! All of it is observation-only for results: eviction and GC can cost a
//! recompute, never a wrong answer, and none of the knobs enter the
//! config fingerprint.
//!
//! The entry payload is the bit-exact [`codec`] encoding of `SimStats`;
//! [`fault`] provides the deterministic fault-injection plans the test
//! suites and `caba bench` use to prove all of the above — including the
//! disk-chaos keys (`enospc_at`, `eio_read_at`, `slow_fsync_ms`) that
//! drive `tests/chaos_soak.rs`.

pub mod codec;
pub mod fault;

pub use codec::{decode_stats, encode_stats, fnv1a64, stats_digest};
pub use fault::{FaultPlan, PutFault};

use crate::stats::SimStats;
use crate::sweep::JobKey;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::SystemTime;

/// On-disk entry format version. Bump whenever the entry layout *or* the
/// stats payload codec changes shape — old entries then quarantine on
/// read (and are recomputed) instead of mis-parsing.
pub const STORE_VERSION: u32 = 1;

/// Entry magic: identifies run-store files regardless of name.
const MAGIC: &[u8; 8] = b"CABARUN1";

/// Extension of committed entries.
const ENTRY_EXT: &str = ".run";

/// Marker embedded in quarantined file names.
const QUARANTINE_MARK: &str = ".quarantined.";

/// Entries structurally revalidated per [`RunStore::compact_step`].
const COMPACT_BATCH: usize = 2;

/// Bounded-resource policy for a [`RunStore`]. Everything here is
/// reclamation policy over a cache — none of it can change a result,
/// and none of it enters the config fingerprint.
#[derive(Clone, Copy, Debug)]
pub struct CapacityPolicy {
    /// Byte budget over committed `.run` entries; 0 = unbounded.
    pub max_bytes: u64,
    /// Keep at most this many `.quarantined.*` files (newest by mtime);
    /// the rest are deleted on open and on each new quarantine.
    pub quarantine_keep: usize,
    /// Run one incremental [`RunStore::compact_step`] every N puts
    /// (0 disables the piggybacked cadence; explicit calls still work).
    pub compact_every: u64,
}

impl Default for CapacityPolicy {
    fn default() -> CapacityPolicy {
        CapacityPolicy { max_bytes: 0, quarantine_keep: 8, compact_every: 16 }
    }
}

/// In-memory LRU index over committed entries: file name → (size,
/// recency stamp). Seeded from mtimes by the on-open manifest scan,
/// stamped monotonically afterwards.
#[derive(Default)]
struct CapIndex {
    entries: HashMap<String, EntryMeta>,
    total_bytes: u64,
    clock: u64,
    /// Pending compaction scan queue (drained [`COMPACT_BATCH`] at a
    /// time, refilled from a fresh dir listing when empty).
    scan: Vec<String>,
}

#[derive(Clone, Copy)]
struct EntryMeta {
    size: u64,
    stamp: u64,
}

/// Monotonic counters describing a store's activity since open.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Entries durably committed.
    pub puts: u64,
    /// Reads answered from a valid on-disk entry.
    pub warm_hits: u64,
    /// Reads that found no entry (including just-quarantined ones).
    pub misses: u64,
    /// Entries renamed aside because they failed validation.
    pub quarantined: u64,
    /// Stale `*.tmp.*` files removed by [`RunStore::open`].
    pub temp_cleaned: u64,
    /// Writes that failed with an I/O error (non-fatal to callers that
    /// treat the store as a cache).
    pub put_errors: u64,
    /// Entries removed by LRU eviction to stay under the byte budget.
    pub evicted: u64,
    /// Bytes reclaimed by LRU eviction.
    pub evicted_bytes: u64,
    /// `.quarantined.*` files aged out (keep-newest-K policy).
    pub quarantine_gced: u64,
    /// Writes skipped because the encoded entry alone exceeds the byte
    /// budget (the result is still returned to the caller — compute
    /// without caching).
    pub put_uncached: u64,
    /// Reads that failed with a (possibly injected) I/O error and were
    /// reported as misses without quarantining — recompute-and-heal.
    pub read_faults: u64,
    /// Incremental compaction steps executed.
    pub compact_steps: u64,
}

/// A crash-safe, content-addressed `JobKey → SimStats` store rooted at
/// one directory. All methods are `&self` and thread-safe: concurrent
/// writers racing on the same key each perform an independent atomic
/// rename, and since identical keys imply bit-identical payloads, either
/// winner leaves the same bytes.
pub struct RunStore {
    dir: PathBuf,
    fault: Option<Arc<FaultPlan>>,
    policy: CapacityPolicy,
    index: Mutex<CapIndex>,
    seq: AtomicU64,
    puts: AtomicU64,
    warm_hits: AtomicU64,
    misses: AtomicU64,
    quarantined: AtomicU64,
    temp_cleaned: AtomicU64,
    put_errors: AtomicU64,
    evicted: AtomicU64,
    evicted_bytes: AtomicU64,
    quarantine_gced: AtomicU64,
    put_uncached: AtomicU64,
    read_faults: AtomicU64,
    compact_steps: AtomicU64,
}

impl RunStore {
    /// Open (creating if needed) a store at `dir` with the default
    /// [`CapacityPolicy`] (unbounded bytes, quarantine GC active),
    /// sweeping any stale temp files left by crashed writers.
    pub fn open(dir: impl Into<PathBuf>) -> Result<RunStore> {
        Self::open_with(dir, CapacityPolicy::default())
    }

    /// Open a store under an explicit capacity policy. Runs the manifest
    /// scan (seeding the LRU index from file mtimes), sweeps stale
    /// temps, ages out excess `.quarantined.*` files, and evicts down to
    /// the byte budget before returning.
    pub fn open_with(dir: impl Into<PathBuf>, policy: CapacityPolicy) -> Result<RunStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("run store: create {}", dir.display()))?;
        let store = RunStore {
            dir,
            fault: None,
            policy,
            index: Mutex::new(CapIndex::default()),
            seq: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            temp_cleaned: AtomicU64::new(0),
            put_errors: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
            evicted_bytes: AtomicU64::new(0),
            quarantine_gced: AtomicU64::new(0),
            put_uncached: AtomicU64::new(0),
            read_faults: AtomicU64::new(0),
            compact_steps: AtomicU64::new(0),
        };
        store.clean_stale_temps()?;
        store.gc_quarantined();
        store.scan_manifest();
        store.enforce_budget();
        Ok(store)
    }

    /// Attach a fault-injection plan (tests, `caba bench`, `caba serve
    /// --fault`). Store writes then consult [`FaultPlan::on_put`].
    pub fn with_fault(mut self, fault: Arc<FaultPlan>) -> RunStore {
        self.fault = Some(fault);
        self
    }

    /// The directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot of the activity counters.
    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            puts: self.puts.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            temp_cleaned: self.temp_cleaned.load(Ordering::Relaxed),
            put_errors: self.put_errors.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
            evicted_bytes: self.evicted_bytes.load(Ordering::Relaxed),
            quarantine_gced: self.quarantine_gced.load(Ordering::Relaxed),
            put_uncached: self.put_uncached.load(Ordering::Relaxed),
            read_faults: self.read_faults.load(Ordering::Relaxed),
            compact_steps: self.compact_steps.load(Ordering::Relaxed),
        }
    }

    /// The capacity policy this store was opened with.
    pub fn policy(&self) -> CapacityPolicy {
        self.policy
    }

    /// Committed `.run` bytes currently accounted by the LRU index
    /// (what the byte budget bounds).
    pub fn disk_bytes(&self) -> u64 {
        self.lock_index().total_bytes
    }

    /// Committed entries currently on disk (diagnostics/tests; excludes
    /// quarantined and temp files).
    pub fn len(&self) -> usize {
        let Ok(rd) = fs::read_dir(&self.dir) else { return 0 };
        rd.filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(ENTRY_EXT))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `key`. `None` means "recompute" — covers both a genuinely
    /// missing entry and one that failed validation (which is quarantined
    /// as a side effect). Never returns stats that failed any check.
    pub fn get(&self, key: &JobKey) -> Option<SimStats> {
        let path = self.entry_path(key);
        if self.fault.as_deref().is_some_and(FaultPlan::on_read) {
            // Injected EIO: the file (if any) is healthy, so no
            // quarantine — report a miss and let the caller recompute;
            // its re-put heals the slot.
            self.read_faults.fetch_add(1, Ordering::Relaxed);
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Err(_) => {
                // Unreadable (permissions, I/O error): treat as a miss
                // without quarantining — the file may recover.
                self.read_faults.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match parse_entry(&bytes, key) {
            Ok(stats) => {
                self.warm_hits.fetch_add(1, Ordering::Relaxed);
                self.touch(&path, bytes.len() as u64);
                Some(stats)
            }
            Err(_) => {
                self.quarantine(&path);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Durably store `key → stats` via the temp + fsync + rename
    /// protocol. Errors are returned (and counted) but callers treating
    /// the store as a cache may ignore them — a failed put only costs a
    /// future recompute.
    pub fn put(&self, key: &JobKey, stats: &SimStats) -> Result<()> {
        let mut bytes = encode_entry(key, stats);
        let final_path = self.entry_path(key);

        if self.policy.max_bytes > 0 && bytes.len() as u64 > self.policy.max_bytes {
            // The entry alone overflows the budget: writing it just to
            // evict it (or everything else) would churn the disk for
            // nothing. Skip the write — compute-without-caching, not an
            // error.
            self.put_uncached.fetch_add(1, Ordering::Relaxed);
            return Ok(());
        }

        match self.fault.as_deref().map_or(PutFault::None, FaultPlan::on_put) {
            PutFault::None => {}
            PutFault::Enospc => {
                // Injected disk-full: nothing reaches disk, the caller
                // sees a counted, non-fatal error and keeps its computed
                // result — the cache degrades, the answer does not.
                self.put_errors.fetch_add(1, Ordering::Relaxed);
                bail!(
                    "injected fault: ENOSPC writing {} (no space left on device)",
                    final_path.display()
                );
            }
            PutFault::Torn => {
                // Simulated crash mid-write: a truncated prefix lands on
                // the final path directly (no temp, no fsync) and the
                // writer "dies" — reported as success, like a real crash
                // reports nothing at all.
                let _ = fs::write(&final_path, &bytes[..bytes.len() / 2]);
                return Ok(());
            }
            PutFault::FlipChecksum => {
                // Corrupt one payload byte *after* the checksum was
                // computed, then commit atomically: the entry arrives
                // whole but fails verification on read.
                let payload_byte = bytes.len() - 9; // last payload byte (before 8-byte checksum)
                bytes[payload_byte] ^= 0x01;
            }
        }

        let res = self.put_atomic(&final_path, &bytes);
        match res {
            Ok(()) => {
                let n = self.puts.fetch_add(1, Ordering::Relaxed) + 1;
                self.index_insert(&final_path, bytes.len() as u64);
                if self.policy.compact_every > 0 && n % self.policy.compact_every == 0 {
                    self.compact_step();
                }
                self.enforce_budget();
                Ok(())
            }
            Err(e) => {
                self.put_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn put_atomic(&self, final_path: &Path, bytes: &[u8]) -> Result<()> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let tmp_name = format!(
            "{}.tmp.{}.{}",
            final_path.file_name().unwrap_or_default().to_string_lossy(),
            std::process::id(),
            seq
        );
        let tmp_path = self.dir.join(tmp_name);
        let write = (|| -> Result<()> {
            let mut f = File::create(&tmp_path)
                .with_context(|| format!("run store: create {}", tmp_path.display()))?;
            f.write_all(bytes).context("run store: write entry")?;
            // Degraded-disk shaping: an attached fault plan may stall
            // every fsync (slow_fsync_ms) to model a saturated device.
            let stall = self.fault.as_deref().map_or(0, FaultPlan::fsync_stall_ms);
            if stall > 0 {
                std::thread::sleep(std::time::Duration::from_millis(stall));
            }
            f.sync_all().context("run store: fsync entry")?;
            drop(f);
            fs::rename(&tmp_path, final_path)
                .with_context(|| format!("run store: commit {}", final_path.display()))?;
            // Make the rename itself durable. Best-effort: some
            // filesystems reject fsync on directories — the entry is
            // still atomic, just not crash-durable there.
            if let Ok(d) = File::open(&self.dir) {
                let _ = d.sync_all();
            }
            Ok(())
        })();
        if write.is_err() {
            let _ = fs::remove_file(&tmp_path);
        }
        write
    }

    /// Rename a failed entry aside so it is preserved for inspection but
    /// never consulted again.
    fn quarantine(&self, path: &Path) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let q_name = format!(
            "{}.quarantined.{}.{}",
            path.file_name().unwrap_or_default().to_string_lossy(),
            std::process::id(),
            seq
        );
        // A concurrent quarantine of the same file can win the rename
        // race; either way the bad entry is gone from the read path.
        let _ = fs::rename(path, self.dir.join(q_name));
        self.quarantined.fetch_add(1, Ordering::Relaxed);
        self.index_remove(path);
        // Keep the quarantine shelf bounded: age out everything beyond
        // the newest `quarantine_keep` right away.
        self.gc_quarantined();
    }

    // ---- capacity manager ------------------------------------------------

    /// Poison-recovering index lock: a panicking thread (e.g. an
    /// injected worker panic mid-put) must never wedge the store.
    fn lock_index(&self) -> MutexGuard<'_, CapIndex> {
        self.index.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
    }

    fn file_name_of(path: &Path) -> String {
        path.file_name().unwrap_or_default().to_string_lossy().into_owned()
    }

    /// Record (or refresh) a committed entry in the LRU index.
    fn index_insert(&self, path: &Path, size: u64) {
        let name = Self::file_name_of(path);
        let mut ix = self.lock_index();
        ix.clock += 1;
        let stamp = ix.clock;
        let old = ix.entries.insert(name, EntryMeta { size, stamp });
        ix.total_bytes = ix.total_bytes - old.map_or(0, |m| m.size) + size;
    }

    fn index_remove(&self, path: &Path) {
        let name = Self::file_name_of(path);
        let mut ix = self.lock_index();
        if let Some(m) = ix.entries.remove(&name) {
            ix.total_bytes = ix.total_bytes.saturating_sub(m.size);
        }
    }

    /// Bump an entry's recency stamp on a warm hit, and best-effort
    /// re-stamp the file's mtime so LRU order survives a restart (the
    /// manifest scan seeds stamps from mtimes — an "atime" we control).
    fn touch(&self, path: &Path, size: u64) {
        let name = Self::file_name_of(path);
        {
            let mut ix = self.lock_index();
            ix.clock += 1;
            let stamp = ix.clock;
            match ix.entries.get_mut(&name) {
                Some(m) => m.stamp = stamp,
                None => {
                    ix.entries.insert(name, EntryMeta { size, stamp });
                    ix.total_bytes += size;
                }
            }
        }
        let _ = File::options()
            .append(true)
            .open(path)
            .and_then(|f| f.set_modified(SystemTime::now()));
    }

    /// On-open manifest scan: list committed entries, seed LRU stamps in
    /// mtime order (oldest = least recently used). Unreadable metadata
    /// degrades to stamp order of discovery — never fatal.
    fn scan_manifest(&self) {
        let Ok(rd) = fs::read_dir(&self.dir) else { return };
        let mut found: Vec<(String, u64, SystemTime)> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                if !name.ends_with(ENTRY_EXT) {
                    return None;
                }
                let md = e.metadata().ok()?;
                let mtime = md.modified().unwrap_or(SystemTime::UNIX_EPOCH);
                Some((name, md.len(), mtime))
            })
            .collect();
        found.sort_by(|a, b| a.2.cmp(&b.2).then_with(|| a.0.cmp(&b.0)));
        let mut ix = self.lock_index();
        for (name, size, _) in found {
            ix.clock += 1;
            let stamp = ix.clock;
            if ix.entries.insert(name, EntryMeta { size, stamp }).is_none() {
                ix.total_bytes += size;
            }
        }
    }

    /// Age out `.quarantined.*` files beyond the newest
    /// `quarantine_keep` (by mtime, name as tiebreak). They exist for
    /// inspection, not as an unbounded graveyard.
    fn gc_quarantined(&self) {
        let Ok(rd) = fs::read_dir(&self.dir) else { return };
        let mut quarantined: Vec<(SystemTime, String)> = rd
            .filter_map(|e| e.ok())
            .filter_map(|e| {
                let name = e.file_name().to_string_lossy().into_owned();
                if !name.contains(QUARANTINE_MARK) {
                    return None;
                }
                let mtime = e
                    .metadata()
                    .and_then(|m| m.modified())
                    .unwrap_or(SystemTime::UNIX_EPOCH);
                Some((mtime, name))
            })
            .collect();
        if quarantined.len() <= self.policy.quarantine_keep {
            return;
        }
        // Oldest first; delete everything before the keep window.
        quarantined.sort();
        let excess = quarantined.len() - self.policy.quarantine_keep;
        for (_, name) in quarantined.into_iter().take(excess) {
            if fs::remove_file(self.dir.join(name)).is_ok() {
                self.quarantine_gced.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Evict least-recently-used entries until committed bytes fit the
    /// budget. Eviction is always safe: entries are a cache over
    /// recomputation, so the worst case is a future warm hit becoming a
    /// recompute. The just-written entry carries the newest stamp and is
    /// therefore chosen last.
    fn enforce_budget(&self) {
        if self.policy.max_bytes == 0 {
            return;
        }
        loop {
            let victim = {
                let mut ix = self.lock_index();
                if ix.total_bytes <= self.policy.max_bytes {
                    return;
                }
                let name = ix
                    .entries
                    .iter()
                    .min_by(|a, b| a.1.stamp.cmp(&b.1.stamp).then_with(|| a.0.cmp(b.0)))
                    .map(|(n, _)| n.clone());
                match name {
                    Some(n) => {
                        let meta = ix.entries.remove(&n).expect("victim exists");
                        ix.total_bytes = ix.total_bytes.saturating_sub(meta.size);
                        (n, meta.size)
                    }
                    // Index empty but total nonzero: accounting drift
                    // (e.g. external writes); reset and let compaction
                    // re-reconcile.
                    None => {
                        ix.total_bytes = 0;
                        return;
                    }
                }
            };
            // Best-effort removal outside the lock; a racing external
            // delete is fine (the bytes are gone either way).
            let _ = fs::remove_file(self.dir.join(&victim.0));
            self.evicted.fetch_add(1, Ordering::Relaxed);
            self.evicted_bytes.fetch_add(victim.1, Ordering::Relaxed);
        }
    }

    /// One background-free compaction step: structurally revalidate up
    /// to [`COMPACT_BATCH`] committed entries (quarantining bit rot
    /// before a reader trips on it) and reconcile the LRU index with
    /// disk truth — externally deleted files leave the index, externally
    /// grown/shrunk ones are re-measured. Piggybacked on every
    /// `compact_every`-th put; also callable directly. Never blocks
    /// readers and never touches a valid entry's bytes (valid entries
    /// are already canonical — exact-length, checksummed — so there is
    /// nothing to rewrite).
    pub fn compact_step(&self) {
        self.compact_steps.fetch_add(1, Ordering::Relaxed);
        let batch: Vec<String> = {
            let mut ix = self.lock_index();
            if ix.scan.is_empty() {
                if let Ok(rd) = fs::read_dir(&self.dir) {
                    ix.scan = rd
                        .filter_map(|e| e.ok())
                        .map(|e| e.file_name().to_string_lossy().into_owned())
                        .filter(|n| n.ends_with(ENTRY_EXT))
                        .collect();
                }
            }
            let take = ix.scan.len().min(COMPACT_BATCH);
            ix.scan.split_off(ix.scan.len() - take)
        };
        for name in batch {
            let path = self.dir.join(&name);
            match fs::read(&path) {
                Err(_) => self.index_remove(&path),
                Ok(bytes) => {
                    if validate_entry(&bytes).is_ok() {
                        let disk_size = bytes.len() as u64;
                        let mut ix = self.lock_index();
                        ix.clock += 1;
                        let stamp = ix.clock;
                        match ix.entries.get_mut(&name) {
                            Some(m) if m.size != disk_size => {
                                ix.total_bytes =
                                    ix.total_bytes.saturating_sub(m.size) + disk_size;
                                m.size = disk_size;
                            }
                            Some(_) => {}
                            // Discovered outside the index (external
                            // copy-in, torn-write debris that validated
                            // — impossible — or a pre-open writer):
                            // adopt it as oldest-known.
                            None => {
                                let meta = EntryMeta { size: disk_size, stamp };
                                ix.entries.insert(name.clone(), meta);
                                ix.total_bytes += disk_size;
                            }
                        }
                    } else {
                        self.quarantine(&path);
                    }
                }
            }
        }
        self.enforce_budget();
    }

    fn clean_stale_temps(&self) -> Result<()> {
        let rd = fs::read_dir(&self.dir)
            .with_context(|| format!("run store: scan {}", self.dir.display()))?;
        for entry in rd.filter_map(|e| e.ok()) {
            let name = entry.file_name();
            if name.to_string_lossy().contains(".tmp.")
                && fs::remove_file(entry.path()).is_ok()
            {
                self.temp_cleaned.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Final path of `key`'s entry. The name is human-greppable
    /// (`app__design__hexes.run`) but only advisory: the key embedded in
    /// the entry is what [`parse_entry`] actually verifies.
    fn entry_path(&self, key: &JobKey) -> PathBuf {
        let sane = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
                .collect()
        };
        let (app, design, fp, scale, digest) = key;
        self.dir.join(format!(
            "{}__{}__{fp:016x}_{scale:016x}_{digest:016x}{ENTRY_EXT}",
            sane(app),
            sane(design)
        ))
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode a complete store entry:
/// `MAGIC · version:u32 · app_len:u16 · app · design_len:u16 · design ·
/// fp:u64 · scale:u64 · digest:u64 · payload_len:u32 · payload ·
/// fnv1a64(everything preceding):u64` — all little-endian.
pub fn encode_entry(key: &JobKey, stats: &SimStats) -> Vec<u8> {
    let (app, design, fp, scale, digest) = key;
    let mut payload = Vec::with_capacity(512);
    encode_stats(stats, &mut payload);

    let mut out = Vec::with_capacity(payload.len() + 96);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, STORE_VERSION);
    put_u16(&mut out, app.len() as u16);
    out.extend_from_slice(app.as_bytes());
    put_u16(&mut out, design.len() as u16);
    out.extend_from_slice(design.as_bytes());
    put_u64(&mut out, *fp);
    put_u64(&mut out, *scale);
    put_u64(&mut out, *digest);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    let sum = fnv1a64(&out);
    put_u64(&mut out, sum);
    out
}

/// Bounds-checked little-endian reader for the entry header.
struct EntryReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> EntryReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated entry: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Structurally validate an entry without knowing its key: magic →
/// version → checksum → header bounds → payload decode → exact-length
/// consumption. Used by [`RunStore::compact_step`] to quarantine bit rot
/// proactively — key matching still happens on every real read.
pub fn validate_entry(bytes: &[u8]) -> Result<()> {
    let mut r = EntryReader { buf: bytes, pos: 0 };
    if r.take(MAGIC.len())? != MAGIC {
        bail!("bad magic: not a run-store entry");
    }
    let version = r.u32()?;
    if version != STORE_VERSION {
        bail!("entry version {version}, this build reads {STORE_VERSION}");
    }
    if bytes.len() < r.pos + 8 {
        bail!("truncated entry: missing checksum");
    }
    let body = &bytes[..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    if stored_sum != fnv1a64(body) {
        bail!("checksum mismatch");
    }
    let app_len = r.u16()? as usize;
    r.take(app_len)?;
    let design_len = r.u16()? as usize;
    r.take(design_len)?;
    r.u64()?; // fp
    r.u64()?; // scale
    r.u64()?; // digest
    let payload_len = r.u32()? as usize;
    let payload = r.take(payload_len)?;
    if r.pos != body.len() {
        bail!("corrupt entry: stray bytes between payload and checksum");
    }
    decode_stats(payload)?;
    Ok(())
}

/// Validate and decode an entry read from disk, in strictly escalating
/// order of trust: magic → version → checksum → embedded-key match →
/// payload decode → exact-length consumption. Any failure is corruption
/// (or a stale format) and the caller quarantines the file.
pub fn parse_entry(bytes: &[u8], key: &JobKey) -> Result<SimStats> {
    let mut r = EntryReader { buf: bytes, pos: 0 };
    if r.take(MAGIC.len())? != MAGIC {
        bail!("bad magic: not a run-store entry");
    }
    let version = r.u32()?;
    if version != STORE_VERSION {
        bail!("entry version {version}, this build reads {STORE_VERSION}");
    }
    if bytes.len() < r.pos + 8 {
        bail!("truncated entry: missing checksum");
    }
    let body = &bytes[..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let actual_sum = fnv1a64(body);
    if stored_sum != actual_sum {
        bail!("checksum mismatch: stored {stored_sum:016x}, computed {actual_sum:016x}");
    }

    let app_len = r.u16()? as usize;
    let app = r.take(app_len)?;
    let design_len = r.u16()? as usize;
    let design = r.take(design_len)?;
    let fp = r.u64()?;
    let scale = r.u64()?;
    let digest = r.u64()?;
    let (want_app, want_design, want_fp, want_scale, want_digest) = key;
    if app != want_app.as_bytes()
        || design != want_design.as_bytes()
        || fp != *want_fp
        || scale != *want_scale
        || digest != *want_digest
    {
        bail!(
            "key mismatch: entry written for ({}, {}), requested ({want_app}, {want_design})",
            String::from_utf8_lossy(app),
            String::from_utf8_lossy(design),
        );
    }

    let payload_len = r.u32()? as usize;
    let payload = r.take(payload_len)?;
    if r.pos != body.len() {
        bail!("corrupt entry: {} stray bytes between payload and checksum", body.len() - r.pos);
    }
    decode_stats(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("caba_store_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    fn a_key() -> JobKey {
        ("SLA", "CABA-BDI", 0xdead_beef_0000_0001, 0.01f64.to_bits(), 0)
    }

    fn a_stats() -> SimStats {
        let mut s = SimStats::default();
        s.cycles = 42_000;
        s.warp_insts = 1234;
        s.dram.bus_busy_cycles = 98.75;
        s.finished = true;
        s
    }

    #[test]
    fn put_get_roundtrip_bit_identical() {
        let dir = tmp_store("roundtrip");
        let store = RunStore::open(&dir).unwrap();
        let (key, stats) = (a_key(), a_stats());
        assert_eq!(store.get(&key), None);
        store.put(&key, &stats).unwrap();
        assert_eq!(store.get(&key), Some(stats));
        let c = store.counters();
        assert_eq!((c.puts, c.warm_hits, c.misses, c.quarantined), (1, 1, 1, 0));

        // A fresh open over the same directory sees the entry.
        let store2 = RunStore::open(&dir).unwrap();
        assert_eq!(store2.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_quarantines() {
        let dir = tmp_store("trunc");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        let path = store.entry_path(&key);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();

        assert_eq!(store.get(&key), None, "truncated entry must read as a miss");
        assert_eq!(store.counters().quarantined, 1);
        assert!(!path.exists(), "bad entry must be renamed aside");
        // Recompute + re-put heals the slot.
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_quarantines_even_with_valid_checksum() {
        let dir = tmp_store("version");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        let path = store.entry_path(&key);
        let mut bytes = fs::read(&path).unwrap();
        // Bump the version field and recompute the checksum so *only* the
        // version check can reject it.
        bytes[8..12].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        fs::write(&path, &bytes).unwrap();

        assert_eq!(store.get(&key), None);
        assert_eq!(store.counters().quarantined, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_mismatch_quarantines() {
        let dir = tmp_store("keymatch");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        // Copy the (valid) entry onto a different key's path — e.g. a
        // file restored to the wrong name.
        let other: JobKey = ("SLA", "Base", 0x1111, 0.01f64.to_bits(), 0);
        fs::copy(store.entry_path(&key), store.entry_path(&other)).unwrap();

        assert_eq!(store.get(&other), None, "entry for another key must never be served");
        assert_eq!(store.counters().quarantined, 1);
        assert_eq!(store.get(&key), Some(a_stats()), "original entry unaffected");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_cleans_stale_temp_files() {
        let dir = tmp_store("temps");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("x.run.tmp.999.0"), b"half-written junk").unwrap();
        fs::write(dir.join("y.run.tmp.999.1"), b"").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.counters().temp_cleaned, 2);
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_fault_is_quarantined_on_read() {
        let dir = tmp_store("torn");
        let fault = Arc::new(FaultPlan::parse("torn_write_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(Arc::clone(&fault));
        let key = a_key();
        store.put(&key, &a_stats()).unwrap(); // "succeeds" like a crash would
        assert_eq!(fault.injected(), 1);
        assert_eq!(store.get(&key), None, "torn entry must not parse");
        assert_eq!(store.counters().quarantined, 1);
        // Second put has no fault scheduled; store heals.
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_flip_fault_is_quarantined_on_read() {
        let dir = tmp_store("flip");
        let fault = Arc::new(FaultPlan::parse("flip_checksum_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(fault);
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), None, "checksum-flipped entry must not parse");
        assert_eq!(store.counters().quarantined, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    fn key_n(i: u64) -> JobKey {
        ("SLA", "CABA-BDI", 0xdead_beef_0000_0000 + i, 0.01f64.to_bits(), 0)
    }

    #[test]
    fn budget_evicts_lru_and_never_exceeds() {
        let dir = tmp_store("budget");
        let entry_len = encode_entry(&key_n(0), &a_stats()).len() as u64;
        // Room for exactly two entries.
        let policy = CapacityPolicy { max_bytes: entry_len * 2, ..Default::default() };
        let store = RunStore::open_with(&dir, policy).unwrap();
        store.put(&key_n(0), &a_stats()).unwrap();
        store.put(&key_n(1), &a_stats()).unwrap();
        assert_eq!(store.counters().evicted, 0);
        assert!(store.disk_bytes() <= policy.max_bytes);

        // Touch key 0 so key 1 becomes the LRU victim.
        assert!(store.get(&key_n(0)).is_some());
        store.put(&key_n(2), &a_stats()).unwrap();
        let c = store.counters();
        assert_eq!((c.evicted, c.evicted_bytes), (1, entry_len));
        assert!(store.disk_bytes() <= policy.max_bytes);
        assert!(store.get(&key_n(0)).is_some(), "recently-touched entry survives");
        assert!(store.get(&key_n(2)).is_some(), "newest entry survives");
        assert!(store.get(&key_n(1)).is_none(), "LRU entry was evicted");
        // Eviction is observation-only: recompute + re-put returns
        // bit-identical stats.
        store.put(&key_n(1), &a_stats()).unwrap();
        assert_eq!(store.get(&key_n(1)), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_scan_seeds_lru_from_mtime_and_enforces_budget() {
        let dir = tmp_store("scan");
        let entry_len = encode_entry(&key_n(0), &a_stats()).len() as u64;
        // Unbounded first open writes three entries...
        let store = RunStore::open(&dir).unwrap();
        for i in 0..3 {
            store.put(&key_n(i), &a_stats()).unwrap();
        }
        drop(store);
        // ...then a budgeted re-open must scan the manifest and evict
        // down to the two newest.
        let policy = CapacityPolicy { max_bytes: entry_len * 2, ..Default::default() };
        let store = RunStore::open_with(&dir, policy).unwrap();
        assert_eq!(store.counters().evicted, 1);
        assert!(store.disk_bytes() <= policy.max_bytes);
        assert_eq!(store.len(), 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn oversize_entry_is_compute_without_caching() {
        let dir = tmp_store("oversize");
        let policy = CapacityPolicy { max_bytes: 16, ..Default::default() };
        let store = RunStore::open_with(&dir, policy).unwrap();
        store.put(&key_n(0), &a_stats()).unwrap();
        let c = store.counters();
        assert_eq!((c.puts, c.put_uncached, c.put_errors), (0, 1, 0));
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn quarantine_gc_keeps_newest_k() {
        let dir = tmp_store("qgc");
        fs::create_dir_all(&dir).unwrap();
        for i in 0..6 {
            fs::write(dir.join(format!("x{i}.run.quarantined.999.{i}")), b"junk").unwrap();
        }
        let policy = CapacityPolicy { quarantine_keep: 2, ..Default::default() };
        let store = RunStore::open_with(&dir, policy).unwrap();
        assert_eq!(store.counters().quarantine_gced, 4);
        let left = fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains(QUARANTINE_MARK))
            .count();
        assert_eq!(left, 2);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn enospc_fault_is_counted_nonfatal_put_error() {
        let dir = tmp_store("enospc");
        let fault = Arc::new(FaultPlan::parse("enospc_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(Arc::clone(&fault));
        assert!(store.put(&key_n(0), &a_stats()).is_err());
        assert_eq!(fault.injected(), 1);
        assert_eq!(store.counters().put_errors, 1);
        assert_eq!(store.len(), 0, "nothing reaches disk on ENOSPC");
        // Next put succeeds — the store heals.
        store.put(&key_n(0), &a_stats()).unwrap();
        assert_eq!(store.get(&key_n(0)), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn eio_read_fault_is_miss_without_quarantine() {
        let dir = tmp_store("eio");
        let fault = Arc::new(FaultPlan::parse("eio_read_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(Arc::clone(&fault));
        store.put(&key_n(0), &a_stats()).unwrap();
        assert_eq!(store.get(&key_n(0)), None, "injected EIO reads as a miss");
        let c = store.counters();
        assert_eq!((c.read_faults, c.quarantined), (1, 0));
        // The healthy file is untouched: the next read serves it.
        assert_eq!(store.get(&key_n(0)), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn compact_step_quarantines_rot_and_reconciles_index() {
        let dir = tmp_store("compact");
        let store = RunStore::open(&dir).unwrap();
        store.put(&key_n(0), &a_stats()).unwrap();
        store.put(&key_n(1), &a_stats()).unwrap();
        // Rot entry 0 behind the store's back; delete entry 1 externally.
        let p0 = store.entry_path(&key_n(0));
        let mut bytes = fs::read(&p0).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        fs::write(&p0, &bytes).unwrap();
        fs::remove_file(store.entry_path(&key_n(1))).unwrap();

        // Enough steps to cover the whole dir.
        store.compact_step();
        store.compact_step();
        let c = store.counters();
        assert!(c.compact_steps >= 2);
        assert_eq!(c.quarantined, 1, "rotted entry quarantined proactively");
        assert!(!p0.exists());
        assert_eq!(store.disk_bytes(), 0, "index reconciled with disk truth");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_same_key_converge() {
        let dir = tmp_store("race");
        let store = Arc::new(RunStore::open(&dir).unwrap());
        let key = a_key();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let store = Arc::clone(&store);
                s.spawn(move || store.put(&key, &a_stats()).unwrap());
            }
        });
        assert_eq!(store.get(&key), Some(a_stats()));
        assert_eq!(store.len(), 1, "same key, same bytes: one entry, no temp litter");
        let _ = fs::remove_dir_all(&dir);
    }
}
