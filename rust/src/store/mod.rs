//! # The crash-safe on-disk run store
//!
//! The sweep engine's [`crate::sweep::RunCache`] is sharded but
//! in-process: it dies with the run, so every `caba fig` invocation and
//! every serve-daemon restart re-simulates from scratch. This module
//! promotes it to a **persistent content-addressed store**: one file per
//! completed run, keyed by the existing [`crate::sweep::JobKey`]
//! (app, design, full-config fingerprint, scale bits, trace digest).
//! Because the key already digests *every* simulated parameter — and the
//! sweep constructors strip run-control knobs like `trace_record` and the
//! telemetry settings — a store entry is valid forever: same key, same
//! bit-identical [`SimStats`], across processes and PRs.
//!
//! ## Durability contract
//!
//! Writes are **atomic or invisible**:
//!
//! 1. encode the full entry (header + payload + checksum) in memory;
//! 2. write it to `<name>.tmp.<pid>.<seq>` in the store directory;
//! 3. `fsync` the temp file;
//! 4. atomically `rename` onto the final `<name>.run` path;
//! 5. `fsync` the directory so the rename itself is durable.
//!
//! A `kill -9` at any point leaves either the old state or a stale
//! `*.tmp.*` file, which [`RunStore::open`] deletes (counted as
//! `temp_cleaned`) — never a half-written entry under the final name.
//!
//! ## Read-side skepticism
//!
//! The store trusts nothing it reads. Every entry carries a magic tag, a
//! format version, the full key it was written under, and an FNV-1a64
//! checksum over everything that precedes it. Any mismatch — truncation,
//! bit rot, a stale format version, a file renamed onto the wrong key —
//! **quarantines** the entry: it is renamed aside
//! (`<name>.quarantined.<pid>.<seq>`), counted, and reported as a miss so
//! the caller recomputes. Corruption can cost a re-simulation; it can
//! never produce wrong stats, and it is never fatal.
//!
//! The entry payload is the bit-exact [`codec`] encoding of `SimStats`;
//! [`fault`] provides the deterministic fault-injection plans the test
//! suites and `caba bench` use to prove all of the above.

pub mod codec;
pub mod fault;

pub use codec::{decode_stats, encode_stats, fnv1a64, stats_digest};
pub use fault::{FaultPlan, PutFault};

use crate::stats::SimStats;
use crate::sweep::JobKey;
use anyhow::{bail, Context, Result};
use std::fs::{self, File};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// On-disk entry format version. Bump whenever the entry layout *or* the
/// stats payload codec changes shape — old entries then quarantine on
/// read (and are recomputed) instead of mis-parsing.
pub const STORE_VERSION: u32 = 1;

/// Entry magic: identifies run-store files regardless of name.
const MAGIC: &[u8; 8] = b"CABARUN1";

/// Extension of committed entries.
const ENTRY_EXT: &str = ".run";

/// Monotonic counters describing a store's activity since open.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Entries durably committed.
    pub puts: u64,
    /// Reads answered from a valid on-disk entry.
    pub warm_hits: u64,
    /// Reads that found no entry (including just-quarantined ones).
    pub misses: u64,
    /// Entries renamed aside because they failed validation.
    pub quarantined: u64,
    /// Stale `*.tmp.*` files removed by [`RunStore::open`].
    pub temp_cleaned: u64,
    /// Writes that failed with an I/O error (non-fatal to callers that
    /// treat the store as a cache).
    pub put_errors: u64,
}

/// A crash-safe, content-addressed `JobKey → SimStats` store rooted at
/// one directory. All methods are `&self` and thread-safe: concurrent
/// writers racing on the same key each perform an independent atomic
/// rename, and since identical keys imply bit-identical payloads, either
/// winner leaves the same bytes.
pub struct RunStore {
    dir: PathBuf,
    fault: Option<Arc<FaultPlan>>,
    seq: AtomicU64,
    puts: AtomicU64,
    warm_hits: AtomicU64,
    misses: AtomicU64,
    quarantined: AtomicU64,
    temp_cleaned: AtomicU64,
    put_errors: AtomicU64,
}

impl RunStore {
    /// Open (creating if needed) a store at `dir`, sweeping any stale
    /// temp files left by crashed writers.
    pub fn open(dir: impl Into<PathBuf>) -> Result<RunStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("run store: create {}", dir.display()))?;
        let store = RunStore {
            dir,
            fault: None,
            seq: AtomicU64::new(0),
            puts: AtomicU64::new(0),
            warm_hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            quarantined: AtomicU64::new(0),
            temp_cleaned: AtomicU64::new(0),
            put_errors: AtomicU64::new(0),
        };
        store.clean_stale_temps()?;
        Ok(store)
    }

    /// Attach a fault-injection plan (tests, `caba bench`, `caba serve
    /// --fault`). Store writes then consult [`FaultPlan::on_put`].
    pub fn with_fault(mut self, fault: Arc<FaultPlan>) -> RunStore {
        self.fault = Some(fault);
        self
    }

    /// The directory this store persists into.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Snapshot of the activity counters.
    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            puts: self.puts.load(Ordering::Relaxed),
            warm_hits: self.warm_hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            temp_cleaned: self.temp_cleaned.load(Ordering::Relaxed),
            put_errors: self.put_errors.load(Ordering::Relaxed),
        }
    }

    /// Committed entries currently on disk (diagnostics/tests; excludes
    /// quarantined and temp files).
    pub fn len(&self) -> usize {
        let Ok(rd) = fs::read_dir(&self.dir) else { return 0 };
        rd.filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().ends_with(ENTRY_EXT))
            .count()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Look up `key`. `None` means "recompute" — covers both a genuinely
    /// missing entry and one that failed validation (which is quarantined
    /// as a side effect). Never returns stats that failed any check.
    pub fn get(&self, key: &JobKey) -> Option<SimStats> {
        let path = self.entry_path(key);
        let bytes = match fs::read(&path) {
            Ok(b) => b,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
            Err(_) => {
                // Unreadable (permissions, I/O error): treat as a miss
                // without quarantining — the file may recover.
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        match parse_entry(&bytes, key) {
            Ok(stats) => {
                self.warm_hits.fetch_add(1, Ordering::Relaxed);
                Some(stats)
            }
            Err(_) => {
                self.quarantine(&path);
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Durably store `key → stats` via the temp + fsync + rename
    /// protocol. Errors are returned (and counted) but callers treating
    /// the store as a cache may ignore them — a failed put only costs a
    /// future recompute.
    pub fn put(&self, key: &JobKey, stats: &SimStats) -> Result<()> {
        let mut bytes = encode_entry(key, stats);
        let final_path = self.entry_path(key);

        match self.fault.as_deref().map_or(PutFault::None, FaultPlan::on_put) {
            PutFault::None => {}
            PutFault::Torn => {
                // Simulated crash mid-write: a truncated prefix lands on
                // the final path directly (no temp, no fsync) and the
                // writer "dies" — reported as success, like a real crash
                // reports nothing at all.
                let _ = fs::write(&final_path, &bytes[..bytes.len() / 2]);
                return Ok(());
            }
            PutFault::FlipChecksum => {
                // Corrupt one payload byte *after* the checksum was
                // computed, then commit atomically: the entry arrives
                // whole but fails verification on read.
                let payload_byte = bytes.len() - 9; // last payload byte (before 8-byte checksum)
                bytes[payload_byte] ^= 0x01;
            }
        }

        let res = self.put_atomic(&final_path, &bytes);
        match res {
            Ok(()) => {
                self.puts.fetch_add(1, Ordering::Relaxed);
                Ok(())
            }
            Err(e) => {
                self.put_errors.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    fn put_atomic(&self, final_path: &Path, bytes: &[u8]) -> Result<()> {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let tmp_name = format!(
            "{}.tmp.{}.{}",
            final_path.file_name().unwrap_or_default().to_string_lossy(),
            std::process::id(),
            seq
        );
        let tmp_path = self.dir.join(tmp_name);
        let write = (|| -> Result<()> {
            let mut f = File::create(&tmp_path)
                .with_context(|| format!("run store: create {}", tmp_path.display()))?;
            f.write_all(bytes).context("run store: write entry")?;
            f.sync_all().context("run store: fsync entry")?;
            drop(f);
            fs::rename(&tmp_path, final_path)
                .with_context(|| format!("run store: commit {}", final_path.display()))?;
            // Make the rename itself durable. Best-effort: some
            // filesystems reject fsync on directories — the entry is
            // still atomic, just not crash-durable there.
            if let Ok(d) = File::open(&self.dir) {
                let _ = d.sync_all();
            }
            Ok(())
        })();
        if write.is_err() {
            let _ = fs::remove_file(&tmp_path);
        }
        write
    }

    /// Rename a failed entry aside so it is preserved for inspection but
    /// never consulted again.
    fn quarantine(&self, path: &Path) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let q_name = format!(
            "{}.quarantined.{}.{}",
            path.file_name().unwrap_or_default().to_string_lossy(),
            std::process::id(),
            seq
        );
        // A concurrent quarantine of the same file can win the rename
        // race; either way the bad entry is gone from the read path.
        let _ = fs::rename(path, self.dir.join(q_name));
        self.quarantined.fetch_add(1, Ordering::Relaxed);
    }

    fn clean_stale_temps(&self) -> Result<()> {
        let rd = fs::read_dir(&self.dir)
            .with_context(|| format!("run store: scan {}", self.dir.display()))?;
        for entry in rd.filter_map(|e| e.ok()) {
            let name = entry.file_name();
            if name.to_string_lossy().contains(".tmp.")
                && fs::remove_file(entry.path()).is_ok()
            {
                self.temp_cleaned.fetch_add(1, Ordering::Relaxed);
            }
        }
        Ok(())
    }

    /// Final path of `key`'s entry. The name is human-greppable
    /// (`app__design__hexes.run`) but only advisory: the key embedded in
    /// the entry is what [`parse_entry`] actually verifies.
    fn entry_path(&self, key: &JobKey) -> PathBuf {
        let sane = |s: &str| -> String {
            s.chars()
                .map(|c| if c.is_ascii_alphanumeric() || c == '-' || c == '.' { c } else { '_' })
                .collect()
        };
        let (app, design, fp, scale, digest) = key;
        self.dir.join(format!(
            "{}__{}__{fp:016x}_{scale:016x}_{digest:016x}{ENTRY_EXT}",
            sane(app),
            sane(design)
        ))
    }
}

fn put_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Encode a complete store entry:
/// `MAGIC · version:u32 · app_len:u16 · app · design_len:u16 · design ·
/// fp:u64 · scale:u64 · digest:u64 · payload_len:u32 · payload ·
/// fnv1a64(everything preceding):u64` — all little-endian.
pub fn encode_entry(key: &JobKey, stats: &SimStats) -> Vec<u8> {
    let (app, design, fp, scale, digest) = key;
    let mut payload = Vec::with_capacity(512);
    encode_stats(stats, &mut payload);

    let mut out = Vec::with_capacity(payload.len() + 96);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, STORE_VERSION);
    put_u16(&mut out, app.len() as u16);
    out.extend_from_slice(app.as_bytes());
    put_u16(&mut out, design.len() as u16);
    out.extend_from_slice(design.as_bytes());
    put_u64(&mut out, *fp);
    put_u64(&mut out, *scale);
    put_u64(&mut out, *digest);
    put_u32(&mut out, payload.len() as u32);
    out.extend_from_slice(&payload);
    let sum = fnv1a64(&out);
    put_u64(&mut out, sum);
    out
}

/// Bounds-checked little-endian reader for the entry header.
struct EntryReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> EntryReader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.buf.len() - self.pos < n {
            bail!(
                "truncated entry: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
}

/// Validate and decode an entry read from disk, in strictly escalating
/// order of trust: magic → version → checksum → embedded-key match →
/// payload decode → exact-length consumption. Any failure is corruption
/// (or a stale format) and the caller quarantines the file.
pub fn parse_entry(bytes: &[u8], key: &JobKey) -> Result<SimStats> {
    let mut r = EntryReader { buf: bytes, pos: 0 };
    if r.take(MAGIC.len())? != MAGIC {
        bail!("bad magic: not a run-store entry");
    }
    let version = r.u32()?;
    if version != STORE_VERSION {
        bail!("entry version {version}, this build reads {STORE_VERSION}");
    }
    if bytes.len() < r.pos + 8 {
        bail!("truncated entry: missing checksum");
    }
    let body = &bytes[..bytes.len() - 8];
    let stored_sum = u64::from_le_bytes(bytes[bytes.len() - 8..].try_into().unwrap());
    let actual_sum = fnv1a64(body);
    if stored_sum != actual_sum {
        bail!("checksum mismatch: stored {stored_sum:016x}, computed {actual_sum:016x}");
    }

    let app_len = r.u16()? as usize;
    let app = r.take(app_len)?;
    let design_len = r.u16()? as usize;
    let design = r.take(design_len)?;
    let fp = r.u64()?;
    let scale = r.u64()?;
    let digest = r.u64()?;
    let (want_app, want_design, want_fp, want_scale, want_digest) = key;
    if app != want_app.as_bytes()
        || design != want_design.as_bytes()
        || fp != *want_fp
        || scale != *want_scale
        || digest != *want_digest
    {
        bail!(
            "key mismatch: entry written for ({}, {}), requested ({want_app}, {want_design})",
            String::from_utf8_lossy(app),
            String::from_utf8_lossy(design),
        );
    }

    let payload_len = r.u32()? as usize;
    let payload = r.take(payload_len)?;
    if r.pos != body.len() {
        bail!("corrupt entry: {} stray bytes between payload and checksum", body.len() - r.pos);
    }
    decode_stats(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_store(name: &str) -> PathBuf {
        let p = std::env::temp_dir().join(format!("caba_store_{}_{name}", std::process::id()));
        let _ = fs::remove_dir_all(&p);
        p
    }

    fn a_key() -> JobKey {
        ("SLA", "CABA-BDI", 0xdead_beef_0000_0001, 0.01f64.to_bits(), 0)
    }

    fn a_stats() -> SimStats {
        let mut s = SimStats::default();
        s.cycles = 42_000;
        s.warp_insts = 1234;
        s.dram.bus_busy_cycles = 98.75;
        s.finished = true;
        s
    }

    #[test]
    fn put_get_roundtrip_bit_identical() {
        let dir = tmp_store("roundtrip");
        let store = RunStore::open(&dir).unwrap();
        let (key, stats) = (a_key(), a_stats());
        assert_eq!(store.get(&key), None);
        store.put(&key, &stats).unwrap();
        assert_eq!(store.get(&key), Some(stats));
        let c = store.counters();
        assert_eq!((c.puts, c.warm_hits, c.misses, c.quarantined), (1, 1, 1, 0));

        // A fresh open over the same directory sees the entry.
        let store2 = RunStore::open(&dir).unwrap();
        assert_eq!(store2.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_quarantines() {
        let dir = tmp_store("trunc");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        let path = store.entry_path(&key);
        let bytes = fs::read(&path).unwrap();
        fs::write(&path, &bytes[..bytes.len() / 3]).unwrap();

        assert_eq!(store.get(&key), None, "truncated entry must read as a miss");
        assert_eq!(store.counters().quarantined, 1);
        assert!(!path.exists(), "bad entry must be renamed aside");
        // Recompute + re-put heals the slot.
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_mismatch_quarantines_even_with_valid_checksum() {
        let dir = tmp_store("version");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        let path = store.entry_path(&key);
        let mut bytes = fs::read(&path).unwrap();
        // Bump the version field and recompute the checksum so *only* the
        // version check can reject it.
        bytes[8..12].copy_from_slice(&(STORE_VERSION + 1).to_le_bytes());
        let body_len = bytes.len() - 8;
        let sum = fnv1a64(&bytes[..body_len]);
        bytes[body_len..].copy_from_slice(&sum.to_le_bytes());
        fs::write(&path, &bytes).unwrap();

        assert_eq!(store.get(&key), None);
        assert_eq!(store.counters().quarantined, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn key_mismatch_quarantines() {
        let dir = tmp_store("keymatch");
        let store = RunStore::open(&dir).unwrap();
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        // Copy the (valid) entry onto a different key's path — e.g. a
        // file restored to the wrong name.
        let other: JobKey = ("SLA", "Base", 0x1111, 0.01f64.to_bits(), 0);
        fs::copy(store.entry_path(&key), store.entry_path(&other)).unwrap();

        assert_eq!(store.get(&other), None, "entry for another key must never be served");
        assert_eq!(store.counters().quarantined, 1);
        assert_eq!(store.get(&key), Some(a_stats()), "original entry unaffected");
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn open_cleans_stale_temp_files() {
        let dir = tmp_store("temps");
        fs::create_dir_all(&dir).unwrap();
        fs::write(dir.join("x.run.tmp.999.0"), b"half-written junk").unwrap();
        fs::write(dir.join("y.run.tmp.999.1"), b"").unwrap();
        let store = RunStore::open(&dir).unwrap();
        assert_eq!(store.counters().temp_cleaned, 2);
        assert_eq!(store.len(), 0);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_write_fault_is_quarantined_on_read() {
        let dir = tmp_store("torn");
        let fault = Arc::new(FaultPlan::parse("torn_write_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(Arc::clone(&fault));
        let key = a_key();
        store.put(&key, &a_stats()).unwrap(); // "succeeds" like a crash would
        assert_eq!(fault.injected(), 1);
        assert_eq!(store.get(&key), None, "torn entry must not parse");
        assert_eq!(store.counters().quarantined, 1);
        // Second put has no fault scheduled; store heals.
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), Some(a_stats()));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn checksum_flip_fault_is_quarantined_on_read() {
        let dir = tmp_store("flip");
        let fault = Arc::new(FaultPlan::parse("flip_checksum_at=0").unwrap());
        let store = RunStore::open(&dir).unwrap().with_fault(fault);
        let key = a_key();
        store.put(&key, &a_stats()).unwrap();
        assert_eq!(store.get(&key), None, "checksum-flipped entry must not parse");
        assert_eq!(store.counters().quarantined, 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn concurrent_writers_same_key_converge() {
        let dir = tmp_store("race");
        let store = Arc::new(RunStore::open(&dir).unwrap());
        let key = a_key();
        std::thread::scope(|s| {
            for _ in 0..8 {
                let store = Arc::clone(&store);
                s.spawn(move || store.put(&key, &a_stats()).unwrap());
            }
        });
        assert_eq!(store.get(&key), Some(a_stats()));
        assert_eq!(store.len(), 1, "same key, same bytes: one entry, no temp litter");
        let _ = fs::remove_dir_all(&dir);
    }
}
