//! Deterministic fault injection for the store and the sweep/serve
//! execution paths.
//!
//! A [`FaultPlan`] names *which* operation fails and *how*: the Nth store
//! write is torn or checksum-flipped, the Nth executed job panics or
//! stalls. Indices are 0-based over the lifetime of the plan and counted
//! with atomics, so a plan shared across worker threads still fires
//! exactly once, at a deterministic global index — the fault-injection
//! suites (`tests/store_faults.rs`, `tests/serve_faults.rs`), the
//! `caba bench` serve family and the CI `serve-smoke` job all drive the
//! same plans and assert the daemon survives every one of them.
//!
//! Faults are *silent at the injection site* by design: a torn write
//! returns `Ok` exactly like a real `kill -9` mid-write would leave no
//! error behind. The contract under test is that the *read* side
//! quarantines the damage and the *execution* side converts the panic
//! into a typed [`crate::sweep::JobError`] — never wrong data, never a
//! process abort.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// What [`FaultPlan::on_put`] tells the store to do to this write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PutFault {
    /// Write normally (temp file + fsync + atomic rename).
    None,
    /// Simulate a crash mid-write: only a truncated prefix of the entry
    /// reaches the final path, bypassing the atomic-rename protocol (a
    /// stand-in for pre-protocol writers and disk-level damage).
    Torn,
    /// Flip one payload bit *after* the checksum is computed, then write
    /// atomically — the entry lands complete but fails verification.
    FlipChecksum,
    /// Fail the write with an ENOSPC-style error before anything reaches
    /// disk. The store counts it as a `put_error`; callers that treat the
    /// store as a cache degrade to compute-without-caching.
    Enospc,
}

/// A deterministic fault schedule. Construct with [`FaultPlan::parse`]
/// (`key=value` comma list, the repo's offline-friendly config idiom) or
/// build in tests via [`FaultPlan::default`] plus the `*_at` fields.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Tear the Nth (0-based) store write.
    pub torn_write_at: Option<u64>,
    /// Corrupt the Nth store write so its checksum fails on read.
    pub flip_checksum_at: Option<u64>,
    /// Panic inside the Nth executed sweep job (caught by the engine and
    /// surfaced as a typed `JobError`).
    pub panic_at_job: Option<u64>,
    /// Stall the Nth executed sweep job for [`FaultPlan::slow_job_ms`].
    pub slow_at_job: Option<u64>,
    /// Stall duration for `slow_at_job` (default 500 ms when unset).
    pub slow_job_ms: u64,
    /// Fail the Nth (0-based) store write with an ENOSPC-style error.
    pub enospc_at: Option<u64>,
    /// Fail the Nth (0-based) store read with an EIO-style error: the
    /// read is reported as a miss *without* quarantining the (healthy)
    /// file, so the caller recomputes and the re-put heals the slot.
    pub eio_read_at: Option<u64>,
    /// Stall every store fsync by this many milliseconds (a latency
    /// shaping knob for a degraded disk, not a discrete fault — it does
    /// not count toward [`FaultPlan::injected`]).
    pub slow_fsync_ms: u64,
    /// Drop the Nth (0-based) response connection: the daemon closes the
    /// stream without writing an answer, exactly like a mid-flight
    /// network/peer failure. The client must classify the EOF as
    /// retryable and converge on a later attempt.
    pub drop_conn_at: Option<u64>,

    puts_seen: AtomicU64,
    jobs_seen: AtomicU64,
    reads_seen: AtomicU64,
    responds_seen: AtomicU64,
    injected: AtomicU64,
}

impl FaultPlan {
    /// Parse a plan from a comma-separated `key=value` spec, e.g.
    /// `panic_at_job=2,torn_write_at=0,slow_at_job=5,slow_job_ms=250`.
    /// Unknown keys fail loudly — a typo'd fault spec that silently
    /// injects nothing would make the harness lie.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((k, v)) = part.split_once('=') else {
                bail!("fault spec {part:?} is not key=value");
            };
            let n: u64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {k}: bad value {v:?}"))?;
            match k.trim() {
                "torn_write_at" => plan.torn_write_at = Some(n),
                "flip_checksum_at" => plan.flip_checksum_at = Some(n),
                "panic_at_job" => plan.panic_at_job = Some(n),
                "slow_at_job" => plan.slow_at_job = Some(n),
                "slow_job_ms" => plan.slow_job_ms = n,
                "enospc_at" => plan.enospc_at = Some(n),
                "eio_read_at" => plan.eio_read_at = Some(n),
                "slow_fsync_ms" => plan.slow_fsync_ms = n,
                "drop_conn_at" => plan.drop_conn_at = Some(n),
                other => bail!(
                    "unknown fault key {other:?} (torn_write_at|flip_checksum_at|panic_at_job|\
                     slow_at_job|slow_job_ms|enospc_at|eio_read_at|slow_fsync_ms|drop_conn_at)"
                ),
            }
        }
        Ok(plan)
    }

    /// Total faults actually fired so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Called by the store before each write; returns the fault (if any)
    /// scheduled for this write index.
    pub fn on_put(&self) -> PutFault {
        let i = self.puts_seen.fetch_add(1, Ordering::Relaxed);
        if self.torn_write_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return PutFault::Torn;
        }
        if self.flip_checksum_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return PutFault::FlipChecksum;
        }
        if self.enospc_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return PutFault::Enospc;
        }
        PutFault::None
    }

    /// Called by the store before each read; `true` means this read
    /// fails with a simulated I/O error (reported as a miss, no
    /// quarantine — the file itself is healthy).
    pub fn on_read(&self) -> bool {
        let i = self.reads_seen.fetch_add(1, Ordering::Relaxed);
        if self.eio_read_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Per-fsync stall in milliseconds (0 = none). Applied by the store
    /// around every durable write while the plan is attached.
    pub fn fsync_stall_ms(&self) -> u64 {
        self.slow_fsync_ms
    }

    /// Called by the serve daemon before writing each response; `true`
    /// means the connection is dropped without an answer.
    pub fn on_respond(&self) -> bool {
        let i = self.responds_seen.fetch_add(1, Ordering::Relaxed);
        if self.drop_conn_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }

    /// Called by the sweep engine immediately before executing a job.
    /// May sleep (slow-job fault) or panic (worker-panic fault — the
    /// caller's `catch_unwind` turns it into a `JobError`).
    pub fn before_job(&self, app: &str, design: &str) {
        let i = self.jobs_seen.fetch_add(1, Ordering::Relaxed);
        if self.slow_at_job == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            let ms = if self.slow_job_ms == 0 { 500 } else { self.slow_job_ms };
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        if self.panic_at_job == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            panic!("injected fault: worker panic at job {i} ({app}, {design})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let p = FaultPlan::parse("panic_at_job=2, torn_write_at=0,slow_job_ms=50").unwrap();
        assert_eq!(p.panic_at_job, Some(2));
        assert_eq!(p.torn_write_at, Some(0));
        assert_eq!(p.slow_job_ms, 50);
        assert_eq!(p.flip_checksum_at, None);
        assert!(FaultPlan::parse("panic_at_job").is_err());
        assert!(FaultPlan::parse("nope=1").is_err());
        assert!(FaultPlan::parse("panic_at_job=x").is_err());
        // Empty spec = no faults.
        assert_eq!(FaultPlan::parse("").unwrap().injected(), 0);
    }

    #[test]
    fn put_faults_fire_once_at_index() {
        let p = FaultPlan::parse("torn_write_at=1").unwrap();
        assert_eq!(p.on_put(), PutFault::None);
        assert_eq!(p.on_put(), PutFault::Torn);
        assert_eq!(p.on_put(), PutFault::None);
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn chaos_keys_parse_and_fire_once() {
        let p = FaultPlan::parse("enospc_at=1,eio_read_at=0,slow_fsync_ms=7,drop_conn_at=2")
            .unwrap();
        assert_eq!(p.fsync_stall_ms(), 7);
        // Put index 0 clean, index 1 ENOSPC, index 2 clean again.
        assert_eq!(p.on_put(), PutFault::None);
        assert_eq!(p.on_put(), PutFault::Enospc);
        assert_eq!(p.on_put(), PutFault::None);
        // Read index 0 fails, later reads succeed.
        assert!(p.on_read());
        assert!(!p.on_read());
        // Response connections 0 and 1 survive, 2 is dropped.
        assert!(!p.on_respond());
        assert!(!p.on_respond());
        assert!(p.on_respond());
        assert!(!p.on_respond());
        // ENOSPC + EIO + conn-drop; the fsync stall is shaping, not a
        // discrete fault, so it never counts.
        assert_eq!(p.injected(), 3);
    }

    #[test]
    fn job_panic_fires_at_index() {
        let p = FaultPlan::parse("panic_at_job=1").unwrap();
        p.before_job("A", "Base"); // job 0: no fault
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.before_job("A", "Base")
        }));
        assert!(caught.is_err());
        assert_eq!(p.injected(), 1);
        p.before_job("A", "Base"); // job 2: no fault again
    }
}
