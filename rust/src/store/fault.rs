//! Deterministic fault injection for the store and the sweep/serve
//! execution paths.
//!
//! A [`FaultPlan`] names *which* operation fails and *how*: the Nth store
//! write is torn or checksum-flipped, the Nth executed job panics or
//! stalls. Indices are 0-based over the lifetime of the plan and counted
//! with atomics, so a plan shared across worker threads still fires
//! exactly once, at a deterministic global index — the fault-injection
//! suites (`tests/store_faults.rs`, `tests/serve_faults.rs`), the
//! `caba bench` serve family and the CI `serve-smoke` job all drive the
//! same plans and assert the daemon survives every one of them.
//!
//! Faults are *silent at the injection site* by design: a torn write
//! returns `Ok` exactly like a real `kill -9` mid-write would leave no
//! error behind. The contract under test is that the *read* side
//! quarantines the damage and the *execution* side converts the panic
//! into a typed [`crate::sweep::JobError`] — never wrong data, never a
//! process abort.

use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};

/// What [`FaultPlan::on_put`] tells the store to do to this write.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PutFault {
    /// Write normally (temp file + fsync + atomic rename).
    None,
    /// Simulate a crash mid-write: only a truncated prefix of the entry
    /// reaches the final path, bypassing the atomic-rename protocol (a
    /// stand-in for pre-protocol writers and disk-level damage).
    Torn,
    /// Flip one payload bit *after* the checksum is computed, then write
    /// atomically — the entry lands complete but fails verification.
    FlipChecksum,
}

/// A deterministic fault schedule. Construct with [`FaultPlan::parse`]
/// (`key=value` comma list, the repo's offline-friendly config idiom) or
/// build in tests via [`FaultPlan::default`] plus the `*_at` fields.
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// Tear the Nth (0-based) store write.
    pub torn_write_at: Option<u64>,
    /// Corrupt the Nth store write so its checksum fails on read.
    pub flip_checksum_at: Option<u64>,
    /// Panic inside the Nth executed sweep job (caught by the engine and
    /// surfaced as a typed `JobError`).
    pub panic_at_job: Option<u64>,
    /// Stall the Nth executed sweep job for [`FaultPlan::slow_job_ms`].
    pub slow_at_job: Option<u64>,
    /// Stall duration for `slow_at_job` (default 500 ms when unset).
    pub slow_job_ms: u64,

    puts_seen: AtomicU64,
    jobs_seen: AtomicU64,
    injected: AtomicU64,
}

impl FaultPlan {
    /// Parse a plan from a comma-separated `key=value` spec, e.g.
    /// `panic_at_job=2,torn_write_at=0,slow_at_job=5,slow_job_ms=250`.
    /// Unknown keys fail loudly — a typo'd fault spec that silently
    /// injects nothing would make the harness lie.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((k, v)) = part.split_once('=') else {
                bail!("fault spec {part:?} is not key=value");
            };
            let n: u64 = v
                .trim()
                .parse()
                .map_err(|_| anyhow::anyhow!("fault spec {k}: bad value {v:?}"))?;
            match k.trim() {
                "torn_write_at" => plan.torn_write_at = Some(n),
                "flip_checksum_at" => plan.flip_checksum_at = Some(n),
                "panic_at_job" => plan.panic_at_job = Some(n),
                "slow_at_job" => plan.slow_at_job = Some(n),
                "slow_job_ms" => plan.slow_job_ms = n,
                other => bail!(
                    "unknown fault key {other:?} (torn_write_at|flip_checksum_at|panic_at_job|slow_at_job|slow_job_ms)"
                ),
            }
        }
        Ok(plan)
    }

    /// Total faults actually fired so far.
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::Relaxed)
    }

    /// Called by the store before each write; returns the fault (if any)
    /// scheduled for this write index.
    pub fn on_put(&self) -> PutFault {
        let i = self.puts_seen.fetch_add(1, Ordering::Relaxed);
        if self.torn_write_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return PutFault::Torn;
        }
        if self.flip_checksum_at == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            return PutFault::FlipChecksum;
        }
        PutFault::None
    }

    /// Called by the sweep engine immediately before executing a job.
    /// May sleep (slow-job fault) or panic (worker-panic fault — the
    /// caller's `catch_unwind` turns it into a `JobError`).
    pub fn before_job(&self, app: &str, design: &str) {
        let i = self.jobs_seen.fetch_add(1, Ordering::Relaxed);
        if self.slow_at_job == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            let ms = if self.slow_job_ms == 0 { 500 } else { self.slow_job_ms };
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
        if self.panic_at_job == Some(i) {
            self.injected.fetch_add(1, Ordering::Relaxed);
            panic!("injected fault: worker panic at job {i} ({app}, {design})");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let p = FaultPlan::parse("panic_at_job=2, torn_write_at=0,slow_job_ms=50").unwrap();
        assert_eq!(p.panic_at_job, Some(2));
        assert_eq!(p.torn_write_at, Some(0));
        assert_eq!(p.slow_job_ms, 50);
        assert_eq!(p.flip_checksum_at, None);
        assert!(FaultPlan::parse("panic_at_job").is_err());
        assert!(FaultPlan::parse("nope=1").is_err());
        assert!(FaultPlan::parse("panic_at_job=x").is_err());
        // Empty spec = no faults.
        assert_eq!(FaultPlan::parse("").unwrap().injected(), 0);
    }

    #[test]
    fn put_faults_fire_once_at_index() {
        let p = FaultPlan::parse("torn_write_at=1").unwrap();
        assert_eq!(p.on_put(), PutFault::None);
        assert_eq!(p.on_put(), PutFault::Torn);
        assert_eq!(p.on_put(), PutFault::None);
        assert_eq!(p.injected(), 1);
    }

    #[test]
    fn job_panic_fires_at_index() {
        let p = FaultPlan::parse("panic_at_job=1").unwrap();
        p.before_job("A", "Base"); // job 0: no fault
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            p.before_job("A", "Base")
        }));
        assert!(caught.is_err());
        assert_eq!(p.injected(), 1);
        p.before_job("A", "Base"); // job 2: no fault again
    }
}
