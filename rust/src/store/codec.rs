//! Bit-exact binary codec for [`SimStats`] — the payload format of the
//! on-disk run store.
//!
//! Every field is written in declaration order as fixed-width
//! little-endian words (`u64`, `f64` by bit pattern, `bool` as one byte),
//! so `decode(encode(s)) == s` holds *bit-identically* — including the
//! `f64` bus-busy counter, which round-trips through `to_bits`/`from_bits`
//! rather than any textual form. The encoder destructures [`SimStats`] and
//! every sub-struct exhaustively: adding a field to any of them is a
//! compile error here, which is the prompt to bump
//! [`super::STORE_VERSION`] (old entries then quarantine instead of
//! mis-parsing).
//!
//! A `tests/proptests.rs` property pins the round-trip over randomized
//! stats; `tests/store_faults.rs` pins the failure paths (truncation never
//! mis-parses, always errors).

use crate::stats::{
    CabaStats, CacheStats, DramStats, EnergyEvents, IcntStats, IssueBreakdown, MdCacheStats,
    SimStats, TraceStats,
};
use anyhow::{bail, Result};

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    put_u64(out, v.to_bits());
}

/// A bounds-checked little-endian reader over the payload bytes.
pub struct StatsReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> StatsReader<'a> {
    pub fn new(buf: &'a [u8]) -> StatsReader<'a> {
        StatsReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn u64(&mut self) -> Result<u64> {
        if self.remaining() < 8 {
            bail!(
                "truncated stats payload: need 8 bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            );
        }
        let mut b = [0u8; 8];
        b.copy_from_slice(&self.buf[self.pos..self.pos + 8]);
        self.pos += 8;
        Ok(u64::from_le_bytes(b))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bool(&mut self) -> Result<bool> {
        if self.remaining() < 1 {
            bail!("truncated stats payload: missing trailing bool at offset {}", self.pos);
        }
        let b = self.buf[self.pos];
        self.pos += 1;
        match b {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("corrupt stats payload: bool byte is {other}, not 0/1"),
        }
    }
}

/// Serialize a full [`SimStats`] into `out`. Exhaustive destructuring —
/// see the module docs for why.
pub fn encode_stats(s: &SimStats, out: &mut Vec<u8>) {
    let SimStats {
        cycles,
        warp_insts,
        thread_insts,
        issue,
        l1,
        l2,
        dram,
        icnt,
        caba,
        md,
        energy_events,
        trace,
        ctas_launched,
        finished,
    } = s;
    put_u64(out, *cycles);
    put_u64(out, *warp_insts);
    put_u64(out, *thread_insts);
    let IssueBreakdown { active, compute_stall, memory_stall, data_stall, idle } = issue;
    for v in [active, compute_stall, memory_stall, data_stall, idle] {
        put_u64(out, *v);
    }
    for cache in [l1, l2] {
        let CacheStats { accesses, hits, misses, evictions, writebacks } = cache;
        for v in [accesses, hits, misses, evictions, writebacks] {
            put_u64(out, *v);
        }
    }
    let DramStats {
        reads,
        writes,
        row_hits,
        row_misses,
        bursts,
        bursts_uncompressed,
        bus_busy_cycles,
        md_accesses,
    } = dram;
    for v in [reads, writes, row_hits, row_misses, bursts, bursts_uncompressed] {
        put_u64(out, *v);
    }
    put_f64(out, *bus_busy_cycles);
    put_u64(out, *md_accesses);
    let IcntStats { packets_fwd, packets_back, flits_fwd, flits_back } = icnt;
    for v in [packets_fwd, packets_back, flits_fwd, flits_back] {
        put_u64(out, *v);
    }
    let CabaStats {
        decompress_warps,
        compress_warps,
        assist_insts_issued,
        assist_insts_idle_slots,
        compress_skipped,
        throttled_deploys,
        killed,
        prefetches_issued,
        memo_lookups,
        memo_hits,
        memo_alias_hits,
        memo_installs,
        memo_evictions,
        memo_lookups_skipped,
    } = caba;
    for v in [
        decompress_warps,
        compress_warps,
        assist_insts_issued,
        assist_insts_idle_slots,
        compress_skipped,
        throttled_deploys,
        killed,
        prefetches_issued,
        memo_lookups,
        memo_hits,
        memo_alias_hits,
        memo_installs,
        memo_evictions,
        memo_lookups_skipped,
    ] {
        put_u64(out, *v);
    }
    let MdCacheStats { accesses, hits } = md;
    put_u64(out, *accesses);
    put_u64(out, *hits);
    let EnergyEvents {
        core_insts,
        assist_insts,
        l1_accesses,
        l2_accesses,
        icnt_flits,
        dram_bursts,
        dram_activates,
        md_cache_accesses,
        hw_compressor_ops,
    } = energy_events;
    for v in [
        core_insts,
        assist_insts,
        l1_accesses,
        l2_accesses,
        icnt_flits,
        dram_bursts,
        dram_activates,
        md_cache_accesses,
        hw_compressor_ops,
    ] {
        put_u64(out, *v);
    }
    let TraceStats { accesses_recorded, payloads_recorded } = trace;
    put_u64(out, *accesses_recorded);
    put_u64(out, *payloads_recorded);
    put_u64(out, *ctas_launched);
    out.push(u8::from(*finished));
}

/// Deserialize a [`SimStats`] written by [`encode_stats`]. The whole
/// payload must be consumed exactly — trailing bytes are corruption, not
/// padding.
pub fn decode_stats(buf: &[u8]) -> Result<SimStats> {
    let mut r = StatsReader::new(buf);
    let mut s = SimStats {
        cycles: r.u64()?,
        warp_insts: r.u64()?,
        thread_insts: r.u64()?,
        ..SimStats::default()
    };
    s.issue = IssueBreakdown {
        active: r.u64()?,
        compute_stall: r.u64()?,
        memory_stall: r.u64()?,
        data_stall: r.u64()?,
        idle: r.u64()?,
    };
    let cache = |r: &mut StatsReader| -> Result<CacheStats> {
        Ok(CacheStats {
            accesses: r.u64()?,
            hits: r.u64()?,
            misses: r.u64()?,
            evictions: r.u64()?,
            writebacks: r.u64()?,
        })
    };
    s.l1 = cache(&mut r)?;
    s.l2 = cache(&mut r)?;
    s.dram = DramStats {
        reads: r.u64()?,
        writes: r.u64()?,
        row_hits: r.u64()?,
        row_misses: r.u64()?,
        bursts: r.u64()?,
        bursts_uncompressed: r.u64()?,
        bus_busy_cycles: r.f64()?,
        md_accesses: r.u64()?,
    };
    s.icnt = IcntStats {
        packets_fwd: r.u64()?,
        packets_back: r.u64()?,
        flits_fwd: r.u64()?,
        flits_back: r.u64()?,
    };
    s.caba = CabaStats {
        decompress_warps: r.u64()?,
        compress_warps: r.u64()?,
        assist_insts_issued: r.u64()?,
        assist_insts_idle_slots: r.u64()?,
        compress_skipped: r.u64()?,
        throttled_deploys: r.u64()?,
        killed: r.u64()?,
        prefetches_issued: r.u64()?,
        memo_lookups: r.u64()?,
        memo_hits: r.u64()?,
        memo_alias_hits: r.u64()?,
        memo_installs: r.u64()?,
        memo_evictions: r.u64()?,
        memo_lookups_skipped: r.u64()?,
    };
    s.md = MdCacheStats { accesses: r.u64()?, hits: r.u64()? };
    s.energy_events = EnergyEvents {
        core_insts: r.u64()?,
        assist_insts: r.u64()?,
        l1_accesses: r.u64()?,
        l2_accesses: r.u64()?,
        icnt_flits: r.u64()?,
        dram_bursts: r.u64()?,
        dram_activates: r.u64()?,
        md_cache_accesses: r.u64()?,
        hw_compressor_ops: r.u64()?,
    };
    s.trace = TraceStats { accesses_recorded: r.u64()?, payloads_recorded: r.u64()? };
    s.ctas_launched = r.u64()?;
    s.finished = r.bool()?;
    if r.remaining() != 0 {
        bail!("corrupt stats payload: {} trailing bytes after the last field", r.remaining());
    }
    Ok(s)
}

/// FNV-1a 64 — the store's entry checksum. Not cryptographic (the threat
/// model is torn writes and bit rot, not adversaries); chosen because it
/// is tiny, dependency-free and byte-order independent.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Content digest of a stats object: FNV over its canonical encoding.
/// The serve daemon returns this with every response so clients (and the
/// fault-injection harness) can assert bit-identity without shipping the
/// full struct.
pub fn stats_digest(s: &SimStats) -> u64 {
    let mut buf = Vec::with_capacity(512);
    encode_stats(s, &mut buf);
    fnv1a64(&buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn busy_stats() -> SimStats {
        let mut s = SimStats::default();
        s.cycles = 123_456;
        s.warp_insts = 9_876;
        s.thread_insts = 314_159;
        s.issue.active = 7;
        s.issue.idle = 11;
        s.l1.hits = 42;
        s.l2.misses = 17;
        s.dram.bursts = 1_000;
        s.dram.bursts_uncompressed = 2_000;
        s.dram.bus_busy_cycles = 1234.5678;
        s.icnt.flits_back = 5;
        s.caba.memo_hits = 99;
        s.md.accesses = 3;
        s.energy_events.hw_compressor_ops = 8;
        s.trace.accesses_recorded = 1;
        s.ctas_launched = 64;
        s.finished = true;
        s
    }

    #[test]
    fn roundtrip_is_bit_identical() {
        let s = busy_stats();
        let mut buf = Vec::new();
        encode_stats(&s, &mut buf);
        assert_eq!(decode_stats(&buf).unwrap(), s);
        // Deterministic encoding: same stats, same bytes.
        let mut buf2 = Vec::new();
        encode_stats(&s, &mut buf2);
        assert_eq!(buf, buf2);
    }

    #[test]
    fn truncation_errors_at_every_length() {
        let mut buf = Vec::new();
        encode_stats(&busy_stats(), &mut buf);
        for cut in 0..buf.len() {
            assert!(
                decode_stats(&buf[..cut]).is_err(),
                "decode of a {cut}-byte prefix must fail, not mis-parse"
            );
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut buf = Vec::new();
        encode_stats(&busy_stats(), &mut buf);
        buf.push(0);
        assert!(decode_stats(&buf).is_err());
    }

    #[test]
    fn bad_bool_rejected() {
        let mut buf = Vec::new();
        encode_stats(&busy_stats(), &mut buf);
        *buf.last_mut().unwrap() = 2;
        assert!(decode_stats(&buf).is_err());
    }

    #[test]
    fn digest_tracks_content() {
        let a = busy_stats();
        let mut b = a.clone();
        assert_eq!(stats_digest(&a), stats_digest(&b));
        b.dram.bursts += 1;
        assert_ne!(stats_digest(&a), stats_digest(&b));
    }
}
