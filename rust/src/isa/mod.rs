//! The simulator's SIMT micro-ISA.
//!
//! Workloads are expressed as warp-level μ-kernels: a loop *body* of typed
//! instructions executed for a per-warp iteration count. This is the same
//! abstraction GPGPU-Sim's performance model reduces SASS to — typed ops
//! with register dependences and memory access descriptors — without
//! functional semantics we don't need (see DESIGN.md §3: compression
//! operates on real bytes produced by the data generators, not on computed
//! values).

use std::sync::Arc;

/// Maximum architectural registers per thread the ISA addresses.
pub const MAX_REGS: usize = 64;

/// Functional-unit class of an instruction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FuKind {
    /// SP pipeline (int/fp ALU, FMA).
    Sp,
    /// Special-function unit (transcendentals — tens of cycles).
    Sfu,
    /// Load/store pipeline.
    Mem,
}

/// How a warp's 32 lanes spread over cache lines for one memory access.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AccessKind {
    /// All lanes fall in one line; consecutive warp-iterations stream
    /// through the array. `reuse` = number of consecutive iterations that
    /// touch the same line (temporal locality knob).
    Coalesced { reuse: u16 },
    /// Lanes spread over `lines` consecutive lines (uncoalesced strided
    /// access; 1 < lines ≤ 32).
    Strided { lines: u16 },
    /// Each lane hashes to an arbitrary line within the footprint
    /// (graph-style gather/scatter); `degree` = distinct lines per warp.
    Scatter { degree: u16 },
}

/// A memory operand: which array, how lanes map to lines.
#[derive(Clone, Copy, Debug)]
pub struct MemAccess {
    /// Array index into the workload's array table (base + footprint).
    pub array: u8,
    pub kind: AccessKind,
}

/// Instruction opcode.
#[derive(Clone, Copy, Debug)]
pub enum Op {
    /// Integer ALU op.
    IAlu,
    /// FP32 ALU op.
    FAlu,
    /// Fused multiply-add.
    Fma,
    /// Special-function op (sin/rsqrt/…).
    Sfu,
    /// Global load into `dst`.
    Ld(MemAccess),
    /// Global store (no dst).
    St(MemAccess),
}

impl Op {
    pub fn fu(&self) -> FuKind {
        match self {
            Op::IAlu | Op::FAlu | Op::Fma => FuKind::Sp,
            Op::Sfu => FuKind::Sfu,
            Op::Ld(_) | Op::St(_) => FuKind::Mem,
        }
    }

    pub fn is_mem(&self) -> bool {
        matches!(self, Op::Ld(_) | Op::St(_))
    }
}

/// One decoded warp instruction with register operands.
#[derive(Clone, Copy, Debug)]
pub struct Inst {
    pub op: Op,
    /// Destination register (ignored for stores).
    pub dst: u8,
    /// Source registers (`MAX_REGS as u8` = unused slot).
    pub srcs: [u8; 2],
}

pub const NO_REG: u8 = MAX_REGS as u8;

impl Inst {
    pub fn new(op: Op, dst: u8, srcs: [u8; 2]) -> Self {
        Inst { op, dst, srcs }
    }

    /// Iterate over used source registers.
    pub fn sources(&self) -> impl Iterator<Item = u8> + '_ {
        self.srcs.iter().copied().filter(|&r| r != NO_REG)
    }
}

/// A warp-level μ-kernel: `body` repeated `iters` times.
#[derive(Clone, Debug)]
pub struct Program {
    pub body: Vec<Inst>,
    pub iters: u32,
}

pub type ProgramRef = Arc<Program>;

impl Program {
    pub fn total_insts(&self) -> u64 {
        self.body.len() as u64 * self.iters as u64
    }

    /// Static per-instruction position → (iteration, body index).
    pub fn locate(&self, pc: u64) -> (u32, usize) {
        let len = self.body.len() as u64;
        ((pc / len) as u32, (pc % len) as usize)
    }

    /// Count memory instructions in the body.
    pub fn mem_insts_per_iter(&self) -> usize {
        self.body.iter().filter(|i| i.op.is_mem()).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ld(array: u8) -> Op {
        Op::Ld(MemAccess { array, kind: AccessKind::Coalesced { reuse: 1 } })
    }

    #[test]
    fn fu_classes() {
        assert_eq!(Op::IAlu.fu(), FuKind::Sp);
        assert_eq!(Op::Fma.fu(), FuKind::Sp);
        assert_eq!(Op::Sfu.fu(), FuKind::Sfu);
        assert_eq!(ld(0).fu(), FuKind::Mem);
        assert!(ld(0).is_mem());
        assert!(!Op::Fma.is_mem());
    }

    #[test]
    fn program_accounting() {
        let p = Program {
            body: vec![
                Inst::new(ld(0), 1, [NO_REG, NO_REG]),
                Inst::new(Op::Fma, 2, [1, 2]),
            ],
            iters: 10,
        };
        assert_eq!(p.total_insts(), 20);
        assert_eq!(p.mem_insts_per_iter(), 1);
        assert_eq!(p.locate(0), (0, 0));
        assert_eq!(p.locate(3), (1, 1));
        assert_eq!(p.locate(19), (9, 1));
    }

    #[test]
    fn sources_skip_unused() {
        let i = Inst::new(Op::Fma, 3, [1, NO_REG]);
        let srcs: Vec<u8> = i.sources().collect();
        assert_eq!(srcs, vec![1]);
    }
}
