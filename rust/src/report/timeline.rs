//! ASCII rendering of a [`TelemetryRun`] (`caba run --timeline`):
//! labeled sparklines for the chip-level series and a per-SM stall
//! heatmap, all plain ASCII so the output survives logs, CI artifacts
//! and terminals without Unicode fonts.
//!
//! Everything here is a pure function of the (already deterministic)
//! telemetry data — rendering twice, or rendering the timeline of a
//! different tick mode, yields byte-identical text.

use crate::stats::IssueBreakdown;
use crate::telemetry::TelemetryRun;

/// Intensity ramp, blank = zero. 9 levels keeps each step distinct in
/// every monospace font.
const RAMP: &[u8] = b" .:-=+*#@";

/// Partition `n` items into at most `width` contiguous buckets (fewer
/// when `n < width` — a short run is not stretched).
fn bucket_ranges(n: usize, width: usize) -> Vec<std::ops::Range<usize>> {
    let buckets = width.min(n);
    (0..buckets)
        .map(|b| (b * n / buckets)..((b + 1) * n / buckets))
        .collect()
}

/// Render `values` as a one-line sparkline at most `width` chars wide
/// (mean-pooled into buckets). Zero maps to blank, the maximum to `@`.
pub fn sparkline(values: &[f64], width: usize) -> String {
    if values.is_empty() || width == 0 {
        return String::new();
    }
    let pooled: Vec<f64> = bucket_ranges(values.len(), width)
        .into_iter()
        .map(|r| {
            let n = r.len().max(1);
            values[r].iter().sum::<f64>() / n as f64
        })
        .collect();
    let max = pooled.iter().cloned().fold(0.0f64, f64::max);
    pooled
        .iter()
        .map(|&v| {
            let idx = if max > 0.0 && v > 0.0 {
                // Non-zero values get at least the faintest mark.
                (((v / max) * (RAMP.len() - 1) as f64).round() as usize).max(1)
            } else {
                0
            };
            RAMP[idx.min(RAMP.len() - 1)] as char
        })
        .collect()
}

/// The dominant issue class of a window, as a heatmap cell. Ties break in
/// severity order (active beats stalls, memory beats the other stalls) so
/// the map is deterministic.
pub fn stall_char(issue: &IssueBreakdown) -> char {
    let classes = [
        (issue.active, '#'),
        (issue.memory_stall, 'm'),
        (issue.compute_stall, 'c'),
        (issue.data_stall, 'd'),
        (issue.idle, '.'),
    ];
    let max = classes.iter().map(|&(n, _)| n).max().unwrap_or(0);
    if max == 0 {
        return '.';
    }
    classes.iter().find(|&&(n, _)| n == max).unwrap().1
}

fn series_line(out: &mut String, label: &str, values: &[f64], width: usize) {
    let lo = values.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = values.iter().cloned().fold(0.0f64, f64::max);
    let lo = if lo.is_finite() { lo } else { 0.0 };
    out.push_str(&format!(
        "  {:<14} |{}| min={:.3} max={:.3}\n",
        label,
        sparkline(values, width),
        lo,
        hi
    ));
}

fn ratio(num: u64, den: u64) -> f64 {
    if den == 0 {
        0.0
    } else {
        num as f64 / den as f64
    }
}

/// Render the full `--timeline` report: chip sparklines, per-SM series,
/// the stall heatmap and the span summary.
pub fn render(run: &TelemetryRun, width: usize) -> String {
    let mut out = String::new();
    let n = run.chip.len();
    out.push_str(&format!(
        "# flight recorder: {} windows x {} cycles ({} cycles total{})\n",
        n,
        run.window,
        run.cycles,
        if run.chip_truncated > 0 {
            format!(", {} windows truncated", run.chip_truncated)
        } else {
            String::new()
        }
    ));
    if n == 0 {
        out.push_str("(no windows recorded)\n");
        return out;
    }

    out.push_str("\n## chip\n");
    series_line(
        &mut out,
        "IPC",
        &run.chip.iter().map(|w| w.ipc()).collect::<Vec<_>>(),
        width,
    );
    series_line(
        &mut out,
        "DRAM bw util",
        &run
            .chip
            .iter()
            .map(|w| w.bw_utilization(run.n_mcs))
            .collect::<Vec<_>>(),
        width,
    );
    series_line(
        &mut out,
        "compr ratio",
        &run.chip.iter().map(|w| w.compression_ratio()).collect::<Vec<_>>(),
        width,
    );
    series_line(
        &mut out,
        "L2 hit rate",
        &run.chip.iter().map(|w| w.l2.hit_rate()).collect::<Vec<_>>(),
        width,
    );
    if run.bus_overcommit_windows > 0 {
        out.push_str(&format!(
            "  note: {} window(s) overcommitted the DRAM bus (raw util > 1.0)\n",
            run.bus_overcommit_windows
        ));
    }

    // Cross-SM aggregates, one value per window index.
    let windows = run.cores.iter().map(|c| c.windows.len()).max().unwrap_or(0);
    if windows > 0 {
        let agg = |f: &dyn Fn(&crate::telemetry::CoreWindow) -> (u64, u64)| -> Vec<f64> {
            (0..windows)
                .map(|i| {
                    let (num, den) = run
                        .cores
                        .iter()
                        .filter_map(|c| c.windows.get(i))
                        .map(f)
                        .fold((0, 0), |(a, b), (x, y)| (a + x, b + y));
                    ratio(num, den)
                })
                .collect()
        };
        out.push_str("\n## SMs (aggregated)\n");
        series_line(&mut out, "L1 hit rate", &agg(&|w| (w.l1.hits, w.l1.accesses)), width);
        series_line(
            &mut out,
            "memo hit rate",
            &agg(&|w| (w.caba.memo_hits, w.caba.memo_lookups)),
            width,
        );
        series_line(
            &mut out,
            "AWT live",
            &agg(&|w| (w.awt_live as u64, 1)),
            width,
        );
        series_line(
            &mut out,
            "MSHR inflight",
            &agg(&|w| (w.mshr_inflight as u64, 1)),
            width,
        );

        out.push_str(
            "\n## per-SM stall heatmap (dominant class: #=active m=memory c=compute d=data .=idle)\n",
        );
        for core in &run.cores {
            let cells: String = bucket_ranges(core.windows.len(), width)
                .into_iter()
                .map(|r| {
                    let mut sum = IssueBreakdown::default();
                    for w in &core.windows[r] {
                        sum.active += w.issue.active;
                        sum.compute_stall += w.issue.compute_stall;
                        sum.memory_stall += w.issue.memory_stall;
                        sum.data_stall += w.issue.data_stall;
                        sum.idle += w.issue.idle;
                    }
                    stall_char(&sum)
                })
                .collect();
            out.push_str(&format!("  SM {:>3} |{}|\n", core.sm_id, cells));
        }
    }

    // Span summary (per kind, across SMs).
    let mut counts = [("decompress", 0u64), ("compress", 0), ("prefetch", 0), ("memo_lookup", 0), ("memo_install", 0)];
    let mut dropped = 0;
    for c in &run.cores {
        dropped += c.spans_dropped;
        for s in &c.spans {
            for entry in counts.iter_mut() {
                if entry.0 == s.kind.name() {
                    entry.1 += 1;
                }
            }
        }
    }
    out.push_str(&format!("\n## assist-warp spans ({} recorded", run.span_count()));
    if dropped > 0 {
        out.push_str(&format!(", {} dropped at the cap", dropped));
    }
    out.push_str(")\n");
    for (name, n) in counts {
        if n > 0 {
            out.push_str(&format!("  {:<14} {}\n", name, n));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::{CabaStats, CacheStats};
    use crate::telemetry::{
        ChipWindow, CoreTimeline, CoreWindow, Span, SpanKind, SpanOutcome, TelemetryRun,
    };

    #[test]
    fn sparkline_scales_and_handles_edges() {
        assert_eq!(sparkline(&[], 10), "");
        assert_eq!(sparkline(&[1.0, 2.0], 0), "");
        // All-zero input renders blanks (no division by zero).
        assert_eq!(sparkline(&[0.0, 0.0, 0.0], 3), "   ");
        // Max maps to '@', zero to ' ', small non-zero to at least '.'.
        let s = sparkline(&[0.0, 0.001, 8.0], 3);
        assert_eq!(s.len(), 3);
        assert_eq!(&s[0..1], " ");
        assert_eq!(&s[1..2], ".");
        assert_eq!(&s[2..3], "@");
        // Short input is not stretched to the full width.
        assert_eq!(sparkline(&[1.0, 1.0], 80).len(), 2);
        // Long input pools down to exactly `width` buckets.
        let long: Vec<f64> = (0..1000).map(|i| i as f64).collect();
        assert_eq!(sparkline(&long, 40).len(), 40);
    }

    #[test]
    fn bucket_ranges_partition_exactly() {
        for n in [0usize, 1, 2, 7, 40, 41, 100] {
            for width in [1usize, 3, 40] {
                let ranges = bucket_ranges(n, width);
                assert_eq!(ranges.len(), width.min(n));
                let covered: Vec<usize> = ranges.into_iter().flatten().collect();
                assert_eq!(covered, (0..n).collect::<Vec<_>>(), "n={n} width={width}");
            }
        }
    }

    #[test]
    fn stall_char_picks_dominant_with_severity_ties() {
        let mut i = IssueBreakdown::default();
        assert_eq!(stall_char(&i), '.'); // empty window
        i.memory_stall = 5;
        i.idle = 3;
        assert_eq!(stall_char(&i), 'm');
        i.active = 5; // tie with memory: active wins
        assert_eq!(stall_char(&i), '#');
        i = IssueBreakdown::default();
        i.compute_stall = 2;
        i.data_stall = 2; // tie among stalls: memory > compute > data order
        assert_eq!(stall_char(&i), 'c');
    }

    fn golden_run() -> TelemetryRun {
        let cw = |active: u64, memory: u64, l1_hits: u64, l1_acc: u64| CoreWindow {
            issue: IssueBreakdown {
                active,
                memory_stall: memory,
                ..Default::default()
            },
            caba: CabaStats::default(),
            l1: CacheStats {
                accesses: l1_acc,
                hits: l1_hits,
                ..Default::default()
            },
            mshr_inflight: 2,
            awt_live: 1,
        };
        TelemetryRun {
            window: 10,
            cycles: 30,
            n_mcs: 2,
            chip: vec![
                ChipWindow {
                    cycles: 10,
                    warp_insts: 20,
                    bursts: 5,
                    bursts_uncompressed: 10,
                    bus_busy_cycles: 10.0,
                    ..Default::default()
                },
                ChipWindow {
                    cycles: 10,
                    warp_insts: 10,
                    ..Default::default()
                },
                ChipWindow {
                    cycles: 10,
                    ..Default::default()
                },
            ],
            chip_truncated: 0,
            bus_overcommit_windows: 0,
            cores: vec![CoreTimeline {
                sm_id: 0,
                windows: vec![cw(8, 2, 3, 4), cw(1, 9, 0, 0), cw(0, 0, 0, 0)],
                truncated_windows: 0,
                spans: vec![Span {
                    token: 0,
                    kind: SpanKind::Decompress,
                    parent_warp: 1,
                    trigger_at: 2,
                    first_issue: 2,
                    end: 8,
                    outcome: SpanOutcome::Retired,
                }],
                spans_dropped: 0,
            }],
        }
    }

    #[test]
    fn render_golden_snapshot() {
        // Byte-exact golden: rendering is part of the deterministic
        // surface (the differential suite compares the underlying data,
        // this pins the presentation).
        let text = render(&golden_run(), 3);
        let expected = "\
# flight recorder: 3 windows x 10 cycles (30 cycles total)

## chip
  IPC            |@= | min=0.000 max=2.000
  DRAM bw util   |@  | min=0.000 max=0.500
  compr ratio    |@==| min=1.000 max=2.000
  L2 hit rate    |   | min=0.000 max=0.000

## SMs (aggregated)
  L1 hit rate    |@  | min=0.000 max=0.750
  memo hit rate  |   | min=0.000 max=0.000
  AWT live       |@@@| min=1.000 max=1.000
  MSHR inflight  |@@@| min=2.000 max=2.000

## per-SM stall heatmap (dominant class: #=active m=memory c=compute d=data .=idle)
  SM   0 |#m.|

## assist-warp spans (1 recorded)
  decompress     1
";
        assert_eq!(text, expected, "got:\n{text}");
    }

    #[test]
    fn render_empty_run_is_graceful() {
        let run = TelemetryRun {
            window: 10,
            cycles: 0,
            n_mcs: 2,
            chip: vec![],
            chip_truncated: 0,
            bus_overcommit_windows: 0,
            cores: vec![],
        };
        let text = render(&run, 40);
        assert!(text.contains("(no windows recorded)"));
    }
}
