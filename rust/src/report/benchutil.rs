//! Shared harness for the `harness = false` bench binaries (criterion is
//! unavailable in the offline image — DESIGN.md §3).
//!
//! Every bench regenerates one paper table/figure through the parallel
//! sweep engine, printing the figure and its wall time.
//!
//! Knobs (env var or bench arg):
//! * `CABA_BENCH_SCALE` — workload scale (default 0.1; 0.25–1.0 for
//!   publication-fidelity runs); `--quick` drops to 0.03 for smoke runs.
//! * `CABA_JOBS` / `--jobs N` — sweep worker count (default: one per
//!   available core; `1` reproduces the old serial behaviour,
//!   bit-identically).

use super::figures::RunCtx;
use crate::SimConfig;
use std::time::Instant;

/// Workload scale for bench runs.
pub fn bench_scale() -> f64 {
    if std::env::args().any(|a| a == "--quick") {
        return 0.03;
    }
    std::env::var("CABA_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

/// Sweep worker count for bench runs (`0` = one per available core).
/// Malformed values fail loudly — a silently ignored `--jobs` would
/// record the EXPERIMENTS.md wall-clock table under the wrong count.
pub fn bench_jobs() -> usize {
    let parse_loudly = |what: &str, v: &str| -> usize {
        v.parse()
            .unwrap_or_else(|_| panic!("{what} expects a non-negative integer, got {v:?}"))
    };
    let mut args = std::env::args();
    while let Some(a) = args.next() {
        if a == "--jobs" {
            let v = args.next().unwrap_or_default();
            return parse_loudly("--jobs", &v);
        }
    }
    match std::env::var("CABA_JOBS") {
        Ok(v) => parse_loudly("CABA_JOBS", &v),
        Err(_) => 0,
    }
}

/// The [`RunCtx`] a bench binary should regenerate its figure with.
pub fn bench_ctx() -> RunCtx {
    RunCtx::with_cfg(SimConfig::default(), bench_scale(), bench_jobs())
}

/// Run one named figure generator and report timing.
pub fn run_bench(name: &str, f: impl FnOnce(&RunCtx) -> String) {
    let ctx = bench_ctx();
    let jobs = crate::sweep::resolve_jobs(ctx.jobs);
    eprintln!("[{name}] generating at scale {} with {jobs} worker(s) ...", ctx.scale);
    let t0 = Instant::now();
    let out = f(&ctx);
    let dt = t0.elapsed().as_secs_f64();
    println!("{out}");
    println!(
        "[{name}] regenerated in {dt:.2}s (scale {}, {jobs} worker(s))",
        ctx.scale
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scale_parses() {
        let s = bench_scale();
        assert!(s > 0.0 && s <= 1.0);
    }

    #[test]
    fn default_jobs_parse() {
        // 0 (auto) unless the test runner's env says otherwise.
        let _ = bench_jobs();
        assert!(crate::sweep::resolve_jobs(bench_jobs()) >= 1);
    }
}
