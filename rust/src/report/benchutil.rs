//! Shared harness for the `harness = false` bench binaries (criterion is
//! unavailable in the offline image — DESIGN.md §3).
//!
//! Every bench regenerates one paper table/figure, printing the figure and
//! its wall time. `CABA_BENCH_SCALE` sets the workload scale (default 0.1;
//! use 0.25–1.0 for publication-fidelity runs). `--quick` in the bench args
//! drops to 0.03 for smoke runs.

use std::time::Instant;

/// Workload scale for bench runs.
pub fn bench_scale() -> f64 {
    if std::env::args().any(|a| a == "--quick") {
        return 0.03;
    }
    std::env::var("CABA_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0.1)
}

/// Run one named figure generator and report timing.
pub fn run_bench(name: &str, f: impl FnOnce(f64) -> String) {
    let scale = bench_scale();
    eprintln!("[{name}] generating at scale {scale} ...");
    let t0 = Instant::now();
    let out = f(scale);
    let dt = t0.elapsed().as_secs_f64();
    println!("{out}");
    println!("[{name}] regenerated in {dt:.2}s (scale {scale})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_scale_parses() {
        let s = bench_scale();
        assert!(s > 0.0 && s <= 1.0);
    }
}
