//! Figure/table regenerators: one function per table and figure in the
//! paper's evaluation (§3, §7). Each runs the required (app × design)
//! simulations and renders the same rows/series the paper plots.
//!
//! Used by both the CLI (`caba fig N [--jobs N] [--set k=v]`) and the
//! bench binaries (`cargo bench --bench figNN_*`). Every regenerator
//! first *warms* the process-wide run cache through the parallel
//! [`crate::sweep::SweepEngine`] — the whole (app × design × bw) matrix
//! executes concurrently, deterministically — then composes its table
//! from cache hits. Figures sharing runs (8–11) still simulate each point
//! once per process.
//!
//! The cache is keyed on the **full** [`SimConfig`] fingerprint (plus
//! app/design/scale), so `--set` overrides can never be served stale
//! stats from a different configuration — the old cache keyed only on
//! `(bw_scale, scale)` and silently ignored overrides.

use super::{figure_matrix, Series};
use crate::compress::Algo;
use crate::energy::EnergyModel;
use crate::sim::designs::{Design, Mechanism};
use crate::stats::SimStats;
use crate::sweep::{SweepEngine, SweepJob};
use crate::workload::apps::{self, AppSpec};
use crate::SimConfig;

/// Everything a figure regeneration needs: the base configuration (before
/// per-figure `bw_scale` adjustments), the workload scale, and the sweep
/// worker count (`0` = one per available core).
#[derive(Clone)]
pub struct RunCtx {
    pub cfg: SimConfig,
    pub scale: f64,
    pub jobs: usize,
}

impl RunCtx {
    /// Default configuration at `scale`, auto parallelism.
    pub fn new(scale: f64) -> RunCtx {
        RunCtx { cfg: SimConfig::default(), scale, jobs: 0 }
    }

    /// Explicit configuration (CLI `--set` overrides) and worker count.
    pub fn with_cfg(cfg: SimConfig, scale: f64, jobs: usize) -> RunCtx {
        RunCtx { cfg, scale, jobs }
    }

    fn engine(&self) -> SweepEngine {
        SweepEngine::shared(self.jobs)
    }

    /// Execute all `(app, design, bw_scale)` points concurrently into the
    /// shared cache (deduplicated; already-cached points are free). A
    /// failed point panics with its typed `JobError` message: a figure
    /// cannot exist without its points, and the message is the
    /// diagnostic (same policy as [`RunCtx::point`]).
    pub fn warm(&self, points: &[(&'static AppSpec, Design, f64)]) {
        let jobs: Vec<SweepJob> = points
            .iter()
            .map(|&(app, design, bw)| SweepJob::with_bw(app, design, &self.cfg, bw, self.scale))
            .collect();
        self.engine().run(&jobs).unwrap_or_else(|e| panic!("{e}"));
    }

    /// Run (or fetch) one simulation point.
    pub fn point(&self, app: &'static AppSpec, design: Design, bw_scale: f64) -> SimStats {
        self.engine()
            .run_one(&SweepJob::with_bw(app, design, &self.cfg, bw_scale, self.scale))
    }
}

fn eval_apps() -> Vec<&'static AppSpec> {
    apps::eval_set()
}

fn names(set: &[&'static AppSpec]) -> Vec<&'static str> {
    set.iter().map(|a| a.name).collect()
}

/// Cross-product helper: every app × every design at the given bandwidth
/// points.
fn matrix(
    set: &[&'static AppSpec],
    designs: &[Design],
    bws: &[f64],
) -> Vec<(&'static AppSpec, Design, f64)> {
    let mut points = Vec::with_capacity(set.len() * designs.len() * bws.len());
    for &app in set {
        for &d in designs {
            for &bw in bws {
                points.push((app, d, bw));
            }
        }
    }
    points
}

fn energy_of(stats: &SimStats, design: &Design) -> f64 {
    EnergyModel::default()
        .evaluate(
            stats,
            design.mechanism == Mechanism::Caba,
            design.mechanism == Mechanism::Hardware,
        )
        .total_mj()
}

// ---------------------------------------------------------------- Fig. 2

/// Issue-cycle breakdown for all 27 apps at ½×/1×/2× memory bandwidth.
pub fn fig02_cycle_breakdown(ctx: &RunCtx) -> String {
    let all: Vec<&'static AppSpec> = apps::APPS.iter().collect();
    ctx.warm(&matrix(&all, &[Design::base()], &[0.5, 1.0, 2.0]));
    let mut out = String::from("# Fig. 2 — breakdown of total issue cycles (Base design)\n");
    for bw in [0.5, 1.0, 2.0] {
        out.push_str(&format!("\n## {}x baseline bandwidth\n", bw));
        let mut t = super::Table::new([
            "app", "class", "compute%", "memory%", "data-dep%", "idle%", "active%",
        ]);
        let mut mem_md_sum = 0.0;
        let mut n_mem = 0;
        for app in apps::APPS {
            let s = ctx.point(app, Design::base(), bw);
            let (c, m, d, i, a) = s.issue.fractions();
            if app.memory_bound {
                mem_md_sum += m + d;
                n_mem += 1;
            }
            t.row([
                app.name.to_string(),
                if app.memory_bound { "mem".into() } else { "comp".to_string() },
                format!("{:.1}", c * 100.0),
                format!("{:.1}", m * 100.0),
                format!("{:.1}", d * 100.0),
                format!("{:.1}", i * 100.0),
                format!("{:.1}", a * 100.0),
            ]);
        }
        out.push_str(&t.render());
        out.push_str(&format!(
            "memory-bound apps: mean(memory+data-dep stalls) = {:.1}% \
             (paper: 61% at 1x, 51% at 2x, higher at 0.5x)\n",
            mem_md_sum / n_mem as f64 * 100.0
        ));
    }
    out
}

// ---------------------------------------------------------------- Fig. 3

/// Fraction of statically unallocated registers per app (pure occupancy
/// arithmetic; no simulation needed).
pub fn fig03_unallocated_regs(ctx: &RunCtx) -> String {
    let cfg = &ctx.cfg;
    let mut t = super::Table::new(["app", "regs/thread", "CTAs/SM", "limiter", "unallocated%"]);
    let mut sum = 0.0;
    for app in apps::APPS {
        let occ = crate::workload::occupancy(app, cfg, 0);
        sum += occ.unallocated_reg_frac;
        t.row([
            app.name.to_string(),
            app.regs_per_thread.to_string(),
            occ.ctas_per_sm.to_string(),
            occ.limiter.to_string(),
            format!("{:.1}", occ.unallocated_reg_frac * 100.0),
        ]);
    }
    format!(
        "# Fig. 3 — statically unallocated registers ({}KB register file/SM)\n{}\
         average unallocated: {:.1}% (paper: 24%)\n",
        cfg.regfile_per_sm * 4 / 1024,
        t.render(),
        sum / apps::APPS.len() as f64 * 100.0
    )
}

// ------------------------------------------------------------- Figs. 8-11

fn headline_series(
    ctx: &RunCtx,
    metric: impl Fn(&SimStats, &Design) -> f64,
) -> (Vec<&'static str>, Vec<Series>) {
    let set = eval_apps();
    let designs = Design::headline();
    ctx.warm(&matrix(&set, &designs, &[1.0]));
    let mut series: Vec<Series> = designs
        .iter()
        .map(|d| Series { label: d.name.to_string(), values: Vec::new() })
        .collect();
    for app in &set {
        for (di, d) in designs.iter().enumerate() {
            let s = ctx.point(app, *d, 1.0);
            series[di].values.push(metric(&s, d));
        }
    }
    (names(&set), series)
}

/// Normalized performance of the five designs (vs Base).
pub fn fig08_performance(ctx: &RunCtx) -> String {
    let set = eval_apps();
    ctx.warm(&matrix(&set, &Design::headline(), &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let (names, mut series) = headline_series(ctx, |s, _| s.ipc());
    for s in &mut series {
        for (i, v) in s.values.iter_mut().enumerate() {
            *v /= base[i];
        }
    }
    format!(
        "# Fig. 8 — normalized performance (IPC vs Base)\n\
         paper: CABA-BDI +41.7% avg (up to 2.6x); within 2.8% of Ideal-BDI;\n\
         +9.9% over HW-BDI-Mem; within 1.6% of HW-BDI\n{}",
        figure_matrix(&names, &series, 3)
    )
}

/// Memory bandwidth utilization of the five designs.
pub fn fig09_bandwidth_utilization(ctx: &RunCtx) -> String {
    let n_mcs = ctx.cfg.n_mcs;
    let (names, series) = headline_series(ctx, move |s, _| {
        s.dram.bandwidth_utilization(s.cycles, n_mcs) * 100.0
    });
    format!(
        "# Fig. 9 — memory bandwidth utilization (%)\n\
         paper: Base 53.6% -> CABA-BDI 35.6% average\n{}",
        figure_matrix(&names, &series, 1)
    )
}

/// Normalized energy of the five designs (vs Base).
pub fn fig10_energy(ctx: &RunCtx) -> String {
    let set = eval_apps();
    ctx.warm(&matrix(&set, &Design::headline(), &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| {
            let s = ctx.point(a, Design::base(), 1.0);
            energy_of(&s, &Design::base())
        })
        .collect();
    let (names, mut series) = headline_series(ctx, |s, d| energy_of(s, d));
    for s in &mut series {
        for (i, v) in s.values.iter_mut().enumerate() {
            *v /= base[i];
        }
    }
    // DRAM-power sub-claim.
    let mut dram_base = 0.0;
    let mut dram_caba = 0.0;
    for app in &set {
        let b = ctx.point(app, Design::base(), 1.0);
        let c = ctx.point(app, Design::caba(Algo::Bdi), 1.0);
        let em = EnergyModel::default();
        dram_base += em.evaluate(&b, false, false).dram_total_mj() / (b.cycles as f64);
        dram_caba += em.evaluate(&c, true, false).dram_total_mj() / (c.cycles as f64);
    }
    format!(
        "# Fig. 10 — normalized energy (vs Base)\n\
         paper: CABA-BDI -22.2% energy; DRAM power -29.5%; within 4.0% of Ideal-BDI\n{}\
         DRAM power (CABA-BDI / Base): {:.3} (paper: 0.705)\n",
        figure_matrix(&names, &series, 3),
        dram_caba / dram_base
    )
}

/// Normalized energy-delay product.
pub fn fig11_edp(ctx: &RunCtx) -> String {
    let em = EnergyModel::default();
    let set = eval_apps();
    ctx.warm(&matrix(&set, &Design::headline(), &[1.0]));
    let edp = |s: &SimStats, d: &Design| {
        em.edp(
            s,
            d.mechanism == Mechanism::Caba,
            d.mechanism == Mechanism::Hardware,
        )
    };
    let base: Vec<f64> = set
        .iter()
        .map(|a| edp(&ctx.point(a, Design::base(), 1.0), &Design::base()))
        .collect();
    let (names, mut series) = headline_series(ctx, edp);
    for s in &mut series {
        for (i, v) in s.values.iter_mut().enumerate() {
            *v /= base[i];
        }
    }
    format!(
        "# Fig. 11 — normalized energy-delay product (vs Base)\n\
         paper: CABA-BDI -45% EDP, within 4% of Ideal-BDI\n{}",
        figure_matrix(&names, &series, 3)
    )
}

// ------------------------------------------------------------ Figs. 12-13

/// Speedup with different compression algorithms under CABA.
pub fn fig12_algorithms(ctx: &RunCtx) -> String {
    let set = eval_apps();
    let designs = [
        Design::caba(Algo::Fpc),
        Design::caba(Algo::Bdi),
        Design::caba(Algo::CPack),
        Design::caba(Algo::BestOfAll),
    ];
    let mut all = designs.to_vec();
    all.push(Design::base());
    ctx.warm(&matrix(&set, &all, &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let series: Vec<Series> = designs
        .iter()
        .map(|d| Series {
            label: d.name.to_string(),
            values: set
                .iter()
                .enumerate()
                .map(|(i, a)| ctx.point(a, *d, 1.0).ipc() / base[i])
                .collect(),
        })
        .collect();
    format!(
        "# Fig. 12 — speedup with different compression algorithms\n\
         paper: FPC +20.7%, BDI +41.7%, C-Pack +35.2%; BestOfAll >= each\n{}",
        figure_matrix(&names(&set), &series, 3)
    )
}

/// Compression ratio of each algorithm (DRAM bursts saved).
pub fn fig13_compression_ratio(ctx: &RunCtx) -> String {
    let set = eval_apps();
    let designs: Vec<Design> = [Algo::Fpc, Algo::Bdi, Algo::CPack, Algo::BestOfAll]
        .iter()
        .map(|&a| Design::caba(a))
        .collect();
    ctx.warm(&matrix(&set, &designs, &[1.0]));
    let series: Vec<Series> = [Algo::Fpc, Algo::Bdi, Algo::CPack, Algo::BestOfAll]
        .iter()
        .map(|&algo| Series {
            label: format!("CABA-{}", algo.name()),
            values: set
                .iter()
                .map(|a| ctx.point(a, Design::caba(algo), 1.0).dram.compression_ratio())
                .collect(),
        })
        .collect();
    format!(
        "# Fig. 13 — compression ratio (uncompressed/compressed DRAM bursts)\n\
         paper: BDI avg 2.1x; LPS/JPEG/MUM/nw favour FPC or C-Pack,\n\
         MM/PVC/PVR favour BDI\n{}",
        figure_matrix(&names(&set), &series, 2)
    )
}

// ---------------------------------------------------------------- Fig. 14

/// Sensitivity to ½×/1×/2× peak DRAM bandwidth.
pub fn fig14_bw_sensitivity(ctx: &RunCtx) -> String {
    let set = eval_apps();
    ctx.warm(&matrix(
        &set,
        &[Design::base(), Design::caba(Algo::Bdi)],
        &[0.5, 1.0, 2.0],
    ));
    let base1: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let mut series = Vec::new();
    for bw in [0.5, 1.0, 2.0] {
        for d in [Design::base(), Design::caba(Algo::Bdi)] {
            series.push(Series {
                label: format!("{}x-{}", bw, if d.mechanism == Mechanism::None { "Base" } else { "CABA" }),
                values: set
                    .iter()
                    .enumerate()
                    .map(|(i, a)| ctx.point(a, d, bw).ipc() / base1[i])
                    .collect(),
            });
        }
    }
    format!(
        "# Fig. 14 — sensitivity to peak memory bandwidth (normalized to Base-1x)\n\
         paper: CABA at 1x approaches Base at 2x\n{}",
        figure_matrix(&names(&set), &series, 3)
    )
}

// ---------------------------------------------------------------- Fig. 15

/// Cache-capacity compression (L1/L2, 2×/4× tags) on top of CABA-BDI.
pub fn fig15_cache_compression(ctx: &RunCtx) -> String {
    let set = eval_apps();
    let designs = [
        Design::caba(Algo::Bdi),
        Design::caba_cache_compressed(2, 1),
        Design::caba_cache_compressed(4, 1),
        Design::caba_cache_compressed(1, 2),
        Design::caba_cache_compressed(1, 4),
    ];
    let mut all = designs.to_vec();
    all.push(Design::base());
    ctx.warm(&matrix(&set, &all, &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let series: Vec<Series> = designs
        .iter()
        .map(|d| Series {
            label: d.name.trim_start_matches("CABA-BDI-").to_string(),
            values: set
                .iter()
                .enumerate()
                .map(|(i, a)| ctx.point(a, *d, 1.0).ipc() / base[i])
                .collect(),
        })
        .collect();
    format!(
        "# Fig. 15 — speedup of cache compression with CABA (vs Base)\n\
         paper: bfs/sssp benefit from L1, TRA/KM from L2; L1 compression can\n\
         severely degrade some apps (decompression on every hit)\n{}",
        figure_matrix(&names(&set), &series, 3)
    )
}

// ---------------------------------------------------------------- Fig. 16

/// The Uncompressed-L2 and Direct-Load optimizations.
pub fn fig16_optimizations(ctx: &RunCtx) -> String {
    let set = eval_apps();
    let designs = [
        Design::caba(Algo::Bdi),
        Design::caba_uncompressed_l2(),
        Design::caba_direct_load(),
    ];
    let mut all = designs.to_vec();
    all.push(Design::base());
    ctx.warm(&matrix(&set, &all, &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let series: Vec<Series> = designs
        .iter()
        .map(|d| Series {
            label: d.name.to_string(),
            values: set
                .iter()
                .enumerate()
                .map(|(i, a)| ctx.point(a, *d, 1.0).ipc() / base[i])
                .collect(),
        })
        .collect();
    format!(
        "# Fig. 16 — effect of Uncompressed-L2 and Direct-Load (vs Base)\n\
         paper: direct-load +2.5% avg (up to +4.6% on MM); uncompressed L2\n\
         helps high-L2-hit-rate apps (e.g. RAY)\n{}",
        figure_matrix(&names(&set), &series, 3)
    )
}

// ------------------------------------------------------------------ §8.1

/// Memoization on the compute-bound suite: the §8.1-style figure the paper
/// leaves to future work. Speedups of CABA-Memo and the compress+memo
/// hybrid over Base, plus the *measured* per-app LUT behaviour (hit /
/// alias / eviction rates and install counts) — every number here emerges
/// from operand values flowing through the per-SM LUTs.
pub fn fig_memo(ctx: &RunCtx) -> String {
    let set = apps::memo_suite();
    let designs = [
        Design::base(),
        Design::caba_memo(),
        Design::caba_memo_hybrid(),
    ];
    ctx.warm(&matrix(&set, &designs, &[1.0]));
    let base: Vec<f64> = set
        .iter()
        .map(|a| ctx.point(a, Design::base(), 1.0).ipc())
        .collect();
    let series: Vec<Series> = designs[1..]
        .iter()
        .map(|d| Series {
            label: d.name.to_string(),
            values: set
                .iter()
                .enumerate()
                .map(|(i, a)| ctx.point(a, *d, 1.0).ipc() / base[i])
                .collect(),
        })
        .collect();
    let mut lut = super::Table::new([
        "app", "p_shared", "classes", "lookups", "hit%", "alias%", "installs", "evict%", "skipped",
    ]);
    for app in &set {
        let s = ctx.point(app, Design::caba_memo(), 1.0);
        let c = s.caba;
        let pct = |num: u64, den: u64| {
            if den == 0 {
                "n/a".to_string()
            } else {
                format!("{:.1}", num as f64 / den as f64 * 100.0)
            }
        };
        lut.row([
            app.name.to_string(),
            format!("{:.2}", app.values.p_shared),
            app.values.classes.to_string(),
            c.memo_lookups.to_string(),
            pct(c.memo_hits, c.memo_lookups),
            pct(c.memo_alias_hits, c.memo_lookups),
            c.memo_installs.to_string(),
            pct(c.memo_evictions, c.memo_installs),
            c.memo_lookups_skipped.to_string(),
        ]);
    }
    format!(
        "# §8.1 — memoization speedup on the compute-bound suite (vs Base)\n\
         hit rates are measured through the per-SM LUT model (capacity carved\n\
         from unutilized shared memory), not drawn from a redundancy table\n{}\
         \n## Measured LUT behaviour (CABA-Memo)\n{}",
        figure_matrix(&names(&set), &series, 3),
        lut.render()
    )
}

// ---------------------------------------------------------------- §5.3.2

/// MD-cache hit rate across the eval set.
pub fn md_cache_hitrate(ctx: &RunCtx) -> String {
    let set = eval_apps();
    ctx.warm(&matrix(&set, &[Design::caba(Algo::Bdi)], &[1.0]));
    let series = vec![Series {
        label: "MD hit rate %".to_string(),
        values: set
            .iter()
            .map(|a| ctx.point(a, Design::caba(Algo::Bdi), 1.0).md.hit_rate() * 100.0)
            .collect(),
    }];
    format!(
        "# MD cache (8KB, 4-way per MC) hit rate\npaper: 85% average, >99% for many apps\n{}",
        figure_matrix(&names(&set), &series, 1)
    )
}

#[cfg(test)]
mod tests {
    // The figure bodies themselves are exercised end-to-end by the bench
    // binaries and `caba fig`; these pin the matrix-plumbing helpers every
    // regenerator builds on.
    use super::*;

    #[test]
    fn matrix_is_app_major_cross_product() {
        let set: Vec<&'static AppSpec> = eval_apps().into_iter().take(2).collect();
        let designs = [Design::base(), Design::caba(Algo::Bdi)];
        let bws = [0.5, 1.0];
        let points = matrix(&set, &designs, &bws);
        assert_eq!(points.len(), 2 * 2 * 2);
        // App-major, then design, then bandwidth — the order the sweep
        // engine keys its cache warm-up on.
        assert!(std::ptr::eq(points[0].0, set[0]));
        assert_eq!(points[0].1.name, "Base");
        assert_eq!(points[0].2, 0.5);
        assert_eq!(points[1].2, 1.0);
        assert_eq!(points[2].1.name, designs[1].name);
        assert!(std::ptr::eq(points[4].0, set[1]));
        // Degenerate axes collapse cleanly.
        assert!(matrix(&[], &designs, &bws).is_empty());
        assert!(matrix(&set, &designs, &[]).is_empty());
    }

    #[test]
    fn names_and_eval_set_are_consistent() {
        let set = eval_apps();
        let n = names(&set);
        assert_eq!(n.len(), set.len());
        assert!(!set.is_empty());
        for (app, name) in set.iter().zip(&n) {
            assert_eq!(app.name, *name);
            assert!(app.in_eval_set, "{name} outside the eval set");
        }
    }

    #[test]
    fn runctx_constructors_carry_overrides() {
        let ctx = RunCtx::new(0.25);
        assert_eq!(ctx.scale, 0.25);
        assert_eq!(ctx.jobs, 0);
        assert_eq!(ctx.cfg.fingerprint(), SimConfig::default().fingerprint());
        let mut cfg = SimConfig::default();
        cfg.n_sms = 3;
        let ctx = RunCtx::with_cfg(cfg.clone(), 1.0, 4);
        assert_eq!(ctx.jobs, 4);
        assert_eq!(ctx.cfg.n_sms, 3);
        assert_eq!(ctx.cfg.fingerprint(), cfg.fingerprint());
    }
}
