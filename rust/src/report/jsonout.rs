//! `caba run --json`: the full end-of-run [`SimStats`] plus a flight
//! recorder summary as machine-readable JSON.
//!
//! Hand-rolled writer in the `BenchReport::to_json` idiom (the offline
//! image has no serde). All keys are fixed identifiers and app/design
//! names are `[A-Za-z0-9_-]`, so no escaping is needed. Derived metrics
//! (hit rates, IPC, compression ratio) are embedded alongside the raw
//! counters so downstream scripts don't re-implement the formulas.

use crate::stats::{CacheStats, SimStats};
use crate::telemetry::TelemetryRun;
use std::fmt::Write as _;

fn cache(s: &CacheStats) -> String {
    format!(
        "{{\"accesses\": {}, \"hits\": {}, \"misses\": {}, \"evictions\": {}, \
         \"writebacks\": {}, \"hit_rate\": {:.6}}}",
        s.accesses,
        s.hits,
        s.misses,
        s.evictions,
        s.writebacks,
        s.hit_rate()
    )
}

/// Render one finished run as a JSON object. `n_mcs` feeds the bandwidth
/// utilization derivation (the stats struct stores raw busy-cycles);
/// `telemetry` is `None` when the flight recorder was off.
pub fn run_json(
    app: &str,
    design: &str,
    stats: &SimStats,
    n_mcs: usize,
    telemetry: Option<&TelemetryRun>,
) -> String {
    let mut s = String::new();
    s.push_str("{\n");
    s.push_str("  \"schema\": \"caba-run-v1\",\n");
    let _ = writeln!(s, "  \"app\": \"{app}\",");
    let _ = writeln!(s, "  \"design\": \"{design}\",");
    let _ = writeln!(s, "  \"finished\": {},", stats.finished);
    let _ = writeln!(s, "  \"cycles\": {},", stats.cycles);
    let _ = writeln!(s, "  \"warp_insts\": {},", stats.warp_insts);
    let _ = writeln!(s, "  \"thread_insts\": {},", stats.thread_insts);
    let _ = writeln!(s, "  \"ctas_launched\": {},", stats.ctas_launched);
    let _ = writeln!(s, "  \"ipc\": {:.6},", stats.ipc());
    let i = &stats.issue;
    let _ = writeln!(
        s,
        "  \"issue\": {{\"active\": {}, \"compute_stall\": {}, \"memory_stall\": {}, \
         \"data_stall\": {}, \"idle\": {}}},",
        i.active, i.compute_stall, i.memory_stall, i.data_stall, i.idle
    );
    let _ = writeln!(s, "  \"l1\": {},", cache(&stats.l1));
    let _ = writeln!(s, "  \"l2\": {},", cache(&stats.l2));
    let d = &stats.dram;
    let _ = writeln!(
        s,
        "  \"dram\": {{\"reads\": {}, \"writes\": {}, \"row_hits\": {}, \"row_misses\": {}, \
         \"bursts\": {}, \"bursts_uncompressed\": {}, \"md_accesses\": {}, \
         \"compression_ratio\": {:.4}, \"bandwidth_utilization\": {:.4}}},",
        d.reads,
        d.writes,
        d.row_hits,
        d.row_misses,
        d.bursts,
        d.bursts_uncompressed,
        d.md_accesses,
        d.compression_ratio(),
        d.bandwidth_utilization(stats.cycles, n_mcs)
    );
    let ic = &stats.icnt;
    let _ = writeln!(
        s,
        "  \"icnt\": {{\"packets_fwd\": {}, \"packets_back\": {}, \"flits_fwd\": {}, \
         \"flits_back\": {}}},",
        ic.packets_fwd, ic.packets_back, ic.flits_fwd, ic.flits_back
    );
    let c = &stats.caba;
    let _ = writeln!(
        s,
        "  \"caba\": {{\"decompress_warps\": {}, \"compress_warps\": {}, \
         \"assist_insts_issued\": {}, \"assist_insts_idle_slots\": {}, \
         \"compress_skipped\": {}, \"throttled_deploys\": {}, \"killed\": {}, \
         \"prefetches_issued\": {}, \"memo_lookups\": {}, \"memo_hits\": {}, \
         \"memo_alias_hits\": {}, \"memo_installs\": {}, \"memo_evictions\": {}, \
         \"memo_lookups_skipped\": {}}},",
        c.decompress_warps,
        c.compress_warps,
        c.assist_insts_issued,
        c.assist_insts_idle_slots,
        c.compress_skipped,
        c.throttled_deploys,
        c.killed,
        c.prefetches_issued,
        c.memo_lookups,
        c.memo_hits,
        c.memo_alias_hits,
        c.memo_installs,
        c.memo_evictions,
        c.memo_lookups_skipped
    );
    let _ = writeln!(
        s,
        "  \"md\": {{\"accesses\": {}, \"hits\": {}, \"hit_rate\": {:.6}}},",
        stats.md.accesses,
        stats.md.hits,
        stats.md.hit_rate()
    );
    let e = &stats.energy_events;
    let _ = writeln!(
        s,
        "  \"energy_events\": {{\"core_insts\": {}, \"assist_insts\": {}, \"l1_accesses\": {}, \
         \"l2_accesses\": {}, \"icnt_flits\": {}, \"dram_bursts\": {}, \"dram_activates\": {}, \
         \"md_cache_accesses\": {}, \"hw_compressor_ops\": {}}},",
        e.core_insts,
        e.assist_insts,
        e.l1_accesses,
        e.l2_accesses,
        e.icnt_flits,
        e.dram_bursts,
        e.dram_activates,
        e.md_cache_accesses,
        e.hw_compressor_ops
    );
    let _ = writeln!(
        s,
        "  \"trace\": {{\"accesses_recorded\": {}, \"payloads_recorded\": {}}},",
        stats.trace.accesses_recorded, stats.trace.payloads_recorded
    );
    match telemetry {
        None => s.push_str("  \"telemetry\": null\n"),
        Some(r) => {
            let mut ipc_min = f64::INFINITY;
            let mut ipc_max = 0.0f64;
            let mut bw_peak = 0.0f64;
            for w in &r.chip {
                ipc_min = ipc_min.min(w.ipc());
                ipc_max = ipc_max.max(w.ipc());
                bw_peak = bw_peak.max(w.bw_utilization_raw(r.n_mcs));
            }
            if r.chip.is_empty() {
                ipc_min = 0.0;
            }
            let dropped: u64 = r.cores.iter().map(|c| c.spans_dropped).sum();
            let _ = writeln!(
                s,
                "  \"telemetry\": {{\"window\": {}, \"windows\": {}, \"chip_truncated\": {}, \
                 \"bus_overcommit_windows\": {}, \"spans\": {}, \"spans_dropped\": {}, \
                 \"ipc_min\": {:.6}, \"ipc_max\": {:.6}, \"bw_util_peak_raw\": {:.6}}}",
                r.window,
                r.window_count(),
                r.chip_truncated,
                r.bus_overcommit_windows,
                r.span_count(),
                dropped,
                ipc_min,
                ipc_max,
                bw_peak
            );
        }
    }
    s.push_str("}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{ChipWindow, CoreTimeline};

    fn stats() -> SimStats {
        let mut s = SimStats {
            cycles: 100,
            warp_insts: 250,
            finished: true,
            ..Default::default()
        };
        s.issue.active = 250;
        s.issue.idle = 150;
        s.l1.accesses = 40;
        s.l1.hits = 30;
        s.dram.bursts = 10;
        s.dram.bursts_uncompressed = 20;
        s.dram.bus_busy_cycles = 50.0;
        s
    }

    #[test]
    fn json_is_balanced_with_and_without_telemetry() {
        let j = run_json("PVC", "CABA-BDI", &stats(), 4, None);
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"schema\": \"caba-run-v1\""));
        assert!(j.contains("\"telemetry\": null"));
        assert!(j.contains("\"ipc\": 2.500000"));
        assert!(j.contains("\"compression_ratio\": 2.0000"));
        // 50 busy / (100 cycles x 4 MCs).
        assert!(j.contains("\"bandwidth_utilization\": 0.1250"));

        let run = TelemetryRun {
            window: 50,
            cycles: 100,
            n_mcs: 4,
            chip: vec![
                ChipWindow { cycles: 50, warp_insts: 200, ..Default::default() },
                ChipWindow { cycles: 50, warp_insts: 50, ..Default::default() },
            ],
            chip_truncated: 0,
            bus_overcommit_windows: 1,
            cores: vec![CoreTimeline {
                sm_id: 0,
                windows: vec![],
                truncated_windows: 0,
                spans: vec![],
                spans_dropped: 3,
            }],
        };
        let j = run_json("PVC", "CABA-BDI", &stats(), 4, Some(&run));
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert!(j.contains("\"windows\": 2"));
        assert!(j.contains("\"bus_overcommit_windows\": 1"));
        assert!(j.contains("\"spans_dropped\": 3"));
        assert!(j.contains("\"ipc_min\": 1.000000"));
        assert!(j.contains("\"ipc_max\": 4.000000"));
    }
}
