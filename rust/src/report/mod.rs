//! Report formatting: markdown tables for the figure/table regenerators,
//! normalized-metric helpers (geomean speedups, etc.).

pub mod benchutil;
pub mod figures;
pub mod jsonout;
pub mod timeline;

use crate::util::{geomean, mean};

/// A simple column-aligned markdown table builder.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new<S: Into<String>>(header: impl IntoIterator<Item = S>) -> Table {
        Table {
            header: header.into_iter().map(Into::into).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row<S: Into<String>>(&mut self, cells: impl IntoIterator<Item = S>) -> &mut Self {
        let cells: Vec<String> = cells.into_iter().map(Into::into).collect();
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.header.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!(" {:<width$} |", c, width = w));
            }
            s
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push('\n');
        out.push('|');
        for w in &widths {
            out.push_str(&format!("{:-<width$}|", "", width = w + 2));
        }
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, v)
}

/// Format a ratio as a percentage delta ("+41.7%").
pub fn pct_delta(ratio: f64) -> String {
    format!("{:+.1}%", (ratio - 1.0) * 100.0)
}

/// Summary row helpers for per-app × per-design matrices.
pub struct Series {
    pub label: String,
    pub values: Vec<f64>,
}

/// Render a figure-style matrix: one row per app, one column per series,
/// with GMean and Mean summary rows (the paper's figures report averages
/// over the app set).
pub fn figure_matrix(app_names: &[&str], series: &[Series], decimals: usize) -> String {
    let mut header = vec!["app".to_string()];
    header.extend(series.iter().map(|s| s.label.clone()));
    let mut t = Table::new(header);
    for (i, app) in app_names.iter().enumerate() {
        let mut row = vec![app.to_string()];
        row.extend(series.iter().map(|s| f(s.values[i], decimals)));
        t.row(row);
    }
    let mut gm = vec!["GMean".to_string()];
    gm.extend(series.iter().map(|s| f(geomean(&s.values), decimals)));
    t.row(gm);
    let mut am = vec!["Mean".to_string()];
    am.extend(series.iter().map(|s| f(mean(&s.values), decimals)));
    t.row(am);
    t.render()
}

/// The `caba trace info` report: header metadata plus stream statistics
/// of a loaded trace.
pub fn trace_summary(t: &crate::trace::replay::TraceData) -> String {
    use crate::trace::TraceKind;
    let m = &t.meta;
    let mut tbl = Table::new(["field", "value"]);
    let kind = match m.kind {
        TraceKind::Recorded if t.complete => "recorded app run",
        TraceKind::Recorded => "recorded app run (budget-truncated, partial coverage)",
        TraceKind::Imported => "imported text dump",
    };
    tbl.row(["kind".to_string(), kind.to_string()]);
    tbl.row(["app".to_string(), m.app.clone()]);
    tbl.row(["workload scale".to_string(), f(m.scale, 3)]);
    tbl.row(["config fingerprint".to_string(), format!("{:#018x}", m.fingerprint)]);
    tbl.row(["workload seed".to_string(), format!("{:#x}", m.seed)]);
    tbl.row(["content digest".to_string(), format!("{:#018x}", t.digest)]);
    tbl.row([
        "geometry".to_string(),
        format!(
            "{} CTAs x {} threads, {} regs/thread, {} iters/warp",
            m.total_ctas, m.threads_per_cta, m.regs_per_thread, m.iters
        ),
    ]);
    for (i, &(fp, code)) in m.arrays.iter().enumerate() {
        tbl.row([format!("array {i}"), format!("{fp} lines (pattern code {code:#04x})")]);
    }
    tbl.row([
        "access records".to_string(),
        format!(
            "{} ({} loads, {} stores, {} lines)",
            t.n_access_records, t.n_loads, t.n_stores, t.total_lines
        ),
    ]);
    let defs = t.payload_defs_count();
    let dedup = if defs == 0 { 1.0 } else { t.n_payload_entries as f64 / defs as f64 };
    tbl.row([
        "payload entries".to_string(),
        format!("{} ({} distinct lines, {dedup:.2}x dedup)", t.n_payload_entries, defs),
    ]);
    if t.first_cycle != u64::MAX {
        tbl.row(["issue-cycle span".to_string(), format!("{}..{}", t.first_cycle, t.last_cycle)]);
    }
    tbl.render()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(["app", "ipc"]);
        t.row(["PVC", "1.23"]);
        t.row(["longer-name", "0.5"]);
        let s = t.render();
        assert!(s.contains("| app         | ipc  |"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new(["a", "b"]);
        t.row(["only-one"]);
    }

    #[test]
    fn matrix_includes_summaries() {
        let s = figure_matrix(
            &["x", "y"],
            &[Series { label: "speedup".into(), values: vec![1.0, 4.0] }],
            2,
        );
        assert!(s.contains("GMean"));
        assert!(s.contains("2.00")); // geomean(1,4)
        assert!(s.contains("2.50")); // mean(1,4)
    }

    #[test]
    fn pct_delta_formats() {
        assert_eq!(pct_delta(1.417), "+41.7%");
        assert_eq!(pct_delta(0.9), "-10.0%");
    }
}
