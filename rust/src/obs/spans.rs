//! Per-request trace spans for the serve daemon.
//!
//! Every request line the daemon accepts gets a [`RequestTrace`]: stage
//! timestamps (microseconds since daemon start) through the lifecycle
//! accept → parse → queue → execute → respond, plus the outcome and the
//! request id that is echoed in the JSON response. Completed spans land in
//! a bounded ring ([`TraceLog`]) that the `trace` verb snapshots and
//! `caba prof --serve` renders as Chrome trace JSON
//! (`telemetry::export::server_trace_json`).
//!
//! Stages a request never reached keep the [`UNSET`] sentinel; the wire
//! encoding maps it to JSON `null` and the Perfetto export skips it.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Sentinel for "this stage never happened" (e.g. `t_queued` on a warm
/// hit). Kept out of arithmetic by explicit checks, never subtracted.
pub const UNSET: u64 = u64::MAX;

/// Default ring capacity: enough for a full CI burst plus interactive
/// poking, small enough that the daemon's footprint stays flat.
pub const DEFAULT_SPAN_CAP: usize = 4096;

/// One completed request. All timestamps are µs since daemon start.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RequestTrace {
    /// The id echoed as `"request_id"` in the JSON response.
    pub id: u64,
    /// Verb as received ("sweep", "stats", …, or "?" for unparsable lines).
    pub verb: String,
    /// Sweep requests carry "APP/DESIGN"; other verbs leave it empty.
    pub detail: String,
    /// Terminal state: ok | warm | cold | dedup | shed | deadline |
    /// error | bad_request | draining.
    pub outcome: String,
    /// Line received on the connection thread.
    pub t_accept: u64,
    /// JSON parse + validation finished ([`UNSET`] if parse failed).
    pub t_parsed: u64,
    /// Job enqueued for a worker ([`UNSET`] on warm/dedup/shed paths).
    pub t_queued: u64,
    /// Response rendered back to the client.
    pub t_done: u64,
    /// Time the job spent queued before a worker claimed it (0 if never
    /// queued). For dedup followers this is the leader's queue wait.
    pub queue_wait_us: u64,
    /// Engine execute wall time for the job this request observed
    /// (0 on warm hits).
    pub exec_us: u64,
}

/// Bounded MPMC span ring: completed spans push at the tail, the oldest
/// fall off the head once `cap` is reached, and `dropped` counts the
/// evictions so the `trace` verb can report truncation honestly. A plain
/// mutex is fine here — pushes happen once per *request*, not per
/// simulated cycle, and the critical section is a VecDeque rotate.
pub struct TraceLog {
    ring: Mutex<VecDeque<RequestTrace>>,
    cap: usize,
    dropped: AtomicU64,
}

impl TraceLog {
    pub fn new(cap: usize) -> Self {
        TraceLog {
            ring: Mutex::new(VecDeque::with_capacity(cap.min(1024))),
            cap: cap.max(1),
            dropped: AtomicU64::new(0),
        }
    }

    pub fn push(&self, span: RequestTrace) {
        let mut ring = match self.ring.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        };
        if ring.len() == self.cap {
            ring.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.push_back(span);
    }

    /// Copy of the ring, oldest first.
    pub fn snapshot(&self) -> Vec<RequestTrace> {
        match self.ring.lock() {
            Ok(g) => g.iter().cloned().collect(),
            Err(poison) => poison.into_inner().iter().cloned().collect(),
        }
    }

    /// Spans evicted to honour the capacity bound.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(id: u64) -> RequestTrace {
        RequestTrace {
            id,
            verb: "sweep".into(),
            detail: "SLA/Base".into(),
            outcome: "cold".into(),
            t_accept: id * 10,
            t_parsed: id * 10 + 1,
            t_queued: id * 10 + 2,
            t_done: id * 10 + 9,
            queue_wait_us: 3,
            exec_us: 4,
        }
    }

    #[test]
    fn ring_evicts_oldest_and_counts_drops() {
        let log = TraceLog::new(3);
        for id in 1..=5 {
            log.push(span(id));
        }
        let snap = log.snapshot();
        assert_eq!(snap.iter().map(|s| s.id).collect::<Vec<_>>(), vec![3, 4, 5]);
        assert_eq!(log.dropped(), 2);
    }

    #[test]
    fn snapshot_preserves_fields() {
        let log = TraceLog::new(8);
        log.push(span(7));
        assert_eq!(log.snapshot(), vec![span(7)]);
    }
}
