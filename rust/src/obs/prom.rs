//! Hand-rolled Prometheus text exposition (format version 0.0.4).
//!
//! The offline image has no serde and no prometheus crate, so — like
//! `serve/json.rs` — the writer is built by hand and pinned by a golden
//! test. Only the three shapes the daemon needs are implemented:
//! `counter`, `gauge`, and `histogram` (rendered from a
//! [`HistSnapshot`](super::hist::HistSnapshot) as cumulative
//! `_bucket{le="…"}` lines plus `_sum`/`_count`).
//!
//! Conventions:
//! * metric names are `caba_`-prefixed snake_case, durations suffixed
//!   `_us` (integer microseconds — the native unit of the histograms);
//! * every metric gets exactly one `# HELP` and one `# TYPE` line;
//! * histogram buckets are emitted cumulatively from bucket 0 through the
//!   highest non-empty bucket, then `+Inf`, so scrapes stay small while
//!   still being valid Prometheus histograms.

use super::hist::{bucket_upper_bound, HistSnapshot};
use std::fmt::Write as _;

/// Incremental exposition builder. `into_string` yields the full scrape
/// body, each metric separated by its HELP/TYPE header.
#[derive(Default)]
pub struct PromWriter {
    out: String,
}

impl PromWriter {
    pub fn new() -> Self {
        PromWriter { out: String::new() }
    }

    fn header(&mut self, name: &str, help: &str, kind: &str) {
        debug_assert!(is_valid_metric_name(name), "bad metric name {name}");
        let _ = writeln!(self.out, "# HELP {name} {help}");
        let _ = writeln!(self.out, "# TYPE {name} {kind}");
    }

    pub fn counter(&mut self, name: &str, help: &str, v: u64) {
        self.header(name, help, "counter");
        let _ = writeln!(self.out, "{name} {v}");
    }

    pub fn gauge(&mut self, name: &str, help: &str, v: u64) {
        self.header(name, help, "gauge");
        let _ = writeln!(self.out, "{name} {v}");
    }

    /// Cumulative-bucket histogram. `le` bounds are the inclusive bucket
    /// upper bounds (0, 1, 3, 7, …) in the histogram's own unit.
    pub fn histogram(&mut self, name: &str, help: &str, h: &HistSnapshot) {
        self.header(name, help, "histogram");
        let highest = h
            .buckets
            .iter()
            .rposition(|&b| b != 0)
            .map(|i| i + 1)
            .unwrap_or(0);
        let mut cum = 0u64;
        for (i, &b) in h.buckets.iter().enumerate().take(highest) {
            cum += b;
            let le = bucket_upper_bound(i);
            let _ = writeln!(self.out, "{name}_bucket{{le=\"{le}\"}} {cum}");
        }
        let _ = writeln!(self.out, "{name}_bucket{{le=\"+Inf\"}} {}", h.count);
        let _ = writeln!(self.out, "{name}_sum {}", h.sum);
        let _ = writeln!(self.out, "{name}_count {}", h.count);
    }

    pub fn into_string(self) -> String {
        self.out
    }
}

fn is_valid_metric_name(name: &str) -> bool {
    !name.is_empty()
        && name
            .chars()
            .next()
            .is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Structural validity check used by the daemon tests and CI: every line
/// must be a `# HELP`/`# TYPE` comment or a `name[{labels}] value` sample,
/// every sample must follow a TYPE declaration for its family, and the
/// value must parse as a number. Returns the first offending line.
pub fn validate(text: &str) -> Result<(), String> {
    let mut typed: Vec<String> = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let err = |m: &str| Err(format!("line {}: {m}: {line:?}", ln + 1));
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# ") {
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut it = decl.split_whitespace();
                let (Some(name), Some(kind)) = (it.next(), it.next()) else {
                    return err("malformed TYPE");
                };
                if !matches!(kind, "counter" | "gauge" | "histogram") {
                    return err("unknown metric kind");
                }
                typed.push(name.to_string());
            } else if !rest.starts_with("HELP ") {
                return err("unknown comment");
            }
            continue;
        }
        // Sample line: name, optional {labels}, space, numeric value.
        let (name_labels, value) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return err("no value"),
        };
        if value.parse::<f64>().is_err() && value != "+Inf" {
            return err("non-numeric value");
        }
        let base = name_labels.split('{').next().unwrap_or("");
        if !is_valid_metric_name(base) {
            return err("bad metric name");
        }
        if name_labels.contains('{') && !name_labels.ends_with('}') {
            return err("unterminated label set");
        }
        // The family is the name with histogram suffixes stripped.
        let family = base
            .strip_suffix("_bucket")
            .or_else(|| base.strip_suffix("_sum"))
            .or_else(|| base.strip_suffix("_count"))
            .filter(|f| typed.iter().any(|t| t == f))
            .unwrap_or(base);
        if !typed.iter().any(|t| t == family) {
            return err("sample before TYPE declaration");
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::hist::Histogram;

    /// Golden exposition: the exact byte shape of each metric kind. A
    /// change here is a scrape-format change and must be deliberate.
    #[test]
    fn golden_exposition_format() {
        let h = Histogram::new();
        h.record(0); // bucket 0
        h.record(1); // bucket 1
        h.record(6); // bucket 3 (range 4..=7)
        h.record(7); // bucket 3
        let mut w = PromWriter::new();
        w.counter("caba_serve_requests_total", "Request lines received.", 9);
        w.gauge("caba_serve_queue_depth", "Jobs waiting in queue.", 2);
        w.histogram("caba_queue_wait_us", "Queue wait, microseconds.", &h.snapshot());
        let got = w.into_string();
        let want = "\
# HELP caba_serve_requests_total Request lines received.
# TYPE caba_serve_requests_total counter
caba_serve_requests_total 9
# HELP caba_serve_queue_depth Jobs waiting in queue.
# TYPE caba_serve_queue_depth gauge
caba_serve_queue_depth 2
# HELP caba_queue_wait_us Queue wait, microseconds.
# TYPE caba_queue_wait_us histogram
caba_queue_wait_us_bucket{le=\"0\"} 1
caba_queue_wait_us_bucket{le=\"1\"} 2
caba_queue_wait_us_bucket{le=\"3\"} 2
caba_queue_wait_us_bucket{le=\"7\"} 4
caba_queue_wait_us_bucket{le=\"+Inf\"} 4
caba_queue_wait_us_sum 14
caba_queue_wait_us_count 4
";
        assert_eq!(got, want);
        validate(&got).expect("golden exposition must validate");
    }

    #[test]
    fn empty_histogram_renders_inf_only() {
        let mut w = PromWriter::new();
        w.histogram("caba_empty_us", "Nothing yet.", &HistSnapshot::empty());
        let got = w.into_string();
        assert!(got.contains("caba_empty_us_bucket{le=\"+Inf\"} 0\n"));
        assert!(!got.contains("le=\"0\""));
        validate(&got).unwrap();
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate("caba_x 1").is_err(), "sample before TYPE");
        assert!(validate("# TYPE caba_x counter\ncaba_x one").is_err());
        assert!(validate("# TYPE caba_x widget\ncaba_x 1").is_err());
        assert!(validate("# TYPE caba_x counter\n9bad 1").is_err());
        assert!(validate("# TYPE caba_x counter\ncaba_x{le=\"1\" 1").is_err());
        assert!(validate("# HELP caba_x fine\n# TYPE caba_x counter\ncaba_x 1").is_ok());
    }
}
