//! Service observability: the process-wide metrics layer for the serve /
//! sweep / store stack (DESIGN.md §5d).
//!
//! Three pieces, each allocation-free on its hot path:
//!
//! * [`hist`] — log2-bucketed latency histograms (p50/p95/p99 derivable
//!   from buckets, property-tested against a sorted-vec model);
//! * [`prom`] — hand-rolled Prometheus text exposition (the offline image
//!   has no serde, so the writer is golden-tested bytes);
//! * [`spans`] — bounded per-request trace ring behind the `trace` verb
//!   and `caba prof --serve`.
//!
//! [`ServiceMetrics`] is the daemon's registry: one instance per
//! `serve::Server`, shared as an `Arc` by every connection thread and
//! worker; [`JobMetrics`] is the slice of it the sweep engine accepts via
//! `SweepEngine::with_metrics`, so CLI sweeps and figure regeneration can
//! opt in without dragging the daemon types along.
//!
//! **Observation-only guarantee.** Nothing in this module is reachable
//! from `SimConfig::fingerprint()` or from any simulation decision: the
//! engine hook times `job.execute()` from the *outside*. The contract is
//! pinned by `tests/serve_obs.rs::metrics_do_not_perturb_simulation`,
//! which asserts SimStats bit-identity with metrics on vs off and that
//! the fingerprinted key list did not grow.

pub mod hist;
pub mod prom;
pub mod spans;

pub use hist::{HistSnapshot, Histogram};
pub use prom::PromWriter;
pub use spans::{RequestTrace, TraceLog, DEFAULT_SPAN_CAP, UNSET};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Engine-side metrics: per-job wall time and queue wait, plus ok/failed
/// outcome counts keyed off the `JobError` taxonomy. Shared between the
/// sweep engine's internal work loop and the daemon's worker loop so both
/// feed the same histograms.
#[derive(Default)]
pub struct JobMetrics {
    /// Time from submission (engine `run` start, or daemon enqueue) until
    /// a worker claimed the job. Microseconds.
    pub queue_wait_us: Histogram,
    /// `SweepJob::execute` wall time per executed job. Microseconds.
    pub job_wall_us: Histogram,
    /// Jobs that returned stats.
    pub jobs_ok: AtomicU64,
    /// Jobs that returned a typed `JobError`.
    pub jobs_failed: AtomicU64,
}

impl JobMetrics {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The daemon's metrics registry. Counters and gauges are relaxed
/// `AtomicU64`s — cheap enough to bump on every request without showing
/// up next to a multi-second simulation job.
pub struct ServiceMetrics {
    started: Instant,
    request_seq: AtomicU64,

    // Request-outcome counters (monotonic).
    pub connections: AtomicU64,
    pub requests: AtomicU64,
    pub warm: AtomicU64,
    pub cold: AtomicU64,
    pub dedup: AtomicU64,
    pub shed: AtomicU64,
    pub deadline_expired: AtomicU64,
    pub job_errors: AtomicU64,
    pub bad_requests: AtomicU64,

    // Brownout (adaptive overload shedding): mode transitions, requests
    // shed *because* of brownout (a subset of `shed`), and a 0/1 gauge.
    pub brownout_entered: AtomicU64,
    pub brownout_exited: AtomicU64,
    pub brownout_shed: AtomicU64,
    pub brownout_active: AtomicU64,

    // Queue gauges: live depth and its high-water mark.
    pub queue_depth: AtomicU64,
    pub queue_depth_hwm: AtomicU64,

    /// End-to-end request latency (line received → response rendered).
    pub request_us: Histogram,

    /// The engine-facing slice, handed to `SweepEngine::with_metrics`.
    pub jobs: Arc<JobMetrics>,

    /// Completed request spans for the `trace` verb / Perfetto export.
    pub trace: TraceLog,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        ServiceMetrics {
            started: Instant::now(),
            request_seq: AtomicU64::new(0),
            connections: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            warm: AtomicU64::new(0),
            cold: AtomicU64::new(0),
            dedup: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            job_errors: AtomicU64::new(0),
            bad_requests: AtomicU64::new(0),
            brownout_entered: AtomicU64::new(0),
            brownout_exited: AtomicU64::new(0),
            brownout_shed: AtomicU64::new(0),
            brownout_active: AtomicU64::new(0),
            queue_depth: AtomicU64::new(0),
            queue_depth_hwm: AtomicU64::new(0),
            request_us: Histogram::new(),
            jobs: Arc::new(JobMetrics::new()),
            trace: TraceLog::new(DEFAULT_SPAN_CAP),
        }
    }

    /// Next request id, starting at 1. Ids are per-daemon-lifetime and
    /// echoed in every JSON response for client-side correlation.
    pub fn next_request_id(&self) -> u64 {
        self.request_seq.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Microseconds since the daemon started — the time base every span
    /// timestamp uses.
    pub fn now_us(&self) -> u64 {
        self.started.elapsed().as_micros().min(u64::MAX as u128) as u64
    }

    /// Track a queue push: bumps depth and folds it into the high-water
    /// mark.
    pub fn queue_pushed(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.queue_depth_hwm.fetch_max(depth, Ordering::Relaxed);
    }

    /// Track a queue pop (worker claimed a job).
    pub fn queue_popped(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_ids_are_dense_from_one() {
        let m = ServiceMetrics::new();
        assert_eq!(m.next_request_id(), 1);
        assert_eq!(m.next_request_id(), 2);
        assert_eq!(m.next_request_id(), 3);
    }

    #[test]
    fn queue_hwm_tracks_peak_not_current() {
        let m = ServiceMetrics::new();
        m.queue_pushed();
        m.queue_pushed();
        m.queue_pushed();
        m.queue_popped();
        m.queue_popped();
        assert_eq!(m.queue_depth.load(Ordering::Relaxed), 1);
        assert_eq!(m.queue_depth_hwm.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn now_us_is_monotonic_from_start() {
        let m = ServiceMetrics::new();
        let a = m.now_us();
        let b = m.now_us();
        assert!(b >= a);
    }
}
