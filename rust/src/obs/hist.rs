//! Log2-bucketed latency histogram — the allocation-free primitive under
//! every latency figure the service stack reports.
//!
//! Design constraints (DESIGN.md §5d):
//!
//! * **Lock-cheap hot path.** `record` is two relaxed `fetch_add`s plus one
//!   on the bucket — no mutex, no allocation, shareable behind `&self`
//!   across the daemon's connection and worker threads.
//! * **Percentiles without samples.** Buckets are powers of two: bucket 0
//!   holds the value 0 and bucket `k` (1..=64) holds `[2^(k-1), 2^k - 1]`.
//!   A quantile is answered as the *upper bound* of the first bucket whose
//!   cumulative count reaches the rank, so the reported value `p` brackets
//!   the true order statistic `t` as `t <= p < 2*max(t, 1)` — a guarantee
//!   the property suite (`prop_hist_percentile_brackets_model`) pins
//!   against a sorted-vec model.
//! * **Mergeable.** Snapshots add bucket-wise; merge is associative and
//!   commutative, so per-thread or per-phase histograms can be combined
//!   without coordination (pinned by `prop_hist_merge_associative`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Bucket 0 plus one bucket per possible bit width of a `u64`.
pub const N_BUCKETS: usize = 65;

/// Bucket index for a value: 0 for 0, otherwise its bit width
/// (`64 - leading_zeros`). `2^k` lands in bucket `k+1`, `2^k - 1` in
/// bucket `k` — the power-of-two boundary exactness the unit tests pin.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        (64 - v.leading_zeros()) as usize
    }
}

/// Inclusive upper bound of a bucket: 0, 1, 3, 7, … `u64::MAX`.
#[inline]
pub fn bucket_upper_bound(idx: usize) -> u64 {
    debug_assert!(idx < N_BUCKETS);
    if idx >= 64 {
        u64::MAX
    } else {
        (1u64 << idx) - 1
    }
}

/// Thread-safe histogram. All operations are relaxed atomics: counts are
/// eventually consistent across threads, which is the right contract for
/// observability (the serve protocol never branches on them).
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one value. No allocation, no locks.
    #[inline]
    pub fn record(&self, v: u64) {
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a wall-clock duration in microseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_micros().min(u64::MAX as u128) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// A point-in-time copy of the buckets, for percentile math and the
    /// Prometheus exposition. Reads are relaxed: a snapshot taken while
    /// writers are active is some valid interleaving, not a torn bucket.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Plain-value copy of a histogram: mergeable, queryable, serializable by
/// hand (no serde in the offline image).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; N_BUCKETS],
    pub count: u64,
    pub sum: u64,
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self::empty()
    }
}

impl HistSnapshot {
    pub fn empty() -> Self {
        HistSnapshot {
            buckets: [0; N_BUCKETS],
            count: 0,
            sum: 0,
        }
    }

    /// Bucket-wise sum. Associative and commutative (property-tested), so
    /// any merge tree over per-thread histograms yields the same result.
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| self.buckets[i] + other.buckets[i]),
            count: self.count + other.count,
            sum: self.sum + other.sum,
        }
    }

    /// Bucket-wise difference `self - earlier`, the inverse of
    /// [`HistSnapshot::merge`] for snapshots of the *same* histogram
    /// taken at two times: the result is the window of activity between
    /// them. Saturating per bucket, so a mismatched pair degrades to
    /// zeros instead of wrapping — histogram counters only ever grow, so
    /// a genuine (snapshot, earlier-snapshot) pair never saturates. The
    /// serve brownout controller computes its windowed queue-wait p95
    /// from exactly this delta.
    pub fn delta_since(&self, earlier: &HistSnapshot) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].saturating_sub(earlier.buckets[i])
            }),
            count: self.count.saturating_sub(earlier.count),
            sum: self.sum.saturating_sub(earlier.sum),
        }
    }

    /// Quantile `q` in [0, 1]: the upper bound of the first bucket whose
    /// cumulative count reaches `ceil(q * count)` (clamped to at least 1).
    /// Returns 0 for an empty histogram.
    pub fn percentile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return bucket_upper_bound(i);
            }
        }
        bucket_upper_bound(N_BUCKETS - 1)
    }

    pub fn p50(&self) -> u64 {
        self.percentile(0.50)
    }
    pub fn p95(&self) -> u64 {
        self.percentile(0.95)
    }
    pub fn p99(&self) -> u64 {
        self.percentile(0.99)
    }

    /// Mean of the recorded values (exact, from `sum`/`count`), 0 if empty.
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_exact_at_powers_of_two() {
        // 2^k goes to bucket k+1 (it is that bucket's lower bound);
        // 2^k - 1 goes to bucket k (it is that bucket's upper bound).
        for k in 1..64usize {
            let p = 1u64 << k;
            assert_eq!(bucket_index(p), k + 1, "2^{k}");
            assert_eq!(bucket_index(p - 1), k, "2^{k} - 1");
            assert_eq!(bucket_upper_bound(k), p - 1);
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 1);
        assert_eq!(bucket_index(u64::MAX), 64);
        assert_eq!(bucket_upper_bound(0), 0);
        assert_eq!(bucket_upper_bound(64), u64::MAX);
    }

    #[test]
    fn record_and_percentile_smoke() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 3, 100, 1000, 100_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 7);
        assert_eq!(s.sum, 101_106);
        // Median rank is ceil(0.5*7)=4 → the bucket holding 3 (index 2).
        assert_eq!(s.p50(), 3);
        // p99 rank is 7 → bucket of 100_000 (bit width 17, upper 131071).
        assert_eq!(s.p99(), (1u64 << 17) - 1);
        assert_eq!(s.percentile(0.0), 0); // clamped to rank 1 → value 0
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let s = Histogram::new().snapshot();
        assert_eq!(s, HistSnapshot::empty());
        assert_eq!(s.percentile(0.5), 0);
        assert_eq!(s.mean(), 0.0);
    }

    #[test]
    fn delta_since_inverts_merge_for_growing_histograms() {
        let h = Histogram::new();
        h.record(5);
        h.record(900);
        let early = h.snapshot();
        h.record(5);
        h.record(70_000);
        let late = h.snapshot();
        let win = late.delta_since(&early);
        assert_eq!(win.count, 2);
        assert_eq!(win.sum, 70_005);
        assert_eq!(win.buckets[bucket_index(5)], 1);
        assert_eq!(win.buckets[bucket_index(70_000)], 1);
        assert_eq!(win.buckets[bucket_index(900)], 0);
        // delta ∘ merge round-trips: early.merge(win) == late.
        assert_eq!(early.merge(&win), late);
        // Mismatched order saturates to empty rather than wrapping.
        assert_eq!(early.delta_since(&late).count, 0);
    }

    #[test]
    fn merge_adds_bucketwise() {
        let a = Histogram::new();
        let b = Histogram::new();
        a.record(5);
        a.record(9);
        b.record(5);
        let m = a.snapshot().merge(&b.snapshot());
        assert_eq!(m.count, 3);
        assert_eq!(m.sum, 19);
        assert_eq!(m.buckets[bucket_index(5)], 2);
        assert_eq!(m.buckets[bucket_index(9)], 1);
    }
}
