//! Value-based memoization subsystem (paper §8.1).
//!
//! "In applications limited by available compute resources, memoization
//! offers an opportunity to trade off computation for storage": assist
//! warps hash the operand values of expensive (SFU) computations, probe a
//! look-up table kept in the **unutilized shared memory**, and on a hit
//! skip the computation entirely, loading the previous result from on-chip
//! storage instead.
//!
//! Unlike the original reproduction stub (a per-app probability draw from
//! a hard-coded redundancy table), this is a *real* capacity-bounded
//! structure: one [`MemoLut`] per SM, set-associative, tagged by a hash of
//! the actual operand values flowing through the workload
//! ([`crate::workload::values`]). Hit rates **emerge** from the data:
//!
//! * capacity is carved from whatever shared memory the resident CTAs
//!   leave unallocated ([`MemoGeometry::for_workload`]) — an app that
//!   fills its shared memory gets a smaller (or no) LUT;
//! * entries are installed on a miss by a *low-priority* assist warp, so
//!   results only become reusable once the install retires;
//! * eviction is LRU within a set, and tag truncation
//!   (`memo_tag_bits`) models aliasing — a probe can match an entry
//!   installed for a *different* operand tuple (counted separately as
//!   `memo_alias_hits`).
//!
//! The trigger point is the SFU issue path in [`crate::core`]: a
//! high-priority lookup subroutine (hash + tag-probe/load + select) runs
//! through the [`crate::caba::Awc`]; the parent's destination register is
//! released when the lookup retires. On a hit the SFU pipeline is never
//! occupied (the result comes from shared memory); on a miss the SFU
//! computes and an install subroutine writes the result back.

use crate::config::SimConfig;
use crate::sim::designs::Design;
use crate::util::mix64;
use crate::workload::Workload;

/// Lookup subroutine: hash inputs (1 ALU), tag-probe+load (1 mem), select.
pub const LOOKUP_SUB_TOTAL: u16 = 3;
pub const LOOKUP_SUB_MEM: u16 = 1;
/// Result-install subroutine on a miss (low priority): address + store.
pub const INSTALL_SUB_TOTAL: u16 = 2;
pub const INSTALL_SUB_MEM: u16 = 1;

/// LUT hit latency: an on-chip shared-memory access (must beat the SFU).
pub const LUT_HIT_LATENCY: u64 = 24;

/// Shape of one SM's LUT, derived from the configuration and the
/// workload's shared-memory occupancy. `sets == 0` means memoization is
/// structurally impossible (no free shared memory, or the design doesn't
/// memoize) — every probe reports [`Lookup::Disabled`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MemoGeometry {
    pub sets: usize,
    pub ways: usize,
    /// Modeled hardware cost per entry (tag + result + LRU bookkeeping).
    pub entry_bytes: usize,
    /// Stored-tag width; truncation below the full hash models aliasing.
    pub tag_bits: u32,
    /// Shared-memory bytes actually claimed (`sets × ways × entry_bytes`).
    pub budget_bytes: usize,
}

impl MemoGeometry {
    /// A zero-capacity geometry (non-memo designs, exhausted smem).
    pub const fn disabled() -> MemoGeometry {
        MemoGeometry { sets: 0, ways: 0, entry_bytes: 0, tag_bits: 0, budget_bytes: 0 }
    }

    /// Explicit geometry (tests and what-if tools). `tag_bits` is clamped
    /// to `1..=63` like [`MemoGeometry::for_workload`] — a 64-bit shift in
    /// `tag_of` would overflow.
    pub fn explicit(sets: usize, ways: usize, entry_bytes: usize, tag_bits: u32) -> MemoGeometry {
        MemoGeometry {
            sets,
            ways,
            entry_bytes,
            tag_bits: tag_bits.clamp(1, 63),
            budget_bytes: sets * ways * entry_bytes,
        }
    }

    /// Carve the LUT out of the shared memory the resident CTAs leave
    /// unallocated, capped by the `memo_lut_bytes` budget knob. The
    /// workload's occupancy decides how much is free: `smem_per_sm −
    /// ctas_per_sm × smem_per_cta`.
    pub fn for_workload(cfg: &SimConfig, design: &Design, wl: &Workload) -> MemoGeometry {
        if !design.memoization {
            return MemoGeometry::disabled();
        }
        let used = wl.occ.ctas_per_sm as usize * wl.spec.smem_per_cta as usize;
        let avail = cfg.smem_per_sm.saturating_sub(used);
        let budget = avail.min(cfg.memo_lut_bytes);
        let entry_bytes = cfg.memo_entry_bytes.max(1);
        let ways = cfg.memo_lut_ways.max(1);
        let sets = budget / entry_bytes / ways;
        if sets == 0 {
            return MemoGeometry::disabled();
        }
        MemoGeometry {
            sets,
            ways,
            entry_bytes,
            tag_bits: cfg.memo_tag_bits.clamp(1, 63),
            budget_bytes: sets * ways * entry_bytes,
        }
    }

    pub fn capacity_entries(&self) -> usize {
        self.sets * self.ways
    }
}

/// Outcome of one LUT probe.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lookup {
    /// Stored tag matched and the entry really was installed for this
    /// operand tuple.
    Hit,
    /// Stored (truncated) tag matched but the entry belongs to a
    /// *different* operand tuple — the aliasing the tag width allows.
    AliasHit,
    Miss,
    /// The LUT has zero capacity (no free shared memory).
    Disabled,
}

#[derive(Clone, Copy, Debug)]
struct Entry {
    /// Full operand key — model-side bookkeeping to *detect* aliasing;
    /// the modeled hardware stores only the truncated tag.
    full: u64,
    tag: u64,
    last_used: u64,
    valid: bool,
}

impl Entry {
    const EMPTY: Entry = Entry { full: 0, tag: 0, last_used: 0, valid: false };
}

/// One SM's memoization look-up table. All counters (lookups, hits,
/// aliases, installs, evictions) are tallied by the core into
/// [`crate::stats::CabaStats`] — install/evict events via
/// [`MemoLut::install`]'s return value — so the stats have exactly one
/// home next to the other assist-warp activity.
pub struct MemoLut {
    geom: MemoGeometry,
    entries: Vec<Entry>,
    occupancy: usize,
}

impl MemoLut {
    pub fn new(geom: MemoGeometry) -> MemoLut {
        MemoLut {
            entries: vec![Entry::EMPTY; geom.capacity_entries()],
            geom,
            occupancy: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.geom.sets > 0
    }

    pub fn geometry(&self) -> &MemoGeometry {
        &self.geom
    }

    /// Valid entries currently resident (≤ [`MemoGeometry::capacity_entries`]).
    pub fn occupancy(&self) -> usize {
        self.occupancy
    }

    pub fn capacity(&self) -> usize {
        self.geom.capacity_entries()
    }

    fn set_of(&self, key: u64) -> usize {
        (mix64(key) as usize) % self.geom.sets
    }

    fn tag_of(&self, key: u64) -> u64 {
        mix64(key ^ 0xA5A5_5A5A_C0FF_EE00) & ((1u64 << self.geom.tag_bits) - 1)
    }

    /// Non-mutating probe: would `key` hit right now? Used by the
    /// scheduler's structural check — a would-hit SFU op bypasses the busy
    /// SFU pipeline (the §8.1 point: storage instead of computation).
    pub fn would_hit(&self, key: u64) -> bool {
        if !self.enabled() {
            return false;
        }
        let set = self.set_of(key);
        let tag = self.tag_of(key);
        let base = set * self.geom.ways;
        self.entries[base..base + self.geom.ways]
            .iter()
            .any(|e| e.valid && e.tag == tag)
    }

    /// Probe for `key` at cycle `now` (a hit refreshes the entry's LRU
    /// position — the hardware would, too).
    pub fn lookup(&mut self, key: u64, now: u64) -> Lookup {
        if !self.enabled() {
            return Lookup::Disabled;
        }
        let set = self.set_of(key);
        let tag = self.tag_of(key);
        let base = set * self.geom.ways;
        for e in &mut self.entries[base..base + self.geom.ways] {
            if e.valid && e.tag == tag {
                e.last_used = now;
                return if e.full == key { Lookup::Hit } else { Lookup::AliasHit };
            }
        }
        Lookup::Miss
    }

    /// Install the result for `key` (called when the install assist warp
    /// retires). Returns true when a valid entry was evicted to make room.
    pub fn install(&mut self, key: u64, now: u64) -> bool {
        if !self.enabled() {
            return false;
        }
        let set = self.set_of(key);
        let tag = self.tag_of(key);
        let base = set * self.geom.ways;
        let ways = &mut self.entries[base..base + self.geom.ways];
        // 1. Same tag already present (a racing warp installed first, or an
        //    alias): refresh in place — occupancy unchanged, no eviction.
        if let Some(e) = ways.iter_mut().find(|e| e.valid && e.tag == tag) {
            e.full = key;
            e.last_used = now;
            return false;
        }
        // 2. Free way.
        if let Some(e) = ways.iter_mut().find(|e| !e.valid) {
            *e = Entry { full: key, tag, last_used: now, valid: true };
            self.occupancy += 1;
            return false;
        }
        // 3. Evict LRU (lowest last_used; ties resolve to the lowest way —
        //    deterministic).
        let victim = (0..ways.len())
            .min_by_key(|&i| (ways[i].last_used, i))
            .expect("ways is non-empty when enabled");
        ways[victim] = Entry { full: key, tag, last_used: now, valid: true };
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lut(sets: usize, ways: usize) -> MemoLut {
        MemoLut::new(MemoGeometry::explicit(sets, ways, 16, 16))
    }

    #[test]
    fn lookup_install_lifecycle() {
        let mut l = lut(4, 2);
        assert_eq!(l.lookup(42, 0), Lookup::Miss);
        assert!(!l.install(42, 1), "first install must not evict");
        assert_eq!(l.lookup(42, 2), Lookup::Hit);
        assert_eq!(l.occupancy(), 1);
    }

    #[test]
    fn capacity_bounded_with_eviction() {
        let mut l = lut(2, 2);
        let mut evictions = 0;
        for k in 0..64u64 {
            if l.lookup(k, k) == Lookup::Miss && l.install(k, k) {
                evictions += 1;
            }
            assert!(l.occupancy() <= l.capacity());
        }
        assert_eq!(l.occupancy(), l.capacity());
        assert!(evictions > 0);
    }

    #[test]
    fn lru_keeps_hot_entries() {
        // One set, two ways: keep key 1 hot; keys 2,3 fight over the other way.
        let mut l = lut(1, 2);
        l.install(1, 0);
        l.install(2, 1);
        assert_eq!(l.lookup(1, 2), Lookup::Hit); // refresh key 1
        l.install(3, 3); // must evict key 2, not 1
        assert_eq!(l.lookup(1, 4), Lookup::Hit);
        assert_eq!(l.lookup(2, 5), Lookup::Miss);
        assert_eq!(l.lookup(3, 6), Lookup::Hit);
    }

    #[test]
    fn narrow_tags_alias() {
        // 1-bit tags: distinct keys in the same set collide almost surely.
        let mut l = MemoLut::new(MemoGeometry::explicit(1, 4, 16, 1));
        l.install(7, 0);
        let aliased = (0..64u64)
            .filter(|&k| k != 7 && matches!(l.lookup(k, 1), Lookup::AliasHit))
            .count();
        assert!(aliased > 0, "1-bit tags must alias");
        // Wide tags on the same keys: no alias observed.
        let mut w = MemoLut::new(MemoGeometry::explicit(1, 4, 16, 48));
        w.install(7, 0);
        let aliased = (0..64u64)
            .filter(|&k| k != 7 && matches!(w.lookup(k, 1), Lookup::AliasHit))
            .count();
        assert_eq!(aliased, 0);
    }

    #[test]
    fn bigger_lut_hits_more_on_the_same_stream() {
        // Capacity sensitivity, deterministically: the same head-skewed
        // operand stream through a 1024-entry LUT vs a 16-entry LUT.
        use crate::workload::values::{operand_key, ValueSpec};
        let vs = ValueSpec::shared(1.0, 4096);
        let run = |mut lut: MemoLut| -> u64 {
            let mut hits = 0;
            for i in 0..6000u64 {
                let key = operand_key(&vs, 0xCABA, i % 32, (i / 32) as u32, 3);
                match lut.lookup(key, i) {
                    Lookup::Hit | Lookup::AliasHit => hits += 1,
                    Lookup::Miss => {
                        lut.install(key, i);
                    }
                    Lookup::Disabled => unreachable!(),
                }
            }
            hits
        };
        let big = run(MemoLut::new(MemoGeometry::explicit(256, 4, 16, 16)));
        let small = run(MemoLut::new(MemoGeometry::explicit(4, 4, 16, 16)));
        assert!(
            big > small * 3 / 2,
            "capacity should move hits: big {big} vs small {small}"
        );
        assert!(small > 0, "even 16 entries must catch the hottest classes");
    }

    #[test]
    fn disabled_geometry_never_hits_or_installs() {
        let mut l = MemoLut::new(MemoGeometry::disabled());
        assert!(!l.enabled());
        assert_eq!(l.lookup(1, 0), Lookup::Disabled);
        assert!(!l.install(1, 0));
        assert!(!l.would_hit(1));
        assert_eq!(l.occupancy(), 0);
    }

    #[test]
    fn geometry_from_workload_respects_smem_budget() {
        use crate::workload::{apps, Workload};
        let cfg = SimConfig::default();
        // smem-free app: full budget.
        let wl = Workload::build(apps::find("FRAG").unwrap(), &cfg, 0.05);
        let g = MemoGeometry::for_workload(&cfg, &Design::caba_memo(), &wl);
        assert!(g.sets > 0);
        assert_eq!(g.budget_bytes, cfg.memo_lut_bytes);
        assert!(g.budget_bytes <= cfg.smem_per_sm);
        // smem-hungry app: LUT shrinks to what's left.
        let wl = Workload::build(apps::find("hs").unwrap(), &cfg, 0.05);
        let used = wl.occ.ctas_per_sm as usize * wl.spec.smem_per_cta as usize;
        let g = MemoGeometry::for_workload(&cfg, &Design::caba_memo(), &wl);
        assert!(g.budget_bytes <= cfg.smem_per_sm - used);
        // Non-memo design: disabled.
        let g = MemoGeometry::for_workload(&cfg, &Design::base(), &wl);
        assert_eq!(g, MemoGeometry::disabled());
    }

    #[test]
    fn lookup_cheaper_than_sfu() {
        // The trade only makes sense if the LUT path beats the SFU latency.
        assert!(LUT_HIT_LATENCY < SimConfig::default().sfu_latency as u64);
    }
}
