//! Event-based energy model (the role GPUWattch + CACTI play in §6).
//!
//! Energy = Σ(event count × per-event energy) + static power × time. The
//! per-event coefficients are drawn from the GPUWattch-class breakdowns for
//! a Fermi-era 40nm GPU (per-instruction core energy, per-access cache
//! energies, per-flit NoC energy, per-burst GDDR5 energy and per-activate
//! row energy). Figures 10–11 compare *relative* energy between designs
//! sharing these coefficients, which is what the paper's conclusions rest
//! on; absolute joules are not claimed (DESIGN.md §3).

use crate::stats::SimStats;

/// Per-event energies in nanojoules, plus static power in W.
#[derive(Clone, Copy, Debug)]
pub struct EnergyModel {
    /// One warp instruction through fetch/decode/RF/execute (≈32 lanes).
    pub core_inst_nj: f64,
    /// One assist-warp instruction (same pipelines; slightly cheaper —
    /// no fetch/decode, instructions come from the AWS buffer).
    pub assist_inst_nj: f64,
    /// L1 / shared-memory access.
    pub l1_access_nj: f64,
    /// L2 slice access.
    pub l2_access_nj: f64,
    /// One 32B NoC flit through the crossbar.
    pub icnt_flit_nj: f64,
    /// One 32B GDDR5 data burst (I/O + DRAM core read/write).
    pub dram_burst_nj: f64,
    /// One row activate+precharge.
    pub dram_activate_nj: f64,
    /// MD-cache access (8KB SRAM, CACTI-class).
    pub md_access_nj: f64,
    /// Dedicated BDI logic op (Synopsys 65nm → 32nm scaled; paper §6).
    pub hw_compressor_op_nj: f64,
    /// Chip static (leakage + constant clocking) power in watts.
    pub static_w: f64,
    /// Extra static power of the CABA structures (AWS+AWC+AWB, ~atoms).
    pub caba_static_w: f64,
    /// Extra static power of dedicated compressor logic (HW designs).
    pub hw_static_w: f64,
    /// Core clock GHz (converts cycles → seconds).
    pub clock_ghz: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        EnergyModel {
            core_inst_nj: 1.6,
            assist_inst_nj: 1.3,
            l1_access_nj: 0.35,
            l2_access_nj: 0.9,
            icnt_flit_nj: 0.6,
            dram_burst_nj: 6.5,
            dram_activate_nj: 2.2,
            md_access_nj: 0.02,
            hw_compressor_op_nj: 0.10,
            static_w: 42.0,
            caba_static_w: 0.12,
            hw_static_w: 0.25,
            clock_ghz: 1.4,
        }
    }
}

/// Energy breakdown for one run, in millijoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyBreakdown {
    pub core_mj: f64,
    pub assist_mj: f64,
    pub l1_mj: f64,
    pub l2_mj: f64,
    pub icnt_mj: f64,
    pub dram_mj: f64,
    pub md_mj: f64,
    pub hw_comp_mj: f64,
    pub static_mj: f64,
}

impl EnergyBreakdown {
    pub fn total_mj(&self) -> f64 {
        self.core_mj
            + self.assist_mj
            + self.l1_mj
            + self.l2_mj
            + self.icnt_mj
            + self.dram_mj
            + self.md_mj
            + self.hw_comp_mj
            + self.static_mj
    }

    /// DRAM-attributed energy (the paper reports a 29.5% DRAM power
    /// reduction under CABA-BDI).
    pub fn dram_total_mj(&self) -> f64 {
        self.dram_mj
    }

    /// Average power in watts given the run length.
    pub fn avg_power_w(&self, cycles: u64, clock_ghz: f64) -> f64 {
        let seconds = cycles as f64 / (clock_ghz * 1e9);
        if seconds == 0.0 {
            0.0
        } else {
            self.total_mj() * 1e-3 / seconds
        }
    }
}

impl EnergyModel {
    /// Evaluate a run. `has_caba`/`has_hw` add the respective structures'
    /// static power.
    pub fn evaluate(&self, stats: &SimStats, has_caba: bool, has_hw: bool) -> EnergyBreakdown {
        let e = &stats.energy_events;
        let nj = |count: u64, per: f64| count as f64 * per * 1e-6; // nJ → mJ
        let seconds = stats.cycles as f64 / (self.clock_ghz * 1e9);
        let static_w = self.static_w
            + if has_caba { self.caba_static_w } else { 0.0 }
            + if has_hw { self.hw_static_w } else { 0.0 };
        EnergyBreakdown {
            core_mj: nj(e.core_insts, self.core_inst_nj),
            assist_mj: nj(e.assist_insts, self.assist_inst_nj),
            l1_mj: nj(e.l1_accesses, self.l1_access_nj),
            l2_mj: nj(e.l2_accesses, self.l2_access_nj),
            icnt_mj: nj(e.icnt_flits, self.icnt_flit_nj),
            dram_mj: nj(e.dram_bursts, self.dram_burst_nj)
                + nj(e.dram_activates, self.dram_activate_nj),
            md_mj: nj(e.md_cache_accesses, self.md_access_nj),
            hw_comp_mj: nj(e.hw_compressor_ops, self.hw_compressor_op_nj),
            static_mj: static_w * seconds * 1e3,
        }
    }

    /// Energy-delay product in mJ·s (Fig. 11 uses normalized values).
    pub fn edp(&self, stats: &SimStats, has_caba: bool, has_hw: bool) -> f64 {
        let e = self.evaluate(stats, has_caba, has_hw);
        let seconds = stats.cycles as f64 / (self.clock_ghz * 1e9);
        e.total_mj() * seconds
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::EnergyEvents;

    fn stats_with(events: EnergyEvents, cycles: u64) -> SimStats {
        let mut s = SimStats::default();
        s.energy_events = events;
        s.cycles = cycles;
        s
    }

    #[test]
    fn fewer_bursts_less_dram_energy() {
        let m = EnergyModel::default();
        let a = stats_with(
            EnergyEvents { dram_bursts: 1000, ..Default::default() },
            1000,
        );
        let b = stats_with(
            EnergyEvents { dram_bursts: 400, ..Default::default() },
            1000,
        );
        let ea = m.evaluate(&a, false, false);
        let eb = m.evaluate(&b, false, false);
        assert!(eb.dram_mj < ea.dram_mj);
        assert!(eb.total_mj() < ea.total_mj());
    }

    #[test]
    fn shorter_run_less_static_energy() {
        let m = EnergyModel::default();
        let long = m.evaluate(&stats_with(Default::default(), 2_000_000), false, false);
        let short = m.evaluate(&stats_with(Default::default(), 1_000_000), false, false);
        assert!((long.static_mj / short.static_mj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn caba_and_hw_static_adders() {
        let m = EnergyModel::default();
        let s = stats_with(Default::default(), 1_000_000);
        let plain = m.evaluate(&s, false, false).total_mj();
        let caba = m.evaluate(&s, true, false).total_mj();
        let hw = m.evaluate(&s, false, true).total_mj();
        assert!(caba > plain);
        assert!(hw > caba, "dedicated logic costs more static power than CABA");
    }

    #[test]
    fn edp_scales_with_delay_squared() {
        let m = EnergyModel::default();
        // Same events, double the cycles → >2× EDP (static energy grows too).
        let e1 = m.edp(&stats_with(Default::default(), 1_000_000), false, false);
        let e2 = m.edp(&stats_with(Default::default(), 2_000_000), false, false);
        assert!(e2 > 3.9 * e1, "e1={e1} e2={e2}");
    }

    #[test]
    fn avg_power_sane() {
        let m = EnergyModel::default();
        let s = stats_with(Default::default(), 1_400_000_000); // 1 second
        let e = m.evaluate(&s, false, false);
        let p = e.avg_power_w(s.cycles, m.clock_ghz);
        assert!((p - m.static_w).abs() < 1.0, "p={p}");
    }
}
