//! Set-associative cache model with LRU replacement and an optional
//! *compressed-capacity* mode (paper §7.5 / Fig. 15): with `tag_mult` > 1
//! the cache holds `assoc × tag_mult` tags per set, and lines occupy data
//! space proportional to their compressed size in 32B sectors, so a set can
//! hold more (compressed) lines than its nominal associativity — exactly
//! the "2×/4× the number of tags" design the paper evaluates.

use crate::stats::CacheStats;

/// Per-line metadata.
#[derive(Clone, Copy, Debug)]
pub struct Entry {
    pub tag: u64,
    pub valid: bool,
    pub dirty: bool,
    /// Data-space occupancy in 32B sectors (4 = uncompressed 128B line).
    pub sectors: u8,
    /// Transfer size in DRAM bursts when this line moves (compressed size).
    pub bursts: u8,
    /// Is the stored copy in compressed form (needs decompression on use)?
    pub compressed: bool,
    pub last_use: u64,
}

const INVALID: Entry = Entry {
    tag: 0,
    valid: false,
    dirty: false,
    sectors: 0,
    bursts: 0,
    compressed: false,
    last_use: 0,
};

/// An evicted line that must be written back.
#[derive(Clone, Copy, Debug)]
pub struct Eviction {
    pub line_addr: u64,
    pub bursts: u8,
    pub compressed: bool,
}

/// Set-associative cache over 128B-line addresses (line numbers, not bytes).
pub struct Cache {
    n_sets: usize,
    /// Tag slots per set (assoc × tag_mult).
    tags_per_set: usize,
    /// Data budget per set in sectors (assoc × 4).
    sectors_per_set: usize,
    sets: Vec<Entry>,
    pub stats: CacheStats,
}

impl Cache {
    /// `bytes`/`assoc` as usual; `tag_mult` = 1 for a normal cache, 2 or 4
    /// for the compressed-capacity configurations of Fig. 15.
    pub fn new(bytes: usize, assoc: usize, line_bytes: usize, tag_mult: usize) -> Cache {
        let n_lines = bytes / line_bytes;
        let n_sets = (n_lines / assoc).max(1);
        let tags_per_set = assoc * tag_mult;
        Cache {
            n_sets,
            tags_per_set,
            sectors_per_set: assoc * (line_bytes / 32),
            sets: vec![INVALID; n_sets * tags_per_set],
            stats: CacheStats::default(),
        }
    }

    fn set_index(&self, line_addr: u64) -> usize {
        // Mix the address so the `1<<40` array-stride layout doesn't alias
        // every array onto the same sets.
        let mut z = line_addr.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        z ^= z >> 29;
        (z as usize) % self.n_sets
    }

    fn set(&mut self, idx: usize) -> &mut [Entry] {
        let s = idx * self.tags_per_set;
        &mut self.sets[s..s + self.tags_per_set]
    }

    /// Look up a line; updates LRU and hit/miss stats. Returns the entry's
    /// (bursts, compressed) on hit.
    pub fn probe(&mut self, line_addr: u64, now: u64) -> Option<(u8, bool)> {
        self.stats.accesses += 1;
        let idx = self.set_index(line_addr);
        let mut hit = None;
        for e in self.set(idx).iter_mut() {
            if e.valid && e.tag == line_addr {
                e.last_use = now;
                hit = Some((e.bursts, e.compressed));
                break;
            }
        }
        if hit.is_some() {
            self.stats.hits += 1;
        } else {
            self.stats.misses += 1;
        }
        hit
    }

    /// Peek without touching stats or LRU (used by tests and the MD path).
    pub fn contains(&self, line_addr: u64) -> bool {
        let idx = self.set_index(line_addr);
        let s = idx * self.tags_per_set;
        self.sets[s..s + self.tags_per_set]
            .iter()
            .any(|e| e.valid && e.tag == line_addr)
    }

    /// Mark a resident line dirty (store hit). Returns false if not present.
    pub fn mark_dirty(&mut self, line_addr: u64, bursts: u8, compressed: bool, now: u64) -> bool {
        let idx = self.set_index(line_addr);
        for e in self.set(idx).iter_mut() {
            if e.valid && e.tag == line_addr {
                e.dirty = true;
                e.bursts = bursts;
                e.compressed = compressed;
                e.sectors = if compressed { bursts } else { 4 };
                e.last_use = now;
                return true;
            }
        }
        false
    }

    /// Insert a line, evicting as needed. In compressed mode a fill may
    /// evict multiple victims to free enough data sectors; dirty victims
    /// are returned for writeback.
    ///
    /// Allocating convenience wrapper over [`Cache::insert_into`] (tests
    /// and cold paths); the simulator hot path passes a reusable scratch.
    pub fn insert(
        &mut self,
        line_addr: u64,
        dirty: bool,
        bursts: u8,
        compressed: bool,
        now: u64,
    ) -> Vec<Eviction> {
        let mut evictions = Vec::new();
        self.insert_into(line_addr, dirty, bursts, compressed, now, &mut evictions);
        evictions
    }

    /// [`Cache::insert`] writing dirty victims into a caller-provided
    /// scratch buffer (cleared first) — no allocation once the scratch has
    /// grown to the workload's eviction fan-out.
    pub fn insert_into(
        &mut self,
        line_addr: u64,
        dirty: bool,
        bursts: u8,
        compressed: bool,
        now: u64,
        evictions: &mut Vec<Eviction>,
    ) {
        evictions.clear();
        let sectors = if compressed { bursts.max(1) } else { 4 };
        let idx = self.set_index(line_addr);
        let sectors_budget = self.sectors_per_set;
        let set = self.set(idx);

        // Already present (e.g., refill of an updated line): update in place.
        if let Some(e) = set.iter_mut().find(|e| e.valid && e.tag == line_addr) {
            e.dirty |= dirty;
            e.bursts = bursts;
            e.compressed = compressed;
            e.sectors = sectors;
            e.last_use = now;
            return;
        }

        // Evict until both a tag slot and enough data sectors are free.
        let mut evicted_total = 0u64;
        loop {
            let used: u32 = set.iter().filter(|e| e.valid).map(|e| e.sectors as u32).sum();
            let free_tag = set.iter().any(|e| !e.valid);
            if free_tag && used + sectors as u32 <= sectors_budget as u32 {
                break;
            }
            // Evict LRU.
            let victim = set
                .iter_mut()
                .filter(|e| e.valid)
                .min_by_key(|e| e.last_use)
                .expect("set cannot be empty here");
            if victim.dirty {
                evictions.push(Eviction {
                    line_addr: victim.tag,
                    bursts: victim.bursts,
                    compressed: victim.compressed,
                });
            }
            *victim = INVALID;
            evicted_total += 1;
        }
        let slot = set.iter_mut().find(|e| !e.valid).unwrap();
        *slot = Entry {
            tag: line_addr,
            valid: true,
            dirty,
            sectors,
            bursts,
            compressed,
            last_use: now,
        };
        self.stats.evictions += evicted_total;
    }

    /// Drop a line if present (write-through no-allocate stores).
    pub fn invalidate(&mut self, line_addr: u64) {
        let idx = self.set_index(line_addr);
        for e in self.set(idx).iter_mut() {
            if e.valid && e.tag == line_addr {
                *e = INVALID;
                return;
            }
        }
    }

    /// Nominal capacity in lines (ignoring compression).
    pub fn capacity_lines(&self) -> usize {
        self.n_sets * self.sectors_per_set / 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Cache {
        // 4 sets × 4 ways of 128B lines = 2KB.
        Cache::new(2048, 4, 128, 1)
    }

    #[test]
    fn hit_after_insert() {
        let mut c = small();
        assert!(c.probe(42, 0).is_none());
        c.insert(42, false, 4, false, 1);
        assert_eq!(c.probe(42, 2), Some((4, false)));
        assert_eq!(c.stats.hits, 1);
        assert_eq!(c.stats.misses, 1);
    }

    #[test]
    fn lru_eviction_order() {
        let mut c = small();
        // Fill one set: find 5 addresses in the same set.
        let mut addrs = Vec::new();
        let target = {
            let c2 = small();
            c2.set_index(1)
        };
        let mut a = 0u64;
        while addrs.len() < 5 {
            if small().set_index(a) == target {
                addrs.push(a);
            }
            a += 1;
        }
        for (t, &addr) in addrs[..4].iter().enumerate() {
            c.insert(addr, false, 4, false, t as u64);
        }
        // Touch addrs[0] so addrs[1] becomes LRU.
        c.probe(addrs[0], 10);
        c.insert(addrs[4], false, 4, false, 11);
        assert!(c.contains(addrs[0]));
        assert!(!c.contains(addrs[1]), "LRU victim should be evicted");
        assert!(c.contains(addrs[4]));
    }

    #[test]
    fn dirty_eviction_returned() {
        let mut c = small();
        let target = small().set_index(7);
        let mut addrs = Vec::new();
        let mut a = 0u64;
        while addrs.len() < 5 {
            if small().set_index(a) == target {
                addrs.push(a);
            }
            a += 1;
        }
        c.insert(addrs[0], true, 3, true, 0);
        for (t, &addr) in addrs[1..4].iter().enumerate() {
            c.insert(addr, false, 4, false, 1 + t as u64);
        }
        let ev = c.insert(addrs[4], false, 4, false, 10);
        assert_eq!(ev.len(), 1);
        assert_eq!(ev[0].line_addr, addrs[0]);
        assert_eq!(ev[0].bursts, 3);
        assert!(ev[0].compressed);
    }

    #[test]
    fn compressed_mode_holds_more_lines() {
        // 1 set × 4 ways, tag_mult 4 → 16 tags, 16 sectors of data.
        let mut c = Cache::new(512, 4, 128, 4);
        // 1-sector (fully compressed) lines: 16 should fit where 4 did.
        for i in 0..16u64 {
            c.insert(i, false, 1, true, i);
        }
        let resident = (0..16u64).filter(|&i| c.contains(i)).count();
        assert_eq!(resident, 16);
        // Uncompressed lines: only 4 fit.
        let mut c2 = Cache::new(512, 4, 128, 4);
        for i in 0..16u64 {
            c2.insert(i, false, 4, false, i);
        }
        let resident2 = (0..16u64).filter(|&i| c2.contains(i)).count();
        assert_eq!(resident2, 4);
    }

    #[test]
    fn compressed_insert_may_evict_multiple() {
        let mut c = Cache::new(512, 4, 128, 4); // 16 sectors
        for i in 0..16u64 {
            c.insert(i, false, 1, true, i);
        }
        // Inserting an uncompressed line (4 sectors) evicts ≥4 victims.
        c.insert(100, false, 4, false, 100);
        let resident = (0..16u64).filter(|&i| c.contains(i)).count();
        assert!(resident <= 12, "resident={resident}");
        assert!(c.contains(100));
    }

    #[test]
    fn mark_dirty_only_if_present() {
        let mut c = small();
        assert!(!c.mark_dirty(9, 4, false, 0));
        c.insert(9, false, 4, false, 0);
        assert!(c.mark_dirty(9, 2, true, 1));
        // Evict it and confirm the dirty metadata travels.
        let set = c.set_index(9);
        let mut a = 1000u64;
        let mut n = 0;
        while n < 8 {
            if c.set_index(a) == set {
                c.insert(a, false, 4, false, 10 + a);
                n += 1;
            }
            a += 1;
        }
        assert!(!c.contains(9));
    }

    #[test]
    fn update_in_place_no_eviction() {
        let mut c = small();
        c.insert(5, false, 4, false, 0);
        let ev = c.insert(5, true, 2, true, 1);
        assert!(ev.is_empty());
        assert_eq!(c.probe(5, 2), Some((2, true)));
    }
}
