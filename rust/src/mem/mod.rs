//! The memory subsystem: sliced L2, crossbar, GDDR5 channels and MD caches,
//! wired together by [`MemSystem`].
//!
//! Requests resolve their timing when injected, by reserving the shared
//! resources they traverse (crossbar ports, DRAM banks, data buses) — a
//! reservation-based contention model that preserves bandwidth saturation,
//! row locality and queueing while keeping the simulator fast (DESIGN.md §3).

pub mod cache;
pub mod dram;
pub mod icnt;
pub mod mdcache;

use crate::compress::oracle::LineVerdict;
use crate::config::SimConfig;
use crate::sim::designs::{Design, Mechanism};

use cache::Cache;
use dram::DramChannel;
use icnt::Crossbar;
use mdcache::MdCache;

/// Result of a load reaching the SM.
#[derive(Clone, Copy, Debug)]
pub struct LoadOutcome {
    /// Cycle at which the line data is available at the requesting SM.
    pub data_at: u64,
    /// `Some((encoding, bursts))` if the line arrives in compressed form
    /// and the core must decompress it (assist warp / dedicated logic).
    pub arrives_compressed: Option<(u8, u8)>,
    /// Whether this access hit in the L2.
    pub l2_hit: bool,
}

/// The chip's shared memory system.
pub struct MemSystem {
    pub l2: Vec<Cache>,
    pub dram: Vec<DramChannel>,
    pub md: Vec<MdCache>,
    pub icnt: Crossbar,
    l2_hit_latency: f64,
    l2_tag_latency: f64,
    hw_dec: f64,
    hw_comp: f64,
    n_mcs: usize,
    /// Dedicated-logic compression ops performed (HW designs).
    pub hw_compressor_ops: u64,
    /// L2 accesses (loads + stores + writebacks) for the energy model.
    pub l2_accesses: u64,
    /// Reusable dirty-victim scratch for L2 fills (no per-access `Vec`).
    evict_scratch: Vec<cache::Eviction>,
}

impl MemSystem {
    pub fn new(cfg: &SimConfig, design: &Design) -> MemSystem {
        MemSystem {
            l2: (0..cfg.n_mcs)
                .map(|_| {
                    Cache::new(
                        cfg.l2_bytes / cfg.n_mcs,
                        cfg.l2_assoc,
                        cfg.line_bytes,
                        design.l2_tag_mult,
                    )
                })
                .collect(),
            dram: (0..cfg.n_mcs).map(|_| DramChannel::new(cfg)).collect(),
            md: (0..cfg.n_mcs)
                .map(|_| MdCache::new(cfg.md_cache_bytes, cfg.md_cache_assoc))
                .collect(),
            icnt: Crossbar::new(cfg.n_sms, cfg.n_mcs, cfg.icnt_bytes_per_cycle, cfg.icnt_latency),
            l2_hit_latency: cfg.l2_hit_latency as f64,
            l2_tag_latency: cfg.l2_tag_latency as f64,
            hw_dec: cfg.hw_decompress_latency as f64,
            hw_comp: cfg.hw_compress_latency as f64,
            n_mcs: cfg.n_mcs,
            hw_compressor_ops: 0,
            l2_accesses: 0,
            evict_scratch: Vec::new(),
        }
    }

    /// Address-interleaved home slice/MC for a line.
    pub fn mc_of(&self, line_addr: u64) -> usize {
        let z = line_addr ^ (line_addr >> 11) ^ (line_addr >> 23);
        (z as usize) % self.n_mcs
    }

    /// Fetch one line for SM `sm`. `verdict` supplies the line's
    /// compression verdict (called at most once, only when a design needs
    /// it); it must reflect the *stored* form (the simulator accounts for
    /// lines flushed uncompressed).
    pub fn load(
        &mut self,
        now: u64,
        sm: usize,
        line_addr: u64,
        design: &Design,
        verdict: &mut dyn FnMut() -> LineVerdict,
    ) -> LoadOutcome {
        let mc = self.mc_of(line_addr);
        let t_req = self.icnt.send_fwd(now as f64, mc, 0.0);
        self.l2_accesses += 1;
        let l2_probe = self.l2[mc].probe(line_addr, now);

        let (t_data_at_mc, stored_bursts, stored_compressed, l2_hit) = match l2_probe {
            Some((bursts, compressed)) => {
                (t_req + self.l2_hit_latency, bursts, compressed, true)
            }
            None => {
                let t_miss = t_req + self.l2_tag_latency;
                let (bursts, compressed, enc_hint) = if design.mem_compression {
                    let v = verdict();
                    (v.bursts, v.is_compressed(), v.encoding)
                } else {
                    (4, false, 0xFF)
                };
                let _ = enc_hint;
                // Metadata lookup sizes the data read. On an MD-cache miss
                // the controller overlaps the metadata fetch with a
                // pessimistic full-size data read (as in prior link-
                // compression designs [100]) instead of serializing — the
                // bandwidth saving is lost for that access, not the latency.
                let mut t_data;
                if design.mem_compression && !self.md[mc].access(line_addr, now) {
                    let md_done =
                        self.dram[mc].md_access(t_miss, line_addr / mdcache::LINES_PER_MD_BLOCK);
                    t_data = self.dram[mc].access(t_miss, line_addr, 4, false).max(md_done);
                } else {
                    t_data = self.dram[mc].access(t_miss, line_addr, bursts, false);
                }
                // HW-BDI-Mem decompresses at the MC with dedicated logic.
                let (fill_bursts, fill_compressed) =
                    if design.mem_compression && !design.icnt_compression {
                        if design.mechanism == Mechanism::Hardware {
                            t_data += self.hw_dec;
                        }
                        self.hw_compressor_ops += u64::from(design.mechanism == Mechanism::Hardware);
                        (bursts, false) // travels + stored uncompressed; bursts kept for writeback sizing
                    } else {
                        (bursts, compressed)
                    };
                // Fill the L2 (compressed form iff the design keeps it).
                let insert_compressed = fill_compressed && design.l2_holds_compressed;
                self.l2_accesses += 1;
                let mut evictions = std::mem::take(&mut self.evict_scratch);
                self.l2[mc].insert_into(line_addr, false, fill_bursts, insert_compressed, now, &mut evictions);
                self.writeback(now, mc, &evictions, design);
                self.evict_scratch = evictions;
                (t_data, fill_bursts, fill_compressed, false)
            }
        };

        // Response over the return crossbar.
        let payload = if stored_compressed && design.icnt_compression {
            stored_bursts as f64 * 32.0
        } else {
            128.0
        };
        let t_sm = self.icnt.send_back(t_data_at_mc, mc, sm, payload);
        let arrives_compressed = if stored_compressed {
            Some((0u8, stored_bursts))
        } else {
            None
        };
        LoadOutcome {
            data_at: t_sm.ceil() as u64,
            arrives_compressed,
            l2_hit,
        }
    }

    /// Write one line from SM `sm`. `compressed` describes the payload as
    /// it leaves the core (CABA/HW-core designs compress before sending;
    /// `None` = uncompressed). `dram_bursts` sizes the eventual writeback.
    pub fn store(
        &mut self,
        now: u64,
        _sm: usize,
        line_addr: u64,
        design: &Design,
        compressed: Option<LineVerdict>,
    ) {
        let mc = self.mc_of(line_addr);
        let payload = match compressed {
            Some(v) if design.icnt_compression => v.bursts as f64 * 32.0,
            _ => 128.0,
        };
        let t_mc = self.icnt.send_fwd(now as f64, mc, payload);
        self.l2_accesses += 1;
        let (bursts, is_comp) = match compressed {
            Some(v) => (v.bursts, v.is_compressed()),
            None => (4, false),
        };
        let insert_compressed = is_comp && design.l2_holds_compressed;
        // Write-allocate into L2; the DRAM write happens on eviction.
        let t_now = t_mc.ceil() as u64;
        if !self.l2[mc].mark_dirty(line_addr, bursts, insert_compressed, t_now) {
            let mut evictions = std::mem::take(&mut self.evict_scratch);
            self.l2[mc].insert_into(line_addr, true, bursts, insert_compressed, t_now, &mut evictions);
            self.writeback(t_now, mc, &evictions, design);
            self.evict_scratch = evictions;
        }
        // Track MD updates for compressed DRAM images.
        if design.mem_compression {
            self.md[mc].access(line_addr, t_now);
        }
    }

    fn writeback(&mut self, now: u64, mc: usize, evictions: &[cache::Eviction], design: &Design) {
        for ev in evictions {
            // HW-BDI-Mem compresses at the MC on the way out (dedicated
            // logic, off the critical path).
            if design.mem_compression
                && !design.icnt_compression
                && design.mechanism == Mechanism::Hardware
            {
                self.hw_compressor_ops += 1;
            }
            let bursts = if design.mem_compression { ev.bursts } else { 4 };
            let _ = self.hw_comp; // latency is off the critical path
            self.l2_accesses += 1;
            self.dram[mc].access(now as f64, ev.line_addr, bursts, true);
        }
    }

    /// Mean DRAM bus backlog across MCs, in cycles (AWC throttle input).
    pub fn dram_backlog(&self, now: u64) -> f64 {
        let s: f64 = self.dram.iter().map(|d| d.backlog(now as f64)).sum();
        s / self.dram.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::oracle::LineVerdict;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    fn compressed_verdict() -> LineVerdict {
        LineVerdict { encoding: 2, size_bytes: 27, bursts: 1 }
    }

    #[test]
    fn base_load_miss_then_hit() {
        let c = cfg();
        let d = Design::base();
        let mut m = MemSystem::new(&c, &d);
        let mut v = || LineVerdict::uncompressed();
        let miss = m.load(0, 0, 42, &d, &mut v);
        assert!(!miss.l2_hit);
        assert!(miss.arrives_compressed.is_none());
        // L2 hit the second time, and faster.
        let hit = m.load(miss.data_at, 0, 42, &d, &mut v);
        assert!(hit.l2_hit);
        assert!(hit.data_at - miss.data_at < miss.data_at);
    }

    #[test]
    fn compressed_designs_move_fewer_bursts() {
        let c = cfg();
        let d = Design::caba(crate::compress::Algo::Bdi);
        let mut m = MemSystem::new(&c, &d);
        let mut v = compressed_verdict;
        for i in 0..50 {
            let out = m.load(i * 10, 0, 1000 + i, &d, &mut v);
            assert_eq!(out.arrives_compressed, Some((0, 1)));
        }
        let bursts: u64 = m.dram.iter().map(|d| d.stats.bursts).sum();
        let base: u64 = m.dram.iter().map(|d| d.stats.bursts_uncompressed).sum();
        assert!(bursts < base / 2, "bursts={bursts} base={base}");
    }

    #[test]
    fn hw_bdi_mem_delivers_uncompressed_lines() {
        let c = cfg();
        let d = Design::hw_bdi_mem();
        let mut m = MemSystem::new(&c, &d);
        let mut v = compressed_verdict;
        let out = m.load(0, 0, 7, &d, &mut v);
        // Decompressed at the MC → the core sees a normal line.
        assert!(out.arrives_compressed.is_none());
        assert_eq!(m.hw_compressor_ops, 1);
        // Cold MD cache: pessimistic full-size fetch (4 bursts) overlapped
        // with the 1-burst metadata read.
        let bursts: u64 = m.dram.iter().map(|d| d.stats.bursts).sum();
        let md: u64 = m.dram.iter().map(|d| d.stats.md_accesses).sum();
        assert_eq!(md, 1);
        assert_eq!(bursts, 5);
        // A warm access moves only the compressed burst.
        let mc = m.mc_of(7);
        let next = (8..512).find(|&a| m.mc_of(a) == mc).unwrap();
        m.load(1000, 0, next, &d, &mut v);
        let bursts2: u64 = m.dram.iter().map(|d| d.stats.bursts).sum();
        assert_eq!(bursts2, 6);
    }

    #[test]
    fn md_cache_miss_costs_extra_access() {
        let c = cfg();
        let d = Design::caba(crate::compress::Algo::Bdi);
        let mut m = MemSystem::new(&c, &d);
        let mut v = compressed_verdict;
        m.load(0, 0, 5, &d, &mut v); // cold: MD miss
        let md_accesses: u64 = m.dram.iter().map(|d| d.stats.md_accesses).sum();
        assert_eq!(md_accesses, 1);
        // A second line in the same MD block *and* the same MC: MD hit.
        let mc = m.mc_of(5);
        let next = (6..512).find(|&a| m.mc_of(a) == mc).unwrap();
        m.load(1000, 0, next, &d, &mut v);
        let md_accesses: u64 = m.dram.iter().map(|d| d.stats.md_accesses).sum();
        assert_eq!(md_accesses, 1);
    }

    #[test]
    fn uncompressed_l2_variant_serves_plain_hits() {
        let c = cfg();
        let d = Design::caba_uncompressed_l2();
        let mut m = MemSystem::new(&c, &d);
        let mut v = compressed_verdict;
        let miss = m.load(0, 0, 9, &d, &mut v);
        // Fill response is compressed (came from DRAM)...
        assert!(miss.arrives_compressed.is_some());
        // ...but the L2 copy is uncompressed, so the hit needs no decompress.
        let hit = m.load(miss.data_at + 1, 0, 9, &d, &mut v);
        assert!(hit.l2_hit);
        assert!(hit.arrives_compressed.is_none());
    }

    #[test]
    fn store_then_evict_writes_back_compressed() {
        let c = cfg();
        let d = Design::caba(crate::compress::Algo::Bdi);
        let mut m = MemSystem::new(&c, &d);
        m.store(0, 0, 77, &d, Some(compressed_verdict()));
        // Fill the same L2 set until 77 is evicted; writes go to DRAM.
        let mut v = compressed_verdict;
        let mut addr = 1_000_000u64;
        let mut writes = 0;
        for _ in 0..100_000 {
            m.load(10, 0, addr, &d, &mut v);
            addr += 1;
            writes = m.dram.iter().map(|d| d.stats.writes).sum();
            if writes > 0 {
                break;
            }
        }
        assert!(writes > 0, "dirty line never written back");
    }

    #[test]
    fn icnt_compression_reduces_return_flits() {
        let c = cfg();
        let mut flits = Vec::new();
        for d in [Design::hw_bdi_mem(), Design::hw_bdi()] {
            let mut m = MemSystem::new(&c, &d);
            let mut v = compressed_verdict;
            for i in 0..20 {
                m.load(i, 0, 500 + i, &d, &mut v);
            }
            flits.push(m.icnt.stats.flits_back);
        }
        assert!(flits[1] < flits[0], "icnt compression must cut flits: {flits:?}");
    }
}
