//! Crossbar interconnect model: one crossbar per direction (Table 1).
//!
//! Contention is modelled with per-port reservation: the forward crossbar
//! serializes at each MC's ingress (many SMs feeding one slice) and the
//! return crossbar at each SM's ingress. Payloads occupy a port for
//! `bytes / icnt_bytes_per_cycle` cycles, so compressed responses (fewer
//! flits) free the port sooner — the interconnect-compression benefit the
//! paper reports for `bfs`/`mst` (§7.1).

use crate::stats::IcntStats;

/// A bandwidth-reserving port: transfers serialize on `free_at`.
#[derive(Clone, Debug)]
pub struct Port {
    pub free_at: f64,
    bytes_per_cycle: f64,
}

impl Port {
    pub fn new(bytes_per_cycle: f64) -> Port {
        Port { free_at: 0.0, bytes_per_cycle }
    }

    /// Reserve the port for `bytes` starting no earlier than `now`;
    /// returns the completion time.
    pub fn transfer(&mut self, now: f64, bytes: f64) -> f64 {
        let start = if now > self.free_at { now } else { self.free_at };
        let done = start + bytes / self.bytes_per_cycle;
        self.free_at = done;
        done
    }

    /// Utilization probe for throttling decisions.
    pub fn busy(&self, now: f64) -> bool {
        self.free_at > now
    }
}

/// The two crossbars.
pub struct Crossbar {
    /// Forward direction: contention at each MC ingress.
    fwd: Vec<Port>,
    /// Return direction, stage 1: each MC's *egress* port — six MCs feed
    /// fifteen SMs, so responses serialize here first. This is where
    /// interconnect compression pays off: an uncompressed 128B response
    /// holds the port 4× longer than a 1-burst compressed one.
    back_egress: Vec<Port>,
    /// Return direction, stage 2: each SM's ingress port.
    back: Vec<Port>,
    latency: f64,
    pub stats: IcntStats,
}

/// A small request/control packet (address + command) in bytes.
pub const CTRL_BYTES: f64 = 8.0;

impl Crossbar {
    pub fn new(n_sms: usize, n_mcs: usize, bytes_per_cycle: f64, latency: u32) -> Crossbar {
        Crossbar {
            fwd: (0..n_mcs).map(|_| Port::new(bytes_per_cycle)).collect(),
            back_egress: (0..n_mcs).map(|_| Port::new(bytes_per_cycle)).collect(),
            back: (0..n_sms).map(|_| Port::new(bytes_per_cycle)).collect(),
            latency: latency as f64,
            stats: IcntStats::default(),
        }
    }

    /// SM → MC packet carrying `payload_bytes` of data (0 for a read
    /// request). Returns arrival time at the MC.
    pub fn send_fwd(&mut self, now: f64, mc: usize, payload_bytes: f64) -> f64 {
        self.stats.packets_fwd += 1;
        self.stats.flits_fwd += 1 + (payload_bytes / 32.0).ceil() as u64;
        let done = self.fwd[mc].transfer(now, CTRL_BYTES + payload_bytes);
        done + self.latency
    }

    /// MC → SM response carrying `payload_bytes` (store-and-forward through
    /// the MC egress port, then the SM ingress port). Returns arrival.
    pub fn send_back(&mut self, now: f64, mc: usize, sm: usize, payload_bytes: f64) -> f64 {
        self.stats.packets_back += 1;
        self.stats.flits_back += 1 + (payload_bytes / 32.0).ceil() as u64;
        let t1 = self.back_egress[mc].transfer(now, CTRL_BYTES + payload_bytes);
        let done = self.back[sm].transfer(t1, CTRL_BYTES + payload_bytes);
        done + self.latency
    }

    /// Mean forward-port backlog in cycles (AWC feedback input).
    pub fn fwd_backlog(&self, now: f64) -> f64 {
        let sum: f64 = self.fwd.iter().map(|p| (p.free_at - now).max(0.0)).sum();
        sum / self.fwd.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_serializes() {
        let mut p = Port::new(32.0);
        let t1 = p.transfer(0.0, 128.0); // 4 cycles
        let t2 = p.transfer(0.0, 128.0); // queued behind
        assert!((t1 - 4.0).abs() < 1e-9);
        assert!((t2 - 8.0).abs() < 1e-9);
        // After a gap, no queuing.
        let t3 = p.transfer(100.0, 32.0);
        assert!((t3 - 101.0).abs() < 1e-9);
    }

    #[test]
    fn compressed_payload_frees_port_sooner() {
        let mut x = Crossbar::new(2, 2, 32.0, 8);
        let full = x.send_back(0.0, 0, 0, 128.0);
        let mut y = Crossbar::new(2, 2, 32.0, 8);
        let comp = y.send_back(0.0, 0, 0, 32.0);
        assert!(comp < full);
    }

    #[test]
    fn independent_ports_no_contention() {
        let mut x = Crossbar::new(2, 2, 32.0, 8);
        let a = x.send_fwd(0.0, 0, 128.0);
        let b = x.send_fwd(0.0, 1, 128.0);
        assert!((a - b).abs() < 1e-9, "different MCs must not contend");
    }

    #[test]
    fn flit_accounting() {
        let mut x = Crossbar::new(1, 1, 32.0, 8);
        x.send_fwd(0.0, 0, 0.0); // read request: 1 ctrl flit
        x.send_back(0.0, 0, 0, 128.0); // response: 1 ctrl + 4 data flits
        assert_eq!(x.stats.flits_fwd, 1);
        assert_eq!(x.stats.flits_back, 5);
    }
}
