//! The per-MC compression-metadata (MD) cache (paper §5.3.2).
//!
//! Bandwidth compression needs per-line burst counts *before* the DRAM read
//! is scheduled. The paper reserves 8MB of DRAM for metadata and caches it
//! in a small 8KB, 4-way MD cache near each memory controller; a miss costs
//! an extra DRAM access. One 128B metadata line holds 2-bit burst codes for
//! 512 data lines, so the MD cache exploits the spatial locality of data
//! accesses (the paper reports an 85% average hit rate).

use super::cache::Cache;
use crate::stats::MdCacheStats;

/// Data lines covered by one 128B metadata line (128B × 4 codes/byte).
pub const LINES_PER_MD_BLOCK: u64 = 512;

pub struct MdCache {
    cache: Cache,
    pub stats: MdCacheStats,
}

impl MdCache {
    pub fn new(bytes: usize, assoc: usize) -> MdCache {
        MdCache {
            cache: Cache::new(bytes, assoc, 128, 1),
            stats: MdCacheStats::default(),
        }
    }

    /// Probe the metadata for `line_addr`. Returns `true` on hit; on miss
    /// the block is fetched (caller charges one extra DRAM access) and
    /// inserted.
    pub fn access(&mut self, line_addr: u64, now: u64) -> bool {
        self.stats.accesses += 1;
        let block = line_addr / LINES_PER_MD_BLOCK;
        if self.cache.probe(block, now).is_some() {
            self.stats.hits += 1;
            true
        } else {
            self.cache.insert(block, false, 4, false, now);
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spatial_locality_hits() {
        let mut md = MdCache::new(8 * 1024, 4);
        // Sequential lines share MD blocks → high hit rate.
        for i in 0..4096u64 {
            md.access(i, i);
        }
        assert!(
            md.stats.hit_rate() > 0.95,
            "sequential hit rate {}",
            md.stats.hit_rate()
        );
    }

    #[test]
    fn random_far_accesses_miss() {
        let mut md = MdCache::new(8 * 1024, 4);
        let mut rng = crate::util::rng::Rng::new(3);
        for t in 0..2000u64 {
            // Addresses spread over 1<<30 lines → ~every access a new block.
            md.access(rng.next_u64() % (1 << 30), t);
        }
        assert!(
            md.stats.hit_rate() < 0.2,
            "random hit rate {}",
            md.stats.hit_rate()
        );
    }

    #[test]
    fn first_access_misses_then_hits() {
        let mut md = MdCache::new(8 * 1024, 4);
        assert!(!md.access(1000, 0));
        assert!(md.access(1000, 1));
        assert!(md.access(1001, 2)); // same MD block
        assert_eq!(md.stats.accesses, 3);
        assert_eq!(md.stats.hits, 2);
    }
}
