//! GDDR5 memory-controller + DRAM timing model (Table 1).
//!
//! Each channel has 16 banks with open-row tracking and a shared data bus.
//! Requests reserve the bank (tRCD/tCL/tRP row management) and then the data
//! bus (one 32B burst per `burst_cycles`, derived from the 177.4GB/s peak).
//! Because the simulator resolves each request's timing when it is injected,
//! FR-FCFS reordering is captured through the open-row state: a stream of
//! same-row requests hits the row buffer exactly as FR-FCFS would schedule
//! them back-to-back, and row conflicts pay the precharge+activate penalty.
//!
//! Compressed lines occupy the data bus for 1–4 bursts instead of always 4 —
//! this is *the* mechanism behind the paper's bandwidth savings.

use crate::config::{DramTiming, SimConfig};
use crate::stats::DramStats;

use super::icnt::Port;

/// Lines per DRAM row (2KB rows / 128B lines).
const LINES_PER_ROW: u64 = 16;

/// FR-FCFS reorder window: the controller batches queued requests to the
/// same row, so a request "row-hits" if its row was touched within the last
/// few accesses to the bank — not only if it is literally the open row.
const ROW_WINDOW: usize = 4;

#[derive(Clone, Copy, Debug, Default)]
struct Bank {
    /// Recently serviced rows (LRU, newest first) — the FR-FCFS window.
    recent_rows: [u64; ROW_WINDOW],
    n_rows: usize,
    /// Earliest cycle the bank can start a new column access.
    free_at: f64,
}

impl Bank {
    fn hit(&mut self, row: u64) -> bool {
        let hit = self.recent_rows[..self.n_rows].contains(&row);
        // LRU update.
        if let Some(pos) = self.recent_rows[..self.n_rows].iter().position(|&r| r == row) {
            self.recent_rows[..=pos].rotate_right(1);
        } else {
            self.n_rows = (self.n_rows + 1).min(ROW_WINDOW);
            self.recent_rows[..self.n_rows].rotate_right(1);
            self.recent_rows[0] = row;
        }
        hit
    }
}

/// One GDDR5 channel (one MC).
pub struct DramChannel {
    banks: Vec<Bank>,
    bus: Port,
    timing: DramTiming,
    base_latency: f64,
    burst_cycles: f64,
    pub stats: DramStats,
}

impl DramChannel {
    pub fn new(cfg: &SimConfig) -> DramChannel {
        DramChannel {
            banks: vec![Bank::default(); cfg.banks_per_mc],
            bus: Port::new(cfg.dram_bytes_per_cycle_per_mc()),
            timing: cfg.dram_timing,
            base_latency: cfg.dram_base_latency as f64,
            burst_cycles: cfg.burst_cycles(),
            stats: DramStats::default(),
        }
    }

    /// Address mapping `[row | bank | column]`: 16 consecutive lines share
    /// a bank+row (so streaming gets row hits), the next 16 move to the
    /// next bank (bank-level parallelism). Upper bits are XOR-folded so
    /// the `1<<40` array stride doesn't alias onto one bank.
    fn bank_of(&self, line_addr: u64) -> usize {
        let group = line_addr / LINES_PER_ROW;
        let z = group ^ (group >> 9) ^ (group >> 21);
        (z as usize) % self.banks.len()
    }

    fn row_of(&self, line_addr: u64) -> u64 {
        line_addr / (LINES_PER_ROW * self.banks.len() as u64)
    }

    /// Schedule an access transferring `bursts` 32B bursts at or after
    /// `now`; returns the cycle the data transfer completes.
    pub fn access(&mut self, now: f64, line_addr: u64, bursts: u8, is_write: bool) -> f64 {
        let b = self.bank_of(line_addr);
        let row = self.row_of(line_addr);
        let t = self.timing;
        let bank = &mut self.banks[b];
        let start = if now > bank.free_at { now } else { bank.free_at };
        let row_hit = bank.hit(row);
        let cmd_latency = if row_hit {
            t.t_cl as f64
        } else {
            (t.t_rp + t.t_rcd + t.t_cl) as f64
        };
        if row_hit {
            self.stats.row_hits += 1;
        } else {
            self.stats.row_misses += 1;
        }
        if is_write {
            self.stats.writes += 1;
        } else {
            self.stats.reads += 1;
        }
        let data_ready = start + cmd_latency;
        let bus_bytes = bursts as f64 * 32.0;
        let bus_done = self.bus.transfer(data_ready, bus_bytes);
        self.stats.bus_busy_cycles += bursts as f64 * self.burst_cycles;
        // CAS commands pipeline: a row-hit only occupies the bank for tCCD;
        // a conflict holds it for precharge+activate as well. Writes add
        // the write-recovery time.
        let mut occupancy = t.t_ccd as f64;
        if !row_hit {
            occupancy += (t.t_rp + t.t_rcd) as f64;
        }
        if is_write {
            occupancy += t.t_wr as f64;
        }
        bank.free_at = start + occupancy;
        self.stats.bursts += bursts as u64;
        self.stats.bursts_uncompressed += 4;
        bus_done + self.base_latency * if is_write { 0.0 } else { 1.0 }
    }

    /// An extra metadata access (MD-cache miss): a 1-burst read from the
    /// reserved MD region. Issued by the MC itself, so it skips the
    /// request-path base latency the paper's footnote 3 also discounts.
    pub fn md_access(&mut self, now: f64, md_block: u64) -> f64 {
        self.stats.md_accesses += 1;
        let done = self.access(now, (1 << 45) + md_block, 1, false);
        // Do not double-count it in the compression-ratio accounting.
        self.stats.bursts_uncompressed -= 4;
        self.stats.bursts_uncompressed += 1;
        done - self.base_latency
    }

    /// Data-bus backlog in cycles (AWC feedback input).
    pub fn backlog(&self, now: f64) -> f64 {
        (self.bus.free_at - now).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chan() -> DramChannel {
        DramChannel::new(&SimConfig::default())
    }

    #[test]
    fn row_hit_faster_than_conflict() {
        let mut d = chan();
        // Lines 0 and 1 share a bank and row under [row|bank|col] mapping.
        assert_eq!(d.bank_of(0), d.bank_of(1));
        assert_eq!(d.row_of(0), d.row_of(1));
        let a0 = d.access(0.0, 0, 4, false);
        let a1 = d.access(a0, 1, 4, false);
        // A conflicting row in the same bank.
        let mut d2 = chan();
        let b0 = d2.access(0.0, 0, 4, false);
        let mut other = 16u64;
        while d2.bank_of(other) != d2.bank_of(0) || d2.row_of(other) == d2.row_of(0) {
            other += 16;
        }
        let b1 = d2.access(b0, other, 4, false);
        assert!(b1 - b0 > a1 - a0, "row conflict must cost more");
        assert_eq!(d.stats.row_hits, 1);
        assert_eq!(d2.stats.row_hits, 0);
    }

    #[test]
    fn compressed_bursts_reduce_bus_occupancy() {
        let mut d = chan();
        for i in 0..100u64 {
            d.access(0.0, i * 997, 4, false);
        }
        let full = d.stats.bus_busy_cycles;
        let mut d2 = chan();
        for i in 0..100u64 {
            d2.access(0.0, i * 997, 1, false);
        }
        assert!(d2.stats.bus_busy_cycles < full / 2.0);
        assert_eq!(d.stats.compression_ratio(), 1.0);
        assert_eq!(d2.stats.compression_ratio(), 4.0);
    }

    #[test]
    fn bus_saturates_under_load() {
        let mut d = chan();
        let mut last = 0.0f64;
        for i in 0..1000u64 {
            last = d.access(0.0, i * 31, 4, false);
        }
        // 1000 lines × 4 bursts × ~1.51 cy/burst ≈ 6060 cycles minimum.
        assert!(last > 5500.0, "last={last}");
    }

    #[test]
    fn md_access_counts() {
        let mut d = chan();
        d.md_access(0.0, 7);
        assert_eq!(d.stats.md_accesses, 1);
        assert_eq!(d.stats.bursts, 1);
        assert_eq!(d.stats.bursts_uncompressed, 1);
    }
}
