//! `caba bench` — the hot-path performance suite in calibrated,
//! machine-readable form.
//!
//! Runs the same three measurement families as `cargo bench --bench
//! perf_hotpath` (compression-substrate throughput, oracle memoization,
//! end-to-end simulator throughput), but:
//!
//! * emits a **JSON report** (`BENCH_pr10.json` by default; schema
//!   documented in EXPERIMENTS.md §Perf) so the perf trajectory is
//!   tracked in-repo from PR 3 onward;
//! * measures the **event-driven tick** against the `strict_tick=true`
//!   reference on a memory-bound and a compute-bound point — the speedup
//!   is a number in the JSON, and any stats divergence between the two
//!   modes is reported as a floor violation (a free differential check on
//!   every CI bench run);
//! * measures **intra-sim sharding** (`sim_threads` = 1/2/4 on one
//!   memory-bound point): kcycles/s per thread count, speedup over the
//!   serial run, and bit-identity of the stats — divergence is again a
//!   violation regardless of the floors file;
//! * measures the **flight recorder's overhead** (`telemetry_window=1024`
//!   vs off on the same points): the fractional slowdown is checked
//!   against a `max_telemetry_overhead` *ceiling* in the floors file, and
//!   any `SimStats` difference between the on/off runs violates the
//!   observation-only contract unconditionally;
//! * measures the **fault-tolerant serve loop** end to end (PR 8): an
//!   in-process `caba serve` daemon on fresh socket/store dirs answers a
//!   cold pass and a multi-client warm burst (`serve_warm_hits_per_s`,
//!   checked against `min_serve_warm_hits_per_s`, plus client-observed
//!   p50/p95/p99 request latency from a log2-bucketed histogram — see
//!   EXPERIMENTS.md measurement family 8), then a second daemon
//!   with an injected worker panic must survive it: exactly one typed
//!   error, every unaffected response bit-identical to the clean run
//!   (by `stats_digest`), and a retry of the failed point recovering —
//!   each of those is a violation unconditionally, not a floor;
//! * measures the serve daemon **under overload** (PR 10): a 4x-queue-cap
//!   burst of distinct cold points against one worker with a 1 ms
//!   brownout threshold, while a concurrent client hammers a warm point —
//!   the shed-vs-brownout split lands in the JSON, warm throughput
//!   *during* the storm is checked against `min_brownout_warm_hits_per_s`,
//!   and a storm with zero brownout sheds is an unconditional violation;
//! * optionally checks the numbers against a committed **floors file**
//!   (`key=value` lines, same offline-friendly format as `SimConfig`
//!   overrides) and reports violations — the CI `bench-smoke` job fails
//!   on any regression below floor;
//! * has a `--quick` mode sized for CI smoke (seconds, not minutes).
//!
//! All measurements are wall-clock on the current host; the JSON embeds
//! the mode and corpus sizes so numbers are only ever compared
//! like-for-like.

use crate::compress::oracle::{CompressionOracle, MemoOracle, NativeOracle};
use crate::compress::{measure, Algo, Line, LINE_BYTES};
use crate::obs::{HistSnapshot, Histogram};
use crate::serve::{self, json::Json, ServeOpts};
use crate::sim::designs::Design;
use crate::sim::Simulator;
use crate::store::FaultPlan;
use crate::workload::apps;
use crate::workload::datagen::{line_data, DataPattern};
use crate::SimConfig;
use anyhow::{anyhow, Context, Result};
use std::fmt::Write as _;
use std::sync::Arc;
use std::time::Instant;

/// CLI options for `caba bench`.
pub struct BenchOpts {
    /// CI smoke sizing (smaller corpus, fewer sim points, scale 0.03).
    pub quick: bool,
    /// JSON output path.
    pub out: String,
    /// Optional floors file (`key=value` lines); violations fail the run.
    pub floors: Option<String>,
}

/// One compression-substrate measurement.
pub struct CompressPoint {
    pub algo: &'static str,
    pub mlines_per_s: f64,
    pub mb_per_s: f64,
    /// Sum of measured sizes — a determinism check across hosts.
    pub size_checksum: u64,
}

/// One strict-vs-event tick comparison point.
pub struct TickPoint {
    pub app: &'static str,
    pub design: &'static str,
    /// Simulated kilocycles per wall-second under `strict_tick=true`
    /// (every SM ticked every cycle — the reference path).
    pub kcycles_per_s_strict: f64,
    /// Same point under the event-driven default.
    pub kcycles_per_s_event: f64,
    /// `kcycles_per_s_event / kcycles_per_s_strict`.
    pub speedup: f64,
    /// Bit-identity of the two runs on (cycles, warp_insts, the full
    /// issue breakdown, memory_signature). `false` is a floor violation
    /// regardless of the floors file.
    pub stats_match: bool,
}

/// One intra-sim sharding measurement (`sim_threads=N` on one point).
pub struct ShardPoint {
    pub app: &'static str,
    pub design: &'static str,
    pub threads: usize,
    pub kcycles_per_s: f64,
    /// `kcycles_per_s / kcycles_per_s(threads=1)`; 1.0 for the serial
    /// point itself.
    pub speedup: f64,
    /// Bit-identity vs. the `sim_threads=1` run on (cycles, warp_insts,
    /// the full issue breakdown, memory_signature). `false` is a floor
    /// violation regardless of the floors file.
    pub stats_match: bool,
}

/// One flight-recorder overhead measurement (`telemetry_window=1024` vs
/// the recorder off, same app/design/scale).
pub struct TelemetryPoint {
    pub app: &'static str,
    pub design: &'static str,
    /// Simulated kilocycles per wall-second with the recorder off.
    pub kcycles_per_s_off: f64,
    /// Same point with `telemetry_window=1024` (and the span log on).
    pub kcycles_per_s_on: f64,
    /// Fractional wall-clock cost of recording: `t_on / t_off - 1`
    /// (0.05 = 5% slower). Checked against the `max_telemetry_overhead`
    /// *ceiling* — the one floors-file key where bigger is worse.
    pub overhead: f64,
    /// Full `SimStats` equality between the off and on runs. `false`
    /// breaks the observation-only contract and is a violation regardless
    /// of the floors file.
    pub stats_match: bool,
    /// Chip windows the on-run recorded (sanity: the recorder ran).
    pub windows: usize,
    /// Assist-warp spans the on-run captured across all SMs.
    pub spans: usize,
}

/// One fault-tolerant serve-loop measurement: a clean phase (cold pass +
/// multi-client warm burst against an in-process daemon) followed by a
/// fault phase (same points, fresh dirs, one injected worker panic).
pub struct ServePoint {
    /// Cold (app, design) points pushed through the daemon.
    pub cold_points: usize,
    /// Warm-burst requests answered from the store-backed cache.
    pub warm_requests: usize,
    /// Warm answers per wall-second across the burst — the floors-file
    /// metric (`min_serve_warm_hits_per_s`).
    pub warm_hits_per_s: f64,
    /// Client-observed warm-burst request latency percentiles, in
    /// microseconds, from a log2-bucketed histogram (each is the upper
    /// bound of its bucket, so within 2x of the true percentile). Zero
    /// when the burst made no requests.
    pub warm_p50_us: u64,
    pub warm_p95_us: u64,
    pub warm_p99_us: u64,
    /// Typed `"status":"error"` responses in the fault phase. Exactly one
    /// panic is injected, so any other count is a violation.
    pub fault_errors: u64,
    /// The faulted daemon answered every request and drained cleanly.
    /// `false` is a violation regardless of the floors file.
    pub survived: bool,
    /// Every unaffected fault-phase response carried the same
    /// `stats_digest` as the clean run. `false` is a violation regardless
    /// of the floors file.
    pub bitident_vs_clean: bool,
    /// Re-requesting the panicked point succeeded (errors are never
    /// cached). `false` is a violation regardless of the floors file.
    pub retry_recovers: bool,
}

/// One overload measurement (PR 10): a 1-worker daemon with a small
/// queue and a 1 ms brownout threshold takes a burst of 4x-queue-cap
/// distinct cold points while a concurrent client hammers one
/// already-stored warm point. The point of the point: under brownout the
/// daemon keeps serving warm hits at full speed while shedding new cold
/// work — `warm_hits_per_s` here is measured *during* the storm and
/// checked against the `min_brownout_warm_hits_per_s` floor.
pub struct OverloadPoint {
    /// Cold requests fired in the burst (4x the queue cap).
    pub burst_requests: usize,
    pub queue_cap: usize,
    /// Total shed answers the daemon counted (queue-full + brownout).
    pub shed: u64,
    /// Sheds attributable to the brownout controller (subset of `shed`).
    pub brownout_shed: u64,
    /// The brownout controller engaged at least once during the storm.
    pub brownout_engaged: bool,
    /// Warm answers served to the hammer client while the storm ran.
    pub warm_hits: usize,
    /// Warm answers per wall-second during the storm — the floors-file
    /// metric (`min_brownout_warm_hits_per_s`).
    pub warm_hits_per_s: f64,
    /// The daemon answered everything and drained cleanly. `false` is a
    /// violation regardless of the floors file.
    pub survived: bool,
}

/// One end-to-end simulator measurement.
pub struct SimPoint {
    pub app: &'static str,
    pub design: &'static str,
    pub cycles: u64,
    pub warp_insts: u64,
    pub kcycles_per_s: f64,
    pub kinsts_per_s: f64,
    /// Oracle memo hit rate over the whole run (None if the oracle keeps
    /// no counters).
    pub memo_hit_rate: Option<f64>,
    /// §8.1 memoization-LUT hit rate (None unless the design memoizes).
    pub lut_hit_rate: Option<f64>,
}

/// The full report; `to_json` renders it.
pub struct BenchReport {
    pub mode: &'static str,
    pub corpus_lines: usize,
    pub sim_scale: f64,
    pub compress: Vec<CompressPoint>,
    pub memo_cold_mlines_per_s: f64,
    pub memo_warm_mlines_per_s: f64,
    pub memo_hit_rate: f64,
    pub sim: Vec<SimPoint>,
    pub tick: Vec<TickPoint>,
    pub shard: Vec<ShardPoint>,
    pub telemetry: Vec<TelemetryPoint>,
    pub serve: Vec<ServePoint>,
    pub overload: Vec<OverloadPoint>,
    pub violations: Vec<String>,
}

/// The mixed-pattern corpus every substrate measurement runs over
/// (compressible, incompressible and sparse thirds — the same mix as
/// `perf_hotpath`).
fn corpus(n_per_pattern: usize) -> Vec<Line> {
    let patterns = [
        DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 },
        DataPattern::Random,
        DataPattern::SparseNarrow { p_nonzero: 0.3 },
    ];
    let mut lines = Vec::with_capacity(3 * n_per_pattern);
    for p in patterns {
        for i in 0..n_per_pattern {
            lines.push(line_data(&p, 3, i as u64, 0));
        }
    }
    lines
}

fn measure_compress(lines: &[Line]) -> Vec<CompressPoint> {
    Algo::CONCRETE
        .iter()
        .map(|&algo| {
            let t0 = Instant::now();
            let mut checksum = 0u64;
            for line in lines {
                checksum += measure(algo, line).1 as u64;
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            CompressPoint {
                algo: algo.name(),
                mlines_per_s: lines.len() as f64 / dt / 1e6,
                mb_per_s: lines.len() as f64 * LINE_BYTES as f64 / dt / 1e6,
                size_checksum: checksum,
            }
        })
        .collect()
}

fn measure_memo(lines: &[Line]) -> (f64, f64, f64) {
    let mut memo = MemoOracle::new(NativeOracle);
    let t0 = Instant::now();
    memo.analyze(Algo::Bdi, lines);
    let cold = t0.elapsed().as_secs_f64().max(1e-9);
    let t0 = Instant::now();
    memo.analyze(Algo::Bdi, lines);
    let warm = t0.elapsed().as_secs_f64().max(1e-9);
    let hit_rate = memo.hits as f64 / (memo.hits + memo.misses).max(1) as f64;
    (
        lines.len() as f64 / cold / 1e6,
        lines.len() as f64 / warm / 1e6,
        hit_rate,
    )
}

/// One timed end-to-end run under the default (event-driven) config,
/// rendered as a [`SimPoint`]. Shared by the sim section and the tick
/// comparison so overlapping pairs are simulated once, not twice.
fn measure_one_sim(app_name: &'static str, design: Design, scale: f64) -> Result<(SimPoint, crate::stats::SimStats)> {
    let app = apps::find(app_name)
        .ok_or_else(|| anyhow!("bench references unknown app {app_name:?}"))?;
    let t0 = Instant::now();
    let mut sim = Simulator::new(SimConfig::default(), design, app, scale);
    let stats = sim.run();
    let dt = t0.elapsed().as_secs_f64().max(1e-9);
    let point = SimPoint {
        app: app.name,
        design: design.name,
        cycles: stats.cycles,
        warp_insts: stats.warp_insts,
        kcycles_per_s: stats.cycles as f64 / dt / 1e3,
        kinsts_per_s: stats.warp_insts as f64 / dt / 1e3,
        memo_hit_rate: sim
            .oracle_memo_stats()
            .map(|(h, m)| h as f64 / (h + m).max(1) as f64),
        lut_hit_rate: stats.caba.memo_hit_rate(),
    };
    Ok((point, stats))
}

/// Measure the event-driven tick against the strict reference. Each pair
/// runs once per mode; the comparison covers both wall-clock and full
/// stat equality, so every bench run doubles as a differential check.
/// Returns the tick points plus the event-mode runs as [`SimPoint`]s so
/// the sim section can reuse them instead of re-simulating.
fn measure_tick(
    pairs: &[(&'static str, Design)],
    scale: f64,
) -> Result<(Vec<TickPoint>, Vec<Option<SimPoint>>)> {
    let mut out = Vec::new();
    let mut event_points = Vec::new();
    for &(app_name, design) in pairs {
        let app = apps::find(app_name)
            .ok_or_else(|| anyhow!("bench references unknown app {app_name:?}"))?;
        let strict_cfg = SimConfig { strict_tick: true, ..SimConfig::default() };
        let t0 = Instant::now();
        let strict = Simulator::new(strict_cfg, design, app, scale).run();
        let dt_strict = t0.elapsed().as_secs_f64().max(1e-9);
        let (event_point, event) = measure_one_sim(app_name, design, scale)?;
        let stats_match = strict.cycles == event.cycles
            && strict.warp_insts == event.warp_insts
            && strict.issue == event.issue
            && strict.memory_signature() == event.memory_signature();
        let kc_strict = strict.cycles as f64 / dt_strict / 1e3;
        let kc_event = event_point.kcycles_per_s;
        out.push(TickPoint {
            app: app.name,
            design: design.name,
            kcycles_per_s_strict: kc_strict,
            kcycles_per_s_event: kc_event,
            speedup: kc_event / kc_strict.max(1e-12),
            stats_match,
        });
        event_points.push(Some(event_point));
    }
    Ok((out, event_points))
}

/// Measure the sharded tick loop at 1/2/4 threads on one memory-bound
/// point. The serial (`sim_threads=1`) run is the baseline for both the
/// speedup and the bit-identity check — so every bench run also exercises
/// the sharding differential on this host's actual core count.
fn measure_shard(app_name: &'static str, design: Design, scale: f64) -> Result<Vec<ShardPoint>> {
    let app = apps::find(app_name)
        .ok_or_else(|| anyhow!("bench references unknown app {app_name:?}"))?;
    let mut out = Vec::new();
    let mut base: Option<(crate::stats::SimStats, f64)> = None;
    for threads in [1usize, 2, 4] {
        let cfg = SimConfig { sim_threads: threads, ..SimConfig::default() };
        let t0 = Instant::now();
        let stats = Simulator::new(cfg, design, app, scale).run();
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let kc = stats.cycles as f64 / dt / 1e3;
        let (speedup, stats_match) = match &base {
            None => (1.0, true),
            Some((b, base_kc)) => (
                kc / base_kc.max(1e-12),
                b.cycles == stats.cycles
                    && b.warp_insts == stats.warp_insts
                    && b.issue == stats.issue
                    && b.memory_signature() == stats.memory_signature(),
            ),
        };
        out.push(ShardPoint {
            app: app.name,
            design: design.name,
            threads,
            kcycles_per_s: kc,
            speedup,
            stats_match,
        });
        if base.is_none() {
            base = Some((stats, kc));
        }
    }
    Ok(out)
}

/// Measure the flight recorder's cost on one point: an off-run and an
/// on-run (`telemetry_window=1024`), compared on wall-clock and on full
/// `SimStats` equality — every bench run doubles as an observation-only
/// check of the recorder.
fn measure_telemetry(
    app_name: &'static str,
    design: Design,
    scale: f64,
) -> Result<TelemetryPoint> {
    let app = apps::find(app_name)
        .ok_or_else(|| anyhow!("bench references unknown app {app_name:?}"))?;
    let t0 = Instant::now();
    let off = Simulator::new(SimConfig::default(), design, app, scale).run();
    let dt_off = t0.elapsed().as_secs_f64().max(1e-9);
    let cfg = SimConfig { telemetry_window: 1024, ..SimConfig::default() };
    let t0 = Instant::now();
    let mut sim = Simulator::new(cfg, design, app, scale);
    let on = sim.run();
    let dt_on = t0.elapsed().as_secs_f64().max(1e-9);
    let run = sim
        .telemetry_run()
        .ok_or_else(|| anyhow!("telemetry bench point recorded nothing"))?;
    Ok(TelemetryPoint {
        app: app.name,
        design: design.name,
        kcycles_per_s_off: off.cycles as f64 / dt_off / 1e3,
        kcycles_per_s_on: on.cycles as f64 / dt_on / 1e3,
        overhead: dt_on / dt_off - 1.0,
        stats_match: off == on,
        windows: run.window_count(),
        spans: run.span_count(),
    })
}

/// What one daemon phase produced.
struct ServePhase {
    /// `stats_digest` per point, in request order; `None` = typed error.
    digests: Vec<Option<String>>,
    /// Typed `"status":"error"` responses across the cold pass.
    errors: u64,
    /// Every errored point answered `ok` when re-requested.
    retry_ok: bool,
    /// Warm-burst answers with `source:"warm"`, and the burst wall-clock.
    warm_hits: usize,
    warm_dt: f64,
    /// Client-observed request latency across the warm burst (all
    /// requests, hit or not), in microseconds.
    warm_lat: HistSnapshot,
}

/// One sweep request through the daemon's client path, parsed. All bench
/// points share the small config (2 SMs, bounded cycles) so the serve
/// family measures the service, not the simulator.
fn serve_request(socket: &std::path::Path, app: &str, design: &str) -> Result<Json> {
    let line = format!(
        "{{\"verb\":\"sweep\",\"app\":\"{app}\",\"design\":\"{design}\",\"scale\":0.01,\
         \"set\":{{\"n_sms\":2,\"max_cycles\":150000}}}}"
    );
    let resp = serve::client_request(socket, &line)?;
    serve::json::parse(&resp).map_err(|e| anyhow!("unparseable serve response {resp:?}: {e:#}"))
}

/// Drive one in-process daemon on fresh socket/store dirs: a sequential
/// cold pass over `points`, a retry of any errored point, an optional
/// concurrent warm burst, then a handle-stop drain. Transport failures
/// (no response, dead socket) propagate as `Err`; the fault phase maps
/// that to `survived=false`.
fn serve_phase(
    tag: &str,
    points: &[(&'static str, &'static str)],
    fault: Option<Arc<FaultPlan>>,
    warm_burst: Option<(usize, usize)>,
) -> Result<ServePhase> {
    let base =
        std::env::temp_dir().join(format!("caba_bench_serve_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).with_context(|| format!("create {}", base.display()))?;
    let socket = base.join("serve.sock");
    let mut opts = ServeOpts::new(&socket);
    opts.jobs = 2;
    opts.default_deadline_ms = 120_000;
    opts.store_dir = Some(base.join("store"));
    opts.fault = fault;
    let server = serve::Server::bind(opts)?;
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());

    let result = (|| -> Result<ServePhase> {
        let mut digests = Vec::with_capacity(points.len());
        let mut errors = 0u64;
        for &(app, design) in points {
            let v = serve_request(&socket, app, design)?;
            match v.get("status").and_then(Json::as_str) {
                Some("ok") => digests.push(Some(
                    v.get("stats_digest")
                        .and_then(Json::as_str)
                        .ok_or_else(|| anyhow!("ok response without stats_digest"))?
                        .to_string(),
                )),
                Some("error") => {
                    errors += 1;
                    digests.push(None);
                }
                other => anyhow::bail!("unexpected serve response status {other:?}"),
            }
        }

        // Errors are never cached, so a retry must recompute and succeed.
        let mut retry_ok = true;
        for (i, d) in digests.iter().enumerate() {
            if d.is_none() {
                let (app, design) = points[i];
                let v = serve_request(&socket, app, design)?;
                retry_ok &= v.get("status").and_then(Json::as_str) == Some("ok");
            }
        }

        let (mut warm_hits, mut warm_dt) = (0usize, 0.0f64);
        let warm_lat = Histogram::new();
        if let Some((clients, reqs_each)) = warm_burst {
            let t0 = Instant::now();
            let counts = std::thread::scope(|scope| {
                let handles: Vec<_> = (0..clients)
                    .map(|c| {
                        let socket = &socket;
                        let warm_lat = &warm_lat;
                        scope.spawn(move || -> Result<usize> {
                            let mut hits = 0usize;
                            for r in 0..reqs_each {
                                let (app, design) = points[(c + r) % points.len()];
                                let t_req = Instant::now();
                                let v = serve_request(socket, app, design)?;
                                warm_lat.record_duration(t_req.elapsed());
                                if v.get("status").and_then(Json::as_str) == Some("ok")
                                    && v.get("source").and_then(Json::as_str) == Some("warm")
                                {
                                    hits += 1;
                                }
                            }
                            Ok(hits)
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join()).collect::<Vec<_>>()
            });
            warm_dt = t0.elapsed().as_secs_f64().max(1e-9);
            for c in counts {
                warm_hits += c.map_err(|_| anyhow!("warm-burst client panicked"))??;
            }
        }

        Ok(ServePhase { digests, errors, retry_ok, warm_hits, warm_dt, warm_lat: warm_lat.snapshot() })
    })();

    // Always drain, even on a client-side error — the accept loop polls
    // the stop flag, so this cannot hang on a wedged socket.
    handle.stop();
    let summary = server_thread.join().map_err(|_| anyhow!("serve thread panicked"))?;
    let _ = std::fs::remove_dir_all(&base);
    summary?;
    result
}

/// The serve family: a clean phase (cold pass + warm burst), then a
/// fault phase on fresh dirs with one injected worker panic
/// (`panic_at_job=1` is 0-based — the second cold job dies). The daemon
/// must survive it, keep every other answer bit-identical to the clean
/// run, and recompute the failed point on retry.
fn measure_serve(quick: bool) -> Result<ServePoint> {
    let points: &[(&'static str, &'static str)] = if quick {
        &[("SLA", "Base"), ("SLA", "CABA-BDI")]
    } else {
        &[("SLA", "Base"), ("SLA", "CABA-BDI"), ("PVC", "Base"), ("PVC", "CABA-BDI")]
    };
    let burst = if quick { (2, 25) } else { (4, 50) };
    let clean = serve_phase("clean", points, None, Some(burst))?;
    if clean.errors != 0 {
        anyhow::bail!("serve clean phase saw {} unexpected job errors", clean.errors);
    }
    let plan = Arc::new(FaultPlan::parse("panic_at_job=1")?);
    let (fault_errors, survived, bitident, retry) =
        match serve_phase("fault", points, Some(plan), None) {
            Ok(f) => {
                let bitident =
                    f.digests.iter().zip(&clean.digests).all(|(f, c)| f.is_none() || f == c);
                (f.errors, true, bitident, f.retry_ok)
            }
            Err(_) => (0, false, false, false),
        };
    Ok(ServePoint {
        cold_points: points.len(),
        warm_requests: clean.warm_hits,
        warm_hits_per_s: clean.warm_hits as f64 / clean.warm_dt.max(1e-9),
        warm_p50_us: clean.warm_lat.p50(),
        warm_p95_us: clean.warm_lat.p95(),
        warm_p99_us: clean.warm_lat.p99(),
        fault_errors,
        survived,
        bitident_vs_clean: bitident,
        retry_recovers: retry,
    })
}

/// The overload family: one daemon, one worker, queue cap 4, brownout
/// threshold 1 ms with a 1-sample window. Seed a warm point, then fire a
/// 4x-queue-cap burst of *distinct* cold points from 4 client threads
/// while a fifth thread hammers the warm point until the storm ends.
/// Cold requests serialize behind the single worker, so queue waits blow
/// past the threshold after the first claim and the controller sheds the
/// rest of the burst; warm hits never touch the queue and must keep
/// flowing throughout.
fn measure_overload() -> Result<OverloadPoint> {
    use std::sync::atomic::{AtomicBool, Ordering};

    let queue_cap = 4usize;
    let burst = queue_cap * 4;
    let clients = 4usize;
    let base = std::env::temp_dir().join(format!("caba_bench_overload_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&base);
    std::fs::create_dir_all(&base).with_context(|| format!("create {}", base.display()))?;
    let socket = base.join("serve.sock");
    let mut opts = ServeOpts::new(&socket);
    opts.jobs = 1;
    opts.queue_cap = queue_cap;
    opts.default_deadline_ms = 120_000;
    opts.store_dir = Some(base.join("store"));
    opts.brownout_p95_ms = 1;
    opts.brownout_min_samples = 1;
    let server = serve::Server::bind(opts)?;
    let handle = server.handle();
    let server_thread = std::thread::spawn(move || server.run());

    let result = (|| -> Result<(u64, usize, f64)> {
        // Seed the warm point before any pressure exists.
        let v = serve_request(&socket, "SLA", "Base")?;
        if v.get("status").and_then(Json::as_str) != Some("ok") {
            anyhow::bail!("overload warm seed failed");
        }

        let stop = AtomicBool::new(false);
        std::thread::scope(|scope| -> Result<(u64, usize, f64)> {
            let socket_ref = &socket;
            let stop_ref = &stop;
            // The warm hammer: full-speed requests for the stored point
            // until the storm ends. Warm answers are served on the
            // connection thread — no queue, no worker — so brownout must
            // not slow them down.
            let hammer = scope.spawn(move || -> Result<(usize, f64)> {
                let t0 = Instant::now();
                let mut hits = 0usize;
                while !stop_ref.load(Ordering::Relaxed) {
                    let v = serve_request(socket_ref, "SLA", "Base")?;
                    if v.get("status").and_then(Json::as_str) == Some("ok")
                        && v.get("source").and_then(Json::as_str) == Some("warm")
                    {
                        hits += 1;
                    }
                }
                Ok((hits, t0.elapsed().as_secs_f64().max(1e-9)))
            });
            // The storm: `burst` distinct cold points (distinct scales →
            // distinct job keys), `clients` threads issuing them. Shed
            // answers return immediately; admitted ones block until the
            // single worker gets there.
            let storm: Vec<_> = (0..clients)
                .map(|c| {
                    scope.spawn(move || -> Result<u64> {
                        let mut sheds = 0u64;
                        for r in 0..burst / clients {
                            let scale = 0.011 + 0.001 * (c * (burst / clients) + r) as f64;
                            let line = format!(
                                "{{\"verb\":\"sweep\",\"app\":\"PVC\",\"design\":\"Base\",\
                                 \"scale\":{scale},\"set\":{{\"n_sms\":2,\"max_cycles\":150000}}}}"
                            );
                            let resp = serve::client_request(socket_ref, &line)?;
                            let v = serve::json::parse(&resp)
                                .map_err(|e| anyhow!("bad overload response {resp:?}: {e:#}"))?;
                            match v.get("status").and_then(Json::as_str) {
                                Some("ok") => {}
                                Some("shed") => sheds += 1,
                                other => anyhow::bail!("unexpected overload status {other:?}"),
                            }
                        }
                        Ok(sheds)
                    })
                })
                .collect();
            let mut client_sheds = 0u64;
            for s in storm {
                client_sheds += s.join().map_err(|_| anyhow!("storm client panicked"))??;
            }
            stop.store(true, Ordering::Relaxed);
            let (hits, dt) = hammer.join().map_err(|_| anyhow!("warm hammer panicked"))??;
            Ok((client_sheds, hits, dt))
        })
    })();

    // Daemon-side counters carry the shed split; read before drain so the
    // numbers describe the storm, then always drain.
    let counters = handle.counters();
    handle.stop();
    let survived = matches!(server_thread.join(), Ok(Ok(_)));
    let _ = std::fs::remove_dir_all(&base);
    let (_client_sheds, warm_hits, warm_dt) = result?;
    Ok(OverloadPoint {
        burst_requests: burst,
        queue_cap,
        shed: counters.shed,
        brownout_shed: counters.brownout_shed,
        brownout_engaged: counters.brownout_entered > 0,
        warm_hits,
        warm_hits_per_s: warm_hits as f64 / warm_dt,
        survived,
    })
}

/// Parse a floors file: `key=value` lines, `#` comments. Known keys:
/// `min_compress_mlines_per_s`, `min_memo_warm_mlines_per_s`,
/// `min_memo_hit_rate`, `min_sim_kcycles_per_s`, `min_lut_hit_rate`,
/// `min_event_speedup`, `min_shard_speedup`, `min_serve_warm_hits_per_s`,
/// `min_brownout_warm_hits_per_s`, and the one ceiling:
/// `max_telemetry_overhead`.
fn parse_floors(text: &str) -> Result<Vec<(String, f64)>> {
    let mut floors = Vec::new();
    for (ln, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (k, v) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("floors line {} is not key=value: {line:?}", ln + 1))?;
        let val: f64 = v
            .trim()
            .parse()
            .with_context(|| format!("floors line {}: bad value {v:?}", ln + 1))?;
        floors.push((k.trim().to_string(), val));
    }
    Ok(floors)
}

fn check_floors(report: &mut BenchReport, floors: &[(String, f64)]) {
    for (key, floor) in floors {
        let worst: Option<f64> = match key.as_str() {
            "min_compress_mlines_per_s" => report
                .compress
                .iter()
                .map(|c| c.mlines_per_s)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            "min_memo_warm_mlines_per_s" => Some(report.memo_warm_mlines_per_s),
            "min_memo_hit_rate" => Some(report.memo_hit_rate),
            "min_sim_kcycles_per_s" => report
                .sim
                .iter()
                .map(|s| s.kcycles_per_s)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            // Worst §8.1 LUT hit rate over the memo-design sim points: the
            // emergent-hit-rate path must never silently collapse to zero.
            "min_lut_hit_rate" => report
                .sim
                .iter()
                .filter_map(|s| s.lut_hit_rate)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            // Worst event-driven-over-strict speedup across the tick
            // comparison points.
            "min_event_speedup" => report
                .tick
                .iter()
                .map(|t| t.speedup)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            // BEST sharded-over-serial speedup across the threads>1
            // points (max, not min: CI runners may expose only 2 cores,
            // where the 4-thread point oversubscribes — the floor guards
            // against sharding regressing into pure overhead, not against
            // a small host).
            "min_shard_speedup" => report
                .shard
                .iter()
                .filter(|p| p.threads > 1)
                .map(|p| p.speedup)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.max(v)))),
            // Worst warm-burst throughput of the serve family: warm
            // answers come straight from the store-backed cache, so a
            // collapse here means the serve hot path (admission, cache
            // read-through, response render) regressed, not the simulator.
            "min_serve_warm_hits_per_s" => report
                .serve
                .iter()
                .map(|p| p.warm_hits_per_s)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            // Worst warm throughput measured DURING a brownout storm: the
            // warm path must stay a connection-thread cache read, immune
            // to the cold queue melting down next to it.
            "min_brownout_warm_hits_per_s" => report
                .overload
                .iter()
                .map(|p| p.warm_hits_per_s)
                .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.min(v)))),
            // The one ceiling key (bigger is worse): worst = the HIGHEST
            // measured recorder overhead, violated when it EXCEEDS the
            // configured value. Handled inline because the shared check
            // below assumes floor semantics.
            "max_telemetry_overhead" => {
                let worst = report
                    .telemetry
                    .iter()
                    .map(|t| t.overhead)
                    .fold(None, |a: Option<f64>, v| Some(a.map_or(v, |a| a.max(v))));
                match worst {
                    Some(w) if w > *floor => report
                        .violations
                        .push(format!("{key}: measured {w:.3} > ceiling {floor:.3}")),
                    None => report
                        .violations
                        .push(format!("{key}: no measurements to check")),
                    _ => {}
                }
                continue;
            }
            other => {
                report
                    .violations
                    .push(format!("unknown floor key {other:?} (typo in floors file?)"));
                continue;
            }
        };
        match worst {
            Some(w) if w < *floor => report
                .violations
                .push(format!("{key}: measured {w:.3} < floor {floor:.3}")),
            None => report
                .violations
                .push(format!("{key}: no measurements to check")),
            _ => {}
        }
    }
}

impl BenchReport {
    /// Hand-rolled JSON (the offline image has no serde). All keys are
    /// fixed identifiers and app/design names are `[A-Za-z0-9_-]`, so no
    /// escaping is needed.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str("  \"schema\": \"caba-bench-v1\",\n");
        let _ = writeln!(s, "  \"mode\": \"{}\",", self.mode);
        let _ = writeln!(s, "  \"corpus_lines\": {},", self.corpus_lines);
        let _ = writeln!(s, "  \"sim_scale\": {},", self.sim_scale);
        s.push_str("  \"compress\": [\n");
        for (i, c) in self.compress.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"algo\": \"{}\", \"mlines_per_s\": {:.3}, \"mb_per_s\": {:.1}, \"size_checksum\": {}}}{}",
                c.algo,
                c.mlines_per_s,
                c.mb_per_s,
                c.size_checksum,
                if i + 1 < self.compress.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        let _ = writeln!(
            s,
            "  \"oracle_memo\": {{\"cold_mlines_per_s\": {:.3}, \"warm_mlines_per_s\": {:.3}, \"hit_rate\": {:.4}}},",
            self.memo_cold_mlines_per_s, self.memo_warm_mlines_per_s, self.memo_hit_rate
        );
        s.push_str("  \"sim\": [\n");
        for (i, p) in self.sim.iter().enumerate() {
            let opt = |v: Option<f64>| match v {
                Some(r) => format!("{r:.4}"),
                None => "null".to_string(),
            };
            let _ = writeln!(
                s,
                "    {{\"app\": \"{}\", \"design\": \"{}\", \"cycles\": {}, \"warp_insts\": {}, \
                 \"kcycles_per_s\": {:.1}, \"kinsts_per_s\": {:.1}, \"memo_hit_rate\": {}, \
                 \"lut_hit_rate\": {}}}{}",
                p.app,
                p.design,
                p.cycles,
                p.warp_insts,
                p.kcycles_per_s,
                p.kinsts_per_s,
                opt(p.memo_hit_rate),
                opt(p.lut_hit_rate),
                if i + 1 < self.sim.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"strict_tick\": [\n");
        for (i, t) in self.tick.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"app\": \"{}\", \"design\": \"{}\", \"kcycles_per_s_strict\": {:.1}, \
                 \"kcycles_per_s_event\": {:.1}, \"speedup\": {:.3}, \"stats_match\": {}}}{}",
                t.app,
                t.design,
                t.kcycles_per_s_strict,
                t.kcycles_per_s_event,
                t.speedup,
                t.stats_match,
                if i + 1 < self.tick.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"sim_threads\": [\n");
        for (i, p) in self.shard.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"app\": \"{}\", \"design\": \"{}\", \"threads\": {}, \
                 \"kcycles_per_s\": {:.1}, \"speedup\": {:.3}, \"stats_match\": {}}}{}",
                p.app,
                p.design,
                p.threads,
                p.kcycles_per_s,
                p.speedup,
                p.stats_match,
                if i + 1 < self.shard.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"telemetry\": [\n");
        for (i, t) in self.telemetry.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"app\": \"{}\", \"design\": \"{}\", \"kcycles_per_s_off\": {:.1}, \
                 \"kcycles_per_s_on\": {:.1}, \"overhead\": {:.4}, \"stats_match\": {}, \
                 \"windows\": {}, \"spans\": {}}}{}",
                t.app,
                t.design,
                t.kcycles_per_s_off,
                t.kcycles_per_s_on,
                t.overhead,
                t.stats_match,
                t.windows,
                t.spans,
                if i + 1 < self.telemetry.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"serve\": [\n");
        for (i, p) in self.serve.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"cold_points\": {}, \"warm_requests\": {}, \"warm_hits_per_s\": {:.1}, \
                 \"warm_p50_us\": {}, \"warm_p95_us\": {}, \"warm_p99_us\": {}, \
                 \"fault_errors\": {}, \"survived\": {}, \"bitident_vs_clean\": {}, \
                 \"retry_recovers\": {}}}{}",
                p.cold_points,
                p.warm_requests,
                p.warm_hits_per_s,
                p.warm_p50_us,
                p.warm_p95_us,
                p.warm_p99_us,
                p.fault_errors,
                p.survived,
                p.bitident_vs_clean,
                p.retry_recovers,
                if i + 1 < self.serve.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"overload\": [\n");
        for (i, p) in self.overload.iter().enumerate() {
            let _ = writeln!(
                s,
                "    {{\"burst_requests\": {}, \"queue_cap\": {}, \"shed\": {}, \
                 \"brownout_shed\": {}, \"brownout_engaged\": {}, \"warm_hits\": {}, \
                 \"warm_hits_per_s\": {:.1}, \"survived\": {}}}{}",
                p.burst_requests,
                p.queue_cap,
                p.shed,
                p.brownout_shed,
                p.brownout_engaged,
                p.warm_hits,
                p.warm_hits_per_s,
                p.survived,
                if i + 1 < self.overload.len() { "," } else { "" }
            );
        }
        s.push_str("  ],\n");
        s.push_str("  \"floor_violations\": [");
        for (i, v) in self.violations.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            // Violation strings contain only our own formatting plus
            // floor-file keys; escape the quotes/backslashes defensively.
            let esc: String = v
                .chars()
                .flat_map(|c| match c {
                    '"' => vec!['\\', '"'],
                    '\\' => vec!['\\', '\\'],
                    c => vec![c],
                })
                .collect();
            let _ = write!(s, "\"{esc}\"");
        }
        s.push_str("]\n}\n");
        s
    }

    /// Terminal summary mirroring `perf_hotpath`'s style.
    pub fn human_summary(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "# caba bench ({} mode, corpus {} lines)\n", self.mode, self.corpus_lines);
        for c in &self.compress {
            let _ = writeln!(
                s,
                "compress {:<7} {:>8.1} Mlines/s  ({:>7.1} MB/s, checksum {})",
                c.algo, c.mlines_per_s, c.mb_per_s, c.size_checksum
            );
        }
        let _ = writeln!(
            s,
            "\noracle memo: cold {:.1} Mlines/s, warm {:.1} Mlines/s, hit rate {:.1}%",
            self.memo_cold_mlines_per_s,
            self.memo_warm_mlines_per_s,
            self.memo_hit_rate * 100.0
        );
        s.push('\n');
        for p in &self.sim {
            let pct = |v: Option<f64>| match v {
                Some(r) => format!("{:.1}%", r * 100.0),
                None => "n/a".to_string(),
            };
            let _ = writeln!(
                s,
                "sim {:>4}/{:<13} {:>9.1} kcycles/s  {:>9.1} kinsts/s  (cycles {}, memo hit {}, LUT hit {})",
                p.app,
                p.design,
                p.kcycles_per_s,
                p.kinsts_per_s,
                p.cycles,
                pct(p.memo_hit_rate),
                pct(p.lut_hit_rate)
            );
        }
        if !self.tick.is_empty() {
            s.push('\n');
        }
        for t in &self.tick {
            let _ = writeln!(
                s,
                "tick {:>4}/{:<13} strict {:>9.1} kcycles/s  event {:>9.1} kcycles/s  speedup {:.2}x  stats {}",
                t.app,
                t.design,
                t.kcycles_per_s_strict,
                t.kcycles_per_s_event,
                t.speedup,
                if t.stats_match { "identical" } else { "DIVERGED" }
            );
        }
        if !self.shard.is_empty() {
            s.push('\n');
        }
        for p in &self.shard {
            let _ = writeln!(
                s,
                "shard {:>4}/{:<13} sim_threads={} {:>9.1} kcycles/s  speedup {:.2}x  stats {}",
                p.app,
                p.design,
                p.threads,
                p.kcycles_per_s,
                p.speedup,
                if p.stats_match { "identical" } else { "DIVERGED" }
            );
        }
        if !self.telemetry.is_empty() {
            s.push('\n');
        }
        for t in &self.telemetry {
            let _ = writeln!(
                s,
                "telem {:>4}/{:<13} off {:>9.1} kcycles/s  on {:>9.1} kcycles/s  overhead {:+.1}%  stats {}  ({} windows, {} spans)",
                t.app,
                t.design,
                t.kcycles_per_s_off,
                t.kcycles_per_s_on,
                t.overhead * 100.0,
                if t.stats_match { "identical" } else { "DIVERGED" },
                t.windows,
                t.spans
            );
        }
        if !self.serve.is_empty() {
            s.push('\n');
        }
        for p in &self.serve {
            let _ = writeln!(
                s,
                "serve {} cold points  warm burst {} reqs @ {:>8.1} hits/s  p50/p95/p99 {}/{}/{} us  fault: {} error(s), {}, retry {}",
                p.cold_points,
                p.warm_requests,
                p.warm_hits_per_s,
                p.warm_p50_us,
                p.warm_p95_us,
                p.warm_p99_us,
                p.fault_errors,
                if p.survived && p.bitident_vs_clean { "survived bit-identical" } else { "FAILED" },
                if p.retry_recovers { "recovered" } else { "STUCK" }
            );
        }
        for p in &self.overload {
            let _ = writeln!(
                s,
                "overload burst {} (queue {})  shed {} ({} brownout)  warm during storm {} @ {:>8.1} hits/s  {}",
                p.burst_requests,
                p.queue_cap,
                p.shed,
                p.brownout_shed,
                p.warm_hits,
                p.warm_hits_per_s,
                if p.survived && p.brownout_engaged { "browned out and survived" } else { "FAILED" }
            );
        }
        for v in &self.violations {
            let _ = writeln!(s, "\nFLOOR VIOLATION: {v}");
        }
        s
    }
}

/// Run the suite, write the JSON, and return the report (callers decide
/// what a non-empty `violations` list means; the CLI exits non-zero).
pub fn run(opts: &BenchOpts) -> Result<BenchReport> {
    let (n_per_pattern, sim_scale, mode) = if opts.quick {
        (1024, 0.03, "quick")
    } else {
        (4096, 0.1, "full")
    };
    let lines = corpus(n_per_pattern);

    let compress = measure_compress(&lines);
    let (cold, warm, hit_rate) = measure_memo(&lines);

    let pairs: Vec<(&'static str, Design)> = if opts.quick {
        vec![
            ("PVC", Design::base()),
            ("PVC", Design::caba(Algo::Bdi)),
            ("FRAG", Design::caba_memo()),
        ]
    } else {
        vec![
            ("PVC", Design::base()),
            ("PVC", Design::caba(Algo::Bdi)),
            ("MM", Design::caba(Algo::Bdi)),
            ("TRA", Design::caba(Algo::Fpc)),
            ("FRAG", Design::caba_memo()),
            ("NNA", Design::caba_memo_hybrid()),
        ]
    };
    // Strict-vs-event tick comparison: one memory-bound point (PVC under
    // full CABA-BDI compression — long DRAM-stall windows, the skip
    // machinery's best case) and one compute-bound point (FRAG under
    // CABA-Memo — busy SFU pipes, its worst case). Full mode adds the
    // plain baseline and the hybrid. Runs first so its event-mode
    // simulations double as the sim points for overlapping pairs below.
    let tick_pairs: Vec<(&'static str, Design)> = if opts.quick {
        vec![("PVC", Design::caba(Algo::Bdi)), ("FRAG", Design::caba_memo())]
    } else {
        vec![
            ("PVC", Design::caba(Algo::Bdi)),
            ("FRAG", Design::caba_memo()),
            ("SLA", Design::base()),
            ("NNA", Design::caba_memo_hybrid()),
        ]
    };
    let (tick, mut tick_event_points) = measure_tick(&tick_pairs, sim_scale)?;

    // Intra-sim sharding: one memory-bound point at 1/2/4 threads (the
    // differential suite covers the full matrix; here we track the perf
    // trajectory and keep a bit-identity check on the bench path).
    let shard = measure_shard("PVC", Design::caba(Algo::Bdi), sim_scale)?;

    // Flight-recorder overhead: the memory-bound headline point always;
    // full mode adds a compute-bound memoizing point (dense span traffic —
    // the span log's worst case).
    let telem_pairs: Vec<(&'static str, Design)> = if opts.quick {
        vec![("PVC", Design::caba(Algo::Bdi))]
    } else {
        vec![("PVC", Design::caba(Algo::Bdi)), ("FRAG", Design::caba_memo())]
    };
    let telemetry = telem_pairs
        .iter()
        .map(|&(a, d)| measure_telemetry(a, d, sim_scale))
        .collect::<Result<Vec<_>>>()?;

    // The fault-tolerant serve loop, end to end (an in-process daemon —
    // the same code path `caba serve` runs).
    let serve = vec![measure_serve(opts.quick)?];

    // The overload/brownout family (PR 10): same burst in both modes —
    // the jobs are tiny and the point is service behavior, not speed.
    let overload = vec![measure_overload()?];

    // Assemble the sim section in `pairs` order, reusing the event-mode
    // run from the tick comparison where the pair overlaps (identical
    // config/scale — same measurement either way, half the simulations).
    let mut sim = Vec::with_capacity(pairs.len());
    for &(app_name, design) in &pairs {
        let reused = tick_pairs
            .iter()
            .position(|&(a, d)| a == app_name && d.name == design.name)
            .and_then(|i| tick_event_points[i].take());
        match reused {
            Some(point) => sim.push(point),
            None => sim.push(measure_one_sim(app_name, design, sim_scale)?.0),
        }
    }

    let mut report = BenchReport {
        mode,
        corpus_lines: lines.len(),
        sim_scale,
        compress,
        memo_cold_mlines_per_s: cold,
        memo_warm_mlines_per_s: warm,
        memo_hit_rate: hit_rate,
        sim,
        tick,
        shard,
        telemetry,
        serve,
        overload,
        violations: Vec::new(),
    };

    // Stats divergence between tick modes is a violation regardless of the
    // floors file — equivalence is a correctness contract, not a perf bar.
    for t in &report.tick {
        if !t.stats_match {
            report.violations.push(format!(
                "strict_tick differential: {}/{} stats diverged between tick modes",
                t.app, t.design
            ));
        }
    }
    // Same contract for thread counts: sharding must never change results.
    for p in &report.shard {
        if !p.stats_match {
            report.violations.push(format!(
                "sim_threads differential: {}/{} stats diverged at {} threads",
                p.app, p.design, p.threads
            ));
        }
    }
    // And for the flight recorder: turning it on must not perturb the run.
    for t in &report.telemetry {
        if !t.stats_match {
            report.violations.push(format!(
                "telemetry observation-only: {}/{} SimStats changed with the recorder on",
                t.app, t.design
            ));
        }
    }
    // The serve fault contract is unconditional too: one injected panic
    // must yield exactly one typed error, never kill the daemon, never
    // perturb other answers, and never poison the failed key.
    for p in &report.serve {
        if !p.survived {
            report
                .violations
                .push("serve fault-injection: daemon died or stopped answering".to_string());
        }
        if p.fault_errors != 1 {
            report.violations.push(format!(
                "serve fault-injection: expected exactly 1 typed error, saw {}",
                p.fault_errors
            ));
        }
        if !p.bitident_vs_clean {
            report.violations.push(
                "serve fault-injection: unaffected responses diverged from the clean run"
                    .to_string(),
            );
        }
        if !p.retry_recovers {
            report.violations.push(
                "serve fault-injection: retry of the failed point did not recover".to_string(),
            );
        }
    }
    // The overload contract is unconditional: the daemon survives the
    // storm and the brownout controller actually sheds — a 4x-queue-cap
    // burst against one worker with a 1 ms threshold that produces zero
    // brownout sheds means the controller is broken, not the host slow.
    for p in &report.overload {
        if !p.survived {
            report
                .violations
                .push("serve overload: daemon died or stopped answering".to_string());
        }
        if p.brownout_shed == 0 {
            report.violations.push(format!(
                "serve overload: burst of {} produced no brownout sheds (engaged={}, shed={})",
                p.burst_requests, p.brownout_engaged, p.shed
            ));
        }
    }

    if let Some(path) = &opts.floors {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading floors file {path:?}"))?;
        let floors = parse_floors(&text)?;
        check_floors(&mut report, &floors);
    }

    std::fs::write(&opts.out, report.to_json())
        .with_context(|| format!("writing bench report to {:?}", opts.out))?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn floors_parse_and_check() {
        let floors = parse_floors(
            "# comment\n\nmin_memo_hit_rate=0.4\nmin_sim_kcycles_per_s = 1.0\n",
        )
        .unwrap();
        assert_eq!(floors.len(), 2);
        let mut report = BenchReport {
            mode: "quick",
            corpus_lines: 0,
            sim_scale: 0.03,
            compress: vec![],
            memo_cold_mlines_per_s: 1.0,
            memo_warm_mlines_per_s: 10.0,
            memo_hit_rate: 0.5,
            tick: vec![],
            shard: vec![],
            telemetry: vec![],
            serve: vec![],
            overload: vec![],
            sim: vec![SimPoint {
                app: "PVC",
                design: "Base",
                cycles: 1000,
                warp_insts: 2000,
                kcycles_per_s: 0.5, // below floor
                kinsts_per_s: 1.0,
                memo_hit_rate: None,
                lut_hit_rate: None,
            }],
            violations: Vec::new(),
        };
        check_floors(&mut report, &floors);
        assert_eq!(report.violations.len(), 1);
        assert!(report.violations[0].contains("min_sim_kcycles_per_s"));
        // Unknown keys are flagged, not ignored.
        check_floors(&mut report, &[("min_typo".to_string(), 1.0)]);
        assert_eq!(report.violations.len(), 2);
        // LUT floor: checked only over memo-design points; a non-memo-only
        // report has nothing to check (flagged), a low measured rate fails.
        check_floors(&mut report, &[("min_lut_hit_rate".to_string(), 0.1)]);
        assert_eq!(report.violations.len(), 3);
        assert!(report.violations[2].contains("no measurements"));
        report.sim[0].lut_hit_rate = Some(0.05);
        check_floors(&mut report, &[("min_lut_hit_rate".to_string(), 0.1)]);
        assert_eq!(report.violations.len(), 4);
        // Shard floor: checked over the BEST threads>1 speedup (a 2-core
        // host legitimately loses on the oversubscribed 4-thread point).
        check_floors(&mut report, &[("min_shard_speedup".to_string(), 1.0)]);
        assert_eq!(report.violations.len(), 5); // empty → nothing to check
        assert!(report.violations[4].contains("no measurements"));
        let shard_point = |threads: usize, speedup: f64| ShardPoint {
            app: "PVC",
            design: "CABA-BDI",
            threads,
            kcycles_per_s: 100.0 * speedup,
            speedup,
            stats_match: true,
        };
        report.shard = vec![
            shard_point(1, 1.0),
            shard_point(2, 0.8),
            shard_point(4, 1.3),
        ];
        check_floors(&mut report, &[("min_shard_speedup".to_string(), 1.0)]);
        assert_eq!(report.violations.len(), 5); // max(0.8, 1.3) clears 1.0
        check_floors(&mut report, &[("min_shard_speedup".to_string(), 1.5)]);
        assert_eq!(report.violations.len(), 6);
        // Telemetry overhead is a CEILING: empty → flagged, a worst-case
        // overhead above the configured value fails, below passes.
        check_floors(&mut report, &[("max_telemetry_overhead".to_string(), 0.5)]);
        assert_eq!(report.violations.len(), 7);
        assert!(report.violations[6].contains("no measurements"));
        let telem_point = |overhead: f64| TelemetryPoint {
            app: "PVC",
            design: "CABA-BDI",
            kcycles_per_s_off: 100.0,
            kcycles_per_s_on: 100.0 / (1.0 + overhead),
            overhead,
            stats_match: true,
            windows: 8,
            spans: 3,
        };
        report.telemetry = vec![telem_point(0.02), telem_point(0.08)];
        check_floors(&mut report, &[("max_telemetry_overhead".to_string(), 0.5)]);
        assert_eq!(report.violations.len(), 7); // worst 0.08 under ceiling
        check_floors(&mut report, &[("max_telemetry_overhead".to_string(), 0.05)]);
        assert_eq!(report.violations.len(), 8);
        assert!(report.violations[7].contains("> ceiling"));
        // Serve warm-throughput floor: empty → flagged, a slow warm burst
        // fails, a fast one passes.
        check_floors(&mut report, &[("min_serve_warm_hits_per_s".to_string(), 20.0)]);
        assert_eq!(report.violations.len(), 9);
        assert!(report.violations[8].contains("no measurements"));
        report.serve = vec![ServePoint {
            cold_points: 4,
            warm_requests: 200,
            warm_hits_per_s: 12.0,
            warm_p50_us: 2047,
            warm_p95_us: 8191,
            warm_p99_us: 16383,
            fault_errors: 1,
            survived: true,
            bitident_vs_clean: true,
            retry_recovers: true,
        }];
        check_floors(&mut report, &[("min_serve_warm_hits_per_s".to_string(), 20.0)]);
        assert_eq!(report.violations.len(), 10);
        report.serve[0].warm_hits_per_s = 250.0;
        check_floors(&mut report, &[("min_serve_warm_hits_per_s".to_string(), 20.0)]);
        assert_eq!(report.violations.len(), 10);
        // Brownout warm-throughput floor (PR 10): empty → flagged, warm
        // service collapsing during the storm fails, staying fast passes.
        check_floors(&mut report, &[("min_brownout_warm_hits_per_s".to_string(), 10.0)]);
        assert_eq!(report.violations.len(), 11);
        assert!(report.violations[10].contains("no measurements"));
        report.overload = vec![OverloadPoint {
            burst_requests: 16,
            queue_cap: 4,
            shed: 12,
            brownout_shed: 12,
            brownout_engaged: true,
            warm_hits: 40,
            warm_hits_per_s: 4.0,
            survived: true,
        }];
        check_floors(&mut report, &[("min_brownout_warm_hits_per_s".to_string(), 10.0)]);
        assert_eq!(report.violations.len(), 12);
        report.overload[0].warm_hits_per_s = 150.0;
        check_floors(&mut report, &[("min_brownout_warm_hits_per_s".to_string(), 10.0)]);
        assert_eq!(report.violations.len(), 12);
    }

    #[test]
    fn floors_reject_malformed_lines() {
        assert!(parse_floors("not a pair").is_err());
        assert!(parse_floors("min_memo_hit_rate=abc").is_err());
    }

    #[test]
    fn json_shape_is_wellformed_enough() {
        let report = BenchReport {
            mode: "quick",
            corpus_lines: 3,
            sim_scale: 0.03,
            compress: vec![CompressPoint {
                algo: "BDI",
                mlines_per_s: 1.5,
                mb_per_s: 192.0,
                size_checksum: 42,
            }],
            memo_cold_mlines_per_s: 1.0,
            memo_warm_mlines_per_s: 2.0,
            memo_hit_rate: 0.75,
            sim: vec![],
            tick: vec![TickPoint {
                app: "PVC",
                design: "CABA-BDI",
                kcycles_per_s_strict: 100.0,
                kcycles_per_s_event: 250.0,
                speedup: 2.5,
                stats_match: true,
            }],
            shard: vec![ShardPoint {
                app: "PVC",
                design: "CABA-BDI",
                threads: 2,
                kcycles_per_s: 400.0,
                speedup: 1.6,
                stats_match: true,
            }],
            telemetry: vec![TelemetryPoint {
                app: "PVC",
                design: "CABA-BDI",
                kcycles_per_s_off: 250.0,
                kcycles_per_s_on: 240.0,
                overhead: 0.0417,
                stats_match: true,
                windows: 12,
                spans: 40,
            }],
            serve: vec![ServePoint {
                cold_points: 4,
                warm_requests: 200,
                warm_hits_per_s: 312.5,
                warm_p50_us: 1023,
                warm_p95_us: 4095,
                warm_p99_us: 8191,
                fault_errors: 1,
                survived: true,
                bitident_vs_clean: true,
                retry_recovers: true,
            }],
            overload: vec![OverloadPoint {
                burst_requests: 16,
                queue_cap: 4,
                shed: 12,
                brownout_shed: 11,
                brownout_engaged: true,
                warm_hits: 80,
                warm_hits_per_s: 160.0,
                survived: true,
            }],
            violations: vec!["min_x: measured 1 < floor 2".to_string()],
        };
        let j = report.to_json();
        assert!(j.contains("\"schema\": \"caba-bench-v1\""));
        assert!(j.contains("\"algo\": \"BDI\""));
        assert!(j.contains("\"sim_threads\""));
        assert!(j.contains("\"telemetry\""));
        assert!(j.contains("\"overhead\": 0.0417"));
        assert!(j.contains("\"warm_hits_per_s\": 312.5"));
        assert!(j.contains("\"warm_p95_us\": 4095"));
        assert!(j.contains("\"bitident_vs_clean\": true"));
        assert!(j.contains("\"brownout_shed\": 11"));
        assert!(j.contains("\"brownout_engaged\": true"));
        assert!(j.contains("floor_violations"));
        // Balanced braces/brackets (cheap well-formedness probe).
        assert_eq!(j.matches('{').count(), j.matches('}').count());
        assert_eq!(j.matches('[').count(), j.matches(']').count());
    }

    #[test]
    fn corpus_is_deterministic() {
        assert_eq!(corpus(4), corpus(4));
    }
}
