//! `caba` — CLI for the CABA reproduction.
//!
//! Subcommands (hand-rolled parser; clap is unavailable offline):
//!
//! ```text
//! caba list                         # apps and designs
//! caba table1 [--set k=v]...       # print the simulated configuration
//! caba run --app PVC --design CABA-BDI [--scale 0.1] [--threads N]
//!          [--oracle native|pjrt] [--timeline] [--json] [--set key=value]...
//! caba prof <out.json> --app PVC [--design D] [--scale S] [--set k=v]...
//! caba prof <out.json> --serve <socket>   # server request spans → Perfetto
//! caba fig <2|3|8|9|10|11|12|13|14|15|16|md|memo> [--scale 0.1]
//!          [--jobs N] [--set key=value]...
//! caba sweep [--apps PVC,MM|eval|all|memo] [--designs Base,CABA-BDI|headline]
//!            [--bw 0.5,1.0,2.0] [--scale 0.1] [--jobs N] [--set k=v]...
//!            [--trace file.cabatrace] [--store DIR] [--store-max-bytes B]
//! caba serve --socket /tmp/caba.sock [--jobs N] [--queue N]
//!            [--deadline-ms D] [--store DIR] [--store-max-bytes B]
//!            [--brownout-p95-ms P] [--brownout-min-samples N]
//!            [--fault spec] [--log]
//! caba client <socket> '<json request>' [--retries N] [--backoff-ms B]
//!             [--backoff-cap-ms C] [--seed S]
//! caba metrics <socket>                 # Prometheus exposition, decoded
//! caba trace record <app> [--design D] [--scale S] [--out file] [--set...]
//! caba trace replay <file.cabatrace> [--design D] [--set k=v]...
//! caba trace info <file.cabatrace>
//! caba trace import <dump.txt> [--out file] [--pattern random|zero|...]
//! caba bench [--quick] [--out BENCH_pr10.json] [--floors BENCH_floors.txt]
//! ```
//!
//! `sweep --store DIR` backs the run cache with the crash-safe on-disk
//! store: results persist across invocations, so re-sweeps are warm.
//! A failed job (corrupt trace, simulator panic) is reported as a typed
//! error with a nonzero exit instead of aborting the process.
//!
//! `serve` runs the sweep service: JSON requests over a unix socket with
//! in-flight dedup, store-backed warm hits, a bounded cold-miss queue
//! with load shedding, per-request deadlines and graceful SIGTERM drain
//! (see `DESIGN.md` §serve). `--fault` injects deterministic faults
//! (`panic_at_job=N,torn_write_at=N,...`) for robustness testing;
//! `--log` writes one structured stderr line per request. Every response
//! echoes a `request_id`; the `metrics`/`stats`/`trace` verbs (and
//! `caba metrics` / `caba prof --serve` as client-side sugar) expose the
//! daemon's observability registry — see `DESIGN.md` §5d.
//!
//! `run --timeline` prints the flight recorder's ASCII timeline (chip
//! sparklines + per-SM stall heatmap) after the usual summary; `run
//! --json` emits the whole run as one JSON object instead. `prof` runs
//! one point with the recorder on and writes Chrome trace-event JSON
//! (open it in <https://ui.perfetto.dev> or `chrome://tracing`). All
//! three default `telemetry_window` to 1024 when unset — recording is
//! observation-only, so results are bit-identical either way.
//!
//! `--jobs N` sets the sweep-engine worker count (default: one per
//! available core). Results are bit-identical for any worker count —
//! every simulation point is deterministic and self-contained.
//!
//! `--threads N` (alias for `--set sim_threads=N`) shards the per-core
//! tick loop *inside* one simulation; also bit-identical for any N (see
//! `tests/strict_tick_differential.rs`).

use anyhow::{anyhow, bail, Result};
use caba::report::figures::{self, RunCtx};
use caba::report::{figure_matrix, trace_summary, Series};
use caba::serve::{self, ServeOpts};
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::store::{FaultPlan, RunStore};
use caba::sweep::{resolve_jobs, RunCache, SweepEngine, SweepJob};
use caba::trace::{import as trace_import, replay::TraceData, TraceKind};
use caba::workload::apps::{self, AppSpec};
use caba::SimConfig;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

struct Args {
    positional: Vec<String>,
    flags: Vec<(String, String)>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = Vec::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            // A following `--flag` is the next flag, not this one's value
            // (boolean flags like `bench --quick --out x.json`).
            let val = match it.peek() {
                Some(v) if !v.starts_with("--") => it.next().unwrap_or_default(),
                _ => String::new(),
            };
            flags.push((name.to_string(), val));
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.as_str())
    }

    fn config(&self) -> Result<SimConfig> {
        let mut cfg = SimConfig::default();
        for (n, v) in &self.flags {
            if n == "set" {
                let (k, val) = v
                    .split_once('=')
                    .ok_or_else(|| anyhow!("--set expects key=value"))?;
                cfg.set(k, val)?;
            } else if n == "threads" {
                // Sugar for --set sim_threads=N; last writer wins either way.
                cfg.set("sim_threads", v)?;
            }
        }
        Ok(cfg)
    }

    fn scale(&self) -> f64 {
        self.flag("scale").and_then(|s| s.parse().ok()).unwrap_or(0.25)
    }

    /// Sweep worker count: `--jobs N`; 0/absent = one per available core.
    fn jobs(&self) -> Result<usize> {
        match self.flag("jobs") {
            None => Ok(0),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("--jobs expects a non-negative integer, got {v:?}")),
        }
    }
}

fn design_by_name(name: &str) -> Result<Design> {
    Design::by_name(name).ok_or_else(|| anyhow!("unknown design {name:?}; see `caba list`"))
}

/// Parse the `sweep --apps` selector.
fn apps_by_selector(sel: &str) -> Result<Vec<&'static AppSpec>> {
    match sel {
        "all" => Ok(apps::APPS.iter().chain(apps::MEMO_APPS.iter()).collect()),
        "eval" => Ok(apps::eval_set()),
        "memo" => Ok(apps::memo_suite()),
        list => list
            .split(',')
            .map(|n| {
                apps::find(n.trim()).ok_or_else(|| anyhow!("unknown app {n:?}; see `caba list`"))
            })
            .collect(),
    }
}

/// `--store-max-bytes B`: store disk budget in bytes, 0/absent = unbounded.
/// Shared by `sweep` and `serve`.
fn parse_store_max_bytes(args: &Args) -> Result<u64> {
    match args.flag("store-max-bytes") {
        None => Ok(0),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow!("--store-max-bytes expects a byte count, got {v:?}")),
    }
}

/// Parse the `sweep --designs` selector.
fn designs_by_selector(sel: &str) -> Result<Vec<Design>> {
    match sel {
        "headline" => Ok(Design::headline().to_vec()),
        list => list.split(',').map(|n| design_by_name(n.trim())).collect(),
    }
}

fn run() -> Result<()> {
    let args = parse_args();
    match args.positional.first().map(String::as_str) {
        Some("list") => {
            println!("# Applications ({} total, * = in the Figs. 8-16 eval set)", apps::APPS.len());
            for a in apps::APPS {
                println!(
                    "  {}{:<6} {:?}  {}",
                    if a.in_eval_set { "*" } else { " " },
                    a.name,
                    a.suite,
                    if a.memory_bound { "memory-bound" } else { "compute-bound" },
                );
            }
            println!(
                "\n# Compute-bound memoization suite ({} apps, §8.1 — see `caba fig memo`)",
                apps::MEMO_APPS.len()
            );
            for a in apps::MEMO_APPS {
                println!(
                    "   {:<6} {:?}  SFU-heavy, operand redundancy p={:.2} over {} classes",
                    a.name, a.suite, a.values.p_shared, a.values.classes,
                );
            }
            println!("\n# Designs");
            for d in Design::all() {
                println!("  {}", d.name);
            }
            Ok(())
        }
        Some("table1") => {
            println!("{}", args.config()?.table1());
            Ok(())
        }
        Some("run") => {
            let app_name = args.flag("app").ok_or_else(|| anyhow!("--app required"))?;
            let app = apps::find(app_name)
                .ok_or_else(|| anyhow!("unknown app {app_name:?}; see `caba list`"))?;
            let design = design_by_name(args.flag("design").unwrap_or("CABA-BDI"))?;
            let timeline = args.flag("timeline").is_some();
            let json = args.flag("json").is_some();
            let mut cfg = args.config()?;
            // Both render paths want the flight recorder; enabling it is
            // observation-only (SimStats stay bit-identical), so a default
            // cadence is safe. An explicit --set telemetry_window wins.
            if (timeline || json) && cfg.telemetry_window == 0 {
                cfg.telemetry_window = 1024;
            }
            let scale = args.scale();
            let mut sim = match args.flag("oracle") {
                Some("pjrt") => {
                    let oracle = caba::runtime::PjrtOracle::from_default_dir()?;
                    Simulator::with_oracle(cfg, design, app, scale, Box::new(
                        caba::compress::oracle::MemoOracle::new(oracle),
                    ))
                }
                Some("native") | None => Simulator::new(cfg, design, app, scale),
                Some(o) => bail!("unknown oracle {o:?} (native|pjrt)"),
            };
            let stats = sim.run();
            if json {
                print!(
                    "{}",
                    caba::report::jsonout::run_json(
                        app.name,
                        design.name,
                        &stats,
                        sim.cfg.n_mcs,
                        sim.telemetry_run().as_ref(),
                    )
                );
                return Ok(());
            }
            print_run(app.name, design.name, &stats, &sim);
            if timeline {
                if let Some(run) = sim.telemetry_run() {
                    println!();
                    print!("{}", caba::report::timeline::render(&run, 64));
                }
            }
            Ok(())
        }
        Some("prof") => {
            let out = args.positional.get(1).map(String::as_str).ok_or_else(|| {
                anyhow!("prof requires an output path, e.g. caba prof trace.json --app PVC")
            })?;
            // `--serve SOCKET`: export a running daemon's request spans
            // instead of simulating — fetch the `trace` verb, decode the
            // spans, render them with the same Chrome-trace writer.
            if let Some(socket) = args.flag("serve") {
                if socket.is_empty() {
                    bail!("--serve expects the daemon's socket path");
                }
                let resp = serve::client_request(Path::new(socket), r#"{"verb":"trace"}"#)?;
                let v = serve::json::parse(&resp)
                    .map_err(|e| anyhow!("trace response was not valid JSON: {e:#}"))?;
                if v.get("status").and_then(serve::json::Json::as_str) != Some("ok") {
                    bail!("trace verb failed: {resp}");
                }
                let spans: Vec<_> = v
                    .get("spans")
                    .and_then(serve::json::Json::elements)
                    .ok_or_else(|| anyhow!("trace response carried no spans array"))?
                    .iter()
                    .filter_map(serve::span_from_json)
                    .collect();
                let dropped =
                    v.get("dropped").and_then(serve::json::Json::as_u64).unwrap_or(0);
                let trace =
                    caba::telemetry::export::server_trace_json(&spans, socket, dropped);
                std::fs::write(out, &trace).map_err(|e| anyhow!("writing {out}: {e}"))?;
                println!(
                    "prof: wrote {out} ({} request spans from {socket}, {} dropped)",
                    spans.len(),
                    dropped
                );
                println!("open it in https://ui.perfetto.dev or chrome://tracing");
                return Ok(());
            }
            let app_name = args.flag("app").ok_or_else(|| anyhow!("--app required"))?;
            let app = apps::find(app_name)
                .ok_or_else(|| anyhow!("unknown app {app_name:?}; see `caba list`"))?;
            let design = design_by_name(args.flag("design").unwrap_or("CABA-BDI"))?;
            let mut cfg = args.config()?;
            if cfg.telemetry_window == 0 {
                cfg.telemetry_window = 1024;
            }
            let mut sim = Simulator::new(cfg, design, app, args.scale());
            let stats = sim.run();
            let run = sim
                .telemetry_run()
                .ok_or_else(|| anyhow!("flight recorder produced no data (telemetry_window=0?)"))?;
            let trace = caba::telemetry::export::chrome_trace_json(&run, app.name, design.name);
            std::fs::write(out, &trace).map_err(|e| anyhow!("writing {out}: {e}"))?;
            println!(
                "prof: wrote {out} ({} windows x {} cycles, {} spans over {} cycles)",
                run.window_count(),
                run.window,
                run.span_count(),
                stats.cycles
            );
            println!("open it in https://ui.perfetto.dev or chrome://tracing");
            Ok(())
        }
        Some("fig") => {
            let which = args
                .positional
                .get(1)
                .ok_or_else(|| anyhow!("fig requires a figure id (2..16, md, memo)"))?;
            let ctx = RunCtx::with_cfg(args.config()?, args.scale(), args.jobs()?);
            let t0 = Instant::now();
            let out = match which.as_str() {
                "2" => figures::fig02_cycle_breakdown(&ctx),
                "3" => figures::fig03_unallocated_regs(&ctx),
                "8" => figures::fig08_performance(&ctx),
                "9" => figures::fig09_bandwidth_utilization(&ctx),
                "10" => figures::fig10_energy(&ctx),
                "11" => figures::fig11_edp(&ctx),
                "12" => figures::fig12_algorithms(&ctx),
                "13" => figures::fig13_compression_ratio(&ctx),
                "14" => figures::fig14_bw_sensitivity(&ctx),
                "15" => figures::fig15_cache_compression(&ctx),
                "16" => figures::fig16_optimizations(&ctx),
                "md" => figures::md_cache_hitrate(&ctx),
                "memo" => figures::fig_memo(&ctx),
                other => bail!("unknown figure {other:?}"),
            };
            println!("{out}");
            eprintln!(
                "[fig {which}] {:.2}s at scale {} with {} worker(s)",
                t0.elapsed().as_secs_f64(),
                ctx.scale,
                resolve_jobs(ctx.jobs)
            );
            Ok(())
        }
        Some("sweep") => {
            // `--trace FILE` swaps the app axis for one trace-driven
            // workload; everything else (designs × bw, caching, workers)
            // is identical — trace jobs are first-class sweep citizens.
            let trace = match args.flag("trace") {
                Some(f) => Some(TraceData::load(f)?),
                None => None,
            };
            if trace.is_some() {
                if args.flag("apps").is_some() {
                    eprintln!("[sweep] note: --apps is ignored with --trace (the trace is the workload)");
                }
                if args.flag("scale").is_some() {
                    eprintln!("[sweep] note: --scale is ignored with --trace (pinned to the recorded scale)");
                }
            }
            let set: Vec<&'static AppSpec> = match &trace {
                Some(t) => vec![t.spec()],
                None => apps_by_selector(args.flag("apps").unwrap_or("eval"))?,
            };
            let designs = designs_by_selector(args.flag("designs").unwrap_or("headline"))?;
            let bws: Vec<f64> = args
                .flag("bw")
                .unwrap_or("1.0")
                .split(',')
                .map(|v| {
                    v.trim()
                        .parse()
                        .map_err(|_| anyhow!("--bw expects comma-separated floats, got {v:?}"))
                })
                .collect::<Result<_>>()?;
            let cfg = args.config()?;
            let scale = match &trace {
                Some(t) => t.meta.scale, // replay geometry is pinned
                None => args.scale(),
            };
            let jobs = args.jobs()?;
            let job_for = |app: &'static AppSpec, d: Design, bw: f64| -> SweepJob {
                match &trace {
                    Some(t) => {
                        let mut c = cfg.clone();
                        c.bw_scale = bw;
                        SweepJob::replay(t, d, c)
                    }
                    None => SweepJob::with_bw(app, d, &cfg, bw, scale),
                }
            };

            // Build the deduplicated job matrix and execute it in one
            // parallel pass; rendering below is all cache hits.
            let mut matrix = Vec::new();
            for &app in &set {
                for d in &designs {
                    for &bw in &bws {
                        matrix.push(job_for(app, *d, bw));
                    }
                }
            }
            // `--store DIR` persists every result through the crash-safe
            // on-disk store: re-sweeps (and the serve daemon pointed at
            // the same directory) answer warm.
            let engine = match args.flag("store") {
                Some(dir) => {
                    let policy = caba::store::CapacityPolicy {
                        max_bytes: parse_store_max_bytes(&args)?,
                        ..Default::default()
                    };
                    SweepEngine::with_cache(
                        jobs,
                        Arc::new(RunCache::with_store(Arc::new(RunStore::open_with(
                            dir, policy,
                        )?))),
                    )
                }
                None => SweepEngine::shared(jobs),
            };
            let t0 = Instant::now();
            // A failed point (corrupt trace, simulator panic) surfaces as
            // a typed error and a nonzero exit — fail-fast policy.
            engine.run(&matrix)?;
            let dt = t0.elapsed().as_secs_f64();

            let names: Vec<&str> = set.iter().map(|a| a.name).collect();
            for &bw in &bws {
                let mut ipc = Vec::new();
                let mut ratio = Vec::new();
                for d in &designs {
                    let mut iv = Vec::new();
                    let mut rv = Vec::new();
                    for &app in &set {
                        let s = engine.run_one(&job_for(app, *d, bw));
                        iv.push(s.ipc());
                        rv.push(s.dram.compression_ratio());
                    }
                    ipc.push(Series { label: d.name.to_string(), values: iv });
                    ratio.push(Series { label: d.name.to_string(), values: rv });
                }
                println!("# Sweep — IPC at {bw}x bandwidth (scale {scale})");
                println!("{}", figure_matrix(&names, &ipc, 3));
                println!("# Sweep — DRAM compression ratio at {bw}x bandwidth");
                println!("{}", figure_matrix(&names, &ratio, 2));
            }
            if let Some(t) = &trace {
                eprintln!(
                    "[sweep] trace-driven: digest {:#018x}, {} accesses served",
                    t.digest,
                    t.replayed_accesses()
                );
            }
            eprintln!(
                "[sweep] {} point(s) in {dt:.2}s with {} worker(s)",
                matrix.len(),
                resolve_jobs(jobs)
            );
            if let Some(sc) = engine.cache().store_counters() {
                eprintln!(
                    "[sweep] store: puts {}  warm_hits {}  misses {}  quarantined {}  temp_cleaned {}  put_errors {}",
                    sc.puts, sc.warm_hits, sc.misses, sc.quarantined, sc.temp_cleaned, sc.put_errors
                );
                if sc.evicted > 0 || sc.quarantine_gced > 0 || sc.put_uncached > 0 {
                    eprintln!(
                        "[sweep] store capacity: evicted {} ({} bytes)  quarantine_gced {}  put_uncached {}",
                        sc.evicted, sc.evicted_bytes, sc.quarantine_gced, sc.put_uncached
                    );
                }
            }
            Ok(())
        }
        Some("serve") => {
            let socket = args
                .flag("socket")
                .ok_or_else(|| anyhow!("--socket PATH required, e.g. --socket /tmp/caba.sock"))?;
            let mut opts = ServeOpts::new(socket);
            opts.jobs = args.jobs()?;
            if let Some(q) = args.flag("queue") {
                opts.queue_cap =
                    q.parse().map_err(|_| anyhow!("--queue expects an integer, got {q:?}"))?;
            }
            if let Some(d) = args.flag("deadline-ms") {
                opts.default_deadline_ms = d
                    .parse()
                    .map_err(|_| anyhow!("--deadline-ms expects milliseconds, got {d:?}"))?;
            }
            opts.store_dir = args.flag("store").map(Into::into);
            opts.store_max_bytes = parse_store_max_bytes(&args)?;
            if let Some(b) = args.flag("brownout-p95-ms") {
                opts.brownout_p95_ms = b
                    .parse()
                    .map_err(|_| anyhow!("--brownout-p95-ms expects milliseconds, got {b:?}"))?;
            }
            if let Some(n) = args.flag("brownout-min-samples") {
                opts.brownout_min_samples = n
                    .parse()
                    .map_err(|_| anyhow!("--brownout-min-samples expects an integer, got {n:?}"))?;
            }
            opts.log = args.flag("log").is_some();
            if let Some(spec) = args.flag("fault") {
                eprintln!("[serve] fault injection active: {spec}");
                opts.fault = Some(Arc::new(FaultPlan::parse(spec)?));
            }
            serve::install_signal_handlers();
            let server = serve::Server::bind(opts)?;
            eprintln!(
                "[serve] listening on {socket} ({} worker(s), queue {}, deadline {} ms{})",
                resolve_jobs(args.jobs()?),
                args.flag("queue").unwrap_or("64"),
                args.flag("deadline-ms").unwrap_or("30000"),
                match args.flag("store") {
                    Some(d) => format!(", store {d}"),
                    None => String::new(),
                }
            );
            let summary = server.run()?;
            println!("{}", serve::render_summary(&summary));
            Ok(())
        }
        Some("client") => {
            let socket = args.positional.get(1).map(String::as_str).ok_or_else(|| {
                anyhow!("usage: caba client <socket> '<json>', e.g. caba client /tmp/caba.sock '{{\"verb\":\"ping\"}}'")
            })?;
            let request = args
                .positional
                .get(2)
                .map(String::as_str)
                .ok_or_else(|| anyhow!("client requires a JSON request as the second argument"))?;
            let mut policy = caba::client::RetryPolicy::default();
            if let Some(r) = args.flag("retries") {
                policy.max_retries = r
                    .parse()
                    .map_err(|_| anyhow!("--retries expects an integer, got {r:?}"))?;
            }
            if let Some(b) = args.flag("backoff-ms") {
                policy.base_ms = b
                    .parse()
                    .map_err(|_| anyhow!("--backoff-ms expects milliseconds, got {b:?}"))?;
            }
            if let Some(c) = args.flag("backoff-cap-ms") {
                policy.cap_ms = c
                    .parse()
                    .map_err(|_| anyhow!("--backoff-cap-ms expects milliseconds, got {c:?}"))?;
            }
            if let Some(s) = args.flag("seed") {
                policy.seed = s
                    .parse()
                    .map_err(|_| anyhow!("--seed expects an integer, got {s:?}"))?;
            }
            let mut conn = caba::client::Conn::new(Path::new(socket), policy);
            let resp = conn.request(request)?;
            // Verbatim response on stdout — scripts see what the daemon
            // said, same as the old one-shot client. Retry activity goes
            // to stderr so it never pollutes pipelines.
            println!("{}", resp.raw());
            let c = conn.counters();
            if c.retries > 0 {
                eprintln!(
                    "[client] converged after {} attempt(s): {} shed, {} deadline, {} connection failure(s)",
                    c.attempts, c.sheds_seen, c.deadlines_seen, c.conn_errors
                );
            }
            Ok(())
        }
        Some("metrics") => {
            // Client-side sugar over the `metrics` verb: fetch the
            // one-line JSON response and print the decoded Prometheus
            // exposition raw — pipe-friendly for CI greps and scrapers.
            let socket = args.positional.get(1).map(String::as_str).ok_or_else(|| {
                anyhow!("usage: caba metrics <socket>, e.g. caba metrics /tmp/caba.sock")
            })?;
            let resp = serve::client_request(Path::new(socket), r#"{"verb":"metrics"}"#)?;
            let v = serve::json::parse(&resp)
                .map_err(|e| anyhow!("metrics response was not valid JSON: {e:#}"))?;
            let text = v
                .get("metrics")
                .and_then(serve::json::Json::as_str)
                .ok_or_else(|| anyhow!("metrics verb failed: {resp}"))?;
            print!("{text}");
            Ok(())
        }
        Some("bench") => {
            let opts = caba::bench::BenchOpts {
                quick: args.flag("quick").is_some(),
                out: args.flag("out").unwrap_or("BENCH_pr10.json").to_string(),
                floors: args.flag("floors").map(str::to_string),
            };
            let t0 = Instant::now();
            let report = caba::bench::run(&opts)?;
            print!("{}", report.human_summary());
            eprintln!(
                "[bench] wrote {} in {:.2}s",
                opts.out,
                t0.elapsed().as_secs_f64()
            );
            if !report.violations.is_empty() {
                bail!(
                    "bench floors violated ({}): see report above",
                    report.violations.len()
                );
            }
            Ok(())
        }
        Some("trace") => run_trace(&args),
        _ => {
            eprintln!(
                "usage: caba <list|table1|run|prof|fig|sweep|serve|client|metrics|trace|bench> [...]\n  \
                 caba run --app PVC --design CABA-BDI [--scale 0.25] [--threads N] [--oracle native|pjrt]\n  \
                 caba run --app PVC --timeline   (ASCII flight-recorder timeline; --json for machine-readable)\n  \
                 caba prof trace.json --app PVC [--design CABA-BDI]   (Perfetto/chrome-trace export)\n  \
                 caba prof spans.json --serve /tmp/caba.sock   (daemon request spans -> Perfetto)\n  \
                 caba fig 8 [--scale 0.25] [--jobs N] [--set key=value]  (fig memo = §8.1 suite)\n  \
                 caba sweep --apps eval|memo --designs headline --bw 0.5,1.0,2.0 [--jobs N] [--store DIR]\n  \
                 caba sweep --trace run.cabatrace --designs headline [--bw 0.5,1.0,2.0]\n  \
                 caba serve --socket /tmp/caba.sock [--jobs N] [--queue 64] [--deadline-ms 30000] [--store DIR]\n  \
                 \x20          [--store-max-bytes B] [--brownout-p95-ms P] [--brownout-min-samples N] [--fault spec] [--log]\n  \
                 caba client /tmp/caba.sock '{{\"verb\":\"sweep\",\"app\":\"SLA\",\"design\":\"CABA-BDI\",\"scale\":0.01}}'\n  \
                 \x20          [--retries 4] [--backoff-ms 10] [--backoff-cap-ms 2000] [--seed S]  (retries shed/deadline/conn-drop)\n  \
                 caba metrics /tmp/caba.sock   (Prometheus text exposition from a running daemon)\n  \
                 caba trace record PVC [--design CABA-BDI] [--scale 0.25] [--out PVC.cabatrace]\n  \
                 caba trace replay run.cabatrace [--design CABA-BDI] [--set key=value]\n  \
                 caba trace info run.cabatrace\n  \
                 caba trace import dump.txt [--out dump.cabatrace] [--pattern random]\n  \
                 caba bench [--quick] [--out BENCH_pr10.json] [--floors BENCH_floors.txt]"
            );
            Ok(())
        }
    }
}

/// The `caba trace <record|replay|info|import>` verb family.
fn run_trace(args: &Args) -> Result<()> {
    let usage = || {
        anyhow!(
            "usage: caba trace <record <app> | replay <file> | info <file> | import <txt>> [...]"
        )
    };
    match args.positional.get(1).map(String::as_str) {
        Some("record") => {
            let app_name = args.positional.get(2).map(String::as_str).ok_or_else(usage)?;
            let app = apps::find(app_name)
                .ok_or_else(|| anyhow!("unknown app {app_name:?}; see `caba list`"))?;
            let design = design_by_name(args.flag("design").unwrap_or("CABA-BDI"))?;
            let cfg = args.config()?;
            if !cfg.trace_record.is_empty() {
                // Catch this before Simulator::new attaches a recorder to
                // the --set path (which the record_to below would then
                // reject, stranding a header-only file on disk).
                bail!("pass the destination as --out OR --set trace_record, not both");
            }
            let scale = args.scale();
            let out = args
                .flag("out")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{}.cabatrace", app.name));
            let mut sim = Simulator::new(cfg, design, app, scale);
            sim.record_to(&out)?;
            let stats = sim.run();
            print_run(app.name, design.name, &stats, &sim);
            println!(
                "trace: wrote {out} ({} access records, {} payload entries)",
                stats.trace.accesses_recorded, stats.trace.payloads_recorded
            );
            Ok(())
        }
        Some("replay") => {
            let file = args.positional.get(2).map(String::as_str).ok_or_else(usage)?;
            let trace = TraceData::load(file)?;
            let cfg = args.config()?;
            if !trace.complete {
                eprintln!(
                    "[trace] note: the recorded run hit its cycle/instruction budget before \
                     draining — this trace covers a prefix of the workload"
                );
            }
            if trace.meta.kind == TraceKind::Recorded && cfg.fingerprint() != trace.meta.fingerprint
            {
                eprintln!(
                    "[trace] note: replaying under a different configuration than the recording \
                     ({:#018x} vs {:#018x}) — trace-driven what-if, not a bit-identity check",
                    cfg.fingerprint(),
                    trace.meta.fingerprint
                );
            }
            let design = design_by_name(args.flag("design").unwrap_or("CABA-BDI"))?;
            let mut sim = Simulator::from_trace(cfg, design, Arc::clone(&trace))?;
            let stats = sim.run();
            print_run(sim.wl.spec.name, design.name, &stats, &sim);
            println!(
                "replay: {} accesses served ({} lines), {} payloads from file, {} regenerated",
                trace.replayed_accesses(),
                trace.replayed_lines(),
                trace.payload_hits_count(),
                trace.payload_fallbacks_count()
            );
            Ok(())
        }
        Some("info") => {
            let file = args.positional.get(2).map(String::as_str).ok_or_else(usage)?;
            let trace = TraceData::load(file)?;
            println!("# Trace {file}");
            println!("{}", trace_summary(&trace));
            Ok(())
        }
        Some("import") => {
            let input = args.positional.get(2).map(String::as_str).ok_or_else(usage)?;
            let out = args
                .flag("out")
                .map(str::to_string)
                .unwrap_or_else(|| format!("{input}.cabatrace"));
            let pattern = args.flag("pattern").unwrap_or("random");
            let trace = trace_import::import_file(input, &out, pattern)?;
            println!("# Imported {input} -> {out}");
            println!("{}", trace_summary(&trace));
            println!("replay it with: caba trace replay {out} --design CABA-BDI");
            Ok(())
        }
        _ => Err(usage()),
    }
}

fn print_run(app: &str, design: &str, stats: &caba::stats::SimStats, sim: &Simulator) {
    let em = caba::energy::EnergyModel::default();
    let mech = sim.design.mechanism;
    let e = em.evaluate(
        stats,
        mech == caba::sim::designs::Mechanism::Caba,
        mech == caba::sim::designs::Mechanism::Hardware,
    );
    println!("app={app} design={design} finished={}", stats.finished);
    println!(
        "cycles={} warp_insts={} IPC={:.3}",
        stats.cycles,
        stats.warp_insts,
        stats.ipc()
    );
    let (c, m, d, i, a) = stats.issue.fractions();
    println!(
        "issue breakdown: active={:.1}% compute={:.1}% memory={:.1}% data={:.1}% idle={:.1}%",
        a * 100.0,
        c * 100.0,
        m * 100.0,
        d * 100.0,
        i * 100.0
    );
    println!(
        "L1 hit={:.1}%  L2 hit={:.1}%  MD hit={:.1}%",
        stats.l1.hit_rate() * 100.0,
        stats.l2.hit_rate() * 100.0,
        stats.md.hit_rate() * 100.0
    );
    println!(
        "DRAM: bursts={} (uncompressed-equivalent {}) ratio={:.2}x bw-util={:.1}%",
        stats.dram.bursts,
        stats.dram.bursts_uncompressed,
        stats.dram.compression_ratio(),
        stats.dram.bandwidth_utilization(stats.cycles, sim.cfg.n_mcs) * 100.0
    );
    println!(
        "CABA: decompress warps={} compress warps={} assist insts={} (idle-slot {}) skipped={} throttled={}",
        stats.caba.decompress_warps,
        stats.caba.compress_warps,
        stats.caba.assist_insts_issued,
        stats.caba.assist_insts_idle_slots,
        stats.caba.compress_skipped,
        stats.caba.throttled_deploys
    );
    if let Some(rate) = stats.caba.memo_hit_rate() {
        let c = &stats.caba;
        println!(
            "memo LUT: lookups={} hit={:.1}% (alias {:.1}%) installs={} evictions={} skipped={}",
            c.memo_lookups,
            rate * 100.0,
            c.memo_alias_hits as f64 / c.memo_lookups as f64 * 100.0,
            c.memo_installs,
            c.memo_evictions,
            c.memo_lookups_skipped
        );
    }
    println!(
        "energy: total={:.2}mJ dram={:.2}mJ static={:.2}mJ  avg power={:.1}W  oracle={}",
        e.total_mj(),
        e.dram_total_mj(),
        e.static_mj,
        e.avg_power_w(stats.cycles, em.clock_ghz),
        sim_data_backend(sim),
    );
}

fn sim_data_backend(_sim: &Simulator) -> &'static str {
    // Oracle backend is private to the sim; report via feature probe.
    "see --oracle"
}
