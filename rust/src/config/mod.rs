//! Simulated-system configuration (the paper's Table 1) plus run controls.
//!
//! Defaults reproduce the paper's baseline exactly:
//! 15 SMs / 32-thread warps / 48 warps per SM / GTO scheduling with 2
//! schedulers per SM / 32768 registers + 32KB shared memory per SM /
//! 16KB 4-way L1 / 768KB 16-way L2 / 1 crossbar per direction at 1.4 GHz /
//! 177.4 GB/s over 6 GDDR5 MCs with FR-FCFS and 16 banks per MC.
//!
//! The offline image has no serde/toml, so overrides are parsed from simple
//! `key=value` pairs (CLI `--set key=value`, files with one pair per line).

use anyhow::{bail, Context, Result};

/// GDDR5 timing parameters in DRAM command cycles (Table 1, Hynix GDDR5).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DramTiming {
    pub t_cl: u32,
    pub t_rp: u32,
    pub t_rc: u32,
    pub t_ras: u32,
    pub t_rcd: u32,
    pub t_rrd: u32,
    pub t_ccd: u32,
    pub t_wr: u32,
}

impl Default for DramTiming {
    fn default() -> Self {
        DramTiming {
            t_cl: 12,
            t_rp: 12,
            t_rc: 40,
            t_ras: 28,
            t_rcd: 12,
            t_rrd: 6,
            t_ccd: 5, // Table 1 lists t_CLDR=5; used as burst-to-burst gap
            t_wr: 12,
        }
    }
}

/// Full simulated-system configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    // --- System overview ---
    /// Streaming multiprocessors.
    pub n_sms: usize,
    /// Threads per warp (SIMT width).
    pub warp_size: usize,
    /// Memory channels / controllers.
    pub n_mcs: usize,
    /// Core clock in GHz (used only to convert to absolute bandwidth).
    pub clock_ghz: f64,

    // --- Shader core ---
    /// Warp schedulers per SM (each issues ≤1 instruction/cycle).
    pub schedulers_per_sm: usize,
    /// Hard warp limit per SM.
    pub max_warps_per_sm: usize,
    /// Hard CTA (thread block) limit per SM.
    pub max_ctas_per_sm: usize,
    /// Hard thread limit per SM.
    pub max_threads_per_sm: usize,
    /// Register file size per SM (32-bit registers).
    pub regfile_per_sm: usize,
    /// Shared memory per SM in bytes.
    pub smem_per_sm: usize,

    // --- Pipelines ---
    /// SP (int/fp ALU) issue slots per SM per cycle.
    pub sp_units: usize,
    /// SFU issue slots per SM per cycle.
    pub sfu_units: usize,
    /// LSU issue slots per SM per cycle.
    pub mem_units: usize,
    /// ALU latency (cycles) for simple int/fp ops.
    pub alu_latency: u32,
    /// FMA latency.
    pub fma_latency: u32,
    /// SFU latency (tens of cycles — the paper's dmr data-dependence note).
    pub sfu_latency: u32,
    /// Cycles the SFU pipeline stays *occupied* per warp SFU instruction
    /// (quarter-rate SFU lanes process 32 threads over several cycles —
    /// this is what makes transcendental-heavy kernels compute-unit-bound
    /// rather than issue-bound, §3/§8.1).
    pub sfu_issue_interval: u32,

    // --- Caches ---
    pub l1_bytes: usize,
    pub l1_assoc: usize,
    pub l1_hit_latency: u32,
    pub l1_mshrs: usize,
    pub l2_bytes: usize,
    pub l2_assoc: usize,
    pub l2_hit_latency: u32,
    /// Latency to detect an L2 miss (tag check only, < hit latency).
    pub l2_tag_latency: u32,
    /// Cache line size in bytes (also the compression granularity).
    pub line_bytes: usize,

    // --- Interconnect ---
    /// One crossbar per direction; per-port payload bandwidth in
    /// bytes/core-cycle (32 = one burst per cycle per port).
    pub icnt_bytes_per_cycle: f64,
    /// Crossbar traversal latency in cycles.
    pub icnt_latency: u32,

    // --- DRAM ---
    /// Peak off-chip bandwidth in GB/s across all MCs (Table 1: 177.4).
    pub dram_bw_gbps: f64,
    /// Bandwidth scale knob for the ½×/1×/2× experiments (Figs 2, 14).
    pub bw_scale: f64,
    pub banks_per_mc: usize,
    pub dram_timing: DramTiming,
    /// Extra fixed latency (command queues, PHY) added to every DRAM access.
    pub dram_base_latency: u32,

    // --- Compression / CABA ---
    /// MD (metadata) cache size in bytes per MC (§5.3.2: 8KB, 4-way).
    pub md_cache_bytes: usize,
    pub md_cache_assoc: usize,
    /// Hardware BDI latencies (paper: 1-cycle decompression, 5-cycle
    /// compression, from the Synopsys implementation of [87]).
    pub hw_decompress_latency: u32,
    pub hw_compress_latency: u32,
    /// Max live assist-warp entries per SM in the Assist Warp Table.
    pub awt_entries: usize,
    /// Dedicated low-priority AWB slots in the instruction buffer (§4.3).
    pub awb_low_prio_slots: usize,
    /// Enable AWC utilization-feedback throttling (§4.4).
    pub caba_throttle: bool,
    /// FU-utilization threshold above which low-priority deployment pauses.
    pub throttle_util_threshold: f64,

    // --- Memoization LUT (§8.1, `crate::memo`) ---
    /// Shared-memory budget cap per SM for the memo LUT; the actual carve
    /// is `min(this, smem left unallocated by the resident CTAs)`.
    pub memo_lut_bytes: usize,
    /// LUT associativity (ways per set).
    pub memo_lut_ways: usize,
    /// Modeled bytes per LUT entry (tag + result + LRU bookkeeping).
    pub memo_entry_bytes: usize,
    /// Stored-tag width in bits; truncation models aliasing.
    pub memo_tag_bits: u32,

    // --- Run controls ---
    /// Force the naive per-cycle tick: every SM is cycled on every core
    /// cycle and the run loop never fast-forwards. The event-driven
    /// default (`false`) skips stalled SMs wholesale and bulk-charges
    /// their stall cycles — provably the same statistics, much less host
    /// work (see EXPERIMENTS.md §4, "Event-driven tick"). This knob exists
    /// so the equivalence is *testable*: the differential suite pins
    /// `strict_tick=1` ≡ event-driven on every golden stat and
    /// `memory_signature()`. Fingerprinted like any simulated parameter —
    /// if the equivalence ever regressed, cached results would still be
    /// correct per mode.
    pub strict_tick: bool,
    /// Host threads used to shard the per-core tick loop *inside* one
    /// simulation (`crate::sim::Simulator::run_sharded`). Cores advance
    /// independently between memory-system epochs, then rendezvous to
    /// drain the shared `MemSystem` in deterministic SM order, so every
    /// thread count produces bit-identical statistics (the three-way
    /// differential suite pins strict × serial × sharded at 1/2/4/8
    /// threads). `1` keeps the event-driven serial path; values are
    /// clamped to `n_sms`; `strict_tick=true` forces the naive serial
    /// reference regardless. Fingerprinted like `strict_tick` and for the
    /// same reason: the equivalence is a *proved invariant*, and if it
    /// ever regressed, cached results would still be correct per mode.
    pub sim_threads: usize,
    /// Stop after this many core cycles (safety net).
    pub max_cycles: u64,
    /// Stop after this many issued warp-instructions (paper: 1B thread-
    /// instructions; we default to a scaled-down budget per workload).
    pub max_warp_insts: u64,
    /// RNG seed for workload generation.
    pub seed: u64,
    /// When non-empty: record this run's memory-access/payload streams to
    /// the given `.cabatrace` path (see `crate::trace`). A run control,
    /// not a simulated parameter — it never changes simulation results,
    /// and it is the one field **excluded** from [`SimConfig::fingerprint`]
    /// (so recording never fragments the run cache, and a trace's recorded
    /// fingerprint matches the same effective config on replay). The sweep
    /// engine additionally strips it: sweep jobs never record.
    pub trace_record: String,
    /// Flight-recorder window cadence in cycles; `0` disables telemetry
    /// (see `crate::telemetry`). A run control like `trace_record`:
    /// recording is observation-only (a dedicated test pins `SimStats`
    /// bit-identical with telemetry on vs off), so it is **excluded** from
    /// [`SimConfig::fingerprint`] and stripped by the sweep engine.
    pub telemetry_window: u64,
    /// Per-SM assist-warp span-log capacity when telemetry is enabled
    /// (`telemetry_window > 0`); `0` records windows but no spans. Same
    /// run-control status as `telemetry_window`: excluded from the
    /// fingerprint, stripped by sweeps.
    pub telemetry_spans: usize,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_sms: 15,
            warp_size: 32,
            n_mcs: 6,
            clock_ghz: 1.4,
            schedulers_per_sm: 2,
            max_warps_per_sm: 48,
            max_ctas_per_sm: 8,
            max_threads_per_sm: 1536,
            regfile_per_sm: 32768,
            smem_per_sm: 32 * 1024,
            sp_units: 2,
            sfu_units: 1,
            mem_units: 1,
            alu_latency: 4,
            fma_latency: 4,
            sfu_latency: 32,
            sfu_issue_interval: 4,
            l1_bytes: 16 * 1024,
            l1_assoc: 4,
            l1_hit_latency: 28,
            l1_mshrs: 64,
            l2_bytes: 768 * 1024,
            l2_assoc: 16,
            l2_hit_latency: 120,
            l2_tag_latency: 30,
            line_bytes: crate::compress::LINE_BYTES,
            icnt_bytes_per_cycle: 28.0,
            icnt_latency: 8,
            dram_bw_gbps: 177.4,
            bw_scale: 1.0,
            banks_per_mc: 16,
            dram_timing: DramTiming::default(),
            dram_base_latency: 80,
            md_cache_bytes: 8 * 1024,
            md_cache_assoc: 4,
            hw_decompress_latency: 1,
            hw_compress_latency: 5,
            awt_entries: 32,
            awb_low_prio_slots: 2,
            caba_throttle: true,
            throttle_util_threshold: 0.9,
            memo_lut_bytes: 16 * 1024,
            memo_lut_ways: 4,
            memo_entry_bytes: 16,
            memo_tag_bits: 16,
            strict_tick: false,
            sim_threads: 1,
            max_cycles: 20_000_000,
            max_warp_insts: u64::MAX,
            seed: 0xCABA,
            trace_record: String::new(),
            telemetry_window: 0,
            telemetry_spans: 256,
        }
    }
}

impl SimConfig {
    /// Per-MC data-bus bandwidth in bytes per core cycle, after `bw_scale`.
    pub fn dram_bytes_per_cycle_per_mc(&self) -> f64 {
        self.dram_bw_gbps * self.bw_scale / self.n_mcs as f64 / self.clock_ghz
    }

    /// Core cycles to move one 32B burst over one MC's data bus.
    pub fn burst_cycles(&self) -> f64 {
        crate::compress::BURST_BYTES as f64 / self.dram_bytes_per_cycle_per_mc()
    }

    /// DRAM bursts per uncompressed line.
    pub fn line_bursts(&self) -> u8 {
        (self.line_bytes / crate::compress::BURST_BYTES) as u8
    }

    /// A stable 64-bit digest over **every** configuration field (floats
    /// by bit pattern). This is the run-cache key component that makes two
    /// configurations distinguishable: any `--set` override changes the
    /// fingerprint, so cached [`crate::stats::SimStats`] are never returned
    /// for a different configuration (the sweep engine and
    /// `report::figures` key on it).
    ///
    /// Keep this in sync with the field list — the `fingerprint_covers_
    /// every_field` test below walks all `set()` keys to enforce it.
    pub fn fingerprint(&self) -> u64 {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        let SimConfig {
            n_sms,
            warp_size,
            n_mcs,
            clock_ghz,
            schedulers_per_sm,
            max_warps_per_sm,
            max_ctas_per_sm,
            max_threads_per_sm,
            regfile_per_sm,
            smem_per_sm,
            sp_units,
            sfu_units,
            mem_units,
            alu_latency,
            fma_latency,
            sfu_latency,
            sfu_issue_interval,
            l1_bytes,
            l1_assoc,
            l1_hit_latency,
            l1_mshrs,
            l2_bytes,
            l2_assoc,
            l2_hit_latency,
            l2_tag_latency,
            line_bytes,
            icnt_bytes_per_cycle,
            icnt_latency,
            dram_bw_gbps,
            bw_scale,
            banks_per_mc,
            dram_timing,
            dram_base_latency,
            md_cache_bytes,
            md_cache_assoc,
            hw_decompress_latency,
            hw_compress_latency,
            awt_entries,
            awb_low_prio_slots,
            caba_throttle,
            throttle_util_threshold,
            memo_lut_bytes,
            memo_lut_ways,
            memo_entry_bytes,
            memo_tag_bits,
            strict_tick,
            sim_threads,
            max_cycles,
            max_warp_insts,
            seed,
            trace_record,
            telemetry_window,
            telemetry_spans,
        } = self; // exhaustive destructuring: adding a field breaks this
        macro_rules! feed {
            ($($v:expr),* $(,)?) => { $( $v.hash(&mut h); )* };
        }
        feed!(
            n_sms, warp_size, n_mcs, clock_ghz.to_bits(), schedulers_per_sm,
            max_warps_per_sm, max_ctas_per_sm, max_threads_per_sm,
            regfile_per_sm, smem_per_sm, sp_units, sfu_units, mem_units,
            alu_latency, fma_latency, sfu_latency, sfu_issue_interval,
            l1_bytes, l1_assoc,
            l1_hit_latency, l1_mshrs, l2_bytes, l2_assoc, l2_hit_latency,
            l2_tag_latency, line_bytes, icnt_bytes_per_cycle.to_bits(),
            icnt_latency, dram_bw_gbps.to_bits(), bw_scale.to_bits(),
            banks_per_mc, dram_base_latency, md_cache_bytes, md_cache_assoc,
            hw_decompress_latency, hw_compress_latency, awt_entries,
            awb_low_prio_slots, caba_throttle,
            throttle_util_threshold.to_bits(), memo_lut_bytes, memo_lut_ways,
            memo_entry_bytes, memo_tag_bits, strict_tick, sim_threads,
            max_cycles, max_warp_insts, seed,
        );
        // Deliberately NOT fed: `trace_record` is a pure run control (see
        // its field doc) — the same simulation recorded to two different
        // paths must fingerprint (and cache) identically. Likewise the
        // telemetry knobs: the flight recorder is observation-only
        // (`SimStats` bit-identical on vs off, pinned by the differential
        // suite), so recording a timeline must not fragment the cache.
        let _ = (trace_record, telemetry_window, telemetry_spans);
        let DramTiming { t_cl, t_rp, t_rc, t_ras, t_rcd, t_rrd, t_ccd, t_wr } = dram_timing;
        feed!(t_cl, t_rp, t_rc, t_ras, t_rcd, t_rrd, t_ccd, t_wr);
        h.finish()
    }

    /// Every key accepted by [`SimConfig::set`] (used by tests and docs).
    pub const KEYS: [&'static str; 51] = [
        "n_sms", "warp_size", "n_mcs", "clock_ghz", "schedulers_per_sm",
        "max_warps_per_sm", "max_ctas_per_sm", "max_threads_per_sm",
        "regfile_per_sm", "smem_per_sm", "sp_units", "sfu_units",
        "mem_units", "alu_latency", "fma_latency", "sfu_latency",
        "sfu_issue_interval",
        "l1_bytes", "l1_assoc", "l1_hit_latency", "l1_mshrs", "l2_bytes",
        "l2_assoc", "l2_hit_latency", "l2_tag_latency",
        "icnt_bytes_per_cycle", "icnt_latency", "dram_bw_gbps", "bw_scale",
        "banks_per_mc", "dram_base_latency", "md_cache_bytes",
        "md_cache_assoc", "hw_decompress_latency", "hw_compress_latency",
        "awt_entries", "awb_low_prio_slots", "caba_throttle",
        "throttle_util_threshold", "memo_lut_bytes", "memo_lut_ways",
        "memo_entry_bytes", "memo_tag_bits", "strict_tick", "sim_threads",
        "max_cycles", "max_warp_insts", "seed", "trace_record",
        "telemetry_window", "telemetry_spans",
    ];

    /// Apply one `key=value` override. Returns an error on unknown keys or
    /// malformed values — configs fail loudly, never silently.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        macro_rules! parse {
            () => {
                value.parse().with_context(|| format!("bad value for {key}: {value:?}"))?
            };
        }
        match key {
            "n_sms" => self.n_sms = parse!(),
            "warp_size" => self.warp_size = parse!(),
            "n_mcs" => self.n_mcs = parse!(),
            "clock_ghz" => self.clock_ghz = parse!(),
            "schedulers_per_sm" => self.schedulers_per_sm = parse!(),
            "max_warps_per_sm" => self.max_warps_per_sm = parse!(),
            "max_ctas_per_sm" => self.max_ctas_per_sm = parse!(),
            "max_threads_per_sm" => self.max_threads_per_sm = parse!(),
            "regfile_per_sm" => self.regfile_per_sm = parse!(),
            "smem_per_sm" => self.smem_per_sm = parse!(),
            "sp_units" => self.sp_units = parse!(),
            "sfu_units" => self.sfu_units = parse!(),
            "mem_units" => self.mem_units = parse!(),
            "alu_latency" => self.alu_latency = parse!(),
            "fma_latency" => self.fma_latency = parse!(),
            "sfu_latency" => self.sfu_latency = parse!(),
            "sfu_issue_interval" => self.sfu_issue_interval = parse!(),
            "l1_bytes" => self.l1_bytes = parse!(),
            "l1_assoc" => self.l1_assoc = parse!(),
            "l1_hit_latency" => self.l1_hit_latency = parse!(),
            "l1_mshrs" => self.l1_mshrs = parse!(),
            "l2_bytes" => self.l2_bytes = parse!(),
            "l2_assoc" => self.l2_assoc = parse!(),
            "l2_hit_latency" => self.l2_hit_latency = parse!(),
            "l2_tag_latency" => self.l2_tag_latency = parse!(),
            "icnt_bytes_per_cycle" => self.icnt_bytes_per_cycle = parse!(),
            "icnt_latency" => self.icnt_latency = parse!(),
            "dram_bw_gbps" => self.dram_bw_gbps = parse!(),
            "bw_scale" => self.bw_scale = parse!(),
            "banks_per_mc" => self.banks_per_mc = parse!(),
            "dram_base_latency" => self.dram_base_latency = parse!(),
            "md_cache_bytes" => self.md_cache_bytes = parse!(),
            "md_cache_assoc" => self.md_cache_assoc = parse!(),
            "hw_decompress_latency" => self.hw_decompress_latency = parse!(),
            "hw_compress_latency" => self.hw_compress_latency = parse!(),
            "awt_entries" => self.awt_entries = parse!(),
            "awb_low_prio_slots" => self.awb_low_prio_slots = parse!(),
            "caba_throttle" => self.caba_throttle = parse!(),
            "throttle_util_threshold" => self.throttle_util_threshold = parse!(),
            "memo_lut_bytes" => self.memo_lut_bytes = parse!(),
            "memo_lut_ways" => self.memo_lut_ways = parse!(),
            "memo_entry_bytes" => self.memo_entry_bytes = parse!(),
            "memo_tag_bits" => self.memo_tag_bits = parse!(),
            "strict_tick" => self.strict_tick = parse!(),
            "sim_threads" => self.sim_threads = parse!(),
            "max_cycles" => self.max_cycles = parse!(),
            "max_warp_insts" => self.max_warp_insts = parse!(),
            "seed" => self.seed = parse!(),
            "trace_record" => self.trace_record = value.to_string(),
            "telemetry_window" => self.telemetry_window = parse!(),
            "telemetry_spans" => self.telemetry_spans = parse!(),
            _ => bail!("unknown config key: {key}"),
        }
        Ok(())
    }

    /// Apply a batch of `key=value` strings.
    pub fn apply_overrides<'a>(&mut self, pairs: impl IntoIterator<Item = &'a str>) -> Result<()> {
        for pair in pairs {
            let (k, v) = pair
                .split_once('=')
                .with_context(|| format!("override must be key=value, got {pair:?}"))?;
            self.set(k.trim(), v.trim())?;
        }
        Ok(())
    }

    /// Render as the paper's Table 1.
    pub fn table1(&self) -> String {
        format!(
            "System Overview    | {} SMs, {} threads/warp, {} memory channels\n\
             Shader Core Config | {:.1}GHz, GTO scheduler, {} schedulers/SM\n\
             Resources / SM     | {} warps/SM, {} registers, {}KB Shared Memory\n\
             L1 Cache           | {}KB, {}-way associative, LRU replacement policy\n\
             L2 Cache           | {}KB, {}-way associative, LRU replacement policy\n\
             Interconnect       | 1 crossbar/direction ({} SMs, {} MCs), {:.1}GHz\n\
             Memory Model       | {:.1}GB/s BW, {} GDDR5 MCs, FR-FCFS, {} banks/MC\n\
             GDDR5 Timing       | tCL={} tRP={} tRC={} tRAS={} tRCD={} tRRD={} tCCD={} tWR={}",
            self.n_sms,
            self.warp_size,
            self.n_mcs,
            self.clock_ghz,
            self.schedulers_per_sm,
            self.max_warps_per_sm,
            self.regfile_per_sm,
            self.smem_per_sm / 1024,
            self.l1_bytes / 1024,
            self.l1_assoc,
            self.l2_bytes / 1024,
            self.l2_assoc,
            self.n_sms,
            self.n_mcs,
            self.clock_ghz,
            self.dram_bw_gbps * self.bw_scale,
            self.n_mcs,
            self.banks_per_mc,
            self.dram_timing.t_cl,
            self.dram_timing.t_rp,
            self.dram_timing.t_rc,
            self.dram_timing.t_ras,
            self.dram_timing.t_rcd,
            self.dram_timing.t_rrd,
            self.dram_timing.t_ccd,
            self.dram_timing.t_wr,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_table1() {
        let c = SimConfig::default();
        assert_eq!(c.n_sms, 15);
        assert_eq!(c.warp_size, 32);
        assert_eq!(c.n_mcs, 6);
        assert_eq!(c.max_warps_per_sm, 48);
        assert_eq!(c.regfile_per_sm, 32768);
        assert_eq!(c.l1_bytes, 16 * 1024);
        assert_eq!(c.l2_bytes, 768 * 1024);
        assert_eq!(c.banks_per_mc, 16);
        assert!((c.dram_bw_gbps - 177.4).abs() < 1e-9);
        assert_eq!(c.dram_timing, DramTiming::default());
    }

    #[test]
    fn bandwidth_math() {
        let c = SimConfig::default();
        // 177.4/6/1.4 ≈ 21.12 B/cycle/MC; a 32B burst ≈ 1.51 cycles.
        assert!((c.dram_bytes_per_cycle_per_mc() - 21.119).abs() < 0.01);
        assert!((c.burst_cycles() - 1.515).abs() < 0.01);
        let mut half = c.clone();
        half.bw_scale = 0.5;
        assert!((half.burst_cycles() - 2.0 * c.burst_cycles()).abs() < 1e-9);
    }

    #[test]
    fn overrides_roundtrip() {
        let mut c = SimConfig::default();
        c.apply_overrides(["n_sms=8", "bw_scale=2.0", "caba_throttle=false"])
            .unwrap();
        assert_eq!(c.n_sms, 8);
        assert_eq!(c.bw_scale, 2.0);
        assert!(!c.caba_throttle);
        assert!(c.set("nonsense_key", "1").is_err());
        assert!(c.set("n_sms", "not_a_number").is_err());
    }

    #[test]
    fn fingerprint_covers_every_field() {
        // Changing any settable key must change the fingerprint — this is
        // the property that makes the sweep/figure run cache sound under
        // `--set` overrides.
        let base = SimConfig::default();
        for key in SimConfig::KEYS {
            let mut c = base.clone();
            // A value different from every default for that key.
            let val = match key {
                "caba_throttle" => "false".to_string(),
                "strict_tick" => "true".to_string(),
                "clock_ghz" | "icnt_bytes_per_cycle" | "dram_bw_gbps"
                | "bw_scale" | "throttle_util_threshold" => "123.456".to_string(),
                _ => "77".to_string(),
            };
            c.set(key, &val).unwrap();
            if matches!(key, "trace_record" | "telemetry_window" | "telemetry_spans") {
                // The deliberate exceptions: pure run controls (trace
                // recording, flight-recorder telemetry) that must NOT
                // fragment the run cache or trace fingerprints.
                assert_eq!(
                    c.fingerprint(),
                    base.fingerprint(),
                    "run control {key} must not affect the fingerprint"
                );
                continue;
            }
            assert_ne!(
                c.fingerprint(),
                base.fingerprint(),
                "fingerprint ignores key {key}"
            );
        }
        // Timing fields are covered too.
        let mut c = base.clone();
        c.dram_timing.t_cl = 99;
        assert_ne!(c.fingerprint(), base.fingerprint());
        // And it is stable for equal configs.
        assert_eq!(base.fingerprint(), SimConfig::default().fingerprint());
    }

    #[test]
    fn table1_renders() {
        let t = SimConfig::default().table1();
        assert!(t.contains("15 SMs"));
        assert!(t.contains("177.4GB/s"));
        assert!(t.contains("tCL=12"));
    }
}
