//! Flight recorder: deterministic, time-resolved telemetry.
//!
//! The simulator's end-of-run [`crate::stats::SimStats`] aggregates hide
//! every *phase* of execution — a compression burst, a throttle event, a
//! memoization warm-up are all invisible. This module samples the existing
//! counters into fixed-cadence windows (`telemetry_window` cycles each)
//! plus a bounded per-assist-warp span log, with two hard contracts:
//!
//! 1. **Mode invariance.** Strict ticking, the event-driven serial loop and
//!    the sharded loop must produce **bit-identical** timelines. Windows
//!    are therefore charged from *delta snapshots taken at window
//!    boundaries*: counter state at boundary `b` is defined as "state at
//!    the start of cycle `b`", i.e. after the drain of cycle `b-1` — a
//!    point every tick mode passes through with identical state. Event
//!    fast-forwards ([`crate::core::Core::settle_to`], epoch jumps in
//!    `sim/mod.rs`) split their bulk charges across any boundaries inside
//!    the skipped range; counters that are frozen during a genuinely
//!    skippable window (L1, CABA, AWT occupancy) snapshot to the same
//!    values either way. The one subtle sample is MSHR occupancy: raw
//!    `MshrTable::len()` depends on lazy-sweep timing, which *does* differ
//!    across modes, so the recorded metric is the count of entries still
//!    awaiting their fill at the boundary
//!    ([`crate::core::tables::MshrTable::count_fills_at_or_after`]),
//!    which is a pure function of table contents that sweeps cannot
//!    change. `tests/strict_tick_differential.rs` pins all of this.
//!
//! 2. **Observation only.** Recording must not perturb the simulation:
//!    `SimStats` is bit-identical with telemetry on vs off, and the
//!    `telemetry_window` / `telemetry_spans` knobs stay *outside*
//!    [`crate::SimConfig::fingerprint`] (they are run controls, like
//!    `trace_record`'s output path).
//!
//! The recorder is zero-allocation on the hot path: all window storage is
//! reserved up front from `max_cycles / window` (capped), and closing a
//! window is a handful of u64 subtractions. Exceeding the cap drops the
//! newest windows and counts them (`truncated_windows`) rather than
//! reallocating.
//!
//! Rendering lives elsewhere: ASCII sparklines and the per-SM stall
//! heatmap in [`crate::report::timeline`], Chrome trace-event JSON (open
//! in Perfetto / `chrome://tracing`) in [`export`].

pub mod export;

use crate::stats::{CabaStats, CacheStats, IssueBreakdown};

/// Sentinel span index stored on AWT entries whose trigger was not
/// recorded (telemetry off, or the span log was full).
pub const SPAN_NONE: u32 = u32::MAX;

/// Hard cap on preallocated windows per timeline. At the default
/// `telemetry_window=1024` this covers runs of 8M+ cycles; beyond it the
/// recorder keeps the *earliest* windows and counts the dropped tail.
pub const WINDOW_CAP: usize = 8192;

fn window_cap(window: u64, max_cycles: u64) -> usize {
    if window == 0 {
        return 0;
    }
    // +1: a final partial window; ceil-div for the full ones.
    let want = max_cycles / window + 2;
    (want as usize).min(WINDOW_CAP)
}

// ---------------------------------------------------------------- windows

/// One closed per-SM window: counter deltas over the window plus two
/// occupancy samples taken at the closing boundary.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CoreWindow {
    /// Issue-slot deltas (sums to `window × schedulers_per_sm` for full
    /// windows — bulk charges are split exactly at boundaries).
    pub issue: IssueBreakdown,
    /// CABA activity deltas (assist issues, memo probes, kills, ...).
    pub caba: CabaStats,
    /// L1 counter deltas.
    pub l1: CacheStats,
    /// MSHR entries still awaiting their fill at the boundary (the
    /// mode-invariant occupancy metric — see the module docs).
    pub mshr_inflight: u32,
    /// Live AWT rows (high + low priority) at the boundary.
    pub awt_live: u32,
}

/// One closed chip-level window: deltas of the shared-side counters
/// (identical across tick modes at the end of every cycle).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChipWindow {
    /// Cycles covered (== the configured window except for the final
    /// partial one).
    pub cycles: u64,
    /// Warp-instruction delta (chip IPC = `warp_insts / cycles`).
    pub warp_insts: u64,
    /// DRAM 32B bursts actually transferred in this window.
    pub bursts: u64,
    /// Bursts an uncompressed system would have moved (ratio = un/bursts).
    pub bursts_uncompressed: u64,
    /// Compression-metadata DRAM accesses in this window.
    pub md_accesses: u64,
    /// Bus-busy delta summed over MCs (f64, but a difference of two
    /// bit-identical accumulators — itself bit-identical across modes).
    pub bus_busy_cycles: f64,
    /// L2 counter deltas.
    pub l2: CacheStats,
    /// Interconnect flits moved (fwd + back).
    pub flits: u64,
}

impl ChipWindow {
    /// Chip IPC over this window.
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.warp_insts as f64 / self.cycles as f64
        }
    }

    /// DRAM bandwidth utilization over this window, clamped to 1.0.
    pub fn bw_utilization(&self, n_mcs: usize) -> f64 {
        self.bw_utilization_raw(n_mcs).min(1.0)
    }

    /// Unclamped bandwidth utilization (may exceed 1.0 — see
    /// `bus_overcommit_windows` on [`TelemetryRun`]).
    pub fn bw_utilization_raw(&self, n_mcs: usize) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.bus_busy_cycles / (self.cycles as f64 * n_mcs as f64)
        }
    }

    /// Compression ratio of the window's DRAM traffic (1.0 when idle).
    pub fn compression_ratio(&self) -> f64 {
        if self.bursts == 0 {
            1.0
        } else {
            self.bursts_uncompressed as f64 / self.bursts as f64
        }
    }
}

/// The chip-side counter values the [`ChipRecorder`] snapshots at each
/// boundary. Assembled by the simulator's drain thread from the live
/// `SimStats` (warp_insts, L2) and `MemSystem` (DRAM, MD, interconnect).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ChipSnap {
    pub warp_insts: u64,
    pub bursts: u64,
    pub bursts_uncompressed: u64,
    pub md_accesses: u64,
    pub bus_busy_cycles: f64,
    pub l2: CacheStats,
    pub flits: u64,
}

fn cache_delta(now: &CacheStats, prev: &CacheStats) -> CacheStats {
    CacheStats {
        accesses: now.accesses - prev.accesses,
        hits: now.hits - prev.hits,
        misses: now.misses - prev.misses,
        evictions: now.evictions - prev.evictions,
        writebacks: now.writebacks - prev.writebacks,
    }
}

fn issue_delta(now: &IssueBreakdown, prev: &IssueBreakdown) -> IssueBreakdown {
    IssueBreakdown {
        active: now.active - prev.active,
        compute_stall: now.compute_stall - prev.compute_stall,
        memory_stall: now.memory_stall - prev.memory_stall,
        data_stall: now.data_stall - prev.data_stall,
        idle: now.idle - prev.idle,
    }
}

fn caba_delta(now: &CabaStats, prev: &CabaStats) -> CabaStats {
    CabaStats {
        decompress_warps: now.decompress_warps - prev.decompress_warps,
        compress_warps: now.compress_warps - prev.compress_warps,
        assist_insts_issued: now.assist_insts_issued - prev.assist_insts_issued,
        assist_insts_idle_slots: now.assist_insts_idle_slots - prev.assist_insts_idle_slots,
        compress_skipped: now.compress_skipped - prev.compress_skipped,
        throttled_deploys: now.throttled_deploys - prev.throttled_deploys,
        killed: now.killed - prev.killed,
        prefetches_issued: now.prefetches_issued - prev.prefetches_issued,
        memo_lookups: now.memo_lookups - prev.memo_lookups,
        memo_hits: now.memo_hits - prev.memo_hits,
        memo_alias_hits: now.memo_alias_hits - prev.memo_alias_hits,
        memo_installs: now.memo_installs - prev.memo_installs,
        memo_evictions: now.memo_evictions - prev.memo_evictions,
        memo_lookups_skipped: now.memo_lookups_skipped - prev.memo_lookups_skipped,
    }
}

// ---------------------------------------------------------------- per-core

/// Per-SM window recorder, owned by each [`crate::core::Core`]. Windows
/// close lazily inside `Core::settle_to` (the one place every tick mode
/// funnels through before a core observes a new `now`), so bulk charges
/// split exactly at boundaries.
#[derive(Clone, Debug)]
pub struct CoreRecorder {
    window: u64,
    next_boundary: u64,
    cap: usize,
    windows: Vec<CoreWindow>,
    truncated: u64,
    prev_issue: IssueBreakdown,
    prev_caba: CabaStats,
    prev_l1: CacheStats,
}

impl CoreRecorder {
    /// `window == 0` disables recording (all hooks become a branch).
    pub fn new(window: u64, max_cycles: u64) -> CoreRecorder {
        let cap = window_cap(window, max_cycles);
        CoreRecorder {
            window,
            next_boundary: window,
            cap,
            windows: Vec::with_capacity(cap),
            truncated: 0,
            prev_issue: IssueBreakdown::default(),
            prev_caba: CabaStats::default(),
            prev_l1: CacheStats::default(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.window > 0
    }

    /// First boundary not yet closed. Only meaningful when enabled.
    #[inline]
    pub fn next_boundary(&self) -> u64 {
        self.next_boundary
    }

    /// Close the window ending at [`Self::next_boundary`] with the core's
    /// current counter state (callers guarantee that state *is* the
    /// boundary state — see `Core::settle_to`).
    pub fn close_window(
        &mut self,
        issue: &IssueBreakdown,
        caba: &CabaStats,
        l1: &CacheStats,
        mshr_inflight: u32,
        awt_live: u32,
    ) {
        self.push(issue, caba, l1, mshr_inflight, awt_live);
        self.next_boundary += self.window;
    }

    /// Close the final partial window `[next_boundary - window, now)` if
    /// non-empty. `now` is the run's final cycle count — identical across
    /// modes, so the tail is too.
    pub fn finish(
        &mut self,
        now: u64,
        issue: &IssueBreakdown,
        caba: &CabaStats,
        l1: &CacheStats,
        mshr_inflight: u32,
        awt_live: u32,
    ) {
        if !self.enabled() {
            return;
        }
        let start = self.next_boundary - self.window;
        if now > start {
            self.push(issue, caba, l1, mshr_inflight, awt_live);
            // Leave next_boundary so a repeated finish() is the caller's
            // bug, not silent double-counting.
            self.next_boundary += self.window;
        }
    }

    fn push(
        &mut self,
        issue: &IssueBreakdown,
        caba: &CabaStats,
        l1: &CacheStats,
        mshr_inflight: u32,
        awt_live: u32,
    ) {
        let w = CoreWindow {
            issue: issue_delta(issue, &self.prev_issue),
            caba: caba_delta(caba, &self.prev_caba),
            l1: cache_delta(l1, &self.prev_l1),
            mshr_inflight,
            awt_live,
        };
        self.prev_issue = *issue;
        self.prev_caba = *caba;
        self.prev_l1 = *l1;
        if self.windows.len() < self.cap {
            self.windows.push(w);
        } else {
            self.truncated += 1;
        }
    }

    pub fn windows(&self) -> &[CoreWindow] {
        &self.windows
    }

    pub fn truncated(&self) -> u64 {
        self.truncated
    }
}

// ---------------------------------------------------------------- spans

/// What an assist warp was deployed to do (derived from the AWC trigger
/// call site, more precise than `Payload` alone).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    Decompress,
    Compress,
    Prefetch,
    MemoLookup,
    MemoInstall,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Decompress => "decompress",
            SpanKind::Compress => "compress",
            SpanKind::Prefetch => "prefetch",
            SpanKind::MemoLookup => "memo_lookup",
            SpanKind::MemoInstall => "memo_install",
        }
    }
}

/// How a span ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanOutcome {
    /// Still live when the run ended (budget-capped runs).
    Pending,
    /// Retired normally; `end` is the retirement-effect cycle.
    Retired,
    /// Killed (e.g. the line arrived uncompressed).
    Killed,
}

/// One assist warp's lifetime: trigger → (first issue) → retire/kill.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Span {
    /// The AWT token (monotonic per SM — a stable, mode-invariant ID).
    pub token: u64,
    pub kind: SpanKind,
    /// Parent warp slot that triggered the deployment.
    pub parent_warp: usize,
    /// Cycle the trigger landed in the AWT (`active_from` — deploy
    /// latency already applied for high-priority triggers).
    pub trigger_at: u64,
    /// First cycle an instruction of this assist warp issued
    /// (`u64::MAX` until it happens).
    pub first_issue: u64,
    /// Retirement-effect or kill cycle (`u64::MAX` while pending).
    pub end: u64,
    pub outcome: SpanOutcome,
}

/// Bounded per-SM span log, owned by the AWC. Triggers append (O(1) — the
/// AWT entry remembers its span index), issue/retire/kill update in place.
#[derive(Clone, Debug, Default)]
pub struct SpanLog {
    cap: usize,
    spans: Vec<Span>,
    dropped: u64,
}

impl SpanLog {
    /// `cap == 0` disables the log ([`Self::open`] returns [`SPAN_NONE`]).
    pub fn new(cap: usize) -> SpanLog {
        SpanLog {
            cap,
            spans: Vec::with_capacity(cap),
            dropped: 0,
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.cap > 0
    }

    /// Record a trigger; returns the span index to stash on the AWT entry.
    pub fn open(&mut self, token: u64, kind: SpanKind, parent_warp: usize, trigger_at: u64) -> u32 {
        if !self.enabled() {
            return SPAN_NONE;
        }
        if self.spans.len() >= self.cap {
            self.dropped += 1;
            return SPAN_NONE;
        }
        self.spans.push(Span {
            token,
            kind,
            parent_warp,
            trigger_at,
            first_issue: u64::MAX,
            end: u64::MAX,
            outcome: SpanOutcome::Pending,
        });
        (self.spans.len() - 1) as u32
    }

    /// Record the first issued instruction of a span (later calls no-op).
    #[inline]
    pub fn note_issue(&mut self, idx: u32, now: u64) {
        if idx == SPAN_NONE {
            return;
        }
        let s = &mut self.spans[idx as usize];
        if s.first_issue == u64::MAX {
            s.first_issue = now;
        }
    }

    /// Close a span. For retirements `end` is the retirement-effect cycle
    /// (`now + retire_latency`), known at enqueue time.
    pub fn close(&mut self, idx: u32, end: u64, outcome: SpanOutcome) {
        if idx == SPAN_NONE {
            return;
        }
        let s = &mut self.spans[idx as usize];
        s.end = end;
        s.outcome = outcome;
    }

    pub fn spans(&self) -> &[Span] {
        &self.spans
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }
}

// ---------------------------------------------------------------- chip

/// Chip-level window recorder, owned by the simulator and driven only on
/// the drain thread (the single writer of shared state): `advance_to`
/// after every `now` change, `finish` once after the loop exits.
#[derive(Clone, Debug)]
pub struct ChipRecorder {
    window: u64,
    next_boundary: u64,
    cap: usize,
    n_mcs: usize,
    windows: Vec<ChipWindow>,
    truncated: u64,
    overcommit: u64,
    prev: ChipSnap,
}

impl ChipRecorder {
    pub fn new(window: u64, max_cycles: u64, n_mcs: usize) -> ChipRecorder {
        let cap = window_cap(window, max_cycles);
        ChipRecorder {
            window,
            next_boundary: window,
            cap,
            n_mcs,
            windows: Vec::with_capacity(cap),
            truncated: 0,
            overcommit: 0,
            prev: ChipSnap::default(),
        }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.window > 0
    }

    /// First boundary not yet closed — lets the run loop skip snapshot
    /// assembly entirely on the (vast majority of) cycles between
    /// boundaries.
    #[inline]
    pub fn next_boundary(&self) -> u64 {
        self.next_boundary
    }

    /// Close every boundary `<= now` with `snap`. Correct because the
    /// caller invokes it whenever `now` advances: a one-cycle step closes
    /// at most the boundary `== now` with post-drain state, and a
    /// fast-forward jump closes the skipped boundaries with the state at
    /// the jump — which *is* the boundary state, since no core executes
    /// (and so no drain runs) inside a skipped range.
    pub fn advance_to(&mut self, now: u64, snap: &ChipSnap) {
        if !self.enabled() {
            return;
        }
        while self.next_boundary <= now {
            let cycles = self.window;
            self.push(cycles, snap);
            self.next_boundary += self.window;
        }
    }

    /// Close the final partial window at run end.
    pub fn finish(&mut self, now: u64, snap: &ChipSnap) {
        if !self.enabled() {
            return;
        }
        self.advance_to(now, snap);
        let start = self.next_boundary - self.window;
        if now > start {
            self.push(now - start, snap);
            self.next_boundary += self.window;
        }
    }

    fn push(&mut self, cycles: u64, snap: &ChipSnap) {
        let w = ChipWindow {
            cycles,
            warp_insts: snap.warp_insts - self.prev.warp_insts,
            bursts: snap.bursts - self.prev.bursts,
            bursts_uncompressed: snap.bursts_uncompressed - self.prev.bursts_uncompressed,
            md_accesses: snap.md_accesses - self.prev.md_accesses,
            bus_busy_cycles: snap.bus_busy_cycles - self.prev.bus_busy_cycles,
            l2: cache_delta(&snap.l2, &self.prev.l2),
            flits: snap.flits - self.prev.flits,
        };
        self.prev = *snap;
        // Overcommit: strictly more bus-busy than cycles × MCs — the spans
        // the public clamped metric hides (satellite of ISSUE 7).
        if w.bus_busy_cycles > cycles as f64 * self.n_mcs as f64 {
            self.overcommit += 1;
        }
        if self.windows.len() < self.cap {
            self.windows.push(w);
        } else {
            self.truncated += 1;
        }
    }

    pub fn windows(&self) -> &[ChipWindow] {
        &self.windows
    }

    pub fn truncated(&self) -> u64 {
        self.truncated
    }

    pub fn overcommit(&self) -> u64 {
        self.overcommit
    }

    pub fn n_mcs(&self) -> usize {
        self.n_mcs
    }

    pub fn window(&self) -> u64 {
        self.window
    }
}

// ---------------------------------------------------------------- run

/// One SM's complete timeline: closed windows plus its span log.
#[derive(Clone, Debug, PartialEq)]
pub struct CoreTimeline {
    pub sm_id: usize,
    pub windows: Vec<CoreWindow>,
    pub truncated_windows: u64,
    pub spans: Vec<Span>,
    pub spans_dropped: u64,
}

/// Everything the flight recorder captured in one run — the value the
/// three-way tick differential compares with `==` (hence `PartialEq`
/// throughout: bit-identical timelines, not approximately-equal ones).
#[derive(Clone, Debug, PartialEq)]
pub struct TelemetryRun {
    /// Window cadence in cycles.
    pub window: u64,
    /// Total run cycles (the final window may be partial).
    pub cycles: u64,
    /// Memory-controller count (denominator of bandwidth utilization).
    pub n_mcs: usize,
    pub chip: Vec<ChipWindow>,
    pub chip_truncated: u64,
    /// Windows whose *raw* bandwidth utilization exceeded 1.0 (clamped in
    /// the public per-run metric — see `DramStats::bandwidth_utilization`).
    pub bus_overcommit_windows: u64,
    pub cores: Vec<CoreTimeline>,
}

impl TelemetryRun {
    /// Spans across all SMs (sum of per-core logs).
    pub fn span_count(&self) -> usize {
        self.cores.iter().map(|c| c.spans.len()).sum()
    }

    /// Total windows recorded (chip timeline length).
    pub fn window_count(&self) -> usize {
        self.chip.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(warp_insts: u64, busy: f64) -> ChipSnap {
        ChipSnap {
            warp_insts,
            bus_busy_cycles: busy,
            ..Default::default()
        }
    }

    #[test]
    fn chip_windows_are_deltas_and_split_on_jumps() {
        let mut r = ChipRecorder::new(10, 100, 2);
        assert!(r.enabled());
        // Cycle-by-cycle advance up to 9: nothing closes.
        for now in 1..10 {
            r.advance_to(now, &snap(now * 3, 0.0));
        }
        assert!(r.windows().is_empty());
        // Boundary 10 closes with the post-drain(9) state.
        r.advance_to(10, &snap(30, 5.0));
        assert_eq!(r.windows().len(), 1);
        assert_eq!(r.windows()[0].warp_insts, 30);
        assert_eq!(r.windows()[0].cycles, 10);
        // Fast-forward 10 → 35 crosses boundaries 20 and 30: both close
        // with the same (frozen) snapshot; the first takes the delta.
        r.advance_to(35, &snap(40, 9.0));
        assert_eq!(r.windows().len(), 3);
        assert_eq!(r.windows()[1].warp_insts, 10);
        assert_eq!(r.windows()[2].warp_insts, 0);
        assert_eq!(r.windows()[1].bus_busy_cycles, 4.0);
        assert_eq!(r.windows()[2].bus_busy_cycles, 0.0);
        // Partial tail [30, 37).
        r.finish(37, &snap(41, 9.0));
        assert_eq!(r.windows().len(), 4);
        assert_eq!(r.windows()[3].cycles, 7);
        assert_eq!(r.windows()[3].warp_insts, 1);
    }

    #[test]
    fn chip_finish_on_exact_boundary_has_no_tail() {
        let mut r = ChipRecorder::new(10, 100, 1);
        r.finish(20, &snap(7, 0.0));
        assert_eq!(r.windows().len(), 2);
        assert_eq!(r.windows()[0].warp_insts, 7);
        assert_eq!(r.windows()[1].warp_insts, 0);
        assert_eq!(r.windows()[1].cycles, 10);
    }

    #[test]
    fn overcommit_counts_strictly_above_capacity() {
        let mut r = ChipRecorder::new(10, 100, 2);
        // Window capacity = 10 cycles × 2 MCs = 20 busy cycles.
        r.advance_to(10, &snap(0, 20.0)); // exactly at capacity: not over
        assert_eq!(r.overcommit(), 0);
        r.advance_to(20, &snap(0, 40.5)); // 20.5 > 20: over
        assert_eq!(r.overcommit(), 1);
        assert!(r.windows()[1].bw_utilization_raw(2) > 1.0);
        assert_eq!(r.windows()[1].bw_utilization(2), 1.0);
    }

    #[test]
    fn window_cap_truncates_and_counts() {
        // window=1 over max_cycles larger than the cap.
        let mut r = ChipRecorder::new(1, u64::MAX - 2, 1);
        assert_eq!(r.cap, WINDOW_CAP);
        for now in 1..=(WINDOW_CAP as u64 + 5) {
            r.advance_to(now, &snap(now, 0.0));
        }
        assert_eq!(r.windows().len(), WINDOW_CAP);
        assert_eq!(r.truncated(), 5);
    }

    #[test]
    fn disabled_recorders_do_nothing() {
        let mut c = ChipRecorder::new(0, 1000, 2);
        assert!(!c.enabled());
        c.advance_to(500, &snap(1, 1.0));
        c.finish(1000, &snap(2, 2.0));
        assert!(c.windows().is_empty());

        let r = CoreRecorder::new(0, 1000);
        assert!(!r.enabled());

        let mut log = SpanLog::new(0);
        assert_eq!(log.open(1, SpanKind::Decompress, 0, 5), SPAN_NONE);
        log.note_issue(SPAN_NONE, 6); // must be a no-op, not a panic
        log.close(SPAN_NONE, 9, SpanOutcome::Retired);
        assert!(log.spans().is_empty());
        assert_eq!(log.dropped(), 0);
    }

    #[test]
    fn core_recorder_snapshots_deltas() {
        let mut r = CoreRecorder::new(5, 50);
        let mut issue = IssueBreakdown::default();
        let caba = CabaStats::default();
        let mut l1 = CacheStats::default();
        issue.active = 4;
        l1.accesses = 2;
        l1.hits = 1;
        r.close_window(&issue, &caba, &l1, 3, 1);
        issue.active = 9;
        issue.idle = 6;
        r.close_window(&issue, &caba, &l1, 0, 0);
        assert_eq!(r.windows().len(), 2);
        assert_eq!(r.windows()[0].issue.active, 4);
        assert_eq!(r.windows()[0].l1.accesses, 2);
        assert_eq!(r.windows()[0].mshr_inflight, 3);
        assert_eq!(r.windows()[0].awt_live, 1);
        assert_eq!(r.windows()[1].issue.active, 5);
        assert_eq!(r.windows()[1].issue.idle, 6);
        assert_eq!(r.windows()[1].l1.accesses, 0);
        assert_eq!(r.next_boundary(), 15);
        // Partial tail [10, 12).
        issue.active = 10;
        r.finish(12, &issue, &caba, &l1, 7, 2);
        assert_eq!(r.windows().len(), 3);
        assert_eq!(r.windows()[2].issue.active, 1);
        assert_eq!(r.windows()[2].mshr_inflight, 7);
    }

    #[test]
    fn span_log_lifecycle_and_bounding() {
        let mut log = SpanLog::new(2);
        let a = log.open(1, SpanKind::Decompress, 3, 100);
        let b = log.open(2, SpanKind::MemoLookup, 5, 101);
        assert_eq!((a, b), (0, 1));
        // Third span drops.
        assert_eq!(log.open(3, SpanKind::Compress, 0, 102), SPAN_NONE);
        assert_eq!(log.dropped(), 1);
        log.note_issue(a, 104);
        log.note_issue(a, 105); // only the first issue sticks
        log.close(a, 110, SpanOutcome::Retired);
        log.close(b, 103, SpanOutcome::Killed);
        let s = log.spans();
        assert_eq!(s[0].first_issue, 104);
        assert_eq!(s[0].end, 110);
        assert_eq!(s[0].outcome, SpanOutcome::Retired);
        assert_eq!(s[1].first_issue, u64::MAX);
        assert_eq!(s[1].outcome, SpanOutcome::Killed);
        assert_eq!(s[1].parent_warp, 5);
        assert_eq!(s[1].kind, SpanKind::MemoLookup);
    }

    #[test]
    fn boundary_split_partitions_commute() {
        // Strict vs event-driven advance over the same execution: state
        // changes only at "executed" cycles, and a fast-forward may jump
        // any range containing none of them. Both walks must close every
        // window identically — the chip-side analogue of the settle-window
        // commutation property.
        let executed = [0u64, 1, 2, 3, 14, 15, 29, 39];
        // State at the start of cycle t: contributions of executed cycles
        // strictly before t (post-drain(t-1), in simulator terms).
        let state = |t: u64| {
            let n = executed.iter().filter(|&&e| e < t).count() as u64;
            snap(n * n * 3, n as f64 * 2.5)
        };
        let run = |steps: &[u64]| {
            let mut r = ChipRecorder::new(7, 64, 1);
            for &to in steps {
                r.advance_to(to, &state(to));
            }
            r.finish(40, &state(40));
            (r.windows().to_vec(), r.overcommit())
        };
        // Strict: advance every cycle.
        let all: Vec<u64> = (1..=40).collect();
        let a = run(&all);
        // Event-driven: advance after each executed cycle (e+1), plus one
        // jump landing on each wake cycle — exactly the two advance_to
        // call sites in Simulator::run_serial / run_sharded.
        let b = run(&[1, 2, 3, 4, 14, 15, 16, 29, 30, 39, 40]);
        assert_eq!(a, b);
        assert_eq!(a.0.len(), 6); // 5 full windows + the [35, 40) tail
        assert_eq!(a.0[5].cycles, 5);
    }
}
