//! Chrome trace-event JSON export of a [`TelemetryRun`] (`caba prof`).
//!
//! The output is the Trace Event Format's JSON-object form
//! (`{"traceEvents": [...]}`), loadable by Perfetto and
//! `chrome://tracing`:
//!
//! - **pid 0** is the chip: `"C"` counter tracks for IPC, DRAM bandwidth
//!   utilization (raw, unclamped), compression ratio and L2 hit rate —
//!   one sample at each window start.
//! - **pid `sm+1`** is one SM: `"X"` complete events for assist-warp
//!   spans (trigger → retire/kill), plus per-SM counter tracks for AWT
//!   occupancy and MSHR in-flight entries. Overlapping spans are packed
//!   into lanes (`tid`) greedily in trigger order — deterministic, so the
//!   exported JSON is bit-identical across tick modes too.
//!
//! Timestamps map 1 core cycle → 1 µs (`ts`/`dur` are µs in the format).
//! Hand-rolled writer in the `BenchReport::to_json` idiom — no serde.
//!
//! [`server_trace_json`] reuses the same writer and lane packing for the
//! serve daemon's request spans (`caba prof --serve`): pid 0 is the
//! daemon, each request an `"X"` event from accept to respond (ts are
//! the daemon's native µs), lane-packed so concurrent requests stack —
//! loadable in the same Perfetto session as a simulator trace.

use super::{Span, SpanOutcome, TelemetryRun};
use crate::obs::{RequestTrace, UNSET};
use std::fmt::Write as _;

/// Pack overlapping spans into lanes: each span takes the first lane
/// whose previous occupant ended at or before its trigger. Spans are
/// already in trigger order (AWT tokens are monotonic per SM).
fn lane_of(lanes: &mut Vec<u64>, start: u64, end: u64) -> usize {
    for (i, busy_until) in lanes.iter_mut().enumerate() {
        if *busy_until <= start {
            *busy_until = end;
            return i;
        }
    }
    lanes.push(end);
    lanes.len() - 1
}

/// Clamp a span's endpoints to the run: pending spans (or spans whose
/// first issue never happened) extend to the final cycle.
fn span_bounds(s: &Span, run_cycles: u64) -> (u64, u64) {
    let start = s.trigger_at.min(run_cycles);
    let end = if s.end == u64::MAX { run_cycles } else { s.end };
    // Zero-length spans still need dur >= 1 to be visible (and to keep
    // lane packing strict).
    (start, end.max(start + 1))
}

fn esc(s: &str) -> String {
    s.chars()
        .flat_map(|c| match c {
            '"' => vec!['\\', '"'],
            '\\' => vec!['\\', '\\'],
            c => vec![c],
        })
        .collect()
}

/// Render `run` as Chrome trace-event JSON. `app` / `design` label the
/// trace in the viewer's metadata; they do not affect the event data.
pub fn chrome_trace_json(run: &TelemetryRun, app: &str, design: &str) -> String {
    let mut out = String::new();
    let w = &mut out;
    writeln!(w, "{{").unwrap();
    writeln!(w, "  \"displayTimeUnit\": \"ms\",").unwrap();
    writeln!(
        w,
        "  \"otherData\": {{\"app\": \"{}\", \"design\": \"{}\", \"window\": {}, \"cycles\": {}, \"bus_overcommit_windows\": {}}},",
        esc(app),
        esc(design),
        run.window,
        run.cycles,
        run.bus_overcommit_windows
    )
    .unwrap();
    writeln!(w, "  \"traceEvents\": [").unwrap();

    let mut events: Vec<String> = Vec::new();

    // --- pid 0: chip metadata + counter tracks ----------------------
    events.push(
        "{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \"args\": {\"name\": \"chip\"}}"
            .to_string(),
    );
    let mut start = 0u64;
    for cw in &run.chip {
        events.push(format!(
            "{{\"name\": \"IPC\", \"ph\": \"C\", \"ts\": {}, \"pid\": 0, \"args\": {{\"ipc\": {:.6}}}}}",
            start,
            cw.ipc()
        ));
        events.push(format!(
            "{{\"name\": \"DRAM bw util\", \"ph\": \"C\", \"ts\": {}, \"pid\": 0, \"args\": {{\"util\": {:.6}}}}}",
            start,
            cw.bw_utilization_raw(run.n_mcs)
        ));
        events.push(format!(
            "{{\"name\": \"compression ratio\", \"ph\": \"C\", \"ts\": {}, \"pid\": 0, \"args\": {{\"ratio\": {:.6}}}}}",
            start,
            cw.compression_ratio()
        ));
        events.push(format!(
            "{{\"name\": \"L2 hit rate\", \"ph\": \"C\", \"ts\": {}, \"pid\": 0, \"args\": {{\"rate\": {:.6}}}}}",
            start,
            cw.l2.hit_rate()
        ));
        start += cw.cycles;
    }

    // --- pid sm+1: spans + per-SM counters --------------------------
    for core in &run.cores {
        let pid = core.sm_id + 1;
        events.push(format!(
            "{{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": {}, \"tid\": 0, \"args\": {{\"name\": \"SM {}\"}}}}",
            pid, core.sm_id
        ));
        let mut lanes: Vec<u64> = Vec::new();
        for s in &core.spans {
            let (start, end) = span_bounds(s, run.cycles);
            let tid = lane_of(&mut lanes, start, end);
            let outcome = match s.outcome {
                SpanOutcome::Pending => "pending",
                SpanOutcome::Retired => "retired",
                SpanOutcome::Killed => "killed",
            };
            let first_issue = if s.first_issue == u64::MAX {
                "null".to_string()
            } else {
                s.first_issue.to_string()
            };
            events.push(format!(
                "{{\"name\": \"{} #{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \"pid\": {}, \"tid\": {}, \"args\": {{\"parent_warp\": {}, \"first_issue\": {}, \"outcome\": \"{}\"}}}}",
                s.kind.name(),
                s.token,
                s.kind.name(),
                start,
                end - start,
                pid,
                tid,
                s.parent_warp,
                first_issue,
                outcome
            ));
        }
        let mut start = 0u64;
        for (i, cw) in core.windows.iter().enumerate() {
            events.push(format!(
                "{{\"name\": \"AWT live\", \"ph\": \"C\", \"ts\": {}, \"pid\": {}, \"args\": {{\"rows\": {}}}}}",
                start, pid, cw.awt_live
            ));
            events.push(format!(
                "{{\"name\": \"MSHR inflight\", \"ph\": \"C\", \"ts\": {}, \"pid\": {}, \"args\": {{\"entries\": {}}}}}",
                start, pid, cw.mshr_inflight
            ));
            // Core windows share the chip cadence; reuse its cycle counts
            // (the final chip window may be the partial tail).
            start += run.chip.get(i).map_or(run.window, |c| c.cycles);
        }
    }

    for (i, e) in events.iter().enumerate() {
        let comma = if i + 1 < events.len() { "," } else { "" };
        writeln!(w, "    {}{}", e, comma).unwrap();
    }
    writeln!(w, "  ]").unwrap();
    writeln!(w, "}}").unwrap();
    out
}

/// Render the serve daemon's request spans ([`crate::obs::RequestTrace`],
/// fetched via the `trace` verb) as Chrome trace-event JSON. One `"X"`
/// event per request, ts/dur in the daemon's µs time base, lane-packed by
/// accept order so concurrent requests stack in the viewer; queue/exec
/// timings and the request id ride in `args`. `source` labels the trace
/// (the socket path, typically).
pub fn server_trace_json(spans: &[RequestTrace], source: &str, dropped: u64) -> String {
    let mut spans: Vec<&RequestTrace> = spans.iter().collect();
    spans.sort_by_key(|s| (s.t_accept, s.id));
    let mut out = String::new();
    let w = &mut out;
    writeln!(w, "{{").unwrap();
    writeln!(w, "  \"displayTimeUnit\": \"ms\",").unwrap();
    writeln!(
        w,
        "  \"otherData\": {{\"source\": \"{}\", \"spans\": {}, \"spans_dropped\": {}}},",
        esc(source),
        spans.len(),
        dropped
    )
    .unwrap();
    writeln!(w, "  \"traceEvents\": [").unwrap();

    let mut events: Vec<String> = Vec::new();
    events.push(
        "{\"name\": \"process_name\", \"ph\": \"M\", \"pid\": 0, \"tid\": 0, \"args\": {\"name\": \"caba serve\"}}"
            .to_string(),
    );
    let mut lanes: Vec<u64> = Vec::new();
    for s in &spans {
        let start = s.t_accept;
        let end = s.t_done.max(start + 1);
        let tid = lane_of(&mut lanes, start, end);
        let t_queued = if s.t_queued == UNSET {
            "null".to_string()
        } else {
            s.t_queued.to_string()
        };
        events.push(format!(
            "{{\"name\": \"{} #{}\", \"cat\": \"{}\", \"ph\": \"X\", \"ts\": {}, \"dur\": {}, \"pid\": 0, \"tid\": {}, \"args\": {{\"request_id\": {}, \"detail\": \"{}\", \"outcome\": \"{}\", \"t_queued\": {}, \"queue_wait_us\": {}, \"exec_us\": {}}}}}",
            esc(&s.verb),
            s.id,
            esc(&s.outcome),
            start,
            end - start,
            tid,
            s.id,
            esc(&s.detail),
            esc(&s.outcome),
            t_queued,
            s.queue_wait_us,
            s.exec_us
        ));
    }

    for (i, e) in events.iter().enumerate() {
        let comma = if i + 1 < events.len() { "," } else { "" };
        writeln!(w, "    {}{}", e, comma).unwrap();
    }
    writeln!(w, "  ]").unwrap();
    writeln!(w, "}}").unwrap();
    out
}

#[cfg(test)]
mod tests {
    use super::super::{ChipWindow, CoreTimeline, CoreWindow, Span, SpanKind, SpanOutcome};
    use super::*;

    fn tiny_run() -> TelemetryRun {
        TelemetryRun {
            window: 10,
            cycles: 25,
            n_mcs: 2,
            chip: vec![
                ChipWindow {
                    cycles: 10,
                    warp_insts: 12,
                    bursts: 4,
                    bursts_uncompressed: 8,
                    bus_busy_cycles: 21.0,
                    ..Default::default()
                },
                ChipWindow {
                    cycles: 10,
                    ..Default::default()
                },
                ChipWindow {
                    cycles: 5,
                    ..Default::default()
                },
            ],
            chip_truncated: 0,
            bus_overcommit_windows: 1,
            cores: vec![CoreTimeline {
                sm_id: 0,
                windows: vec![CoreWindow::default(); 3],
                truncated_windows: 0,
                spans: vec![
                    Span {
                        token: 1,
                        kind: SpanKind::Decompress,
                        parent_warp: 2,
                        trigger_at: 3,
                        first_issue: 4,
                        end: 9,
                        outcome: SpanOutcome::Retired,
                    },
                    Span {
                        token: 2,
                        kind: SpanKind::Prefetch,
                        parent_warp: 0,
                        trigger_at: 5,
                        first_issue: u64::MAX,
                        end: u64::MAX,
                        outcome: SpanOutcome::Pending,
                    },
                ],
                spans_dropped: 0,
            }],
        }
    }

    #[test]
    fn trace_json_is_balanced_and_complete() {
        let json = chrome_trace_json(&tiny_run(), "PVC", "CABA-BDI");
        let braces =
            json.chars().filter(|&c| c == '{').count() - json.chars().filter(|&c| c == '}').count();
        assert_eq!(braces, 0);
        let brackets =
            json.chars().filter(|&c| c == '[').count() - json.chars().filter(|&c| c == ']').count();
        assert_eq!(brackets, 0);
        assert!(json.contains("\"traceEvents\""));
        assert!(json.contains("\"ph\": \"M\""));
        assert!(json.contains("\"name\": \"SM 0\""));
        assert!(json.contains("decompress #1"));
        // Overlapping spans land on different lanes.
        assert!(json.contains("\"tid\": 0"));
        assert!(json.contains("\"tid\": 1"));
        // Pending span clamps to run end: dur = 25 - 5.
        assert!(json.contains("\"dur\": 20"));
        // Trailing element has no comma before the closing bracket.
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn server_trace_json_is_balanced_and_lane_packed() {
        let mk = |id: u64, t_accept: u64, t_done: u64, outcome: &str| RequestTrace {
            id,
            verb: "sweep".to_string(),
            detail: "SLA/Base".to_string(),
            outcome: outcome.to_string(),
            t_accept,
            t_parsed: t_accept + 1,
            t_queued: if outcome == "cold" { t_accept + 2 } else { UNSET },
            t_done,
            queue_wait_us: 5,
            exec_us: 100,
        };
        // Two overlapping requests and one later one — out of accept
        // order, to prove the export sorts before lane packing.
        let spans = vec![mk(3, 500, 600, "warm"), mk(1, 0, 400, "cold"), mk(2, 100, 300, "dedup")];
        let json = server_trace_json(&spans, "/tmp/test.sock", 7);
        let braces =
            json.chars().filter(|&c| c == '{').count() - json.chars().filter(|&c| c == '}').count();
        assert_eq!(braces, 0);
        assert!(json.contains("\"name\": \"caba serve\""));
        assert!(json.contains("\"spans_dropped\": 7"));
        assert!(json.contains("sweep #1"));
        assert!(json.contains("\"request_id\": 2"));
        // Request 2 overlaps request 1 → lane 1; request 3 reuses lane 0.
        assert!(json.contains("\"tid\": 1"));
        // Warm span's t_queued is null, cold's is numeric.
        assert!(json.contains("\"t_queued\": null"));
        assert!(json.contains("\"t_queued\": 2"));
        assert!(!json.contains(",\n  ]"));
    }

    #[test]
    fn lane_packing_is_greedy_and_deterministic() {
        let mut lanes = Vec::new();
        assert_eq!(lane_of(&mut lanes, 0, 10), 0);
        assert_eq!(lane_of(&mut lanes, 5, 8), 1); // overlaps lane 0
        assert_eq!(lane_of(&mut lanes, 8, 12), 1); // lane 1 free at 8
        assert_eq!(lane_of(&mut lanes, 9, 11), 2); // 0 and 1 both busy
        assert_eq!(lane_of(&mut lanes, 12, 13), 0); // lane 0 free again
    }

    #[test]
    fn span_bounds_clamp_pending_and_zero_length() {
        let mut s = Span {
            token: 1,
            kind: SpanKind::Compress,
            parent_warp: 0,
            trigger_at: 7,
            first_issue: u64::MAX,
            end: u64::MAX,
            outcome: SpanOutcome::Pending,
        };
        assert_eq!(span_bounds(&s, 100), (7, 100));
        s.end = 7; // killed the cycle it was triggered
        assert_eq!(span_bounds(&s, 100), (7, 8));
    }
}
