//! The real PJRT oracle (compiled only with `--features pjrt`; requires
//! the vendored `xla` bindings crate — see `runtime/mod.rs`).
//!
//! Interchange is HLO *text*, not serialized protos: jax >= 0.5 emits
//! 64-bit instruction ids that the image's xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! Python runs only at build time; at runtime the artifacts are compiled
//! by the in-process PJRT CPU client and executed directly.

use super::{default_artifacts_dir, BATCH};
use crate::compress::oracle::{CompressionOracle, LineVerdict};
use crate::compress::{bursts_for, Algo, Line, WORDS_PER_LINE};
use anyhow::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;

/// A compiled compression-analysis executable for one algorithm.
struct AlgoExe {
    exe: xla::PjRtLoadedExecutable,
}

/// The PJRT-backed oracle: batches line batches through the AOT-compiled
/// JAX/Pallas model.
pub struct PjrtOracle {
    _client: xla::PjRtClient,
    exes: HashMap<&'static str, AlgoExe>,
}

// The oracle is owned by exactly one `Simulator` and used from one thread
// at a time; the `Send` bound (required so a whole simulation can move to
// a sweep worker) is sound because the PJRT CPU client is only ever
// driven through `&mut self` here.
unsafe impl Send for PjrtOracle {}

fn algo_key(algo: Algo) -> &'static str {
    match algo {
        Algo::Bdi => "bdi",
        Algo::Fpc => "fpc",
        Algo::CPack => "cpack",
        Algo::BestOfAll => "best",
    }
}

impl PjrtOracle {
    /// Load and compile all artifacts from `dir`.
    pub fn load(dir: &Path) -> Result<PjrtOracle> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut exes = HashMap::new();
        for key in ["bdi", "fpc", "cpack", "best"] {
            let path = dir.join(format!("{key}.hlo.txt"));
            if !path.exists() {
                continue;
            }
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("artifact path not utf-8")?,
            )
            .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
            exes.insert(key, AlgoExe { exe });
        }
        if exes.is_empty() {
            return Err(anyhow!(
                "no compression artifacts found in {dir:?}; run `make artifacts`"
            ));
        }
        Ok(PjrtOracle { _client: client, exes })
    }

    /// Load from the default artifacts directory.
    pub fn from_default_dir() -> Result<PjrtOracle> {
        Self::load(&default_artifacts_dir())
    }

    /// Execute one padded batch: returns (encoding, size_bytes) per line.
    fn run_batch(&self, algo: Algo, lines: &[Line]) -> Result<Vec<(u8, u16)>> {
        let exe = self
            .exes
            .get(algo_key(algo))
            .ok_or_else(|| anyhow!("no artifact for {algo:?}"))?;
        debug_assert!(lines.len() <= BATCH);
        // Pack into u32 words, pad with zero lines.
        let mut words = vec![0u32; BATCH * WORDS_PER_LINE];
        for (i, line) in lines.iter().enumerate() {
            for (j, chunk) in line.chunks_exact(4).enumerate() {
                words[i * WORDS_PER_LINE + j] = u32::from_le_bytes(chunk.try_into().unwrap());
            }
        }
        let input = xla::Literal::vec1(&words)
            .reshape(&[BATCH as i64, WORDS_PER_LINE as i64])
            .map_err(|e| anyhow!("reshape: {e:?}"))?;
        let result = exe
            .exe
            .execute::<xla::Literal>(&[input])
            .map_err(|e| anyhow!("execute: {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("sync: {e:?}"))?;
        // aot.py lowers with return_tuple=True → ((enc, size),).
        let tuple = result.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        let (enc_lit, size_lit) = match tuple.len() {
            2 => {
                let mut it = tuple.into_iter();
                (it.next().unwrap(), it.next().unwrap())
            }
            1 => {
                let inner = tuple.into_iter().next().unwrap();
                inner
                    .to_tuple2()
                    .map_err(|e| anyhow!("inner tuple: {e:?}"))?
            }
            n => return Err(anyhow!("unexpected tuple arity {n}")),
        };
        let encs = enc_lit.to_vec::<i32>().map_err(|e| anyhow!("enc vec: {e:?}"))?;
        let sizes = size_lit.to_vec::<i32>().map_err(|e| anyhow!("size vec: {e:?}"))?;
        Ok(lines
            .iter()
            .enumerate()
            .map(|(i, _)| (encs[i] as u8, sizes[i] as u16))
            .collect())
    }
}

impl CompressionOracle for PjrtOracle {
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict> {
        let mut out = Vec::with_capacity(lines.len());
        for chunk in lines.chunks(BATCH) {
            let res = self
                .run_batch(algo, chunk)
                .expect("PJRT oracle execution failed");
            out.extend(res.into_iter().map(|(encoding, size_bytes)| LineVerdict {
                encoding,
                size_bytes,
                bursts: bursts_for(size_bytes as usize),
            }));
        }
        out
    }

    fn backend_name(&self) -> &'static str {
        "pjrt"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn algo_keys_distinct() {
        let keys: Vec<_> = [Algo::Bdi, Algo::Fpc, Algo::CPack, Algo::BestOfAll]
            .iter()
            .map(|&a| algo_key(a))
            .collect();
        let mut uniq = keys.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), keys.len());
    }
}
