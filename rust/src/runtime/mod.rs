//! PJRT runtime: loads the AOT-compiled JAX/Pallas compression model
//! (`artifacts/*.hlo.txt`, produced once by `make artifacts`) and serves it
//! as a [`crate::compress::oracle::CompressionOracle`] from the Rust
//! request path.
//!
//! The real implementation ([`pjrt`]) depends on the `xla` bindings crate,
//! which is not part of the offline image. It is therefore gated behind
//! the `pjrt` cargo feature: vendor the bindings, add them under
//! `[dependencies]`, and build with `--features pjrt`. Without the
//! feature, a stub [`PjrtOracle`] is compiled that fails loudly at load
//! time (and [`artifacts_available`] reports `false`), so every caller —
//! CLI `--oracle pjrt`, `examples/full_eval.rs`, the integration tests —
//! degrades gracefully to the native oracle.

use std::path::PathBuf;

/// Batch size the artifacts are exported with (`python/compile/aot.py`).
pub const BATCH: usize = 256;

/// Default artifacts directory (relative to the repo root). Walks up from
/// the current dir so examples/tests work from anywhere inside the repo.
pub fn default_artifacts_dir() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("bdi.hlo.txt").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Are the PJRT artifacts present *and usable*? Requires both `make
/// artifacts` having run and the crate being built with the `pjrt`
/// feature.
pub fn artifacts_available() -> bool {
    cfg!(feature = "pjrt") && default_artifacts_dir().join("bdi.hlo.txt").exists()
}

#[cfg(feature = "pjrt")]
pub mod pjrt;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtOracle;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use crate::compress::oracle::{CompressionOracle, LineVerdict};
    use crate::compress::{Algo, Line};
    use anyhow::{anyhow, Result};
    use std::path::Path;

    /// Stub compiled when the `pjrt` feature is off: construction always
    /// fails with an actionable error, so no caller can ever hold one.
    #[derive(Debug)]
    pub struct PjrtOracle {
        _private: (),
    }

    impl PjrtOracle {
        pub fn load(_dir: &Path) -> Result<PjrtOracle> {
            Err(anyhow!(
                "this build has no PJRT runtime (the `pjrt` cargo feature is \
                 disabled because the offline image lacks the xla bindings); \
                 vendor the xla crate, rebuild with `--features pjrt`, and run \
                 `make artifacts`"
            ))
        }

        pub fn from_default_dir() -> Result<PjrtOracle> {
            Self::load(Path::new("artifacts"))
        }
    }

    impl CompressionOracle for PjrtOracle {
        fn analyze(&mut self, _algo: Algo, _lines: &[Line]) -> Vec<LineVerdict> {
            // Unreachable: `load` never returns Ok.
            unreachable!("stub PjrtOracle cannot be constructed")
        }

        fn backend_name(&self) -> &'static str {
            "pjrt-stub"
        }
    }
}

#[cfg(not(feature = "pjrt"))]
pub use stub::PjrtOracle;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_discovery_is_total() {
        // Must not panic even when artifacts are absent.
        let _ = default_artifacts_dir();
        let _ = artifacts_available();
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_fails_loudly_with_fix_instructions() {
        let err = PjrtOracle::from_default_dir().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
