//! The evaluated system designs (§7): Base, HW-BDI-Mem, HW-BDI, CABA-*,
//! Ideal-BDI, plus the Fig. 15 cache-compression and Fig. 16 optimization
//! variants.

use crate::compress::Algo;

/// Who performs (de)compression and at what cost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mechanism {
    /// No compression anywhere.
    None,
    /// Dedicated logic: fixed 1-cycle decompression / 5-cycle compression
    /// (paper's Synopsys BDI implementation).
    Hardware,
    /// Assist warps on the cores (the paper's contribution): subroutines
    /// occupy real issue slots and pipelines.
    Caba,
    /// Compression benefits with zero latency/energy overhead (upper bound).
    Ideal,
}

/// A complete design point.
#[derive(Clone, Copy, Debug)]
pub struct Design {
    pub name: &'static str,
    pub algo: Algo,
    pub mechanism: Mechanism,
    /// DRAM link transfers compressed bursts.
    pub mem_compression: bool,
    /// Interconnect data payloads travel compressed.
    pub icnt_compression: bool,
    /// L2 keeps lines in compressed form (default for icnt-compressed
    /// designs; `false` = Fig. 16's "Uncompressed L2" option).
    pub l2_holds_compressed: bool,
    /// Fig. 16 "Direct-Load": the coalescer extracts only needed words, so
    /// L1 keeps the compressed form and every L1 hit pays decompression.
    pub direct_load: bool,
    /// Fig. 15 cache-capacity compression: tag multiplier (1 = off).
    pub l1_tag_mult: usize,
    pub l2_tag_mult: usize,
    /// §8.2 extension: stride-prefetching assist warps.
    pub prefetch: bool,
    /// §8.1 extension: memoization assist warps for SFU computations.
    pub memoization: bool,
}

impl Design {
    pub const fn base() -> Design {
        Design {
            name: "Base",
            algo: Algo::Bdi,
            mechanism: Mechanism::None,
            mem_compression: false,
            icnt_compression: false,
            l2_holds_compressed: false,
            direct_load: false,
            l1_tag_mult: 1,
            l2_tag_mult: 1,
            prefetch: false,
            memoization: false,
        }
    }

    /// §8.2: assist-warp prefetching, no compression — the framework's
    /// memory-latency use case.
    pub const fn caba_prefetch() -> Design {
        Design {
            name: "CABA-Prefetch",
            mechanism: Mechanism::Caba,
            prefetch: true,
            ..Design::base()
        }
    }

    /// §8.1: assist-warp memoization, no compression — the framework's
    /// compute-bottleneck use case (converts computation into storage).
    pub const fn caba_memo() -> Design {
        Design {
            name: "CABA-Memo",
            mechanism: Mechanism::Caba,
            memoization: true,
            ..Design::base()
        }
    }

    /// Compress + memoize hybrid: CABA-BDI's full compression stack with
    /// §8.1 memoization on top — the framework attacking both bottleneck
    /// axes at once with one assist-warp engine.
    pub const fn caba_memo_hybrid() -> Design {
        Design {
            name: "CABA-BDI-Memo",
            memoization: true,
            ..Design::caba(Algo::Bdi)
        }
    }

    /// HW-BDI-Mem: dedicated logic at the MCs; DRAM link only (prior work
    /// [100]-style). Data crosses the interconnect uncompressed.
    pub const fn hw_bdi_mem() -> Design {
        Design {
            name: "HW-BDI-Mem",
            mechanism: Mechanism::Hardware,
            mem_compression: true,
            ..Design::base()
        }
    }

    /// HW-BDI: dedicated logic at the cores; both interconnect and DRAM.
    pub const fn hw_bdi() -> Design {
        Design {
            name: "HW-BDI",
            mechanism: Mechanism::Hardware,
            mem_compression: true,
            icnt_compression: true,
            l2_holds_compressed: true,
            ..Design::base()
        }
    }

    /// CABA with a given algorithm: assist warps at the cores; both
    /// interconnect and DRAM compressed.
    pub const fn caba(algo: Algo) -> Design {
        Design {
            name: match algo {
                Algo::Bdi => "CABA-BDI",
                Algo::Fpc => "CABA-FPC",
                Algo::CPack => "CABA-CPack",
                Algo::BestOfAll => "CABA-BestOfAll",
            },
            algo,
            mechanism: Mechanism::Caba,
            mem_compression: true,
            icnt_compression: true,
            l2_holds_compressed: true,
            ..Design::base()
        }
    }

    /// Ideal-BDI: compression benefits with no overheads.
    pub const fn ideal_bdi() -> Design {
        Design {
            name: "Ideal-BDI",
            mechanism: Mechanism::Ideal,
            mem_compression: true,
            icnt_compression: true,
            l2_holds_compressed: true,
            ..Design::base()
        }
    }

    /// Fig. 16 "Uncompressed L2" variant of CABA-BDI.
    pub const fn caba_uncompressed_l2() -> Design {
        Design {
            name: "CABA-BDI-UncompL2",
            l2_holds_compressed: false,
            ..Design::caba(Algo::Bdi)
        }
    }

    /// Fig. 16 "Direct-Load" variant of CABA-BDI.
    pub const fn caba_direct_load() -> Design {
        Design {
            name: "CABA-BDI-DirectLoad",
            direct_load: true,
            ..Design::caba(Algo::Bdi)
        }
    }

    /// Fig. 15 cache-capacity compression on top of CABA-BDI.
    pub const fn caba_cache_compressed(l1_mult: usize, l2_mult: usize) -> Design {
        Design {
            name: match (l1_mult, l2_mult) {
                (2, 1) => "CABA-BDI-L1-2x",
                (4, 1) => "CABA-BDI-L1-4x",
                (1, 2) => "CABA-BDI-L2-2x",
                (1, 4) => "CABA-BDI-L2-4x",
                _ => "CABA-BDI-cache",
            },
            l1_tag_mult: l1_mult,
            l2_tag_mult: l2_mult,
            ..Design::caba(Algo::Bdi)
        }
    }

    /// The five headline designs of Figs. 8–11.
    pub fn headline() -> [Design; 5] {
        [
            Design::base(),
            Design::hw_bdi_mem(),
            Design::hw_bdi(),
            Design::caba(Algo::Bdi),
            Design::ideal_bdi(),
        ]
    }

    /// Every nameable design point — the lookup universe for the CLI and
    /// the serve daemon's request parser.
    pub fn all() -> [Design; 17] {
        [
            Design::base(),
            Design::hw_bdi_mem(),
            Design::hw_bdi(),
            Design::caba(Algo::Bdi),
            Design::caba(Algo::Fpc),
            Design::caba(Algo::CPack),
            Design::caba(Algo::BestOfAll),
            Design::ideal_bdi(),
            Design::caba_uncompressed_l2(),
            Design::caba_direct_load(),
            Design::caba_cache_compressed(2, 1),
            Design::caba_cache_compressed(4, 1),
            Design::caba_cache_compressed(1, 2),
            Design::caba_cache_compressed(1, 4),
            Design::caba_prefetch(),
            Design::caba_memo(),
            Design::caba_memo_hybrid(),
        ]
    }

    /// Look a design up by its display name (case-insensitive).
    pub fn by_name(name: &str) -> Option<Design> {
        Design::all().iter().find(|d| d.name.eq_ignore_ascii_case(name)).copied()
    }

    /// Does any compression happen at all?
    pub fn compression_enabled(&self) -> bool {
        self.mem_compression || self.icnt_compression || self.l1_tag_mult > 1 || self.l2_tag_mult > 1
    }

    /// Does this design run assist warps at all?
    pub fn uses_assist_warps(&self) -> bool {
        self.mechanism == Mechanism::Caba
            && (self.compression_enabled() || self.prefetch || self.memoization)
    }

    /// Does the L1 store compressed lines (Fig. 15 L1 capacity mode or
    /// Fig. 16 direct-load)?
    pub fn l1_holds_compressed(&self) -> bool {
        self.l1_tag_mult > 1 || self.direct_load
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn headline_names() {
        let names: Vec<_> = Design::headline().iter().map(|d| d.name).collect();
        assert_eq!(names, ["Base", "HW-BDI-Mem", "HW-BDI", "CABA-BDI", "Ideal-BDI"]);
    }

    #[test]
    fn base_has_no_compression() {
        let b = Design::base();
        assert!(!b.compression_enabled());
        assert_eq!(b.mechanism, Mechanism::None);
    }

    #[test]
    fn hw_bdi_mem_leaves_icnt_uncompressed() {
        let d = Design::hw_bdi_mem();
        assert!(d.mem_compression && !d.icnt_compression && !d.l2_holds_compressed);
    }

    #[test]
    fn caba_variants() {
        assert_eq!(Design::caba(Algo::Fpc).name, "CABA-FPC");
        assert!(!Design::caba_uncompressed_l2().l2_holds_compressed);
        assert!(Design::caba_direct_load().l1_holds_compressed());
        assert!(Design::caba_cache_compressed(2, 1).l1_holds_compressed());
        assert_eq!(Design::caba_cache_compressed(1, 4).l2_tag_mult, 4);
    }

    #[test]
    fn by_name_covers_all_and_is_case_insensitive() {
        for d in Design::all() {
            assert_eq!(Design::by_name(d.name).map(|x| x.name), Some(d.name));
            assert_eq!(Design::by_name(&d.name.to_lowercase()).map(|x| x.name), Some(d.name));
        }
        // Names are unique — a duplicate would make by_name ambiguous.
        let names: Vec<_> = Design::all().iter().map(|d| d.name).collect();
        let mut dedup = names.clone();
        dedup.sort_unstable();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        assert!(Design::by_name("no-such-design").is_none());
    }

    #[test]
    fn memo_designs() {
        let m = Design::caba_memo();
        assert!(m.memoization && !m.compression_enabled() && m.uses_assist_warps());
        let h = Design::caba_memo_hybrid();
        assert!(h.memoization && h.mem_compression && h.icnt_compression);
        assert_eq!(h.name, "CABA-BDI-Memo");
    }
}
