//! Flat per-line state slab — the zero-allocation backing store for
//! [`crate::sim::DataModel`].
//!
//! The simulator's line-address space is *bounded and known at build time*:
//! every array lives at `base_line = (array_id + 1) × ARRAY_STRIDE` and
//! spans `footprint_lines` consecutive lines ([`crate::workload`]), so a
//! line address decomposes into `(array_id, offset)` with two integer ops
//! and maps onto a dense slab index by a per-array prefix sum. That turns
//! the three per-access `HashMap`/`HashSet` lookups the old `DataModel`
//! performed (SipHash over the 64-bit address, pointer-chasing buckets)
//! into one shift, one mask and one bounds check into a struct-of-arrays.
//!
//! Lines outside every declared range (possible only for hand-crafted
//! traces; the generators and the importer both stay in range) fall back to
//! a spill map so behaviour is identical, just not fast. Workloads whose
//! total footprint exceeds [`DENSE_CAP_LINES`] (an imported trace spanning
//! a huge sparse window) route *everything* through the spill map rather
//! than allocating an absurd slab — the pre-slab memory behaviour.

use crate::compress::oracle::LineVerdict;
use crate::workload::{ArrayInfo, ARRAY_STRIDE};
use std::collections::HashMap;

/// Sentinel for "no verdict cached yet" in `verdict_epochs`. Store epochs
/// count individual line rewrites and are bounded by the instruction
/// budget, so a real epoch never reaches it.
const NO_VERDICT: u32 = u32::MAX;

/// Above this total footprint (lines) the dense slab is not allocated and
/// every line goes through the spill map. 4 Mlines × 13 B/line ≈ 52 MB is
/// the ceiling a dense slab may cost; every synthetic workload is two
/// orders of magnitude below it.
const DENSE_CAP_LINES: u64 = 1 << 22;

/// Per-line simulator state, struct-of-arrays: epochs, verdict cache and
/// stored-form flag folded into one structure with O(1) addressing.
pub struct LineSlab {
    /// Per-array `(footprint_lines, slab base offset)`, indexed by
    /// `array_id = line / ARRAY_STRIDE - 1`. Empty when the workload
    /// exceeded [`DENSE_CAP_LINES`] (spill-only mode).
    ranges: Vec<(u64, usize)>,
    /// Slot lookup for lines outside every dense range.
    spill: HashMap<u64, usize>,
    /// Store-generation counter per line (0 = never stored).
    epochs: Vec<u32>,
    /// Epoch the cached verdict was computed at ([`NO_VERDICT`] = none).
    verdict_epochs: Vec<u32>,
    /// Cached oracle verdict (valid iff `verdict_epochs[s] != NO_VERDICT`;
    /// *fresh* iff it equals `epochs[s]`).
    verdicts: Vec<LineVerdict>,
    /// Line's DRAM image is uncompressed (compression skipped at store).
    uncompressed: Vec<bool>,
}

impl LineSlab {
    /// Build the slab for a workload's array table.
    pub fn new(arrays: &[ArrayInfo]) -> LineSlab {
        let total: u64 = arrays.iter().map(|a| a.footprint_lines).sum();
        let mut ranges = Vec::new();
        let mut len = 0usize;
        if total <= DENSE_CAP_LINES {
            for (i, a) in arrays.iter().enumerate() {
                // The workload builder always places array i at
                // (i+1) × ARRAY_STRIDE; the decomposition in `slot`
                // depends on it.
                debug_assert_eq!(a.base_line, (i as u64 + 1) * ARRAY_STRIDE);
                ranges.push((a.footprint_lines, len));
                len += a.footprint_lines as usize;
            }
        }
        LineSlab {
            ranges,
            spill: HashMap::new(),
            epochs: vec![0; len],
            verdict_epochs: vec![NO_VERDICT; len],
            verdicts: vec![LineVerdict::uncompressed(); len],
            uncompressed: vec![false; len],
        }
    }

    /// Dense slot for a line, if it falls inside a declared array range.
    #[inline]
    fn dense_slot(&self, line: u64) -> Option<usize> {
        let aid = (line / ARRAY_STRIDE) as usize;
        if aid == 0 || aid > self.ranges.len() {
            return None;
        }
        let (footprint, base) = self.ranges[aid - 1];
        let off = line - aid as u64 * ARRAY_STRIDE;
        (off < footprint).then_some(base + off as usize)
    }

    /// Slot for a line, creating a spill slot on first touch of an
    /// out-of-range address.
    #[inline]
    pub fn slot(&mut self, line: u64) -> usize {
        if let Some(s) = self.dense_slot(line) {
            return s;
        }
        if let Some(&s) = self.spill.get(&line) {
            return s;
        }
        let s = self.epochs.len();
        self.epochs.push(0);
        self.verdict_epochs.push(NO_VERDICT);
        self.verdicts.push(LineVerdict::uncompressed());
        self.uncompressed.push(false);
        self.spill.insert(line, s);
        s
    }

    /// Slot for a line without allocating a spill entry (read-only paths).
    #[inline]
    pub fn slot_ref(&self, line: u64) -> Option<usize> {
        self.dense_slot(line).or_else(|| self.spill.get(&line).copied())
    }

    #[inline]
    pub fn epoch(&self, s: usize) -> u32 {
        self.epochs[s]
    }

    #[inline]
    pub fn bump_epoch(&mut self, s: usize) {
        self.epochs[s] += 1;
    }

    #[inline]
    pub fn stored_uncompressed(&self, s: usize) -> bool {
        self.uncompressed[s]
    }

    #[inline]
    pub fn set_stored_uncompressed(&mut self, s: usize, v: bool) {
        self.uncompressed[s] = v;
    }

    /// Cached verdict if one was computed at exactly `epoch`.
    #[inline]
    pub fn verdict_if_fresh(&self, s: usize, epoch: u32) -> Option<LineVerdict> {
        (self.verdict_epochs[s] == epoch).then_some(self.verdicts[s])
    }

    /// Record a verdict computed at `epoch`.
    #[inline]
    pub fn put_verdict(&mut self, s: usize, epoch: u32, v: LineVerdict) {
        self.verdict_epochs[s] = epoch;
        self.verdicts[s] = v;
    }

    /// Mark the slot's verdict fresh at `epoch` *before* the value is
    /// known — the batch path in `DataModel::warm_verdicts` stamps every
    /// pending slot so in-batch duplicates dedup in O(1), then fills the
    /// values with [`LineSlab::set_verdict_value`] after the one oracle
    /// call. Nothing may read the verdict between stamp and fill.
    #[inline]
    pub fn stamp(&mut self, s: usize, epoch: u32) {
        self.verdict_epochs[s] = epoch;
    }

    #[inline]
    pub fn set_verdict_value(&mut self, s: usize, v: LineVerdict) {
        self.verdicts[s] = v;
    }

    /// Encoding of the most recent verdict ever computed for this slot
    /// (possibly stale — mirrors the old `verdict_cache` semantics where
    /// an epoch bump left the entry in place).
    #[inline]
    pub fn encoding_hint(&self, s: usize) -> Option<u8> {
        (self.verdict_epochs[s] != NO_VERDICT).then_some(self.verdicts[s].encoding)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::ArrayInfo;

    fn arrays(footprints: &[u64]) -> Vec<ArrayInfo> {
        footprints
            .iter()
            .enumerate()
            .map(|(i, &fp)| ArrayInfo {
                base_line: (i as u64 + 1) * ARRAY_STRIDE,
                footprint_lines: fp,
                pattern: crate::workload::datagen::DataPattern::Random,
            })
            .collect()
    }

    #[test]
    fn dense_mapping_is_contiguous_and_disjoint() {
        let slab = LineSlab::new(&arrays(&[4, 2, 8]));
        let mut seen = Vec::new();
        for (i, fp) in [4u64, 2, 8].iter().enumerate() {
            for off in 0..*fp {
                let line = (i as u64 + 1) * ARRAY_STRIDE + off;
                seen.push(slab.slot_ref(line).expect("in range"));
            }
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 14, "slots must be distinct");
        assert_eq!(*sorted.last().unwrap(), 13, "slots must be dense");
        // Out-of-range offsets are not dense slots.
        assert_eq!(slab.slot_ref(ARRAY_STRIDE + 4), None);
        assert_eq!(slab.slot_ref(7), None); // below the first array
    }

    #[test]
    fn spill_lines_get_stable_slots() {
        let mut slab = LineSlab::new(&arrays(&[2]));
        let odd = 5 * ARRAY_STRIDE + 99; // no such array
        let s1 = slab.slot(odd);
        slab.bump_epoch(s1);
        let s2 = slab.slot(odd);
        assert_eq!(s1, s2);
        assert_eq!(slab.epoch(s2), 1);
        assert_eq!(slab.slot_ref(odd), Some(s1));
    }

    #[test]
    fn state_roundtrip() {
        let mut slab = LineSlab::new(&arrays(&[4]));
        let s = slab.slot(ARRAY_STRIDE + 3);
        assert_eq!(slab.epoch(s), 0);
        assert!(!slab.stored_uncompressed(s));
        assert_eq!(slab.verdict_if_fresh(s, 0), None);
        assert_eq!(slab.encoding_hint(s), None);
        let v = LineVerdict { encoding: 2, size_bytes: 27, bursts: 1 };
        slab.put_verdict(s, 0, v);
        assert_eq!(slab.verdict_if_fresh(s, 0), Some(v));
        assert_eq!(slab.encoding_hint(s), Some(2));
        slab.bump_epoch(s);
        // Stale after a store, but the hint survives (old semantics).
        assert_eq!(slab.verdict_if_fresh(s, 1), None);
        assert_eq!(slab.encoding_hint(s), Some(2));
        slab.set_stored_uncompressed(s, true);
        assert!(slab.stored_uncompressed(s));
    }

    #[test]
    fn oversized_footprint_falls_back_to_spill() {
        let slab_arrays = arrays(&[DENSE_CAP_LINES + 1]);
        let mut slab = LineSlab::new(&slab_arrays);
        assert_eq!(slab.slot_ref(ARRAY_STRIDE), None, "no dense range allocated");
        let s = slab.slot(ARRAY_STRIDE);
        assert_eq!(slab.slot_ref(ARRAY_STRIDE), Some(s));
    }
}
