//! The top-level simulator: wires the SMs, memory system, CABA controllers,
//! data model and workload into a cycle loop, and produces [`SimStats`].

pub mod designs;
pub mod slab;

use crate::compress::oracle::{CompressionOracle, LineVerdict, MemoOracle, NativeOracle};
use crate::compress::Algo;
use crate::config::SimConfig;
use crate::core::{Core, CoreCtx, DrainCtx};
use crate::mem::MemSystem;
use crate::util::barrier::SpinBarrier;
use crate::stats::SimStats;
use crate::telemetry::{ChipRecorder, ChipSnap, CoreTimeline, TelemetryRun};
use crate::trace::{record::TraceRecorder, replay::TraceData, TraceKind, TraceMeta, PATTERN_FROM_SPEC};
use crate::workload::{apps::AppSpec, ArrayInfo, TraceRole, Workload};
use anyhow::{bail, Result};
use designs::{Design, Mechanism};
use slab::LineSlab;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Extra registers per thread reserved for assist-warp contexts when CABA
/// is enabled (§4.2.2: each enabled subroutine's register need is added to
/// the per-block requirement). The subroutines are short vector sequences
/// needing ~2 registers per lane; they draw first on the statically
/// unallocated registers (Fig. 3), so occupancy drops only for apps with a
/// nearly fully-allocated register file — the effect §4.2.2 warns about.
pub const CABA_EXTRA_REGS: u32 = 2;

/// The simulator's view of memory *contents*: line data is a pure function
/// of (address, epoch), so stores only bump epochs; the compression oracle
/// verdict is cached per (line, epoch).
///
/// All per-line state (epochs, stored-form flags, verdict cache) lives in
/// a dense [`LineSlab`] indexed by the workload's bounded line space — the
/// per-access path hashes nothing and allocates nothing.
pub struct DataModel {
    oracle: Box<dyn CompressionOracle>,
    slab: LineSlab,
    /// Reusable batch scratch for [`DataModel::warm_verdicts`]:
    /// slots awaiting a verdict and their line payloads.
    pending: Vec<usize>,
    datas: Vec<crate::compress::Line>,
}

impl DataModel {
    pub fn new(oracle: Box<dyn CompressionOracle>, arrays: &[ArrayInfo]) -> DataModel {
        DataModel {
            oracle,
            slab: LineSlab::new(arrays),
            pending: Vec::new(),
            datas: Vec::new(),
        }
    }

    /// Compression verdict for the line's *stored* DRAM image.
    pub fn verdict(&mut self, wl: &Workload, algo: Algo, line: u64) -> LineVerdict {
        let s = self.slab.slot(line);
        if self.slab.stored_uncompressed(s) {
            return LineVerdict::uncompressed();
        }
        let epoch = self.slab.epoch(s);
        if let Some(v) = self.slab.verdict_if_fresh(s, epoch) {
            return v;
        }
        let data = wl.line_data(line, epoch);
        let v = self.oracle.analyze_one(algo, &data);
        self.slab.put_verdict(s, epoch, v);
        v
    }

    /// Batch-compute verdicts for all of `lines` in **one** oracle call
    /// (`CompressionOracle::analyze`), priming the per-line cache so the
    /// per-line [`DataModel::verdict`] lookups that follow are hits.
    ///
    /// This is the hot-path batching the PJRT oracle is built for: a store
    /// instruction's pending lines (up to `Scatter::degree`) become one
    /// executable launch instead of N. Purely a performance device — the
    /// verdict for each line is the same pure function of (line, epoch)
    /// either way, so timing and stats are unchanged.
    ///
    /// In-batch duplicates dedup in O(1): the first occurrence stamps its
    /// slab slot fresh, so the second occurrence's freshness check skips
    /// it (no quadratic `pending` scan, no per-batch allocation — the
    /// scratch vectors are reused across calls).
    pub fn warm_verdicts(&mut self, wl: &Workload, algo: Algo, lines: &[u64]) {
        if lines.len() <= 1 {
            return; // nothing to batch; verdict() handles singles
        }
        self.pending.clear();
        self.datas.clear();
        for &line in lines {
            let s = self.slab.slot(line);
            if self.slab.stored_uncompressed(s) {
                continue; // verdict() short-circuits these
            }
            let epoch = self.slab.epoch(s);
            if self.slab.verdict_if_fresh(s, epoch).is_some() {
                continue; // already fresh — or a duplicate stamped below
            }
            self.slab.stamp(s, epoch);
            self.pending.push(s);
            self.datas.push(wl.line_data(line, epoch));
        }
        if self.pending.is_empty() {
            return;
        }
        let verdicts = self.oracle.analyze(algo, &self.datas);
        debug_assert_eq!(verdicts.len(), self.pending.len());
        for (&s, v) in self.pending.iter().zip(verdicts) {
            self.slab.set_verdict_value(s, v);
        }
    }

    /// Encoding from the most recent verdict for this line (drives the
    /// decompression-subroutine shape; falls back to a mid-cost encoding).
    pub fn cached_encoding(&self, line: u64) -> u8 {
        self.slab
            .slot_ref(line)
            .and_then(|s| self.slab.encoding_hint(s))
            .unwrap_or(crate::compress::bdi::ENC_B8D1)
    }

    /// A store rewrote this line.
    pub fn bump_epoch(&mut self, line: u64) {
        let s = self.slab.slot(line);
        self.slab.bump_epoch(s);
    }

    /// Record whether the DRAM image of this line is compressed.
    pub fn set_stored_compressed(&mut self, line: u64, compressed: bool) {
        let s = self.slab.slot(line);
        self.slab.set_stored_uncompressed(s, !compressed);
    }

    pub fn oracle_backend(&self) -> &'static str {
        self.oracle.backend_name()
    }

    /// Memoization counters of the underlying oracle, if it keeps any
    /// (`(hits, misses)` — see [`CompressionOracle::memo_stats`]).
    pub fn oracle_memo_stats(&self) -> Option<(u64, u64)> {
        self.oracle.memo_stats()
    }
}

/// A complete simulation instance.
pub struct Simulator {
    pub cfg: SimConfig,
    pub design: Design,
    pub wl: Workload,
    /// Workload scale factor this instance was built at (recorded into
    /// trace headers so replays rebuild the same skeleton).
    pub scale: f64,
    cores: Vec<Core>,
    mem: MemSystem,
    data: DataModel,
    /// Next CTA id to dispatch.
    next_cta: u64,
    /// (core, group) slots awaiting a CTA.
    pub stats: SimStats,
    /// Chip-level flight recorder (no-op unless `telemetry_window` is
    /// set). Driven only between the run loop's phases on the drain
    /// thread, so the sharded workers never see it.
    telemetry: ChipRecorder,
}

// The sweep engine moves whole simulations onto worker threads, and the
// intra-sim shard loop moves individual cores across threads while they
// read the config/design/workload concurrently; these compile-time
// assertions keep both properties from regressing (any non-Send field — an
// `Rc`, a raw pointer, a non-Send oracle — fails here, not at a distant
// spawn site).
const _: () = {
    const fn assert_send<T: Send>() {}
    const fn assert_sync<T: Sync>() {}
    assert_send::<Simulator>();
    assert_send::<Core>();
    assert_sync::<SimConfig>();
    assert_sync::<Design>();
    assert_sync::<Workload>();
};

impl Simulator {
    /// Build with the default (memoized native) oracle.
    pub fn new(cfg: SimConfig, design: Design, app: &'static AppSpec, scale: f64) -> Simulator {
        Self::with_oracle(
            cfg,
            design,
            app,
            scale,
            Box::new(MemoOracle::new(NativeOracle)),
        )
    }

    /// Build with an explicit oracle backend (e.g. the PJRT oracle).
    pub fn with_oracle(
        cfg: SimConfig,
        design: Design,
        app: &'static AppSpec,
        scale: f64,
        oracle: Box<dyn CompressionOracle>,
    ) -> Simulator {
        let extra_regs = if design.mechanism == Mechanism::Caba {
            CABA_EXTRA_REGS
        } else {
            0
        };
        let wl = Workload::build_with_extra_regs(app, &cfg, scale, extra_regs);
        // Memo LUT geometry is workload-dependent: it is carved from the
        // shared memory the resident CTAs leave unallocated.
        let memo_geom = crate::memo::MemoGeometry::for_workload(&cfg, &design, &wl);
        let cores = (0..cfg.n_sms)
            .map(|i| Core::new(i, &cfg, &design, &memo_geom))
            .collect();
        let mem = MemSystem::new(&cfg, &design);
        let telemetry = ChipRecorder::new(cfg.telemetry_window, cfg.max_cycles, cfg.n_mcs);
        let mut sim = Simulator {
            cores,
            mem,
            data: DataModel::new(oracle, &wl.arrays),
            next_cta: 0,
            stats: SimStats::default(),
            telemetry,
            cfg,
            design,
            wl,
            scale,
        };
        // Recording requested through the configuration: attach now. The
        // config channel has no Result path, so a failure to open the
        // requested file is a panic — recording was asked for explicitly
        // and must not be dropped silently.
        if !sim.cfg.trace_record.is_empty() {
            let path = sim.cfg.trace_record.clone();
            if let Err(e) = sim.record_to(&path) {
                panic!("trace_record={path:?}: {e:#}");
            }
        }
        sim
    }

    /// Attach a trace recorder writing to `path` (call before [`run`]).
    /// The recorder captures every generated memory access and line
    /// payload; [`Simulator::run`] finalizes the file.
    ///
    /// [`run`]: Simulator::run
    pub fn record_to(&mut self, path: &str) -> Result<()> {
        match self.wl.source {
            // A second attachment would silently abandon the first file
            // half-written (header, no trailer).
            TraceRole::Record(_) => bail!(
                "a trace recorder is already attached (combined `trace record` \
                 with --set trace_record=...? pass one destination only)"
            ),
            // Overwriting the replay source would silently run synthetic
            // generation while claiming to replay the trace.
            TraceRole::Replay(_) => {
                bail!("cannot attach a recorder to a trace-driven simulator")
            }
            TraceRole::Synthetic => {}
        }
        let meta = TraceMeta {
            kind: TraceKind::Recorded,
            fingerprint: self.cfg.fingerprint(),
            seed: self.wl.seed,
            scale: self.scale,
            app: self.wl.spec.name.to_string(),
            regs_per_thread: self.wl.spec.regs_per_thread,
            threads_per_cta: self.wl.spec.threads_per_cta,
            smem_per_cta: self.wl.spec.smem_per_cta,
            total_ctas: self.wl.total_ctas,
            iters: self.wl.program.iters,
            arrays: self
                .wl
                .arrays
                .iter()
                .map(|a| (a.footprint_lines, PATTERN_FROM_SPEC))
                .collect(),
        };
        let rec = TraceRecorder::create(path, &meta)?;
        self.wl.source = TraceRole::Record(Arc::new(rec));
        Ok(())
    }

    /// Build a **trace-driven** simulator: the workload side is served
    /// from `tracedata` (see `crate::trace`) instead of the synthetic
    /// generators; design and configuration are free to differ from the
    /// recording run (trace-driven what-if exploration).
    pub fn from_trace(cfg: SimConfig, design: Design, tracedata: Arc<TraceData>) -> Result<Simulator> {
        Self::from_trace_with_oracle(cfg, design, tracedata, Box::new(MemoOracle::new(NativeOracle)))
    }

    /// [`Simulator::from_trace`] with an explicit oracle backend.
    pub fn from_trace_with_oracle(
        cfg: SimConfig,
        design: Design,
        tracedata: Arc<TraceData>,
        oracle: Box<dyn CompressionOracle>,
    ) -> Result<Simulator> {
        if !cfg.trace_record.is_empty() {
            // An explicit recording request must never vanish silently —
            // and a trace-driven run has nothing new to record (the trace
            // file already IS the recording).
            bail!(
                "trace_record={:?} is not supported for trace-driven runs",
                cfg.trace_record
            );
        }
        let extra_regs = if design.mechanism == Mechanism::Caba {
            CABA_EXTRA_REGS
        } else {
            0
        };
        let scale = tracedata.meta.scale;
        let wl = Workload::build_replay(&tracedata, &cfg, extra_regs)?;
        let memo_geom = crate::memo::MemoGeometry::for_workload(&cfg, &design, &wl);
        let cores = (0..cfg.n_sms)
            .map(|i| Core::new(i, &cfg, &design, &memo_geom))
            .collect();
        let mem = MemSystem::new(&cfg, &design);
        let telemetry = ChipRecorder::new(cfg.telemetry_window, cfg.max_cycles, cfg.n_mcs);
        Ok(Simulator {
            cores,
            mem,
            data: DataModel::new(oracle, &wl.arrays),
            next_cta: 0,
            stats: SimStats::default(),
            telemetry,
            cfg,
            design,
            wl,
            scale,
        })
    }

    /// Should this app run with compression at all? The paper disables
    /// CABA for apps the profiler finds incompressible / compute-bound
    /// (§6: "we rely on static profiling ... disable CABA-based
    /// compression for the others"); they see neither gain nor loss.
    pub fn compression_profitable(app: &AppSpec) -> bool {
        app.in_eval_set
    }

    /// Memoization counters (`(hits, misses)`) of this simulator's oracle,
    /// if the backend keeps any (see [`CompressionOracle::memo_stats`]).
    /// `caba bench` reports the hit rate from here.
    pub fn oracle_memo_stats(&self) -> Option<(u64, u64)> {
        self.data.oracle_memo_stats()
    }

    fn dispatch_ctas(&mut self) {
        let groups = self.wl.occ.ctas_per_sm as usize;
        for core in &mut self.cores {
            for g in 0..groups {
                if self.next_cta >= self.wl.total_ctas as u64 {
                    return;
                }
                if core.group_done(g, &self.wl) && core.warps[g * self.wl.occ.warps_per_cta as usize].uid == u64::MAX
                {
                    core.launch_cta(g, self.next_cta, &self.wl);
                    self.stats.ctas_launched += 1;
                    self.next_cta += 1;
                }
            }
        }
    }

    fn refill_ctas(&mut self) -> bool {
        if self.next_cta >= self.wl.total_ctas as u64 {
            return false;
        }
        let mut launched = false;
        for core in &mut self.cores {
            launched |= refill_core(
                core,
                &self.wl,
                &mut self.next_cta,
                &mut self.stats.ctas_launched,
            );
            if self.next_cta >= self.wl.total_ctas as u64 {
                break;
            }
        }
        launched
    }

    /// Worker-thread count this run will actually use. `strict_tick`
    /// forces the naive serial reference; recording forces serial too (the
    /// recorder's first-encounter emission order is part of the file
    /// format); otherwise `sim_threads`, clamped to `[1, n_sms]` — a
    /// worker beyond one-per-SM could only spin on the barrier.
    fn effective_threads(&self) -> usize {
        if self.cfg.strict_tick {
            return 1;
        }
        if matches!(self.wl.source, TraceRole::Record(_)) {
            return 1;
        }
        self.cfg.sim_threads.max(1).min(self.cores.len().max(1))
    }

    /// Run to completion (or the cycle/instruction budget) and return the
    /// collected statistics.
    ///
    /// The loop is **event-driven per core**: a core whose `next_event`
    /// lies in the future is skipped outright — its stall cycles are
    /// bulk-charged from its memoized per-scheduler classification when it
    /// next wakes ([`Core::settle_to`]) — and when *every* core is
    /// skippable, `now` jumps straight to the earliest `next_event`. The
    /// result is **bit-identical** to ticking every core every cycle
    /// (`strict_tick=true` forces exactly that reference path; the
    /// differential suite in `tests/strict_tick_differential.rs` pins the
    /// equivalence). The soundness argument — why `next_event` can never
    /// overshoot a state change and why the memoized classification holds
    /// across the whole skipped window — is the wake-source contract,
    /// DESIGN.md §3.
    ///
    /// With `sim_threads > 1` the core-local phase A of each cycle is
    /// additionally sharded across a scoped thread pool
    /// ([`Simulator::run_sharded`]); the shared-state drain stays serial
    /// and in SM order, which is why that too is bit-identical (the
    /// rendezvous contract, DESIGN.md §3).
    pub fn run(&mut self) -> SimStats {
        self.dispatch_ctas();
        let threads = self.effective_threads();
        let now = if threads > 1 {
            self.run_sharded(threads)
        } else {
            self.run_serial()
        };
        // Settle every core's outstanding skipped window so the issue
        // breakdown covers each of the `now` cycles exactly once per
        // scheduler slot — on any exit path, in either mode. With
        // telemetry on this also closes every pending per-core window,
        // and `finish_telemetry` the final partial tail.
        for core in &mut self.cores {
            core.settle_to(now, &self.cfg, &self.design);
            core.finish_telemetry(now);
        }
        if self.telemetry.enabled() {
            let snap = chip_snap(&self.mem, &self.stats);
            self.telemetry.finish(now, &snap);
        }
        // On a drained run every CTA was launched exactly once (dispatch or
        // refill) and retired — the launch counter must cover the workload.
        if self.stats.finished {
            debug_assert_eq!(
                self.stats.ctas_launched,
                self.wl.total_ctas as u64,
                "ctas_launched out of sync with total_ctas on a drained run"
            );
        }
        self.collect(now);
        // Seal an attached trace recorder (idempotent). A write failure is
        // fatal here — the user explicitly asked for the trace, and the
        // alternative is a silently unusable file.
        if let TraceRole::Record(rec) = &self.wl.source {
            match rec.finish(self.stats.finished) {
                Ok((a, p)) => {
                    self.stats.trace.accesses_recorded = a;
                    self.stats.trace.payloads_recorded = p;
                }
                Err(e) => panic!("trace recording failed: {e:#}"),
            }
        }
        self.stats.clone()
    }

    /// The single-thread run loop (also the `strict_tick` reference). Each
    /// iteration is one epoch: phase A over every due core, then the
    /// serial drain over *all* cores in SM order (a no-op for skipped
    /// cores), then refill/exit/fast-forward bookkeeping — the same
    /// sequence [`Simulator::run_sharded`] executes, minus the barrier.
    fn run_serial(&mut self) -> u64 {
        let strict = self.cfg.strict_tick;
        let mut now: u64 = 0;
        loop {
            let mut any_live = false;
            let mut min_next = u64::MAX;
            let mut retired_any = false;
            // Phase A: core-local work, shared state read-only.
            {
                let ctx = CoreCtx {
                    cfg: &self.cfg,
                    design: &self.design,
                    wl: &self.wl,
                };
                for core in &mut self.cores {
                    if !strict && core.next_event > now {
                        // Skipped: nothing on this core can change state
                        // before `next_event`; its liveness cache is valid
                        // and its stall slots are charged lazily on wake.
                        any_live |= core.live_cached();
                        min_next = min_next.min(core.next_event);
                        continue;
                    }
                    core.cycle(now, &ctx);
                    any_live |= core.live_cached();
                    retired_any |= core.take_warp_retired();
                    min_next = min_next.min(core.next_event);
                }
            }
            // Phase B: drain queued shared-state ops, SM order.
            {
                let mut ctx = DrainCtx {
                    cfg: &self.cfg,
                    design: &self.design,
                    wl: &self.wl,
                    mem: &mut self.mem,
                    data: &mut self.data,
                    stats: &mut self.stats,
                };
                for core in &mut self.cores {
                    core.drain(now, &mut ctx);
                }
            }
            // CTA-refill eligibility arises only on cycles where a warp
            // retired (group-done and slot-free flags change nowhere else),
            // so the scan is gated on that in event-driven mode; strict
            // mode scans unconditionally, pinning the equivalence of the
            // gating argument itself.
            let launched = if strict || retired_any {
                self.refill_ctas()
            } else {
                false
            };

            now += 1;
            // Flight recorder: a boundary `== now` closes with post-drain
            // state — exactly the "state at start of cycle now" contract.
            if self.telemetry.enabled() && self.telemetry.next_boundary() <= now {
                let snap = chip_snap(&self.mem, &self.stats);
                self.telemetry.advance_to(now, &snap);
            }
            let drained = !any_live && self.next_cta >= self.wl.total_ctas as u64;
            if drained || now >= self.cfg.max_cycles || self.stats.warp_insts >= self.cfg.max_warp_insts
            {
                self.stats.finished = drained;
                break;
            }
            // Fast-forward `now` when no core has anything to do before
            // `min_next` (the common case once per-core skipping makes the
            // per-iteration work proportional to *busy* cores only). The
            // jump is clamped to `max_cycles` so a budget-capped run stops
            // at exactly the cycle the strict path would.
            if !strict && !launched && min_next > now && min_next != u64::MAX {
                now = min_next.min(self.cfg.max_cycles);
                // Boundaries inside the skipped range close with the frozen
                // snapshot: no core executes (hence no drain runs) in
                // there, so the state at the jump IS each boundary's state.
                if self.telemetry.enabled() && self.telemetry.next_boundary() <= now {
                    let snap = chip_snap(&self.mem, &self.stats);
                    self.telemetry.advance_to(now, &snap);
                }
                if now >= self.cfg.max_cycles {
                    self.stats.finished = false;
                    break;
                }
            }
        }
        now
    }

    /// The sharded run loop: phase A fans out across `threads` persistent
    /// workers (this thread is participant 0), phase B and all epoch
    /// bookkeeping stay on this thread between two barrier crossings.
    ///
    /// Determinism does not depend on scheduling: workers only ever touch
    /// their own cores' local state plus read-only shared state, every
    /// cross-core reduction (`any_live`, `retired`, `min_next`) is
    /// commutative, and the only shared-state writer is the serial drain
    /// in SM order — identical to [`Simulator::run_serial`]'s sequence.
    fn run_sharded(&mut self, threads: usize) -> u64 {
        debug_assert!(threads > 1 && !self.cfg.strict_tick);
        let cores: Vec<Mutex<Core>> = std::mem::take(&mut self.cores)
            .into_iter()
            .map(Mutex::new)
            .collect();
        let n = cores.len();
        let barrier = SpinBarrier::new(threads);
        // Epoch clock, published by participant 0 before releasing the
        // workers into the next phase A.
        let now_shared = AtomicU64::new(0);
        let stop = AtomicBool::new(false);
        // Worker → main reduction flags for the current epoch (commutative
        // folds, so Relaxed stores suffice; the barrier orders them).
        let any_live_flag = AtomicBool::new(false);
        let retired_flag = AtomicBool::new(false);
        let min_next_shared = AtomicU64::new(u64::MAX);

        let cfg = &self.cfg;
        let design = &self.design;
        let wl = &self.wl;
        let mem = &mut self.mem;
        let data = &mut self.data;
        let stats = &mut self.stats;
        let next_cta = &mut self.next_cta;
        // Telemetry is driven only by participant 0 between the barriers
        // (the same thread that drains), never by the workers.
        let telem = &mut self.telemetry;
        let total_ctas = wl.total_ctas as u64;

        let final_now = std::thread::scope(|scope| {
            for t in 1..threads {
                let cores = &cores;
                let barrier = &barrier;
                let now_shared = &now_shared;
                let stop = &stop;
                let any_live_flag = &any_live_flag;
                let retired_flag = &retired_flag;
                let min_next_shared = &min_next_shared;
                scope.spawn(move || {
                    let ctx = CoreCtx { cfg, design, wl };
                    loop {
                        let now = now_shared.load(Ordering::Acquire);
                        let mut live = false;
                        let mut retired = false;
                        let mut min_next = u64::MAX;
                        for i in chunk_range(t, threads, n) {
                            // Uncontended by construction: each core is
                            // locked by exactly one participant per phase.
                            let mut core = cores[i].lock().unwrap();
                            if core.next_event > now {
                                live |= core.live_cached();
                                min_next = min_next.min(core.next_event);
                                continue;
                            }
                            core.cycle(now, &ctx);
                            live |= core.live_cached();
                            retired |= core.take_warp_retired();
                            min_next = min_next.min(core.next_event);
                        }
                        if live {
                            any_live_flag.store(true, Ordering::Relaxed);
                        }
                        if retired {
                            retired_flag.store(true, Ordering::Relaxed);
                        }
                        min_next_shared.fetch_min(min_next, Ordering::Relaxed);
                        barrier.wait(); // A: all phase-A chunks complete
                        barrier.wait(); // B: drain + epoch advance done
                        if stop.load(Ordering::Acquire) {
                            return;
                        }
                    }
                });
            }

            let mut now: u64 = 0;
            loop {
                let mut any_live = false;
                let mut retired_any = false;
                let mut min_next = u64::MAX;
                // Phase A for this thread's own chunk.
                {
                    let ctx = CoreCtx { cfg, design, wl };
                    for i in chunk_range(0, threads, n) {
                        let mut core = cores[i].lock().unwrap();
                        if core.next_event > now {
                            any_live |= core.live_cached();
                            min_next = min_next.min(core.next_event);
                            continue;
                        }
                        core.cycle(now, &ctx);
                        any_live |= core.live_cached();
                        retired_any |= core.take_warp_retired();
                        min_next = min_next.min(core.next_event);
                    }
                }
                barrier.wait(); // A: every worker's chunk is done
                any_live |= any_live_flag.swap(false, Ordering::Relaxed);
                retired_any |= retired_flag.swap(false, Ordering::Relaxed);
                min_next = min_next.min(min_next_shared.swap(u64::MAX, Ordering::Relaxed));

                // Phase B + bookkeeping, alone between the barriers: drain
                // in SM order, then refill in SM order (same sequence as
                // the serial loop).
                {
                    let mut dctx = DrainCtx {
                        cfg,
                        design,
                        wl,
                        mem: &mut *mem,
                        data: &mut *data,
                        stats: &mut *stats,
                    };
                    for c in cores.iter() {
                        c.lock().unwrap().drain(now, &mut dctx);
                    }
                }
                let launched = if retired_any && *next_cta < total_ctas {
                    let mut l = false;
                    for c in cores.iter() {
                        let mut core = c.lock().unwrap();
                        l |= refill_core(&mut core, wl, next_cta, &mut stats.ctas_launched);
                        if *next_cta >= total_ctas {
                            break;
                        }
                    }
                    l
                } else {
                    false
                };

                now += 1;
                // Flight recorder: same two call sites (and the same
                // boundary-state argument) as the serial loop.
                if telem.enabled() && telem.next_boundary() <= now {
                    let snap = chip_snap(&*mem, &*stats);
                    telem.advance_to(now, &snap);
                }
                let drained = !any_live && *next_cta >= total_ctas;
                if drained || now >= cfg.max_cycles || stats.warp_insts >= cfg.max_warp_insts {
                    stats.finished = drained;
                    break;
                }
                if !launched && min_next > now && min_next != u64::MAX {
                    now = min_next.min(cfg.max_cycles);
                    if telem.enabled() && telem.next_boundary() <= now {
                        let snap = chip_snap(&*mem, &*stats);
                        telem.advance_to(now, &snap);
                    }
                    if now >= cfg.max_cycles {
                        stats.finished = false;
                        break;
                    }
                }
                now_shared.store(now, Ordering::Release);
                barrier.wait(); // B: release workers into the next epoch
            }
            // Exit: workers are parked at barrier B; raise stop and cross
            // it once more so they observe it and return.
            stop.store(true, Ordering::Release);
            barrier.wait();
            now
        });

        self.cores = cores
            .into_iter()
            .map(|m| m.into_inner().unwrap())
            .collect();
        final_now
    }

    /// Everything the flight recorder captured, assembled per SM. `None`
    /// unless the run was configured with `telemetry_window > 0`. Call
    /// after [`Simulator::run`] — timelines are only final then.
    pub fn telemetry_run(&self) -> Option<TelemetryRun> {
        if !self.telemetry.enabled() {
            return None;
        }
        Some(TelemetryRun {
            window: self.telemetry.window(),
            cycles: self.stats.cycles,
            n_mcs: self.telemetry.n_mcs(),
            chip: self.telemetry.windows().to_vec(),
            chip_truncated: self.telemetry.truncated(),
            bus_overcommit_windows: self.telemetry.overcommit(),
            cores: self
                .cores
                .iter()
                .map(|c| CoreTimeline {
                    sm_id: c.sm_id,
                    windows: c.tl.windows().to_vec(),
                    truncated_windows: c.tl.truncated(),
                    spans: c.awc.spans.spans().to_vec(),
                    spans_dropped: c.awc.spans.dropped(),
                })
                .collect(),
        })
    }

    fn collect(&mut self, now: u64) {
        let s = &mut self.stats;
        s.cycles = now;
        for core in &self.cores {
            s.issue.active += core.issue.active;
            s.issue.compute_stall += core.issue.compute_stall;
            s.issue.memory_stall += core.issue.memory_stall;
            s.issue.data_stall += core.issue.data_stall;
            s.issue.idle += core.issue.idle;
            s.l1.accesses += core.l1.stats.accesses;
            s.l1.hits += core.l1.stats.hits;
            s.l1.misses += core.l1.stats.misses;
            s.caba.decompress_warps += core.awc.stats.decompress_warps;
            s.caba.compress_warps += core.awc.stats.compress_warps;
            s.caba.assist_insts_issued += core.awc.stats.assist_insts_issued;
            s.caba.assist_insts_idle_slots += core.awc.stats.assist_insts_idle_slots;
            s.caba.compress_skipped += core.awc.stats.compress_skipped;
            s.caba.throttled_deploys += core.awc.stats.throttled_deploys;
            s.caba.killed += core.awc.stats.killed;
            s.caba.prefetches_issued += core.awc.stats.prefetches_issued;
            s.caba.memo_lookups += core.awc.stats.memo_lookups;
            s.caba.memo_hits += core.awc.stats.memo_hits;
            s.caba.memo_alias_hits += core.awc.stats.memo_alias_hits;
            s.caba.memo_installs += core.awc.stats.memo_installs;
            s.caba.memo_evictions += core.awc.stats.memo_evictions;
            s.caba.memo_lookups_skipped += core.awc.stats.memo_lookups_skipped;
        }
        // The tentpole invariant of the event-driven tick: executed cycles
        // and bulk-settled windows together account every scheduler slot of
        // every cycle exactly once, in either tick mode.
        debug_assert_eq!(
            s.issue.total(),
            now * (self.cfg.schedulers_per_sm * self.cfg.n_sms) as u64,
            "issue accounting must cover cycles × schedulers × SMs exactly"
        );
        for d in &self.mem.dram {
            s.dram.reads += d.stats.reads;
            s.dram.writes += d.stats.writes;
            s.dram.row_hits += d.stats.row_hits;
            s.dram.row_misses += d.stats.row_misses;
            s.dram.bursts += d.stats.bursts;
            s.dram.bursts_uncompressed += d.stats.bursts_uncompressed;
            s.dram.bus_busy_cycles += d.stats.bus_busy_cycles;
            s.dram.md_accesses += d.stats.md_accesses;
        }
        for m in &self.mem.md {
            s.md.accesses += m.stats.accesses;
            s.md.hits += m.stats.hits;
        }
        s.icnt = self.mem.icnt.stats;
        // Energy events.
        s.energy_events.assist_insts = s.caba.assist_insts_issued;
        s.energy_events.l2_accesses = self.mem.l2_accesses;
        s.energy_events.icnt_flits = s.icnt.flits_fwd + s.icnt.flits_back;
        s.energy_events.dram_bursts = s.dram.bursts;
        s.energy_events.dram_activates = s.dram.row_misses;
        s.energy_events.md_cache_accesses = s.md.accesses;
        s.energy_events.hw_compressor_ops += self.mem.hw_compressor_ops;
    }
}

/// Assemble the chip-side counter snapshot the [`ChipRecorder`] samples at
/// window boundaries. Free function (not a method) so the sharded loop,
/// which holds `mem`/`stats` as disjoint field borrows, can call it too.
/// Every summand is shared-side state written only by the serial drain, so
/// its value at any given cycle boundary is identical across tick modes.
fn chip_snap(mem: &MemSystem, stats: &SimStats) -> ChipSnap {
    let mut bursts = 0;
    let mut bursts_uncompressed = 0;
    let mut md_accesses = 0;
    let mut bus_busy_cycles = 0.0;
    for d in &mem.dram {
        bursts += d.stats.bursts;
        bursts_uncompressed += d.stats.bursts_uncompressed;
        md_accesses += d.stats.md_accesses;
        bus_busy_cycles += d.stats.bus_busy_cycles;
    }
    ChipSnap {
        warp_insts: stats.warp_insts,
        bursts,
        bursts_uncompressed,
        md_accesses,
        bus_busy_cycles,
        l2: stats.l2,
        flits: mem.icnt.stats.flits_fwd + mem.icnt.stats.flits_back,
    }
}

/// Refill scan for one core, shared by the serial loop
/// ([`Simulator::refill_ctas`]) and the sharded loop (which holds its cores
/// behind mutexes and so cannot call a `&mut self` method). CTA ids are
/// handed out greedily in SM order either way — the sequence of
/// `launch_cta` calls is identical.
fn refill_core(core: &mut Core, wl: &Workload, next_cta: &mut u64, ctas_launched: &mut u64) -> bool {
    let groups = wl.occ.ctas_per_sm as usize;
    let wpc = wl.occ.warps_per_cta as usize;
    let mut launched = false;
    for g in 0..groups {
        if *next_cta >= wl.total_ctas as u64 {
            return launched;
        }
        let base = g * wpc;
        let slot_free = core.warps[base].uid == u64::MAX
            || core.warps[base..base + wpc].iter().all(|w| w.done);
        if slot_free && core.group_done(g, wl) {
            core.launch_cta(g, *next_cta, wl);
            *ctas_launched += 1;
            *next_cta += 1;
            launched = true;
        }
    }
    launched
}

/// Contiguous chunk of core indices owned by participant `t` of `threads`
/// (the first `n % threads` participants take one extra core).
fn chunk_range(t: usize, threads: usize, n: usize) -> std::ops::Range<usize> {
    let per = n / threads;
    let rem = n % threads;
    let lo = t * per + t.min(rem);
    let hi = lo + per + usize::from(t < rem);
    lo..hi
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;

    fn tiny_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.n_sms = 2;
        c.max_cycles = 200_000;
        c
    }

    #[test]
    fn base_run_completes_and_counts() {
        let app = apps::find("SLA").unwrap();
        let mut sim = Simulator::new(tiny_cfg(), Design::base(), app, 0.02);
        let stats = sim.run();
        assert!(stats.finished, "run did not drain");
        assert_eq!(stats.ctas_launched, sim.wl.total_ctas as u64);
        assert!(stats.warp_insts > 1000);
        assert!(stats.cycles > 100);
        assert!(stats.ipc() > 0.0);
        // Issue accounting covers every scheduler slot (fast-forward
        // included).
        assert_eq!(
            stats.issue.total(),
            stats.cycles * 2 * 2, // n_sms × schedulers
        );
        assert_eq!(stats.dram.compression_ratio(), 1.0);
    }

    #[test]
    fn caba_reduces_dram_bursts_on_compressible_app() {
        let app = apps::find("PVC").unwrap(); // LowDynRange: very compressible
        let base = Simulator::new(tiny_cfg(), Design::base(), app, 0.02).run();
        let caba = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
        assert!(caba.finished && base.finished);
        assert!(
            caba.dram.compression_ratio() > 1.5,
            "ratio={}",
            caba.dram.compression_ratio()
        );
        assert!(caba.caba.decompress_warps > 0);
    }

    #[test]
    fn strict_tick_matches_event_driven_tick() {
        // The full app×design differential lives in
        // tests/strict_tick_differential.rs; this is the one-pair smoke
        // version kept next to the run loop it guards.
        let app = apps::find("PVC").unwrap();
        let event = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
        let mut strict_cfg = tiny_cfg();
        strict_cfg.strict_tick = true;
        let strict = Simulator::new(strict_cfg, Design::caba(Algo::Bdi), app, 0.02).run();
        assert_eq!(event.cycles, strict.cycles);
        assert_eq!(event.warp_insts, strict.warp_insts);
        // Not just the totals: the bulk-charged stall classification must
        // reproduce the per-cycle taxonomy category for category.
        assert_eq!(event.issue, strict.issue);
        assert_eq!(event.memory_signature(), strict.memory_signature());
    }

    #[test]
    fn sharded_matches_serial_smoke() {
        // The full three-way strict × serial × sharded matrix lives in
        // tests/strict_tick_differential.rs; this is the one-pair smoke
        // version kept next to the run loop it guards.
        let app = apps::find("PVC").unwrap();
        let serial = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
        let mut sharded_cfg = tiny_cfg();
        sharded_cfg.sim_threads = 2;
        let sharded = Simulator::new(sharded_cfg, Design::caba(Algo::Bdi), app, 0.02).run();
        assert_eq!(sharded.cycles, serial.cycles);
        assert_eq!(sharded.warp_insts, serial.warp_insts);
        assert_eq!(sharded.issue, serial.issue);
        assert_eq!(sharded.memory_signature(), serial.memory_signature());
    }

    #[test]
    fn chunk_range_partitions_exactly() {
        for threads in 1..=9usize {
            for n in [0usize, 1, 2, 5, 8, 15, 16, 33] {
                let mut covered = Vec::new();
                for t in 0..threads {
                    covered.extend(chunk_range(t, threads, n));
                }
                assert_eq!(covered, (0..n).collect::<Vec<_>>(), "threads={threads} n={n}");
            }
        }
    }

    #[test]
    fn effective_threads_clamps_and_gates() {
        let app = apps::find("SLA").unwrap();
        let mut cfg = tiny_cfg(); // n_sms = 2
        cfg.sim_threads = 8;
        let sim = Simulator::new(cfg, Design::base(), app, 0.01);
        assert_eq!(sim.effective_threads(), 2, "clamped to n_sms");
        let mut cfg = tiny_cfg();
        cfg.sim_threads = 8;
        cfg.strict_tick = true;
        let sim = Simulator::new(cfg, Design::base(), app, 0.01);
        assert_eq!(sim.effective_threads(), 1, "strict_tick forces serial");
        let mut cfg = tiny_cfg();
        cfg.sim_threads = 0;
        let sim = Simulator::new(cfg, Design::base(), app, 0.01);
        assert_eq!(sim.effective_threads(), 1, "0 normalizes to 1");
    }

    #[test]
    fn deterministic_across_runs() {
        let app = apps::find("MM").unwrap();
        let a = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.01).run();
        let b = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.01).run();
        assert_eq!(a.cycles, b.cycles);
        assert_eq!(a.warp_insts, b.warp_insts);
        assert_eq!(a.dram.bursts, b.dram.bursts);
    }

    #[test]
    fn telemetry_is_observation_only_and_windows_tile_the_run() {
        // The observation-only contract: turning the flight recorder on
        // must leave every simulation statistic bit-identical (and the
        // config fingerprint unchanged — pinned in config::tests).
        let app = apps::find("PVC").unwrap();
        let mut off_sim = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.02);
        let off = off_sim.run();
        assert!(off_sim.telemetry_run().is_none(), "recorder off by default");

        let mut cfg = tiny_cfg();
        cfg.telemetry_window = 512;
        let mut sim = Simulator::new(cfg, Design::caba(Algo::Bdi), app, 0.02);
        let on = sim.run();
        assert_eq!(on, off, "telemetry perturbed the simulation");

        let run = sim.telemetry_run().unwrap();
        assert_eq!(run.window, 512);
        assert_eq!(run.cycles, on.cycles);
        assert_eq!(run.cores.len(), 2);
        // The chip windows tile the run exactly: full windows plus one
        // partial tail, covering every cycle once.
        assert_eq!(run.chip_truncated, 0);
        let covered: u64 = run.chip.iter().map(|w| w.cycles).sum();
        assert_eq!(covered, on.cycles);
        // Deltas sum back to the run totals.
        let wi: u64 = run.chip.iter().map(|w| w.warp_insts).sum();
        assert_eq!(wi, on.warp_insts);
        let l2: u64 = run.chip.iter().map(|w| w.l2.accesses).sum();
        assert_eq!(l2, on.l2.accesses);
        let bursts: u64 = run.chip.iter().map(|w| w.bursts).sum();
        assert_eq!(bursts, on.dram.bursts);
        // Per-core issue deltas, summed over cores and windows, must equal
        // the aggregate breakdown (every scheduler slot in some window).
        let issue_total: u64 = run
            .cores
            .iter()
            .flat_map(|c| c.windows.iter())
            .map(|w| w.issue.total())
            .sum();
        assert_eq!(issue_total, on.issue.total());
        // Every per-core timeline has the same shape as the chip's.
        for c in &run.cores {
            assert_eq!(c.windows.len(), run.chip.len(), "SM {}", c.sm_id);
        }
        // A CABA run on a compressible app deploys assist warps, so the
        // span log is non-empty and spans are well-formed.
        assert!(run.span_count() > 0);
        for s in run.cores.iter().flat_map(|c| c.spans.iter()) {
            if s.first_issue != u64::MAX {
                assert!(s.first_issue >= s.trigger_at);
            }
            if s.end != u64::MAX {
                assert!(s.end >= s.trigger_at);
            }
        }
    }

    #[test]
    fn warm_verdicts_matches_individual_lookups() {
        // Batched (one analyze() call) and per-line verdict computation
        // must agree — the batching is purely a throughput device.
        let app = apps::find("PVC").unwrap();
        let cfg = tiny_cfg();
        let wl = Workload::build(app, &cfg, 0.01);
        let mut warmed = DataModel::new(Box::new(MemoOracle::new(NativeOracle)), &wl.arrays);
        let mut lazy = DataModel::new(Box::new(MemoOracle::new(NativeOracle)), &wl.arrays);
        let lines: Vec<u64> = (0..16).map(|i| wl.arrays[0].base_line + i).collect();
        warmed.warm_verdicts(&wl, Algo::Bdi, &lines);
        for &l in &lines {
            assert_eq!(
                warmed.verdict(&wl, Algo::Bdi, l),
                lazy.verdict(&wl, Algo::Bdi, l),
                "line {l}"
            );
        }
        // Epoch bumps invalidate warmed entries like any other.
        warmed.bump_epoch(lines[0]);
        lazy.bump_epoch(lines[0]);
        warmed.warm_verdicts(&wl, Algo::Bdi, &lines);
        assert_eq!(
            warmed.verdict(&wl, Algo::Bdi, lines[0]),
            lazy.verdict(&wl, Algo::Bdi, lines[0])
        );
    }

    #[test]
    fn incompressible_app_unaffected_by_compression() {
        // Paper §6: the profiler disables CABA for incompressible apps, so
        // they run the Base design and see no degradation at all. Forcing
        // CABA on anyway (below) must still keep the overhead bounded —
        // the cost is occupancy (assist-warp registers) plus assist-warp
        // issue slots, which throttling contains.
        let app = apps::find("SCP").unwrap(); // Random data
        assert!(!Simulator::compression_profitable(app));
        let base = Simulator::new(tiny_cfg(), Design::base(), app, 0.02).run();
        let caba = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.02).run();
        let ratio = caba.dram.compression_ratio();
        assert!(ratio < 1.1, "random data must not compress: {ratio}");
        let slowdown = base.ipc() / caba.ipc();
        assert!(slowdown < 1.35, "forced-CABA slowdown too large: {slowdown}");
    }
}
