//! Plain-text trace import (accelsim-style `op addr size [mask]` dumps).
//!
//! ## Text format
//!
//! One access per line; `#`-prefixed lines and blank lines are ignored.
//! Fields are separated by commas and/or whitespace:
//!
//! ```text
//! ld 0x7f2a00  128  0xffffffff
//! st,0x7f2a80,64
//! ```
//!
//! * field 1 — `ld`/`load` or `st`/`store` (case-insensitive);
//! * field 2 — byte address, hex (`0x…`) or decimal;
//! * field 3 — access size in bytes (> 0); the access covers every
//!   128-byte line the byte range `[addr, addr+size)` touches, capped at
//!   32 lines (one line per lane);
//! * field 4 — optional active-lane mask, accepted and ignored (the
//!   simulator's timing quantum is the cache line, not the lane).
//!
//! ## Mapping onto the simulator
//!
//! The importer synthesizes a μ-kernel whose loop body is
//! `ld; ialu; ialu; st` (loads at body slot 0, stores at slot 3) and lays
//! the records out round-robin: the *i*-th load in the file becomes warp
//! `i mod W`, iteration `i div W` (likewise for stores), where `W` is
//! chosen so each warp runs ~32 iterations. Addresses are rebased into one
//! array whose footprint spans the dump; line payloads are synthesized
//! from the import-assigned data pattern (`--pattern`, default `random`),
//! since text dumps carry no data bytes.

use super::record::encode_in_memory;
use super::replay::TraceData;
use super::{content_digest, pattern_code_by_name, TraceKind, TraceMeta, PATTERN_NAMES};
use crate::isa::{AccessKind, Inst, MemAccess, Op, Program, NO_REG};
use anyhow::{bail, Context, Result};
use std::sync::Arc;

/// Body slot of the imported kernel's load / store instruction.
pub const LOAD_SLOT: u32 = 0;
pub const STORE_SLOT: u32 = 3;

/// Occupancy geometry of the synthesized kernel (256 threads → 8 warps
/// per CTA, modest register pressure).
pub const IMPORT_REGS_PER_THREAD: u32 = 16;
pub const IMPORT_THREADS_PER_CTA: u32 = 256;
const WARPS_PER_CTA: u64 = (IMPORT_THREADS_PER_CTA / 32) as u64;
/// Target iterations per warp when choosing the warp count.
const ITERS_TARGET: u64 = 32;
/// A warp has 32 lanes — one distinct line each at most.
const MAX_LINES_PER_ACCESS: u64 = 32;
/// 128-byte lines.
const LINE_SHIFT: u32 = 7;

/// The fixed loop body every imported trace replays: one load (slot 0),
/// two dependent ALU ops, one store (slot 3).
pub fn trace_program(iters: u32) -> Program {
    let mem = MemAccess { array: 0, kind: AccessKind::Coalesced { reuse: 1 } };
    Program {
        body: vec![
            Inst::new(Op::Ld(mem), 1, [0, NO_REG]),
            Inst::new(Op::IAlu, 2, [1, 0]),
            Inst::new(Op::IAlu, 3, [2, 1]),
            Inst::new(Op::St(mem), NO_REG, [3, NO_REG]),
        ],
        iters,
    }
}

/// One parsed text record: (is_store, byte address, size in bytes).
pub fn parse_text(text: &str) -> Result<Vec<(bool, u64, u64)>> {
    let mut recs = Vec::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> =
            line.split(|c: char| c == ',' || c.is_whitespace()).filter(|f| !f.is_empty()).collect();
        if fields.len() < 3 || fields.len() > 4 {
            bail!("line {}: expected `op addr size [mask]`, got {raw:?}", lineno + 1);
        }
        let is_store = match fields[0].to_ascii_lowercase().as_str() {
            "ld" | "load" => false,
            "st" | "store" => true,
            op => bail!("line {}: unknown op {op:?} (ld|st)", lineno + 1),
        };
        let addr = parse_num(fields[1])
            .map_err(|e| e.context(format!("line {}: bad address", lineno + 1)))?;
        let size = parse_num(fields[2])
            .map_err(|e| e.context(format!("line {}: bad size", lineno + 1)))?;
        if size == 0 {
            bail!("line {}: zero-size access", lineno + 1);
        }
        if addr.checked_add(size).is_none() {
            bail!("line {}: address range {addr:#x}+{size} overflows", lineno + 1);
        }
        if fields.len() == 4 {
            parse_num(fields[3])
                .map_err(|e| e.context(format!("line {}: bad mask", lineno + 1)))?;
        }
        recs.push((is_store, addr, size));
    }
    Ok(recs)
}

fn parse_num(s: &str) -> Result<u64> {
    let v = if let Some(hex) = s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        u64::from_str_radix(hex, 16)
    } else {
        s.parse()
    };
    Ok(v.with_context(|| format!("not a number: {s:?}"))?)
}

/// Convert a text dump into `.cabatrace` file bytes.
pub fn import_text(text: &str, pattern_code: u8) -> Result<Vec<u8>> {
    super::pattern_by_code(pattern_code)
        .with_context(|| format!("unknown data-pattern code {pattern_code}"))?;
    let recs = parse_text(text)?;
    if recs.is_empty() {
        bail!("empty trace: no ld/st records found");
    }

    // Line spans, and the rebase window.
    let mut spans = Vec::with_capacity(recs.len());
    let (mut min_line, mut max_line) = (u64::MAX, 0u64);
    let (mut n_loads, mut n_stores) = (0u64, 0u64);
    for &(is_store, addr, size) in &recs {
        let first = addr >> LINE_SHIFT;
        let last = (addr + size - 1) >> LINE_SHIFT;
        let n = (last - first + 1).min(MAX_LINES_PER_ACCESS);
        min_line = min_line.min(first);
        max_line = max_line.max(first + n - 1);
        if is_store {
            n_stores += 1;
        } else {
            n_loads += 1;
        }
        spans.push((is_store, first, n));
    }
    let footprint = max_line - min_line + 1;

    // Round-robin layout: enough warps that each runs ~ITERS_TARGET
    // iterations of the ld/st body.
    let peak = n_loads.max(n_stores);
    let warps_needed = peak.div_ceil(ITERS_TARGET).max(1);
    let total_ctas = warps_needed.div_ceil(WARPS_PER_CTA).max(1);
    if total_ctas > u32::MAX as u64 {
        bail!("trace too large: {total_ctas} CTAs");
    }
    let total_warps = total_ctas * WARPS_PER_CTA;
    let iters = peak.div_ceil(total_warps).max(1);

    let base = crate::workload::ARRAY_STRIDE;
    let mut accesses = Vec::with_capacity(spans.len());
    let (mut li, mut si) = (0u64, 0u64);
    for (is_store, first, n) in spans {
        let (idx, slot) = if is_store {
            si += 1;
            (si - 1, STORE_SLOT)
        } else {
            li += 1;
            (li - 1, LOAD_SLOT)
        };
        let uid = idx % total_warps;
        let iter = (idx / total_warps) as u32;
        let lines: Vec<u64> = (0..n).map(|j| base + (first - min_line) + j).collect();
        accesses.push((uid, iter, slot, is_store, lines));
    }

    let meta = TraceMeta {
        kind: TraceKind::Imported,
        fingerprint: 0,
        // Deterministic per input: the payload generators key off this.
        seed: content_digest(text.as_bytes()),
        scale: 1.0,
        app: "TRACE".into(),
        regs_per_thread: IMPORT_REGS_PER_THREAD,
        threads_per_cta: IMPORT_THREADS_PER_CTA,
        smem_per_cta: 0,
        total_ctas: total_ctas as u32,
        iters: iters as u32,
        arrays: vec![(footprint, pattern_code)],
    };
    encode_in_memory(&meta, &accesses, &[])
}

/// Import a text dump file, write the binary trace, and load it back.
pub fn import_file(input: &str, out: &str, pattern_name: &str) -> Result<Arc<TraceData>> {
    let code = pattern_code_by_name(pattern_name).with_context(|| {
        let names: Vec<&str> = PATTERN_NAMES.iter().map(|&(n, _)| n).collect();
        format!("unknown --pattern {pattern_name:?}; one of {}", names.join("|"))
    })?;
    let text =
        std::fs::read_to_string(input).with_context(|| format!("read text trace {input:?}"))?;
    let bytes = import_text(&text, code)?;
    std::fs::write(out, &bytes).with_context(|| format!("write trace file {out:?}"))?;
    TraceData::from_bytes(&bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# demo dump
ld 0x1000 128 0xffffffff
st,0x2000,256
LOAD 4096 4
ld 0x1000 128
";

    #[test]
    fn parse_accepts_both_separators_and_case() {
        let recs = parse_text(SAMPLE).unwrap();
        assert_eq!(recs.len(), 4);
        assert_eq!(recs[0], (false, 0x1000, 128));
        assert_eq!(recs[1], (true, 0x2000, 256));
        assert_eq!(recs[2], (false, 4096, 4));
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_text("ld").is_err());
        assert!(parse_text("mov 0x10 4").is_err());
        assert!(parse_text("ld 0x10 0").is_err());
        assert!(parse_text("ld zzz 4").is_err());
        assert!(parse_text("ld 1 2 3 4 5").is_err());
        // addr+size overflowing u64 is a parse error, not a panic.
        assert!(parse_text("ld 0xffffffffffffffc0 128").is_err());
    }

    #[test]
    fn import_roundtrip_and_layout() {
        let bytes = import_text(SAMPLE, 0).unwrap();
        assert_eq!(import_text(SAMPLE, 0).unwrap(), bytes, "import not deterministic");
        let t = TraceData::from_bytes(&bytes).unwrap();
        assert_eq!(t.meta.kind, TraceKind::Imported);
        assert_eq!(t.n_loads, 3);
        assert_eq!(t.n_stores, 1);
        // st 0x2000+256 covers two lines; the rest one each.
        assert_eq!(t.total_lines, 5);
        let mut out = Vec::new();
        // First load lands on warp 0 iter 0 slot LOAD_SLOT, rebased to the
        // array base (min line is 4096>>7 = 32 from the `LOAD 4096` row).
        t.access_into(0, 0, LOAD_SLOT as usize, &mut out);
        assert_eq!(out, vec![crate::workload::ARRAY_STRIDE + (0x1000 >> 7) - 32]);
        // First store: warp 0 iter 0 slot STORE_SLOT, two lines.
        t.access_into(0, 0, STORE_SLOT as usize, &mut out);
        assert_eq!(out.len(), 2);
        // Ragged tail: missing positions are empty, not panics.
        t.access_into(1, 0, STORE_SLOT as usize, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn wide_access_caps_at_warp_lanes() {
        let bytes = import_text("ld 0 65536", 0).unwrap();
        let t = TraceData::from_bytes(&bytes).unwrap();
        assert_eq!(t.total_lines, 32);
    }

    #[test]
    fn program_shape_matches_slots() {
        let p = trace_program(5);
        assert_eq!(p.iters, 5);
        assert!(matches!(p.body[LOAD_SLOT as usize].op, Op::Ld(_)));
        assert!(matches!(p.body[STORE_SLOT as usize].op, Op::St(_)));
        assert_eq!(p.body.len(), 4);
    }
}
