//! Trace recording: a streaming, chunked encoder plus the thread-safe
//! [`TraceRecorder`] the workload layer attaches to a live simulation.
//!
//! The recorder is *non-invasive*: it observes the address/payload streams
//! the generators produce and never feeds anything back, so a recording
//! run's simulation results (timing, caches, DRAM — everything except the
//! `SimStats::trace` capture counters themselves) are bit-identical to an
//! unrecorded run's. Records are
//! deduplicated by key — `(warp uid, iteration, body slot)` for accesses,
//! `(line, epoch)` for payloads — because the simulator may legitimately
//! evaluate the same access function twice (e.g. the §8.2 stride
//! prefetcher recomputes a future demand access).

use super::codec::{put_varint, put_zigzag, rle_encode_line};
use super::TraceMeta;
use crate::compress::Line;
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
use std::fs::File;
use std::io::{self, BufWriter, Write};
use std::sync::Mutex;

/// Flush an access/payload chunk once its record buffer reaches this size
/// (streaming writes: memory stays bounded by the dedup sets, not the
/// encoded stream).
const CHUNK_FLUSH_BYTES: usize = 48 * 1024;

/// The streaming trace encoder. Generic over the sink so the recorder can
/// stream to a file while tests and the text importer encode into memory.
pub struct Encoder<W: Write> {
    w: W,
    a_buf: Vec<u8>,
    a_count: u64,
    prev_uid: u64,
    prev_iter: u32,
    prev_first_line: u64,
    p_buf: Vec<u8>,
    p_count: u64,
    prev_p_line: u64,
    payload_ids: HashMap<Line, u32>,
    n_access: u64,
    n_payload: u64,
    n_defs: u64,
    first_cycle: u64,
    last_cycle: u64,
    complete: bool,
}

impl<W: Write> Encoder<W> {
    /// Write the header and return a ready encoder.
    pub fn new(mut w: W, meta: &TraceMeta) -> io::Result<Encoder<W>> {
        let mut head = Vec::new();
        meta.write(&mut head);
        w.write_all(&head)?;
        Ok(Encoder {
            w,
            a_buf: Vec::new(),
            a_count: 0,
            prev_uid: 0,
            prev_iter: 0,
            prev_first_line: 0,
            p_buf: Vec::new(),
            p_count: 0,
            prev_p_line: 0,
            payload_ids: HashMap::new(),
            n_access: 0,
            n_payload: 0,
            n_defs: 0,
            first_cycle: u64::MAX,
            last_cycle: 0,
            complete: true,
        })
    }

    /// Mark whether the recorded run drained (`SimStats::finished`). A
    /// trace of a truncated run (cycle/instruction budget hit) covers only
    /// a prefix of the workload; the replayer relaxes its miss handling
    /// for such traces instead of treating gaps as corruption.
    pub fn set_complete(&mut self, complete: bool) {
        self.complete = complete;
    }

    /// Append one access record (caller has already deduplicated by key).
    pub fn access(
        &mut self,
        uid: u64,
        iter: u32,
        slot: u32,
        is_store: bool,
        lines: &[u64],
    ) -> io::Result<()> {
        put_zigzag(&mut self.a_buf, (uid as i64).wrapping_sub(self.prev_uid as i64));
        put_zigzag(&mut self.a_buf, iter as i64 - self.prev_iter as i64);
        put_varint(&mut self.a_buf, slot as u64);
        self.a_buf.push(is_store as u8);
        put_varint(&mut self.a_buf, lines.len() as u64);
        let mut prev = self.prev_first_line;
        for (i, &l) in lines.iter().enumerate() {
            put_zigzag(&mut self.a_buf, (l as i64).wrapping_sub(prev as i64));
            if i == 0 {
                self.prev_first_line = l;
            }
            prev = l;
        }
        self.prev_uid = uid;
        self.prev_iter = iter;
        self.a_count += 1;
        self.n_access += 1;
        if self.a_buf.len() >= CHUNK_FLUSH_BYTES {
            self.flush_chunk(super::TAG_ACCESS)?;
        }
        Ok(())
    }

    /// Append one payload entry; identical line images become references.
    pub fn payload(&mut self, line: u64, epoch: u32, data: &Line) -> io::Result<()> {
        put_zigzag(&mut self.p_buf, (line as i64).wrapping_sub(self.prev_p_line as i64));
        self.prev_p_line = line;
        put_varint(&mut self.p_buf, epoch as u64);
        match self.payload_ids.get(data) {
            Some(&id) => put_varint(&mut self.p_buf, id as u64 + 1),
            None => {
                let id = self.payload_ids.len() as u32;
                self.payload_ids.insert(*data, id);
                put_varint(&mut self.p_buf, 0);
                rle_encode_line(data, &mut self.p_buf);
                self.n_defs += 1;
            }
        }
        self.p_count += 1;
        self.n_payload += 1;
        if self.p_buf.len() >= CHUNK_FLUSH_BYTES {
            self.flush_chunk(super::TAG_PAYLOAD)?;
        }
        Ok(())
    }

    /// Note an issue cycle (trace-info timestamp span only).
    pub fn note_cycle(&mut self, now: u64) {
        self.first_cycle = self.first_cycle.min(now);
        self.last_cycle = self.last_cycle.max(now);
    }

    /// (access records, payload entries) emitted so far.
    pub fn counts(&self) -> (u64, u64) {
        (self.n_access, self.n_payload)
    }

    fn flush_chunk(&mut self, tag: u8) -> io::Result<()> {
        let (buf, count) = match tag {
            super::TAG_ACCESS => (&mut self.a_buf, &mut self.a_count),
            _ => (&mut self.p_buf, &mut self.p_count),
        };
        if buf.is_empty() {
            return Ok(());
        }
        let mut head = vec![tag];
        put_varint(&mut head, buf.len() as u64);
        put_varint(&mut head, *count);
        self.w.write_all(&head)?;
        self.w.write_all(buf)?;
        buf.clear();
        *count = 0;
        Ok(())
    }

    /// Flush pending chunks, write the trailer, and hand the sink back.
    pub fn finish(mut self) -> io::Result<W> {
        self.flush_chunk(super::TAG_ACCESS)?;
        self.flush_chunk(super::TAG_PAYLOAD)?;
        let mut tail = vec![super::TAG_TRAILER];
        let flags = u64::from(self.complete);
        for v in [
            self.n_access,
            self.n_payload,
            self.n_defs,
            self.first_cycle,
            self.last_cycle,
            flags,
        ] {
            tail.extend_from_slice(&v.to_le_bytes());
        }
        self.w.write_all(&tail)?;
        self.w.flush()?;
        Ok(self.w)
    }
}

/// Encode a complete trace into memory — the importer's and the property
/// tests' entry point (the recorder streams to a file instead).
pub fn encode_in_memory(
    meta: &TraceMeta,
    accesses: &[(u64, u32, u32, bool, Vec<u64>)],
    payloads: &[(u64, u32, Line)],
) -> Result<Vec<u8>> {
    let mut enc = Encoder::new(Vec::new(), meta).context("encode trace header")?;
    for &(uid, iter, slot, is_store, ref lines) in accesses {
        enc.access(uid, iter, slot, is_store, lines)?;
    }
    for &(line, epoch, ref data) in payloads {
        enc.payload(line, epoch, data)?;
    }
    Ok(enc.finish()?)
}

struct RecInner {
    enc: Option<Encoder<BufWriter<File>>>,
    seen_access: HashSet<(u64, u32, u32)>,
    seen_payload: HashSet<(u64, u32)>,
    /// First write error, latched; reported by [`TraceRecorder::finish`].
    err: Option<String>,
    /// Counts captured at finish time (the encoder is gone afterwards).
    final_counts: Option<(u64, u64)>,
}

/// Thread-safe streaming recorder, attached to a [`crate::workload::
/// Workload`] via `TraceRole::Record`. All methods are `&self` (the
/// workload is shared immutably across the cycle loop); a mutex serializes
/// the encoder. Write errors are latched and surface at `finish()` — the
/// simulation itself is never perturbed mid-run.
pub struct TraceRecorder {
    inner: Mutex<RecInner>,
}

impl TraceRecorder {
    /// Create the output file and write the header.
    pub fn create(path: &str, meta: &TraceMeta) -> Result<TraceRecorder> {
        let f = File::create(path).with_context(|| format!("create trace file {path:?}"))?;
        let enc = Encoder::new(BufWriter::new(f), meta)
            .with_context(|| format!("write trace header to {path:?}"))?;
        Ok(TraceRecorder {
            inner: Mutex::new(RecInner {
                enc: Some(enc),
                seen_access: HashSet::new(),
                seen_payload: HashSet::new(),
                err: None,
                final_counts: None,
            }),
        })
    }

    /// Record one warp-level access (first sighting of its key wins).
    pub fn record_access(&self, uid: u64, iter: u32, slot: usize, is_store: bool, lines: &[u64]) {
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        let Some(enc) = g.enc.as_mut() else { return };
        if !g.seen_access.insert((uid, iter, slot as u32)) {
            return;
        }
        if let Err(e) = enc.access(uid, iter, slot as u32, is_store, lines) {
            g.err = Some(e.to_string());
            g.enc = None;
        }
    }

    /// Record one generated line payload (first sighting of (line, epoch)).
    pub fn record_payload(&self, line: u64, epoch: u32, data: &Line) {
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        let Some(enc) = g.enc.as_mut() else { return };
        if !g.seen_payload.insert((line, epoch)) {
            return;
        }
        if let Err(e) = enc.payload(line, epoch, data) {
            g.err = Some(e.to_string());
            g.enc = None;
        }
    }

    /// Note a memory-instruction issue cycle (trace-info span).
    pub fn note_cycle(&self, now: u64) {
        let mut guard = self.inner.lock().unwrap();
        if let Some(enc) = guard.enc.as_mut() {
            enc.note_cycle(now);
        }
    }

    /// Flush everything and seal the file. `complete` records whether the
    /// simulated run drained (`SimStats::finished`). Idempotent; returns
    /// the final (access, payload) counts, or the latched write error.
    pub fn finish(&self, complete: bool) -> Result<(u64, u64)> {
        let mut guard = self.inner.lock().unwrap();
        let g = &mut *guard;
        if let Some(e) = g.err.take() {
            g.enc = None;
            bail!("trace write failed mid-run: {e}");
        }
        if let Some(mut enc) = g.enc.take() {
            let counts = enc.counts();
            enc.set_complete(complete);
            enc.finish().context("finalize trace file")?;
            g.final_counts = Some(counts);
        }
        g.final_counts.context("trace recorder finished without writing anything")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{TraceKind, TraceMeta, PATTERN_FROM_SPEC};

    fn meta() -> TraceMeta {
        TraceMeta {
            kind: TraceKind::Recorded,
            fingerprint: 1,
            seed: 2,
            scale: 0.5,
            app: "MM".into(),
            regs_per_thread: 20,
            threads_per_cta: 128,
            smem_per_cta: 0,
            total_ctas: 4,
            iters: 8,
            arrays: vec![(64, PATTERN_FROM_SPEC)],
        }
    }

    #[test]
    fn encode_is_deterministic() {
        let accesses = vec![
            (0u64, 0u32, 0u32, false, vec![100, 101, 102]),
            (1, 0, 0, false, vec![103]),
            (0, 1, 2, true, vec![50]),
        ];
        let payloads = vec![(100u64, 0u32, [7u8; 128]), (101, 0, [7u8; 128]), (50, 1, [9u8; 128])];
        let a = encode_in_memory(&meta(), &accesses, &payloads).unwrap();
        let b = encode_in_memory(&meta(), &accesses, &payloads).unwrap();
        assert_eq!(a, b);
        // Identical payload bytes are stored once (second entry is a ref):
        // making the duplicate line distinct must grow the file.
        let distinct = vec![(100u64, 0u32, [7u8; 128]), (101, 0, [8u8; 128]), (50, 1, [9u8; 128])];
        let c = encode_in_memory(&meta(), &accesses, &distinct).unwrap();
        assert!(a.len() < c.len(), "payload dedup saved nothing: {} vs {}", a.len(), c.len());
    }

    #[test]
    fn recorder_dedups_keys() {
        let path = std::env::temp_dir().join(format!("caba_rec_test_{}.cabatrace", std::process::id()));
        let rec = TraceRecorder::create(path.to_str().unwrap(), &meta()).unwrap();
        rec.record_access(3, 1, 0, false, &[10, 11]);
        rec.record_access(3, 1, 0, false, &[10, 11]); // duplicate key
        rec.record_payload(10, 0, &[1u8; 128]);
        rec.record_payload(10, 0, &[1u8; 128]); // duplicate key
        rec.note_cycle(5);
        rec.note_cycle(90);
        let (a, p) = rec.finish(true).unwrap();
        assert_eq!((a, p), (1, 1));
        // finish() is idempotent.
        assert_eq!(rec.finish(true).unwrap(), (1, 1));
        std::fs::remove_file(&path).unwrap();
    }
}
