//! Low-level trace codec primitives: LEB128 varints, zigzag signed
//! deltas, run-length-encoded line payloads, and a bounds-checked byte
//! cursor. Every decode path returns an error instead of panicking — a
//! corrupt or truncated trace must fail loudly, never mis-parse.

use crate::compress::{Line, LINE_BYTES};
use anyhow::{bail, Result};

/// Append `v` as an LEB128 varint (7 bits per byte, MSB = continuation).
pub fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7F) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

/// Zigzag-map a signed delta onto an unsigned varint payload.
pub fn zigzag_encode(v: i64) -> u64 {
    ((v << 1) ^ (v >> 63)) as u64
}

pub fn zigzag_decode(u: u64) -> i64 {
    ((u >> 1) as i64) ^ -((u & 1) as i64)
}

/// Append a zigzag'd signed value as a varint.
pub fn put_zigzag(out: &mut Vec<u8>, v: i64) {
    put_varint(out, zigzag_encode(v));
}

/// Run-length-encode one 128-byte line payload.
///
/// Encoding: a sequence of `(run_len ≥ 1, byte)` pairs covering exactly
/// [`LINE_BYTES`] bytes — or, when the pair form would be larger than the
/// raw line, a single `0x00` marker followed by the 128 raw bytes.
pub fn rle_encode_line(line: &Line, out: &mut Vec<u8>) {
    let mut runs: Vec<(u8, u8)> = Vec::new();
    let mut i = 0;
    while i < LINE_BYTES {
        let b = line[i];
        let mut n = 1usize;
        while i + n < LINE_BYTES && line[i + n] == b && n < 255 {
            n += 1;
        }
        runs.push((n as u8, b));
        i += n;
    }
    if runs.len() * 2 <= LINE_BYTES {
        for (n, b) in runs {
            out.push(n);
            out.push(b);
        }
    } else {
        out.push(0);
        out.extend_from_slice(line);
    }
}

/// Decode one RLE line payload from the cursor.
pub fn rle_decode_line(r: &mut Reader) -> Result<Line> {
    let mut line = [0u8; LINE_BYTES];
    let first = r.u8()?;
    if first == 0 {
        line.copy_from_slice(r.bytes(LINE_BYTES)?);
        return Ok(line);
    }
    let mut pos = 0usize;
    let mut run = first;
    loop {
        let b = r.u8()?;
        let n = run as usize;
        if pos + n > LINE_BYTES {
            bail!("corrupt trace: RLE run overflows the line ({} > {LINE_BYTES})", pos + n);
        }
        line[pos..pos + n].fill(b);
        pos += n;
        if pos == LINE_BYTES {
            return Ok(line);
        }
        run = r.u8()?;
        if run == 0 {
            bail!("corrupt trace: raw-payload marker inside an RLE run sequence");
        }
    }
}

/// A bounds-checked cursor over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    pub fn pos(&self) -> usize {
        self.pos
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn u8(&mut self) -> Result<u8> {
        match self.buf.get(self.pos) {
            Some(&b) => {
                self.pos += 1;
                Ok(b)
            }
            None => bail!("truncated trace: unexpected end of data at byte {}", self.pos),
        }
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!(
                "truncated trace: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32_le(&mut self) -> Result<u32> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64_le(&mut self) -> Result<u64> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn varint(&mut self) -> Result<u64> {
        let mut v = 0u64;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 64 {
                bail!("corrupt trace: varint longer than 64 bits");
            }
            // The 10th byte only has room for bit 63: anything beyond it
            // would be silently shifted out — that's corruption, not data.
            if shift == 63 && (b & 0x7E) != 0 {
                bail!("corrupt trace: varint overflows 64 bits");
            }
            v |= ((b & 0x7F) as u64) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    pub fn zigzag(&mut self) -> Result<i64> {
        Ok(zigzag_decode(self.varint()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn varint_roundtrip_edges() {
        for v in [0u64, 1, 127, 128, 300, u32::MAX as u64, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf);
            assert_eq!(r.varint().unwrap(), v);
            assert_eq!(r.remaining(), 0);
        }
    }

    #[test]
    fn zigzag_roundtrip_edges() {
        for v in [0i64, 1, -1, 63, -64, i64::MAX, i64::MIN] {
            assert_eq!(zigzag_decode(zigzag_encode(v)), v);
        }
    }

    #[test]
    fn rle_all_zero_line_is_tiny() {
        let line = [0u8; LINE_BYTES];
        let mut buf = Vec::new();
        rle_encode_line(&line, &mut buf);
        assert!(buf.len() <= 4, "zero line encoded to {} bytes", buf.len());
        let mut r = Reader::new(&buf);
        assert_eq!(rle_decode_line(&mut r).unwrap(), line);
    }

    #[test]
    fn rle_incompressible_falls_back_to_raw() {
        let mut line = [0u8; LINE_BYTES];
        for (i, b) in line.iter_mut().enumerate() {
            *b = i as u8; // no runs
        }
        let mut buf = Vec::new();
        rle_encode_line(&line, &mut buf);
        assert_eq!(buf.len(), 1 + LINE_BYTES);
        assert_eq!(buf[0], 0);
        let mut r = Reader::new(&buf);
        assert_eq!(rle_decode_line(&mut r).unwrap(), line);
    }

    #[test]
    fn truncated_reads_fail() {
        let mut buf = Vec::new();
        put_varint(&mut buf, u64::MAX); // 10-byte varint
        buf.truncate(3);
        let mut r = Reader::new(&buf);
        assert!(r.varint().is_err());
        let mut r2 = Reader::new(&[0x80, 0x80]); // never-terminating varint
        assert!(r2.varint().is_err());
        // 10th byte with bits beyond bit 63 set: overflow, not silent drop.
        let overlong = [0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x80, 0x7F];
        assert!(Reader::new(&overlong).varint().is_err());
        let mut r3 = Reader::new(&[5u8]); // RLE run with no byte
        assert!(rle_decode_line(&mut r3).is_err());
    }

    #[test]
    fn rle_overrun_detected() {
        // Two runs of 255 overflow a 128-byte line.
        let buf = [255u8, 7, 255, 7];
        let mut r = Reader::new(&buf);
        assert!(rle_decode_line(&mut r).is_err());
    }
}
