//! # Trace capture & replay
//!
//! This subsystem decouples *what the GPU executes* from *how we
//! synthesized it*. A [`record::TraceRecorder`] attached to a running
//! [`crate::workload::Workload`] streams every warp-level memory access and
//! every generated line payload into a compact, versioned, deterministic
//! binary file; a [`replay::TraceData`] serves that file back as the
//! workload side of the simulator, so recorded runs — or externally
//! authored accelsim-style dumps converted by [`import`] — drive the full
//! CABA pipeline (compression, assist warps, DRAM) without the synthetic
//! generators (trace-driven simulation, as in gpucachesim/accel-sim).
//!
//! ## File format (`.cabatrace`, version 1)
//!
//! ```text
//! header:
//!   magic       8 bytes  b"CABATRC\0"
//!   version     u32 le   (= 1)
//!   kind        u8       (0 = recorded app run, 1 = imported)
//!   fingerprint u64 le   SimConfig::fingerprint() of the recording run
//!   seed        u64 le   Workload seed (drives the payload generators)
//!   scale       u64 le   f64 bit pattern of the workload scale factor
//!   app         varint len + UTF-8 app name
//!   geometry    varints: regs/thread, threads/CTA, smem/CTA, total CTAs,
//!               iterations per warp
//!   arrays      varint count, then per array: footprint varint +
//!               data-pattern code u8 (0xFF = "use the app spec's pattern")
//! chunks (repeated):
//!   tag u8 ('A' access | 'P' payload), byte-length varint, record-count
//!   varint, then the record bytes
//! trailer:
//!   tag 'T', then u64 le ×6: access records, payload entries, payload
//!   definitions, first issue cycle, last issue cycle, flags (bit 0 =
//!   the recorded run drained; 0 marks a budget-truncated recording)
//! ```
//!
//! Access records (stream state persists across 'A' chunks): zigzag-varint
//! warp-uid delta, zigzag iteration delta, slot varint, flags u8 (bit 0 =
//! store), line-count varint, then the line addresses — the first as a
//! zigzag delta against the previous record's first line, the rest as
//! zigzag deltas against their predecessor within the record.
//!
//! Payload entries ('P' chunks): zigzag line-address delta, epoch varint,
//! then a reference varint — `id + 1` pointing at an earlier payload
//! definition, or `0` introducing the next definition inline as an
//! RLE-coded 128-byte line ([`codec::rle_encode_line`]). Identical line
//! images are stored once and referenced thereafter.
//!
//! The byte stream is **deterministic**: records are emitted in first-
//! encounter order of the (deterministic) simulation, never from hash-map
//! iteration, so recording the same run twice produces identical files and
//! identical content digests.

pub mod codec;
pub mod import;
pub mod record;
pub mod replay;

use crate::workload::datagen::DataPattern;
use anyhow::{bail, Result};
use codec::{put_varint, Reader};

/// File magic ("bad magic" failures name this).
pub const MAGIC: [u8; 8] = *b"CABATRC\0";
/// Current format version.
pub const VERSION: u32 = 1;

/// Chunk tags.
pub const TAG_ACCESS: u8 = b'A';
pub const TAG_PAYLOAD: u8 = b'P';
pub const TAG_TRAILER: u8 = b'T';

/// Pattern code marking "take the data pattern from the app spec" (used by
/// recorded traces, whose replay falls back to the original generators).
pub const PATTERN_FROM_SPEC: u8 = 0xFF;

/// Where a trace came from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TraceKind {
    /// Recorded from a synthetic-app simulation; replay can regenerate any
    /// payload the file does not carry (same pure generator functions).
    Recorded,
    /// Converted from an external text dump; payloads come from the
    /// import-assigned data pattern.
    Imported,
}

/// Everything the header carries.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceMeta {
    pub kind: TraceKind,
    /// `SimConfig::fingerprint()` of the recording run (0 for imports).
    pub fingerprint: u64,
    /// Workload seed — replay reuses it so generator-fallback payloads are
    /// bit-identical to the recording run's.
    pub seed: u64,
    /// Workload scale factor of the recording run.
    pub scale: f64,
    /// App name (an `apps::APPS` entry, or "TRACE" for imports).
    pub app: String,
    pub regs_per_thread: u32,
    pub threads_per_cta: u32,
    pub smem_per_cta: u32,
    pub total_ctas: u32,
    /// Loop iterations per warp.
    pub iters: u32,
    /// Per array: footprint in lines + data-pattern code.
    pub arrays: Vec<(u64, u8)>,
}

impl TraceMeta {
    /// Serialize the header.
    pub fn write(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&MAGIC);
        out.extend_from_slice(&VERSION.to_le_bytes());
        out.push(match self.kind {
            TraceKind::Recorded => 0,
            TraceKind::Imported => 1,
        });
        out.extend_from_slice(&self.fingerprint.to_le_bytes());
        out.extend_from_slice(&self.seed.to_le_bytes());
        out.extend_from_slice(&self.scale.to_bits().to_le_bytes());
        put_varint(out, self.app.len() as u64);
        out.extend_from_slice(self.app.as_bytes());
        for v in [
            self.regs_per_thread,
            self.threads_per_cta,
            self.smem_per_cta,
            self.total_ctas,
            self.iters,
        ] {
            put_varint(out, v as u64);
        }
        put_varint(out, self.arrays.len() as u64);
        for &(fp, code) in &self.arrays {
            put_varint(out, fp);
            out.push(code);
        }
    }

    /// Parse the header (magic + version are validated here, loudly).
    pub fn parse(r: &mut Reader) -> Result<TraceMeta> {
        let magic = r.bytes(8)?;
        if magic != &MAGIC[..] {
            bail!("bad magic: not a CABA trace file (got {magic:02x?})");
        }
        let version = r.u32_le()?;
        if version != VERSION {
            bail!("unsupported trace version {version} (this build reads version {VERSION})");
        }
        let kind = match r.u8()? {
            0 => TraceKind::Recorded,
            1 => TraceKind::Imported,
            k => bail!("corrupt trace: unknown kind byte {k}"),
        };
        let fingerprint = r.u64_le()?;
        let seed = r.u64_le()?;
        let scale = f64::from_bits(r.u64_le()?);
        let app_len = r.varint()? as usize;
        if app_len > 256 {
            bail!("corrupt trace: app name length {app_len}");
        }
        let app = std::str::from_utf8(r.bytes(app_len)?)
            .map_err(|_| anyhow::anyhow!("corrupt trace: app name is not UTF-8"))?
            .to_string();
        let mut geom = [0u32; 5];
        for g in geom.iter_mut() {
            let v = r.varint()?;
            if v > u32::MAX as u64 {
                bail!("corrupt trace: geometry value {v} out of range");
            }
            *g = v as u32;
        }
        let n_arrays = r.varint()? as usize;
        if n_arrays > 64 {
            bail!("corrupt trace: {n_arrays} arrays");
        }
        let mut arrays = Vec::with_capacity(n_arrays);
        for _ in 0..n_arrays {
            let fp = r.varint()?;
            let code = r.u8()?;
            arrays.push((fp, code));
        }
        Ok(TraceMeta {
            kind,
            fingerprint,
            seed,
            scale,
            app,
            regs_per_thread: geom[0],
            threads_per_cta: geom[1],
            smem_per_cta: geom[2],
            total_ctas: geom[3],
            iters: geom[4],
            arrays,
        })
    }
}

// --- import data patterns -------------------------------------------------
// Imported traces carry no payload bytes; replay synthesizes line contents
// from one of these named distribution classes (see workload::datagen).

static P_RANDOM: DataPattern = DataPattern::Random;
static P_ZERO: DataPattern = DataPattern::ZeroHeavy { p_zero: 0.65 };
static P_LOWDYN: DataPattern = DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 1 };
static P_NARROW: DataPattern = DataPattern::NarrowInt { max: 120 };
static P_POINTER: DataPattern = DataPattern::PointerLike { n_bases: 4 };
static P_REP: DataPattern = DataPattern::RepBytes;
static P_SPARSE: DataPattern = DataPattern::SparseNarrow { p_nonzero: 0.25 };
static P_FLOAT: DataPattern = DataPattern::FloatGrid { exp: 120 };

/// Named pattern table for the import CLI (`--pattern <name>`).
pub const PATTERN_NAMES: [(&str, u8); 8] = [
    ("random", 0),
    ("zero", 1),
    ("lowdyn", 2),
    ("narrow", 3),
    ("pointer", 4),
    ("rep", 5),
    ("sparse", 6),
    ("float", 7),
];

/// Resolve a pattern code from the trace header.
pub fn pattern_by_code(code: u8) -> Option<&'static DataPattern> {
    Some(match code {
        0 => &P_RANDOM,
        1 => &P_ZERO,
        2 => &P_LOWDYN,
        3 => &P_NARROW,
        4 => &P_POINTER,
        5 => &P_REP,
        6 => &P_SPARSE,
        7 => &P_FLOAT,
        _ => return None,
    })
}

/// Resolve a pattern name (import CLI) to its code.
pub fn pattern_code_by_name(name: &str) -> Option<u8> {
    PATTERN_NAMES
        .iter()
        .find(|(n, _)| n.eq_ignore_ascii_case(name))
        .map(|&(_, c)| c)
}

/// FNV-style 64-bit byte fold — the trace's content digest (sweep cache
/// key component for trace-driven jobs; also shown by `caba trace info`).
/// Same fold as `workload`'s app-name hash (FNV offset basis, widened
/// multiplier); only collision resistance for cache keying matters here,
/// not the exact FNV-1a constants.
pub fn content_digest(bytes: &[u8]) -> u64 {
    bytes
        .iter()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, &b| (h ^ b as u64).wrapping_mul(0x1000_0000_01b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta() -> TraceMeta {
        TraceMeta {
            kind: TraceKind::Recorded,
            fingerprint: 0xDEAD_BEEF,
            seed: 42,
            scale: 0.25,
            app: "PVC".into(),
            regs_per_thread: 16,
            threads_per_cta: 256,
            smem_per_cta: 0,
            total_ctas: 30,
            iters: 12,
            arrays: vec![(4096, PATTERN_FROM_SPEC), (128, PATTERN_FROM_SPEC)],
        }
    }

    #[test]
    fn header_roundtrip() {
        let m = meta();
        let mut buf = Vec::new();
        m.write(&mut buf);
        let mut r = Reader::new(&buf);
        let back = TraceMeta::parse(&mut r).unwrap();
        assert_eq!(back, m);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn bad_magic_and_version_fail() {
        let mut buf = Vec::new();
        meta().write(&mut buf);
        let mut garbled = buf.clone();
        garbled[0] = b'X';
        let err = TraceMeta::parse(&mut Reader::new(&garbled)).unwrap_err();
        assert!(format!("{err}").contains("bad magic"), "{err}");
        let mut newer = buf.clone();
        newer[8] = 99; // version low byte
        let err = TraceMeta::parse(&mut Reader::new(&newer)).unwrap_err();
        assert!(format!("{err}").contains("version"), "{err}");
        // Truncation inside the header.
        buf.truncate(16);
        assert!(TraceMeta::parse(&mut Reader::new(&buf)).is_err());
    }

    #[test]
    fn pattern_names_resolve() {
        for (name, code) in PATTERN_NAMES {
            assert_eq!(pattern_code_by_name(name), Some(code));
            assert!(pattern_by_code(code).is_some());
        }
        assert_eq!(pattern_code_by_name("nonsense"), None);
        assert!(pattern_by_code(200).is_none());
    }

    #[test]
    fn digest_is_order_sensitive() {
        assert_ne!(content_digest(b"ab"), content_digest(b"ba"));
        assert_eq!(content_digest(b"xyz"), content_digest(b"xyz"));
    }
}
