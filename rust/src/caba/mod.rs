//! The CABA microarchitecture (§4): Assist Warp Store (AWS), Assist Warp
//! Controller (AWC) with its Assist Warp Table (AWT), and the Assist Warp
//! Buffer (AWB) partitions in the instruction buffer.
//!
//! One [`Awc`] instance lives in each SM. Decompression assist warps are
//! *high priority* — they issue ahead of parent warps and the parent's
//! destination registers stay unavailable until the assist warp retires
//! (§5.2.1: "stalls the progress of its parent warp until it completes").
//! Compression assist warps are *low priority* — they live in the dedicated
//! two-entry AWB partition and issue only into issue slots parent warps
//! left idle (§4.3), subject to the utilization-feedback throttle (§4.4).

pub mod prefetch;
pub mod subroutines;

use crate::compress::oracle::LineVerdict;
use crate::config::SimConfig;
use crate::stats::CabaStats;
use crate::telemetry::{SpanKind, SpanLog, SpanOutcome, SPAN_NONE};
use subroutines::Subroutine;

/// Scheduling priority of an assist warp (§4.2.3).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Priority {
    High,
    Low,
}

/// What happens when an assist warp retires.
#[derive(Clone, Debug)]
pub enum Payload {
    /// Release the parent-warp registers waiting on this decompression:
    /// `(warp slot, register, warp uid)` triples (grows as MSHR merges
    /// attach). The uid stamps the warp *instance*: warp slots are
    /// recycled across CTAs, and a release must never land on a later
    /// tenant of the slot.
    Decompress { regs: Vec<(usize, u8, u64)> },
    /// Dispatch the buffered store with its compression verdict.
    Compress { line_addr: u64, verdict: LineVerdict },
    /// Issue the predicted prefetches into the memory system (§8.2).
    Prefetch { lines: Vec<u64> },
    /// Install a memoized result for this operand key into the per-SM
    /// memo LUT (§8.1, `crate::memo`) when the install warp retires.
    MemoInstall { key: u64 },
}

/// One AWT row (Fig. 5): live-in/out register ids are abstracted into the
/// payload; `SR.ID`/`Inst.ID` into the remaining-instruction counters.
#[derive(Clone, Debug)]
pub struct AwtEntry {
    /// Unique token identifying this entry instance (AWT rows are reused;
    /// stale references must not attach to a recycled row).
    pub token: u64,
    /// Trigger time: instructions may deploy from this cycle on.
    pub active_from: u64,
    pub sp_left: u16,
    pub mem_left: u16,
    pub priority: Priority,
    pub payload: Payload,
    /// Warp slot of the parent (shares its context and warp ID, §4.2.1).
    pub parent_warp: usize,
    /// Flight-recorder span for this deployment ([`SPAN_NONE`] when
    /// telemetry is off or the span log was full) — lets issue/retire/kill
    /// update the span in O(1) without a token lookup.
    pub span_idx: u32,
}

/// A retirement the core must act upon.
#[derive(Clone, Debug)]
pub struct Retirement {
    pub at: u64,
    pub payload: Payload,
}

/// Free issue slots left this cycle (shared with parent warps).
#[derive(Clone, Copy, Debug)]
pub struct Slots {
    pub sp: usize,
    pub sfu: usize,
    pub mem: usize,
}

/// Per-SM Assist Warp Controller.
pub struct Awc {
    /// The AWT; `None` = free row.
    entries: Vec<Option<AwtEntry>>,
    /// Round-robin deployment pointer (§4.4: "selects an assist warp to
    /// deploy in a round-robin fashion").
    rr: usize,
    /// Dedicated low-priority AWB partition size (§4.3: two entries).
    low_prio_slots: usize,
    /// Exec latency applied after the last instruction issues.
    retire_latency: u64,
    /// Monotonic token source for AWT entry instances.
    next_token: u64,
    /// Live AWT row indices per priority, in deployment order — the issue
    /// path touches only live rows instead of scanning the whole table.
    rows_high: Vec<usize>,
    rows_low: Vec<usize>,
    /// Utilization-feedback throttle state: EMA of issue-slot utilization.
    util_ema: f64,
    throttle_enabled: bool,
    throttle_threshold: f64,
    pub stats: CabaStats,
    /// Flight-recorder span log (trigger → issue → retire/kill per assist
    /// warp). Disabled (zero-capacity) unless telemetry is on; every hook
    /// below is then a single branch. Observation-only: never read by any
    /// scheduling decision.
    pub spans: SpanLog,
}

impl Awc {
    pub fn new(cfg: &SimConfig) -> Awc {
        Awc {
            entries: (0..cfg.awt_entries).map(|_| None).collect(),
            rr: 0,
            low_prio_slots: cfg.awb_low_prio_slots,
            retire_latency: cfg.alu_latency as u64,
            next_token: 1,
            rows_high: Vec::new(),
            rows_low: Vec::new(),
            util_ema: 0.0,
            throttle_enabled: cfg.caba_throttle,
            throttle_threshold: cfg.throttle_util_threshold,
            stats: CabaStats::default(),
            spans: SpanLog::new(if cfg.telemetry_window > 0 {
                cfg.telemetry_spans
            } else {
                0
            }),
        }
    }

    /// Trigger a decompression assist warp (high priority). Returns the AWT
    /// row index, or `None` if the AWT is full (caller must fall back to
    /// blocking semantics).
    pub fn trigger_decompress(
        &mut self,
        active_from: u64,
        sub: Subroutine,
        parent_warp: usize,
        reg: u8,
        uid: u64,
    ) -> Option<u64> {
        let token =
            self.trigger_high(active_from, sub, parent_warp, reg, uid, SpanKind::Decompress)?;
        self.stats.decompress_warps += 1;
        Some(token)
    }

    /// Trigger a memo-lookup assist warp (§8.1): high priority like
    /// decompression (the parent's destination register waits on it), but
    /// counted through the memo counters in the core, not as a
    /// decompression warp.
    pub fn trigger_lookup(
        &mut self,
        active_from: u64,
        sub: Subroutine,
        parent_warp: usize,
        reg: u8,
        uid: u64,
    ) -> Option<u64> {
        self.trigger_high(active_from, sub, parent_warp, reg, uid, SpanKind::MemoLookup)
    }

    fn trigger_high(
        &mut self,
        active_from: u64,
        sub: Subroutine,
        parent_warp: usize,
        reg: u8,
        uid: u64,
        kind: SpanKind,
    ) -> Option<u64> {
        let idx = self.free_row()?;
        let token = self.next_token;
        self.next_token += 1;
        let span_idx = self.spans.open(token, kind, parent_warp, active_from);
        self.entries[idx] = Some(AwtEntry {
            token,
            active_from,
            sp_left: sub.sp(),
            mem_left: sub.mem,
            priority: Priority::High,
            payload: Payload::Decompress { regs: vec![(parent_warp, reg, uid)] },
            parent_warp,
            span_idx,
        });
        self.rows_high.push(idx);
        Some(token)
    }

    /// Trigger a compression assist warp (low priority). Returns `None`
    /// (and the caller flushes the store uncompressed) when the AWT is full
    /// or the throttle vetoes deployment (§4.4).
    pub fn trigger_compress(
        &mut self,
        active_from: u64,
        sub: Subroutine,
        parent_warp: usize,
        line_addr: u64,
        verdict: LineVerdict,
    ) -> Option<u64> {
        if self.throttled() {
            self.stats.throttled_deploys += 1;
            return None;
        }
        let idx = self.free_row()?;
        let token = self.next_token;
        self.next_token += 1;
        let span_idx = self.spans.open(token, SpanKind::Compress, parent_warp, active_from);
        self.entries[idx] = Some(AwtEntry {
            token,
            active_from,
            sp_left: sub.sp(),
            mem_left: sub.mem,
            priority: Priority::Low,
            payload: Payload::Compress { line_addr, verdict },
            parent_warp,
            span_idx,
        });
        self.stats.compress_warps += 1;
        self.rows_low.push(idx);
        Some(token)
    }

    /// Trigger a generic low-priority assist warp (prefetch / memo-install).
    pub fn trigger_low(
        &mut self,
        active_from: u64,
        sub: Subroutine,
        parent_warp: usize,
        payload: Payload,
    ) -> Option<u64> {
        if self.throttled() {
            self.stats.throttled_deploys += 1;
            return None;
        }
        let idx = self.free_row()?;
        let token = self.next_token;
        self.next_token += 1;
        let kind = match &payload {
            Payload::Prefetch { .. } => SpanKind::Prefetch,
            Payload::MemoInstall { .. } => SpanKind::MemoInstall,
            Payload::Compress { .. } => SpanKind::Compress,
            Payload::Decompress { .. } => SpanKind::Decompress,
        };
        let span_idx = self.spans.open(token, kind, parent_warp, active_from);
        self.entries[idx] = Some(AwtEntry {
            token,
            active_from,
            sp_left: sub.sp(),
            mem_left: sub.mem,
            priority: Priority::Low,
            payload,
            parent_warp,
            span_idx,
        });
        self.rows_low.push(idx);
        Some(token)
    }

    fn row_of(&self, token: u64) -> Option<usize> {
        self.entries
            .iter()
            .position(|e| e.as_ref().map_or(false, |e| e.token == token))
    }

    /// Attach another waiting register to an in-flight decompression
    /// (MSHR-merge on the same line). Returns false if the entry already
    /// retired (its row may have been recycled).
    pub fn attach_reg(&mut self, token: u64, warp: usize, reg: u8, uid: u64) -> bool {
        if let Some(idx) = self.row_of(token) {
            if let Some(e) = &mut self.entries[idx] {
                if let Payload::Decompress { regs } = &mut e.payload {
                    regs.push((warp, reg, uid));
                    return true;
                }
            }
        }
        false
    }

    /// Kill an entry (line turned out uncompressed / no longer needed,
    /// §4.4 "Communication and Control"). `now` closes the entry's
    /// flight-recorder span.
    pub fn kill(&mut self, token: u64, now: u64) {
        if let Some(idx) = self.row_of(token) {
            if let Some(e) = self.entries[idx].take() {
                match e.priority {
                    Priority::High => self.rows_high.retain(|&r| r != idx),
                    Priority::Low => self.rows_low.retain(|&r| r != idx),
                }
                self.spans.close(e.span_idx, now, SpanOutcome::Killed);
            }
            self.stats.killed += 1;
        }
    }

    /// Is this entry instance still live?
    pub fn is_live(&self, token: u64) -> bool {
        self.row_of(token).is_some()
    }

    fn free_row(&self) -> Option<usize> {
        self.entries.iter().position(|e| e.is_none())
    }

    /// Can another assist warp be triggered right now? (The memo issue
    /// path checks this before committing to the lookup-bypass timing.)
    pub fn has_free_row(&self) -> bool {
        self.free_row().is_some()
    }

    /// Count of live entries (for buffer-capacity decisions).
    pub fn live(&self) -> usize {
        self.rows_high.len() + self.rows_low.len()
    }

    /// Earliest cycle any live entry can issue; `u64::MAX` when the AWT is
    /// empty (fast-forward hint for the core).
    pub fn next_active(&self, now: u64) -> u64 {
        let mut next = u64::MAX;
        for e in self.entries.iter().flatten() {
            if e.active_from <= now {
                return now + 1;
            }
            next = next.min(e.active_from);
        }
        next
    }

    fn throttled(&self) -> bool {
        self.throttle_enabled && self.util_ema > self.throttle_threshold
    }

    /// Update the feedback EMA with this cycle's issue-slot utilization.
    pub fn observe_utilization(&mut self, used: usize, total: usize) {
        let u = used as f64 / total.max(1) as f64;
        self.util_ema = 0.99 * self.util_ema + 0.01 * u;
    }

    /// Bulk-replay `k` cycles on which the core issued nothing and no AWT
    /// entry was active — the event-driven tick's stand-in for `k` calls
    /// of the per-cycle path (see `Simulator::run`). Two per-cycle effects
    /// exist on such cycles and both are replayed **bit-exactly**:
    ///
    /// * `observe_utilization(0, _)` each cycle: with `u = 0` the update
    ///   reduces to `ema = 0.99 * ema + 0.0`, and `x + 0.0 == x` exactly
    ///   for the non-negative EMA, so the loop below is the identical
    ///   float sequence (a closed-form `powi` would round differently).
    ///   The loop stops early at a *fixed point* of the update — not just
    ///   0.0: under round-to-nearest the decay bottoms out at the smallest
    ///   subnormal (`0.99 × 2⁻¹⁰⁷⁴` rounds back up to `2⁻¹⁰⁷⁴`), where the
    ///   per-cycle path would also sit unchanged forever, so breaking
    ///   there is bit-exact and keeps long settles O(~70k) multiplies
    ///   worst-case instead of O(window).
    /// * the round-robin pointer: `issue_high`/`issue_low` bump `rr` once
    ///   per call whenever their row list is non-empty, even when every
    ///   entry is still waiting on a future `active_from`. `high_calls` /
    ///   `low_calls` tell us whether the core would have made those calls
    ///   at all (they are design/config-gated); row-list membership cannot
    ///   change across the window (no triggers, no issues, no kills).
    pub fn skip_idle_cycles(&mut self, k: u64, high_calls: bool, low_calls: bool) {
        let mut per_cycle: u64 = 0;
        if high_calls && !self.rows_high.is_empty() {
            per_cycle += 1;
        }
        if low_calls && !self.rows_low.is_empty() {
            per_cycle += 1;
        }
        if per_cycle > 0 {
            self.rr = self.rr.wrapping_add(k.wrapping_mul(per_cycle) as usize);
        }
        for _ in 0..k {
            let next = 0.99 * self.util_ema;
            if next == self.util_ema {
                break; // fixed point (0.0 or the smallest subnormal)
            }
            self.util_ema = next;
        }
    }

    /// Issue high-priority assist instructions into `slots` (before parent
    /// warps see them). Returns retirements the core must apply.
    pub fn issue_high(&mut self, now: u64, slots: &mut Slots) -> Vec<Retirement> {
        if self.rows_high.is_empty() {
            return Vec::new();
        }
        self.issue_priority(now, slots, Priority::High, usize::MAX, false)
    }

    /// Issue low-priority assist instructions into slots the parent warps
    /// left free this cycle. Only the dedicated AWB partition (2 entries)
    /// is visible to the scheduler. `cycle_idle` marks slots counted as
    /// idle-issue for the stats.
    pub fn issue_low(&mut self, now: u64, slots: &mut Slots) -> Vec<Retirement> {
        if self.rows_low.is_empty() {
            return Vec::new();
        }
        let cap = self.low_prio_slots;
        self.issue_priority(now, slots, Priority::Low, cap, true)
    }

    fn issue_priority(
        &mut self,
        now: u64,
        slots: &mut Slots,
        prio: Priority,
        max_entries: usize,
        idle_slots: bool,
    ) -> Vec<Retirement> {
        let mut retired = Vec::new();
        let rows = std::mem::take(match prio {
            Priority::High => &mut self.rows_high,
            Priority::Low => &mut self.rows_low,
        });
        let n = rows.len();
        let mut visited = 0;
        let mut used_entries = 0;
        let mut any_retired = false;
        // Round-robin over live rows of this priority (§4.4).
        while visited < n && (slots.sp > 0 || slots.mem > 0) && used_entries < max_entries {
            let idx = rows[(self.rr + visited) % n];
            visited += 1;
            let Some(e) = &mut self.entries[idx] else { continue };
            if e.active_from > now {
                continue;
            }
            used_entries += 1;
            // Issue as many of this warp's instructions as slots allow this
            // cycle (the AWC deploys at most issue-width per cycle; slots
            // are shared with everything else, so this is bounded).
            let mut issued_any = false;
            while e.mem_left > 0 && slots.mem > 0 {
                e.mem_left -= 1;
                slots.mem -= 1;
                issued_any = true;
                self.stats.assist_insts_issued += 1;
                if idle_slots {
                    self.stats.assist_insts_idle_slots += 1;
                }
            }
            while e.sp_left > 0 && slots.sp > 0 {
                e.sp_left -= 1;
                slots.sp -= 1;
                issued_any = true;
                self.stats.assist_insts_issued += 1;
                if idle_slots {
                    self.stats.assist_insts_idle_slots += 1;
                }
            }
            if issued_any {
                self.spans.note_issue(e.span_idx, now);
            }
            if e.sp_left == 0 && e.mem_left == 0 {
                let e = self.entries[idx].take().unwrap();
                any_retired = true;
                self.spans
                    .close(e.span_idx, now + self.retire_latency, SpanOutcome::Retired);
                retired.push(Retirement {
                    at: now + self.retire_latency,
                    payload: e.payload,
                });
            }
        }
        let mut rows = rows;
        if any_retired {
            let entries = &self.entries;
            rows.retain(|&r| entries[r].is_some());
        }
        match prio {
            Priority::High => self.rows_high = rows,
            Priority::Low => self.rows_low = rows,
        }
        self.rr = self.rr.wrapping_add(1);
        retired
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use subroutines::{subroutine, AwKind};
    use crate::compress::Algo;

    fn awc() -> Awc {
        Awc::new(&SimConfig::default())
    }

    fn slots() -> Slots {
        Slots { sp: 2, sfu: 1, mem: 1 }
    }

    #[test]
    fn decompress_lifecycle() {
        let mut a = awc();
        let sub = subroutine(Algo::Bdi, AwKind::Decompress, crate::compress::bdi::ENC_B8D1, false);
        let idx = a.trigger_decompress(10, sub, 3, 7, 30).unwrap();
        assert!(a.is_live(idx));
        // Not active before its trigger time.
        let r = a.issue_high(5, &mut slots());
        assert!(r.is_empty());
        assert!(a.is_live(idx));
        // Issue to completion.
        let mut now = 10;
        let mut retired = Vec::new();
        while retired.is_empty() && now < 100 {
            retired = a.issue_high(now, &mut slots());
            now += 1;
        }
        assert_eq!(retired.len(), 1);
        assert!(retired[0].at >= now);
        match &retired[0].payload {
            Payload::Decompress { regs } => assert_eq!(regs, &vec![(3usize, 7u8, 30u64)]),
            _ => panic!("wrong payload"),
        }
        assert!(!a.is_live(idx));
        assert_eq!(a.stats.decompress_warps, 1);
        assert!(a.stats.assist_insts_issued as u16 >= sub.total);
    }

    #[test]
    fn slots_bound_issue_rate() {
        let mut a = awc();
        let sub = Subroutine { total: 10, mem: 4 };
        a.trigger_decompress(0, sub, 0, 1, 0).unwrap();
        // One cycle with 2 sp + 1 mem slots issues at most 3 instructions.
        let before = a.stats.assist_insts_issued;
        let mut s = slots();
        a.issue_high(0, &mut s);
        assert_eq!(a.stats.assist_insts_issued - before, 3);
        assert_eq!(s.sp, 0);
        assert_eq!(s.mem, 0);
    }

    #[test]
    fn low_priority_respects_partition_cap() {
        let mut a = awc();
        let sub = Subroutine { total: 4, mem: 1 };
        let v = LineVerdict { encoding: 0, size_bytes: 17, bursts: 1 };
        for i in 0..4 {
            a.trigger_compress(0, sub, i, 100 + i as u64, v).unwrap();
        }
        // Plenty of slots, but only 2 low-prio entries may progress/cycle.
        let mut s = Slots { sp: 100, sfu: 1, mem: 100 };
        a.issue_low(0, &mut s);
        // 2 entries × 4 insts = 8 issued max this cycle.
        assert!(a.stats.assist_insts_issued <= 8, "{}", a.stats.assist_insts_issued);
    }

    #[test]
    fn awt_capacity_limits_triggers() {
        let mut cfg = SimConfig::default();
        cfg.awt_entries = 2;
        let mut a = Awc::new(&cfg);
        let sub = Subroutine { total: 4, mem: 1 };
        assert!(a.trigger_decompress(0, sub, 0, 1, 0).is_some());
        assert!(a.trigger_decompress(0, sub, 1, 2, 1).is_some());
        assert!(a.trigger_decompress(0, sub, 2, 3, 2).is_none());
        assert_eq!(a.live(), 2);
    }

    #[test]
    fn throttle_vetoes_low_priority_only() {
        let mut a = awc();
        // Saturate the utilization EMA.
        for _ in 0..2000 {
            a.observe_utilization(4, 4);
        }
        let sub = Subroutine { total: 4, mem: 1 };
        let v = LineVerdict { encoding: 0, size_bytes: 17, bursts: 1 };
        assert!(a.trigger_compress(0, sub, 0, 5, v).is_none());
        assert_eq!(a.stats.throttled_deploys, 1);
        // High priority is never throttled (needed for correctness).
        assert!(a.trigger_decompress(0, sub, 0, 1, 0).is_some());
    }

    #[test]
    fn lookup_trigger_is_not_a_decompress_warp() {
        let mut a = awc();
        let sub = Subroutine { total: 3, mem: 1 };
        let tok = a.trigger_lookup(0, sub, 2, 9, 5).unwrap();
        assert!(a.is_live(tok));
        assert_eq!(a.stats.decompress_warps, 0);
        // It still releases the parent register through the high-priority
        // retirement path.
        let mut now = 0;
        let mut retired = Vec::new();
        while retired.is_empty() && now < 100 {
            retired = a.issue_high(now, &mut slots());
            now += 1;
        }
        match &retired[0].payload {
            Payload::Decompress { regs } => assert_eq!(regs, &vec![(2usize, 9u8, 5u64)]),
            _ => panic!("wrong payload"),
        }
    }

    #[test]
    fn attach_and_kill() {
        let mut a = awc();
        let sub = Subroutine { total: 4, mem: 1 };
        let idx = a.trigger_decompress(0, sub, 0, 1, 0).unwrap();
        assert!(a.attach_reg(idx, 5, 9, 50));
        a.kill(idx, 3);
        assert!(!a.is_live(idx));
        assert_eq!(a.stats.killed, 1);
        assert!(!a.attach_reg(idx, 6, 9, 60));
    }

    #[test]
    fn spans_record_trigger_issue_retire_and_kill() {
        use crate::telemetry::{SpanKind, SpanOutcome};
        let mut cfg = SimConfig::default();
        cfg.telemetry_window = 64;
        cfg.telemetry_spans = 8;
        let mut a = Awc::new(&cfg);
        assert!(a.spans.enabled());
        let sub = Subroutine { total: 3, mem: 1 };
        let tok = a.trigger_decompress(10, sub, 4, 7, 1).unwrap();
        let v = LineVerdict { encoding: 0, size_bytes: 17, bursts: 1 };
        let tok2 = a.trigger_compress(12, sub, 5, 42, v).unwrap();
        // Issue the decompression to completion from cycle 10.
        let mut now = 10;
        let mut retired = Vec::new();
        while retired.is_empty() && now < 100 {
            retired = a.issue_high(now, &mut slots());
            now += 1;
        }
        a.kill(tok2, 20);
        let spans = a.spans.spans();
        assert_eq!(spans.len(), 2);
        let d = spans.iter().find(|s| s.token == tok).unwrap();
        assert_eq!(d.kind, SpanKind::Decompress);
        assert_eq!(d.parent_warp, 4);
        assert_eq!(d.trigger_at, 10);
        assert_eq!(d.first_issue, 10);
        assert_eq!(d.outcome, SpanOutcome::Retired);
        assert_eq!(d.end, retired[0].at);
        let c = spans.iter().find(|s| s.token == tok2).unwrap();
        assert_eq!(c.kind, SpanKind::Compress);
        assert_eq!(c.outcome, SpanOutcome::Killed);
        assert_eq!(c.end, 20);
        assert_eq!(c.first_issue, u64::MAX);
    }

    #[test]
    fn spans_disabled_by_default_and_bounded_when_on() {
        // Default config: telemetry off, no spans recorded.
        let mut a = awc();
        let sub = Subroutine { total: 3, mem: 1 };
        a.trigger_decompress(0, sub, 0, 1, 0).unwrap();
        assert!(!a.spans.enabled());
        assert!(a.spans.spans().is_empty());
        assert_eq!(a.spans.dropped(), 0);
        // Enabled with a tiny cap: overflow drops and counts.
        let mut cfg = SimConfig::default();
        cfg.telemetry_window = 64;
        cfg.telemetry_spans = 2;
        let mut a = Awc::new(&cfg);
        for i in 0..4 {
            a.trigger_decompress(0, sub, i, 1, i as u64).unwrap();
        }
        assert_eq!(a.spans.spans().len(), 2);
        assert_eq!(a.spans.dropped(), 2);
    }

    #[test]
    fn skip_idle_cycles_matches_per_cycle_path() {
        // The bulk replay must leave the AWC in the bit-identical state a
        // per-cycle loop of idle cycles produces: same EMA (float-exact),
        // same round-robin pointer.
        let sub = Subroutine { total: 4, mem: 1 };
        let build = || {
            let mut a = awc();
            // Prime a non-trivial EMA and two future-triggered entries so
            // both row lists are non-empty but inactive.
            for _ in 0..50 {
                a.observe_utilization(3, 4);
            }
            a.trigger_decompress(1_000_000, sub, 0, 1, 0).unwrap();
            let v = LineVerdict { encoding: 0, size_bytes: 17, bursts: 1 };
            a.trigger_compress(1_000_000, sub, 1, 42, v).unwrap();
            a
        };
        let mut per_cycle = build();
        let mut bulk = build();
        let k = 777u64;
        for now in 0..k {
            // Mirrors Core::cycle on a fully stalled cycle: both issue
            // calls run (and find nothing active), then the utilization
            // observation sees zero slots used.
            let mut s = slots();
            let r = per_cycle.issue_high(now, &mut s);
            assert!(r.is_empty());
            let r = per_cycle.issue_low(now, &mut s);
            assert!(r.is_empty());
            per_cycle.observe_utilization(0, 4);
        }
        bulk.skip_idle_cycles(k, true, true);
        assert_eq!(per_cycle.rr, bulk.rr);
        assert_eq!(per_cycle.util_ema.to_bits(), bulk.util_ema.to_bits());
        // Empty row lists advance nothing.
        let mut empty_per = awc();
        let mut empty_bulk = awc();
        for now in 0..10 {
            let mut s = slots();
            empty_per.issue_high(now, &mut s);
            empty_per.issue_low(now, &mut s);
            empty_per.observe_utilization(0, 4);
        }
        empty_bulk.skip_idle_cycles(10, true, true);
        assert_eq!(empty_per.rr, empty_bulk.rr);
        assert_eq!(empty_per.util_ema.to_bits(), empty_bulk.util_ema.to_bits());
    }

    #[test]
    fn prop_settle_window_partitions_commute() {
        // The invariant the sharded tick leans on hardest: a core's stall
        // window may be settled in ONE `skip_idle_cycles` call (serial
        // fast-forward), or carved into arbitrary per-epoch sub-windows
        // (the shard loop settles up to each rendezvous boundary as it
        // reaches it). Every partition of the same window must land on the
        // bit-identical AWC state — round-robin pointer and utilization
        // EMA (including through the EMA's fixed-point early-out) — as
        // the cycle-by-cycle reference.
        use crate::util::miniprop::{default_cases, forall};

        #[derive(Debug)]
        struct Case {
            /// EMA priming iterations (0 ⇒ start at the 0.0 fixed point).
            prime: u64,
            /// Whether a (future-triggered, never-active) entry occupies
            /// the high/low row list — row membership gates rr advance.
            has_high: bool,
            has_low: bool,
            /// Whether the core would make the issue calls at all (they
            /// are design/config-gated) — forwarded as the
            /// `skip_idle_cycles` flags.
            call_high: bool,
            call_low: bool,
            /// Total idle window, and a partition of it into sub-windows
            /// (zeros allowed: an epoch boundary can land on a core that
            /// advanced nothing).
            total: u64,
            windows: Vec<u64>,
        }

        let sub = Subroutine { total: 4, mem: 1 };
        let v = LineVerdict { encoding: 0, size_bytes: 17, bursts: 1 };
        let build = |case: &Case| {
            let mut a = awc();
            for _ in 0..case.prime {
                a.observe_utilization(3, 4);
            }
            if case.has_high {
                a.trigger_decompress(1_000_000_000, sub, 0, 1, 0).unwrap();
            }
            if case.has_low {
                a.trigger_compress(1_000_000_000, sub, 1, 42, v).unwrap();
            }
            a
        };

        forall(
            "settle_window_partitions_commute",
            default_cases(),
            |r| {
                let total = 1 + r.below(5_000);
                let n_windows = 1 + r.range(0, 6);
                let mut cuts: Vec<u64> =
                    (0..n_windows - 1).map(|_| r.below(total + 1)).collect();
                cuts.sort_unstable();
                cuts.push(total);
                let mut windows = Vec::with_capacity(n_windows);
                let mut prev = 0;
                for c in cuts {
                    windows.push(c - prev);
                    prev = c;
                }
                Case {
                    prime: r.below(200),
                    has_high: r.chance(0.7),
                    has_low: r.chance(0.7),
                    call_high: r.chance(0.8),
                    call_low: r.chance(0.8),
                    total,
                    windows,
                }
            },
            |case| {
                // Cycle-by-cycle reference: exactly what Core::cycle does
                // on a fully stalled cycle.
                let mut reference = build(case);
                for now in 0..case.total {
                    let mut s = slots();
                    if case.call_high {
                        let r = reference.issue_high(now, &mut s);
                        crate::prop_assert!(
                            r.is_empty(),
                            "future-triggered entry retired at {now}"
                        );
                    }
                    if case.call_low {
                        let r = reference.issue_low(now, &mut s);
                        crate::prop_assert!(
                            r.is_empty(),
                            "future-triggered entry retired at {now}"
                        );
                    }
                    reference.observe_utilization(0, 4);
                }

                // One-shot settle over the whole window.
                let mut one_shot = build(case);
                one_shot.skip_idle_cycles(case.total, case.call_high, case.call_low);

                // The same window carved at arbitrary epoch boundaries.
                let mut carved = build(case);
                for &w in &case.windows {
                    carved.skip_idle_cycles(w, case.call_high, case.call_low);
                }

                for (name, got) in [("one-shot", &one_shot), ("carved", &carved)] {
                    crate::prop_assert!(
                        got.rr == reference.rr,
                        "{name}: rr {} != per-cycle {}",
                        got.rr,
                        reference.rr
                    );
                    crate::prop_assert!(
                        got.util_ema.to_bits() == reference.util_ema.to_bits(),
                        "{name}: ema {:?} != per-cycle {:?}",
                        got.util_ema,
                        reference.util_ema
                    );
                }
                Ok(())
            },
        );
    }
}
