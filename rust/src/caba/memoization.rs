//! CABA use case: **memoization** (paper §8.1).
//!
//! "In applications limited by available compute resources, memoization
//! offers an opportunity to trade off computation for storage": assist
//! warps hash the inputs of expensive (SFU) computations, probe a look-up
//! table kept in the unutilized shared memory, and on a hit skip the
//! computation entirely, loading the previous result instead.
//!
//! Modelled per the paper's sketch: (1) hash inputs at the trigger point,
//! (2) LUT probe through the load/store pipeline, (3) on hit, the result
//! loads from on-chip memory; on miss, the SFU computes and a low-priority
//! assist warp stores the result back. Input redundancy rates come from the
//! studies the paper cites ([8, 13, 98]: high redundancy in fragment /
//! transcendental computations).

/// Lookup subroutine: hash inputs (1 ALU), tag-probe+load (1 mem), select.
pub const LOOKUP_SUB_TOTAL: u16 = 3;
pub const LOOKUP_SUB_MEM: u16 = 1;
/// Result-install subroutine on a miss (low priority): address + store.
pub const INSTALL_SUB_TOTAL: u16 = 2;
pub const INSTALL_SUB_MEM: u16 = 1;

/// LUT hit latency: an on-chip shared-memory access.
pub const LUT_HIT_LATENCY: u64 = 24;

/// Fraction of SFU computations with previously-seen inputs, per app —
/// from the redundancy characterizations the paper cites (approximate
/// values for fragment/transcendental-heavy kernels; conservative 0.15
/// default elsewhere).
pub fn redundancy(app_name: &str) -> f64 {
    match app_name {
        "dmr" => 0.50, // iterative refinement re-evaluates many triangles
        "RAY" => 0.40, // shading reuse across adjacent rays
        "sr" => 0.35,  // diffusion coefficients repeat across the grid
        "bh" => 0.30,  // force terms repeat for far cells
        "bp" => 0.30,  // activation function on clustered sums
        "STO" => 0.20,
        _ => 0.15,
    }
}

/// Deterministic per-invocation hit draw (pure function of warp + pc so
/// runs are reproducible).
pub fn lut_hit(app_name: &str, warp_uid: u64, pc: u64) -> bool {
    let mut z = warp_uid
        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
        .wrapping_add(pc.wrapping_mul(0xD1B5_4A32_D192_ED03));
    z ^= z >> 29;
    z = z.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z ^= z >> 32;
    let p = (z as u32) as f64 / u32::MAX as f64;
    p < redundancy(app_name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hit_rate_tracks_redundancy() {
        for app in ["dmr", "RAY", "MM"] {
            let expected = redundancy(app);
            let hits = (0..20_000)
                .filter(|&i| lut_hit(app, i as u64 / 97, i as u64))
                .count() as f64
                / 20_000.0;
            assert!(
                (hits - expected).abs() < 0.02,
                "{app}: hit rate {hits} vs redundancy {expected}"
            );
        }
    }

    #[test]
    fn deterministic() {
        assert_eq!(lut_hit("dmr", 5, 100), lut_hit("dmr", 5, 100));
    }

    #[test]
    fn lookup_cheaper_than_sfu() {
        // The trade only makes sense if the LUT path beats the SFU latency.
        assert!(LUT_HIT_LATENCY < crate::SimConfig::default().sfu_latency as u64);
    }
}
