//! CABA use case: **opportunistic prefetching** (paper §8.2).
//!
//! Assist warps use spare registers for per-warp stride bookkeeping and the
//! idle memory pipeline to prefetch the warp's predicted next lines into
//! the L1 — "scheduling assist warps that perform prefetching only when
//! the memory pipelines are idle or underutilized".
//!
//! The predictor here is the paper's simple per-warp stride case: a
//! coalesced streaming access by warp *w* at iteration *i* will touch the
//! line its own access function yields at iteration *i + reuse* — which the
//! prefetch assist warp computes with the same address math the parent
//! executes (CABA runs real instructions, so it can run the *application's*
//! address computation — the paper's argument for hybrid software
//! prefetching, §8.2(2)).

use crate::isa::{AccessKind, MemAccess};
use crate::workload::Workload;

/// Instruction budget of the prefetch subroutine: load stride state,
/// compute next address, issue prefetch, update state (§8.2(1)).
pub const PREFETCH_SUB_TOTAL: u16 = 4;
pub const PREFETCH_SUB_MEM: u16 = 1;

/// How many iterations ahead to prefetch.
pub const PREFETCH_DEPTH: u32 = 2;

/// Lines the prefetcher would fetch for this access, or `None` when the
/// pattern is not stride-predictable (scatter) — the cases the paper
/// leaves to application-specific assist warps.
pub fn predict(
    wl: &Workload,
    mem: &MemAccess,
    warp_uid: u64,
    iter: u32,
    slot: usize,
    out: &mut Vec<u64>,
) -> bool {
    match mem.kind {
        AccessKind::Coalesced { reuse } => {
            let target = iter + reuse.max(1) as u32 * PREFETCH_DEPTH;
            if target as u64 >= wl.program.iters as u64 {
                return false;
            }
            wl.access_lines(mem, warp_uid, target, slot, out);
            true
        }
        AccessKind::Strided { .. } => {
            let target = iter + PREFETCH_DEPTH;
            if target as u64 >= wl.program.iters as u64 {
                return false;
            }
            wl.access_lines(mem, warp_uid, target, slot, out);
            true
        }
        AccessKind::Scatter { .. } => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::apps;
    use crate::SimConfig;

    #[test]
    fn predicts_own_future_lines() {
        let app = apps::find("SLA").unwrap();
        let wl = Workload::build(app, &SimConfig::default(), 0.2);
        let mem = MemAccess { array: 0, kind: AccessKind::Coalesced { reuse: 1 } };
        let mut now = Vec::new();
        let mut pred = Vec::new();
        wl.access_lines(&mem, 7, 5 + PREFETCH_DEPTH, 0, &mut now);
        assert!(predict(&wl, &mem, 7, 5, 0, &mut pred));
        assert_eq!(now, pred, "prediction must equal the future demand access");
    }

    #[test]
    fn scatter_not_predicted() {
        let app = apps::find("bfs").unwrap();
        let wl = Workload::build(app, &SimConfig::default(), 0.2);
        let mem = MemAccess { array: 1, kind: AccessKind::Scatter { degree: 4 } };
        let mut pred = Vec::new();
        assert!(!predict(&wl, &mem, 3, 2, 1, &mut pred));
    }

    #[test]
    fn no_prefetch_past_end() {
        let app = apps::find("SLA").unwrap();
        let wl = Workload::build(app, &SimConfig::default(), 0.05);
        let mem = MemAccess { array: 0, kind: AccessKind::Coalesced { reuse: 1 } };
        let last = wl.program.iters - 1;
        let mut pred = Vec::new();
        assert!(!predict(&wl, &mem, 0, last, 0, &mut pred));
    }
}
