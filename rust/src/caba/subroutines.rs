//! Assist-warp subroutine shapes: how many instructions (and how many of
//! them are memory ops) each (algorithm × encoding × direction) subroutine
//! executes on the SIMT pipelines.
//!
//! These are the instruction sequences the paper stores in the Assist Warp
//! Store (Figs. 4–5), derived from Algorithms 1–6. The simulator charges
//! each instruction a real issue slot and pipeline, which is exactly the
//! CABA-vs-Ideal overhead the paper quantifies (§7.1: CABA-BDI within 2.8%
//! of Ideal-BDI).

use crate::compress::{bdi, cpack, fpc, Algo};

/// Direction of an assist-warp subroutine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AwKind {
    Decompress,
    Compress,
}

/// Instruction budget of one subroutine instance.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Subroutine {
    /// Total instructions issued by this assist warp.
    pub total: u16,
    /// Of which memory-pipeline instructions (loads/stores of the line).
    pub mem: u16,
}

impl Subroutine {
    pub fn sp(&self) -> u16 {
        self.total - self.mem
    }
}

/// Number of encodings Algorithm 2/4/6 tests before settling on `encoding`
/// (drives compression-subroutine length).
fn bdi_tests(encoding: u8) -> u16 {
    // Candidates are tried smallest-first (see `bdi::BASE_DELTA_ENCODINGS`);
    // zeros/repeat are detected by the first two cheap checks.
    match encoding {
        bdi::ENC_ZEROS => 1,
        bdi::ENC_REPEAT => 2,
        _ => {
            let mut order = bdi::BASE_DELTA_ENCODINGS;
            order.sort_by_key(|&(_, b, d)| bdi::encoded_size(b, d));
            order
                .iter()
                .position(|&(e, _, _)| e == encoding)
                .map(|p| p as u16 + 3)
                .unwrap_or(9) // uncompressed: tried everything
        }
    }
}

/// Look up the subroutine shape.
///
/// `direct_load` (Fig. 16) shortens decompression: only the requested words
/// are extracted instead of materializing the whole line.
pub fn subroutine(algo: Algo, kind: AwKind, encoding: u8, direct_load: bool) -> Subroutine {
    let s = match (algo, kind) {
        (Algo::Bdi, AwKind::Decompress) => {
            let total = bdi::decompress_subroutine_len(encoding) as u16;
            // Algorithm 1: load base+deltas (≈1/3), add, store (≈1/4).
            Subroutine { total, mem: (total / 3).max(1) + (total / 4).max(1) }
        }
        (Algo::Bdi, AwKind::Compress) => {
            // Algorithm 2: load values (2 wide loads), then per tested
            // encoding: subtract, predicate-AND, size check (≈3 insts),
            // finally store base+deltas (2).
            let tests = bdi_tests(encoding);
            Subroutine { total: 4 + 3 * tests, mem: 4 }
        }
        (Algo::Fpc, AwKind::Decompress) => {
            let total = fpc::decompress_subroutine_len(4) as u16;
            Subroutine { total, mem: 8 } // per-segment load + store
        }
        (Algo::Fpc, AwKind::Compress) => {
            let total = fpc::compress_subroutine_len(4, 2) as u16;
            Subroutine { total, mem: 9 }
        }
        (Algo::CPack, AwKind::Decompress) => {
            let total = cpack::decompress_subroutine_len() as u16;
            Subroutine { total, mem: 7 } // dict loads + masked loads + stores
        }
        (Algo::CPack, AwKind::Compress) => {
            // Algorithm 6 serially builds the dictionary: at least 3 and up
            // to 4 candidate values are tested against the whole line.
            let dict = (encoding.min(4) as u16).clamp(3, 4);
            let total = cpack::compress_subroutine_len(dict as usize) as u16;
            Subroutine { total, mem: 5 }
        }
        (Algo::BestOfAll, kind) => {
            // Selection is idealized (paper §7.3); charge the BDI path.
            return subroutine(Algo::Bdi, kind, encoding, direct_load);
        }
    };
    if direct_load && kind == AwKind::Decompress {
        // Extract only the needed words: ~1/4 the work, minimum 2 insts.
        Subroutine {
            total: (s.total / 4).max(2),
            mem: (s.mem / 4).max(1),
        }
    } else {
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_is_cheapest_bdi_decompress() {
        let z = subroutine(Algo::Bdi, AwKind::Decompress, bdi::ENC_ZEROS, false);
        let d1 = subroutine(Algo::Bdi, AwKind::Decompress, bdi::ENC_B8D1, false);
        let d2b = subroutine(Algo::Bdi, AwKind::Decompress, bdi::ENC_B2D1, false);
        assert!(z.total < d1.total);
        assert!(d1.total < d2b.total);
    }

    #[test]
    fn compression_longer_than_decompression() {
        // The paper gives compression low priority partly because it is the
        // longer, off-critical-path direction.
        for algo in Algo::CONCRETE {
            let d = subroutine(algo, AwKind::Decompress, 2, false);
            let c = subroutine(algo, AwKind::Compress, 2, false);
            assert!(c.total >= d.total, "{algo:?}: c={} d={}", c.total, d.total);
        }
    }

    #[test]
    fn bdi_tests_monotonic_with_encoding_order() {
        assert_eq!(bdi_tests(bdi::ENC_ZEROS), 1);
        assert_eq!(bdi_tests(bdi::ENC_REPEAT), 2);
        assert!(bdi_tests(bdi::ENC_B8D1) < bdi_tests(bdi::ENC_B8D4));
        assert_eq!(bdi_tests(bdi::ENC_UNCOMPRESSED), 9);
    }

    #[test]
    fn direct_load_shortens_decompress() {
        let full = subroutine(Algo::Bdi, AwKind::Decompress, bdi::ENC_B8D1, false);
        let dl = subroutine(Algo::Bdi, AwKind::Decompress, bdi::ENC_B8D1, true);
        assert!(dl.total < full.total);
        assert!(dl.mem >= 1);
        // Compression is unaffected.
        let c1 = subroutine(Algo::Bdi, AwKind::Compress, 2, false);
        let c2 = subroutine(Algo::Bdi, AwKind::Compress, 2, true);
        assert_eq!(c1, c2);
    }

    #[test]
    fn mem_never_exceeds_total() {
        for algo in Algo::CONCRETE {
            for kind in [AwKind::Decompress, AwKind::Compress] {
                for enc in 0..16u8 {
                    let s = subroutine(algo, kind, enc, false);
                    assert!(s.mem <= s.total, "{algo:?} {kind:?} enc={enc}");
                    assert!(s.total > 0);
                }
            }
        }
    }
}
