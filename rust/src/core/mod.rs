//! The SM (streaming multiprocessor) model: warp contexts, GTO scheduling
//! with two schedulers per SM, a scoreboard over per-register ready times,
//! SP/SFU/LSU issue slots, a coalescing load-store unit with MSHRs, a
//! private L1, and the per-SM CABA Assist Warp Controller.
//!
//! Issue-cycle accounting follows Fig. 2's taxonomy exactly: each scheduler
//! slot each cycle is *active* or charged to compute-structural,
//! memory-structural, data-dependence, or idle.
//!
//! # Two-phase cycle protocol
//!
//! Each simulated cycle splits into two phases so the run loop can shard
//! phase A across threads (`sim_threads`, DESIGN.md §3):
//!
//! * **Phase A — [`Core::cycle`]**: everything core-local (scheduling,
//!   scoreboard, FU/LSU/MSHR structural checks, AWC issue, address
//!   generation). It sees only shared *read-only* state ([`CoreCtx`]) and
//!   queues each side effect that must touch the shared chip
//!   ([`MemSystem`], [`DataModel`], [`SimStats`]) as a [`SharedOp`].
//! * **Phase B — [`Core::drain`]**: the queued ops are applied through
//!   [`DrainCtx`], always on one thread, always in SM order. The drain
//!   replays the exact shared-state op sequence the pre-split serial code
//!   performed, so results are bit-identical no matter how phase A was
//!   scheduled — one thread or many.

pub mod tables;

use crate::caba::subroutines::{subroutine, AwKind};
use crate::caba::{Awc, Payload, Retirement, Slots};
use crate::config::SimConfig;
use crate::isa::{FuKind, Op, MAX_REGS};
use crate::mem::cache::Cache;
use crate::mem::MemSystem;
use crate::memo::{self, MemoGeometry, MemoLut};
use crate::sim::designs::{Design, Mechanism};
use crate::sim::DataModel;
use crate::stats::{IssueBreakdown, SimStats, StallKind};
use crate::telemetry::CoreRecorder;
use crate::workload::Workload;
use tables::{MshrInfo, MshrTable, ReleaseTable};

/// Sentinel: register is waiting on an assist-warp retirement.
const PENDING: u64 = u64::MAX;

/// One resident warp context.
#[derive(Clone, Debug)]
pub struct WarpSlot {
    /// Global warp id (drives address generation); `u64::MAX` = slot empty.
    pub uid: u64,
    /// Position in the unrolled program (0..total_insts).
    pub pc: u64,
    /// Cached `pc % body_len` (avoids div/mod in the hot scan).
    pub body_idx: u32,
    /// Cached `pc / body_len`.
    pub iter: u32,
    pub done: bool,
    /// Scoreboard memo: the warp cannot issue before this cycle
    /// (`u64::MAX` while waiting on an assist-warp release).
    pub blocked_until: u64,
    /// Cycle each register's value becomes available ([`PENDING`] =
    /// blocked on an assist warp).
    pub reg_ready: [u64; MAX_REGS],
    /// CTA group on this core this warp belongs to.
    pub group: usize,
}

impl WarpSlot {
    fn empty() -> WarpSlot {
        WarpSlot {
            uid: u64::MAX,
            pc: 0,
            body_idx: 0,
            iter: 0,
            done: true,
            blocked_until: 0,
            reg_ready: [0; MAX_REGS],
            group: 0,
        }
    }

    fn live(&self) -> bool {
        self.uid != u64::MAX && !self.done
    }
}

/// Read-only chip state visible during phase A ([`Core::cycle`]). The
/// borrow checker, not discipline, is what keeps the sharded phase A free
/// of shared mutation: there is simply no `&mut` here to misuse.
pub struct CoreCtx<'a> {
    pub cfg: &'a SimConfig,
    pub design: &'a Design,
    pub wl: &'a Workload,
}

/// Mutable chip state visible during phase B ([`Core::drain`]), which the
/// run loop only ever enters on one thread, in SM order.
pub struct DrainCtx<'a> {
    pub cfg: &'a SimConfig,
    pub design: &'a Design,
    pub wl: &'a Workload,
    pub mem: &'a mut MemSystem,
    pub data: &'a mut DataModel,
    pub stats: &'a mut SimStats,
}

/// A side effect generated during phase A that must touch shared chip
/// state. Queued in [`Core::cycle`] in the exact order the pre-split code
/// performed the corresponding mutations (retirements before scheduled
/// accesses), and applied verbatim in that order by [`Core::drain`].
enum SharedOp {
    /// A compression assist warp retired: dispatch the buffered store.
    /// `at` is the retirement time (≤ now), kept because the pre-split
    /// code stamped the store with it, not with the cycle it was applied.
    CompressRetire {
        at: u64,
        line_addr: u64,
        verdict: crate::compress::oracle::LineVerdict,
    },
    /// A prefetch assist warp retired: issue its predicted lines.
    PrefetchRetire { at: u64, lines: Vec<u64> },
    /// A load issued; its coalesced line addresses live in
    /// `Core::op_arena[start .. start + len]`.
    Load {
        w: usize,
        uid: u64,
        access: crate::isa::MemAccess,
        dst: u8,
        iter: u32,
        body_idx: u32,
        start: u32,
        len: u32,
    },
    /// A store issued (lines in the arena, as for `Load`).
    Store {
        w: usize,
        uid: u64,
        access: crate::isa::MemAccess,
        iter: u32,
        body_idx: u32,
        start: u32,
        len: u32,
    },
}

/// One SM.
pub struct Core {
    pub sm_id: usize,
    pub warps: Vec<WarpSlot>,
    pub l1: Cache,
    pub awc: Awc,
    /// §8.1 per-SM memoization LUT (zero-capacity on non-memo designs).
    pub memo: MemoLut,
    /// Greedy (GTO) warp per scheduler (sized by `schedulers_per_sm`).
    greedy: Vec<Option<usize>>,
    /// Warp slots per scheduler in age (uid) order — rebuilt on CTA launch,
    /// so the per-cycle GTO scan allocates nothing.
    sched_order: Vec<Vec<usize>>,
    /// Last stall classification per scheduler, memoized for the
    /// event-driven tick: valid for every cycle in `(last executed,
    /// next_event)` because nothing on the core can change state inside
    /// that window (every transient stall source pins `next_event` to the
    /// very next cycle — see DESIGN.md §3, wake-source contract).
    stall_memo: Vec<StallKind>,
    /// Earliest operand-ready time seen by the schedulers this cycle
    /// (fast-forward hint collected during the issue scan itself).
    min_ready_hint: u64,
    /// LSU serializes one line transaction per cycle.
    lsu_free_at: u64,
    /// Per-SFU-unit pipeline occupancy: a warp SFU instruction holds a
    /// unit for `sfu_issue_interval` cycles (quarter-rate SFU lanes).
    sfu_free_at: Vec<u64>,
    sfu_issue_interval: u64,
    /// Per-warp-slot memo operand-key cache `(uid, pc, key)`: the key is a
    /// pure function of the instruction instance, and a blocked SFU op
    /// re-probes the LUT every cycle — hash once per instruction, not once
    /// per stalled cycle.
    memo_key_cache: Vec<(u64, u64, u64)>,
    mshr: MshrTable,
    mshr_limit: usize,
    releases: ReleaseTable,
    pending_retires: Vec<Retirement>,
    /// Reusable scratch for address generation (no per-cycle allocation).
    lines_scratch: Vec<u64>,
    /// Reusable scratch for L1 fills (evictions are clean write-through
    /// victims and always discarded) — `lines_scratch` pattern.
    l1_evict_scratch: Vec<crate::mem::cache::Eviction>,
    /// Reusable scratch for prefetch address prediction.
    prefetch_scratch: Vec<u64>,
    /// Buffered stores awaiting compression (paper §5.2.2 store buffer).
    pending_compress_stores: usize,
    store_buffer_cap: usize,
    /// Shared-state side effects queued by phase A this cycle, applied (and
    /// emptied) by [`Core::drain`].
    shared_ops: Vec<SharedOp>,
    /// Line-address arena backing `SharedOp::{Load,Store}`; cleared each
    /// drain so accesses never allocate a payload `Vec`.
    op_arena: Vec<u64>,
    /// Phase-A deltas for the global instruction counters (phase A cannot
    /// reach `SimStats`); flushed first thing in [`Core::drain`] so the run
    /// loop's `max_warp_insts` budget check stays cycle-exact.
    d_warp_insts: u64,
    d_thread_insts: u64,
    d_core_insts: u64,
    pub issue: IssueBreakdown,
    /// Per-SM flight-recorder timeline (no-op unless `telemetry_window`
    /// is set). Windows close lazily inside [`Core::settle_to`] — the one
    /// place every tick mode funnels through with the boundary-state
    /// contract ("state at start of cycle `b`") intact.
    pub tl: CoreRecorder,
    /// Earliest future cycle at which anything on this core can change
    /// state (fast-forward hint; `u64::MAX` = fully drained).
    pub next_event: u64,
    /// First cycle not yet accounted in `issue` — the event-driven run
    /// loop skips this core while `next_event > now`, and
    /// [`Core::settle_to`] bulk-charges the skipped window on wake.
    charged_until: u64,
    /// Cached [`Core::any_live`] — valid while the core is skipped
    /// (liveness only changes inside `cycle` / `launch_cta`).
    live_cache: bool,
    /// Set when a warp retires this cycle (CTA-refill eligibility can only
    /// arise then; the run loop gates its refill scan on this).
    warp_retired: bool,
}

impl Core {
    pub fn new(sm_id: usize, cfg: &SimConfig, design: &Design, memo_geom: &MemoGeometry) -> Core {
        Core {
            sm_id,
            warps: vec![WarpSlot::empty(); cfg.max_warps_per_sm],
            l1: Cache::new(cfg.l1_bytes, cfg.l1_assoc, cfg.line_bytes, design.l1_tag_mult),
            awc: Awc::new(cfg),
            memo: MemoLut::new(*memo_geom),
            greedy: vec![None; cfg.schedulers_per_sm],
            sched_order: vec![Vec::new(); cfg.schedulers_per_sm],
            stall_memo: vec![StallKind::Idle; cfg.schedulers_per_sm],
            min_ready_hint: u64::MAX,
            lsu_free_at: 0,
            sfu_free_at: vec![0; cfg.sfu_units],
            sfu_issue_interval: cfg.sfu_issue_interval as u64,
            memo_key_cache: vec![(u64::MAX, u64::MAX, 0); cfg.max_warps_per_sm],
            mshr: MshrTable::new(cfg.l1_mshrs, cfg.warp_size),
            mshr_limit: cfg.l1_mshrs,
            releases: ReleaseTable::new(cfg.max_warps_per_sm),
            pending_retires: Vec::new(),
            lines_scratch: Vec::new(),
            l1_evict_scratch: Vec::new(),
            prefetch_scratch: Vec::new(),
            pending_compress_stores: 0,
            store_buffer_cap: 16,
            shared_ops: Vec::new(),
            op_arena: Vec::new(),
            d_warp_insts: 0,
            d_thread_insts: 0,
            d_core_insts: 0,
            issue: IssueBreakdown::default(),
            tl: CoreRecorder::new(cfg.telemetry_window, cfg.max_cycles),
            next_event: 0,
            charged_until: 0,
            live_cache: false,
            warp_retired: false,
        }
    }

    /// Launch one CTA into warp slots `[group*wpc, (group+1)*wpc)`.
    pub fn launch_cta(&mut self, group: usize, cta_id: u64, wl: &Workload) {
        let wpc = wl.occ.warps_per_cta as usize;
        for i in 0..wpc {
            let slot = group * wpc + i;
            self.warps[slot] = WarpSlot {
                uid: cta_id * wpc as u64 + i as u64,
                pc: 0,
                body_idx: 0,
                iter: 0,
                done: false,
                blocked_until: 0,
                reg_ready: [0; MAX_REGS],
                group,
            };
        }
        self.next_event = 0;
        self.live_cache = true;
        self.rebuild_sched_order();
    }

    fn rebuild_sched_order(&mut self) {
        // Warp slots interleave across however many schedulers the config
        // asks for (`schedulers_per_sm` is not hard-coded to 2: `--set
        // schedulers_per_sm=4` must size these structures, not index out
        // of bounds).
        let n = self.sched_order.len();
        for sched in 0..n {
            let mut slots: Vec<usize> = (0..self.warps.len())
                .filter(|&i| i % n == sched && self.warps[i].uid != u64::MAX)
                .collect();
            slots.sort_by_key(|&i| self.warps[i].uid);
            self.sched_order[sched] = slots;
        }
    }

    /// CTA groups whose warps have all retired.
    pub fn group_done(&self, group: usize, wl: &Workload) -> bool {
        let wpc = wl.occ.warps_per_cta as usize;
        let base = group * wpc;
        self.warps[base..base + wpc]
            .iter()
            .all(|w| w.uid == u64::MAX || w.done)
    }

    /// Any live warp on this core?
    pub fn any_live(&self) -> bool {
        self.warps.iter().any(|w| w.live())
    }

    /// Cached liveness — valid while the core is skipped (nothing can
    /// retire a warp without the core cycling).
    pub fn live_cached(&self) -> bool {
        self.live_cache
    }

    /// Did a warp retire during the last executed cycle? (Consumes the
    /// flag.) CTA-refill eligibility can only arise at such cycles.
    pub fn take_warp_retired(&mut self) -> bool {
        std::mem::take(&mut self.warp_retired)
    }

    /// Bulk-charge the skipped window `[charged_until, now)` exactly as the
    /// per-cycle path would have: each scheduler's memoized stall
    /// classification once per skipped cycle, plus the AWC's per-idle-cycle
    /// effects ([`Awc::skip_idle_cycles`]). The memoized classification is
    /// exact, not approximate: a window only opens after a cycle on which
    /// *no* scheduler issued, and every stall condition that could clear
    /// before `next_event` pins `next_event` to the very next cycle, so the
    /// per-cycle path would re-derive the identical `StallKind` on every
    /// skipped cycle (proved per stall source in DESIGN.md §3).
    /// With telemetry on, any window boundary inside `[charged_until, now]`
    /// is closed here with the bulk charge *split* at the boundary: the
    /// issue breakdown is charged up to the boundary first, sampled, then
    /// charging resumes — so the per-window deltas are bit-identical to the
    /// strict per-cycle path. Everything else sampled at a boundary (L1 /
    /// CABA stats, AWT occupancy) is frozen across a skipped window
    /// ([`Awc::skip_idle_cycles`] touches only scheduling state), and MSHR
    /// occupancy is sampled sweep-invariantly
    /// ([`MshrTable::count_fills_at_or_after`]), so the boundary snapshot
    /// needs no further splitting. The AWC skip itself stays ONE call with
    /// the full window (partition-commutativity is pinned by
    /// `prop_settle_window_partitions_commute`).
    pub fn settle_to(&mut self, now: u64, cfg: &SimConfig, design: &Design) {
        debug_assert!(self.charged_until <= now, "core settled backwards");
        let k = now - self.charged_until;
        if self.tl.enabled() {
            while self.tl.next_boundary() <= now {
                let b = self.tl.next_boundary();
                let step = b - self.charged_until;
                if step > 0 {
                    for &kind in &self.stall_memo {
                        self.issue.bulk_charge(kind, step);
                    }
                    self.charged_until = b;
                }
                let mshr_inflight = self.mshr.count_fills_at_or_after(b);
                self.tl.close_window(
                    &self.issue,
                    &self.awc.stats,
                    &self.l1.stats,
                    mshr_inflight,
                    self.awc.live() as u32,
                );
            }
        }
        let rest = now - self.charged_until;
        if rest > 0 {
            for &kind in &self.stall_memo {
                self.issue.bulk_charge(kind, rest);
            }
            self.charged_until = now;
        }
        if k > 0 {
            let high = design.uses_assist_warps();
            let low = high && (cfg.sp_units > 0 || cfg.mem_units > 0);
            self.awc.skip_idle_cycles(k, high, low);
        }
    }

    /// Close the flight recorder's partial tail window at end of run
    /// (call after the final [`Core::settle_to`]).
    pub fn finish_telemetry(&mut self, now: u64) {
        let mshr_inflight = self.mshr.count_fills_at_or_after(now);
        self.tl.finish(
            now,
            &self.issue,
            &self.awc.stats,
            &self.l1.stats,
            mshr_inflight,
            self.awc.live() as u32,
        );
    }

    /// Advance this SM by one cycle — phase A only. Every shared-state
    /// side effect lands in the op queue; the caller must follow up with
    /// [`Core::drain`] (on one thread, in SM order) before the next cycle.
    pub fn cycle(&mut self, now: u64, ctx: &CoreCtx) {
        debug_assert!(
            self.shared_ops.is_empty() && self.op_arena.is_empty(),
            "cycle() called with undrained shared ops"
        );
        // Charge any skipped window ending at this wake (no-op when the
        // core ran last cycle, and always a no-op under strict_tick).
        self.settle_to(now, ctx.cfg, ctx.design);

        // 0. Apply due assist-warp retirements (shared-state halves are
        //    queued; they drain ahead of this cycle's scheduled accesses,
        //    matching the pre-split intra-cycle order).
        self.apply_retirements(now);

        let mut slots = Slots {
            sp: ctx.cfg.sp_units,
            sfu: ctx.cfg.sfu_units,
            mem: ctx.cfg.mem_units,
        };
        let total_slots = slots.sp + slots.sfu + slots.mem;

        // 1. High-priority assist warps issue ahead of parent warps.
        if ctx.design.uses_assist_warps() {
            let retires = self.awc.issue_high(now, &mut slots);
            self.pending_retires.extend(retires);
        }

        // 2. Parent-warp issue: one instruction per scheduler.
        let mut any_parent_issued = false;
        for sched in 0..ctx.cfg.schedulers_per_sm {
            let issued = self.schedule(now, sched, &mut slots, ctx);
            any_parent_issued |= issued;
        }

        // 3. Low-priority assist warps fill leftover slots (idle cycles).
        if ctx.design.uses_assist_warps() && (slots.sp > 0 || slots.mem > 0) {
            let retires = self.awc.issue_low(now, &mut slots);
            self.pending_retires.extend(retires);
        }

        let used = total_slots - (slots.sp + slots.sfu + slots.mem);
        self.awc.observe_utilization(used, total_slots);
        let _ = any_parent_issued;

        // Fast-forward hint: earliest time collected during the issue scan,
        // plus pending retirements and live assist-warp work.
        let mut next = self.min_ready_hint;
        for r in &self.pending_retires {
            next = next.min(r.at);
        }
        if self.awc.live() > 0 {
            next = next.min(self.awc.next_active(now));
        }
        self.next_event = next.max(now + 1);
        self.min_ready_hint = u64::MAX;
        self.live_cache = self.any_live();
        self.charged_until = now + 1;
    }

    fn apply_retirements(&mut self, now: u64) {
        if self.pending_retires.is_empty() {
            return;
        }
        let mut i = 0;
        while i < self.pending_retires.len() {
            if self.pending_retires[i].at <= now {
                let r = self.pending_retires.swap_remove(i);
                match r.payload {
                    Payload::Decompress { regs } => {
                        for (w, reg, uid) in regs {
                            self.release_part(w, reg, uid, r.at);
                        }
                    }
                    Payload::Compress { line_addr, verdict } => {
                        // The store-buffer slot frees now (core-local so
                        // this cycle's scheduling sees it); the store
                        // itself touches shared state and drains later.
                        self.pending_compress_stores =
                            self.pending_compress_stores.saturating_sub(1);
                        self.shared_ops.push(SharedOp::CompressRetire {
                            at: r.at,
                            line_addr,
                            verdict,
                        });
                    }
                    Payload::Prefetch { lines } => {
                        self.shared_ops.push(SharedOp::PrefetchRetire { at: r.at, lines });
                    }
                    Payload::MemoInstall { key } => {
                        // The result becomes reusable only now, when the
                        // low-priority install warp retires.
                        let evicted = self.memo.install(key, r.at);
                        self.awc.stats.memo_installs += 1;
                        if evicted {
                            self.awc.stats.memo_evictions += 1;
                        }
                    }
                }
            } else {
                i += 1;
            }
        }
    }

    /// Apply this core's queued shared-state side effects for cycle `now`
    /// — phase B. Called for *every* core the run loop cycled, on one
    /// thread, in SM order; with phase A confined to [`CoreCtx`], this
    /// serial drain is the only writer of shared chip state, so the
    /// mutation sequence (and therefore every stat) is identical whether
    /// phase A ran on one thread or sixteen.
    pub fn drain(&mut self, now: u64, ctx: &mut DrainCtx) {
        ctx.stats.warp_insts += self.d_warp_insts;
        ctx.stats.thread_insts += self.d_thread_insts;
        ctx.stats.energy_events.core_insts += self.d_core_insts;
        self.d_warp_insts = 0;
        self.d_thread_insts = 0;
        self.d_core_insts = 0;
        if self.shared_ops.is_empty() {
            debug_assert!(self.op_arena.is_empty());
            return;
        }
        let mut ops = std::mem::take(&mut self.shared_ops);
        for op in ops.drain(..) {
            match op {
                SharedOp::CompressRetire { at, line_addr, verdict } => {
                    ctx.data.set_stored_compressed(line_addr, verdict.is_compressed());
                    ctx.mem.store(at, self.sm_id, line_addr, ctx.design, Some(verdict));
                }
                SharedOp::PrefetchRetire { at, lines } => {
                    self.drain_prefetch(at, &lines, ctx);
                }
                SharedOp::Load { w, uid, access, dst, iter, body_idx, start, len } => {
                    // An access op implies an issue, which already pinned
                    // `next_event` to the next cycle in phase A — nothing
                    // the drain does here can create an earlier wake.
                    debug_assert_eq!(self.next_event, now + 1);
                    self.exec_load(
                        now, w, uid, &access, dst, iter,
                        body_idx as usize, start as usize, len as usize, ctx,
                    );
                }
                SharedOp::Store { w, uid, access, iter, body_idx, start, len } => {
                    debug_assert_eq!(self.next_event, now + 1);
                    self.exec_store(
                        now, w, uid, &access, iter,
                        body_idx as usize, start as usize, len as usize, ctx,
                    );
                }
            }
        }
        self.shared_ops = ops;
        self.op_arena.clear();
    }

    /// Drain half of a retired prefetch assist warp: issue the predicted
    /// lines into the memory system and pre-fill the L1; a later demand
    /// load merges on the MSHR entry (§8.2).
    fn drain_prefetch(&mut self, at: u64, lines: &[u64], ctx: &mut DrainCtx) {
        for &line in lines {
            if self.l1.contains(line) || self.mshr.contains_key(line) {
                continue;
            }
            if self.mshr.len() >= self.mshr_limit {
                break; // never starve demand misses
            }
            let algo = ctx.design.algo;
            let outcome = {
                let data = &mut *ctx.data;
                let wl = ctx.wl;
                let mut verdict = || data.verdict(wl, algo, line);
                ctx.mem.load(at, self.sm_id, line, ctx.design, &mut verdict)
            };
            ctx.stats.l2.accesses += 1;
            if outcome.l2_hit {
                ctx.stats.l2.hits += 1;
            } else {
                ctx.stats.l2.misses += 1;
            }
            self.l1.insert_into(line, false, 4, false, at, &mut self.l1_evict_scratch);
            self.mshr
                .insert(line, MshrInfo { fill_at: outcome.data_at, awc_token: None });
            self.awc.stats.prefetches_issued += 1;
        }
    }

    /// Memo operand key for warp `w`'s current instruction, cached per
    /// `(uid, pc)` so blocked warps don't re-hash every cycle.
    fn memo_key(&mut self, wl: &Workload, w: usize, iter: u32, body_idx: usize) -> u64 {
        let uid = self.warps[w].uid;
        let pc = self.warps[w].pc;
        let (cu, cp, ck) = self.memo_key_cache[w];
        if cu == uid && cp == pc {
            return ck;
        }
        let key = crate::workload::values::operand_key(&wl.spec.values, wl.seed, uid, iter, body_idx);
        self.memo_key_cache[w] = (uid, pc, key);
        key
    }

    fn release_part(&mut self, warp: usize, reg: u8, uid: u64, at: u64) {
        if let Some(floor) = self.releases.release(warp, reg, uid, at) {
            let w = &mut self.warps[warp];
            // The uid guard (here and in the table) keeps a release that
            // outlives its warp instance from delaying the slot's next
            // tenant — warp slots are recycled across CTA refills.
            if w.uid == uid && w.live() {
                w.reg_ready[reg as usize] = floor;
                w.blocked_until = 0;
            }
        }
    }

    /// One scheduler's issue attempt. Returns true if it issued.
    fn schedule(&mut self, now: u64, sched: usize, slots: &mut Slots, ctx: &CoreCtx) -> bool {
        let mut saw_data = false;
        let mut saw_compute_struct = false;
        let mut saw_mem_struct = false;
        let mut any_candidate = false;

        // GTO order: greedy warp first, then oldest (precomputed at launch).
        let greedy = self.greedy[sched].filter(|&g| self.warps[g].live());
        let order = std::mem::take(&mut self.sched_order[sched]);
        let candidates = greedy
            .into_iter()
            .chain(order.iter().copied().filter(|&i| Some(i) != greedy));

        let mut issued = false;
        for w in candidates {
            if !self.warps[w].live() {
                continue;
            }
            any_candidate = true;
            // Scoreboard memo: skip warps known to be blocked.
            let bu = self.warps[w].blocked_until;
            if bu > now {
                saw_data = true;
                if bu != PENDING {
                    self.min_ready_hint = self.min_ready_hint.min(bu);
                }
                continue;
            }
            let iter = self.warps[w].iter;
            let body_idx = self.warps[w].body_idx as usize;
            let inst = ctx.wl.program.body[body_idx];

            // Scoreboard: sources and destination must be ready. The
            // earliest future ready time doubles as the fast-forward hint.
            let wslot = &self.warps[w];
            let mut inst_ready = now;
            for r in inst.sources() {
                inst_ready = inst_ready.max(wslot.reg_ready[r as usize]);
            }
            if (inst.dst as usize) < MAX_REGS {
                inst_ready = inst_ready.max(wslot.reg_ready[inst.dst as usize]);
            }
            if inst_ready > now {
                saw_data = true;
                self.warps[w].blocked_until = inst_ready;
                if inst_ready != PENDING {
                    self.min_ready_hint = self.min_ready_hint.min(inst_ready);
                }
                continue;
            }

            // Structural: FU slot availability.
            match inst.op.fu() {
                FuKind::Sp if slots.sp == 0 => {
                    saw_compute_struct = true;
                    // Slot contention is transient (another warp consumed
                    // the slot this very cycle), so the wake hint is the
                    // next cycle — folded in with `.min` like every other
                    // hint update, so the `min_ready_hint` lower-bound
                    // invariant survives reordering of these arms.
                    self.min_ready_hint = self.min_ready_hint.min(now + 1);
                    continue;
                }
                FuKind::Sfu => {
                    // Dispatch needs a per-cycle issue slot AND a free SFU
                    // unit (quarter-rate lanes keep a unit busy for
                    // `sfu_issue_interval` cycles). A memoized op whose
                    // operands are resident in the LUT needs neither — it
                    // takes the shared-memory path (§8.1: storage instead
                    // of computation) — provided an AWT row is free for
                    // the lookup warp.
                    let unit_free = self.sfu_free_at.iter().any(|&t| t <= now);
                    if slots.sfu == 0 || !unit_free {
                        let bypasses = ctx.design.memoization
                            && self.memo.enabled()
                            && self.awc.has_free_row()
                            && {
                                let key = self.memo_key(ctx.wl, w, iter, body_idx);
                                self.memo.would_hit(key)
                            };
                        if !bypasses {
                            saw_compute_struct = true;
                            let free = if slots.sfu == 0 || unit_free {
                                now + 1
                            } else {
                                self.sfu_free_at.iter().copied().min().unwrap_or(now + 1)
                            };
                            self.min_ready_hint = self.min_ready_hint.min(free.max(now + 1));
                            continue;
                        }
                    }
                }
                FuKind::Mem => {
                    if slots.mem == 0 || self.lsu_free_at > now {
                        saw_mem_struct = true;
                        self.min_ready_hint =
                            self.min_ready_hint.min(self.lsu_free_at.max(now + 1));
                        continue;
                    }
                    // Estimate transactions for MSHR headroom.
                    if self.mshr.len() >= self.mshr_limit {
                        self.sweep_mshr(now);
                        if self.mshr.len() >= self.mshr_limit {
                            saw_mem_struct = true;
                            // Precise wake: a full MSHR drains only when an
                            // in-flight fill crosses `now` (entries pinned
                            // by a live assist warp are covered by the AWC
                            // activity hint in `cycle`), so the next fill
                            // time is a sound lower bound on this stall
                            // clearing — no `now + 1` spin needed. The scan
                            // is skipped under strict_tick, where hints are
                            // never consumed: paying O(table) per stalled
                            // cycle there would skew the reference baseline
                            // the tick benchmark compares against.
                            let wake = if ctx.cfg.strict_tick {
                                now + 1
                            } else {
                                self.mshr.next_fill_after(now)
                            };
                            self.min_ready_hint =
                                self.min_ready_hint.min(wake.max(now + 1));
                            continue;
                        }
                    }
                }
                _ => {}
            }

            // --- Issue! ---
            match inst.op {
                Op::IAlu | Op::FAlu => {
                    slots.sp -= 1;
                    self.warps[w].reg_ready[inst.dst as usize] = now + ctx.cfg.alu_latency as u64;
                }
                Op::Fma => {
                    slots.sp -= 1;
                    self.warps[w].reg_ready[inst.dst as usize] = now + ctx.cfg.fma_latency as u64;
                }
                Op::Sfu => {
                    let mut latency = ctx.cfg.sfu_latency as u64;
                    let mut sfu_computes = true;
                    if ctx.design.memoization && self.memo.enabled() {
                        // §8.1: a high-priority assist warp hashes the
                        // operand values and probes the shared-memory LUT
                        // (`crate::memo`). A hit replaces the SFU
                        // computation with an on-chip load — the SFU
                        // pipeline is never occupied; a miss computes and
                        // deploys a low-priority install warp, so the
                        // result becomes reusable when that warp retires.
                        use crate::caba::subroutines::Subroutine;
                        let uid = self.warps[w].uid;
                        let key = self.memo_key(ctx.wl, w, iter, body_idx);
                        let sub = Subroutine {
                            total: memo::LOOKUP_SUB_TOTAL,
                            mem: memo::LOOKUP_SUB_MEM,
                        };
                        if self.awc.trigger_lookup(now, sub, w, inst.dst, uid).is_some() {
                            self.awc.stats.memo_lookups += 1;
                            match self.memo.lookup(key, now) {
                                memo::Lookup::Hit => {
                                    latency = memo::LUT_HIT_LATENCY;
                                    sfu_computes = false;
                                    self.awc.stats.memo_hits += 1;
                                }
                                memo::Lookup::AliasHit => {
                                    // Served from a different tuple's entry
                                    // (truncated-tag aliasing): same timing
                                    // as a hit, tracked separately.
                                    latency = memo::LUT_HIT_LATENCY;
                                    sfu_computes = false;
                                    self.awc.stats.memo_hits += 1;
                                    self.awc.stats.memo_alias_hits += 1;
                                }
                                memo::Lookup::Miss | memo::Lookup::Disabled => {
                                    let install = Subroutine {
                                        total: memo::INSTALL_SUB_TOTAL,
                                        mem: memo::INSTALL_SUB_MEM,
                                    };
                                    let _ = self.awc.trigger_low(
                                        now + latency,
                                        install,
                                        w,
                                        crate::caba::Payload::MemoInstall { key },
                                    );
                                }
                            }
                            // The lookup's reg release would fight the SFU
                            // write; resolve by tracking the max: the reg is
                            // ready at max(lookup retire, chosen latency).
                            self.releases.insert(w, inst.dst, uid, 1, now + latency);
                            self.warps[w].reg_ready[inst.dst as usize] = PENDING;
                            self.warps[w].blocked_until = 0;
                        } else {
                            // AWT full: no lookup this time, plain SFU.
                            self.awc.stats.memo_lookups_skipped += 1;
                            self.warps[w].reg_ready[inst.dst as usize] = now + latency;
                        }
                    } else {
                        self.warps[w].reg_ready[inst.dst as usize] = now + latency;
                    }
                    if sfu_computes {
                        // Dispatch to the SFU pipeline: consume the issue
                        // slot and occupy a free unit for the full
                        // multi-cycle interval. On a memo hit neither
                        // happens — the result comes from shared memory.
                        slots.sfu -= 1;
                        if let Some(t) =
                            self.sfu_free_at.iter_mut().find(|t| **t <= now)
                        {
                            *t = now + self.sfu_issue_interval;
                        }
                    }
                }
                Op::Ld(mem) => {
                    slots.mem -= 1;
                    self.queue_access(now, w, &mem, inst.dst, iter, body_idx, false, ctx);
                }
                Op::St(mem) => {
                    slots.mem -= 1;
                    self.queue_access(now, w, &mem, inst.dst, iter, body_idx, true, ctx);
                }
            }
            self.d_warp_insts += 1;
            self.d_thread_insts += ctx.cfg.warp_size as u64;
            self.d_core_insts += 1;
            self.warps[w].pc += 1;
            self.warps[w].body_idx += 1;
            if self.warps[w].body_idx as usize >= ctx.wl.program.body.len() {
                self.warps[w].body_idx = 0;
                self.warps[w].iter += 1;
            }
            if self.warps[w].pc >= ctx.wl.program.total_insts() {
                self.warps[w].done = true;
                self.warp_retired = true;
                if self.greedy[sched] == Some(w) {
                    self.greedy[sched] = None;
                }
            } else {
                self.greedy[sched] = Some(w);
            }
            self.issue.active += 1;
            issued = true;
            break;
        }
        self.sched_order[sched] = order;
        if issued {
            self.min_ready_hint = self.min_ready_hint.min(now + 1);
            return true;
        }

        // Nothing issued: classify (Fig. 2), and memoize the verdict — it
        // holds for every cycle until `next_event` (the event-driven tick
        // bulk-charges it via `settle_to`).
        let kind = if saw_mem_struct {
            StallKind::Memory
        } else if saw_compute_struct {
            StallKind::Compute
        } else if saw_data {
            StallKind::DataDependence
        } else {
            let _ = any_candidate;
            StallKind::Idle
        };
        self.stall_memo[sched] = kind;
        self.issue.record_stall(kind);
        false
    }

    /// Phase-A half of a memory instruction: generate the coalesced line
    /// addresses (the workload generators are pure functions of the warp
    /// instance, so this is core-local), charge the LSU, and queue the
    /// shared-state half for the drain.
    #[allow(clippy::too_many_arguments)]
    fn queue_access(
        &mut self,
        now: u64,
        w: usize,
        access: &crate::isa::MemAccess,
        dst: u8,
        iter: u32,
        body_idx: usize,
        is_store: bool,
        ctx: &CoreCtx,
    ) {
        let uid = self.warps[w].uid;
        ctx.wl.trace_note_cycle(now); // trace-capture timestamp span
        let mut lines = std::mem::take(&mut self.lines_scratch);
        ctx.wl.access_lines(access, uid, iter, body_idx, &mut lines);
        // The LSU processes one line transaction per cycle.
        self.lsu_free_at = now + lines.len() as u64;
        let start = self.op_arena.len() as u32;
        let len = lines.len() as u32;
        self.op_arena.extend_from_slice(&lines);
        self.lines_scratch = lines;
        let (access, body_idx) = (*access, body_idx as u32);
        self.shared_ops.push(if is_store {
            SharedOp::Store { w, uid, access, iter, body_idx, start, len }
        } else {
            SharedOp::Load { w, uid, access, dst, iter, body_idx, start, len }
        });
    }

    /// Drain half of an issued load (runs at the same `now` it issued).
    #[allow(clippy::too_many_arguments)]
    fn exec_load(
        &mut self,
        now: u64,
        w: usize,
        uid: u64,
        mem: &crate::isa::MemAccess,
        dst: u8,
        iter: u32,
        body_idx: usize,
        start: usize,
        len: usize,
        ctx: &mut DrainCtx,
    ) {
        let mut lines = std::mem::take(&mut self.lines_scratch);
        lines.clear();
        lines.extend_from_slice(&self.op_arena[start..start + len]);

        let mut parts = 0u32;
        let mut floor = now + ctx.cfg.l1_hit_latency as u64;
        for &line in &lines {
            ctx.stats.energy_events.l1_accesses += 1;
            // 1. In-flight miss to the same line: merge.
            if let Some(info) = self.mshr.get(line) {
                match info.awc_token {
                    // Attach to the in-flight decompression; if it already
                    // retired, the data is ready at/after the fill time.
                    Some(tok) if self.awc.attach_reg(tok, w, dst, uid) => parts += 1,
                    _ => floor = floor.max(info.fill_at),
                }
                continue;
            }
            // 2. L1 probe.
            if let Some((bursts, compressed)) = self.l1.probe(line, now) {
                let t_hit = now + ctx.cfg.l1_hit_latency as u64;
                if compressed {
                    // Fig. 15 / direct-load: every hit on a compressed L1
                    // line pays decompression.
                    let _ = bursts;
                    match ctx.design.mechanism {
                        Mechanism::Caba => {
                            let enc = ctx.data.cached_encoding(line);
                            let sub = subroutine(
                                ctx.design.algo,
                                AwKind::Decompress,
                                enc,
                                ctx.design.direct_load,
                            );
                            if let Some(tok) = self.awc.trigger_decompress(t_hit, sub, w, dst, uid) {
                                self.mshr.insert(line, MshrInfo { fill_at: t_hit, awc_token: Some(tok) });
                                parts += 1;
                            } else {
                                // AWT full: serialize behind the oldest entry
                                // (blocking semantics).
                                floor = floor.max(t_hit + 2 * sub.total as u64);
                            }
                        }
                        Mechanism::Hardware => {
                            floor = floor.max(t_hit + ctx.cfg.hw_decompress_latency as u64);
                            ctx.stats.energy_events.hw_compressor_ops += 1;
                        }
                        _ => floor = floor.max(t_hit),
                    }
                } else {
                    floor = floor.max(t_hit);
                }
                continue;
            }
            // 3. Miss: go to the memory system.
            let algo = ctx.design.algo;
            let need_verdict = ctx.design.mem_compression;
            let outcome = {
                let data = &mut *ctx.data;
                let wl = ctx.wl;
                let mut verdict = || data.verdict(wl, algo, line);
                let _ = need_verdict;
                ctx.mem.load(now, self.sm_id, line, ctx.design, &mut verdict)
            };
            if outcome.l2_hit {
                ctx.stats.l2.hits += 1;
            } else {
                ctx.stats.l2.misses += 1;
            }
            ctx.stats.l2.accesses += 1;

            match outcome.arrives_compressed {
                Some((_, bursts)) => {
                    // Keep compressed in L1 only for the Fig. 15 / Fig. 16
                    // configurations; default CABA decompresses before fill.
                    let keep_compressed = ctx.design.l1_holds_compressed();
                    self.l1.insert_into(line, false, bursts, keep_compressed, now, &mut self.l1_evict_scratch);
                    match ctx.design.mechanism {
                        Mechanism::Caba => {
                            let enc = ctx.data.cached_encoding(line);
                            let sub = subroutine(
                                ctx.design.algo,
                                AwKind::Decompress,
                                enc,
                                ctx.design.direct_load,
                            );
                            if let Some(tok) =
                                self.awc.trigger_decompress(outcome.data_at, sub, w, dst, uid)
                            {
                                self.mshr.insert(
                                    line,
                                    MshrInfo { fill_at: outcome.data_at, awc_token: Some(tok) },
                                );
                                parts += 1;
                            } else {
                                floor = floor.max(outcome.data_at + 2 * sub.total as u64);
                                self.mshr.insert(
                                    line,
                                    MshrInfo { fill_at: outcome.data_at, awc_token: None },
                                );
                            }
                        }
                        Mechanism::Hardware => {
                            let t = outcome.data_at + ctx.cfg.hw_decompress_latency as u64;
                            ctx.stats.energy_events.hw_compressor_ops += 1;
                            floor = floor.max(t);
                            self.mshr.insert(line, MshrInfo { fill_at: t, awc_token: None });
                        }
                        _ => {
                            floor = floor.max(outcome.data_at);
                            self.mshr
                                .insert(line, MshrInfo { fill_at: outcome.data_at, awc_token: None });
                        }
                    }
                }
                None => {
                    self.l1.insert_into(line, false, 4, false, now, &mut self.l1_evict_scratch);
                    floor = floor.max(outcome.data_at);
                    self.mshr
                        .insert(line, MshrInfo { fill_at: outcome.data_at, awc_token: None });
                }
            }
        }
        // §8.2: deploy a stride-prefetch assist warp for predictable
        // accesses (low priority — issues only into idle slots; the AWC
        // throttle and MSHR headroom bound its aggressiveness).
        // Paper §8.2(3): prefetch only when the memory pipelines are idle /
        // underutilized — gate on the DRAM bus backlog so prefetching never
        // floods the off-chip buses ahead of demand requests.
        if ctx.design.prefetch && ctx.mem.dram_backlog(now) < 250.0 {
            use crate::caba::prefetch as pf;
            use crate::caba::subroutines::Subroutine;
            // Predict into the reusable scratch; a payload Vec is built
            // only when a deploy actually happens (rare vs. per-access).
            let mut pred = std::mem::take(&mut self.prefetch_scratch);
            pred.clear();
            if pf::predict(ctx.wl, mem, uid, iter, body_idx, &mut pred) {
                pred.retain(|l| !self.l1.contains(*l) && !self.mshr.contains_key(*l));
                if !pred.is_empty() {
                    let sub = Subroutine { total: pf::PREFETCH_SUB_TOTAL, mem: pf::PREFETCH_SUB_MEM };
                    let _ = self.awc.trigger_low(
                        now,
                        sub,
                        w,
                        crate::caba::Payload::Prefetch { lines: pred.clone() },
                    );
                }
            }
            self.prefetch_scratch = pred;
        }
        self.lines_scratch = lines;

        // Scoreboard outcome for the destination register.
        if parts > 0 {
            self.warps[w].reg_ready[dst as usize] = PENDING;
            self.releases.insert(w, dst, uid, parts, floor);
        } else {
            self.warps[w].reg_ready[dst as usize] = floor;
        }
    }

    /// Drain half of an issued store (runs at the same `now` it issued).
    #[allow(clippy::too_many_arguments)]
    fn exec_store(
        &mut self,
        now: u64,
        w: usize,
        uid: u64,
        mem: &crate::isa::MemAccess,
        iter: u32,
        body_idx: usize,
        start: usize,
        len: usize,
        ctx: &mut DrainCtx,
    ) {
        // Address generation already happened in phase A; the operand
        // metadata rides along for symmetry with `Load` (and debugging).
        let _ = (mem, uid, iter, body_idx);
        let mut lines = std::mem::take(&mut self.lines_scratch);
        lines.clear();
        lines.extend_from_slice(&self.op_arena[start..start + len]);

        // Pass 1 — per-line write-through bookkeeping (order-independent:
        // invalidation is idempotent, the counter commutative).
        for &line in &lines {
            ctx.stats.energy_events.l1_accesses += 1;
            self.l1.invalidate(line);
        }

        let compression_on = ctx.design.mem_compression || ctx.design.icnt_compression;
        // A transaction that touches the same line twice (possible for
        // Scatter stores) must bump and analyze strictly in line order —
        // the first dispatch's verdict reflects epoch e+1, not e+2. Batch
        // only duplicate-free transactions (the overwhelmingly common
        // case); duplicates keep the interleaved bump/verdict ordering.
        let interleave = compression_on
            && lines.len() > 1
            && (1..lines.len()).any(|i| lines[..i].contains(&lines[i]));
        if !interleave {
            for &line in &lines {
                ctx.data.bump_epoch(line);
            }
            if compression_on {
                // All of this store's pending lines need a compression
                // verdict below — compute them in ONE oracle call (§5.2.2:
                // the AWC dispatches per line, but analysis batches; this
                // is what the PJRT backend's batched executable exists
                // for).
                ctx.data.warm_verdicts(ctx.wl, ctx.design.algo, &lines);
            }
        }

        // Pass 2 — dispatch each line (same line order as before, so the
        // reservation-based memory contention model sees identical
        // request sequences).
        for &line in &lines {
            if interleave {
                ctx.data.bump_epoch(line);
            }
            if !compression_on {
                ctx.mem.store(now, self.sm_id, line, ctx.design, None);
                continue;
            }
            match ctx.design.mechanism {
                Mechanism::Caba => {
                    let v = ctx.data.verdict(ctx.wl, ctx.design.algo, line);
                    let sub =
                        subroutine(ctx.design.algo, AwKind::Compress, v.encoding, false);
                    let can_buffer = self.pending_compress_stores < self.store_buffer_cap;
                    let trig = if can_buffer {
                        self.awc.trigger_compress(now, sub, w, line, v)
                    } else {
                        None
                    };
                    match trig {
                        Some(_) => self.pending_compress_stores += 1,
                        None => {
                            // Buffer overflow / AWT full / throttled →
                            // release the store uncompressed (§5.2.2 ⑤–⑥).
                            self.awc.stats.compress_skipped += 1;
                            ctx.data.set_stored_compressed(line, false);
                            ctx.mem.store(now, self.sm_id, line, ctx.design, None);
                        }
                    }
                }
                Mechanism::Hardware => {
                    let v = ctx.data.verdict(ctx.wl, ctx.design.algo, line);
                    ctx.stats.energy_events.hw_compressor_ops += 1;
                    ctx.data.set_stored_compressed(line, v.is_compressed());
                    // HW-BDI compresses at the core (+5cy, off critical
                    // path for the warp — the store is fire-and-forget);
                    // HW-BDI-Mem compresses at the MC (handled in mem).
                    let t = now + ctx.cfg.hw_compress_latency as u64;
                    ctx.mem.store(t, self.sm_id, line, ctx.design, Some(v));
                }
                Mechanism::Ideal => {
                    let v = ctx.data.verdict(ctx.wl, ctx.design.algo, line);
                    ctx.data.set_stored_compressed(line, v.is_compressed());
                    ctx.mem.store(now, self.sm_id, line, ctx.design, Some(v));
                }
                Mechanism::None => unreachable!("compression_on checked above"),
            }
        }
        self.lines_scratch = lines;
    }

    fn sweep_mshr(&mut self, now: u64) {
        let awc = &self.awc;
        self.mshr.sweep(|info| {
            info.fill_at > now || info.awc_token.map_or(false, |t| awc.is_live(t))
        });
    }

}

#[cfg(test)]
mod tests {
    // Core behaviour is exercised end-to-end through `sim::Simulator` tests
    // (rust/tests/integration_sim.rs) — the cycle logic depends on the full
    // chip context. Unit-level invariants:
    use super::*;

    #[test]
    fn warp_slot_lifecycle() {
        let w = WarpSlot::empty();
        assert!(!w.live());
        let mut w2 = w.clone();
        w2.uid = 3;
        w2.done = false;
        assert!(w2.live());
    }

    #[test]
    fn core_constructs_with_table1_defaults() {
        let cfg = SimConfig::default();
        let d = Design::base();
        let c = Core::new(0, &cfg, &d, &MemoGeometry::disabled());
        assert_eq!(c.warps.len(), 48);
        assert_eq!(c.mshr_limit, 64);
        assert_eq!(c.l1.capacity_lines(), 128); // 16KB / 128B
        assert!(!c.memo.enabled());
    }

    #[test]
    fn scheduler_structures_size_by_config() {
        // `schedulers_per_sm` is a fingerprinted config key; the scheduler
        // structures used to hard-code 2 and index out of bounds at 4.
        for n_sched in [1usize, 2, 3, 4] {
            let mut cfg = SimConfig::default();
            cfg.schedulers_per_sm = n_sched;
            let d = Design::base();
            let mut c = Core::new(0, &cfg, &d, &MemoGeometry::disabled());
            assert_eq!(c.greedy.len(), n_sched);
            assert_eq!(c.sched_order.len(), n_sched);
            assert_eq!(c.stall_memo.len(), n_sched);
            // Populate a few warp slots and rebuild: every live slot must
            // land in exactly one scheduler's order.
            for (i, uid) in [(0usize, 5u64), (1, 3), (2, 8), (5, 1)] {
                c.warps[i].uid = uid;
                c.warps[i].done = false;
            }
            c.rebuild_sched_order();
            let mut seen: Vec<usize> = Vec::new();
            for (sched, order) in c.sched_order.iter().enumerate() {
                for &slot in order {
                    assert_eq!(slot % n_sched, sched, "slot on wrong scheduler");
                    seen.push(slot);
                }
                // Age (uid) order within a scheduler.
                assert!(
                    order.windows(2).all(|p| c.warps[p[0]].uid < c.warps[p[1]].uid),
                    "GTO order not uid-sorted"
                );
            }
            seen.sort_unstable();
            assert_eq!(seen, vec![0, 1, 2, 5]);
        }
    }
}
