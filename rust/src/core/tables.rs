//! Flat per-access data structures for the SM hot path.
//!
//! PR 3 removed the `DataModel`/oracle hash maps; these two tables finish
//! the job for the core itself, replacing the last two per-access
//! `HashMap`s (`Core::mshr`, `Core::releases`) with structures that hash
//! nothing (releases) or one multiply (MSHR) and allocate nothing per
//! access:
//!
//! * [`MshrTable`] — an open-addressed, linear-probing table keyed on line
//!   address, in the spirit of the `MemoOracle` table
//!   (`crate::compress::oracle`). Unlike the memo table it may never drop
//!   an entry (an in-flight miss is architectural state), so instead of a
//!   bounded probe with replacement it sizes itself at ≥2× the logical
//!   MSHR limit and rebuilds on the (rare) sweep. Vacancy is carried by a
//!   key sentinel — the intrusive-free-list equivalent for a table whose
//!   only bulk operation is "drop every filled entry".
//! * [`ReleaseTable`] — a dense array indexed by `warp_slot × MAX_REGS +
//!   reg`. Both key components are small and bounded, so hashing them was
//!   pure waste; a generation stamp (the owning warp's uid) guards each
//!   entry against retirements that outlive their warp instance.

use crate::isa::MAX_REGS;

/// In-flight miss bookkeeping (one entry per outstanding line).
#[derive(Clone, Copy, Debug)]
pub struct MshrInfo {
    /// Cycle the line data reaches this SM.
    pub fill_at: u64,
    /// Token of the AWT entry decompressing this line, if any.
    pub awc_token: Option<u64>,
}

/// Vacant-slot key sentinel. Line addresses are `array base + offset` and
/// never reach `u64::MAX`; inserts assert it.
const VACANT: u64 = u64::MAX;

/// Open-addressed MSHR: line address → [`MshrInfo`].
///
/// The *logical* capacity bound (`l1_mshrs`) stays with the caller — the
/// scheduler's structural-stall check enforces it, exactly as it did over
/// the `HashMap`. This table only provides the storage, sized with enough
/// physical headroom (2× the limit plus one warp-wide access) that linear
/// probes stay short at the worst legal occupancy. Trace replays may serve
/// wider accesses than any synthetic generator; if occupancy ever passes
/// 3/4 the table rebuilds at double size rather than degrade — contents
/// are unchanged, so simulation results cannot depend on it.
pub struct MshrTable {
    keys: Vec<u64>,
    info: Vec<MshrInfo>,
    mask: usize,
    len: usize,
    /// Reusable survivor scratch for [`MshrTable::sweep`].
    scratch: Vec<(u64, MshrInfo)>,
}

#[inline]
fn hash_line(key: u64) -> u64 {
    // One multiply + one xor-shift (fibonacci hashing): line addresses are
    // already well-spread, this just decorrelates the low bits.
    let h = key.wrapping_mul(0x9E37_79B9_7F4A_7C15);
    h ^ (h >> 29)
}

impl MshrTable {
    pub fn new(mshr_limit: usize, max_lines_per_access: usize) -> MshrTable {
        let slots = (2 * (mshr_limit + max_lines_per_access))
            .next_power_of_two()
            .max(16);
        MshrTable {
            keys: vec![VACANT; slots],
            info: vec![MshrInfo { fill_at: 0, awc_token: None }; slots],
            mask: slots - 1,
            len: 0,
            scratch: Vec::new(),
        }
    }

    /// Live entries (the scheduler compares this against `l1_mshrs`).
    pub fn len(&self) -> usize {
        self.len
    }

    /// Physical slot count (a power of two). Exposed so the growth policy
    /// — resize before occupancy passes 3/4, never during a probe — is
    /// directly testable from `tests/core_tables.rs`.
    pub fn capacity_slots(&self) -> usize {
        self.keys.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn contains_key(&self, line: u64) -> bool {
        self.get(line).is_some()
    }

    pub fn get(&self, line: u64) -> Option<&MshrInfo> {
        let mut i = hash_line(line) as usize & self.mask;
        loop {
            let k = self.keys[i];
            if k == line {
                return Some(&self.info[i]);
            }
            if k == VACANT {
                return None;
            }
            i = (i + 1) & self.mask;
        }
    }

    /// Insert a fresh line. Callers never insert a line that is already
    /// present (they merge on [`MshrTable::get`] first); debug builds
    /// assert it.
    pub fn insert(&mut self, line: u64, info: MshrInfo) {
        debug_assert_ne!(line, VACANT, "line address collides with the vacancy sentinel");
        debug_assert!(!self.contains_key(line), "MSHR double-insert for line {line}");
        if (self.len + 1) * 4 > self.keys.len() * 3 {
            self.grow();
        }
        let mut i = hash_line(line) as usize & self.mask;
        while self.keys[i] != VACANT {
            i = (i + 1) & self.mask;
        }
        self.keys[i] = line;
        self.info[i] = info;
        self.len += 1;
    }

    fn grow(&mut self) {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        self.drain_into(&mut scratch);
        let slots = (self.keys.len() * 2).max(16);
        self.keys = vec![VACANT; slots];
        self.info = vec![MshrInfo { fill_at: 0, awc_token: None }; slots];
        self.mask = slots - 1;
        self.len = 0;
        for &(k, v) in &scratch {
            self.insert(k, v);
        }
        self.scratch = scratch;
    }

    fn drain_into(&mut self, out: &mut Vec<(u64, MshrInfo)>) {
        for i in 0..self.keys.len() {
            if self.keys[i] != VACANT {
                out.push((self.keys[i], self.info[i]));
            }
        }
    }

    /// Drop every entry for which `keep` returns false (the lazy fill
    /// sweep). Open-addressed deletion would need tombstones or backward
    /// shifting; since the sweep runs only when the MSHR is *full* (rare),
    /// a full rebuild is simpler and leaves the table tombstone-free.
    pub fn sweep(&mut self, mut keep: impl FnMut(&MshrInfo) -> bool) {
        let mut scratch = std::mem::take(&mut self.scratch);
        scratch.clear();
        for i in 0..self.keys.len() {
            if self.keys[i] != VACANT {
                if keep(&self.info[i]) {
                    scratch.push((self.keys[i], self.info[i]));
                }
                self.keys[i] = VACANT;
            }
        }
        self.len = 0;
        for &(k, v) in &scratch {
            let mut i = hash_line(k) as usize & self.mask;
            while self.keys[i] != VACANT {
                i = (i + 1) & self.mask;
            }
            self.keys[i] = k;
            self.info[i] = v;
            self.len += 1;
        }
        self.scratch = scratch;
    }

    /// Earliest strictly-future fill time among live entries, `u64::MAX`
    /// if none. This is the precise wake time for an MSHR-full structural
    /// stall: entries with `fill_at ≤ now` that survived the sweep are
    /// pinned by a live assist warp, and assist-warp activity feeds the
    /// core's `next_event` through the AWC hint instead (see DESIGN.md §3,
    /// wake-source contract).
    pub fn next_fill_after(&self, now: u64) -> u64 {
        let mut next = u64::MAX;
        for i in 0..self.keys.len() {
            if self.keys[i] != VACANT && self.info[i].fill_at > now {
                next = next.min(self.info[i].fill_at);
            }
        }
        next
    }

    /// Entries whose fill has not landed by the start of `cycle`
    /// (`fill_at >= cycle`) — the flight recorder's MSHR-occupancy sample.
    /// Deliberately *not* [`MshrTable::len`]: the sweep is lazy, so raw
    /// length depends on how often the core executed (which differs across
    /// tick modes), while this count is a pure function of table contents —
    /// a sweep at any `now < cycle` removes only entries the predicate
    /// already excludes. That makes the sample bit-identical across
    /// strict / event-serial / sharded ticking (see `crate::telemetry`).
    pub fn count_fills_at_or_after(&self, cycle: u64) -> u32 {
        let mut n = 0;
        for i in 0..self.keys.len() {
            if self.keys[i] != VACANT && self.info[i].fill_at >= cycle {
                n += 1;
            }
        }
        n
    }
}

/// Multi-part register release (a load spanning several lines completes
/// when all per-line decompressions retire).
#[derive(Clone, Copy, Debug, Default)]
struct ReleaseSlot {
    /// Outstanding parts; 0 = vacant (live entries always hold ≥ 1).
    parts: u32,
    /// Running max of part completion times.
    floor: u64,
    /// Uid of the warp instance that opened this release. Slots are keyed
    /// by (warp slot, reg) and warp slots are recycled across CTAs; the
    /// stamp keeps a retirement that outlives its warp instance from
    /// corrupting the slot's next tenant.
    gen: u64,
}

/// Dense release table: `(warp_slot, reg) → (parts, floor, gen)`.
pub struct ReleaseTable {
    slots: Vec<ReleaseSlot>,
}

impl ReleaseTable {
    pub fn new(warp_slots: usize) -> ReleaseTable {
        ReleaseTable {
            slots: vec![ReleaseSlot::default(); warp_slots * MAX_REGS],
        }
    }

    #[inline]
    fn idx(warp: usize, reg: u8) -> usize {
        warp * MAX_REGS + reg as usize
    }

    /// Open (or replace) the release for `(warp, reg)`, owned by warp
    /// instance `uid`. Replacement matches the old `HashMap::insert`
    /// semantics: a stale release for a previous tenant is simply
    /// overwritten.
    pub fn insert(&mut self, warp: usize, reg: u8, uid: u64, parts: u32, floor: u64) {
        debug_assert!(parts > 0, "a release must have at least one part");
        self.slots[Self::idx(warp, reg)] = ReleaseSlot { parts, floor, gen: uid };
    }

    /// Apply one part completion at time `at` for warp instance `uid`.
    /// Returns `Some(floor)` when this was the final part (the entry is
    /// freed); `None` while parts remain, when no release is open, or when
    /// the open release belongs to a different warp instance (a stale
    /// retirement — dropped, and the entry left for its rightful owner).
    pub fn release(&mut self, warp: usize, reg: u8, uid: u64, at: u64) -> Option<u64> {
        let slot = &mut self.slots[Self::idx(warp, reg)];
        if slot.parts == 0 || slot.gen != uid {
            return None;
        }
        slot.parts -= 1;
        slot.floor = slot.floor.max(at);
        if slot.parts == 0 {
            Some(slot.floor)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mshr_insert_get_len() {
        let mut t = MshrTable::new(4, 4);
        assert!(t.is_empty());
        t.insert(100, MshrInfo { fill_at: 50, awc_token: None });
        t.insert(101, MshrInfo { fill_at: 60, awc_token: Some(7) });
        assert_eq!(t.len(), 2);
        assert!(t.contains_key(100));
        assert!(!t.contains_key(102));
        assert_eq!(t.get(101).unwrap().fill_at, 60);
        assert_eq!(t.get(101).unwrap().awc_token, Some(7));
    }

    #[test]
    fn mshr_sweep_keeps_predicate_and_reuses_slots() {
        let mut t = MshrTable::new(8, 8);
        for i in 0..8u64 {
            t.insert(i, MshrInfo { fill_at: 10 * i, awc_token: None });
        }
        t.sweep(|info| info.fill_at >= 40);
        assert_eq!(t.len(), 4);
        assert!(!t.contains_key(0));
        assert!(t.contains_key(7));
        // Reinsert over swept slots.
        t.insert(100, MshrInfo { fill_at: 1, awc_token: None });
        assert_eq!(t.len(), 5);
        assert_eq!(t.get(100).unwrap().fill_at, 1);
    }

    #[test]
    fn mshr_next_fill_skips_past_and_pinned() {
        let mut t = MshrTable::new(4, 4);
        t.insert(1, MshrInfo { fill_at: 5, awc_token: None });
        t.insert(2, MshrInfo { fill_at: 90, awc_token: None });
        t.insert(3, MshrInfo { fill_at: 40, awc_token: Some(1) });
        assert_eq!(t.next_fill_after(10), 40);
        assert_eq!(t.next_fill_after(50), 90);
        assert_eq!(t.next_fill_after(90), u64::MAX);
    }

    #[test]
    fn mshr_count_fills_is_sweep_invariant() {
        let mut t = MshrTable::new(8, 8);
        t.insert(1, MshrInfo { fill_at: 5, awc_token: None });
        t.insert(2, MshrInfo { fill_at: 10, awc_token: None });
        t.insert(3, MshrInfo { fill_at: 40, awc_token: Some(1) });
        // Boundary semantics: fill_at == cycle still counts as in flight
        // (the fill lands *during* that cycle, after the boundary sample).
        assert_eq!(t.count_fills_at_or_after(10), 2);
        assert_eq!(t.count_fills_at_or_after(11), 1);
        assert_eq!(t.count_fills_at_or_after(0), 3);
        assert_eq!(t.count_fills_at_or_after(41), 0);
        // Sweeping filled entries (any now < cycle) leaves the count
        // unchanged — the mode-invariance argument in the method docs.
        t.sweep(|info| info.fill_at > 9);
        assert_eq!(t.len(), 2);
        assert_eq!(t.count_fills_at_or_after(10), 2);
        assert_eq!(t.count_fills_at_or_after(11), 1);
    }

    #[test]
    fn mshr_grows_past_static_headroom() {
        // A trace replay can serve wider accesses than any synthetic
        // generator; the table must absorb them rather than probe forever.
        let mut t = MshrTable::new(2, 2);
        for i in 0..1000u64 {
            t.insert(i, MshrInfo { fill_at: i, awc_token: None });
        }
        assert_eq!(t.len(), 1000);
        for i in (0..1000u64).step_by(97) {
            assert_eq!(t.get(i).unwrap().fill_at, i);
        }
    }

    #[test]
    fn release_parts_and_floor() {
        let mut r = ReleaseTable::new(4);
        r.insert(2, 5, 77, 3, 100);
        assert_eq!(r.release(2, 5, 77, 150), None);
        assert_eq!(r.release(2, 5, 77, 120), None);
        // Final part: floor is the max over all completion times and the
        // initial floor.
        assert_eq!(r.release(2, 5, 77, 90), Some(150));
        // Entry is freed.
        assert_eq!(r.release(2, 5, 77, 200), None);
    }

    #[test]
    fn release_generation_guards_recycled_slots() {
        let mut r = ReleaseTable::new(4);
        r.insert(1, 3, 10, 1, 50);
        // A retirement stamped with a different warp instance neither
        // completes nor corrupts the open release.
        assert_eq!(r.release(1, 3, 99, 60), None);
        assert_eq!(r.release(1, 3, 10, 60), Some(60));
        // Re-tenanting the slot starts a fresh generation.
        r.insert(1, 3, 20, 2, 0);
        assert_eq!(r.release(1, 3, 10, 70), None); // stale uid ignored
        assert_eq!(r.release(1, 3, 20, 70), None);
        assert_eq!(r.release(1, 3, 20, 80), Some(80));
    }
}
