//! # The parallel sweep engine
//!
//! CABA's evaluation (§7) is a large `(app × design × bw_scale)` matrix —
//! 27 workloads against Base, HW-BDI, CABA-{BDI,FPC,C-Pack} and more. Each
//! point is an independent, fully deterministic cycle-level simulation, so
//! the matrix is embarrassingly parallel — exactly the kind of idle-core
//! work the paper itself harvests with assist warps. This module puts the
//! *host's* idle cores to work the same way.
//!
//! ## Architecture
//!
//! * [`SweepJob`] — one simulation point: `(app, design, cfg, scale)`. The
//!   configuration is carried **whole**; the job key is derived from
//!   [`crate::SimConfig::fingerprint`], which digests every field, so two
//!   jobs differing in any `--set` override never alias (this fixed a
//!   latent cache-poisoning bug where the old figure cache keyed only on
//!   `(app, design, bw_scale, scale)`).
//! * [`RunCache`] — a sharded `(key → SimStats)` map. Sharding by key hash
//!   keeps lock hold times to a single bucket operation; workers touching
//!   different shards never contend (the old cache was one global
//!   `Mutex<HashMap>` around the *whole* run loop's results).
//! * [`SweepEngine`] — deduplicates the requested jobs against the cache,
//!   executes the misses on a scoped `std::thread` worker pool (no
//!   external deps), and returns results in request order.
//!
//! Jobs are synthetic by default; [`SweepJob::replay`] makes a point
//! **trace-driven** (`crate::trace`) — the key then also carries the
//! trace's content digest, so re-sweeping the same trace file is pure
//! cache hits while distinct traces never alias.
//!
//! ## Determinism
//!
//! Parallel execution is **bit-identical** to serial execution because no
//! simulation state is shared between jobs:
//!
//! * each job owns its `Simulator` (cores, memory system, oracle, data
//!   model) — the `Send` bound on [`crate::compress::oracle::
//!   CompressionOracle`] lets the whole bundle move to a worker thread;
//! * every random stream is seeded per job from the configuration:
//!   workload construction derives its RNG seed as
//!   `cfg.seed ^ hash(app.name)` ([`crate::workload::Workload`]), and the
//!   program build uses `hash(app.name)` — nothing depends on wall clock,
//!   thread id, or execution order;
//! * workers only *write* finished `SimStats` into their job's dedicated
//!   slot; the work queue is an atomic index, which affects scheduling but
//!   not results.
//!
//! `tests/integration_sweep.rs` asserts `--jobs 1` ≡ `--jobs 4` on a small
//! matrix, field for field.
//!
//! ## Fault tolerance
//!
//! The engine is the execution substrate of the `caba serve` daemon, so
//! one bad job must never take down the process or poison shared state:
//!
//! * [`SweepJob::execute`] runs under `catch_unwind` and returns a typed
//!   [`JobError`] (app, design, cause) — a panicking simulation (or an
//!   injected [`crate::store::FaultPlan`] fault) becomes an error the
//!   caller chooses how to handle, never an abort;
//! * every [`RunCache`] lock recovers from poisoning
//!   (`PoisonError::into_inner`): the cache only ever holds fully
//!   constructed `SimStats` values inserted under a brief lock, so a
//!   worker that panicked *while holding* a shard lock cannot have left a
//!   torn entry behind — recovering is safe, and the process-wide
//!   [`shared_cache`] stays usable for figure regeneration;
//! * [`SweepEngine::run`] is **fail-fast** (first error aborts the matrix
//!   and is returned), [`SweepEngine::run_collect`] is
//!   **collect-and-report** (every point gets its own `Result` — the
//!   daemon's policy, where one client's bad request must not starve the
//!   others). Errors are never cached: a failed key stays cold and is
//!   retried on the next request.
//!
//! With [`RunCache::with_store`] the cache becomes read-through /
//! write-through against the crash-safe on-disk [`crate::store::RunStore`],
//! making sweep results persistent across processes.
//!
//! With [`SweepEngine::with_metrics`] the engine feeds an observation-only
//! [`crate::obs::JobMetrics`]: per-job wall time and queue wait into log2
//! histograms, ok/failed outcome counts — timed strictly *around*
//! [`SweepJob::execute`], so attaching metrics cannot perturb results
//! (SimStats bit-identity on/off is pinned by `tests/serve_obs.rs`).

use crate::config::SimConfig;
use crate::obs::JobMetrics;
use crate::sim::designs::Design;
use crate::sim::Simulator;
use crate::stats::SimStats;
use crate::store::{FaultPlan, RunStore, StoreCounters};
use crate::trace::replay::TraceData;
use crate::workload::apps::AppSpec;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

/// One point of an evaluation sweep: a complete, self-contained
/// simulation request — synthetic (`app` drives generation) or
/// trace-driven (`trace` replays a recorded/imported access stream).
#[derive(Clone)]
pub struct SweepJob {
    pub app: &'static AppSpec,
    pub design: Design,
    /// The **full** configuration (including `bw_scale` and any `--set`
    /// overrides) — all of it participates in the cache key. The
    /// constructors strip `trace_record` and the telemetry knobs: sweep
    /// jobs never record (traces or timelines), and a recording path must
    /// not fragment the cache.
    pub cfg: SimConfig,
    /// Workload scale factor (iterations / CTA count shrink).
    pub scale: f64,
    /// Replay source; `None` = synthetic workload.
    pub trace: Option<Arc<TraceData>>,
}

/// Cache key: app and design are identified by their unique static names;
/// the configuration by its full-field fingerprint; a trace-driven job
/// additionally by the trace's **content digest** (last element, 0 for
/// synthetic jobs) — two different trace files never alias, and the same
/// file re-loaded (or re-recorded deterministically) hits the cache. A
/// collision between two *different* configs/traces is a 64-bit hash
/// collision — negligible against what a process ever sweeps.
pub type JobKey = (&'static str, &'static str, u64, u64, u64);

/// A sweep point that failed: which point, and why. Carried as a value
/// (not a panic) so one bad job in a matrix — a corrupt trace, an
/// injected fault, a simulator bug — is reportable per-point by the
/// daemon and fail-fast-able by `caba sweep`, without tearing down the
/// engine or poisoning the shared cache.
#[derive(Clone, Debug)]
pub struct JobError {
    pub app: &'static str,
    pub design: &'static str,
    pub cause: String,
}

impl std::fmt::Display for JobError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "sweep job ({}, {}) failed: {}", self.app, self.design, self.cause)
    }
}

impl std::error::Error for JobError {}

/// Best-effort extraction of a panic payload's message (`panic!` with a
/// `&str` or a formatted `String` covers everything this crate raises).
fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "panicked with a non-string payload".to_string()
    }
}

impl SweepJob {
    pub fn new(app: &'static AppSpec, design: Design, mut cfg: SimConfig, scale: f64) -> SweepJob {
        cfg.trace_record = String::new();
        // Same reasoning as trace_record: the flight recorder is a run
        // control outside the fingerprint, so a telemetry-enabled config
        // would alias a cache entry whose stored stats carry no timeline.
        // Sweep results are aggregates only — never record.
        cfg.telemetry_window = 0;
        cfg.telemetry_spans = SimConfig::default().telemetry_spans;
        SweepJob { app, design, cfg, scale, trace: None }
    }

    /// Convenience for the figure sweeps: `base_cfg` with `bw_scale`
    /// applied on top (the ½×/1×/2× experiments of Figs. 2 and 14).
    pub fn with_bw(
        app: &'static AppSpec,
        design: Design,
        base_cfg: &SimConfig,
        bw_scale: f64,
        scale: f64,
    ) -> SweepJob {
        let mut cfg = base_cfg.clone();
        cfg.bw_scale = bw_scale;
        Self::new(app, design, cfg, scale)
    }

    /// A **trace-driven** point: replay `trace` under `design` and `cfg`.
    /// The workload scale is pinned to the trace's recorded scale (the
    /// access keys only cover that geometry).
    pub fn replay(trace: &Arc<TraceData>, design: Design, cfg: SimConfig) -> SweepJob {
        let scale = trace.meta.scale;
        let mut job = Self::new(trace.spec(), design, cfg, scale);
        job.trace = Some(Arc::clone(trace));
        job
    }

    /// The design that will actually execute: the paper's profiler
    /// disables compression for apps where it is unprofitable (§6), so
    /// those points collapse onto Base — normalizing *before* keying makes
    /// them share one cache entry. Memoization is orthogonal to data
    /// compressibility: a compress+memo hybrid on an incompressible app
    /// keeps its memo half and collapses onto CABA-Memo, never onto Base.
    fn effective_design(&self) -> Design {
        if self.design.compression_enabled() && !Simulator::compression_profitable(self.app) {
            if self.design.memoization {
                Design::caba_memo()
            } else {
                Design::base()
            }
        } else {
            self.design
        }
    }

    /// The cache/store key of this point. Public because the serve
    /// daemon dedups in-flight requests and addresses the on-disk store
    /// by this key.
    pub fn key(&self) -> JobKey {
        (
            self.app.name,
            self.effective_design().name,
            self.cfg.fingerprint(),
            self.scale.to_bits(),
            self.trace.as_ref().map_or(0, |t| t.digest),
        )
    }

    /// Run the simulation for this point. Any failure — a trace that no
    /// longer loads, a panic anywhere inside the simulator, an injected
    /// `fault` — comes back as a typed [`JobError`]; this method never
    /// unwinds into the caller.
    fn execute(&self, fault: Option<&FaultPlan>) -> Result<SimStats, JobError> {
        let err = |cause: String| JobError {
            app: self.app.name,
            design: self.design.name,
            cause,
        };
        let run = || -> Result<SimStats, JobError> {
            if let Some(f) = fault {
                f.before_job(self.app.name, self.design.name);
            }
            match &self.trace {
                Some(t) => {
                    Simulator::from_trace(self.cfg.clone(), self.effective_design(), Arc::clone(t))
                        .map_err(|e| err(format!("trace replay setup: {e:#}")))
                        .map(Simulator::run)
                }
                None => Ok(Simulator::new(
                    self.cfg.clone(),
                    self.effective_design(),
                    self.app,
                    self.scale,
                )
                .run()),
            }
        };
        // `AssertUnwindSafe` is justified: `run` owns its Simulator
        // outright, and on unwind nothing it touched survives — the only
        // shared structure (the cache) is written strictly *after* a
        // successful return.
        match std::panic::catch_unwind(AssertUnwindSafe(run)) {
            Ok(res) => res,
            Err(payload) => Err(err(panic_message(payload))),
        }
    }
}

/// Number of cache shards. Far more than any realistic worker count, so
/// two workers completing jobs at the same instant almost never queue on
/// the same lock.
const N_SHARDS: usize = 16;

/// A sharded run cache: `key → SimStats`, split over [`N_SHARDS`]
/// independently locked maps. Locks are held only for single map
/// operations (simulations run entirely outside them), and every lock
/// recovers from poisoning — a panicked worker can only have completed
/// or not-started a whole-value insert, so the map is always coherent.
///
/// With [`RunCache::with_store`] the cache is additionally backed by a
/// persistent [`RunStore`]: reads fall through to disk (populating the
/// memory shard), writes go through to disk (store I/O errors are
/// counted by the store and swallowed — the cache contract is
/// best-effort persistence, never a failed insert).
pub struct RunCache {
    shards: [Mutex<HashMap<JobKey, SimStats>>; N_SHARDS],
    store: Option<Arc<RunStore>>,
}

impl Default for RunCache {
    fn default() -> Self {
        RunCache { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())), store: None }
    }
}

impl RunCache {
    pub fn new() -> RunCache {
        RunCache::default()
    }

    /// A cache persisted through `store` (read-through + write-through).
    pub fn with_store(store: Arc<RunStore>) -> RunCache {
        RunCache { store: Some(store), ..RunCache::default() }
    }

    /// The backing store, if any (the serve daemon reports its counters).
    pub fn store(&self) -> Option<&Arc<RunStore>> {
        self.store.as_ref()
    }

    /// Activity counters of the backing store, if any.
    pub fn store_counters(&self) -> Option<StoreCounters> {
        self.store.as_ref().map(|s| s.counters())
    }

    fn shard(&self, key: &JobKey) -> &Mutex<HashMap<JobKey, SimStats>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % N_SHARDS]
    }

    /// Lock a shard, recovering from poisoning (see the type docs for
    /// why recovery is safe here).
    fn locked(&self, key: &JobKey) -> MutexGuard<'_, HashMap<JobKey, SimStats>> {
        self.shard(key).lock().unwrap_or_else(PoisonError::into_inner)
    }

    pub fn get(&self, key: &JobKey) -> Option<SimStats> {
        if let Some(s) = self.locked(key).get(key).cloned() {
            return Some(s);
        }
        // Read-through: a store hit (which survives the store's own
        // checksum/version/key validation) warms the memory shard so the
        // disk is touched once per key per process.
        let stats = self.store.as_ref()?.get(key)?;
        self.locked(key).insert(*key, stats.clone());
        Some(stats)
    }

    pub fn insert(&self, key: JobKey, stats: SimStats) {
        self.locked(&key).insert(key, stats.clone());
        if let Some(store) = &self.store {
            // Write-through, best-effort: a failed put is counted by the
            // store (`put_errors`) and costs at most a future recompute.
            let _ = store.put(&key, &stats);
        }
    }

    /// Whether `key` would hit. Exactly as strict as [`RunCache::get`]:
    /// when store-backed this *reads* (and validates) the entry, so a
    /// corrupt on-disk entry never counts as present — `contains`
    /// followed by `get` cannot go from `true` to `None`.
    pub fn contains(&self, key: &JobKey) -> bool {
        if self.store.is_none() {
            return self.locked(key).contains_key(key);
        }
        self.get(key).is_some()
    }

    /// Total **in-memory** cached entries (diagnostics; store-resident
    /// entries not yet read through are not counted).
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap_or_else(PoisonError::into_inner).len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Poison the shard holding `key` by panicking a thread inside its
    /// critical section. Test-only hook for proving poison recovery.
    #[doc(hidden)]
    pub fn poison_for_tests(&self, key: &JobKey) {
        let shard = self.shard(key);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                let _guard = shard.lock().unwrap_or_else(PoisonError::into_inner);
                panic!("poisoning shard for test");
            });
            assert!(h.join().is_err());
        });
    }
}

/// The process-wide cache shared by all figure regenerators (figures 8–11
/// reuse each other's runs, exactly as before — but now keyed on the full
/// configuration and sharded).
pub fn shared_cache() -> &'static Arc<RunCache> {
    static CACHE: OnceLock<Arc<RunCache>> = OnceLock::new();
    CACHE.get_or_init(|| Arc::new(RunCache::new()))
}

/// Resolve a `--jobs` request: `0` means "one worker per available core".
pub fn resolve_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Deterministic parallel executor for sweep matrices.
pub struct SweepEngine {
    jobs: usize,
    cache: Arc<RunCache>,
    fault: Option<Arc<FaultPlan>>,
    /// Observation-only instrumentation (`crate::obs`): per-job wall time,
    /// queue wait, and ok/failed counts. `None` (the default) costs
    /// nothing; when set, the hooks time `SweepJob::execute` strictly from
    /// the *outside* — simulation inputs and results are untouched, a
    /// contract pinned by `tests/serve_obs.rs`.
    metrics: Option<Arc<JobMetrics>>,
}

impl SweepEngine {
    /// An engine with its own private cache (tests, one-shot sweeps).
    pub fn new(jobs: usize) -> SweepEngine {
        Self::with_cache(jobs, Arc::new(RunCache::new()))
    }

    /// An engine backed by the process-wide [`shared_cache`] (the figure
    /// regenerators, so figures sharing runs don't re-simulate).
    pub fn shared(jobs: usize) -> SweepEngine {
        Self::with_cache(jobs, Arc::clone(shared_cache()))
    }

    /// An engine over an explicit cache — e.g. a store-backed
    /// [`RunCache::with_store`], shared between `caba sweep` runs and the
    /// serve daemon's workers.
    pub fn with_cache(jobs: usize, cache: Arc<RunCache>) -> SweepEngine {
        SweepEngine { jobs: resolve_jobs(jobs), cache, fault: None, metrics: None }
    }

    /// Attach a fault-injection plan: [`FaultPlan::before_job`] runs
    /// ahead of every executed (non-cached) job.
    pub fn with_fault(mut self, fault: Arc<FaultPlan>) -> SweepEngine {
        self.fault = Some(fault);
        self
    }

    /// Attach job metrics (the serve daemon passes the [`JobMetrics`]
    /// slice of its `obs::ServiceMetrics` registry; `caba sweep` could do
    /// the same). Purely observational — see the field docs.
    pub fn with_metrics(mut self, metrics: Arc<JobMetrics>) -> SweepEngine {
        self.metrics = Some(metrics);
        self
    }

    /// Worker count this engine resolves to.
    pub fn worker_count(&self) -> usize {
        self.jobs
    }

    /// Entries in this engine's run cache (tests assert re-runs of a
    /// matrix — including trace-driven ones — are pure cache hits).
    pub fn cache_entries(&self) -> usize {
        self.cache.len()
    }

    /// This engine's cache (the serve daemon reads store counters off it).
    pub fn cache(&self) -> &Arc<RunCache> {
        &self.cache
    }

    /// Execute a job with the observation hooks around it: wall time into
    /// `job_wall_us`, outcome into `jobs_ok`/`jobs_failed`. With no
    /// metrics attached this is exactly `SweepJob::execute`.
    fn observed_execute(&self, job: &SweepJob) -> Result<SimStats, JobError> {
        let Some(m) = &self.metrics else {
            return job.execute(self.fault.as_deref());
        };
        let t0 = Instant::now();
        let res = job.execute(self.fault.as_deref());
        m.job_wall_us.record_duration(t0.elapsed());
        match &res {
            Ok(_) => m.jobs_ok.fetch_add(1, Ordering::Relaxed),
            Err(_) => m.jobs_failed.fetch_add(1, Ordering::Relaxed),
        };
        res
    }

    /// Dedup `jobs` against the cache, preserving first-seen order (keeps
    /// serial execution order identical to the pre-engine code paths).
    fn plan<'j>(&self, jobs: &'j [SweepJob]) -> (Vec<JobKey>, Vec<&'j SweepJob>, Vec<JobKey>) {
        let keys: Vec<JobKey> = jobs.iter().map(SweepJob::key).collect();
        let mut todo: Vec<&SweepJob> = Vec::new();
        let mut todo_keys: Vec<JobKey> = Vec::new();
        for (job, key) in jobs.iter().zip(&keys) {
            if !todo_keys.contains(key) && !self.cache.contains(key) {
                todo.push(job);
                todo_keys.push(*key);
            }
        }
        (keys, todo, todo_keys)
    }

    /// Execute the deduped misses on a scoped worker pool of
    /// `min(jobs, misses)` threads, publishing successes into the cache
    /// and errors into the returned list (indexed into `todo`). When
    /// `fail_fast` is set, the first error stops workers from *claiming*
    /// further jobs (in-flight simulations still finish and are cached).
    fn execute_todo(
        &self,
        todo: &[&SweepJob],
        todo_keys: &[JobKey],
        fail_fast: bool,
    ) -> Vec<(usize, JobError)> {
        let errors: Mutex<Vec<(usize, JobError)>> = Mutex::new(Vec::new());
        let abort = AtomicBool::new(false);
        let workers = self.jobs.min(todo.len()).max(1);
        // Queue-wait instrumentation: every miss is conceptually enqueued
        // when the matrix is submitted, and "claimed" when a worker calls
        // `run_one` — the gap is what the engine's internal queue cost
        // this job (observation-only, recorded nowhere near results).
        let submitted = Instant::now();
        let run_one = |i: usize| {
            if let Some(m) = &self.metrics {
                m.queue_wait_us.record_duration(submitted.elapsed());
            }
            match self.observed_execute(todo[i]) {
                Ok(stats) => self.cache.insert(todo_keys[i], stats),
                Err(e) => {
                    errors.lock().unwrap_or_else(PoisonError::into_inner).push((i, e));
                    if fail_fast {
                        abort.store(true, Ordering::Relaxed);
                    }
                }
            }
        };
        if workers <= 1 {
            for i in 0..todo.len() {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                run_one(i);
            }
        } else {
            // Scoped worker pool over an atomic work index: each worker
            // claims the next un-run job, simulates it without holding
            // any lock, and publishes the result under its precomputed
            // key.
            let next = AtomicUsize::new(0);
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        if abort.load(Ordering::Relaxed) {
                            break;
                        }
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= todo.len() {
                            break;
                        }
                        run_one(i);
                    });
                }
            });
        }
        let mut errs = errors.into_inner().unwrap_or_else(PoisonError::into_inner);
        errs.sort_by_key(|(i, _)| *i);
        errs
    }

    /// Run every job, returning stats in request order. Duplicate and
    /// already-cached points are simulated exactly once. **Fail-fast**:
    /// the first job error aborts the remaining matrix and is returned —
    /// the policy for `caba sweep` and the test suites, where a partial
    /// matrix is useless. Successes computed before the abort stay
    /// cached, so a retry resumes rather than restarts.
    pub fn run(&self, jobs: &[SweepJob]) -> Result<Vec<SimStats>, JobError> {
        let (keys, todo, todo_keys) = self.plan(jobs);
        let errs = self.execute_todo(&todo, &todo_keys, true);
        if let Some((_, e)) = errs.into_iter().next() {
            return Err(e);
        }
        Ok(keys
            .iter()
            .map(|k| self.cache.get(k).expect("sweep job executed but not cached"))
            .collect())
    }

    /// Run every job, returning a per-point `Result` in request order.
    /// **Collect-and-report**: every miss is attempted regardless of
    /// other points' failures — the serve daemon's policy, where one
    /// client's broken request must not starve the rest. Failed keys are
    /// never cached (the next request retries them).
    pub fn run_collect(&self, jobs: &[SweepJob]) -> Vec<Result<SimStats, JobError>> {
        let (keys, todo, todo_keys) = self.plan(jobs);
        let errs = self.execute_todo(&todo, &todo_keys, false);
        let by_key: HashMap<JobKey, JobError> =
            errs.into_iter().map(|(i, e)| (todo_keys[i], e)).collect();
        keys.iter()
            .map(|k| match self.cache.get(k) {
                Some(s) => Ok(s),
                None => Err(by_key.get(k).cloned().unwrap_or_else(|| JobError {
                    app: k.0,
                    design: k.1,
                    cause: "job executed but neither cached nor reported".to_string(),
                })),
            })
            .collect()
    }

    /// Run (or fetch) a single point, surfacing failure as a value (the
    /// serve daemon's per-request entry point).
    pub fn try_run_one(&self, job: &SweepJob) -> Result<SimStats, JobError> {
        let key = job.key();
        if let Some(s) = self.cache.get(&key) {
            return Ok(s);
        }
        let stats = self.observed_execute(job)?;
        self.cache.insert(key, stats.clone());
        Ok(stats)
    }

    /// Run (or fetch) a single point, panicking on job failure — the
    /// figure-regeneration path, where a failed point means the figure
    /// cannot exist and the typed message is the diagnostic.
    pub fn run_one(&self, job: &SweepJob) -> SimStats {
        self.try_run_one(job).unwrap_or_else(|e| panic!("{e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Algo;
    use crate::workload::apps;

    fn tiny_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.n_sms = 2;
        c.max_cycles = 150_000;
        c
    }

    #[test]
    fn dedup_and_order_preserved() {
        let app = apps::find("SLA").unwrap();
        let j = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let engine = SweepEngine::new(2);
        let out = engine.run(&[j.clone(), j.clone(), j.clone()]).unwrap();
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[1]);
        assert_eq!(out[1], out[2]);
        // All three collapsed to one cache entry.
        assert_eq!(engine.cache.len(), 1);
    }

    #[test]
    fn injected_panic_becomes_typed_error_not_abort() {
        let app = apps::find("SLA").unwrap();
        let j = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let fault = Arc::new(FaultPlan::parse("panic_at_job=0").unwrap());
        let engine = SweepEngine::new(1).with_fault(fault);
        let err = engine.try_run_one(&j).expect_err("injected panic must surface as JobError");
        assert_eq!(err.app, "SLA");
        assert!(err.cause.contains("injected fault"), "cause: {}", err.cause);
        // The failure was not cached: the retry (no fault scheduled at
        // index 1) succeeds.
        assert_eq!(engine.cache_entries(), 0);
        assert!(engine.try_run_one(&j).is_ok());
    }

    #[test]
    fn run_is_fail_fast_and_run_collect_reports_per_point() {
        let sla = apps::find("SLA").unwrap();
        let pvc = apps::find("PVC").unwrap();
        let jobs = [
            SweepJob::new(sla, Design::base(), tiny_cfg(), 0.01),
            SweepJob::new(pvc, Design::base(), tiny_cfg(), 0.01),
        ];
        // Serial engine, fault at job index 0: `run` returns that error.
        let fault = Arc::new(FaultPlan::parse("panic_at_job=0").unwrap());
        let engine = SweepEngine::new(1).with_fault(fault);
        assert!(engine.run(&jobs).is_err());

        // collect-and-report: the faulted point errors, the other still
        // computes (fresh engine, fresh fault so indices restart).
        let fault = Arc::new(FaultPlan::parse("panic_at_job=0").unwrap());
        let engine = SweepEngine::new(1).with_fault(fault);
        let out = engine.run_collect(&jobs);
        assert!(out[0].is_err());
        assert!(out[1].is_ok());
        // And a clean re-run heals the failed point from cache + retry.
        let healed = engine.run(&jobs).unwrap();
        assert_eq!(healed[1], *out[1].as_ref().unwrap());
    }

    #[test]
    fn poisoned_shard_recovers() {
        let app = apps::find("SLA").unwrap();
        let j = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let cache = RunCache::new();
        let key = j.key();
        cache.insert(key, SimStats::default());
        cache.poison_for_tests(&key);
        // Every accessor still works after the poisoning panic.
        assert!(cache.contains(&key));
        assert_eq!(cache.get(&key), Some(SimStats::default()));
        assert_eq!(cache.len(), 1);
        cache.insert(key, SimStats::default());
    }

    #[test]
    fn unprofitable_app_normalizes_to_base_key() {
        let app = apps::find("SCP").unwrap(); // profiler-disabled (§6)
        let caba = SweepJob::new(app, Design::caba(Algo::Bdi), tiny_cfg(), 0.01);
        let base = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        assert_eq!(caba.key(), base.key());
    }

    #[test]
    fn unprofitable_hybrid_collapses_to_memo_not_base() {
        let app = apps::find("MCX").unwrap(); // incompressible, compute-bound
        let hybrid = SweepJob::new(app, Design::caba_memo_hybrid(), tiny_cfg(), 0.01);
        let memo = SweepJob::new(app, Design::caba_memo(), tiny_cfg(), 0.01);
        let base = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        assert_eq!(hybrid.key(), memo.key());
        assert_ne!(hybrid.key(), base.key());
    }

    #[test]
    fn cache_key_distinguishes_configs() {
        let app = apps::find("SLA").unwrap();
        let a = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let mut cfg2 = tiny_cfg();
        cfg2.set("l2_bytes", "131072").unwrap();
        let b = SweepJob::new(app, Design::base(), cfg2, 0.01);
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn trace_record_path_never_fragments_the_cache() {
        // Recording is a run control, not a simulated parameter: two jobs
        // differing only in `trace_record` must share one cache entry (and
        // sweep jobs must never actually record).
        let app = apps::find("SLA").unwrap();
        let a = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let mut cfg2 = tiny_cfg();
        cfg2.set("trace_record", "/tmp/should_not_be_written.cabatrace").unwrap();
        let b = SweepJob::new(app, Design::base(), cfg2, 0.01);
        assert_eq!(a.key(), b.key());
        assert!(b.cfg.trace_record.is_empty(), "constructor must strip trace_record");
        // The flight recorder is stripped for the same reason: a sweep job
        // only ever surfaces aggregate stats, so recording would be pure
        // waste — and two configs differing only in telemetry knobs must
        // share one cache entry.
        let mut cfg3 = tiny_cfg();
        cfg3.set("telemetry_window", "512").unwrap();
        cfg3.set("telemetry_spans", "16").unwrap();
        let c = SweepJob::new(app, Design::base(), cfg3, 0.01);
        assert_eq!(a.key(), c.key());
        assert_eq!(c.cfg.telemetry_window, 0, "constructor must strip telemetry_window");
        assert_eq!(c.cfg.telemetry_spans, SimConfig::default().telemetry_spans);
    }

    #[test]
    fn resolve_jobs_defaults_to_parallelism() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }
}
