//! # The parallel sweep engine
//!
//! CABA's evaluation (§7) is a large `(app × design × bw_scale)` matrix —
//! 27 workloads against Base, HW-BDI, CABA-{BDI,FPC,C-Pack} and more. Each
//! point is an independent, fully deterministic cycle-level simulation, so
//! the matrix is embarrassingly parallel — exactly the kind of idle-core
//! work the paper itself harvests with assist warps. This module puts the
//! *host's* idle cores to work the same way.
//!
//! ## Architecture
//!
//! * [`SweepJob`] — one simulation point: `(app, design, cfg, scale)`. The
//!   configuration is carried **whole**; the job key is derived from
//!   [`crate::SimConfig::fingerprint`], which digests every field, so two
//!   jobs differing in any `--set` override never alias (this fixed a
//!   latent cache-poisoning bug where the old figure cache keyed only on
//!   `(app, design, bw_scale, scale)`).
//! * [`RunCache`] — a sharded `(key → SimStats)` map. Sharding by key hash
//!   keeps lock hold times to a single bucket operation; workers touching
//!   different shards never contend (the old cache was one global
//!   `Mutex<HashMap>` around the *whole* run loop's results).
//! * [`SweepEngine`] — deduplicates the requested jobs against the cache,
//!   executes the misses on a scoped `std::thread` worker pool (no
//!   external deps), and returns results in request order.
//!
//! Jobs are synthetic by default; [`SweepJob::replay`] makes a point
//! **trace-driven** (`crate::trace`) — the key then also carries the
//! trace's content digest, so re-sweeping the same trace file is pure
//! cache hits while distinct traces never alias.
//!
//! ## Determinism
//!
//! Parallel execution is **bit-identical** to serial execution because no
//! simulation state is shared between jobs:
//!
//! * each job owns its `Simulator` (cores, memory system, oracle, data
//!   model) — the `Send` bound on [`crate::compress::oracle::
//!   CompressionOracle`] lets the whole bundle move to a worker thread;
//! * every random stream is seeded per job from the configuration:
//!   workload construction derives its RNG seed as
//!   `cfg.seed ^ hash(app.name)` ([`crate::workload::Workload`]), and the
//!   program build uses `hash(app.name)` — nothing depends on wall clock,
//!   thread id, or execution order;
//! * workers only *write* finished `SimStats` into their job's dedicated
//!   slot; the work queue is an atomic index, which affects scheduling but
//!   not results.
//!
//! `tests/integration_sweep.rs` asserts `--jobs 1` ≡ `--jobs 4` on a small
//! matrix, field for field.

use crate::config::SimConfig;
use crate::sim::designs::Design;
use crate::sim::Simulator;
use crate::stats::SimStats;
use crate::trace::replay::TraceData;
use crate::workload::apps::AppSpec;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// One point of an evaluation sweep: a complete, self-contained
/// simulation request — synthetic (`app` drives generation) or
/// trace-driven (`trace` replays a recorded/imported access stream).
#[derive(Clone)]
pub struct SweepJob {
    pub app: &'static AppSpec,
    pub design: Design,
    /// The **full** configuration (including `bw_scale` and any `--set`
    /// overrides) — all of it participates in the cache key. The
    /// constructors strip `trace_record` and the telemetry knobs: sweep
    /// jobs never record (traces or timelines), and a recording path must
    /// not fragment the cache.
    pub cfg: SimConfig,
    /// Workload scale factor (iterations / CTA count shrink).
    pub scale: f64,
    /// Replay source; `None` = synthetic workload.
    pub trace: Option<Arc<TraceData>>,
}

/// Cache key: app and design are identified by their unique static names;
/// the configuration by its full-field fingerprint; a trace-driven job
/// additionally by the trace's **content digest** (last element, 0 for
/// synthetic jobs) — two different trace files never alias, and the same
/// file re-loaded (or re-recorded deterministically) hits the cache. A
/// collision between two *different* configs/traces is a 64-bit hash
/// collision — negligible against what a process ever sweeps.
pub type JobKey = (&'static str, &'static str, u64, u64, u64);

impl SweepJob {
    pub fn new(app: &'static AppSpec, design: Design, mut cfg: SimConfig, scale: f64) -> SweepJob {
        cfg.trace_record = String::new();
        // Same reasoning as trace_record: the flight recorder is a run
        // control outside the fingerprint, so a telemetry-enabled config
        // would alias a cache entry whose stored stats carry no timeline.
        // Sweep results are aggregates only — never record.
        cfg.telemetry_window = 0;
        cfg.telemetry_spans = SimConfig::default().telemetry_spans;
        SweepJob { app, design, cfg, scale, trace: None }
    }

    /// Convenience for the figure sweeps: `base_cfg` with `bw_scale`
    /// applied on top (the ½×/1×/2× experiments of Figs. 2 and 14).
    pub fn with_bw(
        app: &'static AppSpec,
        design: Design,
        base_cfg: &SimConfig,
        bw_scale: f64,
        scale: f64,
    ) -> SweepJob {
        let mut cfg = base_cfg.clone();
        cfg.bw_scale = bw_scale;
        Self::new(app, design, cfg, scale)
    }

    /// A **trace-driven** point: replay `trace` under `design` and `cfg`.
    /// The workload scale is pinned to the trace's recorded scale (the
    /// access keys only cover that geometry).
    pub fn replay(trace: &Arc<TraceData>, design: Design, cfg: SimConfig) -> SweepJob {
        let scale = trace.meta.scale;
        let mut job = Self::new(trace.spec(), design, cfg, scale);
        job.trace = Some(Arc::clone(trace));
        job
    }

    /// The design that will actually execute: the paper's profiler
    /// disables compression for apps where it is unprofitable (§6), so
    /// those points collapse onto Base — normalizing *before* keying makes
    /// them share one cache entry. Memoization is orthogonal to data
    /// compressibility: a compress+memo hybrid on an incompressible app
    /// keeps its memo half and collapses onto CABA-Memo, never onto Base.
    fn effective_design(&self) -> Design {
        if self.design.compression_enabled() && !Simulator::compression_profitable(self.app) {
            if self.design.memoization {
                Design::caba_memo()
            } else {
                Design::base()
            }
        } else {
            self.design
        }
    }

    fn key(&self) -> JobKey {
        (
            self.app.name,
            self.effective_design().name,
            self.cfg.fingerprint(),
            self.scale.to_bits(),
            self.trace.as_ref().map_or(0, |t| t.digest),
        )
    }

    fn execute(&self) -> SimStats {
        match &self.trace {
            Some(t) => Simulator::from_trace(self.cfg.clone(), self.effective_design(), Arc::clone(t))
                .unwrap_or_else(|e| {
                    panic!("trace-driven sweep job ({}, {}): {e:#}", self.app.name, self.design.name)
                })
                .run(),
            None => Simulator::new(self.cfg.clone(), self.effective_design(), self.app, self.scale)
                .run(),
        }
    }
}

/// Number of cache shards. Far more than any realistic worker count, so
/// two workers completing jobs at the same instant almost never queue on
/// the same lock.
const N_SHARDS: usize = 16;

/// A sharded run cache: `key → SimStats`, split over [`N_SHARDS`]
/// independently locked maps. Locks are held only for single map
/// operations (simulations run entirely outside them).
pub struct RunCache {
    shards: [Mutex<HashMap<JobKey, SimStats>>; N_SHARDS],
}

impl Default for RunCache {
    fn default() -> Self {
        RunCache { shards: std::array::from_fn(|_| Mutex::new(HashMap::new())) }
    }
}

impl RunCache {
    pub fn new() -> RunCache {
        RunCache::default()
    }

    fn shard(&self, key: &JobKey) -> &Mutex<HashMap<JobKey, SimStats>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % N_SHARDS]
    }

    pub fn get(&self, key: &JobKey) -> Option<SimStats> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    pub fn insert(&self, key: JobKey, stats: SimStats) {
        self.shard(&key).lock().unwrap().insert(key, stats);
    }

    pub fn contains(&self, key: &JobKey) -> bool {
        self.shard(key).lock().unwrap().contains_key(key)
    }

    /// Total cached entries (diagnostics).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The process-wide cache shared by all figure regenerators (figures 8–11
/// reuse each other's runs, exactly as before — but now keyed on the full
/// configuration and sharded).
pub fn shared_cache() -> &'static Arc<RunCache> {
    static CACHE: OnceLock<Arc<RunCache>> = OnceLock::new();
    CACHE.get_or_init(|| Arc::new(RunCache::new()))
}

/// Resolve a `--jobs` request: `0` means "one worker per available core".
pub fn resolve_jobs(jobs: usize) -> usize {
    if jobs > 0 {
        jobs
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Deterministic parallel executor for sweep matrices.
pub struct SweepEngine {
    jobs: usize,
    cache: Arc<RunCache>,
}

impl SweepEngine {
    /// An engine with its own private cache (tests, one-shot sweeps).
    pub fn new(jobs: usize) -> SweepEngine {
        SweepEngine { jobs: resolve_jobs(jobs), cache: Arc::new(RunCache::new()) }
    }

    /// An engine backed by the process-wide [`shared_cache`] (the figure
    /// regenerators, so figures sharing runs don't re-simulate).
    pub fn shared(jobs: usize) -> SweepEngine {
        SweepEngine { jobs: resolve_jobs(jobs), cache: Arc::clone(shared_cache()) }
    }

    /// Worker count this engine resolves to.
    pub fn worker_count(&self) -> usize {
        self.jobs
    }

    /// Entries in this engine's run cache (tests assert re-runs of a
    /// matrix — including trace-driven ones — are pure cache hits).
    pub fn cache_entries(&self) -> usize {
        self.cache.len()
    }

    /// Run every job, returning stats in request order. Duplicate and
    /// already-cached points are simulated exactly once; the misses run on
    /// a scoped worker pool of `min(jobs, misses)` threads.
    pub fn run(&self, jobs: &[SweepJob]) -> Vec<SimStats> {
        let keys: Vec<JobKey> = jobs.iter().map(SweepJob::key).collect();

        // Dedup the misses, preserving first-seen order (keeps serial
        // execution order identical to the pre-engine code paths).
        let mut todo: Vec<&SweepJob> = Vec::new();
        let mut todo_keys: Vec<JobKey> = Vec::new();
        for (job, key) in jobs.iter().zip(&keys) {
            if !todo_keys.contains(key) && !self.cache.contains(key) {
                todo.push(job);
                todo_keys.push(*key);
            }
        }

        let workers = self.jobs.min(todo.len()).max(1);
        if workers <= 1 {
            for (job, key) in todo.iter().zip(&todo_keys) {
                self.cache.insert(*key, job.execute());
            }
        } else {
            // Scoped worker pool over an atomic work index: each worker
            // claims the next un-run job, simulates it without holding any
            // lock, and publishes the result under its precomputed key.
            let next = AtomicUsize::new(0);
            let cache = &self.cache;
            let todo = &todo;
            let todo_keys = &todo_keys;
            std::thread::scope(|s| {
                for _ in 0..workers {
                    s.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= todo.len() {
                            break;
                        }
                        let stats = todo[i].execute();
                        cache.insert(todo_keys[i], stats);
                    });
                }
            });
        }

        keys.iter()
            .map(|k| self.cache.get(k).expect("sweep job executed but not cached"))
            .collect()
    }

    /// Run (or fetch) a single point.
    pub fn run_one(&self, job: &SweepJob) -> SimStats {
        let key = job.key();
        if let Some(s) = self.cache.get(&key) {
            return s;
        }
        let stats = job.execute();
        self.cache.insert(key, stats.clone());
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::Algo;
    use crate::workload::apps;

    fn tiny_cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.n_sms = 2;
        c.max_cycles = 150_000;
        c
    }

    #[test]
    fn dedup_and_order_preserved() {
        let app = apps::find("SLA").unwrap();
        let j = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let engine = SweepEngine::new(2);
        let out = engine.run(&[j.clone(), j.clone(), j.clone()]);
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], out[1]);
        assert_eq!(out[1], out[2]);
        // All three collapsed to one cache entry.
        assert_eq!(engine.cache.len(), 1);
    }

    #[test]
    fn unprofitable_app_normalizes_to_base_key() {
        let app = apps::find("SCP").unwrap(); // profiler-disabled (§6)
        let caba = SweepJob::new(app, Design::caba(Algo::Bdi), tiny_cfg(), 0.01);
        let base = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        assert_eq!(caba.key(), base.key());
    }

    #[test]
    fn unprofitable_hybrid_collapses_to_memo_not_base() {
        let app = apps::find("MCX").unwrap(); // incompressible, compute-bound
        let hybrid = SweepJob::new(app, Design::caba_memo_hybrid(), tiny_cfg(), 0.01);
        let memo = SweepJob::new(app, Design::caba_memo(), tiny_cfg(), 0.01);
        let base = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        assert_eq!(hybrid.key(), memo.key());
        assert_ne!(hybrid.key(), base.key());
    }

    #[test]
    fn cache_key_distinguishes_configs() {
        let app = apps::find("SLA").unwrap();
        let a = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let mut cfg2 = tiny_cfg();
        cfg2.set("l2_bytes", "131072").unwrap();
        let b = SweepJob::new(app, Design::base(), cfg2, 0.01);
        assert_ne!(a.key(), b.key());
    }

    #[test]
    fn trace_record_path_never_fragments_the_cache() {
        // Recording is a run control, not a simulated parameter: two jobs
        // differing only in `trace_record` must share one cache entry (and
        // sweep jobs must never actually record).
        let app = apps::find("SLA").unwrap();
        let a = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
        let mut cfg2 = tiny_cfg();
        cfg2.set("trace_record", "/tmp/should_not_be_written.cabatrace").unwrap();
        let b = SweepJob::new(app, Design::base(), cfg2, 0.01);
        assert_eq!(a.key(), b.key());
        assert!(b.cfg.trace_record.is_empty(), "constructor must strip trace_record");
        // The flight recorder is stripped for the same reason: a sweep job
        // only ever surfaces aggregate stats, so recording would be pure
        // waste — and two configs differing only in telemetry knobs must
        // share one cache entry.
        let mut cfg3 = tiny_cfg();
        cfg3.set("telemetry_window", "512").unwrap();
        cfg3.set("telemetry_spans", "16").unwrap();
        let c = SweepJob::new(app, Design::base(), cfg3, 0.01);
        assert_eq!(a.key(), c.key());
        assert_eq!(c.cfg.telemetry_window, 0, "constructor must strip telemetry_window");
        assert_eq!(c.cfg.telemetry_spans, SimConfig::default().telemetry_spans);
    }

    #[test]
    fn resolve_jobs_defaults_to_parallelism() {
        assert!(resolve_jobs(0) >= 1);
        assert_eq!(resolve_jobs(3), 3);
    }
}
