//! A sense-reversing spin barrier for the intra-simulation shard loop.
//!
//! `std::sync::Barrier` parks waiters on a mutex + condvar, costing
//! microseconds per crossing; the shard loop in `crate::sim` crosses twice
//! per simulated epoch — potentially millions of times per run — with
//! per-epoch work that is often well under a microsecond. Waiters here
//! spin briefly (the common case: every participant arrives within the
//! epoch's cache-resident working set) and fall back to `yield_now` so an
//! oversubscribed machine still makes progress.
//!
//! One instance is reused for the whole run; the `generation` counter (the
//! "sense") distinguishes crossings, so a released waiter can immediately
//! start arriving at the next crossing without racing the reset.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Spins before each `yield_now` once a waiter has waited this long.
const SPINS_BEFORE_YIELD: u32 = 10_000;

pub struct SpinBarrier {
    n: usize,
    count: AtomicUsize,
    generation: AtomicUsize,
}

impl SpinBarrier {
    pub fn new(n: usize) -> SpinBarrier {
        assert!(n > 0, "a barrier needs at least one participant");
        SpinBarrier {
            n,
            count: AtomicUsize::new(0),
            generation: AtomicUsize::new(0),
        }
    }

    /// Block until all `n` participants have called `wait` for the current
    /// generation.
    ///
    /// Ordering: every arrival is an `AcqRel` RMW on `count`, so the last
    /// arriver's release-store to `generation` carries *all* participants'
    /// pre-barrier writes; a waiter's acquire-load of the new generation
    /// therefore sees every other participant's work. The count resets
    /// *before* the generation bump — a released waiter re-arming for the
    /// next crossing observes the reset via that same release/acquire
    /// edge.
    pub fn wait(&self) {
        let generation = self.generation.load(Ordering::Acquire);
        if self.count.fetch_add(1, Ordering::AcqRel) + 1 == self.n {
            self.count.store(0, Ordering::Relaxed);
            self.generation
                .store(generation.wrapping_add(1), Ordering::Release);
            return;
        }
        let mut spins = 0u32;
        while self.generation.load(Ordering::Acquire) == generation {
            spins = spins.wrapping_add(1);
            if spins < SPINS_BEFORE_YIELD {
                std::hint::spin_loop();
            } else {
                std::thread::yield_now();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn single_participant_never_blocks() {
        let b = SpinBarrier::new(1);
        for _ in 0..1_000 {
            b.wait();
        }
    }

    #[test]
    #[should_panic(expected = "at least one participant")]
    fn zero_participants_rejected() {
        let _ = SpinBarrier::new(0);
    }

    #[test]
    fn rounds_are_totally_ordered_across_threads() {
        // Every thread's round-r contribution lands strictly before any
        // thread starts round r+1 — the property the shard loop's
        // phase-A → drain handoff rests on.
        const THREADS: usize = 4;
        const ROUNDS: usize = 500;
        let b = SpinBarrier::new(THREADS);
        let counter = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for r in 0..ROUNDS {
                        counter.fetch_add(1, Ordering::Relaxed);
                        b.wait();
                        // Between the two crossings nobody increments, so
                        // every thread reads the exact round total.
                        assert_eq!(counter.load(Ordering::Relaxed), (r + 1) * THREADS);
                        b.wait();
                    }
                });
            }
        });
    }
}
