//! Deterministic xoshiro256** PRNG.
//!
//! The offline image ships no `rand` crate, and the simulator needs strictly
//! reproducible streams anyway (workload generation, data patterns and
//! property tests must be identical across runs and machines), so we carry a
//! tiny, well-known generator ourselves.

/// xoshiro256** by Blackman & Vigna — public-domain reference algorithm.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng {
            s: [next(), next(), next(), next()],
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)`. `bound` must be non-zero.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // Widening-multiply rejection-free mapping (Lemire); slight bias is
        // irrelevant for simulation purposes.
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a slice element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn chance_rate_sane() {
        let mut r = Rng::new(11);
        let hits = (0..10_000).filter(|_| r.chance(0.25)).count();
        assert!((2000..3000).contains(&hits), "hits={hits}");
    }
}
