//! Small self-contained utilities: a deterministic PRNG, a mini
//! property-testing harness (the offline image has no `proptest`), a spin
//! barrier for the intra-sim shard loop, and math helpers shared across
//! the simulator and the report generators.

pub mod barrier;
pub mod miniprop;
pub mod rng;

/// Geometric mean of a slice of positive values. Returns 1.0 for an empty
/// slice (the natural identity for a normalized-speedup geomean).
pub fn geomean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 1.0;
    }
    let log_sum: f64 = values.iter().map(|v| v.max(1e-12).ln()).sum();
    (log_sum / values.len() as f64).exp()
}

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

/// Integer ceiling division.
pub fn ceil_div(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// SplitMix64 finalizer: the shared bit-mixing step behind the workload
/// address generators, the operand-value keys and the memo LUT's set/tag
/// hashes. One definition — key streams in different modules must never
/// silently diverge from a constant tweak applied in only one place.
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
        assert_eq!(geomean(&[]), 1.0);
    }

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[1.0, 3.0]), 2.0);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(0, 32), 0);
        assert_eq!(ceil_div(1, 32), 1);
        assert_eq!(ceil_div(32, 32), 1);
        assert_eq!(ceil_div(33, 32), 2);
        assert_eq!(ceil_div(128, 32), 4);
    }
}
