//! Deterministic cache-line data generators.
//!
//! The paper's workloads compress because of the *value distributions* their
//! data exhibits (BDI paper [87]: low dynamic range; FPC [5]: frequent
//! patterns; C-Pack [25]: dictionary redundancy). We cannot run the CUDA
//! binaries, so each app is assigned a generator that reproduces the
//! distribution class its data belongs to; the compressors then operate on
//! these *real bytes*. Contents are a pure function of
//! `(pattern, seed, line address, epoch)` so the simulator never stores
//! data: stores simply bump a line's epoch.

use crate::compress::{Line, LINE_BYTES};
use crate::util::rng::Rng;

/// A value-distribution class for one array's data.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DataPattern {
    /// `p_zero` of lines are all-zero; the rest narrow integers — sparse
    /// matrices, masks, histogram tails. Compresses extremely well.
    ZeroHeavy { p_zero: f64 },
    /// 8/4/2-byte values with small deltas around a per-line base —
    /// pointers, indices, sorted keys. BDI's home turf (paper Fig. 6).
    LowDynRange { value_bytes: u8, delta_bytes: u8 },
    /// Small unsigned integers in 4-byte words (counts, colors, graph
    /// degrees). FPC sign-ext patterns and BDI base4-d1 both like it.
    NarrowInt { max: u32 },
    /// Words whose upper 3 bytes come from a small set of "pointers";
    /// low byte varies. C-Pack's dictionary case.
    PointerLike { n_bases: u8 },
    /// Repeated-byte words (RGBA fills, splatted constants). FPC RepByte.
    RepBytes,
    /// FP32 values with a shared exponent neighbourhood (images,
    /// simulation grids): upper bytes correlate, low bytes are noisy.
    FloatGrid { exp: u8 },
    /// Mostly-zero words with occasional narrow values (CSR offsets, edge
    /// weights, sparse images). Zero+narrow *segments* are where segmented
    /// FPC beats BDI's whole-line geometry.
    SparseNarrow { p_nonzero: f64 },
    /// Uniformly random bytes — incompressible (paper's sc, SCP).
    Random,
    /// Per-line mix: choose between `a` (probability `p`) and `b`.
    Mix {
        p: f64,
        a: &'static DataPattern,
        b: &'static DataPattern,
    },
}

/// Generate the contents of `line_addr` under `pattern`.
///
/// `epoch` is the line's store-generation: stores rewrite a line with data
/// of the same distribution class (paper assumption: application data stays
/// in its pattern family as it is updated).
pub fn line_data(pattern: &DataPattern, seed: u64, line_addr: u64, epoch: u32) -> Line {
    let mut rng = Rng::new(
        seed ^ line_addr.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ ((epoch as u64) << 48),
    );
    let mut line = [0u8; LINE_BYTES];
    fill(pattern, &mut rng, &mut line);
    line
}

fn fill(pattern: &DataPattern, rng: &mut Rng, line: &mut Line) {
    match *pattern {
        DataPattern::ZeroHeavy { p_zero } => {
            if rng.chance(p_zero) {
                // all zeros — leave as-is
            } else {
                let max = 1 + rng.below(250) as u32;
                fill(&DataPattern::NarrowInt { max }, rng, line);
            }
        }
        DataPattern::LowDynRange { value_bytes, delta_bytes } => {
            let vb = value_bytes as usize;
            let base: u64 = rng.next_u64() >> (64 - 8 * vb as u32 + 9).min(56);
            let span = 1u64 << (8 * delta_bytes as u32 - 1);
            for i in 0..LINE_BYTES / vb {
                // ~12% implicit-zero values (the paper's second base); the
                // first value stays base-relative so it anchors the base.
                let v = if i > 0 && rng.chance(0.12) {
                    rng.below(span)
                } else {
                    base.wrapping_add(rng.below(span))
                };
                line[i * vb..(i + 1) * vb].copy_from_slice(&v.to_le_bytes()[..vb]);
            }
        }
        DataPattern::NarrowInt { max } => {
            for ch in line.chunks_exact_mut(4) {
                let v = rng.below(max.max(1) as u64) as u32;
                ch.copy_from_slice(&v.to_le_bytes());
            }
        }
        DataPattern::PointerLike { n_bases } => {
            let mut bases = [0u32; 8];
            for b in bases.iter_mut().take(n_bases as usize) {
                *b = rng.next_u32() & 0xFFFF_FF00;
            }
            for ch in line.chunks_exact_mut(4) {
                let b = bases[rng.range(0, n_bases as usize)];
                let v = b | rng.below(256) as u32;
                ch.copy_from_slice(&v.to_le_bytes());
            }
        }
        DataPattern::RepBytes => {
            for ch in line.chunks_exact_mut(4) {
                let b = rng.below(16) as u8 * 0x11;
                ch.copy_from_slice(&[b, b, b, b]);
            }
        }
        DataPattern::FloatGrid { exp } => {
            // Smooth FP32 grid: neighbouring cells (one line = 32 adjacent
            // cells) share sign/exponent/upper-mantissa; only the low
            // mantissa is noisy. Most lines are BDI base4-d1; ~25% of lines
            // sit at a magnitude boundary (two upper-mantissa steps) and
            // fall back to base4-d2 / the C-Pack dictionary — the moderate
            // FP compressibility BDI [87] reports.
            let steps = if rng.chance(0.25) { 2 } else { 1 };
            let base_hi = (rng.below(4) as u32) << 20;
            for ch in line.chunks_exact_mut(4) {
                let mant_hi = base_hi + ((rng.below(steps) as u32) << 20);
                let mant_lo = rng.below(64) as u32;
                let bits = ((exp as u32) << 23) | mant_hi | mant_lo;
                ch.copy_from_slice(&bits.to_le_bytes());
            }
        }
        DataPattern::SparseNarrow { p_nonzero } => {
            // Cluster non-zeros in 8-word runs so whole FPC segments stay
            // zero (the sparsity structure real CSR/stencil data has).
            for seg in line.chunks_exact_mut(32) {
                if rng.chance(p_nonzero) {
                    for ch in seg.chunks_exact_mut(4) {
                        let v = 1 + rng.below(100) as u32;
                        ch.copy_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        DataPattern::Random => {
            for ch in line.chunks_exact_mut(8) {
                ch.copy_from_slice(&rng.next_u64().to_le_bytes());
            }
        }
        DataPattern::Mix { p, a, b } => {
            if rng.chance(p) {
                fill(a, rng, line);
            } else {
                fill(b, rng, line);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress, Algo};

    fn avg_ratio(pattern: &DataPattern, algo: Algo) -> f64 {
        let mut total_bursts = 0u32;
        let n = 200;
        for i in 0..n {
            let line = line_data(pattern, 42, i as u64, 0);
            total_bursts += compress(algo, &line).bursts() as u32;
        }
        4.0 * n as f64 / total_bursts as f64
    }

    #[test]
    fn deterministic() {
        let p = DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 };
        assert_eq!(line_data(&p, 1, 7, 0), line_data(&p, 1, 7, 0));
        assert_ne!(line_data(&p, 1, 7, 0), line_data(&p, 1, 8, 0));
        assert_ne!(line_data(&p, 1, 7, 0), line_data(&p, 1, 7, 1));
        assert_ne!(line_data(&p, 1, 7, 0), line_data(&p, 2, 7, 0));
    }

    #[test]
    fn zero_heavy_compresses_hugely() {
        let r = avg_ratio(&DataPattern::ZeroHeavy { p_zero: 0.7 }, Algo::Bdi);
        assert!(r > 2.5, "ratio={r}");
    }

    #[test]
    fn low_dyn_range_favours_bdi() {
        let p = DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 };
        let bdi = avg_ratio(&p, Algo::Bdi);
        let fpc = avg_ratio(&p, Algo::Fpc);
        assert!(bdi > 3.0, "bdi={bdi}");
        assert!(bdi > fpc, "bdi={bdi} fpc={fpc}");
    }

    #[test]
    fn pointer_like_favours_cpack() {
        let p = DataPattern::PointerLike { n_bases: 4 };
        let cp = avg_ratio(&p, Algo::CPack);
        let bdi = avg_ratio(&p, Algo::Bdi);
        assert!(cp > 1.3, "cp={cp}");
        assert!(cp > bdi, "cp={cp} bdi={bdi}");
    }

    #[test]
    fn rep_bytes_favours_fpc() {
        // RepByte: FPC packs each word to 1 byte → 37B → 2 bursts (ratio 2,
        // the burst-quantized maximum for this pattern); BDI gets nothing.
        let fpc = avg_ratio(&DataPattern::RepBytes, Algo::Fpc);
        let bdi = avg_ratio(&DataPattern::RepBytes, Algo::Bdi);
        assert!(fpc > 1.9, "fpc={fpc}");
        assert!(fpc > bdi, "fpc={fpc} bdi={bdi}");
    }

    #[test]
    fn random_incompressible() {
        for algo in Algo::CONCRETE {
            let r = avg_ratio(&DataPattern::Random, algo);
            assert!(r < 1.05, "{algo:?} ratio={r}");
        }
    }

    #[test]
    fn float_grid_moderate() {
        let r = avg_ratio(&DataPattern::FloatGrid { exp: 120 }, Algo::BestOfAll);
        assert!(r > 1.0 && r < 3.0, "ratio={r}");
    }

    #[test]
    fn mix_interpolates() {
        static A: DataPattern = DataPattern::ZeroHeavy { p_zero: 0.9 };
        static B: DataPattern = DataPattern::Random;
        let hi = avg_ratio(&DataPattern::Mix { p: 0.9, a: &A, b: &B }, Algo::Bdi);
        let lo = avg_ratio(&DataPattern::Mix { p: 0.1, a: &A, b: &B }, Algo::Bdi);
        assert!(hi > lo, "hi={hi} lo={lo}");
    }
}
