//! Workload construction: turns an [`apps::AppSpec`] profile into a
//! runnable μ-kernel ([`crate::isa::Program`]), computes the occupancy
//! (CTAs/SM, warps, register allocation — Fig. 3), and generates memory
//! addresses and line contents for the simulator.
//!
//! A workload's address/payload streams can additionally be **captured**
//! (every generated access and line image copied to a
//! [`crate::trace::record::TraceRecorder`]) or **replayed** (served from a
//! loaded [`crate::trace::replay::TraceData`] instead of the generators) —
//! see [`TraceRole`]. Both paths go through the same two functions
//! ([`Workload::access_lines`], [`Workload::line_data`]), so the simulator
//! proper is oblivious to where its workload comes from.

pub mod apps;
pub mod datagen;
pub mod values;

use crate::config::SimConfig;
use crate::compress::Line;
use crate::isa::{AccessKind, Inst, MemAccess, Op, Program, ProgramRef, NO_REG};
use crate::trace::{self, record::TraceRecorder, replay::TraceData, TraceKind};
use crate::util::{mix64, rng::Rng};
use anyhow::{bail, Result};
use apps::AppSpec;
use datagen::DataPattern;
use std::sync::Arc;

/// Array placement: arrays live `1<<40` lines apart, so a line address
/// uniquely identifies (array, index). Public because trace import rebases
/// external addresses into this layout.
pub const ARRAY_STRIDE: u64 = 1 << 40;

/// One materialized array.
#[derive(Clone, Debug)]
pub struct ArrayInfo {
    pub base_line: u64,
    pub footprint_lines: u64,
    pub pattern: DataPattern,
}

/// Static occupancy calculation (the quantities behind Fig. 3).
#[derive(Clone, Copy, Debug)]
pub struct Occupancy {
    pub ctas_per_sm: u32,
    pub warps_per_cta: u32,
    pub warps_per_sm: u32,
    pub regs_allocated: u32,
    /// Fraction of the register file left statically unallocated (Fig. 3).
    pub unallocated_reg_frac: f64,
    /// What capped the occupancy: "threads" | "ctas" | "regs" | "smem".
    pub limiter: &'static str,
}

/// Compute occupancy for `spec` with `extra_regs_per_thread` reserved for
/// assist-warp contexts (§4.2.2: the per-block register requirement grows
/// by each enabled helper subroutine's register need; 0 for non-CABA).
pub fn occupancy(spec: &AppSpec, cfg: &SimConfig, extra_regs_per_thread: u32) -> Occupancy {
    let tpc = spec.threads_per_cta;
    let regs_per_cta = (spec.regs_per_thread + extra_regs_per_thread) * tpc;
    let by_threads = cfg.max_threads_per_sm as u32 / tpc;
    let by_ctas = cfg.max_ctas_per_sm as u32;
    let by_regs = (cfg.regfile_per_sm as u32 / regs_per_cta).max(0);
    let by_smem = if spec.smem_per_cta == 0 {
        u32::MAX
    } else {
        (cfg.smem_per_sm / spec.smem_per_cta as usize) as u32
    };
    let ctas = by_threads.min(by_ctas).min(by_regs).min(by_smem).max(1);
    let limiter = if ctas == by_regs && by_regs <= by_threads && by_regs <= by_ctas && by_regs <= by_smem {
        "regs"
    } else if ctas == by_smem && by_smem <= by_threads && by_smem <= by_ctas {
        "smem"
    } else if ctas == by_threads && by_threads <= by_ctas {
        "threads"
    } else {
        "ctas"
    };
    let warps_per_cta = tpc / cfg.warp_size as u32;
    let regs_allocated = (ctas * regs_per_cta).min(cfg.regfile_per_sm as u32);
    Occupancy {
        ctas_per_sm: ctas,
        warps_per_cta,
        warps_per_sm: (ctas * warps_per_cta).min(cfg.max_warps_per_sm as u32),
        regs_allocated,
        unallocated_reg_frac: 1.0 - regs_allocated as f64 / cfg.regfile_per_sm as f64,
        limiter,
    }
}

/// Where this workload's memory accesses and line payloads come from.
#[derive(Clone)]
pub enum TraceRole {
    /// Pure synthetic generation (the default).
    Synthetic,
    /// Synthetic generation, with every access/payload streamed to a
    /// trace recorder (non-invasive: simulation results are unchanged).
    Record(Arc<TraceRecorder>),
    /// Accesses (and payloads, where present) served from a loaded trace.
    Replay(Arc<TraceData>),
}

/// A fully built workload, ready for simulation.
#[derive(Clone)]
pub struct Workload {
    pub spec: &'static AppSpec,
    pub program: ProgramRef,
    pub arrays: Vec<ArrayInfo>,
    pub occ: Occupancy,
    pub total_ctas: u32,
    pub seed: u64,
    /// Trace capture/replay attachment.
    pub source: TraceRole,
}

impl Workload {
    /// Build a workload. `scale` shrinks the run (iterations and CTA count)
    /// for fast tests/benches; 1.0 = the full profile.
    pub fn build(spec: &'static AppSpec, cfg: &SimConfig, scale: f64) -> Workload {
        Self::build_with_extra_regs(spec, cfg, scale, 0)
    }

    /// Like [`Workload::build`] with assist-warp register provisioning.
    pub fn build_with_extra_regs(
        spec: &'static AppSpec,
        cfg: &SimConfig,
        scale: f64,
        extra_regs_per_thread: u32,
    ) -> Workload {
        let occ = occupancy(spec, cfg, extra_regs_per_thread);
        let iters = ((spec.iters as f64 * scale).ceil() as u32).max(1);
        let total_ctas = ((spec.total_ctas as f64 * scale.sqrt()).ceil() as u32).max(1);
        let program = Arc::new(build_program(spec, iters));
        let arrays = spec
            .arrays
            .iter()
            .enumerate()
            .map(|(i, a)| ArrayInfo {
                base_line: (i as u64 + 1) * ARRAY_STRIDE,
                footprint_lines: a.footprint_lines,
                pattern: a.pattern,
            })
            .collect();
        Workload {
            spec,
            program,
            arrays,
            occ,
            total_ctas,
            seed: cfg.seed ^ name_hash(spec.name),
            source: TraceRole::Synthetic,
        }
    }

    /// Build the workload side of a **trace replay**.
    ///
    /// For a recorded app trace the synthetic skeleton (program, arrays,
    /// occupancy) is rebuilt from the app spec at the trace's recorded
    /// scale — and cross-checked against the header geometry, so a spec
    /// that drifted since recording fails loudly instead of replaying
    /// garbage. For an imported trace the skeleton is synthesized from the
    /// header alone (`trace::import::trace_program` + one rebased array).
    /// Either way `source` is set to [`TraceRole::Replay`], which routes
    /// [`Workload::access_lines`] and (where the file carries payloads)
    /// [`Workload::line_data`] through the trace.
    pub fn build_replay(
        tracedata: &Arc<TraceData>,
        cfg: &SimConfig,
        extra_regs_per_thread: u32,
    ) -> Result<Workload> {
        let m = &tracedata.meta;
        let spec = tracedata.spec();
        match m.kind {
            TraceKind::Recorded => {
                let mut wl = Self::build_with_extra_regs(spec, cfg, m.scale, extra_regs_per_thread);
                if wl.program.iters != m.iters || wl.total_ctas != m.total_ctas {
                    bail!(
                        "trace geometry mismatch for app {:?}: trace has iters={} ctas={}, \
                         rebuild produced iters={} ctas={} — app profiles changed since recording?",
                        m.app,
                        m.iters,
                        m.total_ctas,
                        wl.program.iters,
                        wl.total_ctas
                    );
                }
                if wl.arrays.len() != m.arrays.len()
                    || wl.arrays.iter().zip(&m.arrays).any(|(a, &(fp, _))| a.footprint_lines != fp)
                {
                    bail!("trace array table mismatch for app {:?}", m.app);
                }
                // The recording run's seed, not the replay config's: the
                // payload generator fallback must reproduce recorded data.
                wl.seed = m.seed;
                wl.source = TraceRole::Replay(Arc::clone(tracedata));
                Ok(wl)
            }
            TraceKind::Imported => {
                let mut geom = *spec;
                geom.regs_per_thread = m.regs_per_thread;
                geom.threads_per_cta = m.threads_per_cta;
                geom.smem_per_cta = m.smem_per_cta;
                let occ = occupancy(&geom, cfg, extra_regs_per_thread);
                let mut arrays = Vec::with_capacity(m.arrays.len());
                for (i, &(fp, code)) in m.arrays.iter().enumerate() {
                    let Some(pattern) = trace::pattern_by_code(code) else {
                        bail!("imported trace carries unresolvable data-pattern code {code}");
                    };
                    arrays.push(ArrayInfo {
                        base_line: (i as u64 + 1) * ARRAY_STRIDE,
                        footprint_lines: fp,
                        pattern: *pattern,
                    });
                }
                Ok(Workload {
                    spec,
                    program: Arc::new(trace::import::trace_program(m.iters)),
                    arrays,
                    occ,
                    total_ctas: m.total_ctas,
                    seed: m.seed,
                    source: TraceRole::Replay(Arc::clone(tracedata)),
                })
            }
        }
    }

    /// Total warps launched over the run.
    pub fn total_warps(&self) -> u64 {
        self.total_ctas as u64 * self.occ.warps_per_cta as u64
    }

    /// Distinct line addresses touched by one warp memory instruction.
    /// `slot` is the instruction's index within the body (decorrelates
    /// multiple accesses per iteration).
    pub fn access_lines(&self, mem: &MemAccess, warp_uid: u64, iter: u32, slot: usize, out: &mut Vec<u64>) {
        if let TraceRole::Replay(t) = &self.source {
            t.access_into(warp_uid, iter, slot, out);
            return;
        }
        out.clear();
        let arr = &self.arrays[mem.array as usize];
        let fp = arr.footprint_lines;
        let pos = warp_uid
            .wrapping_mul(self.program.iters as u64)
            .wrapping_add(iter as u64);
        match mem.kind {
            AccessKind::Coalesced { reuse } => {
                let idx = (pos / reuse.max(1) as u64).wrapping_add(slot as u64 * 7919) % fp;
                out.push(arr.base_line + idx);
            }
            AccessKind::Strided { lines } => {
                let n = lines.max(1) as u64;
                let start = (pos.wrapping_mul(n)).wrapping_add(slot as u64 * 7919) % fp;
                for j in 0..n {
                    out.push(arr.base_line + (start + j) % fp);
                }
            }
            AccessKind::Scatter { degree } => {
                // Graph/tree gathers are irregular but *regionally* local:
                // a warp works within a neighbourhood (tree top levels,
                // frontier chunk) for several iterations before moving on.
                // Uniform-random scatter would be the pathological case no
                // real workload exhibits (and would thrash the MD cache far
                // beyond the paper's measured 85% hit rate).
                let n = degree.max(1) as u64;
                let region_lines = fp.min(4096);
                let n_regions = (fp / region_lines).max(1);
                let region = mix64(
                    self.seed ^ warp_uid.wrapping_mul(0xA24B_AED4_963E_E407) ^ (iter as u64 / 8),
                ) % n_regions;
                let region_base = region * region_lines;
                for j in 0..n {
                    let h = mix64(
                        self.seed
                            ^ pos.wrapping_mul(0x2545_F491_4F6C_DD1D)
                            ^ ((slot as u64) << 56)
                            ^ j.wrapping_mul(0x9E37_79B9_7F4A_7C15),
                    );
                    out.push(arr.base_line + region_base + h % region_lines);
                }
            }
        }
        if let TraceRole::Record(rec) = &self.source {
            let is_store =
                self.program.body.get(slot).is_some_and(|i| matches!(i.op, Op::St(_)));
            rec.record_access(warp_uid, iter, slot, is_store, out);
        }
    }

    /// Which array does a line address belong to?
    pub fn array_of(&self, line_addr: u64) -> &ArrayInfo {
        let idx = (line_addr / ARRAY_STRIDE) as usize - 1;
        &self.arrays[idx.min(self.arrays.len() - 1)]
    }

    /// Contents of a line at store-generation `epoch`: replayed from the
    /// trace when one is attached and carries this `(line, epoch)`, else
    /// generated (and, when recording, captured). The generator is a pure
    /// function of `(pattern, seed, line, epoch)`, so for recorded traces
    /// the two paths yield identical bytes — the fallback exists so a
    /// trace recorded under one design replays faithfully under another
    /// (different load/store interleavings sample different epochs).
    pub fn line_data(&self, line_addr: u64, epoch: u32) -> Line {
        if let TraceRole::Replay(t) = &self.source {
            if let Some(line) = t.payload(line_addr, epoch) {
                return line;
            }
            t.note_payload_fallback();
        }
        let arr = self.array_of(line_addr);
        let data = datagen::line_data(&arr.pattern, self.seed, line_addr, epoch);
        if let TraceRole::Record(rec) = &self.source {
            rec.record_payload(line_addr, epoch, &data);
        }
        data
    }

    /// Forward a memory-instruction issue cycle to an attached recorder
    /// (trace-info timestamp span; no-op otherwise).
    pub fn trace_note_cycle(&self, now: u64) {
        if let TraceRole::Record(rec) = &self.source {
            rec.note_cycle(now);
        }
    }
}

fn name_hash(name: &str) -> u64 {
    name.bytes()
        .fold(0xcbf2_9ce4_8422_2325u64, |h, b| (h ^ b as u64).wrapping_mul(0x1000_0000_01b3))
}

/// Build the loop body from the instruction mix: loads first (results
/// feeding the compute chain), compute interleaved with dependences on
/// recent results, stores of the final values — the structure GPGPU kernels
/// reduce to once control flow is regularized.
fn build_program(spec: &AppSpec, iters: u32) -> Program {
    let mut rng = Rng::new(name_hash(spec.name));
    let mut body = Vec::with_capacity(spec.body.insts_per_iter());
    let mut next_reg: u8 = 1; // r0 = thread index, always ready
    let mut live: Vec<u8> = vec![0];

    let alloc = |live: &mut Vec<u8>, next_reg: &mut u8| -> u8 {
        let r = *next_reg;
        *next_reg = (*next_reg % 62) + 1; // wrap within MAX_REGS
        live.push(r);
        if live.len() > 12 {
            live.remove(0);
        }
        r
    };

    for (slot, ld) in spec.body.loads.iter().enumerate() {
        let dst = alloc(&mut live, &mut next_reg);
        let addr_src = live[slot % live.len().max(1)];
        body.push(Inst::new(
            Op::Ld(MemAccess { array: ld.array, kind: ld.kind }),
            dst,
            [addr_src, NO_REG],
        ));
    }

    // Compute chain: each op sources one recent value (usually a load
    // result) and one older value, recreating the load→use dependences
    // behind the paper's Data Dependence Stalls.
    let emit_compute = |op: Op, count: u8, live: &mut Vec<u8>, next_reg: &mut u8, rng: &mut Rng| {
        let mut insts = Vec::new();
        for _ in 0..count {
            let s1 = *rng.pick(&live[live.len().saturating_sub(4)..]);
            let s2 = *rng.pick(live);
            let dst = alloc(live, next_reg);
            insts.push(Inst::new(op, dst, [s1, s2]));
        }
        insts
    };

    let mut compute = Vec::new();
    compute.extend(emit_compute(Op::IAlu, spec.body.ialu, &mut live, &mut next_reg, &mut rng));
    compute.extend(emit_compute(Op::FAlu, spec.body.falu, &mut live, &mut next_reg, &mut rng));
    compute.extend(emit_compute(Op::Fma, spec.body.fma, &mut live, &mut next_reg, &mut rng));
    compute.extend(emit_compute(Op::Sfu, spec.body.sfu, &mut live, &mut next_reg, &mut rng));
    // Deterministic shuffle so FU classes interleave.
    let mut shuffled = Vec::with_capacity(compute.len());
    while !compute.is_empty() {
        let i = rng.range(0, compute.len());
        shuffled.push(compute.remove(i));
    }
    body.extend(shuffled);

    for st in spec.body.stores.iter() {
        let src = *live.last().unwrap();
        body.push(Inst::new(
            Op::St(MemAccess { array: st.array, kind: st.kind }),
            NO_REG,
            [src, NO_REG],
        ));
    }

    Program { body, iters }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SimConfig {
        SimConfig::default()
    }

    #[test]
    fn occupancy_thread_limited() {
        let spec = apps::find("SLA").unwrap(); // 16 regs, 256 tpc
        let occ = occupancy(spec, &cfg(), 0);
        // 1536/256 = 6 CTAs; regs 16*256*6 = 24576 ≤ 32768 → thread-limited.
        assert_eq!(occ.ctas_per_sm, 6);
        assert_eq!(occ.warps_per_sm, 48);
        assert_eq!(occ.limiter, "threads");
        assert!((occ.unallocated_reg_frac - 0.25).abs() < 1e-9);
    }

    #[test]
    fn occupancy_reg_limited() {
        let spec = apps::find("RAY").unwrap(); // 40 regs, 128 tpc
        let occ = occupancy(spec, &cfg(), 0);
        // regs: 32768/(40*128)=6.4 → 6 CTAs; threads: 1536/128=12; ctas cap 8.
        assert_eq!(occ.ctas_per_sm, 6);
        assert_eq!(occ.limiter, "regs");
    }

    #[test]
    fn extra_regs_can_reduce_occupancy() {
        let spec = apps::find("RAY").unwrap();
        let base = occupancy(spec, &cfg(), 0);
        let caba = occupancy(spec, &cfg(), 8);
        assert!(caba.ctas_per_sm <= base.ctas_per_sm);
    }

    #[test]
    fn fig3_average_unallocated_in_paper_range() {
        // Paper: on average 24% of the register file is unallocated.
        let avg: f64 = apps::APPS
            .iter()
            .map(|a| occupancy(a, &cfg(), 0).unallocated_reg_frac)
            .sum::<f64>()
            / apps::APPS.len() as f64;
        assert!(
            (0.10..0.45).contains(&avg),
            "avg unallocated register fraction {avg:.3} out of plausible range"
        );
    }

    #[test]
    fn program_structure() {
        let spec = apps::find("MM").unwrap();
        let w = Workload::build(spec, &cfg(), 1.0);
        assert_eq!(w.program.body.len(), spec.body.insts_per_iter());
        assert_eq!(w.program.mem_insts_per_iter(), spec.body.loads.len() + spec.body.stores.len());
        // Deterministic across builds.
        let w2 = Workload::build(spec, &cfg(), 1.0);
        assert_eq!(w.program.body.len(), w2.program.body.len());
        assert_eq!(w.seed, w2.seed);
    }

    #[test]
    fn access_lines_properties() {
        let spec = apps::find("BFS").unwrap();
        let w = Workload::build(spec, &cfg(), 1.0);
        let mut out = Vec::new();
        // Coalesced → 1 line, within footprint.
        let co = &spec.body.loads[0];
        w.access_lines(&MemAccess { array: co.array, kind: co.kind }, 3, 5, 0, &mut out);
        assert_eq!(out.len(), 1);
        let arr = &w.arrays[co.array as usize];
        assert!(out[0] >= arr.base_line && out[0] < arr.base_line + arr.footprint_lines);
        // Scatter → `degree` lines, all in footprint.
        let sc = &spec.body.loads[1];
        w.access_lines(&MemAccess { array: sc.array, kind: sc.kind }, 3, 5, 1, &mut out);
        if let AccessKind::Scatter { degree } = sc.kind {
            assert_eq!(out.len(), degree as usize);
        }
        for &l in &out {
            let arr = w.array_of(l);
            assert!(l >= arr.base_line && l < arr.base_line + arr.footprint_lines);
        }
        // Deterministic.
        let mut out2 = Vec::new();
        w.access_lines(&MemAccess { array: sc.array, kind: sc.kind }, 3, 5, 1, &mut out2);
        assert_eq!(out, out2);
    }

    #[test]
    fn line_data_routes_to_array_pattern() {
        let spec = apps::find("SCP").unwrap(); // all arrays Random
        let w = Workload::build(spec, &cfg(), 1.0);
        let a = w.line_data(w.arrays[0].base_line + 5, 0);
        let b = w.line_data(w.arrays[0].base_line + 5, 0);
        assert_eq!(a, b);
        let c = w.line_data(w.arrays[0].base_line + 5, 1);
        assert_ne!(a, c);
    }

    #[test]
    fn scale_shrinks_work() {
        let spec = apps::find("MM").unwrap();
        let full = Workload::build(spec, &cfg(), 1.0);
        let small = Workload::build(spec, &cfg(), 0.1);
        assert!(small.program.iters < full.program.iters);
        assert!(small.total_ctas < full.total_ctas);
    }
}
