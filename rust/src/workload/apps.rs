//! The paper's 27-application workload pool (§6: CUDA SDK, Rodinia, Mars,
//! Lonestar), expressed as μ-kernel profiles.
//!
//! Each profile captures the observable behaviour the evaluation depends
//! on: instruction mix (compute vs memory vs SFU), coalescing behaviour,
//! working-set size and reuse, occupancy-determining resources
//! (registers/thread, CTA geometry, shared memory — Fig. 3), the paper's
//! memory-bound/compute-bound classification (Fig. 2), and a data-pattern
//! assignment reproducing each app's compressibility profile (Fig. 13).
//!
//! Parameters were set from the app's published characterizations (suite
//! papers + GPGPU-Sim studies) and then calibrated so the figure *shapes*
//! match the paper; see EXPERIMENTS.md.

use super::datagen::DataPattern;
use super::values::ValueSpec;
use crate::isa::AccessKind;

/// Benchmark suite of origin (Table of §6). `Synthetic` marks the
/// compute-bound memoization suite ([`MEMO_APPS`]) — μ-kernels built for
/// the §8.1 evaluation rather than ported from a published suite.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Suite {
    CudaSdk,
    Rodinia,
    Mars,
    Lonestar,
    Synthetic,
}

/// One array the kernel touches.
#[derive(Clone, Copy, Debug)]
pub struct ArraySpec {
    /// Working set in 128B lines.
    pub footprint_lines: u64,
    /// Value-distribution class for this array's contents.
    pub pattern: DataPattern,
}

/// A memory operand in the loop body.
#[derive(Clone, Copy, Debug)]
pub struct MemOp {
    /// Index into [`AppSpec::arrays`].
    pub array: u8,
    pub kind: AccessKind,
}

/// Loop-body instruction mix.
#[derive(Clone, Copy, Debug)]
pub struct BodySpec {
    pub loads: &'static [MemOp],
    pub stores: &'static [MemOp],
    pub ialu: u8,
    pub falu: u8,
    pub fma: u8,
    pub sfu: u8,
}

impl BodySpec {
    pub fn insts_per_iter(&self) -> usize {
        self.loads.len()
            + self.stores.len()
            + (self.ialu + self.falu + self.fma + self.sfu) as usize
    }
}

/// Full application profile.
#[derive(Clone, Copy, Debug)]
pub struct AppSpec {
    pub name: &'static str,
    pub suite: Suite,
    /// Paper's primary-bottleneck classification (§3: 17/27 memory-bound).
    pub memory_bound: bool,
    /// In the bandwidth-sensitive + compressible evaluation set of
    /// Figs. 8–16 (paper: ≥10% bandwidth compressibility).
    pub in_eval_set: bool,
    pub regs_per_thread: u32,
    pub threads_per_cta: u32,
    pub smem_per_cta: u32,
    pub total_ctas: u32,
    /// Loop iterations per warp.
    pub iters: u32,
    pub body: BodySpec,
    pub arrays: &'static [ArraySpec],
    /// Operand-redundancy class of the SFU computations (drives the memo
    /// LUT of `crate::memo`; [`ValueSpec::UNIQUE`] = nothing to memoize).
    pub values: ValueSpec,
}

// --- shared pattern constants (Mix needs 'static refs) ---
static ZERO_HEAVY_HI: DataPattern = DataPattern::ZeroHeavy { p_zero: 0.65 };
static ZERO_HEAVY_LO: DataPattern = DataPattern::ZeroHeavy { p_zero: 0.4 };
static LDR8: DataPattern = DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 };
static LDR4: DataPattern = DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 1 };
static LDR4W: DataPattern = DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 2 };
static NARROW: DataPattern = DataPattern::NarrowInt { max: 120 };
#[allow(dead_code)] // retained for future per-app tuning
static NARROW16: DataPattern = DataPattern::NarrowInt { max: 30000 };
static PTR4: DataPattern = DataPattern::PointerLike { n_bases: 4 };
static PTR3: DataPattern = DataPattern::PointerLike { n_bases: 3 };
static REP: DataPattern = DataPattern::RepBytes;
static SPARSE: DataPattern = DataPattern::SparseNarrow { p_nonzero: 0.25 };
static SPARSE_DENSER: DataPattern = DataPattern::SparseNarrow { p_nonzero: 0.45 };
static FGRID: DataPattern = DataPattern::FloatGrid { exp: 120 };
static RANDOM: DataPattern = DataPattern::Random;
static MIX_ZL: DataPattern = DataPattern::Mix { p: 0.5, a: &ZERO_HEAVY_HI, b: &LDR4 };
static MIX_GRAPH: DataPattern = DataPattern::Mix { p: 0.25, a: &PTR4, b: &MIX_ZL };
static MIX_TEXT: DataPattern = DataPattern::Mix { p: 0.7, a: &SPARSE_DENSER, b: &REP };
static MIX_IMG: DataPattern = DataPattern::Mix { p: 0.55, a: &REP, b: &NARROW };
static MIX_FLOAT: DataPattern = DataPattern::Mix { p: 0.55, a: &FGRID, b: &LDR4 };
static MIX_HALF_RANDOM: DataPattern = DataPattern::Mix { p: 0.5, a: &LDR4, b: &RANDOM };

const fn co(array: u8) -> MemOp {
    MemOp { array, kind: AccessKind::Coalesced { reuse: 1 } }
}
const fn co_reuse(array: u8, reuse: u16) -> MemOp {
    MemOp { array, kind: AccessKind::Coalesced { reuse } }
}
const fn strided(array: u8, lines: u16) -> MemOp {
    MemOp { array, kind: AccessKind::Strided { lines } }
}
const fn scatter(array: u8, degree: u16) -> MemOp {
    MemOp { array, kind: AccessKind::Scatter { degree } }
}

macro_rules! app {
    ($name:expr, $suite:expr, mem=$mb:expr, eval=$ev:expr, regs=$regs:expr,
     tpc=$tpc:expr, smem=$smem:expr, ctas=$ctas:expr, iters=$iters:expr,
     loads=$loads:expr, stores=$stores:expr,
     ialu=$ialu:expr, falu=$falu:expr, fma=$fma:expr, sfu=$sfu:expr,
     arrays=$arrays:expr) => {
        app!($name, $suite, mem = $mb, eval = $ev, regs = $regs,
            tpc = $tpc, smem = $smem, ctas = $ctas, iters = $iters,
            loads = $loads, stores = $stores,
            ialu = $ialu, falu = $falu, fma = $fma, sfu = $sfu,
            values = ValueSpec::UNIQUE,
            arrays = $arrays)
    };
    ($name:expr, $suite:expr, mem=$mb:expr, eval=$ev:expr, regs=$regs:expr,
     tpc=$tpc:expr, smem=$smem:expr, ctas=$ctas:expr, iters=$iters:expr,
     loads=$loads:expr, stores=$stores:expr,
     ialu=$ialu:expr, falu=$falu:expr, fma=$fma:expr, sfu=$sfu:expr,
     values=$vals:expr,
     arrays=$arrays:expr) => {
        AppSpec {
            name: $name,
            suite: $suite,
            memory_bound: $mb,
            in_eval_set: $ev,
            regs_per_thread: $regs,
            threads_per_cta: $tpc,
            smem_per_cta: $smem,
            total_ctas: $ctas,
            iters: $iters,
            body: BodySpec {
                loads: $loads,
                stores: $stores,
                ialu: $ialu,
                falu: $falu,
                fma: $fma,
                sfu: $sfu,
            },
            arrays: $arrays,
            values: $vals,
        }
    };
}

/// All 27 applications.
pub static APPS: &[AppSpec] = &[
    // ---------------- CUDA SDK ----------------
    // BFS: frontier-based graph traversal; scattered index loads, mostly
    // narrow/zero data; interconnect-sensitive (paper §3).
    app!("BFS", Suite::CudaSdk, mem = true, eval = true, regs = 18, tpc = 512, smem = 0,
        ctas = 360, iters = 96,
        loads = &[co(0), scatter(1, 8)], stores = &[co(2)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 15, pattern: ZERO_HEAVY_LO },
            ArraySpec { footprint_lines: 1 << 16, pattern: MIX_GRAPH },
            ArraySpec { footprint_lines: 1 << 15, pattern: NARROW },
        ]),
    // CONS: convolution-separable; streaming coalesced FP with reuse.
    app!("CONS", Suite::CudaSdk, mem = true, eval = true, regs = 23, tpc = 256, smem = 8192,
        ctas = 400, iters = 128,
        loads = &[co_reuse(0, 2), co(1)], stores = &[co(2)],
        ialu = 1, falu = 2, fma = 3, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 14, pattern: LDR4 },
            ArraySpec { footprint_lines: 1 << 16, pattern: MIX_FLOAT },
        ]),
    // JPEG: DCT/quantization; byte-plane data, repeated bytes + narrow ints
    // (FPC-friendly, Fig. 13).
    app!("JPEG", Suite::CudaSdk, mem = true, eval = true, regs = 28, tpc = 256, smem = 4096,
        ctas = 360, iters = 112,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 3, falu = 1, fma = 2, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: MIX_IMG },
            ArraySpec { footprint_lines: 1 << 13, pattern: NARROW },
            ArraySpec { footprint_lines: 1 << 16, pattern: MIX_IMG },
        ]),
    // LPS: 3D Laplace solver; stencil loads, sparse-narrow grid halos
    // (compresses better with FPC than BDI — paper §7.3).
    app!("LPS", Suite::CudaSdk, mem = true, eval = true, regs = 30, tpc = 128, smem = 6144,
        ctas = 480, iters = 128,
        loads = &[co(0), strided(0, 2), co(1)], stores = &[co(2)],
        ialu = 1, falu = 3, fma = 2, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: SPARSE },
            ArraySpec { footprint_lines: 1 << 13, pattern: SPARSE_DENSER },
            ArraySpec { footprint_lines: 1 << 16, pattern: SPARSE },
        ]),
    // MUM: MUMmer sequence matching; pointer-chasing through suffix tree
    // (text-like data, C-Pack/FPC-friendly).
    app!("MUM", Suite::CudaSdk, mem = true, eval = true, regs = 22, tpc = 256, smem = 0,
        ctas = 360, iters = 96,
        loads = &[scatter(0, 8), co(1)], stores = &[co(2)],
        ialu = 5, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_TEXT },
            ArraySpec { footprint_lines: 1 << 14, pattern: MIX_GRAPH },
            ArraySpec { footprint_lines: 1 << 14, pattern: NARROW },
        ]),
    // RAY: ray tracing; SFU-heavy compute-bound but compressible scene data.
    // Shading reuse across adjacent rays ([8]-style redundancy).
    app!("RAY", Suite::CudaSdk, mem = false, eval = true, regs = 40, tpc = 128, smem = 0,
        ctas = 240, iters = 112,
        loads = &[co_reuse(0, 4)], stores = &[co(1)],
        ialu = 2, falu = 4, fma = 4, sfu = 2,
        values = ValueSpec::shared(0.40, 4096),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 12, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 14, pattern: FGRID },
        ]),
    // SLA: scan large array; pure streaming, narrow partial sums.
    app!("SLA", Suite::CudaSdk, mem = true, eval = true, regs = 16, tpc = 256, smem = 2048,
        ctas = 480, iters = 144,
        loads = &[co(0)], stores = &[co(1)],
        ialu = 3, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 17, pattern: NARROW },
            ArraySpec { footprint_lines: 1 << 17, pattern: NARROW },
        ]),
    // TRA: matrix transpose; strided (uncoalesced) on one side.
    app!("TRA", Suite::CudaSdk, mem = true, eval = true, regs = 19, tpc = 256, smem = 4224,
        ctas = 400, iters = 96,
        loads = &[strided(0, 8)], stores = &[co(1)],
        ialu = 2, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: LDR4 },
            ArraySpec { footprint_lines: 1 << 16, pattern: LDR4 },
        ]),
    // SCP: scalar products; FP-dense, data incompressible (paper: excluded,
    // no benefit and no degradation).
    app!("SCP", Suite::CudaSdk, mem = false, eval = false, regs = 24, tpc = 256, smem = 4096,
        ctas = 300, iters = 128,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 1, falu = 2, fma = 6, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 15, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 15, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 15, pattern: RANDOM },
        ]),
    // FWT: fast Walsh transform; butterfly strides, compute-leaning.
    app!("FWT", Suite::CudaSdk, mem = false, eval = false, regs = 22, tpc = 256, smem = 8192,
        ctas = 360, iters = 112,
        loads = &[strided(0, 4)], stores = &[strided(0, 4)],
        ialu = 2, falu = 3, fma = 1, sfu = 0,
        arrays = &[ArraySpec { footprint_lines: 1 << 16, pattern: MIX_HALF_RANDOM }]),
    // STO: store GPU; long hash chains per datum over a cache-resident
    // working set — the archetypal compute-bound kernel.
    app!("STO", Suite::CudaSdk, mem = false, eval = false, regs = 36, tpc = 128, smem = 0,
        ctas = 240, iters = 128,
        loads = &[co_reuse(0, 4)], stores = &[co(1)],
        ialu = 28, falu = 0, fma = 0, sfu = 1,
        values = ValueSpec::shared(0.20, 16384),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 11, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 12, pattern: RANDOM },
        ]),

    // ---------------- Rodinia ----------------
    // hs (hotspot): stencil, FP grid; compute-leaning but compressible.
    app!("hs", Suite::Rodinia, mem = false, eval = true, regs = 32, tpc = 256, smem = 12288,
        ctas = 300, iters = 112,
        loads = &[co_reuse(0, 2), co(1)], stores = &[co(2)],
        ialu = 1, falu = 5, fma = 4, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 13, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 13, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 13, pattern: MIX_FLOAT },
        ]),
    // nw (Needleman-Wunsch): DP wavefront; narrow score matrix
    // (FPC-friendly per Fig. 13), L1-unfriendly diagonal walk.
    app!("nw", Suite::Rodinia, mem = true, eval = true, regs = 20, tpc = 128, smem = 8448,
        ctas = 420, iters = 96,
        loads = &[co(0), strided(0, 2), co(1)], stores = &[co(0)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: SPARSE_DENSER },
            ArraySpec { footprint_lines: 1 << 13, pattern: NARROW },
        ]),
    // sc (streamcluster): distance computation; incompressible coordinates
    // (paper: excluded from eval set).
    app!("sc", Suite::Rodinia, mem = false, eval = false, regs = 26, tpc = 256, smem = 0,
        ctas = 300, iters = 112,
        loads = &[co(0), co_reuse(1, 8)], stores = &[co(2)],
        ialu = 1, falu = 3, fma = 4, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 10, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 13, pattern: RANDOM },
        ]),
    // bp (backprop): dense layers; FP weights, moderate.
    app!("bp", Suite::Rodinia, mem = false, eval = false, regs = 28, tpc = 256, smem = 4096,
        ctas = 300, iters = 120,
        loads = &[co(0), co_reuse(1, 4)], stores = &[co(2)],
        ialu = 1, falu = 2, fma = 6, sfu = 1,
        values = ValueSpec::shared(0.30, 2048),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 12, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 14, pattern: MIX_FLOAT },
        ]),
    // sr (srad): diffusion; FP grid, SFU exp().
    app!("sr", Suite::Rodinia, mem = false, eval = false, regs = 34, tpc = 256, smem = 6144,
        ctas = 300, iters = 104,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 1, falu = 4, fma = 3, sfu = 2,
        values = ValueSpec::shared(0.35, 4096),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 14, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 14, pattern: FGRID },
        ]),

    // ---------------- Mars (MapReduce) ----------------
    // KM (k-means): centroid distances; narrow cluster ids + float points.
    app!("KM", Suite::Mars, mem = true, eval = true, regs = 24, tpc = 256, smem = 2048,
        ctas = 400, iters = 120,
        loads = &[co(0), co_reuse(1, 16)], stores = &[co(2)],
        ialu = 2, falu = 2, fma = 3, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 17, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 9, pattern: LDR4 },
            ArraySpec { footprint_lines: 1 << 15, pattern: NARROW },
        ]),
    // MM (matrix multiply): tiled GEMM; low-dynamic-range integer matrices
    // (BDI's best case, Fig. 13).
    app!("MM", Suite::Mars, mem = true, eval = true, regs = 28, tpc = 256, smem = 8192,
        ctas = 360, iters = 128,
        loads = &[co_reuse(0, 2), co_reuse(1, 2)], stores = &[co(2)],
        ialu = 1, falu = 0, fma = 4, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: LDR4 },
            ArraySpec { footprint_lines: 1 << 16, pattern: LDR4 },
            ArraySpec { footprint_lines: 1 << 16, pattern: LDR4W },
        ]),
    // PVC (page-view count): URL keys — 8-byte pointers with small deltas,
    // the paper's Fig. 6 example app. Strongly BDI.
    app!("PVC", Suite::Mars, mem = true, eval = true, regs = 19, tpc = 256, smem = 1024,
        ctas = 480, iters = 144,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 17, pattern: LDR8 },
            ArraySpec { footprint_lines: 1 << 15, pattern: LDR8 },
            ArraySpec { footprint_lines: 1 << 15, pattern: LDR8 },
        ]),
    // PVR (page-view rank): like PVC with rank floats.
    app!("PVR", Suite::Mars, mem = true, eval = true, regs = 22, tpc = 256, smem = 1024,
        ctas = 440, iters = 128,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 3, falu = 1, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 17, pattern: LDR8 },
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 15, pattern: LDR8 },
        ]),
    // SS (similarity score): document vectors; narrow counts.
    app!("SS", Suite::Mars, mem = true, eval = true, regs = 24, tpc = 256, smem = 2048,
        ctas = 400, iters = 120,
        loads = &[co(0), co(1)], stores = &[co(2)],
        ialu = 2, falu = 2, fma = 2, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: NARROW },
            ArraySpec { footprint_lines: 1 << 16, pattern: NARROW },
            ArraySpec { footprint_lines: 1 << 14, pattern: MIX_FLOAT },
        ]),

    // ---------------- Lonestar ----------------
    // bfs: worklist graph traversal; scattered, zero-heavy frontier +
    // pointer adjacency. Interconnect-sensitive + L1-capacity-sensitive
    // (Fig. 15).
    app!("bfs", Suite::Lonestar, mem = true, eval = true, regs = 18, tpc = 256, smem = 0,
        ctas = 420, iters = 96,
        loads = &[co(0), scatter(1, 10)], stores = &[scatter(2, 4)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: ZERO_HEAVY_HI },
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_GRAPH },
            ArraySpec { footprint_lines: 1 << 14, pattern: NARROW },
        ]),
    // bh (Barnes-Hut): tree walk + force computation; compute-leaning.
    app!("bh", Suite::Lonestar, mem = false, eval = true, regs = 38, tpc = 256, smem = 2048,
        ctas = 280, iters = 104,
        loads = &[scatter(0, 6), co_reuse(1, 4)], stores = &[co(2)],
        ialu = 2, falu = 3, fma = 4, sfu = 1,
        values = ValueSpec::shared(0.30, 8192),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: PTR3 },
            ArraySpec { footprint_lines: 1 << 12, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 13, pattern: FGRID },
        ]),
    // mst: minimum spanning tree; component ids are zero-heavy narrow ints;
    // strongly bandwidth-bound (paper calls out mst for icnt benefit).
    app!("mst", Suite::Lonestar, mem = true, eval = true, regs = 19, tpc = 256, smem = 0,
        ctas = 440, iters = 112,
        loads = &[co(0), scatter(1, 8), co(2)], stores = &[co(2)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 16, pattern: ZERO_HEAVY_HI },
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_GRAPH },
            ArraySpec { footprint_lines: 1 << 15, pattern: ZERO_HEAVY_LO },
        ]),
    // sp (survey propagation): belief floats + clause graph.
    app!("sp", Suite::Lonestar, mem = true, eval = true, regs = 26, tpc = 256, smem = 0,
        ctas = 360, iters = 112,
        loads = &[scatter(0, 6), co(1)], stores = &[co(1)],
        ialu = 2, falu = 3, fma = 1, sfu = 1,
        values = ValueSpec::shared(0.15, 16384),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_GRAPH },
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_FLOAT },
        ]),
    // sssp: delta-stepping shortest paths; distance array zero/narrow-heavy;
    // L1-capacity-sensitive (Fig. 15).
    app!("sssp", Suite::Lonestar, mem = true, eval = true, regs = 19, tpc = 256, smem = 0,
        ctas = 420, iters = 104,
        loads = &[co(0), scatter(1, 8)], stores = &[scatter(0, 4)],
        ialu = 4, falu = 0, fma = 0, sfu = 0,
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: ZERO_HEAVY_LO },
            ArraySpec { footprint_lines: 1 << 15, pattern: MIX_GRAPH },
        ]),
    // dmr (Delaunay mesh refinement): SFU-heavy, data-dependence-stall
    // dominated (paper §3 calls out dmr's SFU stalls).
    app!("dmr", Suite::Lonestar, mem = false, eval = false, regs = 42, tpc = 128, smem = 0,
        ctas = 240, iters = 104,
        loads = &[scatter(0, 4)], stores = &[co(1)],
        ialu = 2, falu = 2, fma = 2, sfu = 4,
        values = ValueSpec::shared(0.50, 2048),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 14, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 13, pattern: FGRID },
        ]),
];

/// The compute-bound memoization suite (§8.1): SFU-heavy, transcendental
/// μ-kernels with *tunable* operand-value redundancy, built to exercise the
/// paper's second bottleneck axis. Small, cache-resident footprints keep
/// them compute-limited; shared memory stays free so the memo LUT gets its
/// full budget. They live outside [`APPS`] — the paper's 27-app pool and
/// its Fig. 2/3 counts are untouched. (`in_eval_set` here marks data
/// compressibility — it gates whether the compress+memo hybrid design
/// leaves compression enabled, exactly like the §6 profiler does.)
pub static MEMO_APPS: &[AppSpec] = &[
    // FRAG: fragment-shading proxy; the paper's §8.1 poster child — the
    // redundancy studies it cites ([8, 13, 98]) measure fragment /
    // transcendental value streams. High redundancy, head-heavy pool.
    app!("FRAG", Suite::Synthetic, mem = false, eval = true, regs = 34, tpc = 256, smem = 0,
        ctas = 280, iters = 112,
        loads = &[co_reuse(0, 4)], stores = &[co(1)],
        ialu = 1, falu = 3, fma = 3, sfu = 6,
        values = ValueSpec::shared(0.70, 2048),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 12, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 13, pattern: FGRID },
        ]),
    // NNA: neural-activation layer; sigmoid/tanh on clustered pre-sums.
    app!("NNA", Suite::Synthetic, mem = false, eval = true, regs = 30, tpc = 256, smem = 2048,
        ctas = 300, iters = 120,
        loads = &[co(0), co_reuse(1, 8)], stores = &[co(2)],
        ialu = 1, falu = 2, fma = 4, sfu = 4,
        values = ValueSpec::shared(0.55, 512),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 12, pattern: MIX_FLOAT },
            ArraySpec { footprint_lines: 1 << 11, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 12, pattern: MIX_FLOAT },
        ]),
    // GEO: geometry normalization (rsqrt-heavy); moderate redundancy over
    // a pool larger than any plausible LUT — the eviction stress case.
    app!("GEO", Suite::Synthetic, mem = false, eval = true, regs = 32, tpc = 128, smem = 0,
        ctas = 260, iters = 112,
        loads = &[co_reuse(0, 2)], stores = &[co(1)],
        ialu = 2, falu = 3, fma = 2, sfu = 5,
        values = ValueSpec::shared(0.40, 8192),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 12, pattern: FGRID },
            ArraySpec { footprint_lines: 1 << 12, pattern: FGRID },
        ]),
    // MCX: Monte Carlo transport; log/exp on fresh random draws — the
    // near-zero-redundancy control (memoization must *not* pay here).
    app!("MCX", Suite::Synthetic, mem = false, eval = false, regs = 36, tpc = 128, smem = 0,
        ctas = 240, iters = 120,
        loads = &[co_reuse(0, 4)], stores = &[co(1)],
        ialu = 3, falu = 3, fma = 2, sfu = 5,
        values = ValueSpec::shared(0.05, 1 << 16),
        arrays = &[
            ArraySpec { footprint_lines: 1 << 11, pattern: RANDOM },
            ArraySpec { footprint_lines: 1 << 12, pattern: RANDOM },
        ]),
];

/// Look up an app by (case-sensitive) name, across the paper pool and the
/// compute-bound memoization suite.
pub fn find(name: &str) -> Option<&'static AppSpec> {
    APPS.iter()
        .chain(MEMO_APPS.iter())
        .find(|a| a.name == name)
}

/// The bandwidth-sensitive evaluation set used in Figs. 8–16.
pub fn eval_set() -> Vec<&'static AppSpec> {
    APPS.iter().filter(|a| a.in_eval_set).collect()
}

/// The §8.1 memoization evaluation set: the synthetic compute-bound suite
/// plus the paper pool's most SFU-heavy members (dmr's data-dependence
/// stalls are called out in §3; RAY and sr carry transcendental shading /
/// diffusion terms).
pub fn memo_suite() -> Vec<&'static AppSpec> {
    MEMO_APPS
        .iter()
        .chain(["dmr", "RAY", "sr"].into_iter().map(|n| find(n).expect("memo suite app exists")))
        .collect()
}

/// Placeholder profile for **imported trace-driven** workloads (`caba
/// trace import`): not part of [`APPS`], never reachable via [`find`].
/// The program body, arrays and occupancy geometry all come from the
/// trace header (`crate::trace`), so the fields here are only the
/// defaults the header overrides plus the identity the reports print.
/// `in_eval_set` is true so compression is considered profitable —
/// whether a trace's data compresses is decided by its assigned pattern.
pub static TRACE_SPEC: AppSpec = AppSpec {
    name: "TRACE",
    suite: Suite::CudaSdk,
    memory_bound: true,
    in_eval_set: true,
    regs_per_thread: 16,
    threads_per_cta: 256,
    smem_per_cta: 0,
    total_ctas: 8,
    iters: 32,
    body: BodySpec { loads: &[], stores: &[], ialu: 2, falu: 0, fma: 0, sfu: 0 },
    arrays: &[],
    values: ValueSpec::UNIQUE,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pool_matches_paper_counts() {
        assert_eq!(APPS.len(), 27, "paper studies 27 applications");
        let mem_bound = APPS.iter().filter(|a| a.memory_bound).count();
        assert_eq!(mem_bound, 17, "paper: 17 of 27 are memory-bound");
        assert_eq!(eval_set().len(), 20);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<_> =
            APPS.iter().chain(MEMO_APPS.iter()).map(|a| a.name).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), APPS.len() + MEMO_APPS.len());
    }

    #[test]
    fn memo_suite_is_sfu_heavy_and_compute_bound() {
        assert_eq!(MEMO_APPS.len(), 4);
        for app in MEMO_APPS {
            assert!(!app.memory_bound, "{}: memo suite must be compute-bound", app.name);
            assert!(app.body.sfu >= 4, "{}: needs SFU work to memoize", app.name);
            assert!(app.values.p_shared > 0.0, "{}: needs a value spec", app.name);
            assert_eq!(app.suite, Suite::Synthetic);
        }
        // The suite spans the redundancy axis: a high-redundancy member and
        // a near-unique control.
        assert!(find("FRAG").unwrap().values.p_shared >= 0.6);
        assert!(find("MCX").unwrap().values.p_shared <= 0.1);
        // Suite accessor resolves everything.
        assert_eq!(memo_suite().len(), MEMO_APPS.len() + 3);
    }

    #[test]
    fn paper_pool_sfu_apps_carry_value_specs() {
        // The old hard-coded redundancy table is gone; its calibrations now
        // live on the specs as *generator parameters*, measured through the
        // LUT instead of drawn.
        for name in ["dmr", "RAY", "sr", "bh", "bp", "STO", "sp"] {
            let app = find(name).unwrap();
            assert!(app.body.sfu > 0, "{name}");
            assert!(app.values.p_shared > 0.0, "{name}: SFU app without a value spec");
        }
        // Apps with no SFU work have nothing to memoize.
        assert_eq!(find("PVC").unwrap().values, ValueSpec::UNIQUE);
    }

    #[test]
    fn array_refs_in_range() {
        for app in APPS.iter().chain(MEMO_APPS.iter()) {
            for m in app.body.loads.iter().chain(app.body.stores) {
                assert!(
                    (m.array as usize) < app.arrays.len(),
                    "{}: array {} out of range",
                    app.name,
                    m.array
                );
            }
            assert!(app.body.insts_per_iter() > 0);
            assert!(app.iters > 0 && app.total_ctas > 0);
        }
    }

    #[test]
    fn find_works() {
        assert!(find("PVC").is_some());
        assert!(find("nope").is_none());
        assert_eq!(find("MM").unwrap().suite, Suite::Mars);
    }

    #[test]
    fn incompressible_apps_excluded_from_eval() {
        for name in ["SCP", "sc", "STO"] {
            assert!(!find(name).unwrap().in_eval_set, "{name}");
        }
    }
}
