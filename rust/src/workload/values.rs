//! Operand-value generation for SFU computations.
//!
//! The memoization subsystem (`crate::memo`) probes its LUT with a hash of
//! the *actual operand values* an SFU instruction consumes. We cannot run
//! the CUDA binaries, so — exactly like `datagen` reproduces each array's
//! value-distribution class — each app carries a [`ValueSpec`] reproducing
//! the *operand redundancy* class its transcendental computations exhibit
//! (the fragment-shader / transcendental redundancy characterizations the
//! paper cites in §8.1).
//!
//! An invocation either draws from a **shared pool** of `classes` distinct
//! operand tuples (probability `p_shared`, skewed toward popular classes
//! the way real value streams are), or produces a unique tuple nobody else
//! will ever compute. The resulting LUT hit rate is therefore an
//! **emergent** quantity: it depends on `p_shared`, on the pool size
//! relative to the LUT capacity, on scheduling (which warps share an SM),
//! and on eviction — not on a hard-coded per-app probability.
//!
//! Keys are a pure function of `(spec, seed, warp, iteration, slot)`, so
//! trace replays (which pin the recorded workload seed) regenerate the
//! exact operand stream and stay bit-identical.

use crate::util::{mix64, rng::Rng};

/// Operand-redundancy class of an app's SFU computations.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ValueSpec {
    /// Probability an SFU invocation's operands come from the shared pool
    /// (the redundant fraction of the value stream).
    pub p_shared: f64,
    /// Distinct operand tuples in the shared pool. Larger pools exceed the
    /// LUT capacity and force evictions.
    pub classes: u32,
}

impl ValueSpec {
    /// Every invocation computes a fresh tuple — nothing to memoize.
    /// The default for apps whose SFU redundancy was never characterized.
    pub const UNIQUE: ValueSpec = ValueSpec { p_shared: 0.0, classes: 1 };

    pub const fn shared(p_shared: f64, classes: u32) -> ValueSpec {
        ValueSpec { p_shared, classes }
    }
}

/// The operand-value key one SFU invocation presents to the memo LUT.
///
/// `slot` is the instruction's body index: memoizing `sin(x)` never serves
/// `rsqrt(x)`, so each static SFU site namespaces its keys. Shared-pool
/// draws are skewed (fourth power of a uniform) so low-numbered classes
/// are much hotter — the head of the distribution fits a small LUT even
/// when the pool as a whole does not.
pub fn operand_key(vs: &ValueSpec, seed: u64, warp_uid: u64, iter: u32, slot: usize) -> u64 {
    let invocation = seed
        ^ warp_uid.wrapping_mul(0x2545_F491_4F6C_DD1D)
        ^ (iter as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ ((slot as u64) << 48);
    let mut rng = Rng::new(invocation);
    if vs.p_shared > 0.0 && rng.chance(vs.p_shared) {
        // Fourth-power skew ⇒ P(class < k) = (k/N)^¼ — a Zipf-like head
        // (the hottest class alone draws ~(1/N)^¼ of the stream), which is
        // what measured value streams look like and what lets redundancy
        // materialize even over short runs.
        let u = rng.f64();
        let u2 = u * u;
        let class = ((u2 * u2) * vs.classes.max(1) as f64) as u64;
        mix64(seed ^ ((slot as u64) << 32) ^ class.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    } else {
        mix64(invocation ^ 0xDEAD_BEEF_0BAD_F00D)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn deterministic() {
        let vs = ValueSpec::shared(0.5, 64);
        assert_eq!(operand_key(&vs, 1, 2, 3, 4), operand_key(&vs, 1, 2, 3, 4));
        assert_ne!(operand_key(&vs, 1, 2, 3, 4), operand_key(&vs, 2, 2, 3, 4));
    }

    #[test]
    fn unique_spec_never_repeats() {
        let vs = ValueSpec::UNIQUE;
        let keys: HashSet<u64> = (0..10_000u64)
            .map(|i| operand_key(&vs, 7, i / 100, (i % 100) as u32, 3))
            .collect();
        assert_eq!(keys.len(), 10_000);
    }

    #[test]
    fn shared_fraction_tracks_p_shared() {
        // Distinct keys over N invocations shrink as p_shared grows.
        let distinct = |p: f64| {
            let vs = ValueSpec::shared(p, 256);
            (0..8_000u64)
                .map(|i| operand_key(&vs, 7, i / 64, (i % 64) as u32, 3))
                .collect::<HashSet<u64>>()
                .len()
        };
        let lo = distinct(0.2);
        let hi = distinct(0.8);
        assert!(hi < lo, "hi-redundancy distinct {hi} vs lo {lo}");
        // With p=0.8 over a 256-class pool, far fewer than N distinct keys.
        assert!(hi < 3_000, "hi={hi}");
    }

    #[test]
    fn slots_namespace_keys() {
        // A shared class draw from slot 3 must never equal slot 4's keys
        // (memoized sin() results cannot serve rsqrt()).
        let vs = ValueSpec::shared(1.0, 4);
        let a: HashSet<u64> = (0..512u64).map(|i| operand_key(&vs, 7, i, 0, 3)).collect();
        let b: HashSet<u64> = (0..512u64).map(|i| operand_key(&vs, 7, i, 0, 4)).collect();
        assert!(a.is_disjoint(&b));
    }

    #[test]
    fn pool_head_is_hot() {
        // The skew concentrates mass: with 1024 classes, the 256 most
        // popular keys should cover well over a quarter of draws.
        let vs = ValueSpec::shared(1.0, 1024);
        let mut counts = std::collections::HashMap::new();
        let n = 20_000u64;
        for i in 0..n {
            *counts.entry(operand_key(&vs, 7, i / 64, (i % 64) as u32, 1)).or_insert(0u64) += 1;
        }
        let mut freqs: Vec<u64> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        let head: u64 = freqs.iter().take(256).sum();
        assert!(head as f64 / n as f64 > 0.4, "head coverage {}", head as f64 / n as f64);
    }
}
