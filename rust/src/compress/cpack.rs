//! C-Pack dictionary compression, restricted variant (paper §5.1.5).
//!
//! Original C-Pack (Chen et al.) uses variable-length codes and a serially
//! built dictionary, which (like FPC) serializes decompression. The paper's
//! assist-warp variant restricts it so that every compressed word has a
//! *fixed* size and the dictionary lives at the head of the line:
//!
//! * at most **4 dictionary entries**;
//! * word encodings: `zero`, `full match`, `partial match` (upper 3 bytes
//!   match a dictionary entry, low byte stored), `zero-extend` (upper 3
//!   bytes zero, low byte stored);
//! * if the line needs a 5th dictionary entry, it is left uncompressed.
//!
//! Layout: `[hdr][codes ×32 (2b dict-idx + 2b kind, packed 2/byte)]`
//! `[dict ×used ×4B][payload byte ×32]` — `49 + 4×dict_used` bytes when
//! compressible. Fixed positions ⇒ all 32 lanes decompress in parallel,
//! which is exactly the property the paper needs ("A fixed compressed word
//! size enables compression and decompression of different words within the
//! cache line in parallel").

use super::{Compressed, Compressor, Algo, Line, LINE_BYTES, WORDS_PER_LINE};

/// Maximum dictionary entries (paper: "we limit the number of dictionary
/// values to 4").
pub const DICT_SIZE: usize = 4;

/// Per-word code kinds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Code {
    Zero = 0,
    FullMatch = 1,
    PartialMatch = 2,
    ZeroExt = 3,
}

impl Code {
    pub fn from_u8(v: u8) -> Code {
        match v & 0b11 {
            0 => Code::Zero,
            1 => Code::FullMatch,
            2 => Code::PartialMatch,
            _ => Code::ZeroExt,
        }
    }
}

pub const ENC_COMPRESSED: u8 = 0;
pub const ENC_UNCOMPRESSED: u8 = 0xFF;

/// Compressed size when compressible: header + packed 4-bit codes +
/// used dictionary entries + fixed 1-byte payload per word.
pub const fn compressed_size(dict_used: usize) -> usize {
    1 + WORDS_PER_LINE / 2 + dict_used * 4 + WORDS_PER_LINE
}
/// Upper bound (full dictionary).
pub const COMPRESSED_SIZE: usize = compressed_size(DICT_SIZE);

/// Assist-warp subroutine lengths, from Algorithms 5/6: dictionary loads,
/// per-encoding masked loads, mismatch-byte handling, stores.
pub fn decompress_subroutine_len() -> usize {
    2 + DICT_SIZE + 4 * 2 + 2
}
pub fn compress_subroutine_len(dict_entries_tested: usize) -> usize {
    2 + dict_entries_tested * 5 + 3
}

/// Allocation-free `(encoding, size_bytes)` — see [`super::measure`].
/// Runs the serial dictionary build with an on-stack dictionary; codes and
/// payload bytes are never materialized (the size depends only on whether
/// the line fits a ≤4-entry dictionary, and how many entries it needs).
pub(crate) fn measure(line: &Line) -> (u8, usize) {
    let words = super::line_words(line);
    let mut dict = [0u32; DICT_SIZE];
    let mut used = 0usize;
    for &w in words.iter() {
        // Same match order as compress(): zero, zero-extend, full match,
        // partial match, else a new dictionary entry.
        if w == 0 || w & 0xFFFF_FF00 == 0 {
            continue;
        }
        if dict[..used].iter().any(|&d| d == w) {
            continue;
        }
        if dict[..used].iter().any(|&d| d & 0xFFFF_FF00 == w & 0xFFFF_FF00) {
            continue;
        }
        if used == DICT_SIZE {
            return (ENC_UNCOMPRESSED, 1 + LINE_BYTES);
        }
        dict[used] = w;
        used += 1;
    }
    (used as u8, compressed_size(used))
}

/// Restricted C-Pack compressor.
pub struct CPack;

impl Compressor for CPack {
    fn compress(&self, line: &Line) -> Compressed {
        let words = super::line_words(line);
        let mut dict: Vec<u32> = Vec::with_capacity(DICT_SIZE);
        let mut codes = [0u8; WORDS_PER_LINE];
        let mut payload = [0u8; WORDS_PER_LINE];
        // Serial dictionary build (Algorithm 6): each word either matches an
        // existing entry / pattern or becomes a new dictionary entry.
        for (i, &w) in words.iter().enumerate() {
            let code = if w == 0 {
                Some((Code::Zero, 0u8, 0u8))
            } else if w & 0xFFFF_FF00 == 0 {
                Some((Code::ZeroExt, 0, (w & 0xFF) as u8))
            } else if let Some(j) = dict.iter().position(|&d| d == w) {
                Some((Code::FullMatch, j as u8, 0))
            } else if let Some(j) = dict.iter().position(|&d| d & 0xFFFF_FF00 == w & 0xFFFF_FF00) {
                Some((Code::PartialMatch, j as u8, (w & 0xFF) as u8))
            } else {
                None
            };
            match code {
                Some((kind, idx, pay)) => {
                    codes[i] = (idx << 2) | kind as u8;
                    payload[i] = pay;
                }
                None => {
                    if dict.len() == DICT_SIZE {
                        // 5th dictionary value needed — line stays raw.
                        let mut bytes = vec![ENC_UNCOMPRESSED];
                        bytes.extend_from_slice(line);
                        return Compressed {
                            algo: Algo::CPack,
                            encoding: ENC_UNCOMPRESSED,
                            bytes,
                        };
                    }
                    dict.push(w);
                    codes[i] = ((dict.len() as u8 - 1) << 2) | Code::FullMatch as u8;
                    payload[i] = 0;
                }
            }
        }
        let mut bytes = Vec::with_capacity(compressed_size(dict.len()));
        bytes.push(dict.len() as u8);
        // 4-bit codes packed two per byte: low nibble = even word.
        for pair in codes.chunks_exact(2) {
            bytes.push((pair[0] & 0x0F) | (pair[1] << 4));
        }
        for &d in &dict {
            bytes.extend_from_slice(&d.to_le_bytes());
        }
        bytes.extend_from_slice(&payload);
        debug_assert_eq!(bytes.len(), compressed_size(dict.len()));
        // encoding = dictionary entries used (selects the AWS subroutine).
        Compressed { algo: Algo::CPack, encoding: dict.len() as u8, bytes }
    }

    fn decompress(&self, c: &Compressed) -> Line {
        assert_eq!(c.algo, Algo::CPack);
        if c.encoding == ENC_UNCOMPRESSED {
            let mut line = [0u8; LINE_BYTES];
            line.copy_from_slice(&c.bytes[1..1 + LINE_BYTES]);
            return line;
        }
        let dict_used = c.bytes[0] as usize;
        let packed = &c.bytes[1..1 + WORDS_PER_LINE / 2];
        let dict_off = 1 + WORDS_PER_LINE / 2;
        let mut dict = [0u32; DICT_SIZE];
        for (j, d) in dict.iter_mut().take(dict_used).enumerate() {
            *d = u32::from_le_bytes(
                c.bytes[dict_off + j * 4..dict_off + j * 4 + 4].try_into().unwrap(),
            );
        }
        let pay_off = dict_off + dict_used * 4;
        let mut words = [0u32; WORDS_PER_LINE];
        for i in 0..WORDS_PER_LINE {
            let code = (packed[i / 2] >> (4 * (i % 2))) & 0x0F;
            let kind = Code::from_u8(code & 0b11);
            let idx = (code >> 2) as usize;
            let pay = c.bytes[pay_off + i] as u32;
            words[i] = match kind {
                Code::Zero => 0,
                Code::FullMatch => dict[idx],
                Code::PartialMatch => (dict[idx] & 0xFFFF_FF00) | pay,
                Code::ZeroExt => pay,
            };
        }
        super::words_line(&words)
    }

    fn algo(&self) -> Algo {
        Algo::CPack
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(line: &Line) -> Compressed {
        let c = CPack.compress(line);
        assert_eq!(&CPack.decompress(&c), line);
        c
    }

    #[test]
    fn zeros_compress() {
        let line = [0u8; LINE_BYTES];
        let c = roundtrip(&line);
        assert_eq!(c.encoding, 0); // no dictionary entries needed
        assert_eq!(c.size_bytes(), compressed_size(0)); // 49 bytes
        assert_eq!(c.bursts(), 2);
    }

    #[test]
    fn four_distinct_pointers_compress() {
        // Typical pointer-heavy line: 4 distinct upper-3-byte groups.
        let bases = [0x8001_D000u32, 0x8002_0000, 0x9000_1000, 0xA000_0000];
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            let w = bases[i % 4] | (i as u32 & 0xFF);
            ch.copy_from_slice(&w.to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, 4);
        assert_eq!(c.size_bytes(), compressed_size(4)); // 65 → 3 bursts
    }

    #[test]
    fn five_distinct_groups_fail() {
        let bases = [
            0x8001_D000u32,
            0x8002_0000,
            0x9000_1000,
            0xA000_0000,
            0xB000_0000,
        ];
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            ch.copy_from_slice(&bases[i % 5].to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_UNCOMPRESSED);
        assert_eq!(c.bursts(), 4);
    }

    #[test]
    fn zero_extend_words() {
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            ch.copy_from_slice(&((i as u32 % 200) + 1).to_le_bytes());
        }
        let c = roundtrip(&line);
        assert!(c.encoding <= 1); // zero / zero-extend words need no dict
        assert_eq!(c.bursts(), 2);
    }

    #[test]
    fn partial_match_byte_recovered() {
        let mut line = [0u8; LINE_BYTES];
        let base = 0xDEAD_BE00u32;
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            ch.copy_from_slice(&(base | (0xFF - i as u32)).to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, 1); // one dictionary entry
    }

    #[test]
    fn random_lines_roundtrip_always() {
        let mut rng = Rng::new(5);
        for _ in 0..300 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            roundtrip(&line);
        }
    }

    #[test]
    fn dict_reuse_prefers_full_match() {
        // A line of one repeated word must need exactly 1 dict entry.
        let mut line = [0u8; LINE_BYTES];
        for ch in line.chunks_exact_mut(4) {
            ch.copy_from_slice(&0xCAFE_BABEu32.to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.bytes[0], 1); // dict size header
    }
}
