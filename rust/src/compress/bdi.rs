//! Base-Delta-Immediate compression (Pekhimenko et al., PACT 2012), the
//! paper's flagship CABA algorithm (§5.1.1–§5.1.2).
//!
//! A line is viewed as fixed-size values (8/4/2 bytes). If every value is
//! within a small delta of either a common base (the first non-zero value)
//! or the implicit zero base, the line is stored as
//! `[encoding][zero-base bitmask][base][deltas...]` — exactly the paper's
//! Fig. 6 layout generalized to 128-byte lines.
//!
//! Encodings (metadata byte):
//!
//! | enc | meaning        | layout                                   |
//! |-----|----------------|------------------------------------------|
//! | 0   | all zeros      | `[0]`                                    |
//! | 1   | repeated 8B    | `[1][v; 8]`                              |
//! | 2   | base8-delta1   | `[2][mask;2][base;8][d1 ×16]`            |
//! | 3   | base8-delta2   | `[3][mask;2][base;8][d2 ×16]`            |
//! | 4   | base8-delta4   | `[4][mask;2][base;8][d4 ×16]`            |
//! | 5   | base4-delta1   | `[5][mask;4][base;4][d1 ×32]`            |
//! | 6   | base4-delta2   | `[6][mask;4][base;4][d2 ×32]`            |
//! | 7   | base2-delta1   | `[7][mask;8][base;2][d1 ×64]`            |
//! | 15  | uncompressed   | `[15][line;128]`                         |
//!
//! The bitmask marks values encoded against the implicit zero base (paper:
//! "an implicit zero value base"); deltas are signed two's complement.

use super::{Compressed, Compressor, Algo, Line, LINE_BYTES, WORDS64_PER_LINE};

pub const ENC_ZEROS: u8 = 0;
pub const ENC_REPEAT: u8 = 1;
pub const ENC_B8D1: u8 = 2;
pub const ENC_B8D2: u8 = 3;
pub const ENC_B8D4: u8 = 4;
pub const ENC_B4D1: u8 = 5;
pub const ENC_B4D2: u8 = 6;
pub const ENC_B2D1: u8 = 7;
pub const ENC_UNCOMPRESSED: u8 = 15;

/// `(encoding, base_size, delta_size)` in the paper's preference order:
/// candidates are tested smallest-compressed-size first, mirroring
/// Algorithm 2's loop over `(base_size, delta_size)` with early exit.
pub const BASE_DELTA_ENCODINGS: [(u8, usize, usize); 6] = [
    (ENC_B8D1, 8, 1),
    (ENC_B4D1, 4, 1),
    (ENC_B8D2, 8, 2),
    (ENC_B2D1, 2, 1),
    (ENC_B4D2, 4, 2),
    (ENC_B8D4, 8, 4),
];

/// Compressed size in bytes for a given base/delta geometry.
pub fn encoded_size(base_size: usize, delta_size: usize) -> usize {
    let n_values = LINE_BYTES / base_size;
    // metadata byte + zero-base bitmask + base + deltas
    1 + n_values / 8 + base_size + n_values * delta_size
}

/// The human-readable name for an encoding byte (reports, traces).
pub fn encoding_name(enc: u8) -> &'static str {
    match enc {
        ENC_ZEROS => "zeros",
        ENC_REPEAT => "repeat8",
        ENC_B8D1 => "base8-d1",
        ENC_B8D2 => "base8-d2",
        ENC_B8D4 => "base8-d4",
        ENC_B4D1 => "base4-d1",
        ENC_B4D2 => "base4-d2",
        ENC_B2D1 => "base2-d1",
        ENC_UNCOMPRESSED => "uncompressed",
        _ => "invalid",
    }
}

/// Instruction count of the assist-warp subroutine for a given encoding
/// (used by `caba::subroutines` to model issue/exec overhead). Derived from
/// Algorithm 1/2: loads of base+deltas, masked vector add, stores.
pub fn decompress_subroutine_len(enc: u8) -> usize {
    // Algorithm 1 is a masked vector add: load base+deltas, add, store.
    // 16 8-byte values fit one 32-lane pass; 64 2-byte values need two.
    match enc {
        ENC_ZEROS => 2,        // splat zero + wide store
        ENC_REPEAT => 3,       // load value, splat, wide store
        ENC_B8D1 | ENC_B8D2 | ENC_B8D4 => 5,
        ENC_B4D1 | ENC_B4D2 => 6,
        ENC_B2D1 => 8,         // two passes over 32 lanes
        _ => 2,                // uncompressed: passthrough copy setup
    }
}

/// Value `idx` of width `size` (8/4/2 bytes) from the 8-byte word view —
/// one shift+mask instead of a per-byte gather loop.
#[inline]
fn value_at(words: &[u64; WORDS64_PER_LINE], idx: usize, size: usize) -> u64 {
    match size {
        8 => words[idx],
        4 => (words[idx / 2] >> (32 * (idx % 2))) & 0xFFFF_FFFF,
        _ => (words[idx / 4] >> (16 * (idx % 4))) & 0xFFFF,
    }
}

/// Base = first non-zero value (paper: "first few bytes ... always used as
/// the base"; the zero base covers leading zeros).
#[inline]
fn first_nonzero(words: &[u64; WORDS64_PER_LINE], n_values: usize, base_size: usize) -> u64 {
    for i in 0..n_values {
        let v = value_at(words, i, base_size);
        if v != 0 {
            return v;
        }
    }
    0
}

/// Does the `(base_size, delta_size)` geometry fit every value of the
/// line against the first-non-zero base or the implicit zero base? The
/// allocation-free core of both [`measure`] and `Bdi::try_encode`.
fn geometry_fits(words: &[u64; WORDS64_PER_LINE], base_size: usize, delta_size: usize) -> bool {
    let n_values = LINE_BYTES / base_size;
    let base = first_nonzero(words, n_values, base_size);
    for i in 0..n_values {
        let v = value_at(words, i, base_size);
        if !delta_fits(v, base, delta_size) && !delta_fits(v, 0, delta_size) {
            return false;
        }
    }
    true
}

/// [`BASE_DELTA_ENCODINGS`] pre-sorted by increasing compressed size
/// (stable on the 75-byte tie: B2D1 before B8D4, i.e. declaration order).
/// Hard-coded so the per-line hot loop never re-sorts a constant; the
/// `geometry_order_is_sorted_by_size` test pins it to the sorted form.
const SORTED_GEOMETRIES: [(u8, usize, usize); 6] = [
    (ENC_B8D1, 8, 1), // 27 bytes
    (ENC_B4D1, 4, 1), // 41
    (ENC_B8D2, 8, 2), // 43
    (ENC_B4D2, 4, 2), // 73
    (ENC_B2D1, 2, 1), // 75 (tie: declared before B8D4)
    (ENC_B8D4, 8, 4), // 75
];

/// Allocation-free `(encoding, size_bytes)` — see [`super::measure`].
pub(crate) fn measure(line: &Line) -> (u8, usize) {
    let words = super::line_words64(line);
    if words.iter().all(|&w| w == 0) {
        return (ENC_ZEROS, 1);
    }
    if words.iter().all(|&w| w == words[0]) {
        return (ENC_REPEAT, 1 + 8);
    }
    for (enc, base_size, delta_size) in SORTED_GEOMETRIES {
        let size = encoded_size(base_size, delta_size);
        if size >= LINE_BYTES {
            continue;
        }
        if geometry_fits(&words, base_size, delta_size) {
            return (enc, size);
        }
    }
    (ENC_UNCOMPRESSED, 1 + LINE_BYTES)
}

fn delta_fits(value: u64, base: u64, delta_size: usize) -> bool {
    let d = value.wrapping_sub(base) as i64;
    let bits = delta_size as u32 * 8;
    let min = -(1i64 << (bits - 1));
    let max = (1i64 << (bits - 1)) - 1;
    (min..=max).contains(&d)
}

/// Base-Delta-Immediate compressor.
pub struct Bdi;

impl Bdi {
    /// Try one (base,delta) geometry; `None` if some value fits neither the
    /// base nor the implicit zero base. This mirrors the per-lane predicate
    /// + global-AND the paper implements with the warp predicate register.
    fn try_encode(line: &Line, enc: u8, base_size: usize, delta_size: usize) -> Option<Compressed> {
        let words = super::line_words64(line);
        let n_values = LINE_BYTES / base_size;
        let base = first_nonzero(&words, n_values, base_size);
        let mut mask = vec![0u8; n_values / 8];
        let mut deltas = Vec::with_capacity(n_values * delta_size);
        for i in 0..n_values {
            let v = value_at(&words, i, base_size);
            let (from_zero, d) = if delta_fits(v, base, delta_size) {
                (false, v.wrapping_sub(base))
            } else if delta_fits(v, 0, delta_size) {
                (true, v)
            } else {
                return None;
            };
            if from_zero {
                mask[i / 8] |= 1 << (i % 8);
            }
            deltas.extend_from_slice(&d.to_le_bytes()[..delta_size]);
        }
        let mut bytes = Vec::with_capacity(encoded_size(base_size, delta_size));
        bytes.push(enc);
        bytes.extend_from_slice(&mask);
        bytes.extend_from_slice(&base.to_le_bytes()[..base_size]);
        bytes.extend_from_slice(&deltas);
        debug_assert_eq!(bytes.len(), encoded_size(base_size, delta_size));
        Some(Compressed { algo: Algo::Bdi, encoding: enc, bytes })
    }
}

impl Compressor for Bdi {
    fn compress(&self, line: &Line) -> Compressed {
        // Special lines first (cheapest encodings), checked word-wise.
        let words = super::line_words64(line);
        if words.iter().all(|&w| w == 0) {
            return Compressed { algo: Algo::Bdi, encoding: ENC_ZEROS, bytes: vec![ENC_ZEROS] };
        }
        if words.iter().all(|&w| w == words[0]) {
            let mut bytes = vec![ENC_REPEAT];
            bytes.extend_from_slice(&words[0].to_le_bytes());
            return Compressed { algo: Algo::Bdi, encoding: ENC_REPEAT, bytes };
        }
        // Candidate geometries in increasing compressed size; first hit wins
        // and is also the smallest, so this equals exhaustive search.
        for (enc, base_size, delta_size) in SORTED_GEOMETRIES {
            if encoded_size(base_size, delta_size) >= LINE_BYTES {
                continue;
            }
            if let Some(c) = Self::try_encode(line, enc, base_size, delta_size) {
                return c;
            }
        }
        let mut bytes = vec![ENC_UNCOMPRESSED];
        bytes.extend_from_slice(line);
        Compressed { algo: Algo::Bdi, encoding: ENC_UNCOMPRESSED, bytes }
    }

    fn decompress(&self, c: &Compressed) -> Line {
        assert_eq!(c.algo, Algo::Bdi);
        let mut line = [0u8; LINE_BYTES];
        match c.encoding {
            ENC_ZEROS => line,
            ENC_REPEAT => {
                for chunk in line.chunks_exact_mut(8) {
                    chunk.copy_from_slice(&c.bytes[1..9]);
                }
                line
            }
            ENC_UNCOMPRESSED => {
                line.copy_from_slice(&c.bytes[1..1 + LINE_BYTES]);
                line
            }
            enc => {
                let (_, base_size, delta_size) = BASE_DELTA_ENCODINGS
                    .iter()
                    .copied()
                    .find(|&(e, _, _)| e == enc)
                    .expect("valid BDI encoding");
                let n_values = LINE_BYTES / base_size;
                let mask = &c.bytes[1..1 + n_values / 8];
                let base_off = 1 + n_values / 8;
                let mut base = 0u64;
                for b in 0..base_size {
                    base |= (c.bytes[base_off + b] as u64) << (8 * b);
                }
                let deltas_off = base_off + base_size;
                for i in 0..n_values {
                    // Sign-extend the delta.
                    let raw = &c.bytes[deltas_off + i * delta_size..deltas_off + (i + 1) * delta_size];
                    let mut d = 0i64;
                    for (b, &byte) in raw.iter().enumerate() {
                        d |= (byte as i64) << (8 * b);
                    }
                    let shift = 64 - delta_size as u32 * 8;
                    d = (d << shift) >> shift;
                    let from_zero = mask[i / 8] & (1 << (i % 8)) != 0;
                    let v = if from_zero {
                        d as u64
                    } else {
                        base.wrapping_add(d as u64)
                    };
                    line[i * base_size..(i + 1) * base_size]
                        .copy_from_slice(&v.to_le_bytes()[..base_size]);
                }
                line
            }
        }
    }

    fn algo(&self) -> Algo {
        Algo::Bdi
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(line: &Line) -> Compressed {
        let c = Bdi.compress(line);
        assert_eq!(&Bdi.decompress(&c), line, "enc={}", encoding_name(c.encoding));
        c
    }

    #[test]
    fn geometry_order_is_sorted_by_size() {
        // The hard-coded hot-path order must equal the stable sort of the
        // declared encodings by compressed size.
        let mut expect = BASE_DELTA_ENCODINGS;
        expect.sort_by_key(|&(_, b, d)| encoded_size(b, d));
        assert_eq!(SORTED_GEOMETRIES, expect);
    }

    #[test]
    fn zeros_line() {
        let line = [0u8; LINE_BYTES];
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_ZEROS);
        assert_eq!(c.size_bytes(), 1);
        assert_eq!(c.bursts(), 1);
    }

    #[test]
    fn repeated_line() {
        let mut line = [0u8; LINE_BYTES];
        for chunk in line.chunks_exact_mut(8) {
            chunk.copy_from_slice(&0xDEAD_BEEF_1234_5678u64.to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_REPEAT);
        assert_eq!(c.size_bytes(), 9);
    }

    /// The paper's Fig. 6 PVC line: 8-byte pointers with 1-byte deltas plus
    /// implicit-zero values. Our 128B line doubles the value count; layout
    /// still compresses to 1 burst.
    #[test]
    fn bdi_paper_example() {
        let base = 0x0000_0000_8001_D000u64;
        let mut line = [0u8; LINE_BYTES];
        for (i, chunk) in line.chunks_exact_mut(8).enumerate() {
            let v = match i % 4 {
                0 => base + (i as u64),
                1 => 0,
                2 => base + (i as u64) * 2,
                _ => 0,
            };
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_B8D1);
        // 1 meta + 2 mask + 8 base + 16 deltas = 27 bytes (paper 64B line: 17B)
        assert_eq!(c.size_bytes(), 27);
        assert_eq!(c.bursts(), 1);
    }

    #[test]
    fn narrow_u32_values_use_base4() {
        let mut line = [0u8; LINE_BYTES];
        for (i, chunk) in line.chunks_exact_mut(4).enumerate() {
            chunk.copy_from_slice(&(1000u32 + i as u32).to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_B4D1);
        assert_eq!(c.size_bytes(), encoded_size(4, 1)); // 1+4+4+32 = 41
        assert_eq!(c.bursts(), 2);
    }

    #[test]
    fn random_line_uncompressed() {
        let mut rng = Rng::new(99);
        let mut line = [0u8; LINE_BYTES];
        for b in line.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_UNCOMPRESSED);
        assert_eq!(c.bursts(), 4);
    }

    #[test]
    fn negative_deltas() {
        let mut line = [0u8; LINE_BYTES];
        for (i, chunk) in line.chunks_exact_mut(8).enumerate() {
            let v = 1_000_000u64.wrapping_sub(i as u64 * 3);
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_B8D1);
    }

    #[test]
    fn delta_boundary_exact() {
        // Values exactly at the i8 boundary around the base.
        let base = 500u64;
        let mut line = [0u8; LINE_BYTES];
        for (i, chunk) in line.chunks_exact_mut(8).enumerate() {
            let v = if i % 2 == 0 { base + 127 } else { base - 128 };
            chunk.copy_from_slice(&v.to_le_bytes());
        }
        // First non-zero value is base+127, so deltas span [-255, 0] — does
        // NOT fit d1; must fall back to d2.
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_B8D2);
    }

    #[test]
    fn all_encodings_roundtrip_randomized() {
        // Construct lines aimed at each geometry and check roundtrips.
        let mut rng = Rng::new(7);
        for &(enc, base_size, delta_size) in BASE_DELTA_ENCODINGS.iter() {
            for _ in 0..50 {
                let n = LINE_BYTES / base_size;
                let base: u64 = rng.next_u64() >> (64 - 8 * base_size as u32 + 1);
                let mut line = [0u8; LINE_BYTES];
                let half = 1u64 << (delta_size * 8 - 1);
                for i in 0..n {
                    let d = rng.below(half) as u64;
                    // The compressor picks the first non-zero value as base,
                    // so the first value must be base-relative for the
                    // targeted geometry to apply.
                    let v = if i > 0 && rng.chance(0.2) { d } else { base.wrapping_add(d) };
                    line[i * base_size..(i + 1) * base_size]
                        .copy_from_slice(&v.to_le_bytes()[..base_size]);
                }
                let c = roundtrip(&line);
                // Must compress at least as well as the targeted geometry.
                assert!(
                    c.size_bytes() <= encoded_size(base_size, delta_size),
                    "enc {} produced {} > {}",
                    encoding_name(enc),
                    c.size_bytes(),
                    encoded_size(base_size, delta_size)
                );
            }
        }
    }

    #[test]
    fn compress_picks_minimum_size() {
        // compress() must never return a larger form than any single
        // geometry that fits.
        let mut rng = Rng::new(21);
        for _ in 0..200 {
            let mut line = [0u8; LINE_BYTES];
            let base = rng.next_u64() & 0xFFFF;
            for (i, chunk) in line.chunks_exact_mut(2).enumerate() {
                let v = (base + (i as u64 % 100)) as u16;
                chunk.copy_from_slice(&v.to_le_bytes());
            }
            let c = Bdi.compress(&line);
            for &(enc, b, d) in BASE_DELTA_ENCODINGS.iter() {
                if let Some(alt) = Bdi::try_encode(&line, enc, b, d) {
                    assert!(c.size_bytes() <= alt.size_bytes());
                }
            }
        }
    }
}
