//! Frequent Pattern Compression, segmented variant (paper §5.1.4).
//!
//! Original FPC (Alameldeen & Wood) compresses each 4-byte word with an
//! independent 3-bit prefix, which serializes decompression (word *i*'s
//! offset depends on words `0..i`). The paper parallelizes it for assist
//! warps with two modifications we reproduce exactly:
//!
//! 1. all word prefixes (metadata) move to the *head* of the line, and
//! 2. the line is split into fixed segments; all words in a segment share
//!    one encoding, so every lane can compute its operand address
//!    independently ("Each segment is compressed independently and all the
//!    words within each segment are compressed using the same encoding").
//!
//! Layout: `[hdr][seg_enc ×N][seg0 payload][seg1 payload]...` where `hdr`
//! is the segment count and each `seg_enc` is one of [`Pattern`].

use super::{Compressed, Compressor, Algo, Line, LINE_BYTES, WORDS_PER_LINE};

/// Words per segment. 8 words = 32B per segment, 4 segments per line —
/// the simplicity/compressibility trade-off the paper lands on (ablated in
/// `cargo bench --bench ablations`).
pub const DEFAULT_SEGMENT_WORDS: usize = 8;

/// Per-segment encodings, a parallel-friendly subset of FPC's prefixes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pattern {
    /// All words zero — 0 payload bytes/word.
    Zero = 0,
    /// Each word sign-extends from its low byte — 1 payload byte/word.
    SignExt1 = 1,
    /// Each word sign-extends from its low halfword — 2 payload bytes/word.
    SignExt2 = 2,
    /// Each word is one byte repeated ×4 — 1 payload byte/word.
    RepByte = 3,
    /// Uncompressed — 4 payload bytes/word.
    Uncompressed = 4,
}

impl Pattern {
    pub fn from_u8(v: u8) -> Pattern {
        match v {
            0 => Pattern::Zero,
            1 => Pattern::SignExt1,
            2 => Pattern::SignExt2,
            3 => Pattern::RepByte,
            _ => Pattern::Uncompressed,
        }
    }

    /// Payload bytes per word under this pattern.
    pub fn bytes_per_word(&self) -> usize {
        match self {
            Pattern::Zero => 0,
            Pattern::SignExt1 | Pattern::RepByte => 1,
            Pattern::SignExt2 => 2,
            Pattern::Uncompressed => 4,
        }
    }

    /// Does `word` fit this pattern?
    pub fn matches(&self, word: u32) -> bool {
        match self {
            Pattern::Zero => word == 0,
            Pattern::SignExt1 => (word as i32) >= -128 && (word as i32) <= 127,
            Pattern::SignExt2 => (word as i32) >= -32768 && (word as i32) <= 32767,
            Pattern::RepByte => {
                let b = word & 0xFF;
                word == b | (b << 8) | (b << 16) | (b << 24)
            }
            Pattern::Uncompressed => true,
        }
    }

    /// Tried in increasing payload-size order (Algorithm 4's encoding loop).
    pub const CANDIDATES: [Pattern; 5] = [
        Pattern::Zero,
        Pattern::SignExt1,
        Pattern::RepByte,
        Pattern::SignExt2,
        Pattern::Uncompressed,
    ];
}

/// Assist-warp subroutine lengths (instructions) for FPC, modelled from
/// Algorithms 3/4: per-segment load + pattern op + store + address update.
pub fn decompress_subroutine_len(n_segments: usize) -> usize {
    2 + n_segments * 4
}
pub fn compress_subroutine_len(n_segments: usize, encodings_tested: usize) -> usize {
    2 + n_segments * (2 + encodings_tested * 3)
}

pub const ENC_UNCOMPRESSED: u8 = 0xFF;

/// Segmented-FPC compressor. `segment_words` is configurable for the
/// ablation study; use `Fpc::default()` for the paper configuration.
pub struct Fpc {
    pub segment_words: usize,
}

impl Default for Fpc {
    fn default() -> Self {
        Fpc { segment_words: DEFAULT_SEGMENT_WORDS }
    }
}

impl Fpc {
    pub fn n_segments(&self) -> usize {
        WORDS_PER_LINE / self.segment_words
    }

    fn best_pattern(&self, words: &[u32]) -> Pattern {
        for p in Pattern::CANDIDATES {
            if words.iter().all(|&w| p.matches(w)) {
                return p;
            }
        }
        Pattern::Uncompressed
    }

    /// Allocation-free `(encoding, size_bytes)` — see [`super::measure`].
    /// Sums each segment's payload width instead of materializing it.
    pub fn measure(&self, line: &Line) -> (u8, usize) {
        let words = super::line_words(line);
        let n_seg = self.n_segments();
        let mut payload = 0usize;
        let mut compressed_segs = 0usize;
        for seg in words.chunks_exact(self.segment_words) {
            let p = self.best_pattern(seg);
            if p != Pattern::Uncompressed {
                compressed_segs += 1;
            }
            payload += p.bytes_per_word() * self.segment_words;
        }
        let size = 1 + n_seg + payload;
        if size >= LINE_BYTES {
            (ENC_UNCOMPRESSED, 1 + LINE_BYTES)
        } else {
            (compressed_segs as u8, size)
        }
    }
}

impl Compressor for Fpc {
    fn compress(&self, line: &Line) -> Compressed {
        let words = super::line_words(line);
        let n_seg = self.n_segments();
        let mut encs = Vec::with_capacity(n_seg);
        let mut payload = Vec::new();
        for seg in words.chunks_exact(self.segment_words) {
            let p = self.best_pattern(seg);
            encs.push(p as u8);
            for &w in seg {
                match p {
                    Pattern::Zero => {}
                    Pattern::SignExt1 | Pattern::RepByte => payload.push(w as u8),
                    Pattern::SignExt2 => payload.extend_from_slice(&(w as u16).to_le_bytes()),
                    Pattern::Uncompressed => payload.extend_from_slice(&w.to_le_bytes()),
                }
            }
        }
        let size = 1 + n_seg + payload.len();
        if size >= LINE_BYTES {
            let mut bytes = vec![ENC_UNCOMPRESSED];
            bytes.extend_from_slice(line);
            return Compressed { algo: Algo::Fpc, encoding: ENC_UNCOMPRESSED, bytes };
        }
        // Metadata at the head (paper §5.1.4), then payloads in segment order.
        let mut bytes = Vec::with_capacity(size);
        bytes.push(n_seg as u8);
        bytes.extend_from_slice(&encs);
        bytes.extend_from_slice(&payload);
        // encoding byte = bitmap of segment patterns packed 2 bits... we use
        // the count of compressed segments as the AWS subroutine selector.
        let compressed_segs = encs.iter().filter(|&&e| e != Pattern::Uncompressed as u8).count();
        Compressed { algo: Algo::Fpc, encoding: compressed_segs as u8, bytes }
    }

    fn decompress(&self, c: &Compressed) -> Line {
        assert_eq!(c.algo, Algo::Fpc);
        if c.encoding == ENC_UNCOMPRESSED {
            let mut line = [0u8; LINE_BYTES];
            line.copy_from_slice(&c.bytes[1..1 + LINE_BYTES]);
            return line;
        }
        let n_seg = c.bytes[0] as usize;
        let seg_words = WORDS_PER_LINE / n_seg;
        let mut words = [0u32; WORDS_PER_LINE];
        let mut off = 1 + n_seg;
        for s in 0..n_seg {
            let p = Pattern::from_u8(c.bytes[1 + s]);
            for i in 0..seg_words {
                let w = match p {
                    Pattern::Zero => 0,
                    Pattern::SignExt1 => {
                        let b = c.bytes[off] as i8;
                        off += 1;
                        b as i32 as u32
                    }
                    Pattern::RepByte => {
                        let b = c.bytes[off] as u32;
                        off += 1;
                        b | (b << 8) | (b << 16) | (b << 24)
                    }
                    Pattern::SignExt2 => {
                        let h = i16::from_le_bytes([c.bytes[off], c.bytes[off + 1]]);
                        off += 2;
                        h as i32 as u32
                    }
                    Pattern::Uncompressed => {
                        let w = u32::from_le_bytes(c.bytes[off..off + 4].try_into().unwrap());
                        off += 4;
                        w
                    }
                };
                words[s * seg_words + i] = w;
            }
        }
        super::words_line(&words)
    }

    fn algo(&self) -> Algo {
        Algo::Fpc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn roundtrip(line: &Line) -> Compressed {
        let f = Fpc::default();
        let c = f.compress(line);
        assert_eq!(&f.decompress(&c), line);
        c
    }

    #[test]
    fn zeros() {
        let line = [0u8; LINE_BYTES];
        let c = roundtrip(&line);
        assert_eq!(c.size_bytes(), 1 + 4); // hdr + 4 segment encodings
        assert_eq!(c.bursts(), 1);
    }

    #[test]
    fn narrow_values() {
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            ch.copy_from_slice(&(i as u32 % 100).to_le_bytes());
        }
        let c = roundtrip(&line);
        // All segments SignExt1: 1 + 4 + 32 = 37 bytes.
        assert_eq!(c.size_bytes(), 37);
        assert_eq!(c.bursts(), 2);
    }

    #[test]
    fn negative_narrow_values() {
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            ch.copy_from_slice(&(-(i as i32) as u32).to_le_bytes());
        }
        let c = roundtrip(&line);
        assert!(c.size_bytes() <= 37);
    }

    #[test]
    fn repeated_bytes() {
        let mut line = [0u8; LINE_BYTES];
        for (i, ch) in line.chunks_exact_mut(4).enumerate() {
            let b = (i % 7) as u8 + 1;
            ch.copy_from_slice(&[b, b, b, b]);
        }
        let c = roundtrip(&line);
        assert_eq!(c.size_bytes(), 37);
    }

    #[test]
    fn mixed_segments() {
        let mut line = [0u8; LINE_BYTES];
        // Segment 0: zeros. Segment 1: narrow. Segments 2-3: random-ish.
        for i in 8..16 {
            line[i * 4..i * 4 + 4].copy_from_slice(&(i as u32).to_le_bytes());
        }
        let mut rng = Rng::new(3);
        for i in 16..32 {
            line[i * 4..i * 4 + 4].copy_from_slice(&rng.next_u32().to_le_bytes());
        }
        let c = roundtrip(&line);
        // 1 + 4 + (0 + 8 + 32 + 32) = 77
        assert_eq!(c.size_bytes(), 77);
        assert_eq!(c.bursts(), 3);
    }

    #[test]
    fn incompressible_passthrough() {
        let mut rng = Rng::new(17);
        let mut line = [0u8; LINE_BYTES];
        for b in line.iter_mut() {
            *b = rng.next_u32() as u8;
        }
        let c = roundtrip(&line);
        assert_eq!(c.encoding, ENC_UNCOMPRESSED);
        assert_eq!(c.bursts(), 4);
    }

    #[test]
    fn segment_size_ablation_roundtrips() {
        let mut rng = Rng::new(31);
        for seg_words in [4usize, 8, 16] {
            let f = Fpc { segment_words: seg_words };
            for _ in 0..100 {
                let mut line = [0u8; LINE_BYTES];
                for ch in line.chunks_exact_mut(4) {
                    let w = if rng.chance(0.5) { rng.below(200) as u32 } else { rng.next_u32() };
                    ch.copy_from_slice(&w.to_le_bytes());
                }
                let c = f.compress(&line);
                assert_eq!(f.decompress(&c), line, "seg_words={seg_words}");
            }
        }
    }

    #[test]
    fn pattern_matches_are_exact() {
        assert!(Pattern::Zero.matches(0));
        assert!(!Pattern::Zero.matches(1));
        assert!(Pattern::SignExt1.matches(127));
        assert!(Pattern::SignExt1.matches(-128i32 as u32));
        assert!(!Pattern::SignExt1.matches(128));
        assert!(!Pattern::SignExt1.matches(-129i32 as u32));
        assert!(Pattern::SignExt2.matches(32767));
        assert!(!Pattern::SignExt2.matches(32768));
        assert!(Pattern::RepByte.matches(0xABABABAB));
        assert!(!Pattern::RepByte.matches(0xABABAB00));
    }
}
