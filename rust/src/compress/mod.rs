//! Byte-exact compression substrates: BDI, FPC and C-Pack.
//!
//! These are the three algorithms the paper maps onto assist warps
//! (§5.1.1–§5.1.5). Each is implemented twice in this repo:
//!
//! 1. here, in Rust, operating on real cache-line bytes (used by the
//!    simulator's `NativeOracle` and by the "hardware compressor" designs);
//! 2. as a JAX/Pallas model (`python/compile/`), AOT-compiled to an HLO
//!    artifact that the [`crate::runtime`] executes via PJRT (`PjrtOracle`).
//!
//! An integration test (`rust/tests/integration_pjrt.rs`) asserts the two
//! agree on encoding choice and compressed size for random and patterned
//! lines.
//!
//! Compression granularity is one 128-byte cache line (= four 32-byte GDDR5
//! bursts, the paper's "1–4 bursts" transfer quantum).

pub mod bdi;
pub mod cpack;
pub mod fpc;
pub mod oracle;

/// Cache-line size in bytes. 128B, the GPGPU-Sim / Fermi default; four
/// 32-byte DRAM bursts per line.
pub const LINE_BYTES: usize = 128;
/// Minimum DRAM transfer quantum (one GDDR5 burst).
pub const BURST_BYTES: usize = 32;
/// Bursts per uncompressed line.
pub const LINE_BURSTS: u8 = (LINE_BYTES / BURST_BYTES) as u8;
/// 4-byte words per line (FPC / C-Pack view).
pub const WORDS_PER_LINE: usize = LINE_BYTES / 4;

/// One cache line of raw data.
pub type Line = [u8; LINE_BYTES];

/// Compression algorithm selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algo {
    Bdi,
    Fpc,
    CPack,
    /// Idealized per-line best-of-{BDI,FPC,C-Pack} (paper's CABA-BestOfAll).
    BestOfAll,
}

impl Algo {
    pub fn name(&self) -> &'static str {
        match self {
            Algo::Bdi => "BDI",
            Algo::Fpc => "FPC",
            Algo::CPack => "C-Pack",
            Algo::BestOfAll => "BestOfAll",
        }
    }

    /// The three concrete algorithms.
    pub const CONCRETE: [Algo; 3] = [Algo::Bdi, Algo::Fpc, Algo::CPack];
}

/// A compressed cache line: the encoding metadata plus the payload bytes.
///
/// `encoding` is algorithm-specific (see each module); `bytes` always
/// includes all metadata needed for standalone decompression, mirroring the
/// paper's layout choice of putting metadata at the *head* of the line
/// (§5.1.3) so decompression can be set up upfront.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Compressed {
    pub algo: Algo,
    pub encoding: u8,
    pub bytes: Vec<u8>,
}

impl Compressed {
    /// Total compressed size in bytes (metadata included).
    pub fn size_bytes(&self) -> usize {
        self.bytes.len()
    }

    /// DRAM bursts needed to transfer this line (1–4). A line whose
    /// compressed form does not save at least one burst is stored
    /// uncompressed by construction, so this is always `<= LINE_BURSTS`.
    pub fn bursts(&self) -> u8 {
        bursts_for(self.size_bytes())
    }

    /// True if this line is stored in uncompressed form.
    pub fn is_uncompressed(&self) -> bool {
        self.size_bytes() >= LINE_BYTES
    }
}

/// Bursts needed for `size` bytes, clamped to the line maximum.
pub fn bursts_for(size: usize) -> u8 {
    (crate::util::ceil_div(size.max(1), BURST_BYTES) as u8).min(LINE_BURSTS)
}

/// Common interface over the three algorithms.
pub trait Compressor {
    /// Compress one line. Implementations must return an uncompressed
    /// passthrough (`encoding == <algo>::ENC_UNCOMPRESSED`) rather than ever
    /// producing `bytes.len() > LINE_BYTES + metadata`.
    fn compress(&self, line: &Line) -> Compressed;

    /// Exact inverse of [`Compressor::compress`].
    fn decompress(&self, c: &Compressed) -> Line;

    fn algo(&self) -> Algo;
}

/// Compress with a specific algorithm.
pub fn compress(algo: Algo, line: &Line) -> Compressed {
    match algo {
        Algo::Bdi => bdi::Bdi.compress(line),
        Algo::Fpc => fpc::Fpc::default().compress(line),
        Algo::CPack => cpack::CPack.compress(line),
        Algo::BestOfAll => {
            let mut best = bdi::Bdi.compress(line);
            for c in [
                fpc::Fpc::default().compress(line),
                cpack::CPack.compress(line),
            ] {
                if c.size_bytes() < best.size_bytes() {
                    best = c;
                }
            }
            best
        }
    }
}

/// Allocation-free compression verdict: `(encoding, size_bytes)`, exactly
/// equal to `compress(algo, line)`'s `(encoding, size_bytes())` without
/// materializing the compressed bytes. This is the oracle hot path — the
/// simulator only ever needs sizes and encodings, never payloads — so it
/// must stay bit-identical to [`compress`] (pinned by the
/// `measure_matches_compress` test below).
pub fn measure(algo: Algo, line: &Line) -> (u8, usize) {
    match algo {
        Algo::Bdi => bdi::measure(line),
        Algo::Fpc => fpc::Fpc::default().measure(line),
        Algo::CPack => cpack::measure(line),
        Algo::BestOfAll => {
            // Same tie-break as compress(): first strict improvement wins,
            // in BDI → FPC → C-Pack order.
            let mut best = bdi::measure(line);
            for m in [fpc::Fpc::default().measure(line), cpack::measure(line)] {
                if m.1 < best.1 {
                    best = m;
                }
            }
            best
        }
    }
}

/// Decompress a line produced by [`compress`].
pub fn decompress(c: &Compressed) -> Line {
    match c.algo {
        Algo::Bdi => bdi::Bdi.decompress(c),
        Algo::Fpc => fpc::Fpc::default().decompress(c),
        Algo::CPack => cpack::CPack.decompress(c),
        Algo::BestOfAll => unreachable!("BestOfAll lines carry a concrete algo"),
    }
}

/// View a line as 4-byte little-endian words (one 8-byte read per pair).
pub fn line_words(line: &Line) -> [u32; WORDS_PER_LINE] {
    let mut w = [0u32; WORDS_PER_LINE];
    for (i, chunk) in line.chunks_exact(8).enumerate() {
        let x = u64::from_le_bytes(chunk.try_into().unwrap());
        w[2 * i] = x as u32;
        w[2 * i + 1] = (x >> 32) as u32;
    }
    w
}

/// 8-byte little-endian words per line (BDI's widest value granularity).
pub const WORDS64_PER_LINE: usize = LINE_BYTES / 8;

/// View a line as 8-byte little-endian words — the word-wise read the
/// compressor inner loops operate on (values of every BDI granularity are
/// extracted from these by shift/mask instead of per-byte indexing).
pub fn line_words64(line: &Line) -> [u64; WORDS64_PER_LINE] {
    let mut w = [0u64; WORDS64_PER_LINE];
    for (i, chunk) in line.chunks_exact(8).enumerate() {
        w[i] = u64::from_le_bytes(chunk.try_into().unwrap());
    }
    w
}

/// Rebuild a line from 4-byte little-endian words.
pub fn words_line(words: &[u32; WORDS_PER_LINE]) -> Line {
    let mut line = [0u8; LINE_BYTES];
    for (i, w) in words.iter().enumerate() {
        line[i * 4..i * 4 + 4].copy_from_slice(&w.to_le_bytes());
    }
    line
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bursts_for_boundaries() {
        assert_eq!(bursts_for(0), 1);
        assert_eq!(bursts_for(1), 1);
        assert_eq!(bursts_for(32), 1);
        assert_eq!(bursts_for(33), 2);
        assert_eq!(bursts_for(64), 2);
        assert_eq!(bursts_for(96), 3);
        assert_eq!(bursts_for(97), 4);
        assert_eq!(bursts_for(128), 4);
        assert_eq!(bursts_for(1000), 4);
    }

    #[test]
    fn words_roundtrip() {
        let mut line = [0u8; LINE_BYTES];
        for (i, b) in line.iter_mut().enumerate() {
            *b = (i * 7 + 3) as u8;
        }
        assert_eq!(words_line(&line_words(&line)), line);
        // The 8-byte view agrees with the 4-byte view pairwise.
        let w32 = line_words(&line);
        for (i, &w) in line_words64(&line).iter().enumerate() {
            assert_eq!(w as u32, w32[2 * i]);
            assert_eq!((w >> 32) as u32, w32[2 * i + 1]);
        }
    }

    /// The hot-path contract: `measure` must agree with `compress` on
    /// encoding and size for every algorithm, across patterned and random
    /// lines (the simulator's verdicts are all served by `measure`).
    #[test]
    fn measure_matches_compress() {
        let mut rng = crate::util::rng::Rng::new(4242);
        for trial in 0..600 {
            let mut line = [0u8; LINE_BYTES];
            match trial % 6 {
                0 => {} // zeros
                1 => {
                    for chunk in line.chunks_exact_mut(8) {
                        chunk.copy_from_slice(&0xDEAD_BEEF_0000_1111u64.to_le_bytes());
                    }
                }
                2 => {
                    for (i, chunk) in line.chunks_exact_mut(8).enumerate() {
                        let v = 0x8001_D000u64 + (i as u64 % 120);
                        chunk.copy_from_slice(&v.to_le_bytes());
                    }
                }
                3 => {
                    for (i, chunk) in line.chunks_exact_mut(4).enumerate() {
                        chunk.copy_from_slice(&((i as u32) % 200).to_le_bytes());
                    }
                }
                4 => {
                    for b in line.iter_mut() {
                        *b = if rng.chance(0.6) { 0 } else { rng.next_u32() as u8 };
                    }
                }
                _ => {
                    for b in line.iter_mut() {
                        *b = rng.next_u32() as u8;
                    }
                }
            }
            for algo in [Algo::Bdi, Algo::Fpc, Algo::CPack, Algo::BestOfAll] {
                let c = compress(algo, &line);
                let (enc, size) = measure(algo, &line);
                assert_eq!(enc, c.encoding, "{algo:?} trial {trial}");
                assert_eq!(size, c.size_bytes(), "{algo:?} trial {trial}");
            }
        }
    }

    #[test]
    fn best_of_all_never_worse() {
        let mut rng = crate::util::rng::Rng::new(123);
        for _ in 0..200 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            let best = compress(Algo::BestOfAll, &line);
            for algo in Algo::CONCRETE {
                assert!(best.size_bytes() <= compress(algo, &line).size_bytes());
            }
        }
    }
}
