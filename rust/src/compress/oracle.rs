//! The compression *oracle*: the component that answers "how small does
//! this line get, and with which encoding?" for the simulator.
//!
//! Two implementations exist:
//!
//! * [`NativeOracle`] — the Rust compressors in this module tree;
//! * [`crate::runtime::PjrtOracle`] — the AOT-compiled JAX/Pallas model
//!   executed through PJRT (the assist-warp compute genuinely running
//!   through XLA), batched for throughput.
//!
//! Both are wrapped by [`MemoOracle`], which caches results by line content
//! hash — the simulator re-touches the same lines constantly, and the
//! oracle answer is a pure function of the bytes.

use super::{bursts_for, measure, Algo, Line};

/// Oracle verdict for one line under one algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LineVerdict {
    /// Algorithm-specific encoding byte (selects the AWS subroutine).
    pub encoding: u8,
    /// Compressed size in bytes, metadata included.
    pub size_bytes: u16,
    /// DRAM bursts to transfer (1–4).
    pub bursts: u8,
}

impl LineVerdict {
    pub fn uncompressed() -> Self {
        LineVerdict {
            encoding: 0xFF,
            size_bytes: super::LINE_BYTES as u16,
            bursts: super::LINE_BURSTS,
        }
    }

    pub fn is_compressed(&self) -> bool {
        self.bursts < super::LINE_BURSTS
    }
}

/// Batch-capable oracle interface. Batching matters for the PJRT backend
/// (one executable launch amortized over many lines); the native backend
/// just loops.
///
/// `Send` is a supertrait so a whole [`crate::sim::Simulator`] (which owns
/// its oracle) can be moved onto a sweep-engine worker thread
/// ([`crate::sweep`]). Oracles are still used single-threaded — one per
/// simulation — so no `Sync` is required.
pub trait CompressionOracle: Send {
    /// Analyze a batch of lines under `algo`.
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict>;

    /// Single-line convenience.
    fn analyze_one(&mut self, algo: Algo, line: &Line) -> LineVerdict {
        self.analyze(algo, std::slice::from_ref(line))[0]
    }

    /// Human-readable backend name for reports.
    fn backend_name(&self) -> &'static str;

    /// Memoization counters (`(hits, misses)`) if this backend keeps any.
    /// Only [`MemoOracle`] answers; raw backends return `None`.
    fn memo_stats(&self) -> Option<(u64, u64)> {
        None
    }
}

/// Pure-Rust oracle. Verdicts come from the allocation-free
/// [`crate::compress::measure`] path (sizes and encodings only — the
/// compressed payload is never materialized on the simulator hot path).
#[derive(Default)]
pub struct NativeOracle;

fn measured_verdict(algo: Algo, line: &Line) -> LineVerdict {
    let (encoding, size) = measure(algo, line);
    LineVerdict {
        encoding,
        size_bytes: size as u16,
        bursts: bursts_for(size),
    }
}

impl CompressionOracle for NativeOracle {
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict> {
        lines.iter().map(|line| measured_verdict(algo, line)).collect()
    }

    fn analyze_one(&mut self, algo: Algo, line: &Line) -> LineVerdict {
        measured_verdict(algo, line)
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

/// FxHash-style multiply-rotate-xor over the line's sixteen 8-byte words
/// plus the algorithm tag. One multiply per word versus SipHash's full
/// permutation network — the memo probe is no longer hash-dominated.
/// Collisions (two lines with equal 64-bit keys) would silently alias
/// verdicts, exactly as with the previous 64-bit `DefaultHasher` key; at
/// 2^-64 per pair this is accepted.
fn line_key(algo: Algo, line: &Line) -> u64 {
    const K: u64 = 0x517c_c1b7_2722_0a95; // FxHash's 64-bit constant
    let mut h = (algo as u64).wrapping_add(1).wrapping_mul(K);
    for chunk in line.chunks_exact(8) {
        let w = u64::from_le_bytes(chunk.try_into().unwrap());
        h = (h.rotate_left(5) ^ w).wrapping_mul(K);
    }
    // 0 is the table's vacant sentinel; remap the (astronomically rare)
    // zero key instead of reserving a validity bitmap.
    if h == EMPTY_KEY {
        0x9E37_79B9_7F4A_7C15
    } else {
        h
    }
}

/// Vacant-slot sentinel in [`MemoOracle`]'s key array.
const EMPTY_KEY: u64 = 0;
/// Bounded linear probe window: a lookup/insert touches at most this many
/// consecutive slots (one or two cache lines of keys).
const PROBE_WINDOW: usize = 8;
/// Initial table size in slots — small, so tiny runs (unit tests, quick
/// scales, sweep points over small footprints) pay ~50 KB, not megabytes.
const INITIAL_SLOTS: usize = 1 << 12;
/// Growth ceiling in slots (power of two): 512K slots ≈ 6 MB of
/// keys+verdicts, sized to the distinct-line-content population of the
/// large sweep points. Beyond it the table stops growing and relies on
/// per-slot replacement.
const MAX_SLOTS: usize = 1 << 19;

/// Content-hash memoization wrapper. This is a *performance* device for the
/// simulator, not an architectural structure (the MD cache in
/// `mem::mdcache` models the architecture).
///
/// The table is open-addressed with a bounded probe window
/// ([`PROBE_WINDOW`]); when a window is full the incoming entry
/// deterministically replaces the one at its home slot (per-slot
/// replacement — no wholesale `clear()`). It starts at [`INITIAL_SLOTS`]
/// and doubles (rehashing in place) at 50% occupancy until [`MAX_SLOTS`],
/// so memory follows the run's distinct-content population instead of
/// being paid up front by every simulator instance. Memoization stays
/// transparent throughout: a replaced or rehash-dropped entry is simply
/// recomputed on its next miss.
pub struct MemoOracle<O: CompressionOracle> {
    inner: O,
    keys: Vec<u64>,
    verdicts: Vec<LineVerdict>,
    mask: usize,
    /// Slots holding an entry (claimed-from-empty; replacement keeps it).
    occupied: usize,
    /// Growth ceiling for this instance (power of two).
    max_slots: usize,
    pub hits: u64,
    pub misses: u64,
    /// Batch-path scratch (reused across `analyze` calls).
    miss_idx: Vec<usize>,
    miss_lines: Vec<Line>,
}

impl<O: CompressionOracle> MemoOracle<O> {
    pub fn new(inner: O) -> Self {
        Self::with_slots(inner, MAX_SLOTS)
    }

    /// Explicit table-size *ceiling* in slots (rounded up to a power of
    /// two); the table still starts small and grows on demand.
    pub fn with_slots(inner: O, slots: usize) -> Self {
        let max_slots = slots.next_power_of_two().max(PROBE_WINDOW);
        let initial = INITIAL_SLOTS.min(max_slots);
        MemoOracle {
            inner,
            keys: vec![EMPTY_KEY; initial],
            verdicts: vec![LineVerdict::uncompressed(); initial],
            mask: initial - 1,
            occupied: 0,
            max_slots,
            hits: 0,
            misses: 0,
            miss_idx: Vec::new(),
            miss_lines: Vec::new(),
        }
    }

    pub fn inner_mut(&mut self) -> &mut O {
        &mut self.inner
    }

    #[inline]
    fn probe(&self, key: u64) -> Option<LineVerdict> {
        let home = key as usize & self.mask;
        for i in 0..PROBE_WINDOW {
            let s = (home + i) & self.mask;
            let k = self.keys[s];
            if k == key {
                return Some(self.verdicts[s]);
            }
            if k == EMPTY_KEY {
                // Entries are never deleted, so an empty slot ends the run.
                return None;
            }
        }
        None
    }

    /// Probe-window write without the growth check (also the rehash path).
    #[inline]
    fn install_raw(&mut self, key: u64, v: LineVerdict) {
        let home = key as usize & self.mask;
        for i in 0..PROBE_WINDOW {
            let s = (home + i) & self.mask;
            if self.keys[s] == key {
                self.verdicts[s] = v;
                return;
            }
            if self.keys[s] == EMPTY_KEY {
                self.keys[s] = key;
                self.verdicts[s] = v;
                self.occupied += 1;
                return;
            }
        }
        // Window full: replace the home slot (deterministic, O(1)).
        self.keys[home] = key;
        self.verdicts[home] = v;
    }

    #[inline]
    fn install(&mut self, key: u64, v: LineVerdict) {
        if self.occupied * 2 >= self.keys.len() && self.keys.len() < self.max_slots {
            self.grow();
        }
        self.install_raw(key, v);
    }

    /// Double the table and re-place every entry under the new mask.
    /// Deterministic (iteration order is the old slot order); an entry
    /// landing in a full window is dropped — recomputed on next miss.
    fn grow(&mut self) {
        let new_len = (self.keys.len() * 2).min(self.max_slots);
        let old_keys = std::mem::replace(&mut self.keys, vec![EMPTY_KEY; new_len]);
        let old_verdicts =
            std::mem::replace(&mut self.verdicts, vec![LineVerdict::uncompressed(); new_len]);
        self.mask = new_len - 1;
        self.occupied = 0;
        for (k, v) in old_keys.into_iter().zip(old_verdicts) {
            if k != EMPTY_KEY {
                self.install_raw(k, v);
            }
        }
    }
}

impl<O: CompressionOracle> CompressionOracle for MemoOracle<O> {
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict> {
        let mut out = vec![LineVerdict::uncompressed(); lines.len()];
        let mut miss_idx = std::mem::take(&mut self.miss_idx);
        let mut miss_lines = std::mem::take(&mut self.miss_lines);
        miss_idx.clear();
        miss_lines.clear();
        for (i, line) in lines.iter().enumerate() {
            match self.probe(line_key(algo, line)) {
                Some(v) => {
                    self.hits += 1;
                    out[i] = v;
                }
                None => {
                    self.misses += 1;
                    miss_idx.push(i);
                    miss_lines.push(*line);
                }
            }
        }
        if !miss_lines.is_empty() {
            let verdicts = self.inner.analyze(algo, &miss_lines);
            debug_assert_eq!(verdicts.len(), miss_lines.len());
            for (k, &i) in miss_idx.iter().enumerate() {
                self.install(line_key(algo, &miss_lines[k]), verdicts[k]);
                out[i] = verdicts[k];
            }
        }
        self.miss_idx = miss_idx;
        self.miss_lines = miss_lines;
        out
    }

    fn analyze_one(&mut self, algo: Algo, line: &Line) -> LineVerdict {
        // The single-line fast path: no batch vectors, no `Vec` result.
        let key = line_key(algo, line);
        if let Some(v) = self.probe(key) {
            self.hits += 1;
            return v;
        }
        self.misses += 1;
        let v = self.inner.analyze_one(algo, line);
        self.install(key, v);
        v
    }

    fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }

    fn memo_stats(&self) -> Option<(u64, u64)> {
        Some((self.hits, self.misses))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{compress, LINE_BYTES};
    use crate::util::rng::Rng;

    #[test]
    fn native_matches_direct_compress() {
        let mut rng = Rng::new(8);
        let mut oracle = NativeOracle;
        for _ in 0..100 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = if rng.chance(0.5) { 0 } else { rng.next_u32() as u8 };
            }
            for algo in Algo::CONCRETE {
                let v = oracle.analyze_one(algo, &line);
                let c = compress(algo, &line);
                assert_eq!(v.size_bytes as usize, c.size_bytes());
                assert_eq!(v.bursts, c.bursts());
                assert_eq!(v.encoding, c.encoding);
            }
        }
    }

    #[test]
    fn memo_oracle_is_transparent() {
        let mut rng = Rng::new(12);
        let mut plain = NativeOracle;
        let mut memo = MemoOracle::new(NativeOracle);
        let mut lines = Vec::new();
        for _ in 0..64 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            lines.push(line);
        }
        // First call populates the memo; the repeat must hit it.
        let a = plain.analyze(Algo::Bdi, &lines);
        let b1 = memo.analyze(Algo::Bdi, &lines);
        let b2 = memo.analyze(Algo::Bdi, &lines);
        assert_eq!(a, b1);
        assert_eq!(a, b2);
        assert!(memo.hits >= 64, "hits={}", memo.hits);
    }

    #[test]
    fn verdict_uncompressed_constants() {
        let v = LineVerdict::uncompressed();
        assert!(!v.is_compressed());
        assert_eq!(v.bursts, 4);
    }

    #[test]
    fn memo_analyze_one_matches_batch() {
        let mut rng = Rng::new(77);
        let mut memo = MemoOracle::new(NativeOracle);
        let mut plain = NativeOracle;
        for _ in 0..200 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = if rng.chance(0.4) { 0 } else { rng.next_u32() as u8 };
            }
            for algo in Algo::CONCRETE {
                assert_eq!(memo.analyze_one(algo, &line), plain.analyze_one(algo, &line));
            }
        }
        assert_eq!(memo.memo_stats(), Some((memo.hits, memo.misses)));
        assert!(memo.hits + memo.misses > 0);
    }

    #[test]
    fn memo_stays_transparent_under_replacement() {
        // A table far smaller than the working set forces the bounded
        // probe window to replace entries; verdicts must stay correct.
        let mut rng = Rng::new(31);
        let mut tiny = MemoOracle::with_slots(NativeOracle, 16);
        let mut plain = NativeOracle;
        let mut lines = Vec::new();
        for _ in 0..500 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            lines.push(line);
        }
        // Two passes so replaced entries are re-looked-up.
        for _ in 0..2 {
            let got = tiny.analyze(Algo::Bdi, &lines);
            let want = plain.analyze(Algo::Bdi, &lines);
            assert_eq!(got, want);
        }
        assert!(tiny.misses > 0);
    }

    #[test]
    fn memo_grows_past_initial_size_and_stays_transparent() {
        // More distinct contents than INITIAL_SLOTS/2 forces at least one
        // rehash-double; verdicts must stay correct and mostly retained.
        let mut rng = Rng::new(9);
        let mut memo = MemoOracle::new(NativeOracle);
        let initial = memo.keys.len();
        let mut lines = Vec::new();
        for _ in 0..(INITIAL_SLOTS) {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            lines.push(line);
        }
        let mut plain = NativeOracle;
        let first = memo.analyze(Algo::Bdi, &lines);
        assert_eq!(first, plain.analyze(Algo::Bdi, &lines));
        assert!(memo.keys.len() > initial, "table should have grown");
        let hits_before = memo.hits;
        let second = memo.analyze(Algo::Bdi, &lines);
        assert_eq!(first, second);
        // The warm pass is overwhelmingly hits (rehash drops are rare).
        assert!(
            memo.hits - hits_before > (lines.len() as u64 * 9) / 10,
            "warm hits {} of {}",
            memo.hits - hits_before,
            lines.len()
        );
    }

    #[test]
    fn distinct_algos_never_share_memo_entries() {
        let mut memo = MemoOracle::new(NativeOracle);
        let mut line = [0u8; LINE_BYTES];
        for (i, b) in line.iter_mut().enumerate() {
            *b = (i % 250) as u8;
        }
        for algo in Algo::CONCRETE {
            let direct = compress(algo, &line);
            let v = memo.analyze_one(algo, &line);
            assert_eq!(v.size_bytes as usize, direct.size_bytes(), "{algo:?}");
            assert_eq!(v.encoding, direct.encoding, "{algo:?}");
        }
    }
}
