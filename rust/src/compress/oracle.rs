//! The compression *oracle*: the component that answers "how small does
//! this line get, and with which encoding?" for the simulator.
//!
//! Two implementations exist:
//!
//! * [`NativeOracle`] — the Rust compressors in this module tree;
//! * [`crate::runtime::PjrtOracle`] — the AOT-compiled JAX/Pallas model
//!   executed through PJRT (the assist-warp compute genuinely running
//!   through XLA), batched for throughput.
//!
//! Both are wrapped by [`MemoOracle`], which caches results by line content
//! hash — the simulator re-touches the same lines constantly, and the
//! oracle answer is a pure function of the bytes.

use super::{compress, Algo, Line};
use std::collections::HashMap;
use std::hash::{Hash, Hasher};

/// Oracle verdict for one line under one algorithm.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LineVerdict {
    /// Algorithm-specific encoding byte (selects the AWS subroutine).
    pub encoding: u8,
    /// Compressed size in bytes, metadata included.
    pub size_bytes: u16,
    /// DRAM bursts to transfer (1–4).
    pub bursts: u8,
}

impl LineVerdict {
    pub fn uncompressed() -> Self {
        LineVerdict {
            encoding: 0xFF,
            size_bytes: super::LINE_BYTES as u16,
            bursts: super::LINE_BURSTS,
        }
    }

    pub fn is_compressed(&self) -> bool {
        self.bursts < super::LINE_BURSTS
    }
}

/// Batch-capable oracle interface. Batching matters for the PJRT backend
/// (one executable launch amortized over many lines); the native backend
/// just loops.
///
/// `Send` is a supertrait so a whole [`crate::sim::Simulator`] (which owns
/// its oracle) can be moved onto a sweep-engine worker thread
/// ([`crate::sweep`]). Oracles are still used single-threaded — one per
/// simulation — so no `Sync` is required.
pub trait CompressionOracle: Send {
    /// Analyze a batch of lines under `algo`.
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict>;

    /// Single-line convenience.
    fn analyze_one(&mut self, algo: Algo, line: &Line) -> LineVerdict {
        self.analyze(algo, std::slice::from_ref(line))[0]
    }

    /// Human-readable backend name for reports.
    fn backend_name(&self) -> &'static str;
}

/// Pure-Rust oracle.
#[derive(Default)]
pub struct NativeOracle;

impl CompressionOracle for NativeOracle {
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict> {
        lines
            .iter()
            .map(|line| {
                let c = compress(algo, line);
                LineVerdict {
                    encoding: c.encoding,
                    size_bytes: c.size_bytes() as u16,
                    bursts: c.bursts(),
                }
            })
            .collect()
    }

    fn backend_name(&self) -> &'static str {
        "native"
    }
}

fn line_key(algo: Algo, line: &Line) -> u64 {
    // FxHash-style multiply-xor over 8-byte chunks; cheap and good enough
    // for memoization (collisions only cost a wrong verdict in a cache —
    // we additionally store the first 8 bytes to disambiguate cheaply).
    let mut h = std::collections::hash_map::DefaultHasher::new();
    algo.hash(&mut h);
    line.hash(&mut h);
    h.finish()
}

/// Content-hash memoization wrapper. This is a *performance* device for the
/// simulator, not an architectural structure (the MD cache in
/// `mem::mdcache` models the architecture).
pub struct MemoOracle<O: CompressionOracle> {
    inner: O,
    cache: HashMap<u64, LineVerdict>,
    pub hits: u64,
    pub misses: u64,
    capacity: usize,
}

impl<O: CompressionOracle> MemoOracle<O> {
    pub fn new(inner: O) -> Self {
        MemoOracle {
            inner,
            cache: HashMap::new(),
            hits: 0,
            misses: 0,
            capacity: 1 << 20,
        }
    }

    pub fn inner_mut(&mut self) -> &mut O {
        &mut self.inner
    }
}

impl<O: CompressionOracle> CompressionOracle for MemoOracle<O> {
    fn analyze(&mut self, algo: Algo, lines: &[Line]) -> Vec<LineVerdict> {
        let mut out = vec![LineVerdict::uncompressed(); lines.len()];
        let mut miss_idx = Vec::new();
        let mut miss_lines = Vec::new();
        for (i, line) in lines.iter().enumerate() {
            match self.cache.get(&line_key(algo, line)) {
                Some(v) => {
                    self.hits += 1;
                    out[i] = *v;
                }
                None => {
                    self.misses += 1;
                    miss_idx.push(i);
                    miss_lines.push(*line);
                }
            }
        }
        if !miss_lines.is_empty() {
            if self.cache.len() > self.capacity {
                self.cache.clear(); // crude but rare; keeps memory bounded
            }
            let verdicts = self.inner.analyze(algo, &miss_lines);
            for (k, &i) in miss_idx.iter().enumerate() {
                self.cache.insert(line_key(algo, &miss_lines[k]), verdicts[k]);
                out[i] = verdicts[k];
            }
        }
        out
    }

    fn backend_name(&self) -> &'static str {
        self.inner.backend_name()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::LINE_BYTES;
    use crate::util::rng::Rng;

    #[test]
    fn native_matches_direct_compress() {
        let mut rng = Rng::new(8);
        let mut oracle = NativeOracle;
        for _ in 0..100 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = if rng.chance(0.5) { 0 } else { rng.next_u32() as u8 };
            }
            for algo in Algo::CONCRETE {
                let v = oracle.analyze_one(algo, &line);
                let c = compress(algo, &line);
                assert_eq!(v.size_bytes as usize, c.size_bytes());
                assert_eq!(v.bursts, c.bursts());
                assert_eq!(v.encoding, c.encoding);
            }
        }
    }

    #[test]
    fn memo_oracle_is_transparent() {
        let mut rng = Rng::new(12);
        let mut plain = NativeOracle;
        let mut memo = MemoOracle::new(NativeOracle);
        let mut lines = Vec::new();
        for _ in 0..64 {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            lines.push(line);
        }
        // First call populates the memo; the repeat must hit it.
        let a = plain.analyze(Algo::Bdi, &lines);
        let b1 = memo.analyze(Algo::Bdi, &lines);
        let b2 = memo.analyze(Algo::Bdi, &lines);
        assert_eq!(a, b1);
        assert_eq!(a, b2);
        assert!(memo.hits >= 64, "hits={}", memo.hits);
    }

    #[test]
    fn verdict_uncompressed_constants() {
        let v = LineVerdict::uncompressed();
        assert!(!v.is_compressed());
        assert_eq!(v.bursts, 4);
    }
}
