//! Golden-stats regression: pins `(cycles, warp_insts, dram.bursts,
//! dram.bursts_uncompressed, memo_hits, memo_evictions)` — and therefore
//! the compression ratio and the memo-LUT dynamics — for four
//! (app, design) pairs at a fixed scale, so hot-path refactors that
//! change simulation results fail loudly instead of silently shifting the
//! figures.
//!
//! The baseline lives in `tests/golden_stats.txt`. On the first run (file
//! absent) the test **blesses** the current results into it and passes;
//! commit the file to lock them in. After an *intentional* semantic change,
//! regenerate with `CABA_BLESS=1 cargo test --test golden_stats` and commit
//! the diff — the point is that result shifts always show up in review.

use caba::compress::Algo;
use caba::sim::designs::Design;
use caba::workload::apps;
use caba::{SimConfig, Simulator};
use std::fmt::Write as _;

const GOLDEN_PATH: &str = "tests/golden_stats.txt";
const SCALE: f64 = 0.02;

fn pairs() -> Vec<(&'static str, Design)> {
    vec![
        ("SLA", Design::base()),
        ("PVC", Design::caba(Algo::Bdi)),
        ("MM", Design::caba(Algo::Fpc)),
        // Compute-bound × memoization: pins the emergent LUT behaviour
        // (operand-value stream, install/evict dynamics) cycle-for-cycle.
        ("FRAG", Design::caba_memo()),
    ]
}

fn cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.n_sms = 2;
    c.max_cycles = 500_000;
    c
}

fn render_current() -> String {
    let mut out = String::from(
        "# golden simulation stats — regenerate with CABA_BLESS=1 cargo test --test golden_stats\n",
    );
    for (app_name, design) in pairs() {
        let app = apps::find(app_name).expect("golden app exists");
        let stats = Simulator::new(cfg(), design, app, SCALE).run();
        assert!(
            stats.finished,
            "{app_name}/{} did not drain at scale {SCALE} — goldens need drained runs",
            design.name
        );
        let _ = writeln!(
            out,
            "{}/{} cycles={} warp_insts={} bursts={} bursts_uncompressed={} memo_hits={} memo_evictions={}",
            app_name,
            design.name,
            stats.cycles,
            stats.warp_insts,
            stats.dram.bursts,
            stats.dram.bursts_uncompressed,
            stats.caba.memo_hits,
            stats.caba.memo_evictions,
        );
    }
    out
}

#[test]
fn golden_stats_pinned() {
    let actual = render_current();
    let bless = std::env::var("CABA_BLESS").is_ok();
    match std::fs::read_to_string(GOLDEN_PATH) {
        Ok(expected) if !bless => {
            assert_eq!(
                actual.trim(),
                expected.trim(),
                "\nsimulation results diverged from the committed golden baseline \
                 ({GOLDEN_PATH}).\nIf this change is intentional, regenerate with \
                 `CABA_BLESS=1 cargo test --test golden_stats` and commit the diff."
            );
        }
        _ => {
            // Self-bless keeps a fresh checkout green before the baseline
            // is first committed — but a checkout that *requires* the
            // committed baseline (CI after it lands) must not silently
            // re-bless; CABA_REQUIRE_GOLDEN turns absence into a failure.
            assert!(
                std::env::var("CABA_REQUIRE_GOLDEN").is_err() || bless,
                "{GOLDEN_PATH} is missing but CABA_REQUIRE_GOLDEN is set — \
                 the committed baseline was deleted or never checked in"
            );
            std::fs::write(GOLDEN_PATH, &actual).expect("write golden baseline");
            eprintln!("golden_stats: blessed new baseline into {GOLDEN_PATH}:\n{actual}");
        }
    }
}
