//! The sweep engine's two contracts:
//!
//! 1. **Determinism** — a parallel sweep (`--jobs 4`) produces `SimStats`
//!    bit-identical to a serial sweep (`--jobs 1`) of the same matrix,
//!    field for field. Every simulation point is self-contained and its
//!    RNG streams are seeded from `(cfg.seed, app)` only, so worker
//!    scheduling cannot leak into results.
//! 2. **Cache soundness** — the run cache keys on the *full*
//!    `SimConfig` fingerprint, so two sweeps differing only in a `--set`
//!    override never alias (the pre-engine cache keyed only on
//!    `(app, design, bw_scale, scale)` and would return stale stats).

use caba::compress::Algo;
use caba::report::figures::RunCtx;
use caba::sim::designs::Design;
use caba::sim::Simulator;
use caba::sweep::{SweepEngine, SweepJob};
use caba::workload::apps;
use caba::SimConfig;

fn tiny_cfg() -> SimConfig {
    let mut c = SimConfig::default();
    c.n_sms = 2;
    c.max_cycles = 200_000;
    c
}

/// A small but heterogeneous (app × design) matrix: one very compressible
/// app, one matrix kernel, one incompressible (profiler-disabled) app,
/// under the baseline and two CABA variants.
fn small_matrix() -> Vec<SweepJob> {
    let mut jobs = Vec::new();
    for name in ["PVC", "MM", "SCP"] {
        let app = apps::find(name).unwrap();
        for design in [Design::base(), Design::caba(Algo::Bdi), Design::caba(Algo::Fpc)] {
            jobs.push(SweepJob::new(app, design, tiny_cfg(), 0.015));
        }
    }
    jobs
}

#[test]
fn parallel_sweep_bit_identical_to_serial() {
    let jobs = small_matrix();
    // Private caches: each engine must actually execute its own runs.
    let serial = SweepEngine::new(1).run(&jobs).unwrap();
    let parallel = SweepEngine::new(4).run(&jobs).unwrap();
    assert_eq!(serial.len(), parallel.len());
    for (i, (s, p)) in serial.iter().zip(&parallel).enumerate() {
        // SimStats derives PartialEq over every counter (cycles, issue
        // breakdown, caches, DRAM, CABA activity, energy events...), so
        // this is a field-for-field bit-identity check.
        assert_eq!(s, p, "job {i}: serial and parallel stats diverge");
    }
    // And the sweep engine matches direct Simulator invocation.
    let app = apps::find("PVC").unwrap();
    let direct = Simulator::new(tiny_cfg(), Design::caba(Algo::Bdi), app, 0.015).run();
    let via_engine = SweepEngine::new(2)
        .run(&[SweepJob::new(app, Design::caba(Algo::Bdi), tiny_cfg(), 0.015)])
        .unwrap();
    assert_eq!(direct, via_engine[0]);
}

#[test]
fn parallel_sweep_is_repeatable() {
    let jobs = small_matrix();
    let a = SweepEngine::new(4).run(&jobs).unwrap();
    let b = SweepEngine::new(4).run(&jobs).unwrap();
    assert_eq!(a, b);
}

#[test]
fn cache_key_regression_set_overrides_are_not_aliased() {
    // The historical bug: the figure cache keyed on (app, design,
    // bw_scale, scale) only, so a run with a `--set` override could be
    // served stats simulated under a *different* configuration. With the
    // full-fingerprint key, the same (app, design, bw, scale) under two
    // configs must produce two distinct results from one shared cache.
    let app = apps::find("PVC").unwrap();
    let engine = SweepEngine::new(2); // one engine == one shared cache

    let cfg_a = tiny_cfg();
    let mut cfg_b = tiny_cfg();
    cfg_b.set("n_sms", "1").unwrap(); // a --set override

    let a = engine.run(&[SweepJob::new(app, Design::base(), cfg_a.clone(), 0.015)]).unwrap();
    let b = engine.run(&[SweepJob::new(app, Design::base(), cfg_b.clone(), 0.015)]).unwrap();
    // Fewer SMs must change the simulation outcome; a stale cache hit
    // would have returned `a` verbatim.
    assert_ne!(a[0], b[0], "cache served stale stats across --set override");

    // Lookups under the original configs still hit their own entries.
    let a2 = engine.run(&[SweepJob::new(app, Design::base(), cfg_a, 0.015)]).unwrap();
    let b2 = engine.run(&[SweepJob::new(app, Design::base(), cfg_b, 0.015)]).unwrap();
    assert_eq!(a[0], a2[0]);
    assert_eq!(b[0], b2[0]);
}

#[test]
fn figure_ctx_honors_config_overrides() {
    // End-to-end through the figure path: the same point under two RunCtx
    // configs must not alias in the process-wide shared cache.
    let app = apps::find("PVC").unwrap();
    let mut ctx_a = RunCtx::new(0.015);
    ctx_a.cfg = tiny_cfg();
    let mut ctx_b = RunCtx::new(0.015);
    ctx_b.cfg = tiny_cfg();
    // Every PVC miss pays this, so the override must change the outcome.
    ctx_b.cfg.set("dram_base_latency", "400").unwrap();
    let a = ctx_a.point(app, Design::caba(Algo::Bdi), 1.0);
    let b = ctx_b.point(app, Design::caba(Algo::Bdi), 1.0);
    assert_ne!(a, b, "figure cache aliased two configurations");
    // Repeat lookups are cache hits with unchanged values.
    assert_eq!(a, ctx_a.point(app, Design::caba(Algo::Bdi), 1.0));
}

#[test]
fn duplicate_jobs_simulate_once_and_fan_out() {
    let app = apps::find("SLA").unwrap();
    let job = SweepJob::new(app, Design::base(), tiny_cfg(), 0.01);
    let out = SweepEngine::new(4).run(&vec![job.clone(); 8]).unwrap();
    assert_eq!(out.len(), 8);
    for s in &out[1..] {
        assert_eq!(&out[0], s);
    }
}
