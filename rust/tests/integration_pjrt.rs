//! The three-layer contract: the AOT-compiled JAX/Pallas compression model
//! (artifacts/*.hlo.txt), executed through PJRT by the Rust runtime, must
//! agree bit-for-bit with the native Rust compressors on every line.
//!
//! Requires `make artifacts`; tests are skipped (with a loud message) if
//! the artifacts are absent so `cargo test` stays runnable standalone.

use caba::compress::oracle::{CompressionOracle, NativeOracle};
use caba::compress::{Algo, Line, LINE_BYTES};
use caba::runtime::{artifacts_available, PjrtOracle};
use caba::util::rng::Rng;
use caba::workload::datagen::{line_data, DataPattern};

fn pjrt() -> Option<PjrtOracle> {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts/ missing — run `make artifacts` first");
        return None;
    }
    Some(PjrtOracle::from_default_dir().expect("artifact load"))
}

fn patterned_lines(n: usize) -> Vec<Line> {
    let patterns = [
        DataPattern::ZeroHeavy { p_zero: 0.5 },
        DataPattern::LowDynRange { value_bytes: 8, delta_bytes: 1 },
        DataPattern::LowDynRange { value_bytes: 4, delta_bytes: 2 },
        DataPattern::NarrowInt { max: 120 },
        DataPattern::PointerLike { n_bases: 4 },
        DataPattern::RepBytes,
        DataPattern::SparseNarrow { p_nonzero: 0.3 },
        DataPattern::FloatGrid { exp: 120 },
        DataPattern::Random,
    ];
    (0..n)
        .map(|i| line_data(&patterns[i % patterns.len()], 99, i as u64, 0))
        .collect()
}

fn random_lines(n: usize, seed: u64) -> Vec<Line> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let mut line = [0u8; LINE_BYTES];
            for b in line.iter_mut() {
                *b = rng.next_u32() as u8;
            }
            line
        })
        .collect()
}

fn assert_oracles_agree(pjrt: &mut PjrtOracle, lines: &[Line], algo: Algo, what: &str) {
    let mut native = NativeOracle;
    let n = native.analyze(algo, lines);
    let p = pjrt.analyze(algo, lines);
    assert_eq!(n.len(), p.len());
    for (i, (nv, pv)) in n.iter().zip(&p).enumerate() {
        assert_eq!(
            nv.size_bytes, pv.size_bytes,
            "{what}: {algo:?} line {i} size mismatch (native {nv:?} vs pjrt {pv:?})"
        );
        assert_eq!(nv.bursts, pv.bursts, "{what}: {algo:?} line {i} bursts");
        assert_eq!(
            nv.encoding, pv.encoding,
            "{what}: {algo:?} line {i} encoding (native {nv:?} vs pjrt {pv:?})"
        );
    }
}

#[test]
fn pjrt_matches_native_on_patterned_lines() {
    let Some(mut oracle) = pjrt() else { return };
    let lines = patterned_lines(512);
    for algo in Algo::CONCRETE {
        assert_oracles_agree(&mut oracle, &lines, algo, "patterned");
    }
}

#[test]
fn pjrt_matches_native_on_random_lines() {
    let Some(mut oracle) = pjrt() else { return };
    for seed in [1u64, 2, 3] {
        let lines = random_lines(256, seed);
        for algo in Algo::CONCRETE {
            assert_oracles_agree(&mut oracle, &lines, algo, "random");
        }
    }
}

#[test]
fn pjrt_best_of_all_matches_native() {
    let Some(mut oracle) = pjrt() else { return };
    let lines = patterned_lines(256);
    let mut native = NativeOracle;
    let n = native.analyze(Algo::BestOfAll, &lines);
    let p = oracle.analyze(Algo::BestOfAll, &lines);
    for (i, (nv, pv)) in n.iter().zip(&p).enumerate() {
        assert_eq!(nv.size_bytes, pv.size_bytes, "best line {i}");
        assert_eq!(nv.bursts, pv.bursts, "best line {i}");
    }
}

#[test]
fn pjrt_handles_partial_batches() {
    let Some(mut oracle) = pjrt() else { return };
    // Non-multiple-of-BATCH sizes exercise the padding path.
    for n in [1usize, 7, 255, 257, 300] {
        let lines = patterned_lines(n);
        let v = oracle.analyze(Algo::Bdi, &lines);
        assert_eq!(v.len(), n);
        let mut native = NativeOracle;
        let nv = native.analyze(Algo::Bdi, &lines);
        assert_eq!(v, nv, "n={n}");
    }
}

#[test]
fn simulator_runs_with_pjrt_oracle() {
    // End-to-end: the simulator's request path served by the AOT artifact.
    let Some(oracle) = pjrt() else { return };
    let app = caba::workload::apps::find("PVC").unwrap();
    let mut cfg = caba::SimConfig::default();
    cfg.n_sms = 2;
    cfg.max_cycles = 100_000;
    let design = caba::sim::designs::Design::caba(Algo::Bdi);
    let memo = caba::compress::oracle::MemoOracle::new(oracle);
    let mut sim =
        caba::sim::Simulator::with_oracle(cfg.clone(), design, app, 0.004, Box::new(memo));
    let pjrt_stats = sim.run();
    assert!(pjrt_stats.finished);
    // Must be cycle-identical to the native-oracle run (the oracle is a
    // pure function; the backend cannot change timing).
    let mut native_sim = caba::sim::Simulator::new(cfg, design, app, 0.004);
    let native_stats = native_sim.run();
    assert_eq!(pjrt_stats.cycles, native_stats.cycles);
    assert_eq!(pjrt_stats.dram.bursts, native_stats.dram.bursts);
}

#[test]
fn corrupt_artifact_fails_loudly() {
    // Failure injection: a malformed artifact must produce an error at
    // load time, never a silent mis-compile.
    let dir = std::env::temp_dir().join("caba_corrupt_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("bdi.hlo.txt"), "HloModule garbage !!! not hlo").unwrap();
    let res = PjrtOracle::load(&dir);
    assert!(res.is_err(), "corrupt artifact must not load");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn empty_artifact_dir_is_an_error() {
    let dir = std::env::temp_dir().join("caba_empty_artifacts");
    std::fs::create_dir_all(&dir).unwrap();
    let res = PjrtOracle::load(&dir);
    assert!(res.is_err());
    let msg = format!("{:#}", res.err().unwrap());
    assert!(msg.contains("make artifacts"), "error must tell the user the fix: {msg}");
    let _ = std::fs::remove_dir_all(&dir);
}
